"""Analysis throughput vs the paper's reported times.

The paper: 12 s/class (Digits, 0.7M params) and 4.2 h/class (MobileNet,
27M params), bottlenecked by per-scalar MPFI allocation. Our tensorised
engine analyses *by layer*, not by scalar — we measure jitted steady-state
analysis time vs parameter count and extrapolate the MobileNet-class
speedup.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import caa
from repro.core.backend import CaaOps
from repro.models import paper_models as PM


def _time_analysis(h1, h2, d_in=784, reps=3):
    params = PM.init_digits(jax.random.PRNGKey(0), d_in, h1, h2)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    cfg = caa.CaaConfig(u_max=2**-7)
    x = np.random.RandomState(0).rand(d_in)

    def run(xv):
        bk = CaaOps(cfg)
        out = PM.digits_forward(bk, params, caa.weight(xv, cfg))
        return out.dbar, out.ebar

    jrun = jax.jit(run)
    xv = jnp.asarray(x)
    t0 = time.perf_counter()
    jax.block_until_ready(jrun(xv))
    compile_t = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(jrun(xv))
    steady = (time.perf_counter() - t0) / reps
    return n_params, compile_t, steady


def _class_ranges(n_classes=10, d_in=784, pad=0.02, seed=0):
    rng = np.random.RandomState(seed)
    lo = np.clip(rng.rand(n_classes, d_in) - pad, 0.0, 1.0)
    return lo, np.clip(lo + 2 * pad, None, 1.0)


def _bench_batched_vs_sequential(h1=64, h2=32, n_classes=10, reps=3):
    """The tentpole measurement: the paper's 'one run per class' loop vs one
    class-stacked CAA pass (repro.core.analyze.analyze_batched)."""
    from repro.core import analyze
    from repro.core.backend import CaaOps

    params = PM.init_digits(jax.random.PRNGKey(0), 784, h1, h2)
    cfg = caa.CaaConfig(u_max=2**-11)
    lo, hi = _class_ranges(n_classes)

    t0 = time.perf_counter()
    for _ in range(reps):
        for c in range(n_classes):
            out = PM.digits_forward(CaaOps(cfg), params,
                                    caa.from_range(lo[c], hi[c]))
            jax.block_until_ready(out.dbar)
    t_seq = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for _ in range(reps):
        rep = analyze.analyze_batched(
            PM.digits_forward, params, caa.from_range(lo, hi), cfg=cfg)
    t_bat = (time.perf_counter() - t0) / reps
    return t_seq, t_bat


def _bench_certified_store(d_in=64, h1=64, h2=32, n_classes=10):
    """Certified-vs-uncached: full certify (analysis + probes + persist) vs
    the same request served from the content-addressed store. d_in is kept
    small enough that the classes actually certify, so the cold path pays
    the full multi-probe required-k search."""
    import shutil
    import tempfile

    from repro import certify

    params = PM.init_digits(jax.random.PRNGKey(0), d_in, h1, h2)
    lo, hi = _class_ranges(n_classes, d_in=d_in, pad=0.01)
    root = tempfile.mkdtemp(prefix="certbench_")
    try:
        store = certify.CertificateStore(root)
        t0 = time.perf_counter()
        certify.certify(PM.digits_forward, params, list(lo), list(hi),
                        p_star=0.6, model_id="bench/digits", store=store)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        cs = certify.certify(PM.digits_forward, params, list(lo), list(hi),
                             p_star=0.6, model_id="bench/digits", store=store)
        t_hot = time.perf_counter() - t0
        assert cs.meta["from_store"], "store should have served the re-request"
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return t_cold, t_hot


def _bench_probe_ladder(d_in=64, h1=64, h2=32, n_classes=10,
                        ks=(24, 20, 16, 12, 10, 8, 6, 4)):
    """ISSUE-2 acceptance measurement: the per-k eager re-analysis loop vs
    the jit-once probe ladder over the same k grid. Asserts the ladder's
    whole grid cost exactly ONE compilation."""
    import dataclasses

    from repro.certify import batch as B
    from repro.core import analyze

    params = PM.init_digits(jax.random.PRNGKey(0), d_in, h1, h2)
    lo, hi = _class_ranges(n_classes, d_in=d_in, pad=0.01)
    x = B.stack_class_ranges(list(lo), list(hi))

    t0 = time.perf_counter()
    for k in ks:
        cfg = dataclasses.replace(caa.DEFAULT_CONFIG, u_max=2.0 ** (1 - k))
        analyze.analyze_batched(PM.digits_forward, params, x, cfg=cfg)
    t_eager = time.perf_counter() - t0

    ladder = B.ProbeLadder(PM.digits_forward, params, x)
    t0 = time.perf_counter()
    ladder(ks[0])                      # first call pays the one compilation
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    for k in ks[1:]:
        ladder(k)
    t_steady = (time.perf_counter() - t0) / max(len(ks) - 1, 1)
    assert ladder.compiles == 1, (
        f"probe ladder compiled {ladder.compiles}× for the k grid")
    return t_eager / len(ks), t_compile, t_steady


def _bench_mixed_vs_uniform_serving(d_in=64, h1=256, h2=128, batch=256,
                                    reps=20):
    """Serving throughput of the certified backends: uniform QuantJOps vs
    MixedQuantJOps (scope-resolved per-layer k). On emulation hardware both
    pay the same GEMMs — the measurement shows the mixed path's scope
    resolution is compile-time-only (no steady-state overhead) while its
    FLOP-weighted mean k (the real-silicon cost) drops."""
    from repro.launch.serve import MixedQuantJOps, QuantJOps

    params = PM.init_digits(jax.random.PRNGKey(0), d_in, h1, h2)
    x = jnp.asarray(np.random.RandomState(0).rand(batch, d_in), jnp.float32)
    uniform_k = 21
    layer_k = {"dense1": 21, "dense2": 18, "dense3": 14, "softmax": 10}

    def timed(bk):
        f = jax.jit(lambda p, xx: PM.digits_forward(bk, p, xx))
        jax.block_until_ready(f(params, x))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f(params, x))
        return (time.perf_counter() - t0) / reps

    t_uni = timed(QuantJOps(uniform_k))
    t_mix = timed(MixedQuantJOps(layer_k, uniform_k))
    from repro.certify.mixed import flop_weighted_mean_k
    flops = {"dense1": 2.0 * d_in * h1, "dense2": 2.0 * h1 * h2,
             "dense3": 2.0 * h2 * 10, "softmax": 4.0 * 10}
    mean_k = flop_weighted_mean_k(layer_k, flops)
    return t_uni, t_mix, uniform_k, mean_k


def _bench_format_sweep_vs_mantissa(d_in=64, h1=64, h2=32, n_classes=10):
    """Format synthesis (range pass + exponent-lattice descent + eager
    confirmation) vs the mantissa-only certification it extends — the cost
    of certifying (k, emin, emax) instead of k alone, on the same model."""
    from repro.certify import batch as B
    from repro.certify import formats as FS

    params = PM.init_digits(jax.random.PRNGKey(0), d_in, h1, h2)
    lo, hi = _class_ranges(n_classes, d_in=d_in, pad=0.01)
    x = B.stack_class_ranges(list(lo), list(hi))
    feasible = B.margin_feasibility(0.6)

    t0 = time.perf_counter()
    ks, _reports = B.required_k_batched(
        PM.digits_forward, params, x, feasible, k_max=24,
        ladder=B.ProbeLadder(PM.digits_forward, params, x))
    t_mantissa = time.perf_counter() - t0
    uniform_k = int(np.nanmax(ks))

    t0 = time.perf_counter()
    plan = FS.synthesize_formats(
        PM.digits_forward, params, x, feasible, uniform_k)
    t_formats = time.perf_counter() - t0
    assert plan.feasible and plan.compiles == 1
    return t_mantissa, t_formats, plan.savings_bits(), plan.probes


def _bench_scalar_prefetch_vs_recompile(M=256, K=256, N=256, n_formats=8,
                                        reps=5):
    """Serving-format agility: the traced-(k, emax, emin) GEMM (one
    compilation serves every certified format — the scalar-prefetch
    contract) vs the static-format path that recompiles per format. The
    measured quantity is wall-clock across a sweep of formats, i.e. what a
    format-map rollout/canary actually pays."""
    import functools

    from repro.kernels.quant_matmul import quant_matmul_format_ref

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(M, K).astype(np.float32))
    w = jnp.asarray(rng.randn(K, N).astype(np.float32))
    fmts = [(k, 2 ** (e - 1) - 1, 2 - 2 ** (e - 1))
            for k, e in zip(range(8, 8 + n_formats),
                            [3, 4, 5, 6] * ((n_formats + 3) // 4))]

    dyn = jax.jit(quant_matmul_format_ref)
    jax.block_until_ready(dyn(x, w, jnp.asarray(fmts[0], jnp.int32)))
    t0 = time.perf_counter()
    for _ in range(reps):
        for f in fmts:
            jax.block_until_ready(dyn(x, w, jnp.asarray(f, jnp.int32)))
    t_dyn = (time.perf_counter() - t0) / reps
    assert dyn._cache_size() == 1

    def static_fn(f):
        # a fresh jit per format — the per-format-recompile baseline
        return jax.jit(functools.partial(
            lambda xx, ww, kk, ee, mm: quant_matmul_format_ref(
                xx, ww, jnp.asarray([kk, ee, mm], jnp.int32)),
            kk=f[0], ee=f[1], mm=f[2]))

    t0 = time.perf_counter()
    for f in fmts:
        jax.block_until_ready(static_fn(f)(x, w))
    t_static = time.perf_counter() - t0
    return t_dyn, t_static, n_formats


def _bench_stacked_vs_unrolled(depths=(2, 4, 8), reps=3):
    """Tentpole measurement (scan-native CAA): analysis cost vs model depth.

    The eager path unrolls layer_loop in Python — per-layer CAA dispatch,
    O(L) work and (under jit) O(L) HLO. The stacked path traces ONE scan
    body with the per-layer knobs as traced [L] lanes — O(1) HLO in depth,
    one compilation for every depth's whole probe grid. Reports eager wall
    clock, stacked compile+steady, and the traced-graph size ratio."""
    import dataclasses as dc

    from repro import configs
    from repro.core import analyze
    from repro.core.backend import CaaOps, StackedCaaOps
    from repro.models import transformer as T

    smoke = configs.get("qwen2_7b").SMOKE
    cfg0 = dc.replace(smoke, n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                      d_head=16, d_ff=64, vocab=64)
    ccfg = caa.CaaConfig(u_max=2.0 ** -20)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg0.vocab)
    rows = []
    for L in depths:
        arch = dc.replace(cfg0, n_layers=L)
        params = T.init_params(jax.random.PRNGKey(0), arch)

        t0 = time.perf_counter()
        out, _ = T.forward(CaaOps(ccfg), params, arch, tokens)
        jax.block_until_ready(out.dbar)
        t_eager = time.perf_counter() - t0

        def bounds(p, u):
            ops = StackedCaaOps(dc.replace(ccfg, u_max=u))
            o, _ = T.forward(ops, p, arch, tokens)
            return jnp.max(o.dbar)

        jb = jax.jit(bounds)
        t0 = time.perf_counter()
        jax.block_until_ready(jb(params, jnp.asarray(2.0 ** -20)))
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        for r in range(reps):
            jax.block_until_ready(jb(params, jnp.asarray(2.0 ** -(21 + r))))
        t_steady = (time.perf_counter() - t0) / reps
        assert jb._cache_size() == 1
        print(f"  L={L:2d}  eager unrolled: {t_eager:7.2f} s   stacked scan: "
              f"{t_compile:6.2f} s compile + {t_steady * 1e3:7.1f} ms/probe "
              f"(1 compilation)")
        rows.append((L, t_eager, t_compile, t_steady))
    return rows


def run_stacked():
    print("\n== scan-native CAA: stacked analysis vs per-layer unrolling ==")
    rows = _bench_stacked_vs_unrolled()
    (L0, e0, _, s0), (L1, e1, _, s1) = rows[0], rows[-1]
    print(f"depth {L0}→{L1}: eager wall grows ×{e1 / e0:.1f}, stacked "
          f"steady-probe ×{s1 / s0:.1f} (jit-once; HLO flat in depth — "
          f"see tests/test_stacked.py jaxpr-size assertion)")
    return [
        (f"caa_eager_unrolled_L{L}_s", t_e * 1e6, t_e)
        for (L, t_e, _, _) in rows
    ] + [
        (f"caa_stacked_probe_L{L}_s", t_s * 1e6, t_s)
        for (L, _, _, t_s) in rows
    ]


def run_formats():
    print("\n== full-format certificates: synthesis cost + format agility ==")
    t_k, t_fmt, saved, probes = _bench_format_sweep_vs_mantissa()
    print(f"certification      mantissa-only: {t_k:8.3f} s   "
          f"full (k, emin, emax) synthesis: {t_fmt:8.3f} s   "
          f"(+{t_fmt / t_k:.1f}× analysis → −{saved:.1f} bits/value, "
          f"{probes} lattice probes, 1 compile)")
    t_dyn, t_static, nf = _bench_scalar_prefetch_vs_recompile()
    print(f"format sweep GEMM  scalar-prefetch (1 compile): "
          f"{t_dyn*1e3:8.1f} ms/{nf} formats   per-format recompile: "
          f"{t_static*1e3:8.1f} ms   (×{t_static / t_dyn:.1f})")
    return [
        ("certify_mantissa_only_s", t_k * 1e6, t_k),
        ("certify_full_formats_s", t_fmt * 1e6, t_fmt),
        ("gemm_format_sweep_prefetch_s", t_dyn * 1e6, t_dyn),
        ("gemm_format_sweep_recompile_s", t_static * 1e6, t_static),
    ]


def run_mixed():
    print("\n== mixed-precision certificates: jitted ladder + serving ==")
    t_eager, t_compile, t_steady = _bench_probe_ladder()
    print(f"probe cost/k       eager re-analysis: {t_eager*1e3:8.1f} ms   "
          f"jitted ladder: {t_steady*1e3:8.2f} ms steady "
          f"({t_compile:.2f}s one-off compile, 1 compilation total, "
          f"×{t_eager / t_steady:,.0f})")
    t_uni, t_mix, uk, mk = _bench_mixed_vs_uniform_serving()
    print(f"serving throughput uniform k={uk}: {t_uni*1e3:8.2f} ms/batch   "
          f"mixed (mean k={mk:.1f}): {t_mix*1e3:8.2f} ms/batch   "
          f"(emulated; real-silicon FLOP-cost ∝ k: "
          f"−{100*(uk-mk)/uk:.0f}% bits/FLOP)")
    return [
        ("probe_eager_per_k_s", t_eager * 1e6, t_eager),
        ("probe_ladder_steady_s", t_steady * 1e6, t_steady),
        ("serve_uniform_k_s", t_uni * 1e6, t_uni),
        ("serve_mixed_k_s", t_mix * 1e6, t_mix),
    ]


def run_certify():
    print("\n== certificate pipeline: batched classes + store ==")
    t_seq, t_bat = _bench_batched_vs_sequential()
    print(f"10-class analysis  sequential loop: {t_seq:8.3f} s   "
          f"batched single pass: {t_bat:8.3f} s   (×{t_seq / t_bat:.2f})")
    t_cold, t_hot = _bench_certified_store()
    print(f"certify request    cold (analyse+persist): {t_cold:8.3f} s   "
          f"store hit: {t_hot*1e3:8.2f} ms   (×{t_cold / t_hot:,.0f})")
    return [
        ("multiclass_sequential_s", t_seq * 1e6, t_seq),
        ("multiclass_batched_s", t_bat * 1e6, t_bat),
        ("certify_cold_s", t_cold * 1e6, t_cold),
        ("certify_store_hit_s", t_hot * 1e6, t_hot),
    ]


def run():
    print("\n== analysis speed vs model size (CAA engine, jitted) ==")
    print(f"{'params':>12s} {'compile(s)':>11s} {'steady(s)':>10s} "
          f"{'per-Mparam(ms)':>15s}")
    rows = []
    for h1, h2 in [(128, 64), (700, 256), (2048, 1024)]:
        n, ct, st = _time_analysis(h1, h2)
        print(f"{n:12d} {ct:11.2f} {st:10.4f} {1e3 * st / (n / 1e6):15.2f}")
        rows.append((f"analysis_{n // 1000}k_params", st * 1e6,
                     st / (n / 1e6)))
    # paper comparison at the Digits scale (~0.7M): 12 s/class there
    n, ct, st = _time_analysis(700, 256)
    speedup = 12.0 / st
    print(f"paper Digits-scale: 12 s/class → ours {st * 1e3:.1f} ms/class "
          f"(speedup ×{speedup:,.0f})")
    rows.append(("digits_speedup_x", st * 1e6, speedup))
    rows.extend(run_certify())
    rows.extend(run_mixed())
    rows.extend(run_formats())
    rows.extend(run_stacked())
    return rows


if __name__ == "__main__":
    run()
