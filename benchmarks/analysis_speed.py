"""Analysis throughput vs the paper's reported times.

The paper: 12 s/class (Digits, 0.7M params) and 4.2 h/class (MobileNet,
27M params), bottlenecked by per-scalar MPFI allocation. Our tensorised
engine analyses *by layer*, not by scalar — we measure jitted steady-state
analysis time vs parameter count and extrapolate the MobileNet-class
speedup.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import caa
from repro.core.backend import CaaOps
from repro.models import paper_models as PM


def _time_analysis(h1, h2, d_in=784, reps=3):
    params = PM.init_digits(jax.random.PRNGKey(0), d_in, h1, h2)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    cfg = caa.CaaConfig(u_max=2**-7)
    x = np.random.RandomState(0).rand(d_in)

    def run(xv):
        bk = CaaOps(cfg)
        out = PM.digits_forward(bk, params, caa.weight(xv, cfg))
        return out.dbar, out.ebar

    jrun = jax.jit(run)
    xv = jnp.asarray(x)
    t0 = time.perf_counter()
    jax.block_until_ready(jrun(xv))
    compile_t = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(jrun(xv))
    steady = (time.perf_counter() - t0) / reps
    return n_params, compile_t, steady


def run():
    print("\n== analysis speed vs model size (CAA engine, jitted) ==")
    print(f"{'params':>12s} {'compile(s)':>11s} {'steady(s)':>10s} "
          f"{'per-Mparam(ms)':>15s}")
    rows = []
    for h1, h2 in [(128, 64), (700, 256), (2048, 1024)]:
        n, ct, st = _time_analysis(h1, h2)
        print(f"{n:12d} {ct:11.2f} {st:10.4f} {1e3 * st / (n / 1e6):15.2f}")
        rows.append((f"analysis_{n // 1000}k_params", st * 1e6,
                     st / (n / 1e6)))
    # paper comparison at the Digits scale (~0.7M): 12 s/class there
    n, ct, st = _time_analysis(700, 256)
    speedup = 12.0 / st
    print(f"paper Digits-scale: 12 s/class → ours {st * 1e3:.1f} ms/class "
          f"(speedup ×{speedup:,.0f})")
    rows.append(("digits_speedup_x", st * 1e6, speedup))
    return rows


if __name__ == "__main__":
    run()
