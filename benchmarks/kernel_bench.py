"""Kernel micro-benchmarks.

On this CPU container, Pallas runs in interpret mode (Python loop over the
grid) so wall-clock is meaningless for TPU; what we CAN measure and report:
  * correctness-path timings of the jnp reference implementations (the
    pre-kernel baseline a TPU would run without fusion);
  * the *HBM-traffic model*: bytes the fused kernel moves vs the naive
    composition — the quantity the kernel exists to improve (the fused
    interval GEMM reads x once for 3 GEMMs; naive reads 3×).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _timeit(f, *args, reps=5):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    M, K, N = 512, 1024, 512
    rng = np.random.RandomState(0)
    lo = jnp.asarray(rng.randn(M, K), jnp.float32)
    hi = lo + 0.01
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    d = jnp.abs(lo) * 0.1

    jref_int = jax.jit(lambda a, b, c: ref.interval_matmul_ref(a, b, c))
    jref_caa = jax.jit(lambda a, b, c: ref.caa_matmul_ref(a, b, c, 3.0))
    jref_q = jax.jit(lambda a, b: ref.quant_matmul_ref(a, b, 8))

    t = _timeit(jref_int, lo, hi, w)
    rows.append(("interval_matmul_ref_512x1024x512", t * 1e6, 0))
    t = _timeit(jref_caa, lo, d, w)
    rows.append(("caa_matmul_ref_512x1024x512", t * 1e6, 0))
    t = _timeit(jref_q, lo, w)
    rows.append(("quant_matmul_ref_512x1024x512", t * 1e6, 0))

    # HBM traffic model (bytes): fused kernel vs naive composition
    bytes_x = M * K * 4
    bytes_w = K * N * 4
    bytes_out = M * N * 4
    naive_interval = 3 * (2 * bytes_x + bytes_w) + 3 * bytes_out  # lo,hi reads ×3 GEMMs
    fused_interval = (2 * bytes_x + bytes_w) + 3 * bytes_out
    rows.append(("interval_fusion_traffic_ratio", 0.0,
                 naive_interval / fused_interval))
    naive_caa = 2 * (bytes_x + bytes_w) + 2 * bytes_out + bytes_x  # val+err GEMMs + dbar read
    fused_caa = 2 * bytes_x + bytes_w + 2 * bytes_out
    rows.append(("caa_fusion_traffic_ratio", 0.0, naive_caa / fused_caa))

    print("\n== kernel benches (CPU ref timings + HBM-traffic model) ==")
    for name, us, der in rows:
        print(f"{name:40s} {us:12.1f}us  derived={der:.3g}")
    return rows


if __name__ == "__main__":
    run()
