"""Kernel micro-benchmarks — measured, roofline-anchored, trajectory-kept.

Rebuilt on :mod:`repro.obs.profile` (warmup + median-of-k discipline, one
shared implementation): times the certified serving kernels — baseline
``jnp.matmul``, ``quant_matmul_dynamic_k`` (traced-k), the scalar-prefetch
``quant_matmul_format`` across Pallas block candidates, and
``flash_decode_attention`` — and a micro serving profile (real
``build_serve_steps`` prefill/decode with compile-time and jaxpr-size
gauges, p50/p95/p99 from the log-bucket histograms).

Every run appends ONE entry to the ``BENCH_kernels.json`` trajectory
(repo root, mirrored under ``benchmarks/``): measured rows + achieved
FLOP/s + analytic roofline terms + the serving digest, so each PR records
its perf point and ``python -m repro.obs report --kernels`` /
``python -m repro.obs perfgate`` can render and diff the trajectory.

On this CPU container Pallas runs in interpret mode, so the Pallas rows'
absolute wall-clock is mechanism-true but not TPU-predictive (rows carry
``interpret: true``); the jnp-path rows (baseline, dynamic-k) are real
XLA:CPU timings, and the roofline columns are analytic either way.
"""
from __future__ import annotations

import jax


def run(serving: bool = True, reps: int = 3, warmup: int = 1):
    from repro import obs
    from repro.obs import costmodel as CM
    from repro.obs import profile as P

    rows = P.profile_kernels(
        gemm_shapes=((128, 128, 128), (128, 256, 128)),
        ks=(8, 24),
        formats=((4, 8, -6), (8, 15, -14)),
        flash_shapes=((2, 256, 2, 2, 64),),
        reps=reps, warmup=warmup)

    entry = {
        "kind": "kernel_bench",
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "hardware": CM.TPU_POD_CHIP.name,
        "rows": [{k: v for k, v in r.items() if k != "samples"}
                 for r in rows],
    }

    serving_profile = None
    if serving:
        # ≥1 measured serving point per PR, CPU-feasible: 1 layer, tiny
        # batch — compile-time/jaxpr gauges and percentile digests are the
        # signal here, not absolute throughput
        try:
            serving_profile = P.profile_serving(
                arch="qwen2_7b", max_layers=1, batch=2,
                prefill_len=8, decode_steps=6)
            entry["serving"] = serving_profile
        except Exception as e:  # pragma: no cover — keep the bench alive
            print(f"(serving profile skipped: {type(e).__name__}: {e})")

    try:
        model = CM.fit_cost_model(rows)
        entry["cost_model"] = model.to_dict()
    except ValueError:
        model = None

    obs.append_bench("kernels", entry)

    # harness contract: (name, us_per_call, derived) rows for run.py's CSV;
    # derived = fraction of the analytic roofline achieved
    out = []
    for r in rows:
        fmt = (f"_k{r['k']}" if r.get("k") is not None else "")
        blk = ("_b" + "x".join(map(str, r["block"]))
               if r.get("block") else "")
        out.append((f"{r['kernel']}_{r['shape']}{fmt}{blk}",
                    r["median_s"] * 1e6, round(r["roofline_frac"], 6)))
    if serving_profile:
        pre = serving_profile["prefill"]
        pct = serving_profile["decode"]["percentiles"]
        out.append(("serve_prefill_smoke", pre["latency_s"] * 1e6,
                    pre["jaxpr_eqns"]))
        out.append(("serve_decode_p50", pct["p50"] * 1e6, 0))
        out.append(("serve_decode_p99", pct["p99"] * 1e6, 0))

    print("\n== kernel benches (measured median vs analytic roofline) ==")
    from repro.obs import report as R
    print(R.render_kernel_table(obs.read_bench("kernels")))
    if model is not None:
        print("fitted cost model (achieved rates):")
        for k in sorted(model.alpha):
            print(f"  {k:<26} alpha={model.alpha[k]:.3g} FLOP/s  "
                  f"beta={model.beta[k]:.3g} B/s")
    return out


if __name__ == "__main__":
    run()
