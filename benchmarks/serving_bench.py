"""Serving-throughput bench: certified vs uncertified tokens/s.

Steady-state decode throughput of the continuous-batching engine
(:mod:`repro.launch.batching`) across batch sizes × mesh shapes, in three
modes: uncertified f32, uniform certified k (QuantJOps), and a per-scope
certified format map (FormatQuantJOps + certificate-aware flash decode).
The paper's serving claim is that certified execution is *cheap*: the
emulated quantisation rides inside the same scanned body, so certified
tokens/s should stay within ~1.5× of uncertified at batch ≥ 8 — the
``--assert-ratio`` rail CI enforces on the forced-host multi-device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Every run appends ONE entry to the ``BENCH_serving.json`` trajectory
(same dedupe + ``python -m repro.obs perfgate --name serving`` rails as
``BENCH_kernels.json``); rows carry ``kernel``/``shape``/``k``/
``median_s`` so the perfgate's row identity works unchanged. On CPU the
absolute numbers are emulation wall-clock, not TPU-predictive — the
trajectory's job is catching relative regressions in the serving path.
"""
from __future__ import annotations

import time

import jax
import numpy as np


_FMT_MAP = {"": {"k": 11, "emax": 15, "emin": -14},
            "layer*/attn": {"k": 8, "emax": 15, "emin": -14}}


def _tokens_per_s(arch_cfg, sc, params, mesh, batch, *, max_seq=64,
                  page_size=16, prompt_len=8, steps=8, warmup=3):
    """Decode-step latency with every lane occupied.

    Reports the MIN over measured steps (best-of): on shared CI runners
    the scheduler-noise tail is one-sided, and the certified/uncertified
    *ratio* — the rail — needs the noise-free floor of each mode, not a
    median that each mode samples with different luck."""
    from repro.launch.batching import ContinuousBatchingEngine, Request

    engine = ContinuousBatchingEngine(
        arch_cfg, sc, params, mesh=mesh, n_lanes=batch, max_seq=max_seq,
        page_size=page_size, queue_depth=batch)
    rng = np.random.RandomState(0)
    for i in range(batch):
        ok = engine.submit(Request(
            rid=i, prompt=rng.randint(0, arch_cfg.vocab, prompt_len).tolist(),
            max_new_tokens=max_seq - prompt_len))
        assert ok, "bench request rejected at admission"
    for _ in range(warmup):            # admission + prefill/decode compiles
        engine.step()
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        assert engine.step(), "bench lanes drained mid-measurement"
        times.append(time.perf_counter() - t0)
    best = min(times)
    return best, batch / best


def run(batches=(1, 4), *, k=12, include_format=False, steps=8, warmup=3,
        max_seq=64, assert_ratio=None):
    from repro import configs, obs
    from repro.launch import mesh as meshlib, serve
    from repro.models import transformer as T

    arch = "qwen2_7b"
    arch_cfg = configs.get(arch).SMOKE
    params = T.init_params(jax.random.PRNGKey(0), arch_cfg)
    devs = meshlib.device_count()
    mesh_shapes = [(1, 1)]
    if devs > 1:
        mesh_shapes.append((devs, 1))
        if devs >= 4 and devs % 2 == 0:
            mesh_shapes.append((devs // 2, 2))

    def _sc(**kw):
        return serve.ServeConfig(arch=arch, batch=max(batches),
                                 max_seq=max_seq, **kw)

    modes = [("uncertified", _sc(), {}),
             ("certified", _sc(precision_k=k), {"k": k})]
    if include_format:
        f = _FMT_MAP[""]
        modes.append(("certified_format",
                      _sc(precision_layer_format=_FMT_MAP),
                      {"k": f["k"], "emax": f["emax"], "emin": f["emin"]}))

    rows, tps = [], {}
    for d, m in mesh_shapes:
        mesh = meshlib.make_serving_mesh(data=d, model=m)
        for b in batches:
            for mode, sc, ident in modes:
                if mode == "certified_format" and b != max(batches):
                    continue           # one format point bounds the sweep
                med, t = _tokens_per_s(arch_cfg, sc, params, mesh, b,
                                       max_seq=max_seq, steps=steps,
                                       warmup=warmup)
                shape = f"{arch}_b{b}_mesh{d}x{m}"
                rows.append(dict(kernel=f"serving_decode_{mode}",
                                 shape=shape, median_s=med,
                                 tokens_per_s=round(t, 2), batch=b,
                                 mesh=[d, m], **ident))
                tps[(mode, b, d, m)] = t
                print(f"  {mode:<17} b={b:<3} mesh={d}x{m}  "
                      f"{med * 1e3:8.2f} ms/step  {t:8.1f} tok/s")

    # the acceptance ratio: certified within `assert_ratio`× of
    # uncertified at the largest batch, per mesh shape. The rail applies
    # to data-only meshes (the serving default): with model > 1 the
    # per-layer collectives dominate these toy shapes and the ratio
    # measures collective jitter, not quantisation cost — those points
    # are recorded but advisory.
    ratios, advisory = {}, {}
    bmax = max(batches)
    for d, m in mesh_shapes:
        u = tps.get(("uncertified", bmax, d, m))
        c = tps.get(("certified", bmax, d, m))
        if u and c:
            (ratios if m == 1 else advisory)[
                f"b{bmax}_mesh{d}x{m}"] = round(u / c, 3)

    entry = {
        "kind": "serving_bench", "arch": arch,
        "backend": jax.default_backend(), "devices": devs,
        "batches": list(batches), "k": k,
        "rows": rows, "certified_slowdown": ratios,
        "certified_slowdown_model_parallel": advisory,
    }
    obs.append_bench("serving", entry)
    print(f"certified slowdown (uncert tok/s ÷ cert tok/s) @b{bmax}: "
          f"{ratios}  (model-parallel, advisory: {advisory})")
    if assert_ratio is not None:
        bad = {kk: v for kk, v in ratios.items() if v > assert_ratio}
        if bad:
            raise SystemExit(
                f"certified serving slower than {assert_ratio}x "
                f"uncertified: {bad}")
        print(f"ratio rail ok (≤ {assert_ratio}x)")

    # harness contract: (name, us_per_call, derived=tokens/s)
    return [(f"{r['kernel']}_{r['shape']}", r["median_s"] * 1e6,
             r["tokens_per_s"]) for r in rows]


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--k", type=int, default=12)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--warmup", type=int, default=3)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--no-format", action="store_true")
    ap.add_argument("--assert-ratio", type=float, default=None,
                    help="fail if certified tokens/s falls further than "
                         "this factor below uncertified at max batch")
    args = ap.parse_args(argv)
    run(tuple(args.batches), k=args.k, include_format=not args.no_format,
        steps=args.steps, warmup=args.warmup, max_seq=args.max_seq,
        assert_ratio=args.assert_ratio)


if __name__ == "__main__":
    main()
