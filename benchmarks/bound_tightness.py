"""Bound tightness: rigorous CAA bound vs measured error of real k-bit runs,
across precisions and accumulation orders — quantifies the engine's
conservatism (a rigorous bound is useful only if it is within a small
factor of reality)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import caa, formats, quantize
from repro.core.backend import CaaOps


def run():
    rng = np.random.RandomState(0)
    n, m = 256, 64
    x = rng.rand(n) * (rng.rand(n) > 0.5)
    W = rng.randn(n, m) / np.sqrt(n)
    exact = x @ W

    print("\n== dot-product bound tightness (trained-scale weights) ==")
    print(f"{'k':>3s} {'order':>10s} {'measured(u)':>12s} {'bound(u)':>10s} "
          f"{'ratio':>7s}")
    rows = []
    for k in (6, 8, 12, 16):
        fmt = formats.custom(k)
        for order in ("sequential", "pairwise"):
            cfg = caa.CaaConfig(u_max=fmt.u, emulate_k=k, acc_order=order)
            res = caa.matmul(caa.weight(x, cfg), caa.weight(W, cfg), cfg)
            emp = quantize.seq_dot(jnp.asarray(x)[None], jnp.asarray(W), fmt)[0] \
                if order == "sequential" else \
                quantize.pairwise_dot(jnp.asarray(x)[None], jnp.asarray(W), fmt)[0]
            meas = float(jnp.max(jnp.abs(emp - exact))) / fmt.u
            bound = float(jnp.max(res.dbar))
            print(f"{k:3d} {order:>10s} {meas:12.3g} {bound:10.3g} "
                  f"{bound / max(meas, 1e-9):7.1f}")
            rows.append((f"tightness_k{k}_{order}", 0.0,
                         bound / max(meas, 1e-9)))
    return rows


if __name__ == "__main__":
    run()
