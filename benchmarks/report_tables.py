"""Regenerate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
current artifacts (results/dryrun/*.json) — keeps the document reproducible.

Usage: PYTHONPATH=src:. python -m benchmarks.report_tables
Splices between the markers in EXPERIMENTS.md.
"""
import glob
import json


def dryrun_table() -> str:
    rows = [json.load(open(p)) for p in sorted(glob.glob("results/dryrun/*.json"))]
    ok = [r for r in rows if r["status"] == "ok"]
    sk = [r for r in rows if r["status"] == "skipped"]
    er = [r for r in rows if r["status"] == "error"]
    out = [f"cells: {len(rows)} total — {len(ok)} ok, {len(sk)} skipped "
           f"(documented), {len(er)} errors\n"]
    out.append("| arch | shape | mesh | compile(s) | peak GiB/dev | "
               "HLO flops/iter | coll bytes/iter | AG | AR | A2A | CP |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        c = r["collectives"]["count_by_kind"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.1f} | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | {r['cost']['flops']:.3g} | "
            f"{r['collectives']['total_bytes']:.3g} | {c.get('all-gather',0)} | "
            f"{c.get('all-reduce',0)} | {c.get('all-to-all',0)} | "
            f"{c.get('collective-permute',0)} |")
    out.append("")
    seen = set()
    for r in sk:
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        out.append(f"- SKIP {r['arch']} × {r['shape']}: {r['reason']}")
    for r in er:
        out.append(f"- ERROR {r['arch']} × {r['shape']} ({r['mesh']}): "
                   f"{r.get('error','')[:140]}")
    return "\n".join(out)


def roofline_table() -> str:
    from benchmarks import roofline as R

    cells = R.load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    out = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
           "dominant | MODEL_FLOPS | useful | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in ok:
        r = c["roofline"]
        out.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['model_flops']:.3g} | {min(r['usefulness'],9.99):.2f} | "
            f"{r['mfu_bound']:.3f} |")
    picks = R.interesting_cells(cells)
    out.append("")
    for why, c in picks.items():
        out.append(f"- {why}: **{c['arch']} × {c['shape']}** "
                   f"(dominant={c['roofline']['dominant']})")
    return "\n".join(out)


def splice(doc: str, start_marker: str, end_marker: str, new: str) -> str:
    i = doc.index(start_marker) + len(start_marker)
    j = doc.index(end_marker)
    return doc[:i] + "\n\n" + new + "\n\n" + doc[j:]


def main():
    doc = open("EXPERIMENTS.md").read()
    doc = splice(doc, "<!-- DRYRUN_TABLE -->", "<!-- /DRYRUN_TABLE -->",
                 dryrun_table())
    doc = splice(doc, "<!-- ROOFLINE_TABLE -->", "<!-- /ROOFLINE_TABLE -->",
                 roofline_table())
    open("EXPERIMENTS.md", "w").write(doc)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
