"""Benchmark driver: one function per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV at the end (harness contract).
"""
import sys


def main() -> None:
    rows = []
    from benchmarks import table1, analysis_speed, bound_tightness, kernel_bench

    rows += table1.run()
    rows += analysis_speed.run()
    rows += bound_tightness.run()
    rows += kernel_bench.run()

    # serving-throughput trajectory point (BENCH_serving.json): small
    # single-device sweep here so every CPU CI run records one; the
    # multi-device job runs serving_bench directly with the ratio rail
    from benchmarks import serving_bench
    rows += serving_bench.run(batches=(1, 4), steps=4, warmup=2)

    try:
        from benchmarks import roofline
        rows += roofline.run()
    except Exception as e:  # dry-run results not generated yet
        print(f"(roofline skipped: {type(e).__name__}: {e}; "
              "run `python -m repro.launch.dryrun --both-meshes` first)",
              file=sys.stderr)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # machine-readable trajectory: the same rows, appended as one entry to
    # benchmarks/BENCH_micro.json so regressions are diffable across runs
    from repro.obs import append_bench
    append_bench("micro", {
        "kind": "bench_suite",
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
    })


if __name__ == "__main__":
    main()
