"""Roofline analysis (§Roofline): three terms per (arch × shape), single-pod.

METHOD NOTE (important): XLA's ``compiled.cost_analysis()`` counts a
``while`` loop's body ONCE, and every LM step here iterates layers under
``lax.scan`` (that is what keeps 512-device compiles tractable). The raw
HLO numbers are therefore *per-loop-iteration* quantities. We handle this
honestly:

  * the three roofline terms are computed from ANALYTIC closed forms
    (exact for these GEMM-dominated programs; formulas below), and
  * the HLO-derived numbers are reported as calibration: analytic
    per-layer flops vs HLO per-iteration flops must agree within ~2×
    (asserted in tests/test_roofline.py), and the collective census
    (op kinds/counts from the partitioned HLO) is what the §Perf loop
    watches when it reshards.

Analytic terms (per device, per step), hardware 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI:

  compute  = (dense_flops + attn_flops) / chips / PEAK
     train:   6·N_act·tokens (+12·L·B·S·W_eff·H·dh attn, W_eff=min(S,window))
     prefill: 2·N_act·tokens (+4·L·B·S·W_eff·H·dh)
     decode:  2·N_act·B     (+4·L·B·S_ctx·H·dh_kv)
  memory   = bytes/device / HBM:
     train:   remat streams params 3× (fwd, recompute, bwd) + optimizer
              update (m,v,p read+write ≈ 16B/param f32 or 4B int8-quant)
              + activation traffic ≈ 12·B·S·d·L bytes
     prefill: params 1× + KV cache write + activations
     decode:  params 1× + KV cache read  (the decode wall)
  collective = bytes on ICI / device / LINK:
     train:   FSDP: all-gather params fwd + bwd re-gather + reduce-scatter
              grads ≈ 3·P_bytes·(n_sh−1)/n_sh, n_sh = axes params shard over
     serve:   TP activation collectives ≈ L·(4·B·S_q·d·2B) + any param
              gathers if weights are data-axis-sharded (a serving
              anti-pattern §Perf removes)
"""
import glob
import json
import os

from repro import configs
from repro.configs import SHAPES

# hardware peaks live in repro.obs.costmodel (single source: the measured
# cost model and these analytic terms must price the same machine)
from repro.obs.costmodel import TPU_POD_CHIP as _HW

PEAK = _HW.peak_flops
HBM = _HW.hbm_bytes_per_s
LINK = _HW.link_bytes_per_s
CHIPS = 256  # single-pod


# --------------------------------------------------------------------------
# analytic model
# --------------------------------------------------------------------------

from repro.models.transformer import analytic_params as _analytic_params_impl


def analytic_params(cfg, active: bool = False):
    return _analytic_params_impl(cfg, active)


def _analytic_params_unused(cfg, active: bool = False):
    d, dh = cfg.d_model, cfg.head_dim
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.rwkv:
        per_layer += 5 * d * d + d * 64 + 64 * d
        per_layer += d * cfg.d_ff + cfg.d_ff * d + d * d
    else:
        if cfg.mla:
            per_layer += d * cfg.q_rank + cfg.q_rank * cfg.n_heads * (cfg.d_nope + cfg.d_rope)
            per_layer += d * (cfg.kv_rank + cfg.d_rope)
            per_layer += cfg.kv_rank * cfg.n_heads * (cfg.d_nope + cfg.d_v)
            per_layer += cfg.n_heads * cfg.d_v * d
        else:
            per_layer += d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh
            per_layer += cfg.n_heads * dh * d
        if cfg.hybrid:
            di = cfg.mamba_expand * d
            per_layer += 2 * d * di + di * (2 * cfg.ssm_state + 1) + di * d
        if cfg.family == "moe":
            e = cfg.n_experts if not active else cfg.top_k
            ff = cfg.moe_d_ff or cfg.d_ff
            per_layer += d * cfg.n_experts
            per_layer += e * (2 * d * ff + ff * d)
        else:
            per_layer += 3 * d * cfg.d_ff
    n = emb + cfg.n_layers * per_layer
    if cfg.enc_dec:
        n += cfg.n_enc_layers * (4 * d * dh * cfg.n_heads + 3 * d * cfg.d_ff)
        n += cfg.n_layers * 4 * d * dh * cfg.n_heads
    return n


def _attn_flops(cfg, B, S_q, S_kv, backward: bool):
    """QK^T + PV matmul flops (2 GEMMs, 2 flops/MAC), causal ≈ ×1/2 when
    S_q == S_kv; sliding windows cap the effective context."""
    if cfg.rwkv:
        # linear attention: state updates ≈ 2·B·S·H·C² ×2 (two einsums)
        C = cfg.d_model // cfg.n_heads
        f = 4.0 * B * S_q * cfg.n_heads * C * C
        return f * (3.0 if backward else 1.0)
    W = min(S_kv, cfg.window or S_kv)
    if cfg.local_global_period:
        W = (min(S_kv, cfg.local_global_period) + S_kv) / 2  # half local
    causal = 0.5 if S_q == S_kv else 1.0
    f = 4.0 * cfg.n_layers * B * S_q * W * causal * cfg.n_heads * cfg.head_dim
    return f * (3.0 if backward else 1.0) / cfg.n_layers  # per call: caller ×L


DEFAULT_POLICY = {
    # reflects the implemented baseline; §Perf flips these and re-verifies
    # against the dry-run collective census
    "train_fsdp_gather": True,        # params data-axis sharded, gathered/layer
    "serve_params_data_sharded": True,  # greedy sharding also splits over data
    "param_bits": 16,                 # bf16 storage
    "cache_bits": 16,                 # bf16 KV cache
    "quant_moments": None,            # None → auto by size
    "grad_payload_bits": 16,          # int8 compression sets 8
}

D_AX, M_AX = 16, 16  # single-pod mesh


def analytic_terms(cfg, shape, policy=None):
    """Per-DEVICE roofline terms. See module docstring for the formulas."""
    pol = {**DEFAULT_POLICY, **(policy or {})}
    B, S = shape.batch, shape.seq
    N_act = analytic_params(cfg, active=True)
    N_tot = analytic_params(cfg, active=False)
    P_bytes = N_tot * pol["param_bits"] / 8.0
    L, d = cfg.n_layers, cfg.d_model
    toks = B * S

    if shape.kind == "train":
        dense = 6.0 * N_act * toks
        attn = L * _attn_flops(cfg, B, S, S, backward=True)
        flops_dev = (dense + attn) / CHIPS
        # HBM: weights stream 3× per step (fwd, remat recompute, bwd) at the
        # model-parallel shard size; optimizer update on the /chips shard;
        # activation residual traffic for the local tokens
        qm = pol["quant_moments"]
        qm = (_is_big(cfg) if qm is None else qm)
        opt_bytes = N_tot / CHIPS * (6.0 if qm else 16.0)
        w_stream = 3.0 * P_bytes / M_AX
        act = 24.0 * toks / CHIPS * d * L * 2.0 / 16.0  # model-sharded widths
        mem_dev = w_stream + opt_bytes + act
        # ICI: data-axis all-gathers fwd+bwd + grad reduce-scatter + TP acts
        gb = pol["grad_payload_bits"] / 16.0
        coll_dev = (2.0 * P_bytes / M_AX if pol["train_fsdp_gather"] else 0.0)
        coll_dev += P_bytes / M_AX * gb               # grad RS/AR
        coll_dev += L * 8.0 * (toks / D_AX) * d * 2.0 / M_AX  # TP activation
        model = dense
    elif shape.kind == "prefill":
        dense = 2.0 * N_act * toks
        attn = L * _attn_flops(cfg, B, S, S, backward=False)
        flops_dev = (dense + attn) / CHIPS
        cache_dev = _cache_bytes(cfg, B, S) * pol["cache_bits"] / 16.0 / CHIPS
        act = 8.0 * toks / CHIPS * d * L * 2.0 / 16.0
        mem_dev = P_bytes / M_AX + cache_dev + act
        coll_dev = L * 4.0 * (toks / D_AX) * d * 2.0 / M_AX
        if pol["serve_params_data_sharded"]:
            coll_dev += P_bytes / M_AX               # data-axis AG per pass
        model = dense
    else:  # decode
        dense = 2.0 * N_act * B
        attn = L * _attn_flops(cfg, B, 1, S, backward=False)
        flops_dev = (dense + attn) / CHIPS
        cache_dev = _cache_bytes(cfg, B, S) * pol["cache_bits"] / 16.0 / CHIPS
        mem_dev = P_bytes / M_AX * 1.0 + cache_dev
        coll_dev = L * 4.0 * max(B / D_AX, 1.0) * d * 2.0 / M_AX
        if pol["serve_params_data_sharded"]:
            coll_dev += P_bytes / M_AX
        model = dense

    flops = flops_dev * CHIPS
    return {
        "flops": flops, "mem_bytes": mem_dev, "coll_bytes": coll_dev,
        "model_flops": model, "params": N_tot, "active_params": N_act,
        "compute_s": flops_dev / PEAK,
        "memory_s": mem_dev / HBM,
        "collective_s": coll_dev / LINK,
    }


def _cache_bytes(cfg, B, S):
    if cfg.rwkv:
        C = cfg.d_model // cfg.n_heads
        return 2.0 * B * cfg.n_layers * cfg.n_heads * C * C
    if cfg.mla:
        return 2.0 * B * S * cfg.n_layers * (cfg.kv_rank + cfg.d_rope)
    per = 2 * cfg.n_kv_heads * cfg.head_dim
    return 2.0 * B * S * cfg.n_layers * per


def _is_big(cfg):
    return analytic_params(cfg) > 2e10


# --------------------------------------------------------------------------
# assembly: analytic terms + HLO calibration from the dry-run records
# --------------------------------------------------------------------------

def load_cells(out_dir="results/dryrun", mesh="single"):
    from repro.launch.dryrun import effective_shape

    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            cells.append(rec)
            continue
        cfg = configs.get(rec["arch"]).FULL
        shape = effective_shape(cfg, SHAPES[rec["shape"]])
        a = analytic_terms(cfg, shape)
        terms = {k: a[k] for k in ("compute_s", "memory_s", "collective_s")}
        dom = max(terms, key=terms.get).replace("_s", "")
        useful = a["model_flops"] / max(a["flops"], 1.0)
        mfu_bound = (a["model_flops"] / CHIPS / PEAK) / max(max(terms.values()), 1e-30)
        rec["roofline"] = {
            **{k: a[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "model_flops", "flops")},
            "dominant": dom, "usefulness": useful, "mfu_bound": mfu_bound,
            "hlo_flops_per_iter": rec["cost"]["flops"],
            "hlo_coll_bytes_per_iter": rec["collectives"]["total_bytes"],
            "recommendation": _recommend(dom, rec),
        }
        cells.append(rec)
    return cells


def _recommend(dom, rec) -> str:
    if dom == "memory":
        return ("memory-bound: raise arithmetic intensity — bigger per-chip "
                "batch, quantised cache/params (the paper's certified "
                "low-precision serving is exactly this lever)")
    if dom == "collective":
        return ("collective-bound: keep params model-axis-resident (no "
                "data-axis gathers), overlap AG with layer compute, int8 "
                "gradient payloads")
    return "compute-bound: near roofline; tune MXU block shapes / fusion"


def print_table(cells):
    ok = [c for c in cells if c.get("status") == "ok"]
    print("\n== §Roofline (single-pod 16×16; analytic terms, HLO-calibrated) ==")
    print(f"{'arch':<18s}{'shape':<13s}{'compute':>11s}{'memory':>11s}"
          f"{'collect':>11s}{'dom':>8s}{'MFU≤':>7s}")
    rows = []
    for c in ok:
        r = c["roofline"]
        print(f"{c['arch']:<18s}{c['shape']:<13s}"
              f"{r['compute_s']:>11.3e}{r['memory_s']:>11.3e}"
              f"{r['collective_s']:>11.3e}{r['dominant']:>8s}"
              f"{r['mfu_bound']:>7.3f}")
        rows.append((f"roofline_{c['arch']}_{c['shape']}",
                     max(r['compute_s'], r['memory_s'],
                         r['collective_s']) * 1e6,
                     round(r['mfu_bound'], 4)))
    skipped = [c for c in cells if c.get("status") == "skipped"]
    if skipped:
        print(f"({len(skipped)} cells skipped per assignment — see §Dry-run)")
    return rows


def interesting_cells(cells):
    ok = [c for c in cells if c.get("status") == "ok"]
    worst = min(ok, key=lambda c: c["roofline"]["mfu_bound"])
    coll = max(ok, key=lambda c: (c["roofline"]["collective_s"]
                                  / max(c["roofline"]["compute_s"],
                                        c["roofline"]["memory_s"], 1e-30)))
    serving = [c for c in ok if SHAPES[c["shape"]].kind != "train"]
    rep = max(serving, key=lambda c: c["roofline"]["model_flops"])
    return {"worst_mfu": worst, "most_collective": coll, "paper_rep": rep}


def run():
    cells = load_cells()
    rows = print_table(cells)
    picks = interesting_cells(cells)
    print("\nhillclimb candidates:")
    for why, c in picks.items():
        print(f"  {why:16s}: {c['arch']} × {c['shape']} "
              f"(dom={c['roofline']['dominant']}, "
              f"MFU≤{c['roofline']['mfu_bound']:.3f})")
    return rows


if __name__ == "__main__":
    run()
