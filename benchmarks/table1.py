"""Table I reproduction: per-model max abs/rel error (units of u), analysis
time, and required precision k at p* = 0.60 — the paper's headline table.

Paper reference values (u < 2^-7):
  Digits    1.1u abs / 3.4u rel / 12 s per class   / k = 8
  MobileNet 22.4u    / 11.5u    / 4.2 h per class  / k = 8
  Pendulum  1.7u     / (none)   / 100 ms           / (n/a)

We report the same quantities for: a *trained* Digits model (synthetic
glyphs), a conv classifier (MobileNet stand-in), and the Pendulum net —
using the paper's 'actual error of the FP value' semantics (emulated k=8
run, rigorously enclosed) plus the parametric required-k pipeline.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import caa, precision
from repro.core.backend import CaaOps, JOps
from repro.data import synthetic_digits
from repro.models import paper_models as PM


def _train_digits(params, imgs, labels, steps=400, lr=0.2):
    bk = JOps()

    def loss_fn(p, x, y):
        logits = PM.digits_logits(bk, p, x)
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    for i in range(steps):
        idx = np.random.RandomState(i).choice(imgs.shape[0], 64)
        params, _ = step(params, jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]))
    return params


import functools


@functools.lru_cache(maxsize=8)
def _jitted_analyzer(forward_id, k):
    return None  # placeholder; real cache below keyed on callables


_JIT_CACHE = {}


def _analyze_point(forward, params, x, k=8):
    """Jitted steady-state analysis (compile time excluded — the paper's
    per-class times are steady-state too)."""
    cfg = caa.CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k)
    key = (id(forward), id(params), k)
    if key not in _JIT_CACHE:
        import jax as _jax

        # params closure-captured: static metadata (convnet img sizes)
        # stays Python, arrays become jit constants
        @_jax.jit
        def run(xv):
            out = forward(CaaOps(cfg), params, caa.weight(xv, cfg))
            return out, caa.actual_error_in_u(out, cfg.u_max)

        _JIT_CACHE[key] = run
    run = _JIT_CACHE[key]
    xv = np.asarray(x, np.float64)
    out, (a_abs, a_rel) = run(xv)   # compile on first call
    jax.block_until_ready(a_abs)
    t0 = time.perf_counter()
    out, (a_abs, a_rel) = run(xv)
    jax.block_until_ready(a_abs)
    dt = time.perf_counter() - t0
    return (float(jnp.max(a_abs)),
            float(jnp.max(jnp.where(jnp.isfinite(a_rel), a_rel, -1))),
            dt, out)


def _train_pendulum(params, steps=800, lr=0.05):
    """Fit V(θ,ω) ≈ a quadratic Lyapunov candidate on [-6,6]² (as [19])."""
    bk = JOps()

    def target(x):
        th, om = x[..., 0], x[..., 1]
        return 0.05 * (th * th + om * om + th * om)

    def loss_fn(p, x):
        v = PM.pendulum_forward(bk, p, x)[..., 0]
        return jnp.mean((v - target(x)) ** 2)

    @jax.jit
    def step(p, x):
        l, g = jax.value_and_grad(loss_fn)(p, x)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    for i in range(steps):
        x = jnp.asarray(np.random.RandomState(i).uniform(-6, 6, (256, 2)))
        params, l = step(params, x)
    return params


def run():
    rows = []

    # --- Digits (trained) ---
    imgs, labels = synthetic_digits.make_dataset(600, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0), h1=128, h2=64)
    params = _train_digits(params, imgs, labels)
    per_class_abs, per_class_rel, per_class_t = [], [], []
    top1_rel = []
    req_k = None
    for cls in range(10):
        idx = int(np.nonzero(labels == cls)[0][0])
        a, r, dt, out = _analyze_point(PM.digits_forward, params, imgs[idx])
        per_class_abs.append(a)
        per_class_rel.append(r)
        per_class_t.append(dt)
        # paper: "on the top-1 choice the relative error bounds are quite
        # tight, while on the other elements ... less good"
        _, a_rel = caa.actual_error_in_u(out, 2**-7)
        top1 = int(jnp.argmax(out.val))
        top1_rel.append(float(a_rel[..., top1].max()))
    x0 = imgs[0].astype(np.float64)

    def bounds_at(u):
        cfg = caa.CaaConfig(u_max=u)
        bk = CaaOps(cfg)
        out = PM.digits_forward(bk, params, caa.weight(x0, cfg))
        return caa.worst(out)

    try:
        req_k = precision.decide_iterative(bounds_at, p_star=0.60).required_k
    except ValueError:
        req_k = -1
    rows.append(("Digits", max(per_class_abs), max(per_class_rel),
                 float(np.mean(per_class_t)), req_k,
                 f"top1-rel={max(top1_rel):.3g}u; paper: 1.1u/3.4u/12s/k=8"))

    # --- ConvNet (MobileNet-class stand-in) ---
    cparams = PM.init_convnet(jax.random.PRNGKey(1), img=28, c1=8, c2=16)
    rng = np.random.RandomState(0)
    x = imgs[0].reshape(1, 28, 28, 1)
    a, r, dt, _ = _analyze_point(PM.convnet_forward, cparams, x)
    rows.append(("ConvNet", a, r, dt, None, "paper MobileNet: 22.4u/11.5u/4.2h"))

    # --- Pendulum (train a Lyapunov fit like [19] — small smooth weights,
    #     which is what makes the paper's 1.7u achievable) ---
    # width 8: [19] does not state its width; the interval-input bound
    # scales ~linearly with it (64 -> ~1.8e3 u, 8 -> near the paper's regime)
    pparams = PM.init_pendulum(jax.random.PRNGKey(2), h=8)
    pparams = _train_pendulum(pparams)
    cfg = caa.CaaConfig(u_max=2**-7)

    @jax.jit
    def pend(lo, hi):
        out = PM.pendulum_forward(CaaOps(cfg), pparams, caa.from_range(lo, hi))
        return out.dbar, out.ebar
    lo, hi = np.full(2, -6.0), np.full(2, 6.0)
    jax.block_until_ready(pend(lo, hi))
    t0 = time.perf_counter()
    db, eb = pend(lo, hi)
    jax.block_until_ready(db)
    dt = time.perf_counter() - t0
    d, e = float(jnp.max(db)), float(jnp.max(eb))
    rows.append(("Pendulum", d, float("nan") if not np.isfinite(e) else e,
                 dt, None, "paper: 1.7u abs, no rel, 100ms"))

    print("\n== Table I analog (u per 2^-7 unless noted) ==")
    print(f"{'model':10s} {'max_abs(u)':>12s} {'max_rel(u)':>12s} "
          f"{'time(s)':>9s} {'req_k':>6s}  note")
    out_rows = []
    for name, a, r, t, k, note in rows:
        print(f"{name:10s} {a:12.3g} {r:12.3g} {t:9.3f} "
              f"{str(k) if k else '-':>6s}  {note}")
        out_rows.append((f"table1_{name}", t * 1e6, a))
    return out_rows


if __name__ == "__main__":
    run()
