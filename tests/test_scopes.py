"""Property suite for repro.core.scopes — the single home of scope-path
matching shared by the analysis and serving backends.

The invariants here are exactly the ones the stacked (scan-native) pipeline
leans on: segment matching never degenerates to substring matching
(``layer1`` vs ``layer10``), ``[L]``-array wildcard maps round-trip through
:func:`expand_stacked` to the equivalent concrete map, a concrete key beats
the wildcard at equal depth, and sub-layer keys (``layer*/attn``) resolve
below per-layer granularity.
"""
import numpy as np
import pytest

from _hyp import given, st  # optional-hypothesis shim (skips property tests)

from repro.core.scopes import (STACK_SCOPE, expand_stacked,
                               resolve_scope_value, scope_active)


# ---------------------------------------------------------------------------
# segment matching: never substring matching
# ---------------------------------------------------------------------------

def test_layer1_does_not_match_inside_layer10():
    assert not scope_active("layer1", ["layer10"])
    assert not scope_active("layer1", ["layer10", "attn"])
    assert scope_active("layer1", ["layer1"])
    assert scope_active("layer10", ["layer10"])


def test_block_prefix_does_not_match():
    assert not scope_active("block1", ["block10"])
    assert not scope_active("block1", ["block10", "inner"])
    assert scope_active("block1/inner", ["block1", "inner"])
    assert not scope_active("block1/inner", ["block10", "inner"])


@given(st.integers(0, 99), st.integers(0, 99))
def test_prop_distinct_layer_keys_never_cross_match(i, j):
    if i == j:
        assert scope_active(f"layer{i}", [f"layer{j}"])
    else:
        assert not scope_active(f"layer{i}", [f"layer{j}"])
        assert not scope_active(f"layer{i}", [f"layer{j}", "attn"])


@given(st.integers(0, 99))
def test_prop_wildcard_matches_every_concrete_layer(i):
    assert scope_active(STACK_SCOPE, [f"layer{i}"])
    assert scope_active(STACK_SCOPE, ["embed", f"layer{i}", "mlp"])
    # ... but only layer<i> segments, nothing else
    assert not scope_active(STACK_SCOPE, ["embed"])
    assert not scope_active(STACK_SCOPE, [f"block{i}"])


# ---------------------------------------------------------------------------
# [L]-array wildcard maps round-trip through expand_stacked
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(2, 53), min_size=1, max_size=12),
       st.integers(2, 53))
def test_prop_stacked_lane_roundtrips_to_concrete_map(ks, default):
    """{"layer*": [L] lane} and the expand_stacked concrete map resolve
    identically on every concrete layer path (incl. sub-scopes)."""
    n = len(ks)
    lane_map = {STACK_SCOPE: ks}
    concrete_keys = expand_stacked([STACK_SCOPE], n)
    assert concrete_keys == [f"layer{i}" for i in range(n)]
    concrete_map = {key: ks[i] for i, key in enumerate(concrete_keys)}
    for i in range(n):
        for path in ([f"layer{i}"], [f"layer{i}", "attn"],
                     ["embed", f"layer{i}", "mlp"]):
            assert (resolve_scope_value(path, lane_map, default)
                    == resolve_scope_value(path, concrete_map, default)
                    == ks[i])
    # outside every layer both maps fall through to the default
    assert resolve_scope_value(["head"], lane_map, default) == default
    assert resolve_scope_value(["head"], concrete_map, default) == default


def test_stacked_lane_accepts_ndarray():
    ks = np.asarray([7, 11, 13])
    m = {STACK_SCOPE: ks}
    assert resolve_scope_value(["layer2"], m, 0) == 13
    assert resolve_scope_value(["layer0", "attn"], m, 0) == 7


@given(st.integers(1, 8))
def test_prop_expand_stacked_sublayer_keys(n):
    got = expand_stacked(["embed", STACK_SCOPE + "/attn", STACK_SCOPE], n)
    assert got[0] == "embed"
    assert got[1:n + 1] == [f"layer{i}/attn" for i in range(n)]
    assert got[n + 1:] == [f"layer{i}" for i in range(n)]
    # idempotent on already-concrete names
    assert expand_stacked(got, n) == got


# ---------------------------------------------------------------------------
# specificity: concrete beats wildcard, longer beats shorter
# ---------------------------------------------------------------------------

@given(st.integers(0, 7), st.integers(0, 7), st.integers(2, 53),
       st.integers(2, 53))
def test_prop_concrete_beats_wildcard(i, j, a, b):
    m = {STACK_SCOPE: a, f"layer{i}": b}
    assert resolve_scope_value([f"layer{i}"], m, None) == b
    if j != i:
        assert resolve_scope_value([f"layer{j}"], m, None) == a


def test_sublayer_key_beats_layer_key():
    m = {STACK_SCOPE: 1, STACK_SCOPE + "/attn": 2, "layer3": 3}
    assert resolve_scope_value(["layer0"], m, 0) == 1
    assert resolve_scope_value(["layer0", "attn"], m, 0) == 2
    assert resolve_scope_value(["layer0", "mlp"], m, 0) == 1
    # concrete layer3 beats the bare wildcard, but the deeper sub-layer
    # wildcard key still wins under layer3/attn (more segments)
    assert resolve_scope_value(["layer3"], m, 0) == 3
    assert resolve_scope_value(["layer3", "attn"], m, 0) == 2


@given(st.integers(0, 7), st.lists(st.integers(2, 53), min_size=8,
                                   max_size=8))
def test_prop_sublayer_lane_indexes_by_layer(i, lane):
    """A ``layer*/attn`` key with an [L] lane indexes by the matched layer
    number — the exchange format between the stacked analysis and the
    scanned serving backends."""
    m = {STACK_SCOPE + "/attn": lane}
    assert resolve_scope_value([f"layer{i}", "attn"], m, None) == lane[i]
    assert resolve_scope_value([f"layer{i}", "mlp"], m, None) is None
    assert resolve_scope_value([f"layer{i}"], m, None) is None
