"""Mixed-precision certificates: invariants, differentials, serving parity.

The contract under test (ISSUE 2):

  * the mixed map is pointwise ≤ the uniform certified k (property),
  * re-raising any layer's k never increases δ̄ (monotonicity property),
  * a v2 certificate survives the store bit-exactly (property),
  * mixed serving at the certified map is bit-for-bit a pure-quantize
    reference on the digits and pendulum archs (differential),
  * with all scales 1 the mixed analysis IS the uniform analysis,
  * the jitted ladders compile at most once for a whole search.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st

from repro import certify
from repro.certify import batch as B
from repro.certify import mixed as MX
from repro.core import analyze, caa, theory
from repro.core.caa import CaaConfig
from repro.core.quantize import quantize_to_k
from repro.launch.serve import MixedQuantJOps, QuantJOps
from repro.models import paper_models as PM


def _mlp(seed: int, d_in=10, h1=12, h2=8, n_classes=3):
    params = PM.init_digits(jax.random.PRNGKey(seed), d_in=d_in, h1=h1,
                            h2=h2, n_classes=n_classes)
    rng = np.random.RandomState(seed + 1)
    los = [rng.rand(d_in) * 0.3 for _ in range(n_classes)]
    his = [lo + 0.04 for lo in los]
    return params, los, his


@pytest.fixture(scope="module")
def mixed_certified(tmp_path_factory):
    params, los, his = _mlp(0)
    store = certify.CertificateStore(str(tmp_path_factory.mktemp("mx")))
    cs = certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                         model_id="test/mlp", store=store, mixed=True)
    return params, los, his, store, cs


# ---------------------------------------------------------------------------
# scope resolution & MixedCaaOps semantics
# ---------------------------------------------------------------------------

def test_resolve_scope_value_segments_and_specificity():
    m = {"block1": 1, "block1/inner": 2, "block10": 3}
    assert analyze.resolve_scope_value(["block1"], m, 0) == 1
    assert analyze.resolve_scope_value(["block1", "inner"], m, 0) == 2
    assert analyze.resolve_scope_value(["block10"], m, 0) == 3
    assert analyze.resolve_scope_value(["block12"], m, 0) == 0
    assert analyze.resolve_scope_value([], m, 0) == 0


def test_all_scales_one_equals_uniform_analysis():
    """Base case of the greedy descent: a degenerate mixed analysis (every
    scale 1) must reproduce the plain CaaOps bounds exactly."""
    params, los, his = _mlp(3)
    x = B.stack_class_ranges(los, his)
    cfg = CaaConfig(u_max=2.0 ** -10)
    rep = analyze.analyze_batched(PM.digits_forward, params, x, cfg=cfg)
    scopes = analyze.discover_scopes(PM.digits_forward, params, x, cfg)
    assert scopes == ["dense1", "dense2", "dense3", "softmax"]
    lad = MX.MixedProbeLadder(PM.digits_forward, params, x, scopes, cfg=cfg)
    abs_u, rel_u, k_ref = lad({s: 11 for s in scopes}, 11)
    assert k_ref == 11
    np.testing.assert_allclose(abs_u, rep.abs_u, rtol=1e-9)
    np.testing.assert_allclose(rel_u, rep.rel_u, rtol=1e-9)


def test_discover_scopes_depth():
    params, los, his = _mlp(4)
    x = B.stack_class_ranges(los, his)

    def fwd(bk, p, xx):
        with bk.scope("outer"):
            with bk.scope("inner"):
                return bk.matmul(xx, bk.param(p["w1"]))

    assert analyze.discover_scopes(fwd, params, x) == ["outer"]
    assert analyze.discover_scopes(fwd, params, x, depth=2) == [
        "outer", "outer/inner"]


# ---------------------------------------------------------------------------
# greedy descent invariants (examples + hypothesis properties)
# ---------------------------------------------------------------------------

def test_mixed_map_pointwise_le_uniform(mixed_certified):
    _, _, _, _, cs = mixed_certified
    uk = cs.serving_k
    lk = cs.serving_layer_k
    assert uk is not None and lk is not None
    assert set(lk) == {"dense1", "dense2", "dense3", "softmax"}
    assert all(v <= uk for v in lk.values())
    mx = cs.meta["mixed"]
    assert mx["applied"] is True
    assert mx["ladder_compiles"] == 1


def test_mixed_map_still_feasible_at_margins(mixed_certified):
    """The map's own bounds (recomputed here) must satisfy the p* margins —
    the certificate is a real proof, not a heuristic."""
    params, los, his, _, cs = mixed_certified
    x = B.stack_class_ranges(los, his)
    lk = cs.serving_layer_k
    lad = MX.MixedProbeLadder(PM.digits_forward, params, x, sorted(lk))
    abs_u, rel_u, k_ref = lad(lk, cs.serving_k)
    feas = B.margin_feasibility(0.6)
    assert bool(np.all(feas(abs_u, rel_u, k_ref)))


@given(st.integers(min_value=0, max_value=10 ** 6))
def test_property_mixed_le_uniform_any_seed(seed):
    """For any model/seed: every mixed-map entry ≤ the uniform certified k."""
    params, los, his = _mlp(seed % 997, h1=10, h2=6)
    x = B.stack_class_ranges(los, his)
    feas = B.margin_feasibility(0.6)
    ks, _ = B.required_k_batched(PM.digits_forward, params, x, feas, k_max=32)
    if np.isnan(ks).any():
        return  # uncertifiable draw — nothing to compare
    uk = int(np.max(ks))
    plan = MX.greedy_mixed_assignment(PM.digits_forward, params, x, feas, uk)
    assert all(v <= uk for v in plan.layer_k.values())
    assert plan.compiles == 1


@given(st.integers(min_value=0, max_value=10 ** 6),
       st.sampled_from(["dense1", "dense2", "dense3", "softmax"]))
def test_property_reraising_layer_never_increases_dbar(seed, scope):
    """Monotonicity: raising any one layer's k (at fixed u_ref) can only
    shrink the fresh-rounding charges, so δ̄ must not increase."""
    params, los, his = _mlp(seed % 991, h1=10, h2=6)
    x = B.stack_class_ranges(los, his)
    scopes = ["dense1", "dense2", "dense3", "softmax"]
    lad = MX.MixedProbeLadder(PM.digits_forward, params, x, scopes)
    base = {s: 9 for s in scopes}
    lo_abs, _, k_lo = lad(base, 9)
    raised = dict(base, **{scope: 12})
    hi_abs, _, k_hi = lad(raised, 9)
    assert k_lo == k_hi == 9          # u_ref pinned by the other layers
    assert np.all(hi_abs <= lo_abs * (1 + 1e-12))


@given(st.integers(min_value=2, max_value=24),
       st.integers(min_value=0, max_value=10 ** 6))
def test_property_v2_store_roundtrip_exact(k, seed):
    """A v2 certificate (with a random layer map) survives JSON + store
    round-trip exactly."""
    rng = np.random.RandomState(seed % 2 ** 31)
    layer_k = {f"layer{i}": int(rng.randint(2, 1 + k))
               for i in range(rng.randint(1, 5))}
    cert = certify.Certificate(
        model_id="m", params_digest="d" * 64, class_key="c0",
        cfg=CaaConfig(u_max=2.0 ** (1 - k)),
        bounds_u_max=2.0 ** (1 - k),
        final_abs_u=float(rng.rand() * 100),
        final_rel_u=float("inf") if rng.rand() < 0.3 else float(rng.rand()),
        required_k=k, satisfied_by=["binary64"],
        p_star=0.6, layer_k=layer_k,
    )
    assert certify.Certificate.from_json(cert.to_json()) == cert
    cs = certify.CertificateSet(model_id="m", params_digest="d" * 64,
                                certificates=[cert], p_star=0.6)
    back = certify.CertificateSet.from_json(cs.to_json())
    assert back.to_json() == cs.to_json()
    assert back.serving_layer_k == layer_k
    # and through the on-disk store, via a fresh instance (no LRU aliasing)
    import shutil
    import tempfile
    root = tempfile.mkdtemp(prefix="v2rt_")
    try:
        certify.CertificateStore(root).put("key0", cs)
        got = certify.CertificateStore(root).get("key0")
        assert got is not None and got.to_json() == cs.to_json()
        assert got.certificates[0].layer_k == layer_k
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_serving_layer_k_heterogeneous_merge_is_sound():
    """A scope absent from one class's map was only certified at that
    class's uniform required_k — the merge must honour that, never serve it
    at another class's lower k."""
    def cert(class_key, required_k, layer_k):
        return certify.Certificate(
            model_id="m", params_digest="d" * 64, class_key=class_key,
            cfg=CaaConfig(), bounds_u_max=2.0 ** -9,
            final_abs_u=1.0, final_rel_u=1.0,
            required_k=required_k, satisfied_by=["binary64"],
            layer_k=layer_k)

    cs = certify.CertificateSet(
        model_id="m", params_digest="d" * 64,
        certificates=[cert("c0", 10, {"a": 5}), cert("c1", 10, {"b": 6})])
    # scope "a": class c1 never certified it below its uniform k=10
    assert cs.serving_layer_k == {"a": 10, "b": 10}
    cs2 = certify.CertificateSet(
        model_id="m", params_digest="d" * 64,
        certificates=[cert("c0", 10, {"a": 5, "b": 8}),
                      cert("c1", 7, {"a": 6, "b": 4})])
    assert cs2.serving_layer_k == {"a": 6, "b": 8}
    # any certificate without a map (v1) disables the joint mixed map
    cs3 = certify.CertificateSet(
        model_id="m", params_digest="d" * 64,
        certificates=[cert("c0", 10, {"a": 5}), cert("c1", 10, None)])
    assert cs3.serving_layer_k is None


# ---------------------------------------------------------------------------
# differential: mixed serving == pure-quantize reference, bit for bit
# ---------------------------------------------------------------------------

def _ref_mm(a, w, k):
    aq = quantize_to_k(jnp.asarray(a).astype(jnp.float32), k)
    wq = quantize_to_k(jnp.asarray(w).astype(jnp.float32), k)
    out = jnp.matmul(aq, wq, preferred_element_type=jnp.float32)
    return quantize_to_k(out, k)


def test_mixed_serving_digits_bit_for_bit(mixed_certified):
    params, _, _, _, cs = mixed_certified
    lk, dk = cs.serving_layer_k, cs.serving_k
    bk = MixedQuantJOps(lk, dk)
    x = jnp.asarray(np.random.RandomState(7).rand(5, 10), jnp.float32)
    got = PM.digits_forward(bk, params, x)
    f32 = lambda t: jnp.asarray(t).astype(jnp.float32)
    h = jax.nn.relu(_ref_mm(x, params["w1"], lk["dense1"]) + f32(params["b1"]))
    h = jax.nn.relu(_ref_mm(h, params["w2"], lk["dense2"]) + f32(params["b2"]))
    o = _ref_mm(h, params["w3"], lk["dense3"]) + f32(params["b3"])
    want = jax.nn.softmax(o, axis=-1)
    assert bool(jnp.array_equal(got, want))


def test_mixed_serving_pendulum_bit_for_bit():
    params = PM.init_pendulum(jax.random.PRNGKey(2), h=16)
    lk = {"dense1": 9, "dense2": 11, "dense3": 13}
    bk = MixedQuantJOps(lk, 13)
    x = jnp.asarray(np.random.RandomState(3).uniform(-6, 6, (4, 2)),
                    jnp.float32)
    got = PM.pendulum_forward(bk, params, x)
    f32 = lambda t: jnp.asarray(t).astype(jnp.float32)
    h = jnp.tanh(_ref_mm(x, params["w1"], 9) + f32(params["b1"]))
    h = jnp.tanh(_ref_mm(h, params["w2"], 11) + f32(params["b2"]))
    want = _ref_mm(h, params["w3"], 13) + f32(params["b3"])
    assert bool(jnp.array_equal(got, want))


def test_mixed_uniform_map_equals_quantjops():
    """A degenerate map (every scope at the same k) must serve exactly what
    the uniform QuantJOps backend serves."""
    params, _, _ = _mlp(5)
    x = jnp.asarray(np.random.RandomState(9).rand(3, 10), jnp.float32)
    a = PM.digits_forward(MixedQuantJOps({}, 11), params, x)
    b = PM.digits_forward(QuantJOps(11), params, x)
    assert bool(jnp.array_equal(a, b))


@given(st.integers(min_value=2, max_value=24))
def test_property_quantize_to_k_matches_static(k):
    """Traced-k rounding is bitwise the static-k rounding (both carriers)."""
    from repro.core.quantize import _quantize_normal
    rng = np.random.RandomState(k)
    for dt in (np.float32, np.float64):
        x = jnp.asarray(rng.randn(64) * 10.0 ** rng.randint(-6, 6, 64), dt)
        stat = _quantize_normal(x, k)
        dyn = quantize_to_k(x, jnp.asarray(k, jnp.int32))
        assert bool(jnp.array_equal(stat, dyn, equal_nan=True))
        jit_dyn = jax.jit(quantize_to_k)(x, jnp.asarray(k, jnp.int32))
        assert bool(jnp.array_equal(stat, jit_dyn, equal_nan=True))


# ---------------------------------------------------------------------------
# jitted probe ladders: at most one compilation per search
# ---------------------------------------------------------------------------

def test_uniform_ladder_single_compile_whole_grid():
    params, los, his = _mlp(6)
    x = B.stack_class_ranges(los, his)
    lad = B.ProbeLadder(PM.digits_forward, params, x)
    for k in (24, 16, 12, 8, 5, 3):
        abs_u, rel_u = lad(k)
        assert abs_u.shape == (3,) and rel_u.shape == (3,)
    assert lad.compiles == 1
    assert lad.ks_probed == [24, 16, 12, 8, 5, 3]


def test_ladder_search_matches_eager_search():
    params, los, his = _mlp(7)
    x = B.stack_class_ranges(los, his)
    feas = B.margin_feasibility(0.6)
    lad = B.ProbeLadder(PM.digits_forward, params, x)
    ks_lad, rep_lad = B.required_k_batched(
        PM.digits_forward, params, x, feas, ladder=lad)
    ks_eag, _ = B.required_k_batched(PM.digits_forward, params, x, feas)
    assert np.array_equal(ks_lad, ks_eag, equal_nan=True)
    assert lad.compiles == 1
    # the persisted reports are eager — only at the final ks
    finals = {int(v) for v in ks_lad[~np.isnan(ks_lad)]}
    assert finals <= set(rep_lad)


def test_mixed_ladder_single_compile_descent(mixed_certified):
    _, _, _, _, cs = mixed_certified
    assert cs.meta["ladder_compiles"] == 1
    assert cs.meta["mixed"]["ladder_compiles"] == 1


# ---------------------------------------------------------------------------
# flop-weighted mean k
# ---------------------------------------------------------------------------

def test_flop_weighted_mean_k():
    lk = {"a": 10, "b": 20}
    assert MX.flop_weighted_mean_k(lk) == 15.0
    assert MX.flop_weighted_mean_k(lk, {"a": 3.0, "b": 1.0}) == 12.5
    with pytest.raises(ValueError):
        MX.flop_weighted_mean_k({})


def test_mixed_mean_k_strictly_below_uniform_on_digits_arch(mixed_certified):
    """Acceptance bar (scaled-down digits arch): the FLOP-weighted mean k of
    the mixed certificate is strictly below the uniform serving k at the
    same p*."""
    _, _, _, _, cs = mixed_certified
    flops = {"dense1": 2.0 * 10 * 12, "dense2": 2.0 * 12 * 8,
             "dense3": 2.0 * 8 * 3, "softmax": 4.0 * 3}
    mean_k = MX.flop_weighted_mean_k(cs.serving_layer_k, flops)
    assert mean_k < cs.serving_k
