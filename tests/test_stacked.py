"""Scan-native (layer-stacked) CAA: resolution, parity, lanes, and the LM
mixed/format certificate end-to-end.

The contract under test (ISSUE 5):

  * stacked scope resolution: ``layer3/attn`` resolves through a ``[L]``
    map (``{"layer*": ks}`` → ``ks[3]``), concrete keys beat the wildcard;
  * backend-level ``seen_scopes`` dedups through a companion set (every
    backend, JOps included);
  * StackedCaaOps == the eager unrolled analysis (uniform AND per-scope
    scaled), with jaxpr size FLAT in depth;
  * StackedRangeCaaOps' [L, 4] lanes == the eager per-path range
    aggregation;
  * schema-v3 certificates round-trip array-valued per-layer maps exactly;
  * end-to-end: a transformer arch gets a mixed/format certificate through
    ONE compiled stacked probe ladder, serving applies the map bit-for-bit
    against the eager per-layer reference, and the certified serving cost
    beats uniform binary32 bits/value.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import certify
from repro.core import analyze, caa
from repro.core.backend import (CaaOps, JOps, RangeCaaOps, StackedCaaOps,
                                StackedRangeCaaOps)
from repro.core.caa import CaaConfig
from repro.core.scopes import (STACK_SCOPE, expand_stacked,
                               resolve_scope_value, scope_active)
from repro.models import transformer as T

# ---------------------------------------------------------------------------
# stacked scope resolution
# ---------------------------------------------------------------------------


def test_resolve_layer_map_through_stacked_wildcard():
    ks = np.asarray([10, 11, 12, 13])
    assert resolve_scope_value(["layer3", "attn"], {"layer*": ks}, 0) == 13
    assert resolve_scope_value(["layer0"], {"layer*": ks}, 0) == 10
    # concrete key beats the wildcard at equal depth
    assert resolve_scope_value(["layer2"], {"layer*": ks, "layer2": 99},
                               0) == 99
    # non-layer segments never match the wildcard
    assert resolve_scope_value(["block3"], {"layer*": ks}, -1) == -1
    # jnp-valued maps index the same way (the serving-side lane form)
    jks = jnp.asarray([5, 6, 7])
    assert int(resolve_scope_value(["layer1", "mlp"], {"layer*": jks},
                                   0)) == 6
    # deeper wildcard keys resolve through their own segments
    assert resolve_scope_value(["layer1", "attn"], {"layer*/attn": ks},
                               0) == 11
    assert resolve_scope_value(["layer1", "mlp"], {"layer*/attn": ks},
                               7) == 7


def test_scope_active_wildcard_segments():
    assert scope_active(STACK_SCOPE, ["layer12", "mlp"])
    assert scope_active("layer*/attn", ["layer0", "attn"])
    assert not scope_active(STACK_SCOPE, ["block1"])
    # 'layer1' must not activate inside 'layer10' (segment, not substring)
    assert not scope_active("layer1", ["layer10"])
    assert scope_active(STACK_SCOPE, [STACK_SCOPE])


def test_expand_stacked_scopes():
    assert expand_stacked(["embed", STACK_SCOPE, "head"], 3) == [
        "embed", "layer0", "layer1", "layer2", "head"]
    assert expand_stacked([STACK_SCOPE + "/attn"], 2) == [
        "layer0/attn", "layer1/attn"]
    assert expand_stacked(["a", "a"], 2) == ["a"]


def test_backend_seen_scopes_dedup_with_companion_set():
    """Every backend (JOps included) records first-seen scope paths; the
    membership test must go through a set, not the list."""
    bk = JOps()
    for _ in range(3):
        with bk.scope("blk"):
            with bk.scope("inner"):
                pass
    assert bk.seen_scopes == ["blk", "blk/inner"]
    assert isinstance(bk._seen_set, set)
    assert bk._seen_set == {"blk", "blk/inner"}


# ---------------------------------------------------------------------------
# stacked analysis parity on a synthetic layer-stacked model
# ---------------------------------------------------------------------------

_L, _D = 3, 4


def _stacked_mlp_forward(n_layers):
    def forward(bk, params, x):
        def layer(p, h, i, a):
            return bk.relu(bk.matmul(h, bk.param(p))), None

        h, _ = bk.layer_loop(layer, params, x, n_layers)
        with bk.scope("head"):
            return bk.matmul(h, bk.param(np.eye(_D)))

    return forward


@pytest.fixture(scope="module")
def synth():
    W = np.asarray(jax.random.normal(jax.random.PRNGKey(0),
                                     (_L, _D, _D))) * 0.5
    x = caa.from_range(np.full((2, _D), -0.5), np.full((2, _D), 0.5))
    return _stacked_mlp_forward(_L), W, x, CaaConfig(u_max=2.0 ** -10)


def _full(c):
    return np.broadcast_to(np.asarray(c.dbar), c.shape)


def test_stacked_uniform_matches_eager_unroll(synth):
    fwd, W, x, cfg = synth
    eager = fwd(CaaOps(cfg), W, x)
    stacked = fwd(StackedCaaOps(cfg), W, x)
    np.testing.assert_allclose(_full(stacked), _full(eager), rtol=1e-9)


def test_stacked_scales_match_eager_mixed_and_wildcard_vector(synth):
    fwd, W, x, cfg = synth
    sm = {"layer0": 1.0, "layer1": 0.25, "layer2": 0.5, "head": 0.125}
    eager = fwd(certify.MixedCaaOps(cfg, sm, default_scale=1.0), W, x)
    by_name = fwd(StackedCaaOps(cfg, sm), W, x)
    np.testing.assert_allclose(_full(by_name), _full(eager), rtol=1e-9)
    # the [L]-vector wildcard form is the same map
    by_vec = fwd(StackedCaaOps(
        cfg, {"layer*": jnp.asarray([1.0, 0.25, 0.5]), "head": 0.125}), W, x)
    np.testing.assert_allclose(_full(by_vec), _full(by_name), rtol=1e-12)


def test_stacked_layer_stats_and_seen_scopes(synth):
    fwd, W, x, cfg = synth
    ops = StackedCaaOps(cfg)
    fwd(ops, W, x)
    assert ops.layer_stats["abs_u"].shape == (_L,)
    # bounds only grow along the stack (monotone accumulation)
    stats = np.asarray(ops.layer_stats["abs_u"])
    assert (np.diff(stats) >= 0).all()
    assert STACK_SCOPE in ops.seen_scopes and "head" in ops.seen_scopes


def test_stacked_jaxpr_flat_in_depth():
    """One traced scan body for all L layers: the traced graph must not
    grow with depth (the eager unroll grows linearly)."""
    cfg = CaaConfig(u_max=2.0 ** -10)

    def n_eqns(L):
        W = np.asarray(jax.random.normal(jax.random.PRNGKey(0),
                                         (L, _D, _D)))
        fwd = _stacked_mlp_forward(L)

        def bounds(p, xv):
            out = fwd(StackedCaaOps(cfg), p, caa.make(xv))
            return jnp.max(out.dbar)

        return len(jax.make_jaxpr(bounds)(W, np.zeros((2, _D))).eqns)

    assert n_eqns(2) == n_eqns(6)


def test_stacked_range_lanes_match_eager(synth):
    fwd, W, x, cfg = synth
    keys = [f"layer{i}" for i in range(_L)] + ["head"]
    eager_ops = RangeCaaOps(cfg)
    fwd(eager_ops, W, x)
    eager = analyze.aggregate_ranges(eager_ops.scope_ranges, keys)
    stacked_ops = StackedRangeCaaOps(cfg)
    fwd(stacked_ops, W, x)
    stacked = analyze.aggregate_ranges(stacked_ops.collect_ranges(), keys)
    for k in keys:
        assert stacked[k].n_ops == eager[k].n_ops
        np.testing.assert_allclose(stacked[k].max_abs, eager[k].max_abs,
                                   rtol=1e-9)
        if np.isfinite(eager[k].min_nonzero):
            np.testing.assert_allclose(
                stacked[k].min_nonzero, eager[k].min_nonzero, rtol=1e-9)


def test_sensitivity_stacked_matches_eager_gated(synth):
    fwd, W, x, cfg = synth
    keys = [f"layer{i}" for i in range(_L)] + ["head"]
    stacked = analyze.sensitivity_stacked(fwd, W, x, keys, cfg)
    eager = analyze.sensitivity(fwd, W, x, keys, cfg)
    for k in keys:
        np.testing.assert_allclose(stacked[k], eager[k], rtol=1e-7)


def test_analyze_ranges_stacked_api(synth):
    fwd, W, x, cfg = synth
    out = analyze.analyze_ranges_stacked(fwd, W, x, cfg)
    assert "" in out and "layer0" in out and "head" in out
    assert out["layer0"].n_ops > 0


def test_merge_range_maps_profile_aggregation():
    from repro.core.backend import RangeStat

    a = {"layer0": RangeStat(1.0, 0.5, False, 3), "": RangeStat(2.0, 1.0,
                                                                False, 1)}
    b = {"layer0": RangeStat(4.0, 0.25, True, 2), "head": RangeStat(
        8.0, 1.0, False, 1)}
    got = analyze.merge_range_maps([a, b], ["layer0", "head"])
    assert got["layer0"].max_abs == 4.0
    assert got["layer0"].min_nonzero == 0.25
    assert got["layer0"].crosses_zero and got["layer0"].n_ops == 5
    assert got["head"].max_abs == 8.0
    assert got[""].max_abs == 2.0


def test_discover_scopes_stacked(synth):
    fwd, W, x, cfg = synth
    assert analyze.discover_scopes_stacked(fwd, W, x, _L, cfg) == [
        "layer0", "layer1", "layer2", "head"]


# ---------------------------------------------------------------------------
# v3 round-trip of array-valued per-layer maps
# ---------------------------------------------------------------------------


def test_v3_roundtrip_array_valued_layer_maps(tmp_path):
    """A certificate whose layer_k/layer_format span many scan lanes —
    including numpy-integer values, which json cannot serialise raw —
    must survive the store bit-exactly."""
    from repro.core import formats as F

    L = 8
    layer_k = {f"layer{i}": np.int64(10 + i) for i in range(L)}
    layer_k["head"] = np.int64(9)
    layer_format = {
        f"layer{i}": F.from_bits(10 + i, 5, has_subnormals=True,
                                 saturating=True).to_dict()
        for i in range(L)
    }
    layer_format[""] = F.from_bits(24, 8, has_subnormals=True,
                                   saturating=True).to_dict()
    cert = certify.Certificate(
        model_id="lm/test", params_digest="d" * 64,
        class_key="lm/test/tokens[1x4]seed0",
        cfg=CaaConfig(u_max=2.0 ** -17), bounds_u_max=2.0 ** -17,
        final_abs_u=12.5, final_rel_u=float("inf"),
        required_k=18, satisfied_by=["binary32", "binary64"],
        layer_k={s: int(v) for s, v in layer_k.items()},
        layer_format=layer_format)
    cs = certify.CertificateSet(model_id="lm/test", params_digest="d" * 64,
                                certificates=[cert])
    store = certify.CertificateStore(str(tmp_path))
    store.put("k0", cs)
    got = certify.CertificateStore(str(tmp_path)).get("k0")
    assert got.to_json() == cs.to_json()
    assert got.certificates[0].layer_k == {f"layer{i}": 10 + i
                                           for i in range(L)} | {"head": 9}
    assert got.serving_layer_k["layer7"] == 17
    merged = got.serving_layer_format
    assert merged is not None and merged["layer3"]["k"] == 13
    # values must be plain python ints post-roundtrip (json round-trip)
    assert all(type(v) is int
               for v in got.certificates[0].layer_k.values())


# ---------------------------------------------------------------------------
# end-to-end: transformer arch → scan-native mixed/format certificate →
# scanned serving, bit-for-bit vs the eager per-layer reference
# ---------------------------------------------------------------------------


def _nano_arch():
    from repro import configs

    return dataclasses.replace(
        configs.get("qwen2_7b").SMOKE, name="qwen2-nano", n_layers=2,
        d_model=16, n_heads=2, n_kv_heads=2, d_head=8, d_ff=32, vocab=256)


def _train_nano(cfg, steps=200):
    bk = JOps()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (2, 6)))
    targets = jnp.roll(tokens, -1, axis=1)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda pp: T.next_token_loss(bk, pp, cfg, tokens, targets))(p)
        return loss, jax.tree_util.tree_map(lambda a, b: a - 0.5 * b, p, g)

    for _ in range(steps):
        loss, params = step(params)
    assert float(loss) < 0.1, "nano LM failed to overfit its profile"
    return params, tokens


@pytest.fixture(scope="module")
def lm_certified(tmp_path_factory):
    """The acceptance pipeline: train a nano transformer until its decode
    margins are wide, then certify mixed+formats through the scan-native
    analysis (profiles widen the range evidence)."""
    cfg = _nano_arch()
    params, tokens = _train_nano(cfg)
    store = certify.CertificateStore(str(tmp_path_factory.mktemp("lm")))
    cs = certify.certify_lm("qwen2_7b", cfg, params, seq=6, batch=2, seed=0,
                            k_max=53, mixed=True, formats=True,
                            profiles=(4,), store=store)
    return cfg, params, tokens, store, cs


@pytest.mark.slow
def test_lm_mixed_certificate_through_one_compile(lm_certified):
    """Acceptance: schema-v3 certificate via the scan-native analysis with
    exactly ONE probe-ladder compilation for the uniform search, the
    sensitivity ranking, the greedy descent and the exponent descent."""
    _, _, _, _, cs = lm_certified
    assert cs.meta["scan_native"]
    assert cs.meta["ladder_compiles"] == 1
    assert cs.meta["mixed"]["ladder_compiles"] == 1
    cert = cs.certificates[0]
    assert cert.required_k is not None
    assert cert.layer_k is not None
    assert set(cs.meta["scope_keys"]) == set(cert.layer_k)
    # the map is a pointwise refinement of the uniform k
    assert all(v <= cert.required_k for v in cert.layer_k.values())


@pytest.mark.slow
def test_lm_mean_bits_beats_uniform_binary32(lm_certified):
    """Acceptance: the certified serving cost (FLOP-weighted mean bits per
    served value) beats shipping uniform binary32."""
    _, _, _, _, cs = lm_certified
    mx = cs.meta["mixed"]
    assert mx["applied"]
    assert mx["mean_bits_flop_weighted"] < 32.0
    assert mx["savings_bits_vs_binary32"] > 0.0
    # and the formats stage reports the same headline for the cheapest map
    fm = cs.meta["formats"]
    assert fm["applied"]
    assert fm["savings_bits_vs_binary32"] > 0.0
    assert fm["savings_bits_flop_weighted"] > 0.0   # vs its own baseline


@pytest.mark.slow
def test_lm_bounds_confirmed_within_margins(lm_certified):
    """Persisted bounds come from the eager per-layer confirmation and must
    pin the argmax: 2·δ̄·u below the exact-enclosure top-1 gap."""
    _, _, _, _, cs = lm_certified
    cert = cs.certificates[0]
    assert cert.final_abs_u * cert.bounds_u_max * 2.0 < cert.meta["min_gap"]


@pytest.mark.slow
def test_lm_store_roundtrip_serves_identical_maps(lm_certified):
    cfg, params, _, store, cs = lm_certified
    again = certify.certify_lm("qwen2_7b", cfg, params, seq=6, batch=2,
                               seed=0, k_max=53, mixed=True, formats=True,
                               profiles=(4,), store=store)
    assert again.meta["from_store"]
    assert again.serving_layer_k == cs.serving_layer_k
    assert again.certificates[0].to_json() == cs.certificates[0].to_json()


@pytest.mark.slow
def test_lm_mixed_serving_bit_for_bit_vs_eager_reference(lm_certified):
    """Acceptance: serving applies the certified map through the scanned
    per-layer quantisation path, bit-for-bit against the eager per-layer
    reference (static k per layer, Python unroll) — both jitted, so each
    layer runs the identical XLA program."""
    from repro.launch.serve import MixedQuantJOps, UnrolledLayerLoop

    cfg, params, tokens, _, cs = lm_certified
    lk, dk = cs.serving_layer_k, cs.serving_k
    assert lk is not None and dk is not None

    class Unrolled(UnrolledLayerLoop, MixedQuantJOps):
        pass

    f_scan = jax.jit(
        lambda p, t: T.forward(MixedQuantJOps(lk, dk), p, cfg, t)[0])
    f_ref = jax.jit(
        lambda p, t: T.forward(Unrolled(lk, dk), p, cfg, t)[0])
    a = f_scan(params, tokens)
    b = f_ref(params, tokens)
    assert bool(jnp.array_equal(a, b))


def test_lm_format_serving_bit_for_bit_vs_eager_reference():
    """The scanned traced-format serving path applies a v3-style per-layer
    format map bit-for-bit against the eager per-layer reference."""
    from repro.launch.serve import FormatQuantJOps, UnrolledLayerLoop

    cfg = _nano_arch()
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    tokens = jnp.asarray(np.random.RandomState(5).randint(
        0, cfg.vocab, (2, 5)))
    fmt = {"k": 13, "emax": 15, "emin": -14, "has_subnormals": True,
           "saturating": True}
    lf = {"": dict(fmt, k=20),
          "layer0": dict(fmt, k=16),
          "layer1": dict(fmt, k=11, emax=7, emin=-6),
          "head": dict(fmt, k=9, emax=7, emin=-6)}

    class Unrolled(UnrolledLayerLoop, FormatQuantJOps):
        pass

    f_scan = jax.jit(
        lambda p, t: T.forward(FormatQuantJOps(lf), p, cfg, t)[0])
    f_ref = jax.jit(
        lambda p, t: T.forward(Unrolled(lf), p, cfg, t)[0])
    a = f_scan(params, tokens)
    b = f_ref(params, tokens)
    assert bool(jnp.array_equal(a, b))


def test_lm_sublayer_keys_serve_bit_for_bit():
    """Certificate maps with sub-layer keys (``layer0/attn``) must apply at
    sub-layer granularity inside the ONE scanned serving body — bit-for-bit
    against the eager per-layer unrolled reference, where the same keys
    resolve through the ordinary static scope path."""
    from repro.launch.serve import (FormatQuantJOps, MixedQuantJOps,
                                    UnrolledLayerLoop)

    cfg = _nano_arch()
    params = T.init_params(jax.random.PRNGKey(7), cfg)
    tokens = jnp.asarray(np.random.RandomState(9).randint(
        0, cfg.vocab, (2, 5)))

    lk = {"layer0": 16, "layer0/attn": 11, "layer1": 14, "layer1/mlp": 10,
          "head": 9}
    fmt = {"k": 13, "emax": 15, "emin": -14, "has_subnormals": True,
           "saturating": True}
    lf = {"": dict(fmt, k=20),
          "layer0": dict(fmt, k=16),
          "layer0/attn": dict(fmt, k=11, emax=7, emin=-6),
          "layer1": dict(fmt, k=14),
          "layer1/mlp": dict(fmt, k=10, emax=7, emin=-6)}

    class UnrolledM(UnrolledLayerLoop, MixedQuantJOps):
        pass

    class UnrolledF(UnrolledLayerLoop, FormatQuantJOps):
        pass

    am = jax.jit(
        lambda p, t: T.forward(MixedQuantJOps(lk, 20), p, cfg, t)[0]
    )(params, tokens)
    bm = jax.jit(
        lambda p, t: T.forward(UnrolledM(lk, 20), p, cfg, t)[0]
    )(params, tokens)
    assert bool(jnp.array_equal(am, bm))
    # the sub-layer k genuinely changes the arithmetic (the key is not
    # silently dropped to per-layer granularity)
    am2 = jax.jit(
        lambda p, t: T.forward(
            MixedQuantJOps(dict(lk, **{"layer0/attn": 16,
                                       "layer1/mlp": 14}), 20),
            p, cfg, t)[0]
    )(params, tokens)
    assert not bool(jnp.array_equal(am, am2))

    af = jax.jit(
        lambda p, t: T.forward(FormatQuantJOps(lf), p, cfg, t)[0]
    )(params, tokens)
    bf = jax.jit(
        lambda p, t: T.forward(UnrolledF(lf), p, cfg, t)[0]
    )(params, tokens)
    assert bool(jnp.array_equal(af, bf))


def test_apply_certificates_degrades_to_format_only_serving():
    """A v3 set whose certificates carry a complete layer_format map but no
    usable uniform required_k must degrade to format-only serving (the map
    has its own '' default), not crash the server."""
    from repro.core import formats as F
    from repro.launch import serve

    lf = {"": F.from_bits(16, 6, saturating=True).to_dict(),
          "layer0": F.from_bits(10, 5, saturating=True).to_dict()}
    cert = certify.Certificate(
        model_id="lm/test", params_digest="d" * 64, class_key="c0",
        cfg=CaaConfig(), bounds_u_max=2.0 ** -12, final_abs_u=1.0,
        final_rel_u=float("inf"), required_k=None, satisfied_by=[],
        layer_format=lf)
    cs = certify.CertificateSet(model_id="lm/test", params_digest="d" * 64,
                                certificates=[cert])
    assert cs.serving_k is None
    assert cs.serving_layer_format is not None

    sc = serve.ServeConfig(arch="qwen2_7b", certificates="store-dir")
    import repro.certify as C_

    patched = C_.serving_certificate
    C_.serving_certificate = lambda *a, **k: cs
    try:
        sc2, cs2 = serve.apply_certificates(sc, None, None)
    finally:
        C_.serving_certificate = patched
    assert cs2 is cs
    assert sc2.precision_k is None
    assert sc2.precision_layer_k is None
    assert sc2.precision_layer_format == cs.serving_layer_format
    # and the degraded config builds the traced-format backend
    bk = serve._backend(sc2)
    assert type(bk).__name__ == "FormatQuantJOps"

    # with no usable format map either, the old clear error stands
    bad = certify.CertificateSet(
        model_id="lm/test", params_digest="d" * 64,
        certificates=[dataclasses.replace(cert, layer_format=None)])
    C_.serving_certificate = lambda *a, **k: bad
    try:
        with pytest.raises(RuntimeError, match="no certifiable precision"):
            serve.apply_certificates(sc, None, None)
    finally:
        C_.serving_certificate = patched


def test_format_only_degrade_emits_traced_event():
    """The format-only serving degrade is an operational decision — it must
    show up in a configured trace (``serve.format_only_degrade`` event with
    the arch and map size), not just silently change the backend."""
    from repro import obs
    from repro.core import formats as F
    from repro.launch import serve

    lf = {"": F.from_bits(16, 6, saturating=True).to_dict(),
          "layer0": F.from_bits(10, 5, saturating=True).to_dict()}
    cert = certify.Certificate(
        model_id="lm/test", params_digest="d" * 64, class_key="c0",
        cfg=CaaConfig(), bounds_u_max=2.0 ** -12, final_abs_u=1.0,
        final_rel_u=float("inf"), required_k=None, satisfied_by=[],
        layer_format=lf)
    cs = certify.CertificateSet(model_id="lm/test", params_digest="d" * 64,
                                certificates=[cert])

    sc = serve.ServeConfig(arch="qwen2_7b", certificates="store-dir")
    import repro.certify as C_

    patched = C_.serving_certificate
    C_.serving_certificate = lambda *a, **k: cs
    tr = obs.configure()                      # in-memory tracer
    try:
        sc2, _ = serve.apply_certificates(sc, None, None)
    finally:
        C_.serving_certificate = patched
        obs.shutdown()
    assert sc2.precision_layer_format == cs.serving_layer_format
    evs = [e for e in tr.events if e.get("type") == "event"
           and e.get("name") == "serve.format_only_degrade"]
    assert len(evs) == 1
    assert evs[0]["fields"] == {"arch": "qwen2_7b", "scopes": 2}


def test_certificate_map_provenance_roundtrips_v3():
    """Per-profile map provenance lives in free-form ``meta`` — it must
    survive the v3 JSON round-trip, surface through
    ``CertificateSet.map_provenance()``, and print in ``summary()``."""
    base = dict(
        model_id="lm/test", params_digest="d" * 64,
        cfg=CaaConfig(), bounds_u_max=2.0 ** -12, final_abs_u=1.0,
        final_rel_u=float("inf"), required_k=20, satisfied_by=[],
        layer_k={"": 20, "layer0": 14})
    c_primary = certify.Certificate(
        class_key="lm/seq8", meta={"map_provenance": {
            "layer_k": "synthesized", "layer_format": "synthesized"}},
        **base)
    c_resynth = certify.Certificate(
        class_key="lm/seq6", meta={"map_provenance": {
            "layer_k": "resynthesized", "layer_format": "raised"},
            "profile_seq": 6},
        **base)
    c_bare = certify.Certificate(class_key="lm/seq4", **base)
    cs = certify.CertificateSet(
        model_id="lm/test", params_digest="d" * 64,
        certificates=[c_primary, c_resynth, c_bare])

    cs2 = certify.CertificateSet.from_json(cs.to_json())
    prov = cs2.map_provenance()
    assert prov == {
        "lm/seq8": {"layer_k": "synthesized",
                    "layer_format": "synthesized"},
        "lm/seq6": {"layer_k": "resynthesized", "layer_format": "raised"},
    }
    assert "lm/seq4" not in prov              # no provenance recorded
    assert cs2.lookup("lm/seq6").meta["profile_seq"] == 6
    text = cs2.summary()
    assert "map provenance:" in text
    assert "layer_k=resynthesized" in text
    # a set with no recorded provenance prints no provenance line
    assert "map provenance" not in certify.CertificateSet(
        model_id="lm/test", params_digest="d" * 64,
        certificates=[c_bare]).summary()
