"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st
from repro.kernels import ops, ref

SHAPES = [(8, 16, 8), (32, 64, 16), (40, 100, 30), (128, 256, 64)]
BLOCKS = [(8, 8, 16), (16, 16, 32)]


@pytest.mark.parametrize("shape", SHAPES)
def test_interval_matmul_matches_ref(shape):
    M, K, N = shape
    rng = np.random.RandomState(M + K)
    x = rng.randn(M, K).astype(np.float32)
    r = np.abs(rng.randn(M, K)).astype(np.float32) * 0.01
    w = rng.randn(K, N).astype(np.float32)
    lo, hi = x - r, x + r
    klo, khi, kmag = ops.interval_matmul_rigorous(
        lo, hi, w, block_m=16, block_n=16, block_k=32)
    rlo, rhi, rmag = ref.interval_matmul_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(w))
    scale = np.abs(np.asarray(rmag)).max() + 1
    # kernel applies the rigorous gamma-slop widening (grows with K);
    # the ref uses a fixed 1e-6 slop — allow for the difference
    tol = (ref.gamma_in_u(2 * K + 2, 2.0 ** -23) * 2.0 ** -23 + 1e-5) * scale
    assert np.allclose(klo, rlo, atol=tol)
    assert np.allclose(khi, rhi, atol=tol)
    assert np.allclose(kmag, rmag, rtol=1e-4, atol=1e-5 * scale)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_interval_matmul_enclosure(shape):
    M, K, N = shape
    rng = np.random.RandomState(K)
    x = rng.randn(M, K).astype(np.float32)
    r = np.abs(rng.randn(M, K)).astype(np.float32) * 0.05
    w = rng.randn(K, N).astype(np.float32)
    klo, khi, _ = ops.interval_matmul_rigorous(
        x - r, x + r, w, block_m=16, block_n=16, block_k=32)
    for _ in range(5):
        xs = x - r + 2 * r * rng.rand(M, K).astype(np.float32)
        y = xs.astype(np.float64) @ w.astype(np.float64)
        assert bool(np.all(y >= np.asarray(klo) - 1e-9))
        assert bool(np.all(y <= np.asarray(khi) + 1e-9))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("g", [0.5, 3.0])
def test_caa_matmul_matches_ref(shape, g):
    M, K, N = shape
    rng = np.random.RandomState(N)
    x = rng.randn(M, K).astype(np.float32)
    d = np.abs(rng.randn(M, K)).astype(np.float32)
    w = rng.randn(K, N).astype(np.float32)
    val, err = ops.caa_matmul_fused(x, d, w, g=g, block_m=16, block_n=16,
                                    block_k=32)
    rval, rerr = ref.caa_matmul_ref(jnp.asarray(x), jnp.asarray(d),
                                    jnp.asarray(w), g)
    assert np.allclose(val, rval, rtol=1e-4, atol=1e-4)
    assert np.allclose(err, rerr, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("k", [4, 8, 11, 16])
def test_quant_matmul_matches_ref(shape, k):
    M, K, N = shape
    rng = np.random.RandomState(k)
    x = rng.randn(M, K).astype(np.float32)
    w = rng.randn(K, N).astype(np.float32)
    out = ops.quant_matmul_emulated(x, w, k=k, block_m=16, block_n=16,
                                    block_k=32)
    rout = ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), k)
    # accumulation-order differences are bounded by γ_K at f32 precision,
    # then quantisation can flip one k-bit ulp
    tol = max(2.0 ** (1 - k), 1e-5) * (np.abs(np.asarray(rout)).max() + 1)
    assert np.allclose(out, rout, atol=tol)


def test_quant_matmul_inputs_already_quantized_exact():
    """With operands already on the k-bit grid and tiny K, result is exact."""
    k = 8
    from repro.core import quantize, formats
    rng = np.random.RandomState(0)
    x = np.asarray(quantize.quantize(rng.randn(16, 16).astype(np.float32), k))
    w = np.asarray(quantize.quantize(rng.randn(16, 16).astype(np.float32), k))
    out = ops.quant_matmul_emulated(x, w, k=k, block_m=16, block_n=16,
                                    block_k=16)
    rout = ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), k)
    assert bool(jnp.array_equal(out, rout))


@pytest.mark.parametrize("k", [3, 8, 11, 16, 24])
def test_quant_matmul_dynamic_k_matches_ref_bitwise(k):
    """The scalar-k-as-argument GEMM is bitwise the static-k reference: same
    operand rounding, same f32 accumulation, same output rounding — only the
    dropped-bit count is data instead of Python."""
    from repro.kernels.quant_matmul import quant_matmul_dynamic_k
    rng = np.random.RandomState(k)
    x = jnp.asarray(rng.randn(24, 40).astype(np.float32))
    w = jnp.asarray(rng.randn(40, 16).astype(np.float32))
    out = quant_matmul_dynamic_k(x, w, jnp.asarray(k, jnp.int32))
    assert bool(jnp.array_equal(out, ref.quant_matmul_ref(x, w, k)))


def test_quant_matmul_dynamic_k_single_compile_over_grid():
    """One jit compilation serves the whole k grid — the per-k-recompile
    elimination the probe ladder and mixed serving rely on."""
    from repro.kernels.quant_matmul import quant_matmul_dynamic_k
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(16, 32).astype(np.float32))
    w = jnp.asarray(rng.randn(32, 8).astype(np.float32))
    f = jax.jit(quant_matmul_dynamic_k)
    for k in (24, 16, 11, 8, 5, 3):
        got = f(x, w, jnp.asarray(k, jnp.int32))
        assert bool(jnp.array_equal(got, ref.quant_matmul_ref(x, w, k)))
    assert f._cache_size() == 1


@given(st.integers(min_value=2, max_value=24),
       st.integers(min_value=0, max_value=10 ** 6))
def test_property_quant_matmul_dynamic_k_differential(k, seed):
    from repro.kernels.quant_matmul import quant_matmul_dynamic_k
    rng = np.random.RandomState(seed % 2 ** 31)
    x = jnp.asarray((rng.randn(8, 12) * 10.0 ** rng.randint(-3, 4))
                    .astype(np.float32))
    w = jnp.asarray(rng.randn(12, 6).astype(np.float32))
    out = quant_matmul_dynamic_k(x, w, jnp.asarray(k, jnp.int32))
    assert bool(jnp.array_equal(out, ref.quant_matmul_ref(x, w, k),
                                equal_nan=True))


def test_padding_path():
    """Non-tile-aligned shapes go through the zero-padding wrapper."""
    rng = np.random.RandomState(5)
    x = rng.randn(7, 13).astype(np.float32)
    d = np.abs(rng.randn(7, 13)).astype(np.float32)
    w = rng.randn(13, 9).astype(np.float32)
    val, err = ops.caa_matmul_fused(x, d, w, g=1.0, block_m=8, block_n=8,
                                    block_k=8)
    rval, rerr = ref.caa_matmul_ref(jnp.asarray(x), jnp.asarray(d),
                                    jnp.asarray(w), 1.0)
    assert np.allclose(val, rval, rtol=1e-4, atol=1e-5)
    assert np.allclose(err, rerr, rtol=1e-4, atol=1e-5)


def test_batched_inputs():
    rng = np.random.RandomState(6)
    x = rng.randn(2, 5, 32).astype(np.float32)
    w = rng.randn(32, 8).astype(np.float32)
    out = ops.quant_matmul_emulated(x, w, k=10, block_m=8, block_n=8,
                                    block_k=16)
    assert out.shape == (2, 5, 8)


@pytest.mark.parametrize("shape", [(2, 2, 4, 16, 64), (1, 8, 8, 32, 128),
                                   (3, 1, 4, 48, 512)])
def test_flash_decode_matches_ref(shape):
    from repro.kernels.flash_decode import flash_decode_attention
    B, K, G, S, D = shape[0], shape[1], shape[2], shape[4], shape[3]
    rng = np.random.RandomState(B + S)
    q = rng.randn(B, K, G, D).astype(np.float32)
    k = rng.randn(B, S, K, D).astype(np.float32)
    v = rng.randn(B, S, K, D).astype(np.float32)
    lengths = rng.randint(1, S + 1, size=(B,)).astype(np.int32)
    out = flash_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), jnp.asarray(lengths),
                                 block_s=16, interpret=True)
    ref_out = ref.flash_decode_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_full_length():
    from repro.kernels.flash_decode import flash_decode_attention
    rng = np.random.RandomState(0)
    B, K, G, S, D = 1, 2, 2, 64, 32
    q = rng.randn(B, K, G, D).astype(np.float32)
    k = rng.randn(B, S, K, D).astype(np.float32)
    v = rng.randn(B, S, K, D).astype(np.float32)
    lengths = np.full((B,), S, np.int32)
    out = flash_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), jnp.asarray(lengths),
                                 block_s=32, interpret=True)
    ref_out = ref.flash_decode_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)

@pytest.mark.parametrize("fmt", [(8, 15, -14), (4, 8, -6), (11, 30, -30)])
@pytest.mark.parametrize("lead", [(12,), (2, 5)])
def test_quant_matmul_format_dispatch_bitwise(fmt, lead):
    """The serving dispatch (FormatQuantJOps.matmul) must be bitwise
    IDENTICAL through both of its arms: eager ref on CPU, the single-K-step
    scalar-prefetch Pallas kernel on TPU (interpret mode here). Batched
    leading dims flatten through the kernel and restore."""
    from repro.kernels.quant_matmul import (quant_matmul_format_dispatch,
                                            quant_matmul_format_ref)
    rng = np.random.RandomState(fmt[0] + len(lead))
    x = jnp.asarray(rng.randn(*lead, 40).astype(np.float32))
    w = jnp.asarray(rng.randn(40, 24).astype(np.float32))
    f = jnp.asarray(fmt, jnp.int32)
    want = quant_matmul_format_ref(x, w, f)
    eager = quant_matmul_format_dispatch(x, w, f, force_kernel=False)
    kernel = quant_matmul_format_dispatch(x, w, f, force_kernel=True,
                                          interpret=True)
    assert bool(jnp.array_equal(eager, want))
    assert bool(jnp.array_equal(kernel, want))
    assert kernel.shape == (*lead, 24)
