import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro  # noqa: E402  (enables x64 before any test builds jax state)

from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")
