import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # tests/_hyp.py shim

import repro  # noqa: E402  (enables x64 before any test builds jax state)

# hypothesis is a [dev] extra — property tests skip cleanly without it
# (the test modules import given/st from the tests/_hyp.py shim), and the
# profile is registered only when it is available.
try:
    from hypothesis import HealthCheck, settings
except ImportError:
    pass
else:
    settings.register_profile(
        "ci",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("ci")
