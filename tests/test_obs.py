"""repro.obs: spans, JSONL schema, metrics, violation monitors (ISSUE 6).

The contract under test:

  * spans nest (depth/parent) and time monotonically (a child can never
    outlast its parent; seq reconstructs interleavings without the clock);
  * the JSONL trace round-trips through ``load_events`` and passes
    ``validate_events`` (the CI smoke gate), and malformed traces fail it;
  * all obs calls are no-ops with no tracer configured (the hot paths pay
    nothing by default);
  * the structured logger renders human-readable lines AND mirrors every
    record into the trace stream;
  * metrics: histogram math, Prometheus text exposition (cumulative
    buckets), JSONL snapshots;
  * violation monitors stay silent on in-distribution traffic and FIRE on
    out-of-enclosure input / an empirical error beyond δ̄ — and attaching
    one to a serving backend leaves the served values bitwise untouched;
  * probe ladders (uniform AND stacked scan-native) compile exactly once
    under tracing, and the trace says so.
"""
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
@pytest.fixture(autouse=True)
def _fresh_tracer():
    """Never leak a global tracer between tests (or into other modules)."""
    obs.shutdown()
    yield
    obs.shutdown()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_spans_nest_and_time_monotonically():
    tr = obs.configure()          # in-memory
    with obs.span("outer", stage=1):
        with obs.span("inner"):
            pass
        with obs.span("inner2"):
            pass
    spans = {e["name"]: e for e in tr.events if e["type"] == "span"}
    assert set(spans) == {"outer", "inner", "inner2"}
    assert spans["outer"]["depth"] == 0 and spans["outer"]["parent"] is None
    assert spans["inner"]["depth"] == 1
    assert spans["inner"]["parent"] == "outer"
    assert spans["inner2"]["parent"] == "outer"
    # children close before the parent and can never outlast it
    assert spans["inner"]["dur_s"] >= 0
    assert (spans["inner"]["dur_s"] + spans["inner2"]["dur_s"]
            <= spans["outer"]["dur_s"])
    assert spans["inner"]["seq"] < spans["inner2"]["seq"] < spans["outer"]["seq"]
    seqs = [e["seq"] for e in tr.events]
    assert seqs == sorted(seqs) == list(range(len(seqs)))


def test_span_set_and_rename_before_close():
    tr = obs.configure()
    with obs.span("probe", k=10) as sp:
        sp.set(result=3)
        sp.rename("compile")
    (sp_ev,) = [e for e in tr.events if e["type"] == "span"]
    assert sp_ev["name"] == "compile"
    assert sp_ev["attrs"] == {"k": 10, "result": 3}


def test_disabled_obs_calls_are_noops():
    assert not obs.enabled()
    sp = obs.span("anything", a=1)
    with sp as s:
        s.set(b=2)      # must not raise on the null span
        s.rename("x")
    obs.counter("c")
    obs.gauge("g", 1.0)
    obs.event("e", f=1)
    obs.flush()
    assert obs.get_tracer() is None


# ---------------------------------------------------------------------------
# JSONL schema round-trip
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_validates(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    obs.configure(path=path, program="test", argv=["--x"])
    with obs.span("stage_a"):
        obs.event("hit", key="abc")
        obs.counter("store.hits", 2)
        obs.gauge("margin", 1.5)
    obs.shutdown()      # flushes counters/gauges and closes the file

    events = obs.load_events(path)
    assert obs.validate_events(events) == []
    assert events[0]["type"] == "meta"
    assert events[0]["schema"] == obs.SCHEMA
    assert events[0]["program"] == "test" and events[0]["argv"] == ["--x"]
    (counters,) = [e for e in events if e["type"] == "counters"]
    assert counters["values"] == {"store.hits": 2}
    (gauges,) = [e for e in events if e["type"] == "gauges"]
    assert gauges["values"] == {"margin": 1.5}


def test_validate_rejects_bad_events():
    assert obs.validate_events([]) == ["empty trace (no events)"]
    errs = obs.validate_events([
        {"type": "nonsense", "seq": 0},
        {"type": "meta", "schema": 99, "seq": 1},
        {"type": "span", "name": "x", "t": 0.0, "dur_s": -1.0,
         "depth": 0, "attrs": {}, "seq": 2},
        {"type": "span", "name": "y", "seq": "not-an-int"},
    ])
    assert any("unknown type" in e for e in errs)
    assert any("schema" in e for e in errs)
    assert any("negative span duration" in e for e in errs)
    assert any("seq" in e for e in errs)


def test_load_events_raises_on_malformed_jsonl(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"type": "meta", "schema": 1, "seq": 0}\n{oops\n')
    with pytest.raises(ValueError, match="malformed"):
        obs.load_events(str(p))


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------


def test_logger_renders_and_mirrors_to_trace(capfd):
    tr = obs.configure()
    log = obs.get_logger("testcomp")
    log.info("model trained", acc=0.93, steps=10)
    err = capfd.readouterr().err
    assert "[testcomp]" in err and "model trained" in err and "acc=0.93" in err
    (ev,) = [e for e in tr.events if e["type"] == "event"]
    assert ev["name"] == "log.testcomp"
    assert ev["fields"]["msg"] == "model trained"
    assert ev["fields"]["acc"] == 0.93


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_buckets_mean_quantile():
    h = obs.Histogram("lat")
    for v in (0.001, 0.002, 0.004, 0.2):
        h.observe(v)
    assert h.count == 4
    assert h.mean == pytest.approx(0.05175)
    assert h.min == 0.001 and h.max == 0.2
    assert h.quantile(0.5) <= h.quantile(0.99)
    assert sum(h.counts) == 4


def test_prometheus_exposition_cumulative(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("serve.requests", 3)
    reg.gauge("serve.tokens_per_s", 123.5)
    reg.observe("serve.decode_latency_s", 0.01)
    reg.observe("serve.decode_latency_s", 0.02)
    text = reg.render_prometheus()
    assert "# TYPE serve_requests counter\nserve_requests 3" in text
    assert "serve_tokens_per_s 123.5" in text
    assert "serve_decode_latency_s_count 2" in text
    # bucket counts are cumulative and end at +Inf == count
    acc = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
           if line.startswith("serve_decode_latency_s_bucket")]
    assert acc == sorted(acc) and acc[-1] == 2
    assert 'le="+Inf"' in text
    out = tmp_path / "m.prom"
    reg.write_prometheus(str(out))
    assert out.read_text() == text


def test_metrics_jsonl_snapshot(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("n", 1)
    reg.observe("lat", 0.5)
    path = str(tmp_path / "metrics.jsonl")
    reg.write_jsonl(path)
    reg.write_jsonl(path)       # appends — one snapshot per line
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 2
    assert lines[0]["type"] == "metrics"
    assert lines[0]["counters"] == {"n": 1}
    assert lines[0]["histograms"]["lat"]["count"] == 1


# ---------------------------------------------------------------------------
# violation monitors
# ---------------------------------------------------------------------------


def test_monitor_silent_in_distribution_fires_out_of_enclosure():
    mon = obs.ViolationMonitor({"dense1": {"max_abs": 2.0}}, dbar_u=100.0,
                               u=2.0 ** -10)
    # in-distribution: inside the certified enclosure → no violations
    mon.observe_scope(["dense1"], {"max_abs": 1.5, "n_over": 0,
                                   "n_under": 0, "n_nonfinite": 0})
    assert mon.violations == 0
    assert mon.scope_margin["dense1"] == pytest.approx(math.log2(2.0 / 1.5))
    # under-certified input: observed magnitude above the proven enclosure
    mon.observe_scope(["dense1"], {"max_abs": 8.0, "n_over": 0,
                                   "n_under": 0, "n_nonfinite": 0})
    assert mon.counters["obs.enclosure_violations"] == 1
    assert mon.violations == 1
    assert mon.scope_margin["dense1"] == pytest.approx(math.log2(2.0 / 8.0))
    # overflow events against the certified format are violations by
    # themselves, even at in-enclosure magnitudes
    mon.observe_scope(["dense1"], {"max_abs": 1.0, "n_over": 3,
                                   "n_under": 0, "n_nonfinite": 0})
    assert mon.counters["obs.overflow_events"] == 3
    assert mon.counters["obs.enclosure_violations"] == 2
    # an unmapped scope only counts health events, never false-fires
    mon.observe_scope(["elsewhere"], {"max_abs": 1e9, "n_over": 0,
                                      "n_under": 0, "n_nonfinite": 0})
    assert mon.counters["obs.enclosure_violations"] == 2


def test_monitor_error_sample_against_dbar():
    mon = obs.ViolationMonitor({}, dbar_u=10.0, u=2.0 ** -10)
    mon.observe_error(4.0)
    assert mon.counters["obs.bound_violations"] == 0
    assert mon.error_margin_u() == pytest.approx(6.0)
    mon.observe_error(12.5)
    assert mon.counters["obs.bound_violations"] == 1
    assert mon.error_margin_u() == pytest.approx(-2.5)
    assert mon.worst_err_u == 12.5


def test_monitor_from_certificate_set_folds_layer_wildcard():
    class _CS:
        meta = {"formats": {"applied": True, "scope_ranges": {
            "": {"max_abs": 9.9},          # default scope: not addressable
            "layer0": {"max_abs": 2.0},
            "layer1": {"max_abs": 4.0},
            "head": {"max_abs": 1.0},
        }}}

        @staticmethod
        def error_bars():
            return {"dbar_u": 100.0, "u": 2.0 ** -12}

    mon = obs.ViolationMonitor.from_certificate_set(_CS())
    assert mon.envelopes["layer*"] == {"max_abs": 4.0}   # max over layers
    assert "" not in mon.envelopes
    # the scanned serving path observes under the stacked wildcard scope;
    # the loosest layer's enclosure bounds it (no false positives)
    mon.observe_scope(["layer*"], {"max_abs": 3.0})
    assert mon.violations == 0
    mon.observe_scope(["layer*"], {"max_abs": 40.0})
    assert mon.violations == 1
    # concrete scopes still resolve their own (tighter) envelope
    mon.observe_scope(["head"], {"max_abs": 1.5})
    assert mon.violations == 2


def test_monitor_layer_fold_merges_explicit_wildcard():
    """An explicit (narrow) layer* enclosure must be merge-maxed with the
    concrete layer folds, not trusted alone: the scanned serving path runs
    *every* layer under the wildcard scope, so its envelope has to cover
    the widest certified layer. Concrete layer<i> envelopes must stay
    untouched — neither widened nor shadowed by the fold."""
    class _CS:
        meta = {"formats": {"applied": True, "scope_ranges": {
            "layer0": {"max_abs": 1.0},
            "layer3": {"max_abs": 5.0},
            "layer*": {"max_abs": 2.0},
            "layer3/attn": {"max_abs": 0.5},
        }}}

        @staticmethod
        def error_bars():
            return {"dbar_u": 100.0, "u": 2.0 ** -12}

    mon = obs.ViolationMonitor.from_certificate_set(_CS())
    assert mon.envelopes["layer*"] == {"max_abs": 5.0}   # merge-max, not 2.0
    # observing layer3's certified magnitude under the wildcard path must
    # not false-positive against the stale explicit layer* entry
    mon.observe_scope(["layer*"], {"max_abs": 4.9})
    assert mon.violations == 0
    # the concrete layer3 envelope is not widened by the fold
    mon.observe_scope(["layer3"], {"max_abs": 5.2})
    assert mon.violations == 1
    # sub-layer keys fold into their own layer*/<sub> group
    assert mon.envelopes["layer*/attn"] == {"max_abs": 0.5}
    assert mon.envelopes["layer3/attn"] == {"max_abs": 0.5}
    mon.observe_scope(["layer*", "attn"], {"max_abs": 0.7})
    assert mon.violations == 2


def test_monitor_export_into_registry():
    mon = obs.ViolationMonitor({"blk": {"max_abs": 2.0}}, dbar_u=10.0)
    mon.observe_scope(["blk"], {"max_abs": 1.0})
    mon.observe_error(3.0)
    reg = obs.MetricsRegistry()
    mon.export(reg)
    assert reg.counters["obs.scope_observations"] == 1
    assert reg.counters["obs.enclosure_violations"] == 0
    assert reg.gauges["obs.bound_margin_log2{scope=blk}"] == pytest.approx(1.0)
    assert reg.gauges["obs.error_margin_u"] == pytest.approx(7.0)
    # idempotent re-export: counter deltas, not double counts
    mon.export(reg)
    assert reg.counters["obs.scope_observations"] == 1


def test_monitored_serving_backend_bitwise_identical_and_fires():
    """Attaching a ViolationMonitor must not change a single served bit,
    and must fire on input outside the certified enclosure."""
    from repro.launch.serve import QuantJOps

    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(4, 8), jnp.float32)
    w = jnp.asarray(rng.randn(8, 8) * 0.1, jnp.float32)

    def run(bk):
        with bk.scope("blk"):
            return bk.matmul(a, w)

    # k=12: inside the monitor slack's documented k >= 11 regime (the
    # envelope is measured on the QUANTIZED output; the monitor observes
    # the raw product, up to one ulp above it)
    base = np.asarray(run(QuantJOps(12, jnp.float32, jnp.float32)))
    mon = obs.ViolationMonitor({"blk": {"max_abs": float(np.abs(base).max())}})
    bk = QuantJOps(12, jnp.float32, jnp.float32)
    bk.monitor = mon
    monitored = np.asarray(run(bk))
    np.testing.assert_array_equal(base, monitored)
    assert mon.counters["obs.scope_observations"] == 1
    assert mon.violations == 0
    # inject out-of-enclosure traffic: magnitudes 1000x the certified range
    with bk.scope("blk"):
        bk.matmul(a * 1000.0, w)
    assert mon.counters["obs.enclosure_violations"] >= 1
    assert mon.violations >= 1


# ---------------------------------------------------------------------------
# compile-once under tracing
# ---------------------------------------------------------------------------


def _nano_digits():
    from repro.models import paper_models as PM

    params = PM.init_digits(jax.random.PRNGKey(0), d_in=12, h1=8, h2=6,
                            n_classes=4)
    lo = np.zeros(12)
    hi = np.full(12, 0.1)
    return PM.digits_forward, params, lo, hi


def test_uniform_ladder_compiles_once_under_tracing():
    from repro.certify.batch import ProbeLadder, stack_class_ranges

    forward, params, lo, hi = _nano_digits()
    x = stack_class_ranges([lo], [hi])
    tr = obs.configure()
    ladder = ProbeLadder(forward, params, x)
    for k in (10, 14, 18):
        ladder(k)
    assert ladder.compiles == 1
    assert tr.counters["ladder.compiles"] == 1
    names = [e["name"] for e in tr.events if e["type"] == "span"]
    assert names.count("ladder_compile") == 1
    assert names.count("ladder_probe") == 2
    (comp,) = [e for e in tr.events if e.get("name") == "ladder_compile"]
    assert comp["attrs"]["ladder"] == "uniform"


def test_stacked_mixed_ladder_compiles_once_under_tracing():
    """The scan-native per-layer ladder: every probe of every map — and the
    one-hot sensitivity probes — reuse ONE compiled executable, and the
    trace records exactly one ladder_compile span."""
    from repro.certify.mixed import MixedProbeLadder
    from repro.certify.batch import stack_class_ranges

    rng = np.random.RandomState(0)
    L, d = 2, 4
    params = {
        "layers": {"w": jnp.asarray(rng.randn(L, d, d) * 0.3, jnp.float32),
                   "b": jnp.zeros((L, d), jnp.float32)},
        "head": jnp.asarray(rng.randn(d, 3) * 0.3, jnp.float32),
    }

    def forward(ops, p, x):
        def body(lp, carry, i, aux):
            h = ops.add(ops.matmul(carry, ops.param(lp["w"])),
                        ops.param(lp["b"]))
            return ops.relu(h), None
        h, _ = ops.layer_loop(body, p["layers"], x, L)
        with ops.scope("head"):
            return ops.matmul(h, ops.param(p["head"]))

    x = stack_class_ranges([np.full(d, -0.5)], [np.full(d, 0.5)])
    tr = obs.configure()
    ladder = MixedProbeLadder(forward, params, x,
                              scope_keys=["layer0", "layer1", "head"],
                              stacked=True)
    ladder({"layer0": 12, "layer1": 12, "head": 12}, default_k=12)
    ladder({"layer0": 10, "layer1": 14, "head": 12}, default_k=12)
    ladder.sensitivity("layer1", at_k=12)
    assert ladder.compiles == 1
    assert tr.counters["ladder.compiles"] == 1
    names = [e["name"] for e in tr.events if e["type"] == "span"]
    assert names.count("ladder_compile") == 1
    assert names.count("ladder_probe") == 2


# ---------------------------------------------------------------------------
# report + bench
# ---------------------------------------------------------------------------


def test_report_renders_stage_table():
    from repro.obs import report

    tr = obs.configure()
    with obs.span("certify_run"):
        with obs.span("required_k_search"):
            with obs.span("ladder_probe", scope="dense1"):
                pass
        obs.counter("store.misses")
        obs.gauge("margin", 2.0)
    obs.flush()
    text = report.render(tr.events)
    assert "certify_run" in text and "required_k_search" in text
    assert "store.misses" in text and "margin" in text
    summ = report.summarize(tr.events)
    assert summ["root_total_s"] > 0
    assert summ["spans"]["required_k_search"]["count"] == 1


def test_bench_append_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    assert obs.read_bench("runs") == []
    obs.append_bench("runs", {"kind": "certify", "arch": "a", "wall_s": 1.5})
    obs.append_bench("runs", {"kind": "certify", "arch": "b", "wall_s": 1.2})
    entries = obs.read_bench("runs")
    assert len(entries) == 2
    assert all("t" in e for e in entries)
    assert entries[1]["wall_s"] == 1.2
    # same identity fields in the same session → replace, not duplicate
    obs.append_bench("runs", {"kind": "certify", "arch": "b", "wall_s": 0.9})
    entries = obs.read_bench("runs")
    assert len(entries) == 2
    assert entries[1]["wall_s"] == 0.9
    # a non-array file is corrupt, not silently accepted
    (tmp_path / "BENCH_bad.json").write_text('{"not": "a list"}')
    with pytest.raises(ValueError):
        obs.read_bench("bad")
