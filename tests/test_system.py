"""End-to-end system behaviour: the paper's full workflow plus the
production substrates (checkpoint/restart, fault tolerance, stragglers,
data determinism, optimizer, compression)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import caa, precision, quantize
from repro.core.backend import CaaOps, JOps
from repro.data import pipeline, synthetic_digits
from repro.models import paper_models as PM
from repro.optim import grad_compress as gc
from repro.optim import optimizer as opt


# ---------------------------------------------------------------------------
# the paper's headline workflow: train → analyze → pick k → low-precision
# inference preserves top-1
# ---------------------------------------------------------------------------

def _train_digits(params, imgs, labels, steps=300, lr=0.2):
    bk = JOps()

    def loss_fn(p, x, y):
        logits = PM.digits_logits(bk, p, x)
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    n = imgs.shape[0]
    for i in range(steps):
        idx = np.random.RandomState(i).choice(n, 64)
        params, l = step(params, jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]))
    return params


@pytest.fixture(scope="module")
def trained_digits():
    imgs, labels = synthetic_digits.make_dataset(800, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0), h1=128, h2=64)
    params = _train_digits(params, imgs, labels)
    bk = JOps()
    acc = float((jnp.argmax(PM.digits_logits(bk, params, jnp.asarray(imgs)), -1)
                 == jnp.asarray(labels)).mean())
    assert acc > 0.9, f"training failed: acc={acc}"
    return params, imgs, labels


@pytest.mark.slow
def test_e2e_certified_low_precision_inference(trained_digits):
    """The paper's end game: the analysis certifies decisions at k=8; every
    certified decision must agree with the exact model."""
    params, imgs, labels = trained_digits
    test = imgs[:32]
    n_certified = 0
    n_preserved = 0
    for i in range(test.shape[0]):
        x = test[i].astype(np.float64)
        cfg = caa.CaaConfig(u_max=2**-7, emulate_k=8)
        bk = CaaOps(cfg)
        probs = PM.digits_forward(bk, params, caa.weight(x, cfg))
        pred = int(jnp.argmax(probs.val))
        lo = np.asarray(probs.exact.lo)
        hi = np.asarray(probs.exact.hi)
        if precision.classification_safe(lo, hi, pred):
            n_certified += 1
            ref = PM.digits_forward(JOps(jnp.float64, jnp.float64), params,
                                    jnp.asarray(x))
            if int(jnp.argmax(ref)) == pred:
                n_preserved += 1
    assert n_certified >= 16, f"too few certified: {n_certified}"
    assert n_preserved == n_certified, "a certified decision was wrong!"


def test_e2e_analysis_time_far_below_paper(trained_digits):
    """Paper: 12 s/class on Digits with MPFI. Our tensorised engine must be
    orders faster (jitted steady-state)."""
    import time
    params, imgs, _ = trained_digits
    cfg = caa.CaaConfig(u_max=2**-7)

    def run(x):
        bk = CaaOps(cfg)
        out = PM.digits_forward(bk, params, caa.weight(x, cfg))
        return out.dbar, out.ebar

    jrun = jax.jit(run)
    x = jnp.asarray(imgs[0], jnp.float64)
    jax.block_until_ready(jrun(x))
    t0 = time.perf_counter()
    for i in range(5):
        jax.block_until_ready(jrun(jnp.asarray(imgs[i], jnp.float64)))
    per_input = (time.perf_counter() - t0) / 5
    assert per_input < 1.0, f"analysis too slow: {per_input}s"


# ---------------------------------------------------------------------------
# substrates
# ---------------------------------------------------------------------------

def test_data_pipeline_deterministic_and_host_sharded():
    dc = pipeline.DataConfig(vocab=1000, seq=16, global_batch=8, n_hosts=2,
                             host_id=0)
    b1 = pipeline.batch_at(dc, 7)
    b2 = pipeline.batch_at(dc, 7)
    assert bool(jnp.array_equal(b1["tokens"], b2["tokens"]))
    dc1 = pipeline.DataConfig(vocab=1000, seq=16, global_batch=8, n_hosts=2,
                              host_id=1)
    b3 = pipeline.batch_at(dc1, 7)
    assert not bool(jnp.array_equal(b1["tokens"], b3["tokens"]))
    assert b1["tokens"].shape == (4, 16)
    assert int(b1["tokens"].max()) < 1000


def test_checkpoint_save_restore_atomic(tmp_path):
    from repro.checkpoint.checkpointing import Checkpointer

    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"w": jnp.arange(8.0), "opt": {"m": jnp.ones((3, 3))},
             "step": jnp.asarray(5)}
    ck.save(5, state)
    ck.save(10, state, blocking=False)
    ck.wait()
    ck.save(15, state)
    assert ck.all_steps() == [10, 15]  # keep=2 gc'd step 5
    restored, manifest = ck.restore(state)
    assert manifest["step"] == 15
    assert bool(np.array_equal(restored["w"], np.arange(8.0)))


def test_training_restart_bitexact(tmp_path):
    """Kill-and-restore mid-run must reproduce the uninterrupted run (the
    stateless pipeline + full state checkpointing guarantee)."""
    from repro import configs
    from repro.checkpoint.checkpointing import Checkpointer
    from repro.launch.train import TrainConfig, build_train_step
    from repro.launch.mesh import make_host_mesh

    arch = configs.get("qwen2_7b").SMOKE
    tc = TrainConfig(seq=16, global_batch=2, steps=8)
    mesh = make_host_mesh()
    dc = pipeline.DataConfig(vocab=arch.vocab, seq=16, global_batch=2)
    with mesh:
        step_fn, init_fn, _ = build_train_step(arch, tc, mesh)

        s = init_fn(jax.random.PRNGKey(0))
        losses_a = []
        for i in range(6):
            s, l = step_fn(s, pipeline.batch_at(dc, i))
            losses_a.append(float(l))

        ck = Checkpointer(str(tmp_path))
        s = init_fn(jax.random.PRNGKey(0))
        for i in range(3):
            s, l = step_fn(s, pipeline.batch_at(dc, i))
        ck.save(3, s)
        template = jax.tree_util.tree_map(np.asarray, s)
        restored, _ = ck.restore(template)
        s2 = jax.tree_util.tree_map(jnp.asarray, restored)
        losses_b = []
        for i in range(3, 6):
            s2, l = step_fn(s2, pipeline.batch_at(dc, i))
            losses_b.append(float(l))
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-5)


def test_fault_tolerance_swap_and_shrink():
    from repro.runtime.fault_tolerance import Supervisor

    sup = Supervisor(n_hosts=8, chips_per_host=4, model_parallel=4, spares=1)
    ev = sup.handle_failures(10, {3})
    assert ev.kind == "swap"
    sup.monitor.hosts[5].alive = False
    ev = sup.handle_failures(20, {5})
    assert ev.kind == "shrink"
    d, m = ev.new_mesh
    assert m == 4 and d * m <= 7 * 4 and d >= 1 and (d & (d - 1)) == 0


def test_elastic_restore_to_smaller_mesh(tmp_path):
    from repro.checkpoint.checkpointing import Checkpointer
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, state)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = ck.restore(state, shardings=sh)
    assert bool(np.array_equal(np.asarray(restored["w"]),
                               np.arange(16.0).reshape(4, 4)))


def test_straggler_detector():
    from repro.runtime.straggler import StragglerDetector, plan_backups

    det = StragglerDetector(6)
    flagged = set()
    for step in range(25):
        for h in range(6):
            det.report(h, 1.0 + (4.0 if h == 2 else 0.02 * h))
        flagged = det.flagged()
    assert flagged == {2}
    plans = plan_backups(flagged, fastest=[0, 1], shard_of_host={2: 2})
    assert plans[0].backup_host == 0 and plans[0].shard == 2


def test_adamw_converges_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                          total_steps=200)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = opt.update(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adamw_8bit_moments_converges():
    cfg8 = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                           total_steps=200, quantized_moments=True)
    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.randn(64) * 3)}
    state = opt.init(params, cfg8)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state, cfg8)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_compression_error_feedback():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(256) * 3)
    ef = gc.init_ef({"x": x})
    params = {"x": x}
    for i in range(150):
        grads = {"x": 2 * params["x"]}
        dec, ef = gc.compress_tree(grads, ef)
        params = {"x": params["x"] - 0.05 * dec["x"]}
    assert float(jnp.abs(params["x"]).max()) < 0.1
    assert float(jnp.abs(ef.residual["x"]).max()) < 1.0


def test_moe_dense_vs_dropping_equivalence():
    """With generous capacity, the dropping path must match dense combine."""
    from repro.models import moe as M
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, d=16, d_ff=32, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 16))
    bk = JOps()
    y_dense = M.moe_mlp(bk, x, p, n_experts=4, top_k=2, mode="dense")
    y_drop = M.moe_mlp(bk, x, p, n_experts=4, top_k=2, mode="dropping",
                       capacity_factor=4.0, chunk_tokens=12)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_drop),
                               rtol=2e-4, atol=2e-5)


def test_rwkv_chunked_matches_stepwise():
    """Chunked WKV must equal the naive per-token recurrence."""
    from repro.models import ssm as S
    rng = np.random.RandomState(0)
    B, T, H, C = 1, 20, 2, 4
    r = jnp.asarray(rng.randn(B, T, H, C) * 0.5)
    k = jnp.asarray(rng.randn(B, T, H, C) * 0.5)
    v = jnp.asarray(rng.randn(B, T, H, C) * 0.5)
    w_log = jnp.asarray(-np.exp(rng.randn(B, T, H, C) * 0.3 - 0.6))
    u = jnp.asarray(rng.randn(H, C) * 0.3)
    bk = JOps(jnp.float64, jnp.float64)
    out, S_fin = S._wkv_chunked(bk, r, k, v, w_log, u, chunk=7)
    w = np.exp(np.asarray(w_log, np.float64))
    rn, kn, vn = (np.asarray(t, np.float64) for t in (r, k, v))
    un = np.asarray(u, np.float64)
    St = np.zeros((B, H, C, C))
    outs = np.zeros((B, T, H, C))
    for t in range(T):
        kv = np.einsum("bhc,bhv->bhcv", kn[:, t], vn[:, t])
        outs[:, t] = np.einsum("bhc,bhcv->bhv", rn[:, t],
                               St + un[None, :, :, None] * kv)
        St = w[:, t][..., None] * St + kv
    np.testing.assert_allclose(np.asarray(out), outs, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(S_fin), St, rtol=1e-6, atol=1e-8)


def test_run_with_failures_harness():
    """Failure-injection loop: losses continue across a swap and a shrink;
    re-run steps reproduce the stateless pipeline's batches."""
    from repro.runtime.fault_tolerance import Supervisor, run_with_failures

    sup = Supervisor(n_hosts=4, chips_per_host=4, model_parallel=4, spares=1)
    computed = []
    saved = {"step": 0}

    def train_step(step):
        computed.append(step)
        return 1.0 / (step + 1)

    def save_fn(step):
        saved["step"] = step

    def restore_fn(new_mesh):
        assert new_mesh[1] == 4  # model-parallel degree preserved
        return saved["step"]

    losses = run_with_failures(train_step, save_fn, restore_fn, sup,
                               n_steps=20, checkpoint_every=5,
                               failures={7: [1], 13: [2]})
    assert len(losses) >= 20                 # all 20 steps eventually ran
    assert sup.events[0].kind == "swap"      # spare absorbed first failure
    assert sup.events[1].kind == "shrink"    # second failure shrank the mesh
    # steps after the restore point were recomputed (exactly-once data comes
    # from the stateless pipeline, so recompute is safe)
    assert computed.count(5) >= 2
