"""IA enclosure property tests: every op's output interval must contain the
exact image of every point in the operand intervals."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, st  # optional-hypothesis shim (skips property tests)

from repro.core import interval as iv

finite = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   width=64)


def _mk(lo, w):
    return iv.make(np.asarray(lo), np.asarray(lo) + abs(np.asarray(w)))


def _sample(a: iv.Interval, n=7):
    ts = np.linspace(0.0, 1.0, n)
    lo, hi = np.asarray(a.lo, np.float64), np.asarray(a.hi, np.float64)
    return [lo + t * (hi - lo) for t in ts]


@given(finite, st.floats(0, 1e3), finite, st.floats(0, 1e3))
def test_add_sub_mul_enclosure(al, aw, bl, bw):
    a, b = _mk(al, aw), _mk(bl, bw)
    add, sub, mul = iv.add(a, b), iv.sub(a, b), iv.mul(a, b)
    for xa in _sample(a, 4):
        for xb in _sample(b, 4):
            assert bool(iv.contains(add, xa + xb))
            assert bool(iv.contains(sub, xa - xb))
            assert bool(iv.contains(mul, xa * xb))


moderate = st.floats(min_value=-600, max_value=600, allow_nan=False, width=64)


@given(moderate, st.floats(0, 1e2))
def test_unary_enclosure(al, aw):
    a = _mk(al, aw)
    for x in _sample(a):
        assert bool(iv.contains(iv.exp(a), np.exp(x)))
        assert bool(iv.contains(iv.tanh(a), np.tanh(x)))
        assert bool(iv.contains(iv.sigmoid(a), 1 / (1 + np.exp(-x))))
        assert bool(iv.contains(iv.square(a), x * x))
        assert bool(iv.contains(iv.abs_(a), abs(x)))


@given(st.floats(1e-6, 1e6), st.floats(0, 1e3))
def test_positive_unary_enclosure(al, aw):
    a = _mk(al, aw)
    for x in _sample(a):
        assert bool(iv.contains(iv.sqrt(a), np.sqrt(x)))
        assert bool(iv.contains(iv.log(a), np.log(x)))
        assert bool(iv.contains(iv.recip(a), 1.0 / x))


@given(st.floats(-100, 100, allow_nan=False, width=64), st.floats(0, 10))
def test_silu_gelu_enclosure(al, aw):
    a = _mk(al, aw)
    for x in _sample(a, 9):
        s = x / (1 + np.exp(-np.clip(x, -700, 700)))
        assert bool(iv.contains(iv.silu(a), s))


def test_division_by_zero_interval():
    a = iv.make(1.0, 2.0)
    b = iv.make(-1.0, 1.0)
    d = iv.div(a, b)
    assert np.isneginf(d.lo) and np.isposinf(d.hi)


def test_matmul_const_enclosure():
    rng = np.random.RandomState(0)
    x = rng.randn(5, 8)
    r = np.abs(rng.randn(5, 8)) * 0.1
    w = rng.randn(8, 4)
    a = iv.Interval(jnp.asarray(x - r), jnp.asarray(x + r))
    out = iv.matmul_const(a, w)
    for _ in range(20):
        xs = x - r + 2 * r * rng.rand(5, 8)
        y = xs @ w
        assert bool(jnp.all(out.lo <= y + 1e-12)) and bool(jnp.all(y <= out.hi + 1e-12))


def test_einsum_ball_enclosure():
    rng = np.random.RandomState(1)
    x = rng.randn(4, 6)
    rx = np.abs(rng.randn(4, 6)) * 0.05
    y = rng.randn(6, 3)
    ry = np.abs(rng.randn(6, 3)) * 0.05
    a = iv.Interval(jnp.asarray(x - rx), jnp.asarray(x + rx))
    b = iv.Interval(jnp.asarray(y - ry), jnp.asarray(y + ry))
    out = iv.einsum_ball("ij,jk->ik", a, b)
    for _ in range(20):
        xs = x - rx + 2 * rx * rng.rand(4, 6)
        ys = y - ry + 2 * ry * rng.rand(6, 3)
        z = xs @ ys
        assert bool(jnp.all(out.lo <= z + 1e-10)) and bool(jnp.all(z <= out.hi + 1e-10))


def test_softmax_range_enclosure():
    rng = np.random.RandomState(2)
    x = rng.randn(3, 6) * 3
    r = np.abs(rng.randn(3, 6)) * 0.2
    a = iv.Interval(jnp.asarray(x - r), jnp.asarray(x + r))
    out = iv.softmax_range(a, axis=-1)
    for _ in range(30):
        xs = x - r + 2 * r * rng.rand(3, 6)
        e = np.exp(xs - xs.max(-1, keepdims=True))
        p = e / e.sum(-1, keepdims=True)
        assert bool(jnp.all(out.lo <= p + 1e-12)) and bool(jnp.all(p <= out.hi + 1e-12))


def test_sum_nonneg_stays_nonneg():
    # regression: directed widening must not push an exactly-zero sum below 0
    a = iv.Interval(jnp.zeros(64), jnp.full(64, 1e9))
    s = iv.sum_(a, axis=0)
    assert float(s.lo) >= 0.0
    # and squares of symmetric ranges keep lo == 0 through mean+shift
    b = iv.make(-jnp.ones(16), jnp.ones(16))
    sq = iv.square(b)
    m = iv.mean(sq, axis=0)
    # scale's outward rounding may emit -5e-324; anything above -1e-300 is
    # absorbed by the +eps shift every norm applies before rsqrt
    assert float(m.lo) >= -1e-300
