"""The format zoo must agree with the hardware's own ground truth.

jnp.finfo carries ml_dtypes' bit-exact constants for every format jax can
materialise; any drift between our analytic FpFormat properties and those
constants would silently mis-certify (a wrong max_finite turns the overflow
check into fiction). This regression caught FP8_E4M3's clipped top binade:
the all-ones code is NaN, so its max is 448, not the formula's 480.
"""
import math

import jax.numpy as jnp
import pytest

from repro.core import formats


_FINFO_DTYPES = {
    "float16": jnp.float16,
    "bfloat16": jnp.bfloat16,
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
}


@pytest.mark.parametrize("name,dtype", sorted(_FINFO_DTYPES.items()))
def test_zoo_matches_finfo(name, dtype):
    fmt = formats.get(name)
    fi = jnp.finfo(dtype)
    assert fmt.u == float(fi.eps), f"{name}: u (=eps) drifted"
    assert fmt.max_finite == float(fi.max), f"{name}: max_finite drifted"
    assert fmt.min_normal == float(fi.tiny), f"{name}: min_normal drifted"
    assert fmt.min_subnormal == float(fi.smallest_subnormal), (
        f"{name}: min_subnormal drifted")
    # the exponent fields themselves (finfo.maxexp = emax + 1)
    assert fmt.emax == fi.maxexp - 1
    assert fmt.emin == fi.minexp


def test_e4m3_top_binade_is_clipped():
    """The OCP trick: emax=8 but the 1.111·2^8 code is NaN → max 448."""
    f = formats.FP8_E4M3
    assert f.max_finite == 448.0
    assert f.max_finite < (2.0 - 2.0 ** (1 - f.k)) * 2.0 ** f.emax


def test_binary32_binary64_self_consistent():
    import numpy as np
    assert formats.BINARY32.max_finite == float(np.finfo(np.float32).max)
    assert formats.BINARY64.max_finite == float(np.finfo(np.float64).max)
    assert formats.BINARY32.u == float(np.finfo(np.float32).eps)
    assert formats.BINARY64.u == float(np.finfo(np.float64).eps)


def test_exponent_bits_and_total_bits():
    assert formats.BINARY32.exponent_bits == 8
    assert formats.BINARY32.total_bits == 32
    assert formats.FP16.exponent_bits == 5
    assert formats.FP16.total_bits == 16
    assert formats.BFLOAT16.exponent_bits == 8
    assert formats.BFLOAT16.total_bits == 16
    assert formats.FP8_E5M2.exponent_bits == 5
    # e5m2 prices as 1+5+2 = 8 bits
    assert formats.FP8_E5M2.total_bits == 8


def test_from_bits_roundtrip():
    for k in (4, 8, 11, 19, 24):
        for e in (2, 3, 5, 8):
            f = formats.from_bits(k, e)
            assert f.emax == 2 ** (e - 1) - 1
            assert f.emin == 1 - f.emax
            assert f.exponent_bits == e
            assert f.total_bits == 1 + e + (k - 1)
            assert formats.get(f.name) == f


def test_format_descriptor_roundtrip():
    f = formats.from_bits(16, 4, has_subnormals=True, saturating=True)
    assert formats.from_dict(f.to_dict()) == f
    g = formats.FP8_E4M3
    assert formats.from_dict(g.to_dict()) == g
    assert formats.from_dict(g.to_dict()).max_finite == 448.0


def test_underflow_unit():
    f = formats.from_bits(11, 5)          # fp16-shaped
    assert f.underflow_unit == 2.0 ** (f.emin - (f.k - 1))
    g = formats.DLFLOAT16                 # no subnormals → FTZ charge
    assert g.underflow_unit == 2.0 ** g.emin
    assert math.isfinite(f.underflow_unit)
