"""Continuous-batching engine: scheduling, metrics, and the bitwise oracle.

The load-bearing claim: a request served through the mesh-sharded,
continuously-batched engine produces EXACTLY the tokens of running that
request alone through the single-device eager reference (unrolled
per-layer backend, unpadded batch-1 prefill). Staggered arrivals, lane
recycling, page-padded prefills and idle-lane junk must all be invisible
— per-lane rows of every op are bitwise independent of batch composition.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs
from repro.launch import mesh as meshlib
from repro.launch import serve
from repro.launch.batching import (ContinuousBatchingEngine, Request,
                                   make_backend, reference_generate)
from repro.models import transformer as T

CFG = configs.get("qwen2_7b").SMOKE


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _requests(n, seed=0, plen_lo=5, plen_hi=12, max_new=5, stride=1):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, CFG.vocab,
                                       rng.randint(plen_lo, plen_hi + 1)
                                       ).tolist(),
                    max_new_tokens=max_new, arrival_step=i * stride)
            for i in range(n)]


def _assert_matches_reference(sc, params, responses, reqs, max_seq):
    for req in reqs:
        got = next(r["tokens"] for r in responses if r["id"] == req.rid)
        want = reference_generate(CFG, sc, params, req.prompt,
                                  req.max_new_tokens, max_seq=max_seq)
        assert got == want, (req.rid, got, want)


def test_staggered_arrivals_match_reference_plain(params):
    sc = serve.ServeConfig(arch="qwen2_7b", batch=3, max_seq=48)
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=3, max_seq=48,
                                   page_size=8, queue_depth=8)
    reqs = _requests(5, stride=2)
    responses = eng.run(reqs)
    assert len(responses) == 5
    _assert_matches_reference(sc, params, responses, reqs, 48)


def test_mixed_certificate_matches_reference(params):
    """Per-layer k map (v2-style) through the scanned lane machinery,
    including a sub-layer key."""
    sc = serve.ServeConfig(arch="qwen2_7b", batch=2, max_seq=48,
                           precision_k=12,
                           precision_layer_k={"layer0": 9,
                                              "layer1/mlp": 10})
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=2, max_seq=48,
                                   page_size=8, queue_depth=8)
    reqs = _requests(3, seed=1)
    responses = eng.run(reqs)
    assert len(responses) == 3
    _assert_matches_reference(sc, params, responses, reqs, 48)


def test_format_certificate_matches_reference(params):
    """Per-scope format map (v3-style) — wildcard layer*/attn sub-lane and
    a concrete layer key — served through FormatQuantJOps + the certified
    flash-decode hook; bitwise against the unrolled eager reference."""
    fmt = {"": {"k": 11, "emax": 15, "emin": -14},
           "layer*/attn": {"k": 8, "emax": 15, "emin": -14},
           "layer1": {"k": 9, "emax": 15, "emin": -14}}
    sc = serve.ServeConfig(arch="qwen2_7b", batch=2, max_seq=48,
                           precision_layer_format=fmt)
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=2, max_seq=48,
                                   page_size=8, queue_depth=8)
    reqs = _requests(3, seed=2)
    responses = eng.run(reqs)
    assert len(responses) == 3
    _assert_matches_reference(sc, params, responses, reqs, 48)


def test_format_fused_decode_actually_engages(params, monkeypatch):
    """The certified flash-decode hook must be exercised, not silently
    skipped: every decode step of a format-certified serve must route
    attention through ``certified_decode_attention`` (prefill, Sq > 1,
    legitimately takes the composed path)."""
    from repro.kernels import flash_decode as fd

    calls = []
    real = fd.certified_decode_attention

    def spy(q, k, v, lengths, fmt, **kw):
        calls.append(q.shape)
        return real(q, k, v, lengths, fmt, **kw)

    monkeypatch.setattr(fd, "certified_decode_attention", spy)
    fmt = {"": {"k": 5, "emax": 15, "emin": -14}}
    sc = serve.ServeConfig(arch="qwen2_7b", batch=1, max_seq=48,
                           precision_layer_format=fmt)
    prompt = list(np.random.RandomState(3).randint(0, CFG.vocab, 6))
    out = reference_generate(CFG, sc, params, prompt, 6, max_seq=48)
    assert len(out) == 6
    # eager unrolled reference: one hook call per layer per decode step
    assert len(calls) == CFG.n_layers * (len(out) - 1)


def test_lane_recycling_and_page_accounting(params):
    """More requests than lanes: lanes recycle, pages return to the pool,
    and every request still completes bit-identically."""
    sc = serve.ServeConfig(arch="qwen2_7b", batch=2, max_seq=32)
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=2, max_seq=32,
                                   page_size=8, queue_depth=10)
    assert eng.free_pages == eng.total_pages == 8
    reqs = _requests(6, seed=4, max_new=3, stride=0)
    responses = eng.run(reqs)
    assert len(responses) == 6
    assert eng.free_pages == eng.total_pages          # all pages returned
    assert all(l is None for l in eng.lanes)
    _assert_matches_reference(sc, params, responses, reqs, 32)


def test_eos_recycles_lane_early(params):
    sc = serve.ServeConfig(arch="qwen2_7b", batch=1, max_seq=48)
    prompt = list(np.random.RandomState(5).randint(0, CFG.vocab, 6))
    free_run = reference_generate(CFG, sc, params, prompt, 8, max_seq=48)
    eos = free_run[2]          # a token the model will actually emit
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=1, max_seq=48,
                                   page_size=8, eos_id=eos)
    [resp] = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=8)])
    assert resp["tokens"] == free_run[:3]             # stopped AT the eos
    assert eng.free_pages == eng.total_pages


def test_admission_rejection_and_queue_bound(params):
    sc = serve.ServeConfig(arch="qwen2_7b", batch=1, max_seq=32)
    reg = obs.MetricsRegistry()
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=1, max_seq=32,
                                   page_size=8, queue_depth=2, registry=reg)
    # can never fit: prompt + max_new exceeds max_seq
    assert not eng.submit(Request(rid=0, prompt=[1] * 30,
                                  max_new_tokens=10))
    # queue bound: two fit, the third bounces
    assert eng.submit(Request(rid=1, prompt=[1] * 4, max_new_tokens=2))
    assert eng.submit(Request(rid=2, prompt=[1] * 4, max_new_tokens=2))
    assert not eng.submit(Request(rid=3, prompt=[1] * 4, max_new_tokens=2))
    assert reg.counters["serve.requests_rejected{reason=too_long}"] == 1
    assert reg.counters["serve.requests_rejected{reason=queue_full}"] == 1
    responses = eng.run([])
    assert {r["id"] for r in responses} == {1, 2}


def test_gauges_and_per_lane_histograms(params):
    sc = serve.ServeConfig(arch="qwen2_7b", batch=2, max_seq=32)
    reg = obs.MetricsRegistry()
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=2, max_seq=32,
                                   page_size=8, registry=reg)
    for r in _requests(2, seed=6, max_new=3, stride=0):
        assert eng.submit(r)
    eng.step()
    assert reg.gauges["serve.batch_occupancy"] == 1.0
    assert reg.gauges["serve.admission_queue_depth"] == 0.0
    eng.run([])
    assert reg.gauges["serve.batch_occupancy"] == 0.0
    for lane in (0, 1):
        h = reg.histograms[f"serve.decode_latency_s{{lane={lane}}}"]
        assert h.count >= 1
    assert reg.counters["serve.requests_completed"] == 2
    # the lane label renders as a proper Prometheus label
    prom = reg.render_prometheus()
    assert 'serve_decode_latency_s_bucket{lane="0",le=' in prom


def test_responses_carry_certificate_bars(params):
    class _FakeCertSet:
        params_digest = "deadbeef"

        def error_bars(self):
            return {"dbar": 1.5e-3, "ebar": 2.0e-4, "k": 12}

    sc = serve.ServeConfig(arch="qwen2_7b", batch=1, max_seq=32,
                           precision_k=12)
    eng = ContinuousBatchingEngine(CFG, sc, params, n_lanes=1, max_seq=32,
                                   page_size=8, certset=_FakeCertSet())
    responses = eng.run(_requests(2, seed=7, max_new=2, stride=0))
    assert len(responses) == 2
    for r in responses:
        assert r["certificate"]["k"] == 12
        assert r["certificate"]["dbar"] == 1.5e-3
        assert r["certificate"]["params_digest"] == "deadbeef"


def test_padded_prefill_bitwise_equals_unpadded(params):
    """The linchpin of batched prefill-insert: padding a prompt to a whole
    number of pages must not change the last real row's logits (causal
    masking makes pad columns contribute exact -1e9-masked zeros) nor the
    first P cache positions."""
    bk = make_backend(serve.ServeConfig(arch="qwen2_7b", batch=1,
                                        max_seq=32))
    rng = np.random.RandomState(8)
    toks = rng.randint(0, CFG.vocab, 6)
    padded = np.zeros(16, np.int32)
    padded[:6] = toks
    c1 = T.init_cache(CFG, 1, 32, jnp.float32, per_lane_idx=True)
    c2 = T.init_cache(CFG, 1, 32, jnp.float32, per_lane_idx=True)
    z = jnp.zeros((1,), jnp.int32)
    lg1, c1 = T.forward(bk, params, CFG, jnp.asarray(toks[None]),
                        cache=c1, q_offset=z)
    lg2, c2 = T.forward(bk, params, CFG, jnp.asarray(padded[None]),
                        cache=c2, q_offset=z)
    assert bool(jnp.array_equal(lg1[0, :6], lg2[0, :6]))
    assert bool(jnp.array_equal(c1["k"][:, :, :6], c2["k"][:, :, :6]))
    assert bool(jnp.array_equal(c1["v"][:, :, :6], c2["v"][:, :, :6]))


def test_engine_on_explicit_mesh(params):
    """Whatever devices exist, the engine accepts a mesh and the sharded
    run stays bitwise against the meshless eager reference (CI's
    forced-host 4-device job exercises the >1-device case)."""
    mesh = meshlib.make_serving_mesh()
    sc = serve.ServeConfig(arch="qwen2_7b", batch=2, max_seq=32,
                           precision_k=11)
    eng = ContinuousBatchingEngine(CFG, sc, params, mesh=mesh, n_lanes=2,
                                   max_seq=32, page_size=8)
    reqs = _requests(3, seed=9, max_new=3)
    responses = eng.run(reqs)
    assert len(responses) == 3
    _assert_matches_reference(sc, params, responses, reqs, 32)
