"""k-bit RNE emulation correctness (the empirical oracle must itself be right)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st  # optional-hypothesis shim (skips property tests)

from repro.core import formats, quantize


def test_bf16_matches_native_cast():
    rng = np.random.RandomState(0)
    x = (rng.randn(4096) * 10 ** rng.uniform(-20, 20, 4096)).astype(np.float32)
    q = quantize.quantize(x, "bfloat16")
    ref = jnp.asarray(x, jnp.bfloat16).astype(jnp.float32)
    assert bool(jnp.array_equal(q, ref))


def test_fp16_matches_native_cast_normals():
    rng = np.random.RandomState(1)
    x = (rng.randn(4096) * 10 ** rng.uniform(-3, 3, 4096)).astype(np.float32)
    q = quantize.quantize(x, "float16")
    ref = jnp.asarray(x, jnp.float16).astype(jnp.float32)
    assert bool(jnp.array_equal(q, ref))


@given(st.floats(min_value=-2.0**99, max_value=2.0**99, allow_nan=False,
                 width=32), st.integers(2, 23))
def test_rne_error_bound(x, k):
    """|q − x| ≤ ½·2^{1−k}·|x| — eq. (5) with ε ≤ 1/2, which (as the paper
    notes) assumes no underflow: exclude the subnormal range."""
    assume = abs(x) == 0 or abs(x) >= 2.0 ** -100
    if not assume:
        return
    q = float(quantize.quantize(np.float32(x), k))
    assert abs(q - x) <= 0.5 * 2.0 ** (1 - k) * abs(x) + 1e-45


@given(st.integers(2, 23), st.integers(0, 100))
def test_idempotent(k, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(64).astype(np.float32)
    q1 = quantize.quantize(x, k)
    q2 = quantize.quantize(q1, k)
    assert bool(jnp.array_equal(q1, q2))


def test_ties_to_even():
    # exactly representable midpoint at k=3 (mantissa 1.xx): 1.125 between
    # 1.0 and 1.25 → rounds to 1.0 (even); 1.375 → 1.5 (even mantissa 1.10)
    assert float(quantize.quantize(np.float32(1.125), 3)) == 1.0
    assert float(quantize.quantize(np.float32(1.375), 3)) == 1.5


def test_overflow_saturating_and_inf():
    big = np.float32(1e30)
    e4m3 = quantize.quantize(big, "fp8_e4m3")     # saturating
    assert float(e4m3) == formats.FP8_E4M3.max_finite
    f16 = quantize.quantize(np.float64(1e10), "float16")
    assert np.isinf(float(f16))


def test_subnormals_fp16():
    # 1e-7 is subnormal in fp16; grid spacing 2^-24
    x = np.float64(1e-7)
    q = float(quantize.quantize(x, "float16"))
    ref = float(np.float16(1e-7))
    assert q == ref


def test_seq_dot_one_rounding_per_flop():
    # n=2 sequential: fl(fl(x0*w0) + fl(x1*w1)); verify against manual
    fmt = formats.custom(5)
    x = jnp.asarray([[1.1, 2.3]])
    w = jnp.asarray([[0.7], [0.9]])
    got = quantize.seq_dot(x, w, fmt)
    q = lambda v: quantize.quantize(jnp.asarray(v), fmt)
    manual = q(q(q(1.1) * q(0.7)) + q(q(2.3) * q(0.9)))
    assert float(got[0, 0]) == float(manual)


@pytest.mark.parametrize("fmt_name", ["bfloat16", "float16", "fp8_e4m3",
                                      "fp8_e5m2", "dlfloat16", "tf32"])
def test_formats_roundtrip_error(fmt_name):
    """ε ≤ ½u holds on the format's NORMAL range (paper eq. (5) caveat)."""
    fmt = formats.get(fmt_name)
    rng = np.random.RandomState(2)
    x = rng.randn(1024).astype(np.float64)
    x = np.sign(x) * np.clip(np.abs(x), 4 * fmt.min_normal,
                             fmt.max_finite / 4)
    q = np.asarray(quantize.quantize(x, fmt), np.float64)
    rel = np.abs(q - x) / np.abs(x)
    assert rel.max() <= 0.5 * fmt.u * (1 + 1e-9)


def test_measured_error_in_u():
    fmt = formats.custom(8)
    x = jnp.asarray([1.0, 2.0])
    approx = x * (1 + 0.4 * fmt.u)
    a, r = quantize.measured_error_in_u(x, approx, fmt)
    assert np.allclose(np.asarray(r), 0.4, rtol=1e-6)
