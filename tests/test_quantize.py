"""k-bit RNE emulation correctness (the empirical oracle must itself be right)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st  # optional-hypothesis shim (skips property tests)

from repro.core import formats, quantize


def test_bf16_matches_native_cast():
    rng = np.random.RandomState(0)
    x = (rng.randn(4096) * 10 ** rng.uniform(-20, 20, 4096)).astype(np.float32)
    q = quantize.quantize(x, "bfloat16")
    ref = jnp.asarray(x, jnp.bfloat16).astype(jnp.float32)
    assert bool(jnp.array_equal(q, ref))


def test_fp16_matches_native_cast_normals():
    rng = np.random.RandomState(1)
    x = (rng.randn(4096) * 10 ** rng.uniform(-3, 3, 4096)).astype(np.float32)
    q = quantize.quantize(x, "float16")
    ref = jnp.asarray(x, jnp.float16).astype(jnp.float32)
    assert bool(jnp.array_equal(q, ref))


@given(st.floats(min_value=-2.0**99, max_value=2.0**99, allow_nan=False,
                 width=32), st.integers(2, 23))
def test_rne_error_bound(x, k):
    """|q − x| ≤ ½·2^{1−k}·|x| — eq. (5) with ε ≤ 1/2, which (as the paper
    notes) assumes no underflow: exclude the subnormal range."""
    assume = abs(x) == 0 or abs(x) >= 2.0 ** -100
    if not assume:
        return
    q = float(quantize.quantize(np.float32(x), k))
    assert abs(q - x) <= 0.5 * 2.0 ** (1 - k) * abs(x) + 1e-45


@given(st.integers(2, 23), st.integers(0, 100))
def test_idempotent(k, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(64).astype(np.float32)
    q1 = quantize.quantize(x, k)
    q2 = quantize.quantize(q1, k)
    assert bool(jnp.array_equal(q1, q2))


def test_ties_to_even():
    # exactly representable midpoint at k=3 (mantissa 1.xx): 1.125 between
    # 1.0 and 1.25 → rounds to 1.0 (even); 1.375 → 1.5 (even mantissa 1.10)
    assert float(quantize.quantize(np.float32(1.125), 3)) == 1.0
    assert float(quantize.quantize(np.float32(1.375), 3)) == 1.5


def test_overflow_saturating_and_inf():
    big = np.float32(1e30)
    e4m3 = quantize.quantize(big, "fp8_e4m3")     # saturating
    assert float(e4m3) == formats.FP8_E4M3.max_finite
    f16 = quantize.quantize(np.float64(1e10), "float16")
    assert np.isinf(float(f16))


def test_subnormals_fp16():
    # 1e-7 is subnormal in fp16; grid spacing 2^-24
    x = np.float64(1e-7)
    q = float(quantize.quantize(x, "float16"))
    ref = float(np.float16(1e-7))
    assert q == ref


def test_seq_dot_one_rounding_per_flop():
    # n=2 sequential: fl(fl(x0*w0) + fl(x1*w1)); verify against manual
    fmt = formats.custom(5)
    x = jnp.asarray([[1.1, 2.3]])
    w = jnp.asarray([[0.7], [0.9]])
    got = quantize.seq_dot(x, w, fmt)
    q = lambda v: quantize.quantize(jnp.asarray(v), fmt)
    manual = q(q(q(1.1) * q(0.7)) + q(q(2.3) * q(0.9)))
    assert float(got[0, 0]) == float(manual)


@pytest.mark.parametrize("fmt_name", ["bfloat16", "float16", "fp8_e4m3",
                                      "fp8_e5m2", "dlfloat16", "tf32"])
def test_formats_roundtrip_error(fmt_name):
    """ε ≤ ½u holds on the format's NORMAL range (paper eq. (5) caveat)."""
    fmt = formats.get(fmt_name)
    rng = np.random.RandomState(2)
    x = rng.randn(1024).astype(np.float64)
    x = np.sign(x) * np.clip(np.abs(x), 4 * fmt.min_normal,
                             fmt.max_finite / 4)
    q = np.asarray(quantize.quantize(x, fmt), np.float64)
    rel = np.abs(q - x) / np.abs(x)
    assert rel.max() <= 0.5 * fmt.u * (1 + 1e-9)


def test_measured_error_in_u():
    fmt = formats.custom(8)
    x = jnp.asarray([1.0, 2.0])
    approx = x * (1 + 0.4 * fmt.u)
    a, r = quantize.measured_error_in_u(x, approx, fmt)
    assert np.allclose(np.asarray(r), 0.4, rtol=1e-6)


# ---------------------------------------------------------------------------
# quantize_to_format: the traced-(k, emax, emin) full-format rounding the
# schema-v3 serving path and the scalar-prefetch Pallas kernel rely on
# ---------------------------------------------------------------------------

def _fmt_strategy():
    """Synthesizer-shaped lattice formats: k bits × IEEE exponent widths."""
    return st.tuples(st.integers(2, 24), st.integers(2, 8))


def _qf(x, fmt, **kw):
    x = jnp.asarray(np.asarray(x, np.float32))
    return quantize.quantize_to_format(x, fmt.k, fmt.emax, fmt.emin,
                                       fmt.has_subnormals, fmt.saturating,
                                       **kw)


@given(_fmt_strategy(), st.integers(0, 10 ** 6))
def test_property_format_idempotent(ke, seed):
    k, e = ke
    fmt = formats.from_bits(k, e, saturating=True)
    rng = np.random.RandomState(seed % 2 ** 31)
    x = (rng.randn(128) * 10.0 ** rng.uniform(-30, 30, 128)).astype(np.float32)
    q1 = _qf(x, fmt)
    q2 = _qf(q1, fmt)
    assert bool(jnp.array_equal(q1, q2, equal_nan=True))


@given(_fmt_strategy(), st.integers(0, 10 ** 6))
def test_property_format_exact_values_roundtrip(ke, seed):
    """Values already representable in the format pass through unchanged:
    sign · (k-bit mantissa in [1,2)) · 2^exponent, exponents in range."""
    k, e = ke
    fmt = formats.from_bits(k, e, saturating=True)
    rng = np.random.RandomState(seed % 2 ** 31)
    mant = 1.0 + rng.randint(0, 2 ** (k - 1), 64) * 2.0 ** (1 - k)
    expo = rng.randint(fmt.emin, fmt.emax + 1, 64)
    x = (rng.choice([-1.0, 1.0], 64) * mant * np.ldexp(1.0, expo)
         ).astype(np.float32)
    x = x[np.abs(x) <= fmt.max_finite]       # top-binade mantissae can poke out
    q = _qf(x, fmt)
    assert bool(jnp.array_equal(q, jnp.asarray(x)))


@given(_fmt_strategy(), st.integers(0, 10 ** 6))
def test_property_format_saturation(ke, seed):
    """|x| > max_finite clamps to ±max_finite iff saturating, else ±inf."""
    k, e = ke
    rng = np.random.RandomState(seed % 2 ** 31)
    fmt_sat = formats.from_bits(k, e, saturating=True)
    fmt_inf = formats.from_bits(k, e, saturating=False)
    # strictly beyond the rounding-up threshold: one k-bit ulp past max
    x = np.float32(fmt_sat.max_finite * (1 + 2.0 ** (1 - k)))
    if not np.isfinite(x):
        return
    assert float(_qf(x, fmt_sat)) == fmt_sat.max_finite
    assert np.isinf(float(_qf(x, fmt_inf)))
    assert float(_qf(-x, fmt_sat)) == -fmt_sat.max_finite


@given(_fmt_strategy(), st.integers(0, 10 ** 6))
def test_property_format_flush_below_min_subnormal(ke, seed):
    """Magnitudes below half the subnormal grid spacing flush to zero;
    values at ≥ the spacing snap onto the grid (RNE from the original)."""
    k, e = ke
    fmt = formats.from_bits(k, e, saturating=True)
    if fmt.min_subnormal < 2.0 ** -100:      # keep clear of carrier FTZ zone
        return
    rng = np.random.RandomState(seed % 2 ** 31)
    tiny = np.asarray(rng.uniform(0, 0.49, 32) * fmt.min_subnormal,
                      np.float32) * rng.choice([-1.0, 1.0], 32).astype(np.float32)
    assert bool(jnp.all(_qf(tiny, fmt) == 0.0))
    grid = np.asarray(rng.randint(1, 2 ** (k - 1), 32) * fmt.min_subnormal,
                      np.float32)
    q = np.asarray(_qf(grid, fmt), np.float64)
    assert np.all(np.abs(q) % fmt.min_subnormal == 0)
    assert np.all(np.abs(q - grid) <= fmt.min_subnormal / 2 * (1 + 1e-6))


@given(st.integers(2, 24), st.integers(0, 10 ** 6))
def test_property_format_agrees_with_quantize_to_k_unbounded(k, seed):
    """With a binary32-wide exponent range and carrier-normal inputs the
    range machinery is inert: quantize_to_format == quantize_to_k."""
    rng = np.random.RandomState(seed % 2 ** 31)
    x = (rng.randn(256) * 10.0 ** rng.uniform(-20, 20, 256)).astype(np.float32)
    fmt = formats.custom(k, emax=127, saturating=True)
    got = quantize.quantize_to_format(jnp.asarray(x), k, 127, -126)
    want = quantize.quantize_to_k(jnp.asarray(x), k)
    # the wide range clips nothing for these magnitudes
    assert bool(jnp.array_equal(got, want))
    assert float(jnp.max(jnp.abs(want))) <= fmt.max_finite


@given(_fmt_strategy(), st.integers(0, 10 ** 6))
def test_property_format_matches_static_quantize_bitwise(ke, seed):
    """Traced-scalar path == the static bit-twiddle path, bit for bit, on
    carrier-normal inputs (the contract the Pallas kernel inherits)."""
    k, e = ke
    fmt = formats.from_bits(k, e, saturating=True)
    rng = np.random.RandomState(seed % 2 ** 31)
    x = (rng.randn(256) * 10.0 ** rng.uniform(-35, 35, 256)).astype(np.float32)
    x = np.where(np.abs(x) < 2.0 ** -126, np.float32(0.0), x)  # carrier-normal
    formats.REGISTRY[fmt.name] = fmt
    try:
        ref = quantize.quantize(x, fmt)
    finally:
        del formats.REGISTRY[fmt.name]
    got = _qf(x, fmt)
    assert bool(jnp.array_equal(got, ref, equal_nan=True))


def test_format_special_values():
    fmt = formats.from_bits(8, 4, saturating=True)
    x = np.asarray([np.nan, np.inf, -np.inf, 0.0, -0.0], np.float32)
    q = np.asarray(_qf(x, fmt))
    assert np.isnan(q[0]) and np.isinf(q[1]) and np.isinf(q[2])
    assert q[3] == 0.0 and q[4] == 0.0


def test_format_max_finite_override_e4m3():
    """The clipped-binade override reaches the traced path too."""
    f = formats.FP8_E4M3
    x = np.float32(460.0)                    # between 448 and the formula's 480
    got = float(quantize.quantize_to_format(
        jnp.asarray(x), f.k, f.emax, f.emin, f.has_subnormals, True,
        max_finite=f.max_finite))
    assert got == 448.0
    assert float(quantize.quantize(x, f)) == 448.0


def test_format_saturates_carrier_overflow():
    """Mantissa rounding can overflow the CARRIER (finite x near f32 max →
    rounded y = inf); a saturating format must still clamp to max_finite —
    and both the static and the traced path must agree on it."""
    fmt = formats.FpFormat("sat4", k=4, emax=7, emin=-6, saturating=True)
    x = np.float32(3.4028235e38)             # f32 max; k=4 RNE rounds to inf
    got_dyn = float(_qf(x, fmt))
    formats.REGISTRY[fmt.name] = fmt
    try:
        got_static = float(quantize.quantize(x, fmt))
    finally:
        del formats.REGISTRY[fmt.name]
    assert got_dyn == fmt.max_finite == got_static
    assert float(_qf(-x, fmt)) == -fmt.max_finite
    # non-saturating formats keep IEEE overflow-to-inf semantics
    fmt_inf = formats.FpFormat("inf4", k=4, emax=7, emin=-6, saturating=False)
    assert np.isinf(float(_qf(x, fmt_inf)))
