"""CAA soundness: for every rule, the bound must dominate the measured error
of an actual k-bit execution (the quantize oracle), for random inputs and
several precisions. This is the core guarantee of the whole framework."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st  # optional-hypothesis shim (skips property tests)

from repro.core import caa, formats, quantize
from repro.core.caa import CaaConfig, CaaTensor
from repro.core import interval as iv

KS = [5, 8, 12]


def _rand_caa(rng, shape, k, scale=1.0):
    """A tensor stored exactly in format k (value = its own reference)."""
    x = quantize.quantize(rng.randn(*shape).astype(np.float64) * scale,
                          formats.custom(k))
    cfg = CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k)
    return caa.weight(np.asarray(x), cfg), np.asarray(x), cfg


def _check_sound(res: CaaTensor, exact_val, u):
    """Emulated val must differ from the true value by ≤ bounds."""
    err = np.abs(np.asarray(res.val, np.float64) - exact_val)
    dbar = np.asarray(res.dbar)
    ok_abs = err <= dbar * u + 1e-300
    rel_ok = np.ones_like(ok_abs, bool)
    with np.errstate(all="ignore"):
        ebar = np.asarray(res.ebar)
        fin = np.isfinite(ebar)
        rel_ok[fin] = err[fin] <= np.abs(exact_val[fin]) * ebar[fin] * u + 1e-300
    assert bool(np.all(ok_abs | rel_ok)), (
        f"violation: err={err.max()}, dbar*u={(dbar*u).max()}")


@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("op", ["add", "sub", "mul"])
def test_binary_ops_sound(k, op):
    rng = np.random.RandomState(hash((k, op)) % 2**31)
    a, av, cfg = _rand_caa(rng, (64,), k)
    b, bv, _ = _rand_caa(rng, (64,), k)
    res = getattr(caa, op)(a, b, cfg)
    exact = {"add": av + bv, "sub": av - bv, "mul": av * bv}[op]
    _check_sound(res, exact, cfg.u_max)


@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("op", ["exp", "tanh", "sigmoid", "relu", "sqrt", "log"])
def test_unary_ops_sound(k, op):
    rng = np.random.RandomState(hash((k, op)) % 2**31)
    scale = 1.0
    a, av, cfg = _rand_caa(rng, (64,), k, scale)
    if op in ("sqrt", "log"):
        a = caa.weight(np.abs(av) + 0.5, cfg)
        av = np.asarray(a.val)
    res = getattr(caa, op)(a, cfg)
    exact = {
        "exp": np.exp(av), "tanh": np.tanh(av),
        "sigmoid": 1 / (1 + np.exp(-av)), "relu": np.maximum(av, 0),
        "sqrt": np.sqrt(av), "log": np.log(av),
    }[op]
    _check_sound(res, exact, cfg.u_max)


@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("order", ["sequential", "pairwise"])
def test_matmul_sound(k, order):
    rng = np.random.RandomState(k)
    fmt = formats.custom(k)
    x = np.asarray(quantize.quantize(rng.randn(3, 32) * 0.5, fmt), np.float64)
    w = np.asarray(quantize.quantize(rng.randn(32, 8) * 0.3, fmt), np.float64)
    cfg = CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k, acc_order=order)
    res = caa.matmul(caa.weight(x, cfg), caa.weight(w, cfg), cfg)
    # oracle: step-by-step k-bit execution in the same order
    emp = quantize.seq_dot(jnp.asarray(x), jnp.asarray(w), fmt) \
        if order == "sequential" else \
        quantize.pairwise_dot(jnp.asarray(x), jnp.asarray(w), fmt)
    assert bool(jnp.array_equal(emp, res.val)), "emulated val mismatch"
    exact = x @ w
    _check_sound(res, exact, cfg.u_max)


@pytest.mark.parametrize("k", KS)
def test_matmul_gamma_mode_sound(k):
    """Large-n path (γ closed form, no trajectory)."""
    rng = np.random.RandomState(k + 7)
    fmt = formats.custom(k)
    x = np.asarray(quantize.quantize(rng.randn(2, 48) * 0.5, fmt), np.float64)
    w = np.asarray(quantize.quantize(rng.randn(48, 5) * 0.3, fmt), np.float64)
    cfg = CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k, use_trajectory=False)
    res = caa.matmul(caa.weight(x, cfg), caa.weight(w, cfg), cfg)
    emp = quantize.seq_dot(jnp.asarray(x), jnp.asarray(w), fmt)
    err = np.abs(np.asarray(emp, np.float64) - x @ w)
    assert bool(np.all(err <= np.asarray(res.dbar) * cfg.u_max))


@pytest.mark.parametrize("k", [8, 12])
def test_softmax_sound(k):
    rng = np.random.RandomState(k)
    fmt = formats.custom(k)
    x = np.asarray(quantize.quantize(rng.randn(4, 10) * 2, fmt), np.float64)
    cfg = CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k)
    res = caa.softmax(caa.weight(x, cfg), -1, cfg)
    e = np.exp(x - x.max(-1, keepdims=True))
    exact = e / e.sum(-1, keepdims=True)
    # the emulated val uses jax softmax + final rounding; measure true error
    err = np.abs(np.asarray(res.val, np.float64) - exact)
    bound = np.asarray(res.dbar) * cfg.u_max
    assert bool(np.all(err <= bound)), (err.max(), bound.min())


def test_trajectory_tighter_than_gamma():
    """Trajectory mode must be no looser than the γ closed form."""
    rng = np.random.RandomState(0)
    x = rng.randn(4, 64)
    w = rng.randn(64, 8) * 0.1
    c_t = CaaConfig(u_max=2**-12, use_trajectory=True)
    c_g = CaaConfig(u_max=2**-12, use_trajectory=False)
    r_t = caa.matmul(caa.weight(x, c_t), caa.weight(w, c_t), c_t)
    r_g = caa.matmul(caa.weight(x, c_g), caa.weight(w, c_g), c_g)
    assert float(jnp.max(r_t.dbar)) <= float(jnp.max(r_g.dbar)) * 1.001


def test_normalize_cross_improvement():
    t = caa.make(jnp.asarray([2.0]), iv.make(jnp.asarray([1.9]), jnp.asarray([2.1])),
                 dbar=jnp.asarray([1.0]), ebar=jnp.asarray([jnp.inf]))
    # ebar should be recovered as dbar/mig = 1/1.9
    assert float(t.ebar[0]) <= 1.0 / 1.9 * 1.01


def test_relu_preserves_bounds():
    cfg = CaaConfig(u_max=2**-10)
    a = caa.make(jnp.asarray([-1.0, 2.0]),
                 iv.make(jnp.asarray([-1.5, 1.5]), jnp.asarray([-0.5, 2.5])),
                 dbar=jnp.asarray([3.0, 3.0]), ebar=jnp.asarray([5.0, 5.0]))
    r = caa.relu(a, cfg)
    assert float(jnp.max(r.dbar)) <= 3.0 * 1.01
    assert float(r.exact.lo[0]) == 0.0


def test_clamp_exact_sound_and_tightening():
    a = caa.make(jnp.asarray([1.0]), iv.make(jnp.asarray([-10.0]), jnp.asarray([10.0])),
                 dbar=jnp.asarray([1.0]))
    c = caa.clamp_exact(a, -2.0, 2.0)
    assert float(c.exact.lo[0]) == -2.0 and float(c.exact.hi[0]) == 2.0


def test_scan_fixpoint_sound_contraction():
    """Geometric bound vs actual scan with rounding."""
    rng = np.random.RandomState(3)
    k = 10
    fmt = formats.custom(k)
    cfg = CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k)
    T = 200
    decay = 0.9 * np.ones((4,))
    drive_v = np.asarray(quantize.quantize(rng.randn(4) * 0.1, fmt), np.float64)
    d = caa.weight(decay, cfg)
    b = caa.weight(drive_v, cfg)
    fix = caa.scan_affine_fixpoint(d, b, T, cfg)
    # exact recurrence and emulated recurrence
    h = np.zeros(4)
    hq = np.zeros(4)
    q = lambda v: np.asarray(quantize.quantize(v, fmt), np.float64)
    for _ in range(T):
        h = decay * h + drive_v
        hq = q(q(decay * hq) + drive_v)
    assert bool(np.all(np.abs(h) <= np.asarray(fix.exact.hi) + 1e-12))
    err = np.abs(hq - h)
    assert bool(np.all(err <= np.asarray(fix.dbar) * cfg.u_max))


@pytest.mark.parametrize("k", [6, 10])
def test_matmul_kahan_sound_and_tighter(k):
    """Kahan order: bound must dominate the compensated execution and be
    tighter than the sequential bound (γ_3-like vs γ_n)."""
    rng = np.random.RandomState(k)
    fmt = formats.custom(k)
    x = np.asarray(quantize.quantize(rng.randn(2, 40) * 0.5, fmt), np.float64)
    w = np.asarray(quantize.quantize(rng.randn(40, 6) * 0.3, fmt), np.float64)
    cfg_k = CaaConfig(u_max=2.0 ** (1 - k), acc_order="kahan",
                      use_trajectory=False)
    cfg_s = CaaConfig(u_max=2.0 ** (1 - k), acc_order="sequential",
                      use_trajectory=False)
    r_k = caa.matmul(caa.weight(x, cfg_k), caa.weight(w, cfg_k), cfg_k)
    r_s = caa.matmul(caa.weight(x, cfg_s), caa.weight(w, cfg_s), cfg_s)
    emp = quantize.kahan_dot(jnp.asarray(x), jnp.asarray(w), fmt)
    err = np.abs(np.asarray(emp, np.float64) - x @ w)
    assert bool(np.all(err <= np.asarray(r_k.dbar) * cfg_k.u_max))
    if k >= 10:
        # compensation only wins when n·u ≪ 1; at k=6 the rigorous n²u
        # second-order guard honestly exceeds γ_n (Higham 4.3 caveat)
        assert float(jnp.max(r_k.dbar)) < float(jnp.max(r_s.dbar))


def test_mixed_precision_plan():
    from repro.core import precision
    plan = precision.mixed_precision_plan(
        {"dense1": 100.0, "dense2": 10.0}, target_margin=0.1)
    by_name = {p.layer: p for p in plan}
    # the more sensitive layer needs more bits
    assert by_name["dense1"].k > by_name["dense2"].k
    assert all(p.k >= 2 for p in plan)


def test_weight_quantization_charged_when_not_exact():
    cfg = CaaConfig(u_max=2**-7, emulate_k=8)
    w = caa.weight(np.asarray([1.01, -2.7]), cfg, exact=False)
    assert float(jnp.max(w.ebar)) >= 0.5 * 0.999  # the ½u storage rounding
    # and the stored val is on the k-bit grid
    q = quantize.quantize(np.asarray([1.01, -2.7]), 8)
    assert bool(jnp.array_equal(w.val, jnp.asarray(q, jnp.float64)))
