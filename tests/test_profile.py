"""Tests for the measured-performance layer: ``repro.obs.profile`` (timing
discipline, analytic roofline terms, jaxpr-size gauges), the fitted cost
model (``repro.obs.costmodel``) and its certificate what-if report, the
bench-trajectory plumbing (root emission, session dedupe, soft perf gate),
the Prometheus exposition details the serving digests depend on (label
escaping, cumulative buckets, percentile math), and the ``repro.obs``
CLI views over ``BENCH_kernels.json``.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import costmodel as CM
from repro.obs import profile as P
from repro.obs.report import render_kernel_table

from _hyp import given, st


@pytest.fixture(autouse=True)
def _fresh_tracer():
    obs.shutdown()
    yield
    obs.shutdown()


# ---------------------------------------------------------------------------
# timing + jaxpr primitives
# ---------------------------------------------------------------------------


def test_measure_median_within_extremes():
    f = jax.jit(lambda a, b: a + b)
    x = jnp.ones((8, 8))
    t = P.measure(f, x, x, reps=5, warmup=1)
    assert t["reps"] == 5 and len(t["samples"]) == 5
    assert 0 < t["min_s"] <= t["median_s"] <= t["max_s"]
    assert t["min_s"] <= t["mean_s"] <= t["max_s"]


def test_jaxpr_stats_descends_into_scan_body():
    def flat(x):
        return x * 2.0 + 1.0

    def scanned(x):
        def body(c, _):
            return c * 2.0 + 1.0, None
        out, _ = jax.lax.scan(body, x, None, length=4)
        return out

    x = jnp.ones((3,))
    n_flat = P.jaxpr_stats(flat, x)["eqns"]
    n_scan = P.jaxpr_stats(scanned, x)["eqns"]
    # the scan body's equations are counted (scan + body > flat body alone)
    assert n_scan > n_flat >= 2


def test_time_compile_returns_runnable_executable():
    f = jax.jit(lambda a: a @ a)
    x = jnp.eye(4)
    r = P.time_compile(f, x)
    assert r["lower_s"] >= 0 and r["compile_s"] > 0
    np.testing.assert_allclose(np.asarray(r["compiled"](x)), np.eye(4))


def test_gemm_terms_math():
    t = P.gemm_terms(128, 256, 64, bits=8.0)
    assert t["flops"] == 2.0 * 128 * 256 * 64
    assert t["bytes"] == (128 * 256 + 256 * 64 + 128 * 64) * 1.0
    assert t["intensity"] == pytest.approx(t["flops"] / t["bytes"])
    assert t["roofline_s"] == pytest.approx(
        max(t["compute_s"], t["memory_s"]))
    # small GEMMs sit on the memory side of the TPU ridge
    assert t["bound"] == "memory"
    # narrower storage moves the SAME flops with fewer bytes
    assert P.gemm_terms(128, 256, 64, bits=32.0)["bytes"] == 4 * t["bytes"]


def test_flash_decode_terms_math():
    t = P.flash_decode_terms(2, 256, 2, 2, 64, bits=32.0)
    assert t["flops"] == 4.0 * 2 * 2 * 2 * 256 * 64
    assert t["bytes"] == (2 * 2 * 256 * 2 * 64 + 2 * 2 * 2 * 2 * 64) * 4.0
    assert t["bound"] == "memory"   # decode attention streams the KV cache


def test_block_candidates_respect_divisibility():
    from repro.kernels.quant_matmul import block_candidates

    for (M, K, N) in ((128, 128, 128), (128, 256, 128), (256, 512, 256)):
        cands = block_candidates(M, K, N)
        assert cands and len(cands) <= 4
        assert len(set(cands)) == len(cands)
        for (bm, bn, bk) in cands:
            assert M % bm == 0 and N % bn == 0 and K % bk == 0
    # non-tile-aligned dims fall back to the full dimension
    assert block_candidates(24, 24, 24) == [(24, 24, 24)]


def test_profile_kernels_rows_and_spans():
    tr = obs.configure()
    rows = P.profile_kernels(
        gemm_shapes=((16, 16, 16),), ks=(8,),
        include=("matmul_baseline", "quant_matmul_dynamic_k"),
        reps=2, warmup=1)
    assert [r["kernel"] for r in rows] == ["matmul_baseline",
                                           "quant_matmul_dynamic_k"]
    for r in rows:
        assert r["median_s"] > 0
        assert r["achieved_flops_per_s"] == pytest.approx(
            r["flops"] / r["median_s"])
        assert r["roofline_frac"] > 0 and r["bound"] in ("memory", "compute")
    assert rows[1]["k"] == 8 and rows[1]["format_bits"] == CM.format_bits(8)
    names = [e["name"] for e in tr.events if e["type"] == "span"]
    assert names.count("profile.kernel") == 2


@pytest.mark.slow
def test_profile_kernels_pallas_format_point():
    (row,) = P.profile_kernels(
        gemm_shapes=((16, 16, 16),), formats=((4, 8, -6),),
        blocks=((16, 16, 16),), include=("quant_matmul_format",),
        reps=1, warmup=1)
    assert row["kernel"] == "quant_matmul_format"
    assert row["interpret"] == (jax.default_backend() != "tpu")
    assert row["block"] == [16, 16, 16]
    assert row["format_bits"] == CM.format_bits(4, 8, -6)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_format_bits_and_scope_class():
    assert CM.format_bits(24) == 1 + 8 + 23          # binary32 carrier
    assert CM.format_bits(8) == 1 + 8 + 7
    assert CM.format_bits(4, emax=8, emin=-6) < CM.format_bits(4)
    assert CM.scope_class("") == "default"
    assert CM.scope_class("layer3") == "layer"
    assert CM.scope_class("layer3/attn") == "layer/attn"
    assert CM.scope_class("dense1") == "dense"


def _toy_model(alpha_gemm=1e9, beta_gemm=1e8):
    return CM.CostModel(
        alpha={"quant_matmul_format": alpha_gemm, "flash_decode": 5e8},
        beta={"quant_matmul_format": beta_gemm, "flash_decode": 2e8})


def test_fit_cost_model_median_rates():
    recs = [
        {"kernel": "g", "median_s": 1e-3, "flops": 1e6, "bytes": 1e5},
        {"kernel": "g", "median_s": 2e-3, "flops": 1e6, "bytes": 1e5},
        {"kernel": "g", "median_s": 4e-3, "flops": 1e6, "bytes": 1e5},
    ]
    m = CM.fit_cost_model(recs)
    assert m.alpha["g"] == pytest.approx(1e6 / 2e-3)   # median point
    assert m.beta["g"] == pytest.approx(1e5 / 2e-3)
    assert m.meta["fit_points"] == {"g": 3}
    with pytest.raises(ValueError):
        CM.fit_cost_model([{"kernel": "g", "median_s": 0.0,
                            "flops": 1.0, "bytes": 1.0}])


def test_fit_cost_model_drops_interpret_rows_when_real_exist():
    # interpret-mode rows time the Python emulator, not the hardware: with
    # a real row present they must not drag the fitted rate down
    recs = [
        {"kernel": "g", "median_s": 1e-3, "flops": 1e6, "bytes": 1e5,
         "interpret": False},
        {"kernel": "g", "median_s": 1.0, "flops": 1e6, "bytes": 1e5,
         "interpret": True},
        {"kernel": "g", "median_s": 2.0, "flops": 1e6, "bytes": 1e5,
         "interpret": True},
    ]
    m = CM.fit_cost_model(recs)
    assert m.alpha["g"] == pytest.approx(1e6 / 1e-3)   # real row only
    assert m.meta["fit_points"] == {"g": 1}
    assert m.meta["interpret_rows_dropped"] == 2
    assert "interpret_only" not in m.meta


def test_fit_cost_model_interpret_only_warns_and_flags():
    recs = [{"kernel": "g", "median_s": 1e-3, "flops": 1e6, "bytes": 1e5,
             "interpret": True}]
    with pytest.warns(RuntimeWarning, match="interpret-mode"):
        m = CM.fit_cost_model(recs)
    assert m.meta["interpret_only"] is True
    assert m.alpha["g"] == pytest.approx(1e6 / 1e-3)   # still fits


def test_predict_two_term_roofline():
    m = _toy_model(alpha_gemm=1e9, beta_gemm=1e8)
    # narrow format: few bytes → compute side; wide: many bytes → memory
    narrow = m.predict("dense1", flops_per_token=1e6, k=4, emax=8, emin=-6)
    wide = m.predict("dense1", flops_per_token=1e6, k=24)
    assert narrow["bits"] < wide["bits"]
    assert narrow["bytes"] < wide["bytes"]
    assert wide["latency_s"] == pytest.approx(
        max(wide["compute_s"], wide["memory_s"]))
    assert wide["latency_s"] >= narrow["latency_s"]
    # attention scopes route to the attention kernel class
    assert m.kernel_for("layer3/attn") == "flash_decode"
    assert m.kernel_for("dense1") == "quant_matmul_format"


def test_cost_model_json_roundtrip(tmp_path):
    m = _toy_model()
    path = str(tmp_path / "cm.json")
    m.save_json(path)
    m2 = CM.CostModel.load_json(path)
    assert m2.alpha == m.alpha and m2.beta == m.beta
    assert m2.hardware.name == m.hardware.name
    d = m.to_dict()
    assert d["schema"] == 1 and "alpha_flops_per_s" in d


def test_cost_report_flags_compute_bound_disagreement():
    # β huge → memory term negligible → every scope compute-bound → the
    # bits objective credits narrowing that buys no predicted latency
    m = CM.CostModel(alpha={"quant_matmul_format": 1e9},
                     beta={"quant_matmul_format": 1e30})
    rep = CM.cost_report(m, layer_flops={"layer0": 1e6, "head": 5e5},
                         layer_k={"layer0": 6, "head": 20})
    assert {r["scope"] for r in rep["scopes"]} == {"layer0", "head"}
    assert sum(r["latency_share"] for r in rep["scopes"]) == pytest.approx(1)
    assert rep["mean_bits_flop_weighted"] < CM.BINARY32_BITS
    notes = [d["note"] for d in rep["disagreements"]]
    assert any("compute-bound" in n for n in notes)
    # memory-bound regime: latency saved tracks bits saved → ranks agree
    m2 = CM.CostModel(alpha={"quant_matmul_format": 1e30},
                      beta={"quant_matmul_format": 1e8})
    rep2 = CM.cost_report(m2, layer_flops={"layer0": 1e6, "head": 5e5},
                          layer_k={"layer0": 6, "head": 20})
    assert rep2["rank_agreement"] == 1.0
    text = CM.render_cost_report(rep)
    assert "scope" in text and "layer0" in text


def test_certificate_cost_report_uses_serving_map():
    class _Set:
        model_id = "m"
        params_digest = "d"
        serving_layer_format = None
        serving_layer_k = {"layer0": 8}
        serving_k = 12

    rep = CM.certificate_cost_report(
        _Set(), {"layer0": 1e6, "head": 1e6}, _toy_model())
    by = {r["scope"]: r for r in rep["scopes"]}
    assert by["layer0"]["k"] == 8          # mixed map wins for layer0
    assert by["head"]["k"] == 12           # uniform fallback elsewhere
    assert rep["serving_map"] == "mixed"
    assert rep["model_id"] == "m"


# ---------------------------------------------------------------------------
# bench trajectory: root emission, dedupe, soft perf gate, CLI views
# ---------------------------------------------------------------------------


def _kernel_entry(median_a=1e-3, median_b=1e-3):
    return {
        "kind": "kernel_bench", "backend": "cpu", "interpret": True,
        "hardware": CM.TPU_POD_CHIP.to_dict(),
        "rows": [
            {"kernel": "matmul_baseline", "shape": "128x128x128",
             "median_s": median_a, "flops": 2.0 * 128 ** 3,
             "bytes": 3 * 128 * 128 * 4.0, "intensity": 10.7,
             "roofline_s": 2e-7, "roofline_frac": 2e-4, "bound": "memory",
             "achieved_flops_per_s": 2.0 * 128 ** 3 / median_a,
             "achieved_bytes_per_s": 3 * 128 * 128 * 4.0 / median_a,
             "reps": 3, "interpret": False},
            {"kernel": "quant_matmul_format", "shape": "128x128x128",
             "k": 4, "emax": 8, "emin": -6, "block": [128, 128, 128],
             "median_s": median_b, "flops": 2.0 * 128 ** 3,
             "bytes": 3 * 128 * 128 * 4.0, "intensity": 10.7,
             "roofline_s": 2e-7, "roofline_frac": 2e-4, "bound": "memory",
             "achieved_flops_per_s": 2.0 * 128 ** 3 / median_b,
             "achieved_bytes_per_s": 3 * 128 * 128 * 4.0 / median_b,
             "reps": 3, "interpret": True},
        ],
        "serving": {
            "prefill": {"latency_s": 0.3, "compile_s": 0.4, "lower_s": 0.1,
                        "jaxpr_eqns": 176, "tokens_per_s": 53.0},
            "decode": {"percentiles": {"p50": 2e-4, "p95": 3e-4,
                                       "p99": 3e-4},
                       "mean_s": 2e-4, "count": 6, "compile_s": 0.2,
                       "lower_s": 0.05, "jaxpr_eqns": 191,
                       "tokens_per_s": 5000.0},
        },
    }


def test_bench_root_emission_and_mirror(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    obs.append_bench("kernels", _kernel_entry())
    root = tmp_path / "BENCH_kernels.json"
    mirror = tmp_path / "benchmarks" / "BENCH_kernels.json"
    assert root.exists() and mirror.exists()
    assert json.loads(root.read_text()) == json.loads(mirror.read_text())


def test_bench_seeds_from_legacy_location(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    legacy = tmp_path / "benchmarks"
    legacy.mkdir()
    (legacy / "BENCH_kernels.json").write_text(
        json.dumps([{"t": 1.0, "kind": "kernel_bench", "arch": "old",
                     "rows": []}]))
    obs.append_bench("kernels", {**_kernel_entry(), "arch": "new"})
    entries = json.loads((tmp_path / "BENCH_kernels.json").read_text())
    assert len(entries) == 2 and entries[0]["arch"] == "old"


def test_bench_root_is_single_source_of_truth(tmp_path, monkeypatch):
    # once the root file exists it WINS — even when empty — so a stale
    # legacy mirror can never resurrect entries the root dropped
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    legacy = tmp_path / "benchmarks"
    legacy.mkdir()
    (legacy / "BENCH_kernels.json").write_text(
        json.dumps([{"t": 1.0, "kind": "kernel_bench", "arch": "stale",
                     "rows": []}]))
    (tmp_path / "BENCH_kernels.json").write_text("[]")
    assert obs.read_bench("kernels") == []


def test_bench_read_dedupes_by_content(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    e1 = {"t": 1.0, "kind": "kernel_bench", "arch": "a", "rows": []}
    e2 = {"t": 2.0, "kind": "kernel_bench", "arch": "b", "rows": []}
    (tmp_path / "BENCH_kernels.json").write_text(json.dumps([e1, e2, e1]))
    entries = obs.read_bench("kernels")
    assert entries == [e1, e2]                # first-occurrence order


def test_bench_mirror_is_read_only_snapshot(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    obs.append_bench("kernels", {**_kernel_entry(), "arch": "a"})
    mirror = tmp_path / "benchmarks" / "BENCH_kernels.json"
    import stat
    mode = stat.S_IMODE(mirror.stat().st_mode)
    assert not mode & (stat.S_IWUSR | stat.S_IWGRP | stat.S_IWOTH)
    # the read-only snapshot must not break subsequent appends (os.replace
    # renames over it — only directory perms matter)
    obs.append_bench("kernels", {**_kernel_entry(), "arch": "b"})
    entries = json.loads((tmp_path / "BENCH_kernels.json").read_text())
    assert [e["arch"] for e in entries] == ["a", "b"]
    assert json.loads(mirror.read_text()) == entries


def test_check_regressions_flags_only_regressed_rows(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    assert obs.check_regressions("kernels") == []   # nothing to compare
    obs.append_bench("kernels", {**_kernel_entry(1e-3, 1e-3), "arch": "a"})
    obs.append_bench("kernels",
                     {**_kernel_entry(1e-3, 1.5e-3), "arch": "b"})
    findings = obs.check_regressions("kernels", threshold=0.25)
    assert len(findings) == 1
    assert findings[0]["kernel"] == "quant_matmul_format"
    assert findings[0]["ratio"] == pytest.approx(1.5)
    assert obs.check_regressions("kernels", threshold=0.6) == []


def test_render_kernel_table_shows_roofline_and_serving():
    text = render_kernel_table([_kernel_entry()])
    assert "matmul_baseline" in text and "quant_matmul_format" in text
    assert "p50" in text and "p99" in text
    assert "prefill" in text
    # a second entry gets a Δprev column vs the first's matching rows
    text2 = render_kernel_table([_kernel_entry(1e-3, 1e-3),
                                 _kernel_entry(1e-3, 2e-3)])
    assert "+100%" in text2


def test_report_kernels_cli(tmp_path, monkeypatch, capsys):
    from repro.obs.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    obs.append_bench("kernels", _kernel_entry())
    assert main(["report", "--kernels"]) == 0
    out = capsys.readouterr().out
    assert "quant_matmul_format" in out and "p99" in out


def test_perfgate_cli_warns_and_exits_zero(tmp_path, monkeypatch, capsys):
    from repro.obs.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    assert main(["perfgate"]) == 0                 # empty trajectory: ok
    obs.append_bench("kernels", {**_kernel_entry(1e-3, 1e-3), "arch": "a"})
    obs.append_bench("kernels", {**_kernel_entry(1e-3, 2e-3), "arch": "b"})
    assert main(["perfgate", "--threshold", "0.25"]) == 0   # never fails
    out = capsys.readouterr().out
    assert "::warning::" in out and "quant_matmul_format" in out


def test_perfgate_fail_on_hard_rail(tmp_path, monkeypatch, capsys):
    from repro.obs.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    obs.append_bench("kernels", {**_kernel_entry(1e-3, 1e-3), "arch": "a"})
    obs.append_bench("kernels", {**_kernel_entry(1e-3, 2e-3), "arch": "b"})
    # +100% regression beyond the 50% rail → hard failure with ::error::
    assert main(["perfgate", "--threshold", "0.25",
                 "--fail-on", "0.5"]) == 1
    out = capsys.readouterr().out
    assert "::error::" in out and "quant_matmul_format" in out
    # the same regression under a higher rail stays a soft warning
    assert main(["perfgate", "--threshold", "0.25",
                 "--fail-on", "2.0"]) == 0
    out = capsys.readouterr().out
    assert "::warning::" in out and "::error::" not in out


# ---------------------------------------------------------------------------
# metrics details the serving digests rely on
# ---------------------------------------------------------------------------


def test_prometheus_label_escaping_and_labeled_buckets():
    reg = obs.MetricsRegistry()
    reg.counter('serve.requests{arch=qwen2_7b,mode=a"b}', 3)
    reg.observe('serve.decode_latency_s{arch=qwen2_7b}', 0.01)
    reg.observe('serve.decode_latency_s{arch=qwen2_7b}', 0.02)
    text = reg.render_prometheus()
    assert 'serve_requests{arch="qwen2_7b",mode="a\\"b"} 3' in text
    # labeled histogram series keep the _bucket suffix + cumulative counts
    acc = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
           if line.startswith("serve_decode_latency_s_bucket{")]
    assert acc and acc == sorted(acc) and acc[-1] == 2
    assert 'arch="qwen2_7b"' in text and 'le="+Inf"' in text
    assert 'serve_decode_latency_s_count{arch="qwen2_7b"} 2' in text
    # one # TYPE line per base metric name even with many label sets
    reg.observe('serve.decode_latency_s{arch=other}', 0.01)
    text = reg.render_prometheus()
    assert text.count("# TYPE serve_decode_latency_s histogram") == 1


def test_percentiles_clamped_into_observed_range():
    h = obs.Histogram("lat")
    for v in (0.011, 0.012, 0.013):
        h.observe(v)
    p = h.percentiles()
    assert set(p) == {"p50", "p95", "p99"}
    assert h.min <= p["p50"] <= p["p95"] <= p["p99"] <= h.max


@given(st.lists(st.floats(min_value=1e-6, max_value=1e3,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=64))
def test_percentile_digest_order_property(values):
    h = obs.Histogram("lat")
    for v in values:
        h.observe(v)
    p = h.percentiles()
    assert p["p50"] <= p["p95"] <= p["p99"]
    assert h.min <= p["p50"] and p["p99"] <= h.max
    assert math.isfinite(p["p99"])


# ---------------------------------------------------------------------------
# gauges recorded by the certify path
# ---------------------------------------------------------------------------


def test_ladder_compile_gauges_recorded():
    from repro.certify.batch import ProbeLadder, stack_class_ranges
    from repro.models import paper_models as PM

    params = PM.init_digits(jax.random.PRNGKey(0), d_in=12, h1=8, h2=6,
                            n_classes=4)
    x = stack_class_ranges([np.zeros(12)], [np.full(12, 0.1)])
    tr = obs.configure()
    ladder = ProbeLadder(PM.digits_forward, params, x)
    ladder(10)
    ladder(14)
    assert tr.gauges["ladder.uniform_compile_s"] > 0
    assert tr.gauges["ladder.uniform_jaxpr_eqns"] > 0


def test_aff_condense_counts_drops_when_traced():
    from repro.core.interval import AffineForm, aff_condense

    terms = jnp.stack([jnp.full((2,), 0.1 * (i + 1)) for i in range(6)])
    a = AffineForm(center=jnp.zeros((2,)), terms=terms,
                   ids=jnp.arange(1, 7, dtype=jnp.int32),
                   rad=jnp.zeros((2,)))
    tr = obs.configure()
    out = aff_condense(a, budget=2)
    assert out.budget == 2
    assert tr.counters["affine.condense_calls"] == 1
    assert tr.counters["affine.condense_drops"] == 4
    assert tr.gauges["affine.condense_drops"] == 4
