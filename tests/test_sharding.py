"""Sharding rule engine: divisibility fallbacks and policy behaviour.

Uses a mock mesh (the helpers only touch axis_names/devices.shape) so the
rules are testable without 256 devices.
"""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh


class MockMesh:
    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape, dtype=object)


SINGLE = MockMesh((16, 16), ("data", "model"))
MULTI = MockMesh((2, 16, 16), ("pod", "data", "model"))


def test_greedy_assigns_model_to_biggest_divisible_dim():
    spec = sh._greedy_param_spec((4096, 16384), SINGLE, stacked=False)
    assert spec == P("data", "model")  # 16384 biggest → model; 4096 → data


def test_greedy_respects_stacked_layer_dim():
    spec = sh._greedy_param_spec((48, 4096, 16384), SINGLE, stacked=True)
    assert spec[0] is None


def test_greedy_small_tensors_replicate():
    spec = sh._greedy_param_spec((128,), SINGLE, stacked=False)
    assert spec == P(None)


def test_greedy_indivisible_dims_skipped():
    # 30 not divisible by 16 on either axis → replicate that dim
    spec = sh._greedy_param_spec((30, 1 << 20), SINGLE, stacked=False)
    assert spec[0] is None and spec[1] == "model"


def test_model_only_never_uses_data():
    spec = sh._greedy_param_spec((8192, 8192), SINGLE, stacked=False,
                                 axes=("model",))
    assert "data" not in tuple(spec) and "model" in tuple(spec)


def test_batch_spec_prefers_batch_then_seq():
    assert sh.batch_spec(SINGLE, 256, 4096) == P(("data",), None)
    # batch 1 can't take the axis → sequence parallelism fallback
    assert sh.batch_spec(SINGLE, 1, 524288) == P(None, ("data",))
    # multi-pod: both dp axes over batch when divisible
    assert sh.batch_spec(MULTI, 256, 4096) == P(("pod", "data"), None)


def test_cache_spec_gqa_heads_divisible():
    # [L,B,S,K,Dh] with K=16 divisible by model → heads sharded
    spec = sh.cache_spec(SINGLE, (46, 128, 32768, 16, 128), "gqa")
    assert spec[3] == "model" and spec[1] == "data"


def test_cache_spec_gqa_seq_fallback():
    # K=8 not divisible by 16 → KV-sequence over model (flash-style)
    spec = sh.cache_spec(SINGLE, (28, 128, 32768, 8, 128), "gqa")
    assert spec[2] == "model" and spec[3] is None


def test_cache_spec_batch1_long_context():
    spec = sh.cache_spec(SINGLE, (24, 1, 524288, 8, 128), "gqa")
    # batch 1: sequence takes both axes
    assert spec[2] in (("data", "model"), "model")


def test_cache_spec_mla_latent():
    spec = sh.cache_spec(SINGLE, (62, 128, 32768, 256), "mla")
    assert spec[1] == "data" and spec[2] == "model"
