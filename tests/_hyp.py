"""Optional-hypothesis shim.

hypothesis lives in the ``[dev]`` extra; on a clean runtime environment the
property tests must *skip* while every example-based test in the same module
still runs. Importing ``given``/``st``/``assume`` from here instead of from
hypothesis gives exactly that: real objects when hypothesis is installed,
stubs that mark the test skipped otherwise.
"""
import pytest

try:
    from hypothesis import assume, given, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def assume(*_a, **_k):  # noqa: D103
        return None

    class _AnyStrategy:
        """Stands in for any strategy expression built at collection time."""

        def __getattr__(self, name):
            return lambda *a, **k: _AnyStrategy()

        def __call__(self, *a, **k):
            return _AnyStrategy()

        def map(self, _f):
            return self

        def filter(self, _f):
            return self

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed ([dev] extra)")
