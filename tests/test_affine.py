"""Affine (zonotope) range pass — the anti-saturation evidence (ISSUE 7).

The IA range pass bounds rounded magnitudes through the CAA γ accumulation
terms, which saturate to inf at coarse mantissa precisions — silently
forcing attention archs back to uniform-k formats. The affine pass must

  * stay FINITE at every precision (operational (1+u/2)^n rounding model),
  * soundly enclose the exact f64 forward value at fine precision,
  * cancel correlated terms interval arithmetic cannot (x - x),
  * agree between the eager and the scan-native (stacked) variants,
  * min-combine with IA evidence via ``tighten_range_maps``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analyze, caa, interval as iv
from repro.core import formats as F
from repro.core.backend import (AffineRangeCaaOps, JOps, RangeStat,
                                StackedAffineRangeCaaOps)

FINE = F.custom(50)       # near-f64: enclosures should hug the exact value
COARSE = F.custom(5)      # far coarser than any IA pass survives


# ---------------------------------------------------------------------------
# interval.py affine forms
# ---------------------------------------------------------------------------

def test_aff_sub_cancels_correlated_terms():
    x = iv.aff_make(jnp.asarray([2.0, -1.0]), budget=8)
    x = iv.aff_append_symbol(x, jnp.asarray([1.0, 2.0]), 1, budget=8)
    d = iv.aff_interval(iv.aff_sub(x, x, budget=8))
    # terms sharing a noise-symbol id cancel exactly; only the pass's own
    # f64 slop remains in the remainder
    w = np.asarray(d.hi) - np.asarray(d.lo)
    assert (w <= 1e-12).all()
    # IA subtraction of the same enclosures doubles the width instead
    I = iv.aff_interval(x)
    wi = np.asarray(iv.sub(I, I).hi) - np.asarray(iv.sub(I, I).lo)
    assert (wi >= 2.0).all()


def test_aff_mul_encloses_true_product():
    rng = np.random.RandomState(0)
    lo = rng.randn(8)
    hi = lo + rng.rand(8)
    a = iv.aff_from_interval(iv.Interval(jnp.asarray(lo), jnp.asarray(hi)))
    prod = iv.aff_interval(iv.aff_mul(a, iv.aff_scale(a, 2.0), budget=8))
    for t in np.linspace(0.0, 1.0, 7):
        v = lo + t * (hi - lo)
        p = v * (2.0 * v)
        assert (np.asarray(prod.lo) <= p + 1e-12).all()
        assert (np.asarray(prod.hi) >= p - 1e-12).all()


# ---------------------------------------------------------------------------
# backend pass: soundness, finiteness, cancellation
# ---------------------------------------------------------------------------

def _fwd(bk, params, x):
    x = bk.input(x)
    with bk.scope("blk"):
        h2 = bk.tanh(bk.matmul(x, bk.param(params["w1"])))
    with bk.scope("head"):
        out = bk.matmul(h2, bk.param(params["w2"]))
        out = bk.add(out, bk.mul(h2, h2))
    return bk.softmax(out, axis=-1)


def _setup():
    rng = np.random.RandomState(1)
    params = {"w1": jnp.asarray(rng.randn(6, 4) * 0.5),
              "w2": jnp.asarray(rng.randn(4, 4) * 0.5)}
    lo = rng.rand(3, 6) * 0.4
    return params, lo, lo + 0.05


def test_affine_pass_encloses_exact_forward_and_stays_finite():
    params, lo, hi = _setup()
    mid = jnp.asarray((lo + hi) / 2.0)

    exact = _fwd(JOps(), params, mid)

    for fmt in (FINE, COARSE):
        ops = AffineRangeCaaOps({}, fmt)
        out = _fwd(ops, params, caa.from_range(lo, hi))
        I = out.exact
        lo_e, hi_e = np.asarray(I.lo), np.asarray(I.hi)
        assert np.isfinite(lo_e).all() and np.isfinite(hi_e).all()
        assert (lo_e <= np.asarray(exact) + 1e-12).all()
        assert (hi_e >= np.asarray(exact) - 1e-12).all()
        # every recorded scope enclosure is finite, even at k=5
        for s, st in ops.scope_ranges.items():
            assert np.isfinite(st.max_abs), (fmt, s, st)


def test_affine_pass_cancels_rounding_symbols_interval_channel_cannot():
    """Where the two channels differ: the rounding charge of u = x + x is
    ONE shared noise symbol, so sub(u, u)'s form channel cancels it, while
    the interval channel's widths add. The exact enclosure (channel
    intersection) must follow the tight form side — this is the
    correlation-tracking IA fundamentally lacks."""
    raw = jnp.asarray([1.5, 2.0, -3.0, 2.5, -1.0])
    ops = AffineRangeCaaOps({}, COARSE)   # hu = 2^-5: IA widths are visible
    x = ops.input(raw)
    u = ops.add(x, x)
    d = ops.sub(u, u)
    w_exact = np.asarray(d.exact.hi) - np.asarray(d.exact.lo)
    w_ivl = np.asarray(d.ivl.hi) - np.asarray(d.ivl.lo)
    assert (w_ivl > 0.1).all()            # IA: ~8·hu·|x| per element
    assert (w_exact <= 0.01 * w_ivl).all()


def test_stacked_affine_matches_eager_per_scope():
    """Scan-native [L, lanes] accumulation == the eager unrolled pass on
    every emitted key, including the sub-layer lanes."""
    rng = np.random.RandomState(2)
    L, d = 3, 4
    stacked_w = jnp.asarray(rng.randn(L, d, d) * 0.4)
    lo = rng.rand(2, d) * 0.3
    x = caa.from_range(lo, lo + 0.1)

    def fwd(bk, params, xin):
        def body(p, h, i, _a):
            with bk.scope("attn"):
                h = bk.tanh(bk.matmul(h, p))
            with bk.scope("mlp"):
                h = bk.add(h, bk.mul(h, h))
            return h, None
        h = bk.input(xin)
        return bk.layer_loop(body, params, h, L)

    scope_fmts = {"layer*": F.custom(9), "layer*/mlp": F.custom(7)}
    eager = AffineRangeCaaOps(scope_fmts, FINE)
    fwd(eager, stacked_w, x)
    stk = StackedAffineRangeCaaOps(scope_fmts, FINE,
                                   sublanes=("attn", "mlp"))
    fwd(stk, stacked_w, x)
    got = stk.collect_ranges()

    want_keys = {f"layer{i}" for i in range(L)}
    want_keys |= {f"layer{i}/{s}" for i in range(L) for s in ("attn", "mlp")}
    assert want_keys <= set(got)
    for key in sorted(want_keys | {""}):
        e, g = eager.scope_ranges.get(key), got.get(key)
        if e is None and (g is None or g.n_ops == 0):
            continue
        assert g is not None, key
        np.testing.assert_allclose(g.max_abs, e.max_abs, rtol=1e-9,
                                   err_msg=key)
        np.testing.assert_allclose(g.min_nonzero, e.min_nonzero, rtol=1e-9,
                                   err_msg=key)
        assert g.crosses_zero == e.crosses_zero, key


def test_analyze_ranges_affine_driver():
    params, lo, hi = _setup()
    got = analyze.analyze_ranges_affine(
        _fwd, params, caa.from_range(lo, hi), {}, COARSE, stacked=False)
    assert {"blk", "head", ""} <= set(got)
    assert all(np.isfinite(st.max_abs) for st in got.values()
               if st.n_ops > 0)


# ---------------------------------------------------------------------------
# evidence combination
# ---------------------------------------------------------------------------

def test_tighten_range_maps_min_combines():
    base = {"a": RangeStat(max_abs=np.inf, min_nonzero=1e-3,
                           crosses_zero=False, n_ops=4),
            "b": RangeStat(max_abs=2.0, min_nonzero=1e-2,
                           crosses_zero=True, n_ops=1),
            "c": RangeStat()}
    tight = {"a": RangeStat(max_abs=5.0, min_nonzero=1e-4,
                            crosses_zero=True, n_ops=4),
             "b": RangeStat(max_abs=8.0, min_nonzero=1e-1,
                            crosses_zero=False, n_ops=2),
             "c": RangeStat(max_abs=1.0, min_nonzero=1e-2,
                            crosses_zero=False, n_ops=9)}
    out = analyze.tighten_range_maps(base, tight)
    # the affine evidence de-saturates the inf; underflow stays conservative
    assert out["a"].max_abs == 5.0
    assert out["a"].min_nonzero == 1e-4
    assert out["a"].crosses_zero
    assert out["a"].n_ops == 4
    assert out["b"].max_abs == 2.0 and out["b"].crosses_zero
    # an empty base entry passes through (nothing to tighten)
    assert out["c"].n_ops == 0
    # keys missing from tight pass through unchanged
    out2 = analyze.tighten_range_maps(base, {})
    assert out2["a"].max_abs == np.inf
