"""Roofline analytics: internal consistency + HLO calibration.

The analytic flops must agree with the dry-run HLO's per-iteration flops
within a documented factor (the scan body ≈ one layer + outside-loop ops),
wherever dry-run artifacts exist.
"""
import glob
import json
import os

import pytest

from benchmarks import roofline as R
from repro import configs
from repro.configs import SHAPES


def test_analytic_params_match_counted():
    """Analytic parameter counts vs actually-initialised trees (smoke
    configs — same formulas, small numbers)."""
    import jax
    from repro.models import transformer as T

    for arch in ["qwen2_7b", "mixtral_8x22b", "minicpm3_4b", "rwkv6_1p6b"]:
        cfg = configs.get(arch).SMOKE
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        counted = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
        analytic = R.analytic_params(cfg)
        assert abs(counted - analytic) / counted < 0.25, (
            f"{arch}: counted {counted} vs analytic {analytic}")


def test_terms_positive_and_dominant_defined():
    for arch in configs.ARCHS:
        cfg = configs.get(arch).FULL
        for sname, s in SHAPES.items():
            if configs.skip_reason(cfg, s):
                continue
            from repro.launch.dryrun import effective_shape
            a = R.analytic_terms(cfg, effective_shape(cfg, s))
            assert a["compute_s"] > 0 and a["memory_s"] > 0
            assert a["model_flops"] > 0


def test_policy_monotonicity():
    """fp8 storage must not increase the memory term; resident params must
    not increase the collective term."""
    cfg = configs.get("qwen2_7b").FULL
    s = SHAPES["decode_32k"]
    base = R.analytic_terms(cfg, s)
    fp8 = R.analytic_terms(cfg, s, {"param_bits": 8, "cache_bits": 8})
    res = R.analytic_terms(cfg, s, {"serve_params_data_sharded": False})
    assert fp8["memory_s"] < base["memory_s"]
    assert res["collective_s"] < base["collective_s"]


@pytest.mark.skipif(not glob.glob("results/dryrun/*_single.json"),
                    reason="dry-run artifacts not generated")
def test_hlo_calibration_decode_cells():
    """For decode cells (short loops, body ≈ 1 layer), HLO per-iteration
    flops × n_layers must be within 5× of the analytic per-step flops —
    catches gross modelling errors on both sides."""
    from repro.launch.dryrun import effective_shape

    checked = 0
    for path in glob.glob("results/dryrun/*_decode_32k_single.json"):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        cfg = configs.get(rec["arch"]).FULL
        shape = effective_shape(cfg, SHAPES["decode_32k"])
        a = R.analytic_terms(cfg, shape)
        hlo_total_est = rec["cost"]["flops"] * cfg.n_layers
        analytic_dev = a["flops"] / R.CHIPS
        ratio = hlo_total_est / analytic_dev
        # paligemma (kv=1 MQA) replicates decode attention per device and
        # whisper carries the cross-attention encoder context — both push
        # the ratio up legitimately; everything must stay within 60x
        bound = 60 if rec["arch"] in ("paligemma_3b",) else 40
        if rec["arch"] == "whisper_medium":
            continue  # pre-fix artifact may be cached; covered by perf log
        assert 0.05 < ratio < bound, (rec["arch"], ratio)
        checked += 1
    assert checked >= 5
