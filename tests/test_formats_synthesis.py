"""Schema-v3 full-format certificates, end to end (the PR's acceptance).

On digits and pendulum: certify(formats=True) must emit v3 certificates
whose per-scope formats survive three independent cross-examinations —

  * an EAGER re-analysis, rebuilt from the stored descriptors alone, with
    the formats' own underflow (round_abs) terms, re-confirms the bounds
    within each class's decision margin;
  * the IA range enclosures of that pass prove no value can overflow the
    chosen emax;
  * serving through the scalar-prefetch Pallas kernel is bitwise identical
    to eager quantize_to_format emulation —

with reported total-bits strictly below the uniform-k + binary32-range
baseline on at least one arch.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import certify as C
from repro.certify import formats as FS
from repro.certify.spec import Certificate, CertificateSet
from repro.core import caa
from repro.core import formats as F
from repro.core.quantize import quantize_to_format
from repro.models import paper_models as PM

P_STAR = 0.6
ABS_TOL = 1e-3


def _digits_setup():
    from repro.data import synthetic_digits

    imgs, labels = synthetic_digits.make_dataset(160, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0), h1=16, h2=8)
    los, his = [], []
    for c in range(10):
        m = imgs[labels == c].mean(0)
        los.append(np.clip(m - 0.02, 0.0, 1.0))
        his.append(np.clip(m + 0.02, 0.0, 1.0))
    return params, los, his


@pytest.fixture(scope="module")
def digits_case():
    params, los, his = _digits_setup()
    cs = C.certify(PM.digits_forward, params, los, his, p_star=P_STAR,
                   model_id="digits/fmt-test", k_max=24,
                   mixed=True, formats=True)
    return params, los, his, cs


@pytest.fixture(scope="module")
def pendulum_case():
    params = PM.init_pendulum(jax.random.PRNGKey(2), h=16)
    lo, hi = np.full(2, -6.0), np.full(2, 6.0)
    cs = C.certify(PM.pendulum_forward, params, [lo], [hi], abs_tol=ABS_TOL,
                   model_id="pendulum/fmt-test", k_max=32, formats=True)
    return params, [lo], [hi], cs


def _cases(digits_case, pendulum_case):
    return [("digits", PM.digits_forward, C.margin_feasibility(P_STAR),
             digits_case),
            ("pendulum", PM.pendulum_forward, C.tolerance_feasibility(ABS_TOL),
             pendulum_case)]


# ---------------------------------------------------------------------------
# schema v3
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_v3_emitted_and_roundtrips(digits_case, pendulum_case):
    for name, _fwd, _feas, (params, los, his, cs) in _cases(
            digits_case, pendulum_case):
        assert cs.meta["formats"]["applied"], name
        for cert in cs.certificates:
            d = cert.to_dict()
            assert d["schema_version"] == 3
            assert cert.layer_format is not None
            assert "" in cert.layer_format, "default format entry required"
            back = Certificate.from_json(cert.to_json())
            assert back.layer_format == cert.layer_format
            for s, fd in cert.layer_format.items():
                fmt = F.from_dict(fd)
                assert fmt.saturating and fmt.has_subnormals
                assert fmt.k >= 2 and fmt.emax >= 1
        back = CertificateSet.from_json(cs.to_json())
        assert back.serving_layer_format == cs.serving_layer_format
        assert cs.serving_layer_format is not None


@pytest.mark.slow
def test_v2_and_v1_entries_stay_readable(digits_case):
    _params, _los, _his, cs = digits_case
    d = cs.certificates[0].to_dict()
    d.pop("layer_format")
    d["schema_version"] = 2
    v2 = Certificate.from_dict(d)
    assert v2.layer_format is None and v2.layer_k is not None
    d.pop("layer_k")
    d["schema_version"] = 1
    v1 = Certificate.from_dict(d)
    assert v1.layer_k is None and v1.required_k == cs.certificates[0].required_k


# ---------------------------------------------------------------------------
# acceptance 1: eager re-analysis from the stored descriptors re-confirms
# ---------------------------------------------------------------------------

def _map_from_cert(cert):
    lf = {s: F.from_dict(fd) for s, fd in cert.layer_format.items()}
    default = lf.pop("")
    keys = sorted(lf)
    return lf, default, keys


@pytest.mark.slow
def test_eager_reconfirmation_within_margins(digits_case, pendulum_case):
    for name, fwd, feasible, (params, los, his, cs) in _cases(
            digits_case, pendulum_case):
        cert = cs.certificates[0]
        lf, default, keys = _map_from_cert(cert)
        x = C.stack_class_ranges(los, his)
        abs_u, rel_u, k_ref, _ranges = FS.eager_format_report(
            fwd, params, x, lf, default, keys)
        assert bool(np.all(feasible(abs_u, rel_u, k_ref))), (
            f"{name}: stored formats fail eager re-confirmation")
        # and it reproduces the pipeline's recorded confirmation exactly
        fm = cs.meta["formats"]
        assert fm["k_ref"] == k_ref
        np.testing.assert_array_equal(abs_u, np.asarray(fm["abs_u_ref"]))


@pytest.mark.slow
def test_format_bounds_dominate_unbounded_range_bounds(pendulum_case):
    """The underflow term only ever ADDS error: the format-aware bounds at
    the same u must be ≥ the plain mantissa-only bounds."""
    params, los, his, cs = pendulum_case
    cert = cs.certificates[0]
    lf, default, keys = _map_from_cert(cert)
    x = C.stack_class_ranges(los, his)
    abs_u, _rel, k_ref, _r = FS.eager_format_report(
        fwd := PM.pendulum_forward, params, x, lf, default, keys)
    from repro.core import analyze
    rep = analyze.analyze_batched(
        fwd, params, x,
        cfg=dataclasses.replace(caa.DEFAULT_CONFIG,
                                u_max=2.0 ** (1 - k_ref)))
    assert np.all(abs_u >= rep.abs_u * (1 - 1e-12))


# ---------------------------------------------------------------------------
# acceptance 2: IA enclosures prove no overflow at the chosen emax
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_no_overflow_at_certified_emax(digits_case, pendulum_case):
    for name, fwd, _feas, (params, los, his, cs) in _cases(
            digits_case, pendulum_case):
        cert = cs.certificates[0]
        lf, default, keys = _map_from_cert(cert)
        x = C.stack_class_ranges(los, his)
        _a, _e, _k, ranges = FS.eager_format_report(
            fwd, params, x, lf, default, keys)
        for s in keys:
            if ranges[s].n_ops == 0:
                continue
            fmt = lf[s]
            assert ranges[s].max_abs <= fmt.max_finite, (
                f"{name}/{s}: range {ranges[s].max_abs} overflows "
                f"{fmt.describe()}")
        # the certificate's own recorded evidence agrees
        rec = cs.meta["formats"]["scope_ranges"]
        for s in keys:
            if rec[s]["n_ops"]:
                assert rec[s]["max_abs"] <= lf[s].max_finite


# ---------------------------------------------------------------------------
# acceptance 3: scalar-prefetch kernel == eager quantize_to_format, bitwise
# ---------------------------------------------------------------------------

def _fmt_triple(fmt):
    return jnp.asarray([fmt.k, fmt.emax, fmt.emin], jnp.int32)


@pytest.mark.slow
def test_kernel_bitwise_vs_eager_emulation(digits_case):
    from repro.kernels.quant_matmul import (quant_matmul_format,
                                            quant_matmul_format_ref)

    params, los, his, cs = digits_case
    lf, default, keys = _map_from_cert(cs.certificates[0])
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.rand(8, 784).astype(np.float32))
    h = x
    for scope, w, b in (("dense1", "w1", "b1"), ("dense2", "w2", "b2"),
                        ("dense3", "w3", "b3")):
        fmt = lf[scope]
        wq = jnp.asarray(np.asarray(params[w], np.float32))
        Kdim = int(h.shape[1])
        out_k = quant_matmul_format(
            h, wq, _fmt_triple(fmt),
            block_m=8, block_n=int(wq.shape[1]), block_k=Kdim,
            interpret=True)
        out_e = quant_matmul_format_ref(h, wq, _fmt_triple(fmt))
        assert bool(jnp.array_equal(out_k, out_e)), f"{scope}: kernel drift"
        h = jax.nn.relu(out_e + jnp.asarray(params[b], jnp.float32))


@pytest.mark.slow
def test_serving_backend_applies_v3_map_bitwise(digits_case):
    """launch/serve's FormatQuantJOps under the merged serving map equals a
    hand-rolled eager emulation of exactly that map."""
    from repro.launch.serve import FormatQuantJOps

    params, los, his, cs = digits_case
    sm = cs.serving_layer_format
    bk = FormatQuantJOps(sm, None)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.rand(4, 784).astype(np.float32))
    got = PM.digits_forward(bk, params, x)

    def q(v, fd):
        return quantize_to_format(jnp.asarray(v, jnp.float32),
                                  fd["k"], fd["emax"], fd["emin"])

    def mm(a, w, b, fd):
        out = q(jnp.matmul(q(a, fd), q(jnp.asarray(w, jnp.float32), fd),
                           preferred_element_type=jnp.float32), fd)
        return out + jnp.asarray(b, jnp.float32)

    h = jax.nn.relu(mm(x, params["w1"], params["b1"], sm["dense1"]))
    h = jax.nn.relu(mm(h, params["w2"], params["b2"], sm["dense2"]))
    o = mm(h, params["w3"], params["b3"], sm["dense3"])
    want = jax.nn.softmax(o, axis=-1)
    assert bool(jnp.array_equal(got, want))


# ---------------------------------------------------------------------------
# acceptance 4: total bits strictly below the uniform-k + binary32 baseline
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_total_bits_savings_positive(digits_case, pendulum_case):
    savings = {}
    for name, _fwd, _feas, (_p, _l, _h, cs) in _cases(
            digits_case, pendulum_case):
        fm = cs.meta["formats"]
        savings[name] = fm["savings_bits_flop_weighted"]
        assert fm["baseline_bits"] == fm["uniform_k"] + 8
    assert max(savings.values()) > 0, savings
    # both small models should comfortably beat binary32-range storage
    assert savings["pendulum"] > 0


@pytest.mark.slow
def test_ladder_compiles_once(digits_case, pendulum_case):
    for _name, _fwd, _feas, (_p, _l, _h, cs) in _cases(
            digits_case, pendulum_case):
        assert cs.meta["formats"]["ladder_compiles"] == 1


# ---------------------------------------------------------------------------
# serving-map merge + store round-trip
# ---------------------------------------------------------------------------

def _mk_cert(layer_format, k=12):
    return Certificate(
        model_id="m", params_digest="d", class_key="c",
        cfg=caa.CaaConfig(), bounds_u_max=2.0 ** (1 - k),
        final_abs_u=1.0, final_rel_u=1.0, required_k=k,
        satisfied_by=[], layer_format=layer_format)


def test_serving_layer_format_merges_coarsest_demand():
    f1 = {"": F.from_bits(10, 5, saturating=True).to_dict(),
          "blk": F.from_bits(8, 3, saturating=True).to_dict()}
    f2 = {"": F.from_bits(12, 4, saturating=True).to_dict(),
          "blk": F.from_bits(6, 6, saturating=True).to_dict()}
    cs = CertificateSet("m", "d", [_mk_cert(f1), _mk_cert(f2)])
    merged = cs.serving_layer_format
    blk = merged["blk"]
    assert blk["k"] == 8                      # max k
    assert blk["emax"] == 2 ** 5 - 1          # max emax (e=6)
    assert blk["emin"] == 1 - (2 ** 5 - 1)    # min emin
    root = merged[""]
    assert root["k"] == 12 and root["emax"] == 2 ** 4 - 1

    # one class without a map → no joint format serving
    cs2 = CertificateSet("m", "d", [_mk_cert(f1), _mk_cert(None)])
    assert cs2.serving_layer_format is None


@pytest.mark.slow
def test_store_roundtrip_preserves_v3(tmp_path, pendulum_case):
    _params, _los, _his, cs = pendulum_case
    store = C.CertificateStore(str(tmp_path / "certs"))
    store.put("k1", cs)
    store._lru.clear()                        # force the disk path
    back = store.get("k1")
    assert back.serving_layer_format == cs.serving_layer_format
    assert back.certificates[0].layer_format == \
        cs.certificates[0].layer_format
    payload = json.loads(open(store.path_for("k1")).read())
    assert payload["certificate_set"]["schema_version"] == 3


def test_serving_backend_honours_map_flags():
    """The map's subnormal/saturation flags reach the quantisation path —
    an FTZ (has_subnormals=False) map must serve FTZ arithmetic, and mixed
    flags must be rejected rather than silently unified."""
    from repro.launch.serve import FormatQuantJOps

    ftz = {"": F.from_bits(8, 4, has_subnormals=False,
                           saturating=True).to_dict()}
    bk = FormatQuantJOps(ftz, None)
    assert bk.has_subnormals is False and bk.saturating is True
    fmt = F.from_dict(ftz[""])
    # a value between min_subnormal and min_normal/2: FTZ flushes it to 0,
    # gradual underflow would keep it on the subnormal grid
    x = jnp.asarray([[np.float32(fmt.min_normal * 0.26)]])
    w = jnp.asarray([[np.float32(1.0)]])
    out = bk.matmul(x, w)
    assert float(out[0, 0]) == 0.0
    sub = FormatQuantJOps(
        {"": F.from_bits(8, 4, saturating=True).to_dict()}, None)
    assert float(sub.matmul(x, w)[0, 0]) != 0.0

    mixed_flags = {"": F.from_bits(8, 4, saturating=True).to_dict(),
                   "blk": F.from_bits(8, 4, saturating=False).to_dict()}
    with pytest.raises(ValueError):
        FormatQuantJOps(mixed_flags, None)
    clipped = {"": dict(F.FP8_E4M3.to_dict(), max_finite_override=448.0)}
    with pytest.raises(NotImplementedError):
        FormatQuantJOps(clipped, None)


def test_serving_layer_format_merge_propagates_override():
    """Encoding-clipped formats (e4m3-style max_finite_override) keep their
    clipped range through the coarsest-demand merge."""
    clipped = {"": F.FP8_E4M3.to_dict()}
    cs = CertificateSet("m", "d", [_mk_cert(clipped, k=4),
                                   _mk_cert(clipped, k=4)])
    merged = cs.serving_layer_format[""]
    assert F.from_dict(merged).max_finite == 448.0
    # merged with an UNclipped class at the same (k, emax): the formula
    # value is the widest certified range, so the override disappears
    unclipped = {"": dataclasses.asdict(F.FP8_E4M3)}
    unclipped[""]["max_finite_override"] = None
    cs2 = CertificateSet("m", "d", [_mk_cert(clipped, k=4),
                                    _mk_cert(unclipped, k=4)])
    assert F.from_dict(cs2.serving_layer_format[""]).max_finite == 480.0
