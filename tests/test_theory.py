"""The paper's Section III/IV closed forms, property-tested.

  * softmax abs→rel conversion: measured relative output error under input
    perturbations ‖δ‖∞ is ≤ the paper's 5.5·max|δ_k| (eq. 11) in its small-δ
    regime, and our engine's rigorous bound lies between measured and a
    sane multiple.
  * tanh rel→rel factor 2.63 with gate ε̄u ≤ 1/4 (paper §III).
  * margin formulas μ = p*−1/2, ν = (2p*−1)/(2p*+1) and the worked example.
"""
import numpy as np
from _hyp import assume, given, st  # optional-hypothesis shim

from repro.core import theory


@given(st.integers(2, 12), st.floats(1e-6, 1e-2), st.integers(0, 10_000))
def test_softmax_paper_bound_holds_empirically(n, dmax, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(n) * 2.0
    delta = (rng.rand(n) * 2 - 1) * dmax

    def sm(v):
        e = np.exp(v - v.max())
        return e / e.sum()

    y, yp = sm(x), sm(x + delta)
    rel = np.abs(yp - y) / y
    assert rel.max() <= theory.softmax_rel_bound_paper(dmax) + 1e-12


@given(st.floats(-20, 20), st.floats(1e-9, 0.2), st.integers(0, 1000))
def test_tanh_paper_factor_holds(x, rel_err, seed):
    """tanh(x(1+e)) vs tanh(x): relative error ≤ 2.63·|e| while |e| ≤ 1/4."""
    assume(abs(x) > 1e-6)
    xp = x * (1 + rel_err)
    t, tp = np.tanh(x), np.tanh(xp)
    if t != 0:
        measured = abs(tp - t) / abs(t)
        assert measured <= theory.TANH_REL_FACTOR * rel_err + 1e-12


def test_margins():
    assert np.isclose(theory.abs_margin(0.6), 0.1)
    assert np.isclose(theory.rel_margin(0.6), 0.2 / 2.2)
    chk = theory.paper_example_check()
    assert chk["nu_gt_0_0909"] and chk["tol_gt_1_65e_2"]
    # paper: ν > 2^-3.45 — i.e. about 3.45 valid bits suffice
    assert 3.3 < chk["nu_bits"] < 3.5


def test_engine_softmax_no_looser_than_paper_blowup():
    """Our rigorous softmax rule should not exceed ~the paper's 5.5 factor
    in the small-error regime (it is usually tighter)."""
    import jax.numpy as jnp
    from repro.core import caa, interval as iv

    cfg = caa.CaaConfig(u_max=2**-20)
    x = np.linspace(-2, 2, 8)
    a = caa.CaaTensor(jnp.asarray(x), iv.point(jnp.asarray(x)),
                      jnp.full(8, 100.0), jnp.full(8, np.inf))
    out = caa.softmax(a, -1, cfg)
    d_in = 100.0
    # own roundings add a small constant; allow paper factor + 10 units
    assert float(jnp.max(out.ebar)) <= 5.5 * (2 * d_in) + 50
