"""Certificate-aware flash decode: kernel/oracle parity + properties.

Three layers of guarantee, matching the serving engine's contract:

- the uncertified Pallas kernel matches the naive masked-attention oracle
  (ragged lengths, page-boundary lengths) to fp tolerance;
- the certified kernel (scalar-prefetched (k, emax, emin), q/k/v tiles
  quantized in-register) is BITWISE its eager mirror
  ``flash_decode_quantized_ref`` at a single S block — the mirror is what
  the serving backends run off-TPU, so the engine's bit-for-bit claim
  covers the kernel path;
- one jit compilation serves every certified format (the traced-triple
  no-recompile property the scalar prefetch exists for).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st
from repro.kernels import ref
from repro.kernels.flash_decode import (
    certified_decode_attention,
    flash_decode_attention,
    flash_decode_certified,
    flash_decode_quantized_ref,
)

FMT = (8, 15, -14)


def _qkv(rng, B, S, K, G, D):
    q = jnp.asarray(rng.randn(B, K, G, D).astype(np.float32))
    k = jnp.asarray(rng.randn(B, S, K, D).astype(np.float32))
    v = jnp.asarray(rng.randn(B, S, K, D).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("lengths", [(1, 7), (16, 3), (32, 32), (31, 1)])
def test_flash_decode_ragged_lengths_vs_naive(lengths):
    B, S, K, G, D = len(lengths), 32, 2, 2, 16
    rng = np.random.RandomState(sum(lengths))
    q, k, v = _qkv(rng, B, S, K, G, D)
    ln = jnp.asarray(lengths, jnp.int32)
    out = flash_decode_attention(q, k, v, ln, block_s=8, interpret=True)
    want = ref.flash_decode_ref(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("length", [8, 9, 15, 16, 17, 24])
def test_flash_decode_page_boundary_lengths(length):
    """Lengths on/either side of a block (page) edge: the masked tail of a
    partially-filled block and fully-masked trailing blocks both behave."""
    B, S, K, G, D = 1, 32, 1, 4, 16
    rng = np.random.RandomState(length)
    q, k, v = _qkv(rng, B, S, K, G, D)
    ln = jnp.asarray([length], jnp.int32)
    out = flash_decode_attention(q, k, v, ln, block_s=8, interpret=True)
    want = ref.flash_decode_ref(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(1, 24), st.integers(0, 10 ** 6))
def test_property_flash_decode_monotone_length_masking(length, seed):
    """Growing the valid length only ADDS attended positions: the output at
    length L equals the naive reference computed on the first L positions
    alone — junk beyond the length can never leak in. This is the property
    lane recycling relies on (stale cache contents behind a recycled lane's
    shorter length are unreachable)."""
    B, S, K, G, D = 1, 24, 2, 1, 8
    rng = np.random.RandomState(seed % 2 ** 31)
    q, k, v = _qkv(rng, B, S, K, G, D)
    # poison everything beyond `length` with huge junk; if masking ever
    # admitted position >= length the output would blow up
    pos = np.arange(S)[None, :, None, None]
    kj = jnp.where(pos < length, k, 1e9)
    vj = jnp.where(pos < length, v, -1e9)
    ln = jnp.asarray([length], jnp.int32)
    out = flash_decode_attention(q, kj, vj, ln, block_s=8, interpret=True)
    want = ref.flash_decode_ref(q[:, :, :, :], k[:, :length], v[:, :length],
                                ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("fmt", [(8, 15, -14), (4, 8, -6), (11, 30, -30)])
def test_certified_kernel_bitwise_vs_eager_mirror(fmt):
    """Single S block ⇒ the Pallas certified kernel and the eager mirror
    share every op and its order — bitwise equal, interpret mode."""
    B, S, K, G, D = 2, 16, 2, 2, 8
    rng = np.random.RandomState(fmt[0])
    q, k, v = _qkv(rng, B, S, K, G, D)
    ln = jnp.asarray([5, 16], jnp.int32)
    f = jnp.asarray(fmt, jnp.int32)
    ker = flash_decode_certified(q, k, v, ln, f, block_s=S, interpret=True)
    mirror = flash_decode_quantized_ref(q, k, v, ln, f)
    assert bool(jnp.array_equal(ker, mirror))


def test_certified_kernel_multiblock_close_to_mirror():
    """Across S blocks the online-softmax rescale order differs from the
    one-shot mirror — allclose, not bitwise (the serving path never mixes
    the two: TPU runs the kernel end-to-end, CPU runs the mirror)."""
    B, S, K, G, D = 2, 32, 2, 2, 8
    rng = np.random.RandomState(0)
    q, k, v = _qkv(rng, B, S, K, G, D)
    ln = jnp.asarray([9, 32], jnp.int32)
    f = jnp.asarray(FMT, jnp.int32)
    ker = flash_decode_certified(q, k, v, ln, f, block_s=8, interpret=True)
    mirror = flash_decode_quantized_ref(q, k, v, ln, f)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(mirror),
                               rtol=2e-5, atol=2e-5)


def test_certified_decode_dispatch_cpu_is_mirror():
    """Off-TPU the dispatcher must return exactly the eager mirror."""
    B, S, K, G, D = 2, 16, 2, 2, 8
    rng = np.random.RandomState(3)
    q, k, v = _qkv(rng, B, S, K, G, D)
    ln = jnp.asarray([7, 12], jnp.int32)
    f = jnp.asarray(FMT, jnp.int32)
    out = certified_decode_attention(q, k, v, ln, f)
    assert bool(jnp.array_equal(out, flash_decode_quantized_ref(q, k, v,
                                                                ln, f)))


def test_certified_decode_compiles_once_across_formats():
    """The (k, emax, emin) triple is DATA (scalar-prefetched on TPU, traced
    through quantize_to_format off-TPU): one compilation serves every
    certified format. This is the serving engine's compile-cost contract —
    swapping certificates costs zero recompiles."""
    B, S, K, G, D = 2, 16, 2, 2, 8
    rng = np.random.RandomState(1)
    q, k, v = _qkv(rng, B, S, K, G, D)
    ln = jnp.asarray([5, 16], jnp.int32)
    f = jax.jit(lambda q, k, v, ln, fmt: certified_decode_attention(
        q, k, v, ln, fmt))
    for fmt in [(8, 15, -14), (4, 8, -6), (11, 30, -30), (23, 127, -126)]:
        got = f(q, k, v, ln, jnp.asarray(fmt, jnp.int32))
        want = flash_decode_quantized_ref(q, k, v, ln,
                                          jnp.asarray(fmt, jnp.int32))
        assert bool(jnp.array_equal(got, want)), fmt
    assert f._cache_size() == 1


def test_certified_lengths_saturate_probs():
    """Fully-masked rows cannot produce NaNs: every lane has length ≥ 1 in
    serving (prefill inserts before the first decode), and the kernel's
    masked positions contribute exact zeros."""
    B, S, K, G, D = 1, 16, 1, 1, 8
    rng = np.random.RandomState(2)
    q, k, v = _qkv(rng, B, S, K, G, D)
    f = jnp.asarray(FMT, jnp.int32)
    out = flash_decode_quantized_ref(q, k, v, jnp.asarray([1], jnp.int32), f)
    assert bool(jnp.all(jnp.isfinite(out)))
