"""The paper's three experiment models under analysis (Table-I semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analyze, caa, precision
from repro.core.backend import CaaOps, JOps
from repro.models import paper_models as PM


def test_digits_param_count_near_paper():
    params = PM.init_digits(jax.random.PRNGKey(0))
    n = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    assert 0.6e6 < n < 0.8e6  # paper: ≈0.7M


def test_digits_analysis_table1_semantics():
    """Emulated k=8 run: actual error must be rigorously enclosed and in the
    paper's magnitude range (order ~1u on probabilities)."""
    key = jax.random.PRNGKey(0)
    params = PM.init_digits(key, h1=128, h2=64)
    rng = np.random.RandomState(0)
    x = (rng.rand(784) * (rng.rand(784) > 0.7)).astype(np.float64)
    cfg = caa.CaaConfig(u_max=2**-7, emulate_k=8)
    bk = CaaOps(cfg)
    probs = PM.digits_forward(bk, params, caa.weight(x, cfg))
    a_abs, a_rel = caa.actual_error_in_u(probs, 2**-7)
    assert bool(jnp.isfinite(a_abs).all())
    assert float(jnp.max(a_abs)) < 50.0          # paper digits: 1.1u
    # soundness vs an independent f64 reference OF THE STORED MODEL —
    # weights are exact *as quantised into the target format* (paper default)
    from repro.core import quantize
    params_q = jax.tree_util.tree_map(
        lambda p: np.asarray(quantize.quantize(np.asarray(p, np.float64), 8)),
        params)
    b64 = JOps(jnp.float64, jnp.float64)
    ref = PM.digits_forward(b64, params_q, jnp.asarray(
        np.asarray(quantize.quantize(x, 8), np.float64)))
    err = jnp.abs(probs.val - ref) / 2**-7
    assert bool(jnp.all(err <= a_abs + 1e-9))


def test_digits_required_k_pipeline():
    key = jax.random.PRNGKey(1)
    params = PM.init_digits(key, h1=64, h2=32)
    rng = np.random.RandomState(1)
    x = (rng.rand(784) * (rng.rand(784) > 0.7)).astype(np.float64)

    def bounds_at(u):
        import math
        cfg = caa.CaaConfig(u_max=u)
        bk = CaaOps(cfg)
        out = PM.digits_forward(bk, params, caa.weight(x, cfg))
        return caa.worst(out)

    d = precision.decide_iterative(bounds_at, p_star=0.6)
    assert 2 <= d.required_k <= 53
    # sanity: bound at the chosen k satisfies a margin
    u = 2.0 ** (1 - d.required_k)
    assert (d.final_abs_bound_u * u <= d.abs_margin
            or d.final_rel_bound_u * u <= d.rel_margin)


def test_pendulum_no_relative_bound():
    """Paper: 'A relative error bound does not exist since the output
    interval contains zero' — with interval inputs covering [-6,6]²."""
    key = jax.random.PRNGKey(2)
    params = PM.init_pendulum(key, h=32)
    cfg = caa.CaaConfig(u_max=2**-7)
    bk = CaaOps(cfg)
    x = caa.from_range(np.full(2, -6.0), np.full(2, 6.0))
    out = PM.pendulum_forward(bk, params, x)
    d, e = caa.worst(out)
    assert np.isfinite(d)           # absolute bound exists (paper: 1.7u)
    assert not np.isfinite(e)       # relative bound does not
    assert float(out.exact.lo[0]) < 0 < float(out.exact.hi[0])


def test_pendulum_point_input_fast_and_tight():
    key = jax.random.PRNGKey(2)
    params = PM.init_pendulum(key, h=32)
    cfg = caa.CaaConfig(u_max=2**-7, emulate_k=8)
    bk = CaaOps(cfg)
    out = PM.pendulum_forward(bk, params, caa.weight(np.asarray([1.0, -2.0]), cfg))
    a_abs, _ = caa.actual_error_in_u(out, 2**-7)
    assert float(jnp.max(a_abs)) < 10.0   # paper: 1.7u


@pytest.mark.slow
def test_convnet_analysis_runs():
    key = jax.random.PRNGKey(3)
    params = PM.init_convnet(key, img=12, c1=4, c2=8)
    rng = np.random.RandomState(3)
    x = rng.rand(1, 12, 12, 1).astype(np.float64)
    cfg = caa.CaaConfig(u_max=2**-7, emulate_k=8)
    bk = CaaOps(cfg)
    probs = PM.convnet_forward(bk, params, caa.weight(x, cfg))
    a_abs, _ = caa.actual_error_in_u(probs, 2**-7)
    assert bool(jnp.isfinite(a_abs).all())
    assert float(jnp.max(a_abs)) < 100.0
    # value path agrees with plain inference up to emulation error
    ref = PM.convnet_forward(JOps(jnp.float64, jnp.float64), params,
                             jnp.asarray(x))
    assert np.allclose(np.asarray(probs.val), np.asarray(ref), atol=0.05)


def test_analyze_driver_and_report():
    key = jax.random.PRNGKey(4)
    params = PM.init_digits(key, h1=32, h2=16)
    rng = np.random.RandomState(4)
    x = caa.weight((rng.rand(784) > 0.7) * rng.rand(784),
                   caa.CaaConfig(u_max=2**-9))
    rep = analyze.analyze(lambda bk, p, xx: PM.digits_forward(bk, p, xx),
                          params, x, p_star=0.55,
                          cfg=caa.CaaConfig(u_max=2**-9))
    assert rep.decision is None or rep.decision.required_k >= 1
    assert len(rep.layers) >= 4
    assert rep.analysis_seconds < 60
    dom = rep.dominant_layer()
    assert dom is not None


def test_sensitivity_attribution():
    key = jax.random.PRNGKey(5)
    params = PM.init_digits(key, h1=32, h2=16)
    rng = np.random.RandomState(5)
    cfg = caa.CaaConfig(u_max=2**-9)
    x = caa.weight((rng.rand(784) > 0.7) * rng.rand(784), cfg)
    fwd = lambda bk, p, xx: PM.digits_forward(bk, p, xx)
    full = analyze.analyze(fwd, params, x, cfg=cfg)
    sens = analyze.sensitivity(fwd, params, x, ["dense1", "dense2"], cfg)
    assert all(v >= 0 for v in sens.values())
    # each single-layer contribution is below the full bound
    assert all(v <= full.final_abs_u * 1.05 for v in sens.values())
