"""Per-architecture smoke tests (assignment requirement): reduced same-family
configs, one forward + one train step on CPU, asserting shapes and no NaNs;
plus a decode step against the cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.backend import JOps
from repro.models import transformer as T


def _batch_kwargs(cfg, B, rng):
    kwargs = {}
    if cfg.frontend == "audio":
        kwargs["enc_embeds"] = rng.randn(B, cfg.frontend_seq,
                                         cfg.frontend_dim).astype(np.float32)
    elif cfg.frontend == "vision":
        kwargs["frontend_embeds"] = rng.randn(B, cfg.frontend_seq,
                                              cfg.frontend_dim).astype(np.float32)
    return kwargs


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_shapes(arch):
    cfg = configs.get(arch).SMOKE
    bk = JOps()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    rng = np.random.RandomState(0)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits, _ = T.forward(bk, params, cfg, tokens, **_batch_kwargs(cfg, B, rng))
    exp_s = S + (cfg.frontend_seq if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get(arch).SMOKE
    bk = JOps()
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    rng = np.random.RandomState(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    targets = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kwargs = _batch_kwargs(cfg, B, rng)
    loss, grads = jax.value_and_grad(
        lambda p: T.next_token_loss(bk, p, cfg, tokens, targets, **kwargs)
    )(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all())
               for g in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get(arch).SMOKE
    bk = JOps()
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    B, Smax = 2, 32
    rng = np.random.RandomState(2)
    kwargs = _batch_kwargs(cfg, B, rng)
    cache = T.init_cache(cfg, B, Smax, jnp.float32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    for pos in range(3):
        logits, cache = T.forward(bk, params, cfg, tok, cache=cache,
                                  q_offset=pos, **kwargs)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1:, :], axis=-1)


def test_decode_matches_full_forward_dense():
    """Step-by-step decode must agree with the full forward (teacher-forced)."""
    cfg = configs.get("qwen2_7b").SMOKE
    bk = JOps()
    key = jax.random.PRNGKey(3)
    params = T.init_params(key, cfg)
    B, S = 1, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = T.forward(bk, params, cfg, tokens)
    cache = T.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for i in range(S):
        logits, cache = T.forward(bk, params, cfg, tokens[:, i:i + 1],
                                  cache=cache, q_offset=i)
        outs.append(logits[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_decode_matches_full_forward_rwkv():
    cfg = configs.get("rwkv6_1p6b").SMOKE
    bk = JOps()
    key = jax.random.PRNGKey(4)
    params = T.init_params(key, cfg)
    B, S = 1, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = T.forward(bk, params, cfg, tokens)
    cache = T.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for i in range(S):
        logits, cache = T.forward(bk, params, cfg, tokens[:, i:i + 1],
                                  cache=cache, q_offset=i)
        outs.append(logits[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=5e-3, atol=5e-3)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyper-parameters."""
    c = configs.get("mixtral_8x22b").FULL
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (56, 6144, 48, 8)
    assert (c.d_ff, c.vocab, c.n_experts, c.top_k) == (16384, 32768, 8, 2)
    c = configs.get("llama4_maverick").FULL
    assert (c.n_layers, c.d_model, c.vocab, c.n_experts, c.top_k) == (
        48, 5120, 202048, 128, 1)
    c = configs.get("qwen2_7b").FULL
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (28, 3584, 28, 4, 18944, 152064, True)
    c = configs.get("gemma2_27b").FULL
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (46, 4608, 36864, 256000)
    assert c.softcap_attn == 50.0 and c.softcap_final == 30.0
    c = configs.get("command_r_35b").FULL
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (40, 8192, 64, 22528)
    c = configs.get("minicpm3_4b").FULL
    assert c.mla and (c.n_layers, c.d_model, c.d_ff, c.vocab) == (
        62, 2560, 6400, 73448)
    c = configs.get("rwkv6_1p6b").FULL
    assert c.rwkv and (c.n_layers, c.d_model, c.d_ff, c.vocab) == (
        24, 2048, 7168, 65536)
    c = configs.get("hymba_1p5b").FULL
    assert c.hybrid and (c.n_layers, c.d_model, c.d_ff, c.vocab,
                         c.ssm_state) == (32, 1600, 5504, 32001, 16)
    c = configs.get("whisper_medium").FULL
    assert c.enc_dec and (c.n_layers, c.n_enc_layers, c.d_model,
                          c.d_ff) == (24, 24, 1024, 4096)
    c = configs.get("paligemma_3b").FULL
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (18, 2048, 8, 1, 16384, 257216)
