"""repro.certify: schema round-trip, store semantics, pipeline behaviour.

Covers the subsystem contract: certificates survive JSON (including ±inf
bounds), the store is content-addressed with params-digest invalidation
and an LRU hot path, the pipeline's batched bounds agree with sequential
analysis, and the jit reverifier agrees with the eager per-input check.
"""
import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import certify
from repro.core import analyze, caa
from repro.core.caa import CaaConfig
from repro.models import paper_models as PM


# ---------------------------------------------------------------------------
# fixtures: a tiny MLP certified once per module
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mlp():
    params = PM.init_digits(jax.random.PRNGKey(0), d_in=10, h1=12, h2=8,
                            n_classes=3)
    rng = np.random.RandomState(1)
    los = [rng.rand(10) * 0.3 for _ in range(3)]
    his = [lo + 0.04 for lo in los]
    return params, los, his


@pytest.fixture(scope="module")
def certified(mlp, tmp_path_factory):
    params, los, his = mlp
    store = certify.CertificateStore(str(tmp_path_factory.mktemp("certs")))
    cs = certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                         model_id="test/mlp", store=store)
    return params, los, his, store, cs


# ---------------------------------------------------------------------------
# spec: JSON round-trip
# ---------------------------------------------------------------------------

def _mk_cert(**kw):
    base = dict(
        model_id="m", params_digest="d" * 64, class_key="class0",
        cfg=CaaConfig(u_max=2.0 ** -9, acc_order="pairwise"),
        bounds_u_max=2.0 ** -9, final_abs_u=12.5, final_rel_u=float("inf"),
        required_k=10, satisfied_by=["binary32", "binary64"],
        trace_summary=[{"name": "dense1", "kind": "layer", "shape": [4],
                        "out_mag": 1.0, "max_dbar": float("inf"),
                        "max_ebar": 3.0}],
        p_star=0.6, meta={"note": "x"},
    )
    base.update(kw)
    return certify.Certificate(**base)


def test_certificate_json_roundtrip_with_inf():
    c = _mk_cert()
    c2 = certify.Certificate.from_json(c.to_json())
    assert c2 == c
    assert np.isinf(c2.final_rel_u)
    assert c2.cfg == c.cfg  # CaaConfig survives including acc_order
    assert np.isinf(c2.trace_summary[0]["max_dbar"])


def test_certificate_set_json_roundtrip():
    cs = certify.CertificateSet(
        model_id="m", params_digest="d" * 64,
        certificates=[_mk_cert(class_key=f"class{i}", required_k=8 + i)
                      for i in range(3)],
        p_star=0.6, meta={"analysis_seconds": 1.25},
    )
    cs2 = certify.CertificateSet.from_json(cs.to_json())
    assert cs2.to_json() == cs.to_json()
    assert cs2.serving_k == 10  # max of per-class required_k
    assert [c.class_key for c in cs2.certificates] == [
        "class0", "class1", "class2"]


def test_uncertifiable_serving_k():
    cs = certify.CertificateSet(
        model_id="m", params_digest="d" * 64,
        certificates=[_mk_cert(required_k=None, satisfied_by=[])])
    assert cs.serving_k is None
    assert cs.error_bars()["k"] is None


# ---------------------------------------------------------------------------
# store: digest, content addressing, LRU, invalidation
# ---------------------------------------------------------------------------

def test_params_digest_sensitive(mlp):
    params, _, _ = mlp
    d1 = certify.params_digest(params)
    assert d1 == certify.params_digest(params)  # deterministic
    bumped = dict(params, w1=params["w1"] + 1e-7)
    assert certify.params_digest(bumped) != d1
    # shape/dtype also matter
    cast = dict(params, w1=np.asarray(params["w1"], np.float32))
    assert certify.params_digest(cast) != certify.params_digest(
        dict(params, w1=np.asarray(params["w1"], np.float64)))


def test_request_key_separates_requests():
    cfg = CaaConfig()
    k1 = certify.request_key("m", "d1", "r", cfg, {"p_star": 0.6})
    assert k1 == certify.request_key("m", "d1", "r", cfg, {"p_star": 0.6})
    assert k1 != certify.request_key("m", "d2", "r", cfg, {"p_star": 0.6})
    assert k1 != certify.request_key("m", "d1", "r", cfg, {"p_star": 0.7})
    assert k1 != certify.request_key(
        "m", "d1", "r", dataclasses.replace(cfg, acc_order="pairwise"),
        {"p_star": 0.6})


def test_store_miss_hit_and_stale_rejection(tmp_path):
    store = certify.CertificateStore(str(tmp_path), lru_size=2)
    cs = certify.CertificateSet(model_id="m", params_digest="live" * 16,
                                certificates=[_mk_cert()])
    assert store.get("k1") is None
    store.put("k1", cs)
    # memory hit, then disk hit from a fresh store instance
    assert store.get("k1") is not None
    assert store.stats.hits_mem == 1
    fresh = certify.CertificateStore(str(tmp_path))
    assert fresh.get("k1") is not None
    assert fresh.stats.hits_disk == 1
    # wrong expected digest must never serve
    assert fresh.get("k1", expect_params_digest="other" * 16) is None
    assert fresh.stats.rejected_stale == 1


def test_store_corrupt_entry_is_a_miss(tmp_path):
    store = certify.CertificateStore(str(tmp_path))
    cs = certify.CertificateSet(model_id="m", params_digest="d" * 64,
                                certificates=[_mk_cert()])
    store.put("k1", cs)
    with open(store.path_for("k1"), "w") as f:
        f.write("{truncated")
    fresh = certify.CertificateStore(str(tmp_path))
    assert fresh.get("k1") is None   # degrade, don't crash
    assert fresh.stats.corrupt == 1
    fresh.put("k1", cs)              # overwrite repairs it
    assert certify.CertificateStore(str(tmp_path)).get("k1") is not None


def test_store_lru_bounded(tmp_path):
    store = certify.CertificateStore(str(tmp_path), lru_size=2)
    cs = certify.CertificateSet(model_id="m", params_digest="d" * 64,
                                certificates=[])
    for i in range(4):
        store.put(f"k{i}", cs)
    assert len(store._lru) == 2
    assert len(store) == 4  # disk keeps everything


def test_store_invalidate_params(tmp_path):
    store = certify.CertificateStore(str(tmp_path))
    a = certify.CertificateSet(model_id="m", params_digest="a" * 64,
                               certificates=[])
    b = certify.CertificateSet(model_id="m", params_digest="b" * 64,
                               certificates=[])
    store.put("ka", a)
    store.put("kb", b)
    assert store.invalidate_params("a" * 64) == 1
    assert store.get("ka") is None
    assert store.get("kb") is not None


# ---------------------------------------------------------------------------
# store: v1→v2 schema migration + concurrent-writer hardening
# ---------------------------------------------------------------------------

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
V1_KEY = "e3" * 32


def _install_v1_fixture(root):
    shutil.copy(os.path.join(FIXTURES, "v1_certificate_set.json"),
                os.path.join(str(root), f"{V1_KEY}.json"))


def test_v1_certificate_still_readable_and_served(tmp_path):
    """Regression: an entry written by PR 1's uniform-k pipeline (checked-in
    fixture, schema_version 1, no layer_k field) must load, expose the same
    serving decision, and serve responses — with layer_k simply absent."""
    _install_v1_fixture(tmp_path)
    store = certify.CertificateStore(str(tmp_path))
    cs = store.get(V1_KEY)
    assert cs is not None
    assert store.stats.read_v1 == 1
    assert cs.serving_k == 12                      # max(10, 12) of the fixture
    assert cs.serving_layer_k is None              # uniform-only certificate
    assert [c.layer_k for c in cs.certificates] == [None, None]
    assert np.isinf(cs.certificates[0].final_rel_u)
    bars = cs.error_bars()
    assert bars["k"] == 12 and "layer_k" not in bars
    # it serves: the response path consumes it like any v2 set
    from repro.launch.serve import make_responses
    resp = make_responses(jnp.zeros((1, 3), jnp.int32), cs)
    assert resp[0]["certificate"]["k"] == 12
    # and digest guarding still applies to legacy entries
    assert store.get(V1_KEY, expect_params_digest="zz" * 32) is None


def test_v1_roundtrip_preserved_after_rewrite(tmp_path):
    """Reading a v1 set and re-putting it writes the CURRENT writer schema
    (absent maps serialised as null) — the upgrade path is lossless."""
    from repro.certify.spec import SCHEMA_VERSION

    _install_v1_fixture(tmp_path)
    store = certify.CertificateStore(str(tmp_path))
    cs = store.get(V1_KEY)
    store.put("newkey", cs)
    back = certify.CertificateStore(str(tmp_path)).get("newkey")
    assert back.to_json() == cs.to_json()
    with open(store.path_for("newkey")) as f:
        assert (json.load(f)["certificate_set"]["schema_version"]
                == SCHEMA_VERSION)


def test_future_schema_rejected_as_miss(tmp_path):
    """An entry from a NEWER writer must degrade to a miss (re-analyse),
    never be half-parsed."""
    store = certify.CertificateStore(str(tmp_path))
    cs = certify.CertificateSet(model_id="m", params_digest="d" * 64,
                                certificates=[_mk_cert()])
    store.put("k9", cs)
    with open(store.path_for("k9")) as f:
        payload = json.load(f)
    payload["certificate_set"]["schema_version"] = 99
    with open(store.path_for("k9"), "w") as f:
        json.dump(payload, f)
    fresh = certify.CertificateStore(str(tmp_path))
    assert fresh.get("k9") is None
    assert fresh.stats.corrupt == 1
    with pytest.raises(ValueError, match="schema v99"):
        certify.CertificateSet.from_dict(payload["certificate_set"])


def test_request_key_separates_schema_and_mixed():
    """The content-key schema bump: v2 keys differ from what the same
    request hashed to under v1, and mixed requests address separately."""
    cfg = CaaConfig()
    k2 = certify.request_key("m", "d", "r", cfg, {"p_star": 0.6})
    # reconstruct the v1 canonicalisation (no schema field)
    import hashlib
    from repro.certify.spec import _cfg_to_dict
    v1_canon = json.dumps(
        {"model_id": "m", "params_digest": "d", "range_key": "r",
         "cfg": _cfg_to_dict(cfg), "target": {"p_star": 0.6}},
        sort_keys=True)
    assert k2 != hashlib.sha256(v1_canon.encode()).hexdigest()
    k_mixed = certify.request_key(
        "m", "d", "r", cfg, {"p_star": 0.6, "mixed": {"scopes": None}})
    assert k_mixed != k2


def test_concurrent_writers_never_corrupt(tmp_path):
    """Two (here: eight) interleaved writers hammering the same key must
    leave every observable state a complete, parseable entry — the atomic
    tmp+fsync+os.replace contract."""
    import threading

    root = str(tmp_path)
    writer_store = [certify.CertificateStore(root) for _ in range(8)]
    sets = [
        certify.CertificateSet(
            model_id=f"m{i}", params_digest=f"{i:02d}" * 32,
            certificates=[_mk_cert(required_k=4 + i)])
        for i in range(8)
    ]
    stop = threading.Event()
    errors = []

    def write(i):
        try:
            for _ in range(40):
                writer_store[i].put("shared", sets[i],
                                    request={"writer": i})
        except Exception as e:          # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    def read():
        reader = certify.CertificateStore(root, lru_size=0)
        seen = 0
        while not stop.is_set() or seen == 0:
            cs = reader.get("shared")
            if cs is not None:
                seen += 1
                # any observed value is one of the writers' complete sets
                assert cs.model_id in {s.model_id for s in sets}
                assert cs.certificates[0].required_k is not None
        assert reader.stats.corrupt == 0

    threads = [threading.Thread(target=write, args=(i,)) for i in range(8)]
    readers = [threading.Thread(target=read) for _ in range(2)]
    for t in readers + threads:
        t.start()
    for t in threads + readers:
        t.join()
    assert not errors
    final = certify.CertificateStore(root).get("shared")
    assert final is not None
    assert len(os.listdir(root)) == 1   # no stranded tmp files


# ---------------------------------------------------------------------------
# pipeline: hit/miss, digest invalidation, bounds agreement
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_certify_persists_then_serves_from_store(certified):
    params, los, his, store, cs = certified
    assert cs.meta["from_store"] is False
    assert len(cs.certificates) == 3
    assert cs.params_digest == certify.params_digest(params)

    cs2 = certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                          model_id="test/mlp", store=store)
    assert cs2.meta["from_store"] is True
    assert cs2.serving_k == cs.serving_k
    assert [c.required_k for c in cs2.certificates] == [
        c.required_k for c in cs.certificates]


def test_store_hit_does_not_mutate_cold_result(certified):
    """The LRU caches the object the cold path returned; marking a later
    hit must not retroactively rewrite the first caller's meta."""
    params, los, his, store, cs = certified
    cs2 = certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                          model_id="test/mlp", store=store)
    assert cs2.meta["from_store"] is True
    assert cs.meta["from_store"] is False  # first caller's view unchanged


def test_certify_keys_on_weights_exact(certified):
    """weights_exact changes the proven semantics → different address,
    never served the other mode's bounds."""
    params, los, his, store, cs = certified
    cs2 = certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                          model_id="test/mlp", store=store,
                          weights_exact=False)
    assert cs2.meta["from_store"] is False
    # the inexact-weights bounds really are different (looser)
    assert cs2.certificates[0].final_abs_u != cs.certificates[0].final_abs_u


def test_certify_validates_class_keys_length(mlp):
    params, los, his = mlp
    with pytest.raises(ValueError, match="class_keys"):
        certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                        model_id="test/mlp", class_keys=["only-one"])


def test_certify_params_change_invalidates(certified):
    params, los, his, store, _ = certified
    tweaked = dict(params, w3=params["w3"] * (1 + 1e-6))
    cs = certify.certify(PM.digits_forward, tweaked, los, his, p_star=0.6,
                         model_id="test/mlp", store=store)
    assert cs.meta["from_store"] is False  # digest differs → re-analysis


def test_certified_bounds_match_sequential_analysis(certified):
    """The acceptance bar: per-class certificate bounds equal the per-class
    sequential analyze() at the same u_max, within f64 slop."""
    params, los, his, _, cs = certified
    for c, cert in enumerate(cs.certificates):
        assert cert.required_k is not None
        cfg = dataclasses.replace(cert.cfg, u_max=cert.bounds_u_max)
        seq = analyze.analyze(PM.digits_forward, params,
                              caa.from_range(los[c], his[c]), cfg=cfg)
        np.testing.assert_allclose(cert.final_abs_u, seq.final_abs_u,
                                   rtol=1e-9)
        np.testing.assert_allclose(cert.final_rel_u, seq.final_rel_u,
                                   rtol=1e-9)
        # and the certified k is genuinely feasible for the p* margins
        from repro.core import theory
        u = 2.0 ** (1 - cert.required_k)
        assert (cert.final_abs_u * u <= theory.abs_margin(0.6)
                or cert.final_rel_u * u <= theory.rel_margin(0.6))


def test_certify_requires_exactly_one_target(mlp):
    params, los, his = mlp
    with pytest.raises(ValueError):
        certify.certify(PM.digits_forward, params, los, his,
                        model_id="test/mlp")
    with pytest.raises(ValueError):
        certify.certify(PM.digits_forward, params, los, his, p_star=0.6,
                        abs_tol=1e-3, model_id="test/mlp")


def test_tolerance_certificate(mlp):
    """Regression-style certificate (pendulum mode): δ̄·u ≤ abs_tol."""
    params, los, his = mlp
    cs = certify.certify(PM.digits_logits, params, los[:1], his[:1],
                         abs_tol=1e-2, model_id="test/mlp-logits")
    cert = cs.certificates[0]
    assert cert.required_k is not None
    u = 2.0 ** (1 - cert.required_k)
    assert cert.final_abs_u * u <= 1e-2


# ---------------------------------------------------------------------------
# serving fast path
# ---------------------------------------------------------------------------

def test_reverifier_agrees_with_eager(mlp):
    params, _, _ = mlp
    verify = certify.make_reverifier(PM.digits_forward, params, 12)
    x = np.random.RandomState(7).rand(4, 10)
    preds, safe = verify(jnp.asarray(x))
    for i in range(4):
        eager = analyze.verify_classification(
            PM.digits_forward, params, caa.make(x[i]), 12, int(preds[i]))
        assert bool(safe[i]) == eager


# ---------------------------------------------------------------------------
# store GC: age/count eviction with recency refreshed by reads
# ---------------------------------------------------------------------------

def _put_n(store, n, prefix="gc"):
    for i in range(n):
        store.put(f"{prefix}{i}", _mk_set(certify.Certificate(
            model_id="m", params_digest="d" * 64, class_key=f"c{i}",
            cfg=CaaConfig(), bounds_u_max=2.0 ** -9, final_abs_u=1.0,
            final_rel_u=1.0, required_k=10, satisfied_by=[])))


def _mk_set(cert):
    return certify.CertificateSet(
        model_id=cert.model_id, params_digest=cert.params_digest,
        certificates=[cert])


def _age(store, key, days):
    import time
    past = time.time() - days * 86400.0
    os.utime(store.path_for(key), (past, past))


def test_gc_by_age_evicts_only_stale(tmp_path):
    store = certify.CertificateStore(str(tmp_path))
    _put_n(store, 4)
    _age(store, "gc0", days=10)
    _age(store, "gc1", days=10)
    n = store.gc(max_age_days=7)
    assert n == 2
    assert store.stats.evicted == 2
    assert store.get("gc0") is None          # evicted from disk AND the LRU
    assert store.get("gc2") is not None
    assert len(store) == 2


def test_gc_by_count_evicts_oldest_unused(tmp_path):
    store = certify.CertificateStore(str(tmp_path))
    _put_n(store, 5)
    for i, key in enumerate(["gc0", "gc1", "gc2", "gc3", "gc4"]):
        _age(store, key, days=5 - i)         # gc0 oldest ... gc4 newest
    # a disk read refreshes recency: touch gc0 so it survives the cut
    store._lru.clear()
    assert store.get("gc0") is not None
    n = store.gc(max_entries=2)
    assert n == 3
    assert sorted(store.keys()) == ["gc0", "gc4"]
    assert store.stats.evicted == 3


def test_gc_combined_age_then_count(tmp_path):
    store = certify.CertificateStore(str(tmp_path))
    _put_n(store, 6)
    for i in range(6):
        _age(store, f"gc{i}", days=20 - 2 * i)   # gc0..gc2 beyond 15 days
    n = store.gc(max_age_days=15, max_entries=2)
    assert n == 4                            # 3 stale + 1 excess
    assert len(store) == 2
    assert sorted(store.keys()) == ["gc4", "gc5"]


def test_gc_noop_when_within_budget(tmp_path):
    store = certify.CertificateStore(str(tmp_path))
    _put_n(store, 3)
    assert store.gc(max_age_days=30, max_entries=10) == 0
    assert store.stats.evicted == 0
    assert len(store) == 3


def test_gc_then_get_is_clean_miss_and_recertify(tmp_path):
    """After eviction the address is a plain miss; a re-put re-creates it
    atomically (no torn state observable)."""
    store = certify.CertificateStore(str(tmp_path))
    _put_n(store, 1)
    assert store.gc(max_entries=0) == 1
    assert store.get("gc0") is None
    assert store.stats.misses >= 1
    _put_n(store, 1)
    assert store.get("gc0") is not None
