"""Analyser driver behaviour: batched == sequential, and scope gating.

The batched entry point must reproduce the paper's per-class runs exactly
(bit-identical bounds — the stacked pass IS the same arithmetic), and the
sensitivity gating must match layer scopes by path segment, not substring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analyze, caa
from repro.core.analyze import _scope_active
from repro.core.backend import CaaOps
from repro.models import paper_models as PM


@pytest.fixture(scope="module")
def small_mlp():
    params = PM.init_digits(jax.random.PRNGKey(0), d_in=12, h1=16, h2=8,
                            n_classes=4)
    rng = np.random.RandomState(0)
    lo = rng.rand(4, 12) * 0.3
    hi = lo + 0.05
    return params, lo, hi


def test_batched_matches_sequential(small_mlp):
    """One class-stacked pass must give the same per-class δ̄/ε̄ as the
    paper's one-run-per-class loop (within documented f64 slop: the ops are
    identical up to jnp reduction order, so the tolerance is tiny)."""
    params, lo, hi = small_mlp
    cfg = caa.CaaConfig(u_max=2.0 ** -10)

    rep = analyze.analyze_batched(
        PM.digits_forward, params, caa.from_range(lo, hi), cfg=cfg)
    assert rep.n_classes == 4

    for c in range(4):
        seq = analyze.analyze(PM.digits_forward, params,
                              caa.from_range(lo[c], hi[c]), cfg=cfg)
        b_abs, b_rel = rep.per_class(c)
        assert np.isfinite(seq.final_abs_u)
        np.testing.assert_allclose(b_abs, seq.final_abs_u, rtol=1e-9)
        np.testing.assert_allclose(b_rel, seq.final_rel_u, rtol=1e-9)
        # output enclosures agree too
        np.testing.assert_allclose(np.asarray(rep.output_range[0])[c],
                                   np.asarray(seq.output_range[0]), rtol=1e-12)


def test_batched_decisions(small_mlp):
    params, lo, hi = small_mlp
    rep = analyze.analyze_batched(
        PM.digits_forward, params, caa.from_range(lo, hi),
        p_star=0.6, cfg=caa.CaaConfig(u_max=2.0 ** -10))
    assert rep.decisions is not None and len(rep.decisions) == 4
    for c, dec in enumerate(rep.decisions):
        if dec is not None:
            seq = analyze.analyze(PM.digits_forward, params,
                                  caa.from_range(lo[c], hi[c]),
                                  p_star=0.6, cfg=caa.CaaConfig(u_max=2.0 ** -10))
            assert dec.required_k == seq.decision.required_k


def test_batch_config_scales_trajectory_gate():
    cfg = caa.CaaConfig()
    bcfg = analyze.batch_config(cfg, 7)
    assert bcfg.traj_max_elems == 7 * cfg.traj_max_elems
    assert bcfg.u_max == cfg.u_max


# ---------------------------------------------------------------------------
# sensitivity scope gating: segments, not substrings
# ---------------------------------------------------------------------------

def test_scope_active_matches_segments():
    assert _scope_active("block1", ["block1"])
    assert _scope_active("block1", ["outer", "block1", "inner"])
    assert _scope_active("a/b", ["x", "a", "b"])
    # the regression: 'block1' is a substring of 'block10' but NOT a segment
    assert not _scope_active("block1", ["block10"])
    assert not _scope_active("block1", ["outer", "block12"])
    assert not _scope_active("lock1", ["block1"])


def test_gated_ops_state_by_segment():
    """The gate itself: inside scope 'block10', probe 'block1' must stay
    OFF (round_scale 0) — the substring bug turned it on."""
    cfg = caa.CaaConfig()
    ops = analyze._GatedCaaOps(cfg, "block1")
    assert ops.cfg.round_scale == 0.0
    with ops.scope("block10"):
        assert ops.cfg.round_scale == 0.0
    with ops.scope("block1"):
        assert ops.cfg.round_scale == cfg.round_scale
        with ops.scope("inner"):
            assert ops.cfg.round_scale == cfg.round_scale
    assert ops.cfg.round_scale == 0.0


def test_sensitivity_block1_not_charged_for_block10():
    """End to end: a network whose only layer lives in scope 'block10' must
    attribute zero to probe 'block1' — with the substring bug, 'block1'
    activated inside 'block10' and collected its full roundings."""
    w2 = jax.random.normal(jax.random.PRNGKey(3), (6, 6))
    params = {"w2": w2}

    def fwd(bk, p, x):
        with bk.scope("block10"):
            x = bk.matmul(x, bk.param(p["w2"]))
        return x

    x = caa.from_range(np.full(6, -1.0), np.full(6, 1.0))
    sens = analyze.sensitivity(fwd, params, x, ["block1", "block10"])
    assert sens["block10"] > 0.0
    assert sens["block1"] == 0.0, sens
