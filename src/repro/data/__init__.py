"""data subsystem."""
