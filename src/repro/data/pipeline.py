"""Data pipeline: deterministic synthetic token streams, host-sharded.

Production shape: each host generates only its slice of the global batch
(``host_slice``), so input feeding scales to thousands of nodes without a
central reader; determinism comes from counter-based stateless RNG
(threefry on (step, host)) so restarts and elastic re-sharding reproduce
the same stream — the property checkpoint/restart tests rely on.

For the paper's experiments the same interface serves image-like inputs
(digits/convnet) from procedural generators (data/synthetic_digits.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def host_slice(cfg: DataConfig) -> Tuple[int, int]:
    per = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * per, per


def batch_at(cfg: DataConfig, step: int) -> dict:
    """The (host-local slice of the) batch for a given step — stateless."""
    start, per = host_slice(cfg)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.host_id
    )
    # Zipf-ish marginal over the vocab — more LM-like than uniform, cheap:
    u = jax.random.uniform(key, (per, cfg.seq + 1), minval=1e-6, maxval=1.0)
    alpha = 1.1
    ranks = jnp.floor(cfg.vocab * u ** alpha).astype(jnp.int32)
    toks = jnp.clip(ranks, 0, cfg.vocab - 1)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
