"""Procedural digit dataset (MNIST stand-in, fully offline).

Renders 28×28 glyphs for digits 0-9 from stroke templates with random
affine jitter + noise — enough signal to train the paper's Digits model to
high accuracy so its Table-I analysis runs against a *real* trained
classifier with meaningful top-1 margins p*.
"""
from __future__ import annotations

import numpy as np

_SEGS = {
    # 7-segment-ish stroke templates on a 28x28 canvas: (x0,y0,x1,y1) lines
    0: [(7, 4, 20, 4), (7, 23, 20, 23), (6, 5, 6, 22), (21, 5, 21, 22)],
    1: [(14, 4, 14, 23), (10, 7, 14, 4)],
    2: [(7, 4, 20, 4), (21, 5, 21, 13), (7, 14, 20, 14), (6, 15, 6, 22), (7, 23, 20, 23)],
    3: [(7, 4, 20, 4), (21, 5, 21, 13), (10, 14, 20, 14), (21, 15, 21, 22), (7, 23, 20, 23)],
    4: [(6, 4, 6, 13), (7, 14, 20, 14), (21, 4, 21, 23)],
    5: [(7, 4, 21, 4), (6, 5, 6, 13), (7, 14, 20, 14), (21, 15, 21, 22), (6, 23, 20, 23)],
    6: [(7, 4, 20, 4), (6, 5, 6, 22), (7, 14, 20, 14), (21, 15, 21, 22), (7, 23, 20, 23)],
    7: [(6, 4, 21, 4), (21, 5, 21, 23)],
    8: [(7, 4, 20, 4), (6, 5, 6, 22), (21, 5, 21, 22), (7, 14, 20, 14), (7, 23, 20, 23)],
    9: [(7, 4, 20, 4), (6, 5, 6, 13), (21, 5, 21, 22), (7, 14, 20, 14), (7, 23, 20, 23)],
}


def _draw(digit: int, rng: np.random.RandomState) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    dx, dy = rng.randint(-2, 3), rng.randint(-2, 3)
    sx, sy = 1.0 + 0.12 * rng.randn(), 1.0 + 0.12 * rng.randn()
    for (x0, y0, x1, y1) in _SEGS[digit]:
        n = 40
        xs = np.linspace(x0, x1, n) * sx + dx
        ys = np.linspace(y0, y1, n) * sy + dy
        for x, y in zip(xs, ys):
            xi, yi = int(round(x)), int(round(y))
            for ox in (-1, 0, 1):
                for oy in (-1, 0, 1):
                    xj, yj = xi + ox, yi + oy
                    if 0 <= xj < 28 and 0 <= yj < 28:
                        w = 1.0 if (ox == 0 and oy == 0) else 0.45
                        img[yj, xj] = max(img[yj, xj], w)
    img += 0.08 * rng.rand(28, 28).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_dataset(n: int, seed: int = 0):
    """Returns (images [n,784] in [0,1], labels [n])."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, size=n)
    imgs = np.stack([_draw(int(d), rng).reshape(-1) for d in labels])
    return imgs.astype(np.float32), labels.astype(np.int32)
