"""CLI: render or validate JSONL traces.

  python -m repro.obs report trace.jsonl [--no-scopes]
  python -m repro.obs validate trace.jsonl

``report`` prints the per-stage/per-scope summary table; ``validate``
checks the schema (exit 1 on an empty or invalid trace — the CI smoke's
assertion).
"""
from __future__ import annotations

import argparse
import sys

from . import report as R
from . import trace as T


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = p.add_subparsers(dest="cmd", required=True)

    pr = sub.add_parser("report", help="render a trace into summary tables")
    pr.add_argument("trace", help="JSONL trace file")
    pr.add_argument("--no-scopes", action="store_true",
                    help="suppress per-scope sub-rows")

    pv = sub.add_parser("validate", help="schema-check a trace (CI gate)")
    pv.add_argument("trace", help="JSONL trace file")

    args = p.parse_args(argv)
    try:
        events = T.load_events(args.trace)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1

    if args.cmd == "validate":
        errors = T.validate_events(events)
        if errors:
            for e in errors[:20]:
                print(f"invalid: {e}", file=sys.stderr)
            return 1
        n_spans = sum(1 for ev in events if ev.get("type") == "span")
        print(f"ok: {len(events)} events ({n_spans} spans) schema-valid")
        return 0

    try:
        print(R.render(events, per_scope=not args.no_scopes))
    except BrokenPipeError:  # report | head — downstream closed, not an error
        sys.stderr.close()
        return 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
