"""CLI: render or validate JSONL traces; kernel trajectory views.

  python -m repro.obs report trace.jsonl [--no-scopes]
  python -m repro.obs report --kernels [--bench-dir DIR]
  python -m repro.obs validate trace.jsonl
  python -m repro.obs perfgate [--threshold 0.25] [--bench-dir DIR]

``report`` prints the per-stage/per-scope summary table (and/or, with
``--kernels``, the measured-kernel roofline table + serving percentile
digest from the ``BENCH_kernels.json`` trajectory); ``validate`` checks
the schema (exit 1 on an empty or invalid trace — the CI smoke's
assertion). ``perfgate`` is the SOFT perf gate: it compares the last two
kernel trajectory entries and prints a ``::warning::`` line per kernel
whose median regressed beyond the threshold — exit 0; timing on shared CI
runners is advisory, not a merge blocker. The opt-in ``--fail-on PCT``
adds a HARD rail on top: regressions beyond that (larger) fraction print
``::error::`` and exit 1.
"""
from __future__ import annotations

import argparse
import sys

from . import bench as B
from . import report as R
from . import trace as T


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = p.add_subparsers(dest="cmd", required=True)

    pr = sub.add_parser("report", help="render a trace into summary tables")
    pr.add_argument("trace", nargs="?", default=None,
                    help="JSONL trace file (optional with --kernels)")
    pr.add_argument("--no-scopes", action="store_true",
                    help="suppress per-scope sub-rows")
    pr.add_argument("--kernels", action="store_true",
                    help="render the measured kernel-bench trajectory "
                         "(BENCH_kernels.json): median latency, achieved "
                         "intensity vs analytic roofline, serving "
                         "p50/p95/p99")
    pr.add_argument("--bench-dir", default=None,
                    help="trajectory directory (default: repo root / "
                         "$REPRO_BENCH_DIR)")

    pv = sub.add_parser("validate", help="schema-check a trace (CI gate)")
    pv.add_argument("trace", help="JSONL trace file")

    pg = sub.add_parser("perfgate",
                        help="warn (never fail) on kernel medians that "
                             "regressed vs the previous trajectory entry")
    pg.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression to warn at (default 0.25)")
    pg.add_argument("--fail-on", type=float, default=None, metavar="PCT",
                    help="opt-in hard gate: exit 1 (with ::error:: "
                         "annotations) when any kernel median regressed "
                         "beyond this fraction (e.g. 1.0 = +100%%); "
                         "regressions between --threshold and --fail-on "
                         "still only warn")
    pg.add_argument("--bench-dir", default=None)
    pg.add_argument("--name", default="kernels",
                    help="trajectory name (BENCH_<name>.json)")

    args = p.parse_args(argv)

    if args.cmd == "perfgate":
        try:
            findings = B.check_regressions(args.name, args.threshold,
                                           args.bench_dir)
        except (OSError, ValueError) as e:
            print(f"perfgate: cannot read trajectory ({e}) — skipping",
                  file=sys.stderr)
            return 0
        if not findings:
            n = len(B.read_bench(args.name, args.bench_dir))
            print(f"perfgate: ok — no kernel median regressed "
                  f">{args.threshold:.0%} ({n} trajectory entries)")
            return 0
        hard = []
        for f in findings:
            # ::warning::/::error:: render as GitHub Actions annotations;
            # plain text everywhere else
            over_rail = (args.fail_on is not None
                         and f["ratio"] > 1.0 + args.fail_on)
            if over_rail:
                hard.append(f)
            level = "error" if over_rail else "warning"
            print(f"::{level}::perf: {f['kernel']} {f.get('shape', '')} "
                  f"k={f.get('k')} median {f['prev_median_s'] * 1e6:.1f}us "
                  f"-> {f['last_median_s'] * 1e6:.1f}us "
                  f"({f['ratio'] - 1.0:+.0%})")
        if hard:
            print(f"perfgate: {len(hard)} kernel point(s) regressed "
                  f">{args.fail_on:.0%} (--fail-on hard gate) — failing")
            return 1
        print(f"perfgate: {len(findings)} kernel point(s) regressed "
              f">{args.threshold:.0%} (soft gate — not failing the build)")
        return 0

    if args.cmd == "report" and args.kernels and args.trace is None:
        try:
            entries = B.read_bench("kernels", args.bench_dir)
        except (OSError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print(R.render_kernel_table(entries))
        return 0

    if args.trace is None:
        print("error: report needs a trace file (or --kernels)",
              file=sys.stderr)
        return 1
    try:
        events = T.load_events(args.trace)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1

    if args.cmd == "validate":
        errors = T.validate_events(events)
        if errors:
            for e in errors[:20]:
                print(f"invalid: {e}", file=sys.stderr)
            return 1
        n_spans = sum(1 for ev in events if ev.get("type") == "span")
        print(f"ok: {len(events)} events ({n_spans} spans) schema-valid")
        return 0

    try:
        print(R.render(events, per_scope=not args.no_scopes))
        if args.kernels:
            entries = B.read_bench("kernels", args.bench_dir)
            print()
            print(R.render_kernel_table(entries))
    except BrokenPipeError:  # report | head — downstream closed, not an error
        sys.stderr.close()
        return 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
