"""Render a JSONL trace into per-stage / per-scope summary tables.

``python -m repro.obs report trace.jsonl`` aggregates span lines by name
(count, total/mean/max wall time, share of the root span), groups
``greedy_descent_step``-style spans by their ``scope`` attribute, and
appends the final counter/gauge aggregates — the profile view the ISSUE's
acceptance criterion reads ladder compile counts and store hit/miss stats
from. ``report --kernels`` additionally renders the measured kernel
trajectory (``BENCH_kernels.json``) as a roofline table — median latency,
achieved intensity vs the analytic term, bound classification, and the
serving p50/p95/p99 digest — via :func:`render_kernel_table`.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional


def _agg_spans(events: Iterable[Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    agg: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("type") != "span":
            continue
        a = agg.setdefault(ev["name"], {
            "count": 0, "total_s": 0.0, "max_s": 0.0, "depth": ev["depth"],
            "scopes": {},
        })
        a["count"] += 1
        a["total_s"] += ev["dur_s"]
        a["max_s"] = max(a["max_s"], ev["dur_s"])
        a["depth"] = min(a["depth"], ev["depth"])
        scope = (ev.get("attrs") or {}).get("scope")
        if scope is not None:
            sc = a["scopes"].setdefault(str(scope),
                                        {"count": 0, "total_s": 0.0})
            sc["count"] += 1
            sc["total_s"] += ev["dur_s"]
    return agg


def _last_values(events: Iterable[Dict[str, Any]], kind: str
                 ) -> Dict[str, Any]:
    """Final aggregate line wins (flush may have run more than once)."""
    out: Dict[str, Any] = {}
    for ev in events:
        if ev.get("type") == kind:
            out = dict(ev.get("values") or {})
    return out


def summarize(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Machine-readable summary (the tests and the bench hook consume it)."""
    spans = _agg_spans(events)
    total = max((a["total_s"] for a in spans.values()
                 if a["depth"] == 0), default=0.0)
    meta = next((ev for ev in events if ev.get("type") == "meta"), {})
    return {
        "program": meta.get("program", ""),
        "argv": meta.get("argv", []),
        "spans": spans,
        "counters": _last_values(events, "counters"),
        "gauges": _last_values(events, "gauges"),
        "root_total_s": total,
        "n_events": len(events),
    }


def render(events: List[Dict[str, Any]], per_scope: bool = True) -> str:
    """Human-readable table over one trace's events."""
    s = summarize(events)
    spans, total = s["spans"], s["root_total_s"]
    lines: List[str] = []
    if s["program"]:
        lines.append(f"trace: {s['program']} {' '.join(s['argv'])}")
    lines.append(f"{'stage':<28} {'count':>6} {'total_s':>10} "
                 f"{'mean_s':>10} {'max_s':>10} {'share':>7}")
    order = sorted(spans.items(),
                   key=lambda kv: (kv[1]["depth"], -kv[1]["total_s"]))
    for name, a in order:
        share = (a["total_s"] / total) if total > 0 else 0.0
        indent = "  " * a["depth"]
        label = (indent + name)[:28]
        lines.append(
            f"{label:<28} {a['count']:>6} {a['total_s']:>10.4f} "
            f"{a['total_s'] / a['count']:>10.4f} {a['max_s']:>10.4f} "
            f"{share:>6.1%}")
        if per_scope and a["scopes"]:
            for scope, sc in sorted(a["scopes"].items(),
                                    key=lambda kv: -kv[1]["total_s"]):
                lab = (indent + "  · " + scope)[:28]
                lines.append(
                    f"{lab:<28} {sc['count']:>6} {sc['total_s']:>10.4f} "
                    f"{sc['total_s'] / sc['count']:>10.4f} {'':>10} {'':>7}")
    if s["counters"]:
        lines.append("")
        lines.append("counters:")
        for k in sorted(s["counters"]):
            lines.append(f"  {k:<40} {s['counters'][k]}")
    if s["gauges"]:
        lines.append("")
        lines.append("gauges:")
        for k in sorted(s["gauges"]):
            lines.append(f"  {k:<40} {s['gauges'][k]:.6g}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# measured-kernel trajectory view (BENCH_kernels.json)
# ---------------------------------------------------------------------------

def _fmt_label(r: Dict[str, Any]) -> str:
    if r.get("emax") is not None:
        return f"k{r['k']}e{r['emax']}"
    if r.get("k") is not None:
        return f"k{r['k']}"
    return "f32"


def _block_label(r: Dict[str, Any]) -> str:
    b = r.get("block")
    if not b:
        return "-"
    return "x".join(str(v) for v in b)


def render_kernel_table(entries: List[Dict[str, Any]],
                        baseline: Optional[Dict[str, Any]] = None) -> str:
    """Roofline table over the LAST kernel-bench trajectory entry, with a
    Δ column against ``baseline`` (default: the previous entry) so a PR's
    perf movement is visible in the same view.

    Columns: measured median, achieved GFLOP/s, achieved intensity
    (flops/byte) vs the analytic roofline time at the modelled hardware
    peaks, the bound classification, and median change vs baseline."""
    if not entries:
        return ("no kernel trajectory yet — run benchmarks/kernel_bench.py "
                "(or python benchmarks/run.py) to record one")
    last = entries[-1]
    if baseline is None and len(entries) >= 2:
        baseline = entries[-2]
    base_rows: Dict[str, Dict[str, Any]] = {}
    if baseline:
        for r in baseline.get("rows", []):
            base_rows[(r.get("kernel"), r.get("shape"), _fmt_label(r),
                       _block_label(r))] = r

    lines = [
        f"kernel bench — backend={last.get('backend', '?')} "
        f"interpret={last.get('interpret', '?')} "
        f"hw={last.get('hardware', '?')} rows={len(last.get('rows', []))}",
        f"{'kernel':<24} {'shape':<14} {'fmt':>7} {'block':>12} "
        f"{'median_us':>10} {'GFLOP/s':>9} {'int.':>7} {'roof_us':>9} "
        f"{'bound':>7} {'Δprev':>7}",
    ]
    for r in last.get("rows", []):
        key = (r.get("kernel"), r.get("shape"), _fmt_label(r),
               _block_label(r))
        prev = base_rows.get(key)
        if prev and prev.get("median_s"):
            delta = f"{(r['median_s'] / prev['median_s'] - 1.0):+.0%}"
        else:
            delta = "-"
        lines.append(
            f"{r.get('kernel', '?'):<24} {r.get('shape', '?'):<14} "
            f"{_fmt_label(r):>7} {_block_label(r):>12} "
            f"{r['median_s'] * 1e6:>10.1f} "
            f"{r.get('achieved_flops_per_s', 0) / 1e9:>9.2f} "
            f"{r.get('intensity', 0):>7.2f} "
            f"{r.get('roofline_s', 0) * 1e6:>9.3f} "
            f"{r.get('bound', '?'):>7} {delta:>7}")
    serving = last.get("serving")
    if serving:
        lines.append("")
        lines.append("serving latency (measured, "
                     f"{serving.get('arch', '?')} SMOKE "
                     f"L={serving.get('n_layers', '?')} "
                     f"B={serving.get('batch', '?')}):")
        pre = serving.get("prefill", {})
        if pre:
            lines.append(
                f"  prefill: {pre.get('latency_s', 0) * 1e3:.1f}ms "
                f"(compile {pre.get('compile_s', 0):.2f}s, "
                f"jaxpr {pre.get('jaxpr_eqns', '?')} eqns)")
        dec = serving.get("decode", {})
        pct = dec.get("percentiles", {})
        if pct:
            lines.append(
                f"  decode:  p50 {pct.get('p50', 0) * 1e3:.1f}ms  "
                f"p95 {pct.get('p95', 0) * 1e3:.1f}ms  "
                f"p99 {pct.get('p99', 0) * 1e3:.1f}ms  "
                f"({dec.get('count', 0)} steps, compile "
                f"{dec.get('compile_s', 0):.2f}s, "
                f"jaxpr {dec.get('jaxpr_eqns', '?')} eqns)")
    return "\n".join(lines)
