"""Render a JSONL trace into per-stage / per-scope summary tables.

``python -m repro.obs report trace.jsonl`` aggregates span lines by name
(count, total/mean/max wall time, share of the root span), groups
``greedy_descent_step``-style spans by their ``scope`` attribute, and
appends the final counter/gauge aggregates — the profile view the ISSUE's
acceptance criterion reads ladder compile counts and store hit/miss stats
from.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List


def _agg_spans(events: Iterable[Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    agg: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("type") != "span":
            continue
        a = agg.setdefault(ev["name"], {
            "count": 0, "total_s": 0.0, "max_s": 0.0, "depth": ev["depth"],
            "scopes": {},
        })
        a["count"] += 1
        a["total_s"] += ev["dur_s"]
        a["max_s"] = max(a["max_s"], ev["dur_s"])
        a["depth"] = min(a["depth"], ev["depth"])
        scope = (ev.get("attrs") or {}).get("scope")
        if scope is not None:
            sc = a["scopes"].setdefault(str(scope),
                                        {"count": 0, "total_s": 0.0})
            sc["count"] += 1
            sc["total_s"] += ev["dur_s"]
    return agg


def _last_values(events: Iterable[Dict[str, Any]], kind: str
                 ) -> Dict[str, Any]:
    """Final aggregate line wins (flush may have run more than once)."""
    out: Dict[str, Any] = {}
    for ev in events:
        if ev.get("type") == kind:
            out = dict(ev.get("values") or {})
    return out


def summarize(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Machine-readable summary (the tests and the bench hook consume it)."""
    spans = _agg_spans(events)
    total = max((a["total_s"] for a in spans.values()
                 if a["depth"] == 0), default=0.0)
    meta = next((ev for ev in events if ev.get("type") == "meta"), {})
    return {
        "program": meta.get("program", ""),
        "argv": meta.get("argv", []),
        "spans": spans,
        "counters": _last_values(events, "counters"),
        "gauges": _last_values(events, "gauges"),
        "root_total_s": total,
        "n_events": len(events),
    }


def render(events: List[Dict[str, Any]], per_scope: bool = True) -> str:
    """Human-readable table over one trace's events."""
    s = summarize(events)
    spans, total = s["spans"], s["root_total_s"]
    lines: List[str] = []
    if s["program"]:
        lines.append(f"trace: {s['program']} {' '.join(s['argv'])}")
    lines.append(f"{'stage':<28} {'count':>6} {'total_s':>10} "
                 f"{'mean_s':>10} {'max_s':>10} {'share':>7}")
    order = sorted(spans.items(),
                   key=lambda kv: (kv[1]["depth"], -kv[1]["total_s"]))
    for name, a in order:
        share = (a["total_s"] / total) if total > 0 else 0.0
        indent = "  " * a["depth"]
        label = (indent + name)[:28]
        lines.append(
            f"{label:<28} {a['count']:>6} {a['total_s']:>10.4f} "
            f"{a['total_s'] / a['count']:>10.4f} {a['max_s']:>10.4f} "
            f"{share:>6.1%}")
        if per_scope and a["scopes"]:
            for scope, sc in sorted(a["scopes"].items(),
                                    key=lambda kv: -kv[1]["total_s"]):
                lab = (indent + "  · " + scope)[:28]
                lines.append(
                    f"{lab:<28} {sc['count']:>6} {sc['total_s']:>10.4f} "
                    f"{sc['total_s'] / sc['count']:>10.4f} {'':>10} {'':>7}")
    if s["counters"]:
        lines.append("")
        lines.append("counters:")
        for k in sorted(s["counters"]):
            lines.append(f"  {k:<40} {s['counters'][k]}")
    if s["gauges"]:
        lines.append("")
        lines.append("gauges:")
        for k in sorted(s["gauges"]):
            lines.append(f"  {k:<40} {s['gauges'][k]:.6g}")
    return "\n".join(lines)
