"""Structured logging with a human-readable default sink.

A thin layer over ``logging`` so library and CLI code emits key=value
structured records instead of bare ``print``. The default sink renders

    [certify] required_k search done k=11 probes=4 (0.82s)

to stderr; when the global tracer is active (``obs.trace.configure``),
every log record is *also* recorded as a trace event, so a single
``--trace out.jsonl`` captures the full narrative alongside spans.

Use :func:`get_logger` (namespaced under ``repro``) and call ``.info``
etc. with a message plus keyword fields::

    log = get_logger("certify")
    log.info("store hit", key=key[:12], schema=3)
"""
from __future__ import annotations

import logging
import sys
from typing import Any, Dict

from . import trace as _trace

_CONFIGURED = False


def _fmt_fields(fields: Dict[str, Any]) -> str:
    if not fields:
        return ""
    parts = []
    for k, v in fields.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.6g}")
        else:
            parts.append(f"{k}={v}")
    return " " + " ".join(parts)


class _Handler(logging.Handler):
    """Renders ``[component] msg k=v`` lines.

    The sink stream is resolved at *emit* time (``sys.stderr`` unless a
    fixed stream was given): the handler is installed once per process —
    often at import, e.g. by a module-level ``get_logger`` — and binding
    the stream then would pin whatever object happened to be installed
    (a test harness's capture, a redirected pipe) for the process
    lifetime."""

    def __init__(self, stream=None):
        super().__init__()
        self._stream = stream

    def format(self, record: logging.LogRecord) -> str:
        name = record.name
        if name.startswith("repro."):
            name = name[len("repro."):]
        fields = getattr(record, "fields", None) or {}
        return f"[{name}] {record.getMessage()}{_fmt_fields(fields)}"

    def emit(self, record: logging.LogRecord):
        try:
            stream = self._stream if self._stream is not None else sys.stderr
            stream.write(self.format(record) + "\n")
            stream.flush()
        except Exception:
            self.handleError(record)


class StructuredLogger:
    """Wraps a stdlib logger; forwards fields to both sink and tracer."""

    def __init__(self, logger: logging.Logger, component: str):
        self._logger = logger
        self._component = component

    def _log(self, level: int, msg: str, fields: Dict[str, Any]):
        self._logger.log(level, msg, extra={"fields": fields})
        _trace.event(f"log.{self._component}", msg=msg,
                     level=logging.getLevelName(level), **fields)

    def debug(self, msg: str, **fields):
        self._log(logging.DEBUG, msg, fields)

    def info(self, msg: str, **fields):
        self._log(logging.INFO, msg, fields)

    def warning(self, msg: str, **fields):
        self._log(logging.WARNING, msg, fields)

    def error(self, msg: str, **fields):
        self._log(logging.ERROR, msg, fields)


def setup(level: int = logging.INFO, stream=None):
    """Install the human-readable handler on the ``repro`` root (once)."""
    global _CONFIGURED
    root = logging.getLogger("repro")
    if not _CONFIGURED:
        handler = _Handler(stream)
        root.addHandler(handler)
        root.propagate = False
        _CONFIGURED = True
    root.setLevel(level)


def get_logger(component: str) -> StructuredLogger:
    """Namespaced structured logger; auto-installs the default sink."""
    setup()
    return StructuredLogger(logging.getLogger(f"repro.{component}"),
                            component)
