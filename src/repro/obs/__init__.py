"""repro.obs — observability for the certification pipeline and serving.

The paper's pitch is *rigorous, a-priori* bounds; this package makes the
system that produces and serves them *observable*, in four pieces:

* :mod:`repro.obs.trace` — a lightweight span API with a JSONL event sink.
  ``obs.span("range_pass")`` / ``obs.counter("store.hits_mem")`` /
  ``obs.gauge(...)`` are module-level no-ops until a CLI installs a tracer
  (``--trace out.jsonl`` on ``python -m repro.certify``), after which one
  certify run yields a per-stage timing + ladder-compile-count + store
  hit/miss profile.
* :mod:`repro.obs.metrics` — serving-side latency histograms
  (prefill/decode split), tokens/s and occupancy gauges, exported as JSONL
  and as a Prometheus text exposition (no server dependency).
* :mod:`repro.obs.monitors` — certificate-violation monitors: runtime
  numeric-health stats per scope (via
  :func:`repro.core.quantize.numeric_health` + ``jax.debug.callback``)
  compared against the certified IA enclosures and (δ̄, ε̄) bounds —
  overflow/underflow/saturation counters and per-scope "bound margin"
  gauges, so a certificate that under-covers live traffic is detected.
* :mod:`repro.obs.report` + the ``python -m repro.obs report`` CLI —
  renders a trace into per-stage/per-scope summary tables; ``validate``
  schema-checks a trace (the CI smoke gate). :mod:`repro.obs.bench`
  appends machine-readable ``BENCH_*.json`` entries so the perf
  trajectory accumulates across runs.

Instrumentation contract: library code imports ``from repro import obs``
and calls ``obs.span/counter/gauge/event`` freely — all are cheap no-ops
when no tracer is configured, so the analysis and serving hot paths pay
nothing by default, and nothing here ever changes a jitted value (monitor
stats leave jit through ``jax.debug.callback``).
"""
from .trace import (  # noqa: F401
    SCHEMA,
    Tracer,
    configure,
    counter,
    enabled,
    event,
    flush,
    gauge,
    get_tracer,
    load_events,
    shutdown,
    span,
    validate_events,
)
from .log import get_logger  # noqa: F401
from .metrics import Histogram, MetricsRegistry  # noqa: F401
from .monitors import ViolationMonitor  # noqa: F401
from .bench import append_bench, check_regressions, read_bench  # noqa: F401


def __getattr__(name):
    # profile/costmodel are jax-adjacent (profile builds serving steps);
    # expose them lazily so `import repro.obs` stays as light as before
    if name in ("profile", "costmodel"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
