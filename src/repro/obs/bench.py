"""Machine-readable benchmark trajectory: append-only ``BENCH_*.json``.

Each ``BENCH_<name>.json`` is one JSON *array* of run entries — the
accumulating perf trajectory ROADMAP's roofline/fleet items read from.
:func:`append_bench` does an atomic read-modify-replace so a crashed run
never leaves a truncated file, and stamps every entry with a wall-clock
time plus whatever fields the caller measured::

    append_bench("runs", {"kind": "certify", "wall_s": 12.3, ...})

Discoverability contract: the growth harness (and anything else sampling
the trajectory) reads ``BENCH_*.json`` at the REPO ROOT — the root file is
the SINGLE SOURCE OF TRUTH. Every write also refreshes a READ-ONLY
snapshot under ``benchmarks/`` so the historical location and its readers
(CI asserts on ``benchmarks/BENCH_runs.json``) keep working; the snapshot
is chmod'd read-only precisely so nothing accidentally treats it as a
second writable trajectory. A pre-existing trajectory under
``benchmarks/`` seeds the root file on first write — no history is lost in
the move — and entries duplicated across the two locations are deduped by
content on read. ``$REPRO_BENCH_DIR`` still overrides everything (tests
point it at a tmpdir; no mirroring outside the repo then — the mirror
lands under ``<dir>/benchmarks/``).

Repeated runs in one process (e.g. a sweep re-certifying the same arch
with the same flags) REPLACE their previous entry instead of appending a
duplicate: :func:`append_bench` keys each entry on its identity fields
(``kind``/``arch`` + flag-ish values) and dedupes within the session.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

_BENCH_DIR_ENV = "REPRO_BENCH_DIR"
_MIRROR_SUBDIR = "benchmarks"

#: entry fields that identify "the same benchmark point" for in-session
#: dedupe: same values → the new entry replaces the old one
_IDENTITY_FIELDS = ("kind", "arch", "mixed", "formats", "profiles",
                    "mantissa_mode", "kernel", "case", "flags")

#: (name, dir, identity) → index appended this session
_session_keys: Dict[Tuple[str, str, str], int] = {}


def repo_root() -> str:
    # src/repro/obs/bench.py → repo root is three dirnames up from obs/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def bench_dir(explicit: Optional[str] = None) -> str:
    """Repo root (or $REPRO_BENCH_DIR / explicit override)."""
    if explicit:
        return explicit
    env = os.environ.get(_BENCH_DIR_ENV)
    if env:
        return env
    return repo_root()


def bench_path(name: str, directory: Optional[str] = None) -> str:
    return os.path.join(bench_dir(directory), f"BENCH_{name}.json")


def _mirror_path(name: str, directory: Optional[str] = None) -> str:
    return os.path.join(bench_dir(directory), _MIRROR_SUBDIR,
                        f"BENCH_{name}.json")


def _read_array(path: str) -> List[Dict[str, Any]]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a JSON array of run entries")
    return data


def _dedupe(entries: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Drop exact-duplicate entries (the same run recorded via both the
    root file and the legacy mirror), keeping first-occurrence order."""
    seen = set()
    out = []
    for e in entries:
        key = json.dumps(e, sort_keys=True, default=str)
        if key not in seen:
            seen.add(key)
            out.append(e)
    return out


def read_bench(name: str, directory: Optional[str] = None
               ) -> List[Dict[str, Any]]:
    """The trajectory for ``name``. The ROOT file is the single source of
    truth whenever it exists (even when empty); the legacy ``benchmarks/``
    mirror is only consulted before the root file is first written."""
    path = bench_path(name, directory)
    if os.path.exists(path):
        return _dedupe(_read_array(path))
    return _dedupe(_read_array(_mirror_path(name, directory)))


def _identity(entry: Dict[str, Any]) -> Optional[str]:
    picked = {f: entry[f] for f in _IDENTITY_FIELDS if f in entry}
    if not picked:
        return None
    return json.dumps(picked, sort_keys=True, default=str)


def _write_atomic(path: str, entries: List[Dict[str, Any]]):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(entries, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


def append_bench(name: str, entry: Dict[str, Any],
                 directory: Optional[str] = None) -> str:
    """Append one run entry (timestamped) to BENCH_<name>.json; atomic.

    Writes the repo-root file (the single source of truth; seeded from any
    legacy ``benchmarks/`` trajectory on first write) and refreshes the
    read-only ``benchmarks/`` snapshot CI asserts read. A same-session
    entry with identical identity fields replaces the one it supersedes
    instead of duplicating it."""
    path = bench_path(name, directory)
    entries = read_bench(name, directory)  # root, else legacy seed
    stamped = {"t": time.time(), **entry}

    ident = _identity(stamped)
    skey = (name, bench_dir(directory), ident or "")
    replaced = False
    if ident is not None and skey in _session_keys:
        idx = _session_keys[skey]
        if 0 <= idx < len(entries) and _identity(entries[idx]) == ident:
            entries[idx] = stamped
            replaced = True
    if not replaced:
        entries.append(stamped)
    if ident is not None:
        _session_keys[skey] = (idx if replaced else len(entries) - 1)

    _write_atomic(path, entries)
    mirror = _mirror_path(name, directory)
    if os.path.abspath(mirror) != os.path.abspath(path):
        _write_atomic(mirror, entries)
        try:
            # read-only snapshot: CI asserts may read it, nothing should
            # write it (os.replace above still works — renames only need
            # directory write permission)
            os.chmod(mirror, 0o444)
        except OSError:
            pass
    return path


def check_regressions(name: str = "kernels", threshold: float = 0.25,
                      directory: Optional[str] = None
                      ) -> List[Dict[str, Any]]:
    """Soft perf gate: compare the LAST trajectory entry's kernel medians
    against the previous entry's, flagging points whose ``median_s`` grew
    by more than ``threshold`` (0.25 = +25%).

    Entries are expected to carry ``rows``: a list of row dicts with a
    ``kernel`` (plus optional shape/k/block fields — all identity) and a
    ``median_s``. Returns one finding dict per regressed row; empty list
    when there is nothing to compare (fewer than two entries) — the gate
    WARNS, it never fails a build on noisy shared-runner timings."""
    entries = read_bench(name, directory)
    if len(entries) < 2:
        return []
    prev, last = entries[-2], entries[-1]

    def _rowkey(r: Dict[str, Any]) -> str:
        return json.dumps({f: r[f] for f in
                           ("kernel", "shape", "k", "emax", "emin", "block")
                           if f in r}, sort_keys=True, default=str)

    prev_rows = {_rowkey(r): r for r in prev.get("rows", [])
                 if r.get("median_s")}
    findings = []
    for r in last.get("rows", []):
        p = prev_rows.get(_rowkey(r))
        if not p or not r.get("median_s"):
            continue
        ratio = r["median_s"] / p["median_s"]
        if ratio > 1.0 + threshold:
            findings.append({
                "kernel": r.get("kernel"), "shape": r.get("shape"),
                "k": r.get("k"), "block": r.get("block"),
                "prev_median_s": p["median_s"],
                "last_median_s": r["median_s"],
                "ratio": ratio,
            })
    return findings
