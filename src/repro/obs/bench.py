"""Machine-readable benchmark trajectory: append-only ``BENCH_*.json``.

Each ``BENCH_<name>.json`` under ``benchmarks/`` is one JSON *array* of run
entries — the accumulating perf trajectory ROADMAP's roofline/fleet items
read from. :func:`append_bench` does an atomic read-modify-replace so a
crashed run never leaves a truncated file, and stamps every entry with a
wall-clock time plus whatever fields the caller measured::

    append_bench("runs", {"kind": "certify", "wall_s": 12.3, ...})
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

_BENCH_DIR_ENV = "REPRO_BENCH_DIR"


def bench_dir(explicit: Optional[str] = None) -> str:
    """benchmarks/ next to the repo root (or $REPRO_BENCH_DIR override)."""
    if explicit:
        return explicit
    env = os.environ.get(_BENCH_DIR_ENV)
    if env:
        return env
    # src/repro/obs/bench.py → repo root is three dirnames up
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "benchmarks")


def bench_path(name: str, directory: Optional[str] = None) -> str:
    return os.path.join(bench_dir(directory), f"BENCH_{name}.json")


def read_bench(name: str, directory: Optional[str] = None
               ) -> List[Dict[str, Any]]:
    path = bench_path(name, directory)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a JSON array of run entries")
    return data


def append_bench(name: str, entry: Dict[str, Any],
                 directory: Optional[str] = None) -> str:
    """Append one run entry (timestamped) to BENCH_<name>.json; atomic."""
    path = bench_path(name, directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    entries = read_bench(name, directory)
    entries.append({"t": time.time(), **entry})
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(entries, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return path
