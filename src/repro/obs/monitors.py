"""Certificate-violation monitors: check live traffic against proven bounds.

A certificate is an *a-priori* promise: per-scope IA magnitude enclosures
(schema-v3 ``scope_ranges``) and output error bounds (δ̄, ε̄ in units of u).
Those proofs are conditional on the input annotation they were run under —
live traffic that drifts outside it (e.g. data-dependent MoE routing, longer
contexts, distribution shift) silently voids them. A
:class:`ViolationMonitor` makes that detectable instead of trusted:

* **enclosure checks** — serving backends stream per-scope
  :func:`repro.core.quantize.numeric_health` stats to
  :meth:`observe_scope` (via ``jax.debug.callback``, so jitted values are
  untouched); an observed ``max_abs`` above the certified enclosure bumps
  ``obs.enclosure_violations`` and the per-scope ``bound_margin`` gauge —
  log2(certified/observed) — goes negative.
* **overflow / underflow / saturation counters** — the same stats carry
  ``n_over`` / ``n_under`` / ``n_nonfinite`` against the scope's *certified
  format*; any overflow event under a certificate that proved
  overflow-freedom is a violation by itself.
* **error checks** — :meth:`observe_error` takes a *sampled* empirical
  error (a full-precision reference pass on a small probe batch, in units
  of u) and compares it to the certified δ̄; exceeding it bumps
  ``obs.bound_violations``.

The monitor is pure host-side Python over floats; export goes through
:meth:`export` into a :class:`repro.obs.metrics.MetricsRegistry`.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, Optional

from repro.core.scopes import resolve_scope_value

_LAYER_KEY = re.compile(r"^layer\d+$")

# Multiplicative slack on enclosure comparisons. The certified max_abs is an
# upper bound computed in f64 IA; the observed stat is an f32 max of the
# *quantized* tensor, which rounding may carry up to one ulp past the bound
# without anything being wrong. 1 + 2^-10 covers every certified k ≥ 11.
DEFAULT_SLACK = 1.0 + 2.0 ** -10


class ViolationMonitor:
    """Compares observed numeric health against one certificate set."""

    def __init__(self, envelopes: Dict[str, Dict[str, float]],
                 dbar_u: float = math.inf, u: Optional[float] = None,
                 slack: float = DEFAULT_SLACK):
        # envelopes: {scope_key: {"max_abs": float, ...}} — certified
        # per-scope magnitude enclosures (concrete layer names, resolved
        # against observed paths with the scopes module's matcher).
        self.envelopes = dict(envelopes)
        self.dbar_u = float(dbar_u)
        self.u = u
        self.slack = float(slack)
        self.counters: Dict[str, int] = {
            "obs.scope_observations": 0,
            "obs.enclosure_violations": 0,
            "obs.overflow_events": 0,
            "obs.underflow_events": 0,
            "obs.nonfinite_events": 0,
            "obs.error_samples": 0,
            "obs.bound_violations": 0,
        }
        # scope → log2(certified max_abs / observed max_abs); > 0 = headroom
        self.scope_margin: Dict[str, float] = {}
        # worst observed empirical error in units of u (−inf until sampled)
        self.worst_err_u = -math.inf

    # -- construction from certificates -------------------------------------
    @classmethod
    def from_certificate_set(cls, cs, slack: float = DEFAULT_SLACK
                             ) -> "ViolationMonitor":
        """Build a monitor from one certificate set.

        Per-scope magnitude envelopes are taken ONLY from the format
        pipeline's ``scope_ranges`` (set-level meta, schema v3): those are
        rigorous IA enclosures over *every* op in the scope, so an observed
        matmul product above one is a genuine departure from the certified
        regime. v1/v2 sets carry no such enclosures (``trace_summary``
        out_mag records cover only the handful of explicitly recorded
        tensors — comparing arbitrary matmul products against them would
        false-positive constantly), so for those the monitor tracks
        overflow/underflow/nonfinite events and the sampled δ̄ error check
        only.
        """
        envelopes: Dict[str, Dict[str, float]] = {}
        fm = (cs.meta or {}).get("formats") or {}
        if fm.get("applied") and fm.get("scope_ranges"):
            for s, r in fm["scope_ranges"].items():
                ma = r.get("max_abs")
                if s and ma is not None and math.isfinite(ma):
                    envelopes[s] = {"max_abs": float(ma)}
        # serving scans run every layer through ONE traced body under the
        # stacked wildcard scope, so concrete layer<i> envelopes also fold
        # into a layer* key (max over layers — the loosest layer's enclosure,
        # which can never false-positive on a layer certified tighter).
        # An explicit layer* entry is merge-maxed, not trusted alone: the
        # wildcard path covers every concrete layer, so its envelope must be
        # at least as wide as the widest layer<i>. Concrete layer<i> keys
        # are left untouched — observations under a concrete path still
        # check against their own (possibly tighter) enclosure. Sub-layer
        # keys (layer3/attn) fold into their own layer*/attn group.
        folds: Dict[str, float] = {}
        for s, v in envelopes.items():
            head, _, rest = s.partition("/")
            if _LAYER_KEY.match(head):
                wild = "layer*" + (("/" + rest) if rest else "")
                folds[wild] = max(folds.get(wild, -math.inf), v["max_abs"])
        for wild, ma in folds.items():
            prev = envelopes.get(wild)
            if prev is None or prev["max_abs"] < ma:
                envelopes[wild] = {"max_abs": ma}
        bars = cs.error_bars()
        return cls(envelopes, dbar_u=bars.get("dbar_u", math.inf),
                   u=bars.get("u"), slack=slack)

    # -- observation (host side) --------------------------------------------
    def observe_scope(self, scope, stats: Dict[str, Any]):
        """Fold one scope's numeric-health stats (plain floats/ints).

        ``scope`` is a scope-path list (what a backend's ``scope_path``
        holds) or a single scope string; envelope keys resolve against it
        with the scopes module's matcher, so concrete ``layer3`` envelopes
        match observations made under the stacked ``layer*`` path and
        vice versa."""
        path = (list(scope) if isinstance(scope, (list, tuple))
                else [str(scope)])
        label = "/".join(path) or "<root>"
        self.counters["obs.scope_observations"] += 1
        n_over = int(stats.get("n_over", 0))
        n_under = int(stats.get("n_under", 0))
        n_nonfin = int(stats.get("n_nonfinite", 0))
        self.counters["obs.overflow_events"] += n_over
        self.counters["obs.underflow_events"] += n_under
        self.counters["obs.nonfinite_events"] += n_nonfin
        max_abs = float(stats.get("max_abs", 0.0))
        env = resolve_scope_value(path, self.envelopes, None)
        if env is not None:
            cert_max = float(env["max_abs"])
            violated = max_abs > cert_max * self.slack
            if violated or n_over > 0 or n_nonfin > 0:
                self.counters["obs.enclosure_violations"] += 1
            if max_abs > 0 and cert_max > 0:
                margin = math.log2(cert_max / max_abs)
            elif cert_max > 0:
                margin = math.inf  # nothing observed yet: full headroom
            else:
                margin = -math.inf
            prev = self.scope_margin.get(label)
            self.scope_margin[label] = (margin if prev is None
                                        else min(prev, margin))

    def observe_error(self, abs_err_u: float):
        """Fold one sampled empirical output error (units of u)."""
        self.counters["obs.error_samples"] += 1
        abs_err_u = float(abs_err_u)
        self.worst_err_u = max(self.worst_err_u, abs_err_u)
        if math.isfinite(self.dbar_u) and abs_err_u > self.dbar_u:
            self.counters["obs.bound_violations"] += 1

    # -- reporting -----------------------------------------------------------
    @property
    def violations(self) -> int:
        return (self.counters["obs.enclosure_violations"]
                + self.counters["obs.bound_violations"])

    def error_margin_u(self) -> float:
        """Certified δ̄ minus worst observed error (units of u); +inf when
        nothing sampled or no finite bound, negative = bound exceeded."""
        if not math.isfinite(self.dbar_u) or self.worst_err_u == -math.inf:
            return math.inf
        return self.dbar_u - self.worst_err_u

    def export(self, registry):
        """Write counters and bound-margin gauges into a MetricsRegistry."""
        for name, v in self.counters.items():
            registry.counter(name, v - registry.counters.get(name, 0))
        for scope, margin in self.scope_margin.items():
            if math.isfinite(margin):
                registry.gauge(f"obs.bound_margin_log2{{scope={scope}}}",
                               margin)
        em = self.error_margin_u()
        if math.isfinite(em):
            registry.gauge("obs.error_margin_u", em)
        if self.worst_err_u != -math.inf:
            registry.gauge("obs.worst_err_u", self.worst_err_u)

    def summary(self) -> Dict[str, Any]:
        return {
            "counters": dict(self.counters),
            "scope_margin_log2": {s: m for s, m in
                                  sorted(self.scope_margin.items())},
            "worst_err_u": (None if self.worst_err_u == -math.inf
                            else self.worst_err_u),
            "dbar_u": self.dbar_u,
            "violations": self.violations,
        }
