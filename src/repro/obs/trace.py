"""Span tracing with a JSONL event sink — the certify-side profile recorder.

One :class:`Tracer` owns an ordered event stream. Spans measure wall time
with ``time.perf_counter()`` (monotonic — nested spans can never report a
child longer than its parent from clock steps), carry a name, a nesting
depth, a parent span name and free-form JSON attributes, and are written as
one JSONL line each when they close. Counters and gauges accumulate
in-memory and are written as single aggregate lines by :meth:`Tracer.flush`
(span lines stream immediately; counter increments would otherwise dominate
the file).

Event schema (one JSON object per line; ``validate_events`` pins it):

  {"type": "meta",     "schema": 1, "program": ..., "argv": [...], "t": ...}
  {"type": "span",     "name": ..., "t": ..., "dur_s": ..., "depth": ...,
                       "parent": ..., "seq": ..., "attrs": {...}}
  {"type": "event",    "name": ..., "t": ..., "fields": {...}}
  {"type": "counters", "values": {name: int, ...}, "t": ...}
  {"type": "gauges",   "values": {name: float, ...}, "t": ...}

``t`` is epoch seconds of the *start* (spans) or emission (everything
else); ``seq`` is a process-wide monotone sequence number so a reader can
reconstruct interleavings without trusting the clock. The global tracer is
disabled by default: every obs call is then a cheap no-op, so instrumented
library code (the certify pipeline, the store, the serving path) pays
nothing unless a CLI opted in via :func:`configure`.
"""
from __future__ import annotations

import io
import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Optional

SCHEMA = 1

_EVENT_TYPES = ("meta", "span", "event", "counters", "gauges")


class _NullSpan:
    """Context manager returned when tracing is off — near-zero cost."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def rename(self, name: str):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """An open span; writes its line on ``__exit__``."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_wall", "_depth",
                 "_parent")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        tr = self._tracer
        with tr._lock:
            stack = tr._stack
            self._depth = len(stack)
            self._parent = stack[-1].name if stack else None
            stack.append(self)
        self._wall = time.time()
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs):
        """Attach attributes discovered mid-span (e.g. a search's result)."""
        self.attrs.update(attrs)
        return self

    def rename(self, name: str):
        """Change the span's name before it closes (e.g. a probe that
        turned out to be the one paying the compile)."""
        self.name = str(name)
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        tr = self._tracer
        with tr._lock:
            if tr._stack and tr._stack[-1] is self:
                tr._stack.pop()
        tr._emit({
            "type": "span", "name": self.name, "t": self._wall,
            "dur_s": dur, "depth": self._depth, "parent": self._parent,
            "attrs": self.attrs,
        })
        return False


class Tracer:
    """JSONL event recorder behind the module-level obs API.

    ``path=None`` keeps everything in-memory (``events`` — the test and
    report-rendering mode); with a path, lines are appended as they happen
    and the in-memory list is kept too (it is the cheap source for
    ``flush``-time summaries). Thread-safe: one lock guards the span stack,
    the aggregates, and the sink.
    """

    def __init__(self, path: Optional[str] = None,
                 program: str = "", argv: Optional[List[str]] = None):
        self.path = path
        self._file: Optional[io.TextIOBase] = None
        self._lock = threading.RLock()
        self._stack: List["_Span"] = []
        self._seq = 0
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        if path:
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
            self._file = open(path, "a")
        self._emit({"type": "meta", "schema": SCHEMA, "program": program,
                    "argv": list(argv or []), "t": time.time()})

    # -- sink ---------------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]):
        with self._lock:
            ev["seq"] = self._seq
            self._seq += 1
            self.events.append(ev)
            if self._file is not None:
                self._file.write(json.dumps(ev) + "\n")
                self._file.flush()

    # -- API ----------------------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, str(name), attrs)

    def event(self, name: str, **fields):
        self._emit({"type": "event", "name": str(name), "t": time.time(),
                    "fields": fields})

    def counter(self, name: str, inc: int = 1):
        with self._lock:
            self.counters[str(name)] = self.counters.get(str(name), 0) + int(inc)

    def gauge(self, name: str, value: float):
        with self._lock:
            self.gauges[str(name)] = float(value)

    def flush(self):
        """Write the aggregate counter/gauge lines (idempotent per state)."""
        if self.counters:
            self._emit({"type": "counters", "values": dict(self.counters),
                        "t": time.time()})
        if self.gauges:
            self._emit({"type": "gauges", "values": dict(self.gauges),
                        "t": time.time()})

    def close(self):
        self.flush()
        if self._file is not None:
            self._file.close()
            self._file = None


# ---------------------------------------------------------------------------
# module-level current tracer (what the instrumented library code calls)
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def configure(path: Optional[str] = None, program: str = "",
              argv: Optional[List[str]] = None) -> Tracer:
    """Install (and return) the global tracer. ``path=None`` → in-memory."""
    global _TRACER
    if _TRACER is not None:
        _TRACER.close()
    _TRACER = Tracer(path, program=program, argv=argv)
    return _TRACER


def shutdown():
    """Flush and uninstall the global tracer (subsequent calls are no-ops)."""
    global _TRACER
    if _TRACER is not None:
        _TRACER.close()
        _TRACER = None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def span(name: str, **attrs):
    """Open a span on the global tracer; a no-op context when disabled."""
    if _TRACER is None:
        return _NULL_SPAN
    return _TRACER.span(name, **attrs)


def event(name: str, **fields):
    if _TRACER is not None:
        _TRACER.event(name, **fields)


def counter(name: str, inc: int = 1):
    if _TRACER is not None:
        _TRACER.counter(name, inc)


def gauge(name: str, value: float):
    if _TRACER is not None:
        _TRACER.gauge(name, value)


def flush():
    if _TRACER is not None:
        _TRACER.flush()


# ---------------------------------------------------------------------------
# schema validation + file loading (report CLI, CI smoke)
# ---------------------------------------------------------------------------

def validate_events(events: Iterable[Dict[str, Any]]) -> List[str]:
    """Schema check; returns a list of human-readable problems (empty = ok)."""
    errors: List[str] = []
    n = 0
    for i, ev in enumerate(events):
        n += 1
        if not isinstance(ev, dict):
            errors.append(f"line {i}: not a JSON object")
            continue
        t = ev.get("type")
        if t not in _EVENT_TYPES:
            errors.append(f"line {i}: unknown type {t!r}")
            continue
        if "seq" not in ev or not isinstance(ev["seq"], int):
            errors.append(f"line {i}: missing integer 'seq'")
        if t == "meta" and ev.get("schema") != SCHEMA:
            errors.append(f"line {i}: meta schema {ev.get('schema')!r} != "
                          f"{SCHEMA}")
        if t == "span":
            for field, typ in (("name", str), ("t", (int, float)),
                               ("dur_s", (int, float)), ("depth", int),
                               ("attrs", dict)):
                if not isinstance(ev.get(field), typ):
                    errors.append(f"line {i}: span missing/typed "
                                  f"{field!r}")
            if isinstance(ev.get("dur_s"), (int, float)) and ev["dur_s"] < 0:
                errors.append(f"line {i}: negative span duration")
        if t == "event" and not isinstance(ev.get("name"), str):
            errors.append(f"line {i}: event missing 'name'")
        if t in ("counters", "gauges") and not isinstance(
                ev.get("values"), dict):
            errors.append(f"line {i}: {t} missing 'values'")
    if n == 0:
        errors.append("empty trace (no events)")
    return errors


def load_events(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL trace file (raises on malformed JSON lines)."""
    events = []
    with open(path) as f:
        for ln, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln + 1}: malformed JSONL: {e}")
    return events
