"""Measured cost model: {scope_class × (k, emax)} → predicted serving latency.

The format search's objective so far is FLOP-weighted bits/value — a proxy
that weights a mantissa bit identically whether the scope it lives in is
memory-bound (where narrower storage is wall-clock) or MXU-bound (where it
buys nothing). This module earns the other axis: it FITS a two-term roofline
cost model to *measured* kernel timings (:mod:`repro.obs.profile`), predicts
per-scope serving latency as

    latency(scope, fmt) = max( flops / α_kernel ,  bytes(fmt) / β_kernel )

with α (achieved FLOP/s) and β (achieved bytes/s) taken per kernel class
from the medians of the measured profile — not the datasheet — and re-scores
existing certificates: for every scope, the FLOP-weighted-bits objective vs
the predicted-latency objective, with the disagreements (compute-bound
scopes whose bits the greedy descent spent latency-blind) made explicit.

The fitted model exports as JSON (``CostModel.to_dict``/``save_json``) so
the certify CLI's ``--cost-report`` pass and a future latency-objective
greedy descent read the same artifact. Hardware peaks live here too —
:data:`TPU_POD_CHIP` is the single source for the analytic roofline terms
``benchmarks/roofline.py`` prints.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Dict, List, Optional, Sequence

#: serving cost of a bare mantissa-k map in a binary32 carrier:
#: 1 sign + 8 exponent + (k-1) stored mantissa bits (matches certify.lm's
#: mean_bits_flop_weighted convention)
CARRIER_EXP_BITS = 8
BINARY32_BITS = 32


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Peak terms of the roofline (per chip). ``ridge_intensity`` is the
    FLOP/byte above which a kernel is compute-bound at these peaks."""

    name: str
    peak_flops: float          # FLOP/s
    hbm_bytes_per_s: float
    link_bytes_per_s: float

    @property
    def ridge_intensity(self) -> float:
        return self.peak_flops / self.hbm_bytes_per_s

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


#: the single-pod chip the analytic roofline (benchmarks/roofline.py) uses:
#: 197 TFLOP/s bf16 MXU, 819 GB/s HBM, 50 GB/s/link ICI
TPU_POD_CHIP = Hardware("tpu-pod-chip", 197e12, 819e9, 50e9)


def format_bits(k: int, emax: Optional[int] = None,
                emin: Optional[int] = None) -> float:
    """Total storage bits/value of a certified format: sign + exponent field
    + stored mantissa. A mantissa-only (mixed) map rides a binary32-carrier
    exponent field of 8 bits."""
    if emax is None or emin is None:
        return 1 + CARRIER_EXP_BITS + (int(k) - 1)
    from repro.core import formats as F
    return 1 + F.exponent_bits(int(emax), int(emin)) + (int(k) - 1)


def scope_class(scope: str) -> str:
    """Fold a certificate scope key into its kernel-facing class.

    ``layer3/attn`` and ``layer*/attn`` are the same class (one scanned
    body serves them); dense paper-model scopes fold to ``dense``."""
    s = str(scope)
    if not s:
        return "default"
    if "/" in s:
        return "layer/" + s.rsplit("/", 1)[1]
    if s.startswith("layer"):
        return "layer"
    if s.startswith("dense"):
        return "dense"
    return s  # head, embed, softmax, ...


#: which measured kernel's achieved (α, β) prices each scope class; first
#: present in the fitted model wins
CLASS_KERNELS: Dict[str, Sequence[str]] = {
    "layer/attn": ("flash_decode", "quant_matmul_format",
                   "quant_matmul_dynamic_k", "matmul_baseline"),
}
DEFAULT_KERNELS: Sequence[str] = ("quant_matmul_format",
                                  "quant_matmul_dynamic_k",
                                  "matmul_baseline", "flash_decode")


@dataclasses.dataclass
class CostModel:
    """Per-kernel achieved-throughput coefficients fitted from measurement.

    ``alpha[kernel]`` = achieved FLOP/s (median over the profiled points),
    ``beta[kernel]`` = achieved bytes/s. ``predict`` combines them with a
    scope's analytic flops and format-dependent bytes into the measured
    two-term roofline above.
    """

    alpha: Dict[str, float]
    beta: Dict[str, float]
    hardware: Hardware = TPU_POD_CHIP
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- kernel resolution --------------------------------------------------
    def kernel_for(self, scope: str) -> str:
        cls = scope_class(scope)
        for k in CLASS_KERNELS.get(cls, DEFAULT_KERNELS):
            if k in self.alpha:
                return k
        if not self.alpha:
            raise ValueError("empty cost model (no fitted kernels)")
        return sorted(self.alpha)[0]

    # -- prediction ---------------------------------------------------------
    def predict(self, scope: str, flops_per_token: float,
                k: int, emax: Optional[int] = None,
                emin: Optional[int] = None,
                tokens: int = 1) -> Dict[str, Any]:
        """Predicted latency contribution of one scope for one serving step.

        ``flops_per_token`` is the scope's matmul work per token (the same
        figure the FLOP-weighted bits objective weights by); the scope's
        weight traffic is ``flops/2`` values streamed once per step at the
        format's storage width — the decode-wall model, where weights
        dominate bytes and activations ride in cache.
        """
        kernel = self.kernel_for(scope)
        bits = format_bits(k, emax, emin)
        flops = float(flops_per_token) * max(int(tokens), 1)
        weights = float(flops_per_token) / 2.0
        bytes_moved = weights * bits / 8.0
        compute_s = flops / self.alpha[kernel]
        memory_s = bytes_moved / self.beta[kernel]
        bound = "memory" if memory_s >= compute_s else "compute"
        return {
            "kernel": kernel, "bits": bits,
            "flops": flops, "bytes": bytes_moved,
            "compute_s": compute_s, "memory_s": memory_s,
            "latency_s": max(compute_s, memory_s), "bound": bound,
        }

    # -- serialisation ------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": 1,
            "alpha_flops_per_s": dict(self.alpha),
            "beta_bytes_per_s": dict(self.beta),
            "hardware": self.hardware.to_dict(),
            "meta": dict(self.meta),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CostModel":
        hw = d.get("hardware") or {}
        return cls(alpha=dict(d["alpha_flops_per_s"]),
                   beta=dict(d["beta_bytes_per_s"]),
                   hardware=Hardware(**hw) if hw else TPU_POD_CHIP,
                   meta=dict(d.get("meta") or {}))

    def save_json(self, path: str):
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load_json(cls, path: str) -> "CostModel":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def _median(xs: Sequence[float]) -> float:
    xs = sorted(xs)
    n = len(xs)
    if n == 0:
        raise ValueError("median of empty sequence")
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def fit_cost_model(records: Sequence[Dict[str, Any]],
                   hardware: Hardware = TPU_POD_CHIP) -> CostModel:
    """Fit (α, β) per kernel from measured profile records.

    Each record needs ``kernel``, ``median_s``, ``flops``, ``bytes`` — the
    shape :func:`repro.obs.profile.profile_kernels` emits. The fit is the
    median achieved throughput across that kernel's measured points (robust
    to one cold-cache outlier; no least squares needed for a two-parameter
    rate model).

    Rows measured in Pallas INTERPRET mode (``interpret=True``, the CPU CI
    fallback) time the Python interpreter, not the hardware — they are
    dropped whenever any real-hardware row exists. A fit from interpret
    rows only still succeeds (so CPU-only environments keep a model) but
    is flagged ``meta["interpret_only"]`` and warned about."""
    usable = [r for r in records
              if r.get("median_s", 0) and r["median_s"] > 0]
    real = [r for r in usable if not r.get("interpret")]
    interpret_only = bool(usable) and not real
    if interpret_only:
        import warnings
        warnings.warn(
            "fit_cost_model: every measurement row is Pallas interpret-mode "
            "(CPU emulation) — the fitted rates model the interpreter, not "
            "the hardware; treat predictions as relative only",
            RuntimeWarning, stacklevel=2)
    else:
        usable = real
    per: Dict[str, List[Dict[str, Any]]] = {}
    for r in usable:
        per.setdefault(str(r["kernel"]), []).append(r)
    if not per:
        raise ValueError("no usable measurement records to fit")
    alpha = {k: _median([r["flops"] / r["median_s"] for r in rs])
             for k, rs in per.items()}
    beta = {k: _median([r["bytes"] / r["median_s"] for r in rs])
            for k, rs in per.items()}
    meta: Dict[str, Any] = {"fit_points": {k: len(rs)
                                           for k, rs in per.items()}}
    dropped = sum(1 for r in records
                  if r.get("median_s", 0) and r["median_s"] > 0
                  and r.get("interpret")) if not interpret_only else 0
    if dropped:
        meta["interpret_rows_dropped"] = dropped
    if interpret_only:
        meta["interpret_only"] = True
    return CostModel(alpha=alpha, beta=beta, hardware=hardware, meta=meta)


# ---------------------------------------------------------------------------
# certificate re-scoring: FLOP-weighted bits vs predicted latency
# ---------------------------------------------------------------------------

def _resolve_fmt(scope: str, layer_format: Optional[Dict[str, Dict]],
                 layer_k: Optional[Dict[str, int]],
                 uniform_k: Optional[int]):
    """(k, emax, emin) a scope would serve under — format map first, then
    mixed map (binary32 carrier), then the uniform k."""
    if layer_format:
        f = layer_format.get(scope, layer_format.get(""))
        if f is not None:
            return int(f["k"]), int(f["emax"]), int(f["emin"])
    if layer_k and scope in layer_k:
        return int(layer_k[scope]), None, None
    if uniform_k is not None:
        return int(uniform_k), None, None
    return 24, None, None  # binary32 carrier, full mantissa


def cost_report(model: CostModel,
                layer_flops: Dict[str, float],
                layer_format: Optional[Dict[str, Dict]] = None,
                layer_k: Optional[Dict[str, int]] = None,
                uniform_k: Optional[int] = None,
                tokens: int = 1) -> Dict[str, Any]:
    """Score a certified serving map under BOTH objectives, per scope.

    For every scope with a FLOP weight: its serving format, the
    FLOP-weighted-bits objective share, the measured-model predicted
    latency share, the savings each objective credits vs a uniform
    binary32 baseline, and the rank each objective assigns the scope.
    ``disagreements`` lists scopes the two objectives order differently —
    exactly where swapping the greedy descent's objective would change the
    map. The full objective swap stays a follow-up; this report is the
    evidence for it.
    """
    rows: List[Dict[str, Any]] = []
    for scope in sorted(layer_flops):
        fl = float(layer_flops[scope])
        k, emax, emin = _resolve_fmt(scope, layer_format, layer_k, uniform_k)
        pred = model.predict(scope, fl, k, emax, emin, tokens=tokens)
        base = model.predict(scope, fl, 24, None, None, tokens=tokens)
        rows.append({
            "scope": scope, "class": scope_class(scope),
            "k": k, "emax": emax, "emin": emin,
            "bits": pred["bits"], "flops_per_token": fl,
            "kernel": pred["kernel"], "bound": pred["bound"],
            "predicted_s": pred["latency_s"],
            "compute_s": pred["compute_s"], "memory_s": pred["memory_s"],
            # what each objective says this scope's narrowing was worth:
            "bits_saved_weighted": fl * (BINARY32_BITS - pred["bits"]),
            "latency_saved_s": base["latency_s"] - pred["latency_s"],
        })
    tot_fl = sum(r["flops_per_token"] for r in rows) or 1.0
    tot_lat = sum(r["predicted_s"] for r in rows) or 1.0
    for r in rows:
        r["bits_objective_share"] = (r["flops_per_token"] * r["bits"]
                                     / (tot_fl * BINARY32_BITS))
        r["latency_share"] = r["predicted_s"] / tot_lat

    def _rank(key):
        order = sorted(range(len(rows)), key=lambda i: -rows[i][key])
        rk = [0] * len(rows)
        for pos, i in enumerate(order):
            rk[i] = pos
        return rk

    rank_bits = _rank("bits_saved_weighted")
    rank_lat = _rank("latency_saved_s")
    disagreements = []
    for i, r in enumerate(rows):
        r["rank_by_bits_saved"] = rank_bits[i]
        r["rank_by_latency_saved"] = rank_lat[i]
        r["rank_disagreement"] = rank_bits[i] - rank_lat[i]
        if rank_bits[i] != rank_lat[i] or (
                r["bound"] == "compute" and r["bits"] < BINARY32_BITS):
            disagreements.append({
                "scope": r["scope"], "bound": r["bound"],
                "rank_by_bits_saved": rank_bits[i],
                "rank_by_latency_saved": rank_lat[i],
                "note": ("compute-bound: narrower storage buys ~no latency "
                         "here, but the bits objective still credits it"
                         if r["bound"] == "compute"
                         else "objectives rank this scope differently"),
            })
    mean_bits = sum(r["flops_per_token"] * r["bits"] for r in rows) / tot_fl
    agree = sum(1 for i in range(len(rows)) if rank_bits[i] == rank_lat[i])
    return {
        "schema": 1,
        "tokens": int(tokens),
        "scopes": rows,
        "mean_bits_flop_weighted": mean_bits,
        "predicted_step_latency_s": tot_lat,
        "rank_agreement": agree / max(len(rows), 1),
        "disagreements": sorted(
            disagreements,
            key=lambda d: -abs(d["rank_by_bits_saved"]
                               - d["rank_by_latency_saved"])),
    }


def certificate_cost_report(certset, layer_flops: Dict[str, float],
                            model: CostModel, tokens: int = 1
                            ) -> Dict[str, Any]:
    """`cost_report` over what a :class:`repro.certify.spec.CertificateSet`
    would actually serve (format map ≻ mixed map ≻ uniform k)."""
    lf = certset.serving_layer_format
    lk = certset.serving_layer_k
    rep = cost_report(model, layer_flops, layer_format=lf, layer_k=lk,
                      uniform_k=certset.serving_k, tokens=tokens)
    rep["model_id"] = certset.model_id
    rep["params_digest"] = certset.params_digest
    rep["serving_map"] = ("format" if lf else
                          "mixed" if lk else "uniform")
    return rep


def render_cost_report(rep: Dict[str, Any]) -> str:
    """Human-readable bits-vs-predicted-latency table."""
    lines = [
        f"cost model what-if — {rep.get('serving_map', '?')} map, "
        f"mean bits {rep['mean_bits_flop_weighted']:.2f}, predicted step "
        f"latency {rep['predicted_step_latency_s'] * 1e6:.2f}us, "
        f"objective rank agreement {rep['rank_agreement']:.0%}",
        f"{'scope':<18} {'bits':>5} {'bound':>8} {'pred_us':>10} "
        f"{'lat%':>6} {'bits_rank':>9} {'lat_rank':>8}",
    ]
    for r in rep["scopes"]:
        lines.append(
            f"{(r['scope'] or '<default>'):<18} {r['bits']:>5.0f} "
            f"{r['bound']:>8} {r['predicted_s'] * 1e6:>10.3f} "
            f"{r['latency_share']:>6.1%} {r['rank_by_bits_saved']:>9} "
            f"{r['rank_by_latency_saved']:>8}")
    if rep["disagreements"]:
        lines.append("objective disagreements (bits-objective blind spots):")
        for d in rep["disagreements"]:
            lines.append(f"  {d['scope'] or '<default>'}: {d['note']}")
    else:
        lines.append("objectives agree on every scope's ranking")
    return "\n".join(lines)
