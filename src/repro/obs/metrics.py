"""Serving-side metrics: latency histograms, rate meters, gauges.

Pure-Python accumulators (no server, no dependency) exported two ways:

- JSONL: one ``{"type": "metrics", ...}`` snapshot object via
  :meth:`MetricsRegistry.to_dict` / :meth:`write_jsonl`.
- Prometheus text exposition (the ``/metrics``-shaped dump): via
  :meth:`render_prometheus`, so an operator can point any scraper-shaped
  tool at the emitted file without us running an HTTP server.

Histograms use fixed log-spaced latency buckets (100µs … ~100s) which
cover both a prefill over long context and a single decode step; they
export Prometheus-style cumulative bucket counts plus sum/count so mean
latency is recoverable exactly and quantiles approximately.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, List, Optional

# 100µs → ~100s, 4 buckets per decade (log-spaced).
_DEFAULT_BUCKETS = tuple(10.0 ** (-4 + i / 4.0) for i in range(25))


class Histogram:
    """Fixed-bucket latency histogram with Prometheus-style cumulation."""

    def __init__(self, name: str, buckets=_DEFAULT_BUCKETS,
                 help_text: str = ""):
        self.name = name
        self.help_text = help_text
        self.buckets: List[float] = sorted(buckets)
        self.counts: List[int] = [0] * (len(self.buckets) + 1)  # +inf tail
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float):
        value = float(value)
        self.sum += value
        self.count += 1
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        # first bucket whose upper bound admits the value
        lo, hi = 0, len(self.buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile from bucket boundaries (upper bound),
        clamped into the recorded [min, max] — a bucket's upper edge can
        overshoot the largest value actually observed, and a digest that
        reports p99 above the recorded max is a lie detector's finding,
        not a digest."""
        if not self.count:
            return 0.0
        target = q * self.count
        acc = 0
        val = self.max if self.max is not None else math.inf
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                if i < len(self.buckets):
                    val = self.buckets[i]
                break
        if self.min is not None:
            val = max(val, self.min)
        if self.max is not None:
            val = min(val, self.max)
        return val

    def percentiles(self) -> Dict[str, float]:
        """The serving-latency digest: p50/p95/p99 (clamped, monotone)."""
        return {"p50": self.quantile(0.5), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "count": self.count, "sum": self.sum,
            "min": self.min, "max": self.max, "mean": self.mean,
            "p50": self.quantile(0.5), "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "buckets": [{"le": b, "n": n}
                        for b, n in zip(self.buckets, self.counts)
                        if n] + ([{"le": "inf", "n": self.counts[-1]}]
                                 if self.counts[-1] else []),
        }


class MetricsRegistry:
    """Named counters / gauges / histograms with dual exporters."""

    def __init__(self):
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.meta: Dict[str, Any] = {}

    # -- recording ----------------------------------------------------------
    def counter(self, name: str, inc: int = 1):
        self.counters[name] = self.counters.get(name, 0) + int(inc)

    def gauge(self, name: str, value: float):
        self.gauges[name] = float(value)

    def histogram(self, name: str, help_text: str = "") -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, help_text=help_text)
        return h

    def observe(self, name: str, value: float):
        self.histogram(name).observe(value)

    # -- export -------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "type": "metrics", "t": time.time(), "meta": dict(self.meta),
            "counters": dict(self.counters), "gauges": dict(self.gauges),
            "histograms": {k: h.to_dict()
                           for k, h in self.histograms.items()},
        }

    def write_jsonl(self, path: str):
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(self.to_dict()) + "\n")

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (scrape-file shaped).

        Registry keys may carry labels inline — ``base{key=value,k2=v2}``
        — which render as proper Prometheus labels with the exposition
        format's escaping (``\\``, ``"``, newline) applied to values.
        Label-less keys render bare, exactly as before."""
        lines: List[str] = []
        typed: set = set()

        def _name(n: str) -> str:
            out = []
            for ch in n:
                out.append(ch if (ch.isalnum() or ch in "_:") else "_")
            return "".join(out)

        def _esc(v: str) -> str:
            return (str(v).replace("\\", "\\\\").replace('"', '\\"')
                    .replace("\n", "\\n"))

        def _split(key: str):
            """``base{k=v,k2=v2}`` → (base, [(k, v), ...])."""
            if key.endswith("}") and "{" in key:
                base, _, body = key[:-1].partition("{")
                pairs = []
                for part in body.split(","):
                    if not part:
                        continue
                    lk, eq, lv = part.partition("=")
                    pairs.append((lk.strip(), lv if eq else ""))
                return base, pairs
            return key, []

        def _series(key: str, extra=()):
            base, pairs = _split(key)
            n = _name(base)
            labels = [(_name(lk), _esc(lv)) for lk, lv in pairs]
            labels += [(lk, _esc(lv)) for lk, lv in extra]
            if labels:
                body = ",".join(f'{lk}="{lv}"' for lk, lv in labels)
                return n, f"{n}{{{body}}}"
            return n, n

        def _type_line(n: str, kind: str):
            if n not in typed:
                typed.add(n)
                lines.append(f"# TYPE {n} {kind}")

        for k in sorted(self.counters):
            n, series = _series(k)
            _type_line(n, "counter")
            lines.append(f"{series} {self.counters[k]}")
        for k in sorted(self.gauges):
            n, series = _series(k)
            _type_line(n, "gauge")
            lines.append(f"{series} {self.gauges[k]:.9g}")
        for k in sorted(self.histograms):
            h = self.histograms[k]
            base, pairs = _split(k)
            n = _name(base)
            labels = [(_name(lk), _esc(lv)) for lk, lv in pairs]
            lbody = ",".join(f'{lk}="{lv}"' for lk, lv in labels)
            own = f"{{{lbody}}}" if lbody else ""

            def _bucket(le: str) -> str:
                body = (lbody + "," if lbody else "") + f'le="{le}"'
                return f"{n}_bucket{{{body}}}"

            _type_line(n, "histogram")
            if h.help_text:
                lines.append(f"# HELP {n} {h.help_text}")
            acc = 0
            for b, c in zip(h.buckets, h.counts):
                acc += c
                if c or acc:
                    lines.append(f"{_bucket(f'{b:.9g}')} {acc}")
            acc += h.counts[-1]
            lines.append(f"{_bucket('+Inf')} {acc}")
            lines.append(f"{n}_sum{own} {h.sum:.9g}")
            lines.append(f"{n}_count{own} {h.count}")
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str):
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.render_prometheus())
        os.replace(tmp, path)
