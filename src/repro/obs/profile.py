"""Measured kernel/serving profiling: warmup + median-of-k timing against
analytic roofline terms.

Two entry points:

* :func:`profile_kernels` — times the certified kernels
  (``quant_matmul_dynamic_k``, the scalar-prefetch ``quant_matmul_format``,
  a baseline ``jnp.matmul``, and ``flash_decode_attention``) across shapes,
  formats, and Pallas block sizes. Every row carries the measured median
  alongside the ANALYTIC terms (flops, bytes, intensity, roofline time at
  the :class:`repro.obs.costmodel.Hardware` peaks) so achieved-vs-roofline
  is one division, and :func:`repro.obs.costmodel.fit_cost_model` can fit
  achieved (α, β) rates from the same rows.
* :func:`profile_serving` — builds the real serving steps
  (``launch.serve.build_serve_steps``) for a SMOKE arch, AOT-compiles them
  (compile-time + jaxpr-size gauges), runs a prefill + decode loop under
  trace spans, and digests the latencies into p50/p95/p99 via the
  log-bucket histograms in :mod:`repro.obs.metrics`.

Timing discipline: jit/compile fully OUTSIDE the timed region (AOT lower →
compile, or one warmup call), then ``reps`` timed calls each ending in
``jax.block_until_ready``, reported as the median (robust to one GC pause
— the same discipline ``benchmarks/analysis_speed.py`` hand-rolled; this
is the shared implementation). On CPU the Pallas kernels run in interpret
mode — medians are mechanism-true (same code path) but roofline fractions
are only meaningful on real TPUs; rows carry ``interpret`` so readers can
tell.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from .costmodel import Hardware, TPU_POD_CHIP, format_bits

BYTES_F32 = 4  # the emulation's carrier width: everything streams as f32


# ---------------------------------------------------------------------------
# timing + jaxpr primitives
# ---------------------------------------------------------------------------

def measure(fn: Callable, *args, reps: int = 5, warmup: int = 2,
            **kwargs) -> Dict[str, float]:
    """Median-of-``reps`` wall time of ``fn(*args)``, post-warmup.

    The warmup calls absorb jit compilation and first-touch allocation;
    every timed call blocks on the result so async dispatch can't hide
    device time. Returns median/min/mean/max plus the raw samples."""
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args, **kwargs))
    times: List[float] = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    ts = sorted(times)
    n = len(ts)
    median = ts[n // 2] if n % 2 else 0.5 * (ts[n // 2 - 1] + ts[n // 2])
    return {"median_s": median, "min_s": ts[0], "max_s": ts[-1],
            "mean_s": sum(ts) / n, "reps": n, "samples": times}


def _count_eqns(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                n += _count_eqns(inner)
            elif isinstance(v, (list, tuple)):
                for w in v:
                    iw = getattr(w, "jaxpr", w)
                    if hasattr(iw, "eqns"):
                        n += _count_eqns(iw)
    return n


def jaxpr_stats(fn: Callable, *args, **kwargs) -> Dict[str, int]:
    """Total equation count of ``fn``'s jaxpr, descending into sub-jaxprs
    (scan/cond/pjit bodies) — the "program size" gauge: a scan-native
    analysis stays flat in depth, an unrolled one doesn't."""
    import jax

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return {"eqns": _count_eqns(closed.jaxpr),
            "outvars": len(closed.jaxpr.outvars)}


def time_compile(jitted, *args) -> Dict[str, Any]:
    """AOT lower + compile ``jitted`` for ``args``, separately timed.

    Returns the compiled executable plus ``lower_s``/``compile_s`` — the
    gauges the serving profile records per jit so compile-time regressions
    show up in the trace, not just as mysterious first-call latency."""
    t0 = time.perf_counter()
    lowered = jitted.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return {"compiled": compiled, "lower_s": t1 - t0, "compile_s": t2 - t1}


# ---------------------------------------------------------------------------
# analytic terms per kernel invocation
# ---------------------------------------------------------------------------

def gemm_terms(M: int, K: int, N: int, bits: float = 32.0,
               hw: Hardware = TPU_POD_CHIP) -> Dict[str, Any]:
    """Analytic roofline terms of one [M,K]@[K,N] GEMM at ``bits``/value
    storage: flops = 2·M·K·N, bytes = operands in + result out (each value
    touched once — the blocked kernel's VMEM residency makes this the
    floor), intensity = flops/bytes vs the hardware ridge."""
    flops = 2.0 * M * K * N
    bytes_moved = (M * K + K * N + M * N) * bits / 8.0
    intensity = flops / bytes_moved
    compute_s = flops / hw.peak_flops
    memory_s = bytes_moved / hw.hbm_bytes_per_s
    return {
        "flops": flops, "bytes": bytes_moved, "intensity": intensity,
        "compute_s": compute_s, "memory_s": memory_s,
        "roofline_s": max(compute_s, memory_s),
        "bound": "memory" if memory_s >= compute_s else "compute",
    }


def flash_decode_terms(B: int, S: int, K: int, G: int, D: int,
                       bits: float = 32.0,
                       hw: Hardware = TPU_POD_CHIP) -> Dict[str, Any]:
    """Analytic terms of one flash-decode call: QK^T + PV are 2·2·B·K·G·S·D
    flops; bytes stream the KV cache once (the whole point of the online
    softmax) plus q in / o out."""
    flops = 4.0 * B * K * G * S * D
    bytes_moved = (2.0 * B * S * K * D + 2.0 * B * K * G * D) * bits / 8.0
    intensity = flops / bytes_moved
    compute_s = flops / hw.peak_flops
    memory_s = bytes_moved / hw.hbm_bytes_per_s
    return {
        "flops": flops, "bytes": bytes_moved, "intensity": intensity,
        "compute_s": compute_s, "memory_s": memory_s,
        "roofline_s": max(compute_s, memory_s),
        "bound": "memory" if memory_s >= compute_s else "compute",
    }


# ---------------------------------------------------------------------------
# kernel profiling
# ---------------------------------------------------------------------------

#: CPU-feasible default sweep: small enough for interpret-mode Pallas in CI,
#: shaped like real tiles (128-multiples) so TPU runs reuse the same preset
DEFAULT_GEMM_SHAPES: Sequence[tuple] = ((128, 128, 128), (128, 256, 128))
DEFAULT_KS: Sequence[int] = (8, 24)
DEFAULT_FORMATS: Sequence[tuple] = ((4, 8, -6), (8, 15, -14))
DEFAULT_FLASH_SHAPES: Sequence[tuple] = ((2, 256, 2, 2, 64),)

ALL_KERNELS = ("matmul_baseline", "quant_matmul_dynamic_k",
               "quant_matmul_format", "flash_decode")


def _row(kernel: str, terms: Dict[str, Any], timing: Dict[str, float],
         **extra) -> Dict[str, Any]:
    med = timing["median_s"]
    return {
        "kernel": kernel,
        "median_s": med, "min_s": timing["min_s"], "reps": timing["reps"],
        "flops": terms["flops"], "bytes": terms["bytes"],
        "intensity": terms["intensity"],
        "roofline_s": terms["roofline_s"], "bound": terms["bound"],
        "achieved_flops_per_s": terms["flops"] / med if med > 0 else 0.0,
        "achieved_bytes_per_s": terms["bytes"] / med if med > 0 else 0.0,
        "roofline_frac": terms["roofline_s"] / med if med > 0 else 0.0,
        **extra,
    }


def profile_kernels(gemm_shapes: Iterable[tuple] = DEFAULT_GEMM_SHAPES,
                    ks: Iterable[int] = DEFAULT_KS,
                    formats: Iterable[tuple] = DEFAULT_FORMATS,
                    blocks: Optional[Iterable[tuple]] = None,
                    flash_shapes: Iterable[tuple] = DEFAULT_FLASH_SHAPES,
                    include: Sequence[str] = ALL_KERNELS,
                    reps: int = 5, warmup: int = 2,
                    interpret: Optional[bool] = None,
                    hw: Hardware = TPU_POD_CHIP) -> List[Dict[str, Any]]:
    """Time every certified kernel across the sweep; one row per point.

    ``blocks`` — (bm, bn, bk) Pallas tile candidates for the format kernel
    (default: :func:`repro.kernels.quant_matmul.block_candidates` per
    shape, the autotune axis); ``interpret`` default follows the backend
    (interpret off-TPU). Rows are what ``fit_cost_model`` and the
    ``BENCH_kernels.json`` trajectory consume."""
    import jax
    import jax.numpy as jnp
    from repro import obs
    from repro.kernels.quant_matmul import (block_candidates, quant_matmul,
                                            quant_matmul_dynamic_k,
                                            quant_matmul_format)
    from repro.kernels.flash_decode import flash_decode_attention

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rows: List[Dict[str, Any]] = []
    key = jax.random.PRNGKey(0)

    for (M, K, N) in gemm_shapes:
        kx, kw = jax.random.split(jax.random.fold_in(key, M * K + N))
        x = jax.random.normal(kx, (M, K), jnp.float32)
        w = jax.random.normal(kw, (K, N), jnp.float32)
        shape = {"M": M, "K": K, "N": N, "shape": f"{M}x{K}x{N}"}
        terms32 = gemm_terms(M, K, N, 32.0, hw)

        if "matmul_baseline" in include:
            f = jax.jit(lambda a, b: jnp.matmul(
                a, b, preferred_element_type=jnp.float32))
            with obs.span("profile.kernel", kernel="matmul_baseline", **{
                    "shape": shape["shape"]}):
                t = measure(f, x, w, reps=reps, warmup=warmup)
            rows.append(_row("matmul_baseline", terms32, t, **shape,
                             interpret=False))

        if "quant_matmul_dynamic_k" in include:
            f = jax.jit(quant_matmul_dynamic_k)
            for k in ks:
                with obs.span("profile.kernel",
                              kernel="quant_matmul_dynamic_k", k=int(k),
                              shape=shape["shape"]):
                    t = measure(f, x, w, jnp.int32(k), reps=reps,
                                warmup=warmup)
                rows.append(_row("quant_matmul_dynamic_k", terms32, t,
                                 **shape, k=int(k), interpret=False,
                                 format_bits=format_bits(k)))

        if "quant_matmul_format" in include:
            cands = list(blocks) if blocks is not None else \
                block_candidates(M, K, N)
            for (bm, bn, bk) in cands:
                f = jax.jit(lambda a, b, fmt, _bm=bm, _bn=bn, _bk=bk:
                            quant_matmul_format(a, b, fmt, block_m=_bm,
                                                block_n=_bn, block_k=_bk,
                                                interpret=interpret))
                for (fk, femax, femin) in formats:
                    fmt = jnp.asarray([fk, femax, femin], jnp.int32)
                    with obs.span("profile.kernel",
                                  kernel="quant_matmul_format",
                                  k=int(fk), block=f"{bm}x{bn}x{bk}",
                                  shape=shape["shape"]):
                        t = measure(f, x, w, fmt, reps=reps, warmup=warmup)
                    rows.append(_row(
                        "quant_matmul_format", terms32, t, **shape,
                        k=int(fk), emax=int(femax), emin=int(femin),
                        block=[bm, bn, bk], interpret=bool(interpret),
                        format_bits=format_bits(fk, femax, femin)))

        if "quant_matmul" in include:  # static-k Pallas kernel (opt-in)
            for k in ks:
                f = jax.jit(lambda a, b, _k=int(k): quant_matmul(
                    a, b, k=_k, interpret=interpret))
                with obs.span("profile.kernel", kernel="quant_matmul",
                              k=int(k), shape=shape["shape"]):
                    t = measure(f, x, w, reps=reps, warmup=warmup)
                rows.append(_row("quant_matmul", terms32, t, **shape,
                                 k=int(k), interpret=bool(interpret)))

    if "flash_decode" in include:
        for (B, S, Kh, G, D) in flash_shapes:
            kq, kk, kv = jax.random.split(jax.random.fold_in(key, S + D), 3)
            q = jax.random.normal(kq, (B, Kh, G, D), jnp.float32)
            kc = jax.random.normal(kk, (B, S, Kh, D), jnp.float32)
            vc = jax.random.normal(kv, (B, S, Kh, D), jnp.float32)
            lengths = jnp.full((B,), S, jnp.int32)
            bs = min(128, S)
            f = jax.jit(lambda *a: flash_decode_attention(
                *a, block_s=bs, interpret=interpret))
            terms = flash_decode_terms(B, S, Kh, G, D, 32.0, hw)
            with obs.span("profile.kernel", kernel="flash_decode",
                          shape=f"B{B}S{S}K{Kh}G{G}D{D}"):
                t = measure(f, q, kc, vc, lengths, reps=reps, warmup=warmup)
            rows.append(_row("flash_decode", terms, t,
                             B=B, S=S, K=Kh, G=G, D=D,
                             shape=f"B{B}S{S}K{Kh}G{G}D{D}",
                             block=[bs], interpret=bool(interpret)))
    return rows


# ---------------------------------------------------------------------------
# serving latency attribution
# ---------------------------------------------------------------------------

def profile_serving(arch: str = "qwen2_7b", max_layers: int = 2,
                    batch: int = 2, prefill_len: int = 8,
                    decode_steps: int = 8,
                    precision_k: Optional[int] = None,
                    registry=None) -> Dict[str, Any]:
    """Profile the real serving path end to end on the host mesh.

    Builds ``launch.serve.build_serve_steps`` for the arch's SMOKE config
    (layer count capped for CI), AOT-compiles prefill and decode with the
    lower/compile phases separately timed, counts jaxpr equations per jit,
    then runs one prefill + ``decode_steps`` decodes under trace spans.
    Latencies land in log-bucket histograms and come back as p50/p95/p99
    digests; compile-time and jaxpr-size gauges go to the active tracer."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs, obs
    from repro.launch import mesh as meshlib
    from repro.launch import serve as S
    from repro.models import transformer as T

    arch_cfg = configs.get(arch).SMOKE
    if max_layers:
        arch_cfg = dc.replace(
            arch_cfg, n_layers=min(arch_cfg.n_layers, int(max_layers)))
    sc = S.ServeConfig(arch=arch, batch=batch,
                       max_seq=prefill_len + decode_steps + 1,
                       prefill_len=prefill_len, precision_k=precision_k)
    from .metrics import MetricsRegistry
    reg = registry if registry is not None else MetricsRegistry()
    reg.meta.update(arch=arch, batch=batch, n_layers=arch_cfg.n_layers,
                    precision_k=precision_k)

    mesh = meshlib.make_host_mesh()
    out: Dict[str, Any] = {"arch": arch, "n_layers": arch_cfg.n_layers,
                           "batch": batch, "prefill_len": prefill_len,
                           "decode_steps": decode_steps,
                           "precision_k": precision_k}
    with mesh:
        prefill, decode, _ = S.build_serve_steps(arch_cfg, sc, mesh)
        params = T.init_params(jax.random.PRNGKey(0), arch_cfg)
        cache = T.init_cache(arch_cfg, sc.batch, sc.max_seq, jnp.float32)
        rng = np.random.RandomState(0)
        batch_in = {"tokens": jnp.asarray(
            rng.randint(0, arch_cfg.vocab, (sc.batch, sc.prefill_len)))}

        # compile-time + program-size gauges, per serving jit
        with obs.span("profile.serve_compile", stage="prefill"):
            pc = time_compile(prefill, params, cache, batch_in)
        js_pre = jaxpr_stats(prefill, params, cache, batch_in)
        obs.gauge("serve.prefill_compile_s", pc["compile_s"])
        obs.gauge("serve.prefill_jaxpr_eqns", js_pre["eqns"])
        reg.gauge("serve.prefill_compile_s", pc["compile_s"])
        reg.gauge("serve.prefill_jaxpr_eqns", js_pre["eqns"])

        db0 = {"tokens": jnp.zeros((sc.batch, 1), jnp.int32),
               "pos": jnp.asarray(sc.prefill_len, jnp.int32)}
        with obs.span("profile.serve_compile", stage="decode"):
            # decode's cache arg is donated; compile from shapes only
            dc_t0 = time.perf_counter()
            dlow = decode.lower(params, jax.eval_shape(lambda: cache), db0)
            dcomp_t = time.perf_counter()
            dlow.compile()
            dcomp = {"lower_s": dcomp_t - dc_t0,
                     "compile_s": time.perf_counter() - dcomp_t}
        js_dec = jaxpr_stats(decode, params, jax.eval_shape(lambda: cache),
                             db0)
        obs.gauge("serve.decode_compile_s", dcomp["compile_s"])
        obs.gauge("serve.decode_jaxpr_eqns", js_dec["eqns"])
        reg.gauge("serve.decode_compile_s", dcomp["compile_s"])
        reg.gauge("serve.decode_jaxpr_eqns", js_dec["eqns"])

        # timed serving loop under spans
        t0 = time.perf_counter()
        with obs.span("serve.prefill", arch=arch, batch=sc.batch,
                      prefill_len=sc.prefill_len):
            logits, cache = prefill(params, cache, batch_in)
            jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        reg.observe("serve.prefill_latency_s", t_prefill)

        tok = jnp.argmax(logits[:, -1, :], axis=-1)
        # one untimed decode absorbs first-dispatch cost (executable load,
        # eager-op compiles) so the percentile digest reflects steady state
        tok, cache = decode(params, cache, {
            "tokens": tok[:, None],
            "pos": jnp.asarray(sc.prefill_len, jnp.int32)})
        jax.block_until_ready(tok)
        for i in range(decode_steps):
            db = {"tokens": tok[:, None],
                  "pos": jnp.asarray(sc.prefill_len + 1 + i, jnp.int32)}
            td = time.perf_counter()
            with obs.span("serve.decode", step=i):
                tok, cache = decode(params, cache, db)
                jax.block_until_ready(tok)
            reg.observe("serve.decode_latency_s",
                        time.perf_counter() - td)

    hp = reg.histograms["serve.decode_latency_s"]
    out.update({
        "prefill": {"latency_s": t_prefill,
                    "compile_s": pc["compile_s"], "lower_s": pc["lower_s"],
                    "jaxpr_eqns": js_pre["eqns"],
                    "tokens_per_s": sc.batch * sc.prefill_len / t_prefill},
        "decode": {"percentiles": hp.percentiles(),
                   "mean_s": hp.mean, "count": hp.count,
                   "compile_s": dcomp["compile_s"],
                   "lower_s": dcomp["lower_s"],
                   "jaxpr_eqns": js_dec["eqns"],
                   "tokens_per_s": (sc.batch * hp.count / hp.sum
                                    if hp.sum > 0 else 0.0)},
    })
    return out
