"""parallel subsystem."""
