"""Sharding rule engine: map every tensor in the system onto the mesh.

Strategy (hybrid FSDP × TP × EP, DESIGN.md §5):
  * parameters: greedy largest-divisible-dims assignment — "model" goes to
    the biggest tensor-parallel-friendly dim (d_ff, experts, vocab,
    heads·head_dim), "data" (and "pod" when present and the tensor is
    large) to the next — i.e. fully-sharded (ZeRO-3-like) storage; XLA SPMD
    inserts the per-layer all-gathers;
  * activations/batch: batch over ("pod","data"); fall back to sequence
    sharding when the batch doesn't divide (long_500k has batch 1);
  * KV caches: batch over "data" when divisible else sequence; KV heads
    over "model" when divisible else sequence over "model" (XLA then
    builds the flash-style distributed softmax reductions).

Everything returns NamedShardings so the same rules serve jit in_shardings,
device_put, and the dry-run's ShapeDtypeStruct annotations.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# -- helpers ---------------------------------------------------------------

def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _greedy_param_spec(shape, mesh: Mesh, *, stacked: bool,
                       min_shard_bytes: int = 1 << 20,
                       axes=None) -> P:
    """Assign mesh axes to tensor dims, biggest-first.

    ``stacked``: leading dim is the scanned layer axis — never sharded
    (scan iterates it). Small tensors (< min_shard_bytes) replicate: the
    all-gather latency isn't worth it. ``axes`` restricts which mesh axes
    may be used (serving passes ("model",)).
    """
    dims = list(shape)
    start = 1 if stacked and len(dims) > 1 else 0
    nbytes = int(np.prod(shape)) * 4
    spec = [None] * len(dims)
    if nbytes < min_shard_bytes:
        return P(*spec)
    # order candidate dims by size, largest first
    order = sorted(range(start, len(dims)), key=lambda i: -dims[i])
    cand = axes if axes is not None else ("model", "data", "pod")
    axes_to_place = [a for a in cand if _axis_size(mesh, a) > 1]
    for ax in axes_to_place:
        sz = _axis_size(mesh, ax)
        for i in order:
            if spec[i] is None and dims[i] % sz == 0 and dims[i] >= sz:
                spec[i] = ax
                break
    return P(*spec)


def shard_params(params, mesh: Mesh, *, model_only: bool = False) -> Any:
    """NamedSharding pytree for a parameter tree (stacked layer dicts).

    model_only=True keeps parameters resident on the "model" axis and
    REPLICATED across data/pod — the serving policy (§Perf): a data-axis-
    sharded parameter must be all-gathered on every forward pass, which
    dominates decode's collective term; replication trades HBM capacity
    (P/16 per chip instead of P/256) for zero per-step parameter traffic.
    """
    def one(path, leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        keys = [getattr(k, "key", str(k)) for k in path]
        stacked = any(k in ("layers", "enc_layers", "cross") for k in keys)
        # expert-parallel weights: shard the expert dim over "model" (the
        # shard_map MoE path requires it); [L, E, d, ff] → P(None,"model",..)
        if "moe" in keys and keys[-1] in ("w_gate", "w_up", "w_down"):
            e_dim = 1 if stacked else 0
            m_sz = _axis_size(mesh, "model")
            if len(shape) > e_dim and shape[e_dim] % m_sz == 0 and m_sz > 1:
                spec = [None] * len(shape)
                spec[e_dim] = "model"
                # remaining big dims may still take data (ZeRO storage)
                if not model_only:
                    d_sz = _axis_size(mesh, "data")
                    for i in sorted(range(e_dim + 1, len(shape)),
                                    key=lambda i: -shape[i]):
                        if shape[i] % d_sz == 0 and d_sz > 1:
                            spec[i] = "data"
                            break
                return NamedSharding(mesh, P(*spec))
        spec = _greedy_param_spec(shape, mesh, stacked=stacked,
                                  axes=("model",) if model_only else None)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(mesh: Mesh, batch: int, seq: int) -> P:
    """[B, S] token batches. Batch goes over every DP axis that divides it;
    axes the batch cannot absorb (e.g. long_500k's batch of 1) move to the
    sequence dim — sequence parallelism as the fallback."""
    dp = [a for a in ("pod", "data") if _axis_size(mesh, a) > 1]
    b_use, s_use = [], []
    rem_b, rem_s = batch, seq
    for a in dp:
        sz = _axis_size(mesh, a)
        if rem_b % sz == 0 and rem_b >= sz:
            b_use.append(a)
            rem_b //= sz
        elif rem_s % sz == 0 and rem_s >= sz:
            s_use.append(a)
            rem_s //= sz
    b_axes = tuple(b_use) if b_use else None
    s_axes = tuple(s_use) if s_use else None
    return P(b_axes, s_axes)


def shard_batch(mesh: Mesh, batch: int, seq: int) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, batch, seq))


def cache_spec(mesh: Mesh, cache_leaf_shape, kind: str) -> P:
    """Decode caches, stacked [L, B, Smax, ...]:
      gqa k/v: [L, B, S, K, Dh]; mla: [L, B, S, R]; rwkv S: [L,B,H,C,C].
    """
    shape = list(cache_leaf_shape)
    spec = [None] * len(shape)
    if len(shape) < 3:
        return P(*spec)
    d_sz = _axis_size(mesh, "data")
    m_sz = _axis_size(mesh, "model")
    B = shape[1]
    # batch over data when divisible, else seq over data
    if B % d_sz == 0 and B >= d_sz:
        spec[1] = "data"
        seq_data = False
    else:
        seq_data = True
    if kind == "gqa":  # [L,B,S,K,Dh]
        S, K = shape[2], shape[3]
        if K % m_sz == 0 and K >= m_sz:
            spec[3] = "model"
            if seq_data and S % d_sz == 0:
                spec[2] = "data"
        elif S % (m_sz * (d_sz if seq_data else 1)) == 0:
            spec[2] = ("data", "model") if seq_data else "model"
        elif S % m_sz == 0:
            spec[2] = "model"
    elif kind == "mla":  # [L,B,S,R]
        S = shape[2]
        div = m_sz * (d_sz if seq_data else 1)
        if S % div == 0:
            spec[2] = ("data", "model") if seq_data else "model"
        elif S % m_sz == 0:
            spec[2] = "model"
    elif kind == "rwkv":  # [L,B,H,C,C] or [L,B,d]
        if len(shape) >= 4 and shape[2] % m_sz == 0:
            spec[2] = "model"
        elif len(shape) == 3 and shape[2] % m_sz == 0:
            spec[2] = "model"
    return P(*spec)


def shard_cache(cache, mesh: Mesh, cfg) -> Any:
    def one(path, leaf):
        key = getattr(path[-1], "key", str(path[-1]))
        if key in ("k", "v"):
            kind = "mla" if getattr(cfg, "mla", False) else "gqa"
        elif key in ("S", "h_ssm"):
            kind = "rwkv"
        else:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_spec(mesh, leaf.shape, kind))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# -- serving (bitwise-safe) rules ------------------------------------------
#
# The continuous-batching engine asserts BIT-FOR-BIT equality against a
# single-device eager reference, which outlaws any partitioning that splits
# a reduction (partial sums reassociate the accumulation): no contraction-
# dim weight sharding, no sequence-over-"model" KV (the distributed-softmax
# pattern), no batch-matmul contraction splits. What remains is exactly
# Megatron column parallelism (shard each weight's OUTPUT dim over "model")
# plus lane parallelism (shard batch/cache lanes over "data") — every
# collective XLA inserts is then an all-gather/slice of exact values.

def serving_param_spec(path_keys, shape, mesh: Mesh, *,
                       min_shard_bytes: int = 1 << 16) -> P:
    """Column-parallel spec for one serving parameter.

    ``embed``/``head`` tables [vocab, d] shard the vocab dim (the embed
    gather and the head einsum's non-contracting dim); every other ≥2-D
    weight shards its LAST dim (the matmul output dim — never the
    contraction). Stacked [L, ...] tensors skip the scanned leading axis.
    1-D tensors (norm scales, biases) replicate.
    """
    m_sz = _axis_size(mesh, "model")
    spec = [None] * len(shape)
    nbytes = int(np.prod(shape)) * 4 if shape else 0
    if m_sz <= 1 or len(shape) < 2 or nbytes < min_shard_bytes:
        return P(*spec)
    stacked = any(k in ("layers", "enc_layers", "cross") for k in path_keys)
    if path_keys and path_keys[-1] in ("embed", "head"):
        dim = 1 if stacked else 0
    else:
        dim = len(shape) - 1
    if shape[dim] % m_sz == 0 and shape[dim] >= m_sz:
        spec[dim] = "model"
    return P(*spec)


def shard_params_serving(params, mesh: Mesh, *,
                         min_shard_bytes: int = 1 << 16) -> Any:
    """NamedSharding pytree under the bitwise-safe serving rules."""
    def one(path, leaf):
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        keys = [getattr(k, "key", str(k)) for k in path]
        return NamedSharding(mesh, serving_param_spec(
            keys, shape, mesh, min_shard_bytes=min_shard_bytes))

    return jax.tree_util.tree_map_with_path(one, params)


def lane_cache_spec(mesh: Mesh, leaf_shape, key: str) -> P:
    """Per-lane KV cache spec, stacked [L, B, Smax, ...]: lanes over
    "data" when divisible, KV heads over "model" when divisible — and
    NEVER the sequence dim over "model" (a sequence split makes XLA build
    the distributed softmax, whose reduction order breaks the engine's
    bit-for-bit contract)."""
    shape = list(leaf_shape)
    spec = [None] * len(shape)
    if len(shape) < 2:
        return P(*spec)
    d_sz = _axis_size(mesh, "data")
    m_sz = _axis_size(mesh, "model")
    B = shape[1]
    if d_sz > 1 and B % d_sz == 0 and B >= d_sz:
        spec[1] = "data"
    if key in ("k", "v") and len(shape) == 5:       # gqa [L,B,S,K,Dh]
        K = shape[3]
        if m_sz > 1 and K % m_sz == 0 and K >= m_sz:
            spec[3] = "model"
    return P(*spec)


def shard_cache_serving(cache, mesh: Mesh) -> Any:
    def one(path, leaf):
        key = getattr(path[-1], "key", str(path[-1]))
        return NamedSharding(mesh, lane_cache_spec(mesh, leaf.shape, key))

    return jax.tree_util.tree_map_with_path(one, cache)


def lane_batch_sharding(mesh: Mesh, n_lanes: int) -> NamedSharding:
    """[B] / [B, 1] decode-lane vectors: lanes over "data" when divisible."""
    d_sz = _axis_size(mesh, "data")
    if d_sz > 1 and n_lanes % d_sz == 0 and n_lanes >= d_sz:
        return NamedSharding(mesh, P("data"))
    return NamedSharding(mesh, P())
