"""Checkpointing: atomic step manifests, async snapshots, restore, elastic.

Layout:  <dir>/step_<N>/{arrays.npz, manifest.json}
  * write to step_<N>.tmp, fsync, rename — a crash mid-write never corrupts
    the latest valid checkpoint (restore scans for the newest complete
    manifest);
  * async mode hands the (host-local, already device_get) arrays to a
    writer thread so the train loop overlaps I/O with compute;
  * restore is *resharding*: arrays are loaded host-side and device_put
    with whatever shardings the (possibly different-size) current mesh
    dictates — this is the elastic-rescale path runtime.elastic uses.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: dict):
    def one(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        return arr
    return jax.tree_util.tree_map_with_path(one, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = True,
             metadata: Optional[dict] = None):
        """Snapshot ``state`` (any pytree). Async unless blocking."""
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        if blocking:
            self._write(step, host_state, metadata or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, metadata or {}))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, metadata: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_arrays": len(flat),
            "bytes": int(sum(a.nbytes for a in flat.values())),
            **metadata,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        """Load into the structure of ``template``; optionally device_put
        with ``shardings`` (pytree of NamedSharding) — the elastic path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = dict(np.load(os.path.join(path, "arrays.npz")))
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest
