"""checkpoint subsystem."""
