"""repro — rigorous FP precision/accuracy analysis for deep learning, in JAX.

Reproduction and scale-out of Lauter & Volkova (2020), "A Framework for
Semi-Automatic Precision and Accuracy Analysis for Fast and Rigorous Deep
Learning": a CAA (combined affine + interval) arithmetic engine that bounds
FP rounding error through DNN inference, parameterised by precision
u = 2^{1-k}, plus the precision-tailoring end-game (p* margins → required k)
— integrated as a first-class feature of a multi-pod JAX training/serving
framework (10 LM-family architectures, 512-chip mesh dry-runs, Pallas TPU
kernels for the rigorous/low-precision GEMM hot spots).

NOTE: float64 must be enabled before any jax usage for the analysis engine;
importing repro does this.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
