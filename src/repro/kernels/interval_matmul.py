"""Pallas TPU kernel: interval GEMM (the rigorous-inference hot spot).

Computes, for interval activations [lo, hi] and a constant weight matrix W,
the enclosure of x@W by sign-splitting W — plus the magnitude majorant
|x|@|W| needed by the CAA rounding terms. The three GEMMs share the same
operand tiles, so one HBM pass feeds 3× MXU work: the kernel is
*bandwidth*-optimal for rigorous inference (the naive composition reads x
and W three times).

Design for TPU (DESIGN.md hardware-adaptation):
  * grid (M/bm, N/bn, K/bk), K innermost so accumulators live in VMEM
    scratch across the K loop;
  * block sizes default to 128/256 multiples — MXU-aligned (128×128
    systolic) and VPU-lane aligned (8×128);
  * sign-split (W⁺ = max(W,0), W⁻ = min(W,0)) computed on the tile in
    registers, never materialised in HBM.

Directed rounding: TPUs have no rounding-mode control; following the same
strategy as the f64 engine (interval.py), the wrapper widens the result
outward by γ-slop · mag — sound because |fl(e) − e| ≤ γ_K · (|x|@|W|) for
every accumulation order XLA/MXU can pick, and mag is computed by this very
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interval_matmul_kernel(lo_ref, hi_ref, w_ref, out_lo_ref, out_hi_ref,
                            out_mag_ref, acc_lo, acc_hi, acc_mag, *,
                            n_k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_lo[...] = jnp.zeros_like(acc_lo)
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_mag[...] = jnp.zeros_like(acc_mag)

    lo = lo_ref[...]
    hi = hi_ref[...]
    w = w_ref[...]
    wp = jnp.maximum(w, 0.0)
    wm = jnp.minimum(w, 0.0)
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    # interval product bounds under sign-split
    acc_lo[...] += dot(lo, wp) + dot(hi, wm)
    acc_hi[...] += dot(hi, wp) + dot(lo, wm)
    # magnitude majorant |x|_sup @ |W|
    m = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
    acc_mag[...] += dot(m, jnp.abs(w))

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _done():
        out_lo_ref[...] = acc_lo[...].astype(out_lo_ref.dtype)
        out_hi_ref[...] = acc_hi[...].astype(out_hi_ref.dtype)
        out_mag_ref[...] = acc_mag[...].astype(out_mag_ref.dtype)


def interval_matmul(lo: jax.Array, hi: jax.Array, w: jax.Array, *,
                    block_m: int = 256, block_n: int = 256,
                    block_k: int = 512, interpret: bool = False):
    """[M,K] interval × [K,N] constant → (lo', hi', mag') each [M,N].

    The returned bounds are the raw f32 accumulations; apply the γ-slop
    widening (ops.interval_matmul_rigorous) before using them as a rigorous
    enclosure.
    """
    M, K = lo.shape
    K2, N = w.shape
    assert K == K2 and hi.shape == lo.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes ({M},{K})x({K},{N}) must tile by ({bm},{bn},{bk}); "
        "use ops.interval_matmul_rigorous which pads")
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    kernel = functools.partial(_interval_matmul_kernel, n_k_steps=nk)
    out_shape = [jax.ShapeDtypeStruct((M, N), jnp.float32)] * 3
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(lo, hi, w)
