"""Pallas TPU kernel: fused value + absolute-error-bound GEMM.

The CAA dot-product rule (repro.core.caa.contract) needs, per layer,
   val  = x @ W
   err' = (δ_x + g·|x|) @ |W|        [units of u; g = γ(K) rounding factor]
i.e. two GEMMs over the same tiles. Executed naively that is two HBM passes
over x/W; fused here into one kernel with two VMEM accumulators, the
arithmetic-error pipeline runs at the memory cost of ordinary inference + 1
extra operand (δ_x) — this is the kernel that makes *rigorous serving*
(inference that ships an error bar with every logit) affordable on TPU.

g is a compile-time constant (baked into the kernel): the analysis fixes
the accumulation order and K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _caa_matmul_kernel(x_ref, d_ref, w_ref, val_ref, err_ref,
                       acc_val, acc_err, *, n_k_steps: int, g: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_val[...] = jnp.zeros_like(acc_val)
        acc_err[...] = jnp.zeros_like(acc_err)

    x = x_ref[...]
    d = d_ref[...]
    w = w_ref[...]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    acc_val[...] += dot(x, w)
    acc_err[...] += dot(d + g * jnp.abs(x), jnp.abs(w))

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _done():
        val_ref[...] = acc_val[...].astype(val_ref.dtype)
        err_ref[...] = acc_err[...].astype(err_ref.dtype)


def caa_matmul(x: jax.Array, dbar: jax.Array, w: jax.Array, *, g: float,
               block_m: int = 256, block_n: int = 256, block_k: int = 512,
               interpret: bool = False):
    """x, dbar: [M,K]; w: [K,N]; returns (val, dbar') both [M,N]."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and dbar.shape == x.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    kernel = functools.partial(_caa_matmul_kernel, n_k_steps=nk, g=float(g))
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.float32),
            jax.ShapeDtypeStruct((M, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dbar, w)
