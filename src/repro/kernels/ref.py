"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

Semantics match the CAA engine's rules so the kernels slot into the rigorous
pipeline:
  interval_matmul — IA enclosure of x@W for interval x, constant W
                    (sign-split), plus the f64/f32 evaluation slop.
  caa_matmul      — value + absolute-error-bound propagation through a GEMM
                    (the tensorised γ rule of repro.core.caa.contract).
  quant_matmul    — emulated k-bit-mantissa GEMM: operands RNE-rounded to k
                    bits, f32 accumulation (the MXU model), result rounded
                    to k bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import _quantize_normal


def gamma_in_u(n: int, u: float) -> float:
    """γ_n in units of u (pairwise order is what the MXU tree does —
    callers pass the effective n)."""
    m = 0.5 * n * u
    return (0.5 * n) / (1.0 - m) if m < 1 else float("inf")


def interval_matmul_ref(lo: jax.Array, hi: jax.Array, w: jax.Array,
                        slop: float = 1e-6):
    """(lo', hi', mag') with lo' ≤ x@W ≤ hi' for all x in [lo, hi].

    mag' = |x|_sup @ |W| is the magnitude majorant used for rounding-error
    terms; the enclosure is widened by slop·mag to cover the kernel's own
    f32 arithmetic (γ_K of f32 ≪ 1e-6 for K ≤ 8192).
    """
    wp = jnp.maximum(w, 0.0)
    wm = jnp.minimum(w, 0.0)
    out_lo = lo @ wp + hi @ wm
    out_hi = hi @ wp + lo @ wm
    mag = jnp.maximum(jnp.abs(lo), jnp.abs(hi)) @ jnp.abs(w)
    return out_lo - slop * mag, out_hi + slop * mag, mag


def caa_matmul_ref(x: jax.Array, dbar: jax.Array, w: jax.Array,
                   g: float):
    """(val, dbar') where dbar' = (dbar + g·|x|) @ |W| — the fused form of
    the propagated-error + fresh-rounding terms (units of u)."""
    val = x @ w
    err = (dbar + g * jnp.abs(x)) @ jnp.abs(w)
    return val, err


def quant_matmul_ref(x: jax.Array, w: jax.Array, k: int):
    """Emulated k-bit GEMM: round inputs to k bits, accumulate in f32
    (MXU semantics), round the result once."""
    xq = _quantize_normal(x.astype(jnp.float32), k)
    wq = _quantize_normal(w.astype(jnp.float32), k)
    out = jnp.matmul(xq, wq, preferred_element_type=jnp.float32)
    return _quantize_normal(out, k)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array):
    """Naive decode attention oracle: q [B,K,G,D], k/v [B,S,K,D]."""
    B, K, G, D = q.shape
    S = k.shape[1]
    s = jnp.einsum("bkgd,bskd->bkgs", q, k) * (D ** -0.5)
    pos = jnp.arange(S)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32)).astype(q.dtype)
