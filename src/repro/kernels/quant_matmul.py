"""Pallas TPU kernel: emulated k-bit-mantissa GEMM (low-precision serving).

Once the CAA analysis has certified a precision k (Table-I end-game), the
serving path runs with operands rounded to k mantissa bits. On real silicon
that would be a narrow datapath; on today's TPUs we *emulate*: RNE-truncate
the f32 mantissa to k bits in-register (bit twiddling on the tile — zero
extra HBM traffic), accumulate on the MXU in f32, and round the result once.
That matches the `quantize.quantize`/MXU model the analysis assumes
(`emulate_accum=False` mode), so certified bounds apply to what this kernel
computes.

The RNE bit-twiddle: with s = 23-(k-1) dropped bits,
   q = (b + ((b >> s) & 1) + (2^{s-1} - 1)) & ~(2^s - 1)
carries into the exponent correctly on mantissa overflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rne_to_k_bits(x, k: int):
    if k >= 24:
        return x
    s = 24 - k
    one = jnp.uint32(1)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    half = (one << (s - 1)) - one
    lsb = (bits >> s) & one
    q = (bits + half + lsb) & ~((one << s) - one)
    out = jax.lax.bitcast_convert_type(q, jnp.float32)
    return jnp.where(jnp.isfinite(x), out, x)


def _quant_matmul_kernel(x_ref, w_ref, o_ref, acc, *, n_k_steps: int, k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    xq = _rne_to_k_bits(x_ref[...].astype(jnp.float32), k)
    wq = _rne_to_k_bits(w_ref[...].astype(jnp.float32), k)
    acc[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _done():
        o_ref[...] = _rne_to_k_bits(acc[...], k).astype(o_ref.dtype)


def quant_matmul_dynamic_k(x: jax.Array, w: jax.Array, k) -> jax.Array:
    """Emulated k-bit GEMM with ``k`` as a (possibly traced) scalar argument.

    Same rounding semantics as :func:`quant_matmul` — RNE-truncate both
    operands to k mantissa bits, accumulate in f32, round the result once —
    but the dropped-bit count is computed in integer arithmetic
    (:func:`repro.core.quantize.quantize_to_k`), so a single jit compilation
    serves every k: the mixed-precision serving path feeds per-layer k out of
    a scanned array, and the certificate probe ladder sweeps a whole k grid,
    neither paying a recompile per precision.
    """
    from repro.core.quantize import quantize_to_k

    xq = quantize_to_k(jnp.asarray(x, jnp.float32), k)
    wq = quantize_to_k(jnp.asarray(w, jnp.float32), k)
    out = jnp.matmul(xq, wq, preferred_element_type=jnp.float32)
    return quantize_to_k(out, k)


def quant_matmul(x: jax.Array, w: jax.Array, *, k: int,
                 block_m: int = 256, block_n: int = 256, block_k: int = 512,
                 interpret: bool = False):
    """Emulated k-bit GEMM: [M,K] @ [K,N] → [M,N] (f32 carrier)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    kernel = functools.partial(_quant_matmul_kernel, n_k_steps=nk, k=int(k))
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w)
