"""Pallas TPU kernel: emulated k-bit-mantissa GEMM (low-precision serving).

Once the CAA analysis has certified a precision k (Table-I end-game), the
serving path runs with operands rounded to k mantissa bits. On real silicon
that would be a narrow datapath; on today's TPUs we *emulate*: RNE-truncate
the f32 mantissa to k bits in-register (bit twiddling on the tile — zero
extra HBM traffic), accumulate on the MXU in f32, and round the result once.
That matches the `quantize.quantize`/MXU model the analysis assumes
(`emulate_accum=False` mode), so certified bounds apply to what this kernel
computes.

The RNE bit-twiddle: with s = 23-(k-1) dropped bits,
   q = (b + ((b >> s) & 1) + (2^{s-1} - 1)) & ~(2^s - 1)
carries into the exponent correctly on mantissa overflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rne_to_k_bits(x, k: int):
    if k >= 24:
        return x
    s = 24 - k
    one = jnp.uint32(1)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    half = (one << (s - 1)) - one
    lsb = (bits >> s) & one
    q = (bits + half + lsb) & ~((one << s) - one)
    out = jax.lax.bitcast_convert_type(q, jnp.float32)
    return jnp.where(jnp.isfinite(x), out, x)


def _quant_matmul_kernel(x_ref, w_ref, o_ref, acc, *, n_k_steps: int, k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    xq = _rne_to_k_bits(x_ref[...].astype(jnp.float32), k)
    wq = _rne_to_k_bits(w_ref[...].astype(jnp.float32), k)
    acc[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _done():
        o_ref[...] = _rne_to_k_bits(acc[...], k).astype(o_ref.dtype)


def block_candidates(M: int, K: int, N: int, *,
                     tiles=(128, 256, 512), max_candidates: int = 4):
    """Valid (block_m, block_n, block_k) Pallas tile candidates for an
    [M,K]@[K,N] GEMM — the autotune axis the kernel profiler sweeps.

    Candidates are built from MXU-friendly tile edges (capped to each
    dimension, which the kernels do anyway via ``min``), keeping only
    shapes that satisfy the kernels' divisibility contract, largest tiles
    first (fewer grid steps → usually fastest), deduplicated, truncated to
    ``max_candidates`` so a profile sweep stays bounded."""
    def _edges(dim):
        opts = [t for t in tiles if t <= dim and dim % t == 0]
        return opts or [dim]

    out, seen = [], set()
    for bk in sorted(_edges(K), reverse=True):
        for bm in sorted(_edges(M), reverse=True):
            for bn in sorted(_edges(N), reverse=True):
                cand = (bm, bn, bk)
                if cand not in seen:
                    seen.add(cand)
                    out.append(cand)
    return out[:max_candidates]


def quant_matmul_dynamic_k(x: jax.Array, w: jax.Array, k) -> jax.Array:
    """Emulated k-bit GEMM with ``k`` as a (possibly traced) scalar argument.

    Same rounding semantics as :func:`quant_matmul` — RNE-truncate both
    operands to k mantissa bits, accumulate in f32, round the result once —
    but the dropped-bit count is computed in integer arithmetic
    (:func:`repro.core.quantize.quantize_to_k`), so a single jit compilation
    serves every k: the mixed-precision serving path feeds per-layer k out of
    a scanned array, and the certificate probe ladder sweeps a whole k grid,
    neither paying a recompile per precision.
    """
    from repro.core.quantize import quantize_to_k

    xq = quantize_to_k(jnp.asarray(x, jnp.float32), k)
    wq = quantize_to_k(jnp.asarray(w, jnp.float32), k)
    out = jnp.matmul(xq, wq, preferred_element_type=jnp.float32)
    return quantize_to_k(out, k)


def quant_matmul_format_ref(x: jax.Array, w: jax.Array, fmt,
                            has_subnormals: bool = True,
                            saturating: bool = True) -> jax.Array:
    """Eager full-format GEMM oracle: operands and result rounded into the
    custom (k, emax, emin) format via
    :func:`repro.core.quantize.quantize_to_format`, f32 accumulation.

    ``fmt`` is an i32[3] array/sequence (k, emax, emin) — possibly traced,
    so one jit compilation serves every certified format (the serving
    backend's per-scope maps and the scanned per-layer arrays both rely on
    it); the subnormal/saturation flags are static (a v3 serving map is
    flag-uniform by construction). This is the function the scalar-prefetch
    Pallas kernel below must match bitwise.
    """
    from repro.core.quantize import quantize_to_format

    fmt = jnp.asarray(fmt, jnp.int32)
    k, emax, emin = fmt[0], fmt[1], fmt[2]
    q = lambda v: quantize_to_format(v, k, emax, emin,
                                     has_subnormals, saturating)
    out = jnp.matmul(q(jnp.asarray(x, jnp.float32)),
                     q(jnp.asarray(w, jnp.float32)),
                     preferred_element_type=jnp.float32)
    return q(out)


def _quant_matmul_format_kernel(fmt_ref, x_ref, w_ref, o_ref, acc, *,
                                n_k_steps: int, has_subnormals: bool,
                                saturating: bool):
    from repro.core.quantize import quantize_to_format

    k, emax, emin = fmt_ref[0], fmt_ref[1], fmt_ref[2]
    q = lambda v: quantize_to_format(v, k, emax, emin,
                                     has_subnormals, saturating)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(q(x_ref[...].astype(jnp.float32)),
                        q(w_ref[...].astype(jnp.float32)),
                        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _done():
        o_ref[...] = q(acc[...]).astype(o_ref.dtype)


def quant_matmul_format(x: jax.Array, w: jax.Array, fmt, *,
                        has_subnormals: bool = True, saturating: bool = True,
                        block_m: int = 256, block_n: int = 256,
                        block_k: int = 512, interpret: bool = False):
    """Emulated custom-format GEMM, format delivered by SCALAR PREFETCH.

    ``fmt`` = i32[3] (k, emax, emin). The triple rides in SMEM via
    ``pltpu.PrefetchScalarGridSpec`` and is read before the tiles stream,
    so ONE compiled kernel serves every certified format — swapping the
    serving format (or serving a per-scope v3 map) costs zero recompiles,
    vs one full Mosaic compile per format for the static-``k`` kernel
    above (benchmarks/analysis_speed.py measures the difference). Rounding
    semantics are exactly :func:`quant_matmul_format_ref`'s; with a single
    K step (block_k ≥ K) the two are bitwise identical — the acceptance
    test for v3 certificates serves through both and compares bits.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    kernel = functools.partial(_quant_matmul_format_kernel, n_k_steps=nk,
                               has_subnormals=has_subnormals,
                               saturating=saturating)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, fmt_ref: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk, fmt_ref: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, fmt_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(fmt, jnp.int32), x, w)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ target (dim itself when small)."""
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return dim


def quant_matmul_format_dispatch(x: jax.Array, w: jax.Array, fmt,
                                 has_subnormals: bool = True,
                                 saturating: bool = True, *,
                                 force_kernel=None,
                                 interpret: bool = False) -> jax.Array:
    """Serving dispatch for the full-format GEMM: the scalar-prefetch
    Pallas kernel on TPU, :func:`quant_matmul_format_ref` elsewhere.

    Batched ``x`` ([..., K]) is flattened to [M, K] for the kernel and
    restored after. The kernel always runs with a SINGLE K step
    (block_k = K) so its accumulation order — and therefore its bits —
    match the eager reference exactly; the differential test serves the
    same GEMM through both paths and compares bits. ``force_kernel``
    overrides the platform check (tests exercise the kernel in interpret
    mode on CPU)."""
    use_kernel = force_kernel
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return quant_matmul_format_ref(x, w, fmt,
                                       has_subnormals=has_subnormals,
                                       saturating=saturating)
    lead = x.shape[:-1]
    K = x.shape[-1]
    M = 1
    for d in lead:
        M *= d
    N = w.shape[-1]
    out = quant_matmul_format(
        jnp.asarray(x, jnp.float32).reshape(M, K), jnp.asarray(w, jnp.float32),
        fmt, has_subnormals=has_subnormals, saturating=saturating,
        block_m=_pick_block(M, 256), block_n=_pick_block(N, 256),
        block_k=K, interpret=interpret)
    return out.reshape(*lead, N)


def quant_matmul(x: jax.Array, w: jax.Array, *, k: int,
                 block_m: int = 256, block_n: int = 256, block_k: int = 512,
                 interpret: bool = False):
    """Emulated k-bit GEMM: [M,K] @ [K,N] → [M,N] (f32 carrier)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    kernel = functools.partial(_quant_matmul_kernel, n_k_steps=nk, k=int(k))
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w)
