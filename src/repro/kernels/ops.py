"""Jit'd public wrappers over the Pallas kernels.

Handle padding to MXU-aligned tiles (zeros are absorbing for all three
kernels: zero rows/cols contribute zero to every accumulator), batch-dim
flattening, dtype plumbing, and the rigorous γ-slop widening that turns the
raw interval GEMM into a sound enclosure. ``interpret`` defaults to True on
CPU (this container) and False on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .caa_matmul import caa_matmul
from .interval_matmul import interval_matmul
from .quant_matmul import quant_matmul


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x, m_mult, n_mult):
    M, N = x.shape
    pm = (-M) % m_mult
    pn = (-N) % n_mult
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _blocks(M, N, K, bm, bn, bk):
    return min(bm, M), min(bn, N), min(bk, K)


def _flatten_batch(x):
    """[..., K] → ([T, K], unflatten)."""
    lead = x.shape[:-1]
    T = 1
    for d in lead:
        T *= d
    return x.reshape(T, x.shape[-1]), lead


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def interval_matmul_rigorous(lo, hi, w, *, block_m=256, block_n=256,
                             block_k=512, interpret=None):
    """Rigorous interval GEMM: [..., K] interval × [K, N] → Interval-ish
    (lo', hi') with the kernel's own f32 accumulation error folded in."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    lo2, lead = _flatten_batch(jnp.asarray(lo, jnp.float32))
    hi2, _ = _flatten_batch(jnp.asarray(hi, jnp.float32))
    w = jnp.asarray(w, jnp.float32)
    M, K = lo2.shape
    N = w.shape[1]
    bm, bn, bk = _blocks(M, N, K, block_m, block_n, block_k)
    lo_p = _pad_to(lo2, bm, bk)
    hi_p = _pad_to(hi2, bm, bk)
    w_p = _pad_to(w, bk, bn)
    out_lo, out_hi, out_mag = interval_matmul(
        lo_p, hi_p, w_p, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret)
    out_lo = out_lo[:M, :N]
    out_hi = out_hi[:M, :N]
    out_mag = out_mag[:M, :N]
    # γ-slop: the kernel's f32 accumulation error (any order) ≤ γ_{2K+2}·mag
    g = ref.gamma_in_u(2 * K + 2, 2.0 ** -23) * 2.0 ** -23
    out_lo = out_lo - g * out_mag
    out_hi = out_hi + g * out_mag
    return (out_lo.reshape(*lead, N), out_hi.reshape(*lead, N),
            out_mag.reshape(*lead, N))


@functools.partial(jax.jit, static_argnames=("g", "block_m", "block_n",
                                             "block_k", "interpret"))
def caa_matmul_fused(x, dbar, w, *, g: float, block_m=256, block_n=256,
                     block_k=512, interpret=None):
    """Fused value+error GEMM: returns (val, dbar') for [..., K] @ [K, N]."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    x2, lead = _flatten_batch(jnp.asarray(x, jnp.float32))
    d2, _ = _flatten_batch(jnp.asarray(dbar, jnp.float32))
    w = jnp.asarray(w, jnp.float32)
    M, K = x2.shape
    N = w.shape[1]
    bm, bn, bk = _blocks(M, N, K, block_m, block_n, block_k)
    val, err = caa_matmul(_pad_to(x2, bm, bk), _pad_to(d2, bm, bk),
                          _pad_to(w, bk, bn), g=g, block_m=bm, block_n=bn,
                          block_k=bk, interpret=interpret)
    return (val[:M, :N].reshape(*lead, N), err[:M, :N].reshape(*lead, N))


@functools.partial(jax.jit, static_argnames=("k", "block_m", "block_n",
                                             "block_k", "interpret"))
def quant_matmul_emulated(x, w, *, k: int, block_m=256, block_n=256,
                          block_k=512, interpret=None):
    """Emulated k-bit-mantissa GEMM for the certified low-precision path."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    x2, lead = _flatten_batch(jnp.asarray(x, jnp.float32))
    w = jnp.asarray(w, jnp.float32)
    M, K = x2.shape
    N = w.shape[1]
    bm, bn, bk = _blocks(M, N, K, block_m, block_n, block_k)
    out = quant_matmul(_pad_to(x2, bm, bk), _pad_to(w, bk, bn), k=k,
                       block_m=bm, block_n=bn, block_k=bk,
                       interpret=interpret)
    return out[:M, :N].reshape(*lead, N)
