"""Pallas TPU kernels for the rigorous/low-precision GEMM hot spots.

  interval_matmul — interval GEMM (sign-split) + magnitude majorant,
                    3 GEMMs per HBM pass (bandwidth-optimal rigorous
                    inference)
  caa_matmul      — fused value + absolute-error-bound GEMM
  quant_matmul    — emulated k-bit-mantissa GEMM (certified serving)
  flash_decode    — online-softmax GQA decode attention (streams the KV
                    cache once; VMEM-resident m/l/acc state)

ops.py: jit'd wrappers (padding, batching, rigorous widening).
ref.py: pure-jnp oracles; every kernel is swept against them in
tests/test_kernels.py (interpret mode on CPU, compiled on TPU).
"""
from . import ops, ref
from .flash_decode import flash_decode_attention
from .ops import caa_matmul_fused, interval_matmul_rigorous, quant_matmul_emulated
from .quant_matmul import quant_matmul_dynamic_k

__all__ = ["ops", "ref", "caa_matmul_fused", "interval_matmul_rigorous",
           "quant_matmul_emulated", "quant_matmul_dynamic_k",
           "flash_decode_attention"]
