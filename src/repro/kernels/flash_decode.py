"""Pallas TPU kernel: flash decode attention (online-softmax, GQA).

The decode hot spot: one query token per sequence attends to a [S, K, Dh]
KV cache. A naive lowering materialises the [H, S] score row in HBM and
reads the cache twice (scores, then values). This kernel streams the cache
once in S-blocks, keeping the online-softmax state (running max m, running
sum l, output accumulator) in VMEM scratch — the standard flash recurrence

    m' = max(m, rowmax(s));  α = e^{m−m'}
    l' = α·l + rowsum(e^{s−m'});  o' = α·o + e^{s−m'}·V_blk

TPU adaptation: grid (B, K, S/bs) with the S loop innermost so scratch
persists across cache blocks; block sizes 128-aligned for the MXU; GQA
groups (G = H/K query heads per KV head) processed together so the kv
block is read once per group. Variable sequence lengths are masked from a
scalar-prefetched length vector.

The CERTIFICATE-AWARE variant (:func:`flash_decode_certified`) additionally
rounds the q/k/v tiles into a certified custom (k, emax, emin) format
in-register before the MXU contractions, with the triple delivered by
SCALAR PREFETCH exactly like ``quant_matmul_format`` — so ONE compiled
kernel serves every certified format and every per-layer lane of a v3
serving map. :func:`flash_decode_quantized_ref` is the eager oracle
(bitwise-identical with a single S block — the off-TPU serving fallback),
and :func:`certified_decode_attention` is the dispatch the serving
backends call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, n_s_steps: int,
                         block_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                       # [G, D]
    k = k_ref[0, :, 0, :]                 # [bs, D]
    v = v_ref[0, :, 0, :]                 # [bs, D]
    length = len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bs]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG)

    m_prev = m_ref[...]                   # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                # [G, bs]
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_ref[...] + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s_idx == n_s_steps - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_decode_attention(q, k, v, lengths, *, block_s: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: [B, K, G, D] (grouped query heads); k, v: [B, S, K, D];
    lengths: [B] valid cache lengths. Returns [B, K, G, D]."""
    B, K, G, D = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0
    n_s = S // bs
    scale = D ** -0.5
    kernel = functools.partial(_flash_decode_kernel, n_s_steps=n_s,
                               block_s=bs, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, K, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)


# --------------------------------------------------------------------------
# certificate-aware decode: per-layer (k, emax, emin) via scalar prefetch
# --------------------------------------------------------------------------

def _flash_decode_fmt_kernel(fmt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                             m_ref, l_ref, acc_ref, *, n_s_steps: int,
                             block_s: int, scale: float,
                             has_subnormals: bool, saturating: bool):
    from repro.core.quantize import quantize_to_format

    kk, emax, emin = fmt_ref[0], fmt_ref[1], fmt_ref[2]
    qf = lambda t: quantize_to_format(t, kk, emax, emin,
                                      has_subnormals, saturating)
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = qf(q_ref[0, 0].astype(jnp.float32))          # [G, D]
    k = qf(k_ref[0, :, 0, :].astype(jnp.float32))    # [bs, D]
    v = qf(v_ref[0, :, 0, :].astype(jnp.float32))    # [bs, D]
    length = len_ref[pl.program_id(0)]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_ref[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s_idx == n_s_steps - 1)
    def _done():
        o_ref[0, 0] = qf(acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_decode_certified(q, k, v, lengths, fmt, *,
                           has_subnormals: bool = True,
                           saturating: bool = True,
                           block_s: int = 256,
                           interpret: bool = False) -> jax.Array:
    """Certificate-aware flash decode: q/k/v tiles rounded into the
    (k, emax, emin) format in-kernel, output rounded once — the decode
    twin of ``quant_matmul_format``'s serving semantics.

    ``fmt`` (i32[3]) and ``lengths`` (i32[B]) ride in SMEM via
    ``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=2)``, so one
    compiled kernel serves every certified format across every per-layer
    lane — swapping formats costs zero recompiles (the ladder-compile
    contract the serving scan relies on). With a single S block
    (block_s ≥ S) the result is bitwise
    :func:`flash_decode_quantized_ref`.
    """
    B, K, G, D = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0
    n_s = S // bs
    scale = D ** -0.5
    kernel = functools.partial(_flash_decode_fmt_kernel, n_s_steps=n_s,
                               block_s=bs, scale=scale,
                               has_subnormals=has_subnormals,
                               saturating=saturating)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, fmt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s, fmt, ln: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s, fmt, ln: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, fmt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(fmt, jnp.int32), jnp.asarray(lengths, jnp.int32), q, k, v)


def flash_decode_quantized_ref(q, k, v, lengths, fmt, *,
                               has_subnormals: bool = True,
                               saturating: bool = True) -> jax.Array:
    """Eager oracle for :func:`flash_decode_certified` — mirrors the
    kernel's op order for the single-S-block case (one dot per (b, h)
    head pair, same NEG masking, same acc/l division), with the same
    traced-format rounding. This is the off-TPU serving fallback the
    certified decode path runs on CPU CI — bitwise what the kernel
    computes with block_s ≥ S."""
    from repro.core.quantize import quantize_to_format

    fmt = jnp.asarray(fmt, jnp.int32)
    kk, emax, emin = fmt[0], fmt[1], fmt[2]
    qf = lambda t: quantize_to_format(t.astype(jnp.float32), kk, emax, emin,
                                      has_subnormals, saturating)
    B, K, G, D = q.shape
    scale = D ** -0.5
    qq, kq, vq = qf(q), qf(k), qf(v)

    def one(qb, kb, vb, ln):      # [G,D], [S,D], [S,D], scalar length
        s = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32) * scale
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < ln, s, NEG)
        m = jnp.maximum(jnp.full_like(s[:, :1], NEG),
                        jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=1, keepdims=True)
        acc = jnp.dot(p, vb, preferred_element_type=jnp.float32)
        return qf(acc / l)

    out = jax.vmap(jax.vmap(one, in_axes=(0, 1, 1, None)),
                   in_axes=(0, 0, 0, 0))(
        qq, kq, vq, jnp.asarray(lengths, jnp.int32))
    return out.astype(q.dtype)


def certified_decode_attention(q, k, v, lengths, fmt, *,
                               has_subnormals: bool = True,
                               saturating: bool = True,
                               block_s: int = 256,
                               force_kernel=None,
                               interpret: bool = False) -> jax.Array:
    """Serving dispatch: the Pallas certified kernel on TPU, the eager
    oracle elsewhere. ``force_kernel`` overrides the platform check (tests
    run the kernel in interpret mode on CPU)."""
    use_kernel = force_kernel
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return flash_decode_certified(
            q, k, v, lengths, fmt, has_subnormals=has_subnormals,
            saturating=saturating, block_s=block_s, interpret=interpret)
    return flash_decode_quantized_ref(
        q, k, v, lengths, fmt, has_subnormals=has_subnormals,
        saturating=saturating)
