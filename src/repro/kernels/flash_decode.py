"""Pallas TPU kernel: flash decode attention (online-softmax, GQA).

The decode hot spot: one query token per sequence attends to a [S, K, Dh]
KV cache. A naive lowering materialises the [H, S] score row in HBM and
reads the cache twice (scores, then values). This kernel streams the cache
once in S-blocks, keeping the online-softmax state (running max m, running
sum l, output accumulator) in VMEM scratch — the standard flash recurrence

    m' = max(m, rowmax(s));  α = e^{m−m'}
    l' = α·l + rowsum(e^{s−m'});  o' = α·o + e^{s−m'}·V_blk

TPU adaptation: grid (B, K, S/bs) with the S loop innermost so scratch
persists across cache blocks; block sizes 128-aligned for the MXU; GQA
groups (G = H/K query heads per KV head) processed together so the kv
block is read once per group. Variable sequence lengths are masked from a
scalar-prefetched length vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, n_s_steps: int,
                         block_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                       # [G, D]
    k = k_ref[0, :, 0, :]                 # [bs, D]
    v = v_ref[0, :, 0, :]                 # [bs, D]
    length = len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bs]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG)

    m_prev = m_ref[...]                   # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                # [G, bs]
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_ref[...] + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s_idx == n_s_steps - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_decode_attention(q, k, v, lengths, *, block_s: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: [B, K, G, D] (grouped query heads); k, v: [B, S, K, D];
    lengths: [B] valid cache lengths. Returns [B, K, G, D]."""
    B, K, G, D = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0
    n_s = S // bs
    scale = D ** -0.5
    kernel = functools.partial(_flash_decode_kernel, n_s_steps=n_s,
                               block_s=bs, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, K, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
