"""Fault tolerance for 1000+-node runs: heartbeats, restart policy, elastic
re-meshing — simulated faithfully on CPU (the state machine and resharding
logic are the deliverable; the transport is process-local here, DCN in
production).

Components
  HeartbeatMonitor — per-host liveness with deadline; marks hosts dead and
    triggers the supervisor.
  Supervisor — drives the run loop: on failure, (a) if spares exist, swap
    and restore from the latest checkpoint; (b) else *elastically* shrink
    the mesh to the largest (d', m') grid the survivors support, re-lower
    the step, and restore with the new shardings (checkpointing.restore is
    resharding-aware).
  run_with_failures — a harness the tests use: injects failures at chosen
    steps and asserts loss-curve continuity after recovery.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, deadline_s: float = 60.0):
        now = time.monotonic()
        self.deadline = deadline_s
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, now) for h in range(n_hosts)
        }

    def beat(self, host_id: int, at: Optional[float] = None):
        hs = self.hosts[host_id]
        hs.last_beat = time.monotonic() if at is None else at
        hs.alive = True

    def sweep(self, now: Optional[float] = None) -> Set[int]:
        now = time.monotonic() if now is None else now
        dead = set()
        for h, st in self.hosts.items():
            if st.alive and now - st.last_beat > self.deadline:
                st.alive = False
                dead.add(h)
        return dead

    def alive_count(self) -> int:
        return sum(1 for s in self.hosts.values() if s.alive)


def largest_mesh(n_chips: int, model_parallel: int) -> tuple:
    """Biggest (data, model) grid on the surviving chips, keeping the
    model-parallel degree (params must still fit) and maximising data."""
    data = n_chips // model_parallel
    # power-of-two data axis keeps batch divisibility simple
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel)


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    kind: str            # 'swap' | 'shrink'
    dead_hosts: List[int]
    new_mesh: tuple


class Supervisor:
    """Failure-driven control loop around a training job."""

    def __init__(self, n_hosts: int, chips_per_host: int,
                 model_parallel: int, spares: int = 0,
                 deadline_s: float = 60.0):
        self.monitor = HeartbeatMonitor(n_hosts, deadline_s)
        self.chips_per_host = chips_per_host
        self.model_parallel = model_parallel
        self.spares = spares
        self.events: List[RecoveryEvent] = []

    def handle_failures(self, step: int, dead: Set[int]) -> Optional[RecoveryEvent]:
        if not dead:
            return None
        if self.spares >= len(dead):
            self.spares -= len(dead)
            for h in dead:  # spare swapped in; host id reused
                self.monitor.beat(h)
            ev = RecoveryEvent(step, "swap", sorted(dead),
                               self.current_mesh())
        else:
            ev = RecoveryEvent(step, "shrink", sorted(dead),
                               largest_mesh(self.alive_chips(),
                                            self.model_parallel))
        self.events.append(ev)
        return ev

    def alive_chips(self) -> int:
        return self.monitor.alive_count() * self.chips_per_host

    def current_mesh(self) -> tuple:
        return largest_mesh(self.alive_chips(), self.model_parallel)


def run_with_failures(
    train_step: Callable[[int], float],
    save_fn: Callable[[int], None],
    restore_fn: Callable[[tuple], int],
    supervisor: Supervisor,
    n_steps: int,
    checkpoint_every: int = 10,
    failures: Optional[Dict[int, List[int]]] = None,
) -> List[float]:
    """Simulated run loop: ``failures[step] = [host_ids]`` dies at ``step``.

    On failure the loop restores from the latest checkpoint (re-running the
    steps since — exactly-once data semantics come from the stateless
    pipeline) and continues on the recovered/shrunk mesh.
    """
    failures = failures or {}
    losses: List[float] = []
    step = 0
    while step < n_steps:
        if step in failures:
            for h in failures.pop(step):
                self_state = supervisor.monitor.hosts[h]
                self_state.alive = False
            ev = supervisor.handle_failures(step, {e for e in
                                                   [h.host_id for h in
                                                    supervisor.monitor.hosts.values()
                                                    if not h.alive]})
            step = restore_fn(ev.new_mesh)
            continue
        loss = train_step(step)
        losses.append(loss)
        if step % checkpoint_every == 0:
            save_fn(step)
        step += 1
    return losses
