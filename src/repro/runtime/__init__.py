"""runtime subsystem."""
