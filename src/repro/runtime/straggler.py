"""Straggler mitigation.

At 1000+ nodes, tail-latency hosts dominate step time (synchronous SPMD
waits for the slowest). Mitigations implemented:

  StragglerDetector — online per-host step-time EWMA + robust z-score; a
    host whose recent step times exceed median + k·MAD for ``patience``
    consecutive windows is flagged. Flagged hosts trigger Supervisor.swap
    (treat as soft failure) — the standard production response, since a
    chronically slow host is usually failing hardware.

  BackupStepPolicy — for the final (straggler-prone) steps of a job:
    schedule speculative duplicates of the data shards of flagged hosts on
    the fastest hosts and take whichever finishes first (requires stateless
    data pipeline — we have one).
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional, Set


@dataclasses.dataclass
class StragglerConfig:
    window: int = 20          # step-time history per host
    k_mad: float = 4.0        # robust threshold
    patience: int = 3         # consecutive flagged windows before action
    min_steps: int = 10


class StragglerDetector:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Dict[int, Deque[float]] = {
            h: deque(maxlen=cfg.window) for h in range(n_hosts)
        }
        self.strikes: Dict[int, int] = defaultdict(int)

    def report(self, host_id: int, step_time_s: float):
        self.times[host_id].append(step_time_s)

    def flagged(self) -> Set[int]:
        """Hosts currently beyond median + k·MAD of the fleet."""
        recents = {
            h: statistics.fmean(ts) for h, ts in self.times.items()
            if len(ts) >= self.cfg.min_steps
        }
        if len(recents) < 3:
            return set()
        vals = sorted(recents.values())
        med = vals[len(vals) // 2]
        mad = statistics.median([abs(v - med) for v in vals]) or 1e-9
        out = set()
        for h, v in recents.items():
            if v > med + self.cfg.k_mad * 1.4826 * mad:
                self.strikes[h] += 1
                if self.strikes[h] >= self.cfg.patience:
                    out.add(h)
            else:
                self.strikes[h] = 0
        return out


@dataclasses.dataclass
class SpeculativeAssignment:
    shard: int
    primary_host: int
    backup_host: int


def plan_backups(flagged: Set[int], fastest: List[int],
                 shard_of_host: Dict[int, int]) -> List[SpeculativeAssignment]:
    """Duplicate flagged hosts' data shards onto the fastest healthy hosts
    (stateless pipeline ⇒ the duplicate computes an identical gradient
    shard; first-finisher wins, the other is cancelled)."""
    plans = []
    backups = [h for h in fastest if h not in flagged]
    for i, h in enumerate(sorted(flagged)):
        if i < len(backups):
            plans.append(SpeculativeAssignment(shard_of_host[h], h, backups[i]))
    return plans
