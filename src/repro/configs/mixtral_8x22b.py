"""Mixtral 8x22B — MoE 8 experts top-2, GQA kv=8, SWA window
Source: arXiv:2401.04088
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='mixtral-8x22b',
    family='moe',
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    window=4096,
    rope_theta=1000000.0,
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name='mixtral-smoke',
    family='moe',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    n_experts=4,
    top_k=2,
    moe_d_ff=128,
    window=16,
    tie_embeddings=False,
)
