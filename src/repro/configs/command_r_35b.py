"""Command-R 35B — dense GQA kv=8, no biases
Source: hf:CohereForAI/c4ai-command-r-v01
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='command-r-35b',
    family='dense',
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22528,
    vocab=256000,
    rope_theta=8000000.0,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name='command-r-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    tie_embeddings=True,
)
