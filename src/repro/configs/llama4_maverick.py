"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1, GQA kv=8, early fusion
Source: hf:meta-llama/Llama-4-Scout-17B-16E (scaled per assignment)
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='llama4-maverick-400b-a17b',
    family='moe',
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    moe_d_ff=8192,
    rope_theta=500000.0,
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name='llama4-smoke',
    family='moe',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    n_experts=8,
    top_k=1,
    moe_d_ff=128,
    tie_embeddings=False,
)
