"""RWKV6 'Finch' 1.6B — attention-free, data-dependent decay
Source: arXiv:2404.05892
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='rwkv6-1.6b',
    family='ssm',
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    rwkv=True,
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name='rwkv6-smoke',
    family='ssm',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=128,
    rwkv=True,
    tie_embeddings=False,
)
