"""PaliGemma 3B — SigLIP vision frontend is a STUB (input_specs supplies 256 patch embeddings of dim 1152); gemma backbone, MQA kv=1
Source: arXiv:2407.07726
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='paligemma-3b',
    family='vlm',
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    embed_scale=True,
    frontend='vision',
    frontend_seq=256,
    frontend_dim=1152,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name='paligemma-smoke',
    family='vlm',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab=128,
    embed_scale=True,
    frontend='vision',
    frontend_seq=8,
    frontend_dim=32,
    tie_embeddings=True,
)
