"""Architecture registry: one module per assigned arch, exact public configs.

Each module exposes FULL (the assigned configuration) and SMOKE (a reduced
same-family configuration for CPU tests). ``get(name)`` returns the module;
``ARCHS`` lists all ids; ``SHAPES`` the assigned input-shape families.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCHS = [
    "rwkv6_1p6b",
    "mixtral_8x22b",
    "llama4_maverick",
    "hymba_1p5b",
    "qwen2_7b",
    "gemma2_27b",
    "command_r_35b",
    "minicpm3_4b",
    "whisper_medium",
    "paligemma_3b",
]

_ALIASES = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "hymba-1.5b": "hymba_1p5b",
    "qwen2-7b": "qwen2_7b",
    "gemma2-27b": "gemma2_27b",
    "command-r-35b": "command_r_35b",
    "minicpm3-4b": "minicpm3_4b",
    "whisper-medium": "whisper_medium",
    "paligemma-3b": "paligemma_3b",
}


def get(name: str):
    name = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def cells(include_skipped: bool = False):
    """All 40 (arch × shape) assignment cells; marks the documented skips."""
    out = []
    for a in ARCHS:
        mod = get(a)
        for s in SHAPES.values():
            skip = skip_reason(mod.FULL, s)
            if skip and not include_skipped:
                out.append((a, s.name, skip))
            else:
                out.append((a, s.name, skip))
    return out


def skip_reason(cfg, shape: ShapeSpec) -> Optional[str]:
    """The assignment's documented skips (see DESIGN.md §Arch table)."""
    if shape.name == "long_500k":
        if cfg.rwkv or cfg.hybrid:
            return None
        if cfg.window or cfg.local_global_period:
            # SWA / local-global archs still need the full cache for their
            # global layers at 500k — run them (window bounds compute).
            return None
        return ("pure full-attention arch: 500k decode needs sub-quadratic "
                "attention — skipped per assignment")
    if cfg.enc_dec and shape.kind in ("decode", "prefill"):
        return None  # runs at the decoder's architectural max (448), noted
    return None
