"""MiniCPM3 4B — MLA (multi-head latent attention): q_rank 768, kv_rank 256
Source: hf:openbmb/MiniCPM3-4B
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='minicpm3-4b',
    family='dense',
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    mla=True,
    q_rank=768,
    kv_rank=256,
    d_nope=64,
    d_rope=32,
    d_v=64,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name='minicpm3-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=128,
    mla=True,
    q_rank=32,
    kv_rank=16,
    d_nope=8,
    d_rope=8,
    d_v=8,
    tie_embeddings=True,
)
