"""Hymba 1.5B — hybrid: parallel attention + mamba heads; SWA on attention (full-cache global layers omitted in this config — window bounds decode state)
Source: arXiv:2411.13676
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='hymba-1.5b',
    family='hybrid',
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    hybrid=True,
    ssm_state=16,
    window=1024,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name='hymba-smoke',
    family='hybrid',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    hybrid=True,
    ssm_state=4,
    window=16,
    tie_embeddings=True,
)
