"""Gemma2 27B — local(4096)/global alternating, logit softcaps, GQA kv=16
Source: arXiv:2408.00118
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='gemma2-27b',
    family='dense',
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=36864,
    vocab=256000,
    softcap_attn=50.0,
    softcap_final=30.0,
    local_global_period=4096,
    act='gelu',
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name='gemma2-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    softcap_attn=50.0,
    softcap_final=30.0,
    local_global_period=16,
    act='gelu',
    embed_scale=True,
    tie_embeddings=True,
)
