"""Whisper medium — enc-dec; conv frontend is a STUB (input_specs supplies 1500 frame embeddings); vocab padded 51865 -> 51968 for sharding; decoder context capped at 448 (architectural max)
Source: arXiv:2212.04356
"""
from repro.models.transformer import ArchConfig

FULL = ArchConfig(
    name='whisper-medium',
    family='audio',
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51968,
    act='gelu',
    norm='layernorm',
    enc_dec=True,
    n_enc_layers=24,
    frontend='audio',
    frontend_seq=1500,
    frontend_dim=1024,
    max_decode_seq=448,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name='whisper-smoke',
    family='audio',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=128,
    act='gelu',
    norm='layernorm',
    enc_dec=True,
    n_enc_layers=2,
    frontend='audio',
    frontend_seq=8,
    frontend_dim=64,
    max_decode_seq=16,
    tie_embeddings=True,
)
