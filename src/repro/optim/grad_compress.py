"""Gradient compression with error feedback (distributed-optimization trick).

Int8 gradient payloads cut DP all-reduce bytes 4× (the collective-bound term
of the roofline, §Roofline). Error feedback keeps convergence: the residual
(g − dequant(quant(g))) is carried and added to the next step's gradient —
the standard EF-SGD construction, known to preserve AdamW convergence rates.

Under pjit the all-reduce is implicit, so compression is expressed as a
``shard_map`` over the DP axes: quantise the local shard → psum int32 →
dequantise — giving XLA an integer-typed collective. ``compress_tree`` is
the pure (collective-free) codec used both by the shard_map path and by the
tests.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class EFState(NamedTuple):
    residual: Any   # same pytree as grads


def init_ef(grads_like) -> EFState:
    return EFState(jax.tree_util.tree_map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def _quant_int8(x: jax.Array, block: int = 256):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale, shape, block: int = 256):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_tree(grads, ef: EFState, block: int = 256) -> Tuple[Any, EFState]:
    """Error-feedback int8 round-trip: returns (decompressed grads, new EF).

    What every worker would transmit is the int8 payload; the returned
    gradients are exactly what the receiving side reconstructs, so training
    with these gradients *is* training under compressed communication.
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quant_int8(x, block)
        d = _dequant_int8(q, s, g.shape, block)
        return d, x - d

    pairs = jax.tree_util.tree_map(one, grads, ef.residual)
    is2 = lambda t: isinstance(t, tuple) and len(t) == 2
    dec = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is2)
    res = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is2)
    return dec, EFState(res)


def compressed_psum_grads(local_grads, mesh, dp_axes=("data",), block: int = 256):
    """shard_map DP all-reduce with int8 payloads.

    The local per-shard gradient is quantised, summed as int32 across the DP
    axes (the wire format a fabric-offload implementation would ship), and
    dequantised with the summed scales upper bound. Bytes on the wire: 1/4
    of f32 (+ 1/block scale overhead).
    """
    from jax.experimental.shard_map import shard_map

    def reduce_one(g):
        def f(x):
            q, s = _quant_int8(x, block)
            qs = jax.lax.psum(q.astype(jnp.int32), dp_axes)
            ss = jax.lax.psum(s, dp_axes)  # conservative: sum of scales
            n = jax.lax.psum(jnp.ones((), jnp.float32), dp_axes)
            return _dequant_int8(qs.astype(jnp.float32) / n, ss / n, x.shape, block)

        return shard_map(f, mesh=mesh, in_specs=P(*[None] * g.ndim),
                         out_specs=P(*[None] * g.ndim))(g)

    return jax.tree_util.tree_map(reduce_one, local_grads)
