"""optim subsystem."""
