"""AdamW with optional 8-bit quantised moments (blockwise), ZeRO-sharded.

The moments inherit the parameters' (fully-sharded) NamedShardings, so
optimizer state is ZeRO-3-sharded for free under pjit. The 8-bit mode packs
m/v into int8 with per-block (128) scales — a 7.5× optimizer-memory cut
that is what lets the llama4-400B training cell fit 512 chips (see
EXPERIMENTS.md §Dry-run). Dequant→update→requant happens inside the jitted
train step, fully sharded; the quantisation is exactly the dynamic-range
int8 scheme the CAA engine can bound (one rounding at block scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_moments: bool = False   # 8-bit blockwise m/v
    block: int = 128
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    m_scale: Any       # per-block scales when quantized, else None-pytree
    v_scale: Any


# -- 8-bit blockwise codec ---------------------------------------------------

def _q8(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape, block: int):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


# -- init / update ------------------------------------------------------------

def init(params, cfg: AdamWConfig) -> OptState:
    def zeros_like_tree():
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)

    if cfg.quantized_moments:
        def q(t):  # distinct buffers per moment (donation safety)
            return jax.tree_util.tree_map(lambda p: _q8(p, cfg.block)[0], t)

        def s(t):
            return jax.tree_util.tree_map(lambda p: _q8(p, cfg.block)[1], t)

        return OptState(jnp.zeros((), jnp.int32),
                        q(zeros_like_tree()), q(zeros_like_tree()),
                        s(zeros_like_tree()), s(zeros_like_tree()))
    return OptState(jnp.zeros((), jnp.int32), zeros_like_tree(),
                    zeros_like_tree(), None, None)


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state)."""
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * clip, grads)

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    if cfg.quantized_moments:
        def upd(p, g, mq, ms, vq, vs):
            m = _dq8(mq, ms, p.shape, cfg.block)
            sv = _dq8(vq, vs, p.shape, cfg.block)
            v = sv * sv        # v stored as sqrt(v): halves the dynamic
            m = cfg.b1 * m + (1 - cfg.b1) * g   # range int8 must span, so
            v = cfg.b2 * v + (1 - cfg.b2) * g * g  # small moments survive
            upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            newp = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
            mq2, ms2 = _q8(m, cfg.block)
            vq2, vs2 = _q8(jnp.sqrt(v), cfg.block)
            return newp.astype(p.dtype), mq2, ms2, vq2, vs2

        out = jax.tree_util.tree_map(upd, params, grads, state.m, state.m_scale,
                                     state.v, state.v_scale)
        newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        mq = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        ms = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        vq = jax.tree_util.tree_map(lambda t: t[3], out, is_leaf=lambda t: isinstance(t, tuple))
        vs = jax.tree_util.tree_map(lambda t: t[4], out, is_leaf=lambda t: isinstance(t, tuple))
        return newp, OptState(step, mq, vq, ms, vs)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    newp = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is3)
    m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is3)
    v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=is3)
    return newp, OptState(step, m, v, None, None)
