"""Floating-point format zoo.

The paper parameterises its whole analysis by the *precision* ``k`` — the number
of mantissa bits held by the format, counting the implicit bit — through the
unit ``u = 2^{1-k}`` (eq. (5): ``fl(a∘b) = (a∘b)(1+ε u)`` with ``|ε| ≤ 1/2``).
All CAA error bounds are expressed in units of this ``u`` so a single analysis
serves every candidate format; a format is then chosen by comparing its ``u``
against the bound (Section IV of the paper).

We additionally carry the exponent range so the empirical oracle
(:mod:`repro.core.quantize`) can emulate overflow/underflow behaviour, and so
range checks against IA enclosures can flag formats whose dynamic range is the
real problem (the paper's observation that DNNs also behave well under *low
exponent range* is checkable this way).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class FpFormat:
    """A binary floating-point format.

    Attributes:
      name: human-readable identifier.
      k: precision — mantissa bits *including* the implicit leading bit
         (IEEE binary32 → 24, binary64 → 53, bfloat16 → 8).
      emax: maximum unbiased exponent of a normal number.
      emin: minimum unbiased exponent of a normal number.
      has_subnormals: whether gradual underflow is supported.
      saturating: if True, overflow clamps to ±max_finite (common for fp8
         inference datapaths); otherwise overflow produces ±inf.
      max_finite_override: explicit largest finite value, for formats whose
         top binade is clipped by an encoding trick (OCP e4m3 spends the
         all-ones exponent+mantissa code on NaN, so its max is 1.75·2^8 =
         448, not the formula's 1.875·2^8 = 480).
    """

    name: str
    k: int
    emax: int
    emin: int
    has_subnormals: bool = True
    saturating: bool = False
    max_finite_override: Optional[float] = None

    @property
    def u(self) -> float:
        """The paper's unit: u = 2^{1-k}. One elementary rounding is ≤ (1/2)u."""
        return 2.0 ** (1 - self.k)

    @property
    def unit_roundoff(self) -> float:
        """Standard unit roundoff = u/2 = 2^{-k}."""
        return 2.0 ** (-self.k)

    @property
    def max_finite(self) -> float:
        if self.max_finite_override is not None:
            return self.max_finite_override
        # (2 - 2^{1-k}) * 2^{emax}
        return (2.0 - 2.0 ** (1 - self.k)) * (2.0 ** self.emax)

    @property
    def min_normal(self) -> float:
        return 2.0 ** self.emin

    @property
    def min_subnormal(self) -> float:
        if not self.has_subnormals:
            return self.min_normal
        return 2.0 ** (self.emin - (self.k - 1))

    @property
    def underflow_unit(self) -> float:
        """Per-rounding underflow absorption bound η, in value terms.

        One result rounding into this format may — beyond the relative
        (1+εu) part of eq. (5) — displace the result absolutely by the
        subnormal grid spacing ``2^{emin-(k-1)}``; without gradual
        underflow the whole flushed value is lost, charged at ``2^{emin}``.
        This is the η of the full standard model fl(x) = x(1+ε) + η, and
        the absolute term the format-certifying analysis folds into δ̄
        (CaaConfig.round_abs, in units of u)."""
        if self.has_subnormals:
            return 2.0 ** (self.emin - (self.k - 1))
        return 2.0 ** self.emin

    @property
    def exponent_bits(self) -> int:
        """Smallest IEEE-style exponent field width covering [emin, emax]
        (e bits encode emax = 2^{e-1}−1, emin = 2−2^{e-1}). Formats that
        stretch emax by an encoding trick (e4m3) report the IEEE width."""
        return exponent_bits(self.emax, self.emin)

    @property
    def total_bits(self) -> int:
        """Storage cost: sign + exponent field + stored mantissa (k counts
        the implicit bit, so k−1 bits are stored)."""
        return 1 + self.exponent_bits + (self.k - 1)

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready descriptor (the schema-v3 ``layer_format`` entry)."""
        d = dataclasses.asdict(self)
        if d["max_finite_override"] is None:
            del d["max_finite_override"]
        return d

    def describe(self) -> str:
        return (
            f"{self.name}: k={self.k} (u=2^{1 - self.k}), "
            f"emax={self.emax}, emin={self.emin}, "
            f"max={self.max_finite:.3e}"
        )


def from_dict(d: Dict[str, Any]) -> FpFormat:
    known = {f.name for f in dataclasses.fields(FpFormat)}
    return FpFormat(**{k: v for k, v in d.items() if k in known})


def exponent_bits(emax: int, emin: int) -> int:
    """Smallest IEEE-style exponent field width e with 2^{e-1}−1 ≥ emax and
    2−2^{e-1} ≤ emin."""
    e = 2
    while (2 ** (e - 1) - 1 < emax) or (2 - 2 ** (e - 1) > emin):
        e += 1
    return e


def custom(k: int, emax: int = 127, name: str | None = None, **kw) -> FpFormat:
    """A custom format with k-bit precision; default binary32 exponent range.

    This is the knob the paper turns: 'required precision to prevent
    misclassification' (Table I) is a statement about k alone.
    """
    return FpFormat(name or f"custom_k{k}", k=k, emax=emax, emin=-(emax - 1), **kw)


def from_bits(k: int, e: int, name: str | None = None, **kw) -> FpFormat:
    """The IEEE-style format with k-bit precision and an e-bit exponent
    field: emax = 2^{e-1}−1, emin = 2−2^{e-1}. This is the lattice the
    format synthesizer (:mod:`repro.certify.formats`) searches over."""
    emax = 2 ** (e - 1) - 1
    return FpFormat(name or f"custom_k{k}e{e}", k=k, emax=emax,
                    emin=1 - emax, **kw)


# --- The format zoo -------------------------------------------------------
BINARY64 = FpFormat("binary64", k=53, emax=1023, emin=-1022)
BINARY32 = FpFormat("binary32", k=24, emax=127, emin=-126)
TF32 = FpFormat("tf32", k=11, emax=127, emin=-126)
FP16 = FpFormat("float16", k=11, emax=15, emin=-14)
BFLOAT16 = FpFormat("bfloat16", k=8, emax=127, emin=-126)
# IBM DLfloat: 16 bits, 6 exponent, 9 stored mantissa bits (k=10), no subnormals.
DLFLOAT16 = FpFormat("dlfloat16", k=10, emax=31, emin=-30, has_subnormals=False)
# OCP 8-bit formats (e4m3 has emax=8 with the all-ones-exponent trick;
# saturating). Its top binade is clipped: the all-ones code is NaN, so the
# max is 1.75·2^8 = 448 (== jnp.finfo(float8_e4m3fn).max), not the formula's
# 480 — pinned by the finfo cross-check in tests/test_formats_zoo.py.
FP8_E4M3 = FpFormat("fp8_e4m3", k=4, emax=8, emin=-6, saturating=True,
                    max_finite_override=448.0)
FP8_E5M2 = FpFormat("fp8_e5m2", k=3, emax=15, emin=-14, saturating=True)

REGISTRY: Dict[str, FpFormat] = {
    f.name: f
    for f in (
        BINARY64,
        BINARY32,
        TF32,
        FP16,
        BFLOAT16,
        DLFLOAT16,
        FP8_E4M3,
        FP8_E5M2,
    )
}


def get(name_or_k) -> FpFormat:
    """Look a format up by name, or build ``custom(k)`` from an int."""
    if isinstance(name_or_k, FpFormat):
        return name_or_k
    if isinstance(name_or_k, int):
        return custom(name_or_k)
    if name_or_k in REGISTRY:
        return REGISTRY[name_or_k]
    if name_or_k.startswith("custom_k"):
        spec = name_or_k[len("custom_k"):]
        if "e" in spec:      # "custom_k{k}e{e}" — synthesized lattice formats
            kk, ee = spec.split("e", 1)
            return from_bits(int(kk), int(ee))
        return custom(int(spec))
    raise KeyError(f"unknown FP format {name_or_k!r}; known: {sorted(REGISTRY)}")


def required_k_from_bound(bound_in_u: float, margin: float) -> int:
    """Smallest precision k such that ``bound_in_u * 2^{1-k} <= margin``.

    This is the paper's final step (Section IV): the analysis yields a bound
    B in units of u; a margin μ (absolute) or ν (relative) comes from the
    top-1/top-2 separation; the format must satisfy B·u ≤ margin.
    """
    if bound_in_u <= 0:
        return 1
    if not math.isfinite(bound_in_u) or margin <= 0:
        raise ValueError(
            f"no finite precision achieves bound={bound_in_u} within margin={margin}"
        )
    # B * 2^{1-k} <= m  <=>  k >= 1 + log2(B/m)
    return max(1, math.ceil(1.0 + math.log2(bound_in_u / margin)))
