"""Closed forms from the paper's Section IV — kept verbatim for validation.

These are the *paper's* constants and margin formulas; the engine in
:mod:`repro.core.caa` computes tighter rigorous bounds, and the property
tests check `empirical ≤ engine ≤ paper` in the regimes where the paper's
assumptions hold.
"""
from __future__ import annotations

import math

SOFTMAX_ABS_TO_REL_FACTOR = 11.0 / 2.0  # eq. (11): |ε_i| ≤ (11/2)·max_k|δ_k|
TANH_REL_FACTOR = 2.63                  # §III, valid while ε̄·u ≤ 1/4
TANH_REL_GATE = 0.25


def softmax_rel_bound_paper(max_abs_in_u: float) -> float:
    """Paper eq. (11): relative output error ≤ 5.5 × max absolute input error."""
    return SOFTMAX_ABS_TO_REL_FACTOR * max_abs_in_u


def tanh_rel_bound_paper(rel_in_u: float, u: float) -> float:
    """Paper §III tanh rule (gated)."""
    if rel_in_u * u <= TANH_REL_GATE:
        return TANH_REL_FACTOR * rel_in_u
    return math.inf


def abs_margin(p_star: float) -> float:
    """μ = p* − 1/2 — absolute error margin per output element (Section IV)."""
    if not 0.5 < p_star <= 1.0:
        raise ValueError("p* must be in (0.5, 1]")
    return p_star - 0.5


def rel_margin(p_star: float) -> float:
    """ν = (2p* − 1)/(2p* + 1) — relative error margin (Section IV)."""
    if not 0.5 < p_star <= 1.0:
        raise ValueError("p* must be in (0.5, 1]")
    return (2.0 * p_star - 1.0) / (2.0 * p_star + 1.0)


def paper_example_check() -> dict:
    """The worked example of Section IV: p* = 0.60 ⇒ ν > 0.0909 > 2^-3.45;
    tolerated softmax-input absolute error ν/5.5 > 1.65e-2 ≈ 2^-6."""
    nu = rel_margin(0.60)
    tol_in = nu / SOFTMAX_ABS_TO_REL_FACTOR
    return {
        "nu": nu,
        "nu_gt_0_0909": nu > 0.0909,
        "nu_bits": -math.log2(nu),
        "tolerated_softmax_input_abs": tol_in,
        "tol_gt_1_65e_2": tol_in > 1.65e-2,
    }
