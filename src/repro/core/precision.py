"""Precision tailoring: from CAA bounds + top-1 margin to a format choice.

Implements the paper's Section IV end-game: given the analysis output (final
absolute/relative bounds in units of u) and external knowledge p* > 0.5 (the
guaranteed top-1 probability — from SafeAI-style tools or simply specified,
accepting some misclassification rate), choose the smallest precision k such
that rounding can never flip the argmax. Beyond the paper: per-layer
mixed-precision assignment from the layer trace.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from . import formats, theory


@dataclasses.dataclass(frozen=True)
class PrecisionDecision:
    p_star: float
    abs_margin: float
    rel_margin: float
    final_abs_bound_u: float   # δ̄ of the output vector (max over classes)
    final_rel_bound_u: float   # ε̄ of the output vector
    required_k: int            # smallest k preventing misclassification
    satisfied_by: List[str]    # standard formats that satisfy it

    def explain(self) -> str:
        return (
            f"p*={self.p_star}: margins μ={self.abs_margin:.4g}, "
            f"ν={self.rel_margin:.4g}; output bounds δ̄={self.final_abs_bound_u:.4g}u, "
            f"ε̄={self.final_rel_bound_u:.4g}u ⇒ required precision k={self.required_k} "
            f"(u=2^{1-self.required_k}); satisfied by: {', '.join(self.satisfied_by) or 'none'}"
        )


def decide(final_abs_u: float, final_rel_u: float, p_star: float) -> PrecisionDecision:
    """Smallest k such that either bound fits inside its margin.

    Misclassification is prevented if each output element moves by less than
    half the top-1/top-2 gap: absolute route needs δ̄·u ≤ μ; relative route
    needs ε̄·u ≤ ν. Either suffices (the paper uses whichever bound is
    finite/tighter).
    """
    mu = theory.abs_margin(p_star)
    nu = theory.rel_margin(p_star)
    ks = []
    if math.isfinite(final_abs_u) and final_abs_u > 0:
        ks.append(formats.required_k_from_bound(final_abs_u, mu))
    elif final_abs_u == 0:
        ks.append(1)
    if math.isfinite(final_rel_u) and final_rel_u > 0:
        ks.append(formats.required_k_from_bound(final_rel_u, nu))
    elif final_rel_u == 0:
        ks.append(1)
    if not ks:
        raise ValueError("no finite output bound — cannot pick a precision")
    k = min(ks)
    sat = [f.name for f in formats.REGISTRY.values() if f.k >= k]
    return PrecisionDecision(p_star, mu, nu, final_abs_u, final_rel_u, k, sorted(sat))


def decide_iterative(
    bounds_at_umax, p_star: float, k_min: int = 2, k_max: int = 53
) -> PrecisionDecision:
    """Smallest k that prevents misclassification, re-analysing per candidate.

    CAA bounds are *parameterised* by u but contain u_max-dependent terms
    (second-order products; the softmax abs→rel conversion saturates when
    δ̄·u_max is large). ``bounds_at_umax(u_max) -> (abs_u, rel_u)`` re-runs
    the analysis; feasibility is monotone in k, so we binary-search.
    """
    mu = theory.abs_margin(p_star)
    nu = theory.rel_margin(p_star)

    def feasible(k: int):
        u = 2.0 ** (1 - k)
        abs_u, rel_u = bounds_at_umax(u)
        ok = (abs_u * u <= mu) or (rel_u * u <= nu)
        return ok, abs_u, rel_u

    ok_hi, abs_hi, rel_hi = feasible(k_max)
    if not ok_hi:
        raise ValueError(
            f"even k={k_max} cannot guarantee top-1 with p*={p_star} "
            f"(bounds {abs_hi:.3g}u abs / {rel_hi:.3g}u rel)"
        )
    lo, hi = k_min, k_max          # invariant: hi feasible
    best = (k_max, abs_hi, rel_hi)
    while lo < hi:
        mid = (lo + hi) // 2
        ok, a, r = feasible(mid)
        if ok:
            hi = mid
            best = (mid, a, r)
        else:
            lo = mid + 1
    k, abs_u, rel_u = best
    sat = [f.name for f in formats.REGISTRY.values() if f.k >= k]
    return PrecisionDecision(p_star, mu, nu, abs_u, rel_u, k, sorted(sat))


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    layer: str
    k: int
    format: str


def mixed_precision_plan(
    layer_slack_u: Dict[str, float],
    target_margin: float,
    share: Optional[Dict[str, float]] = None,
) -> List[LayerPrecision]:
    """Beyond-paper: distribute the end-to-end error budget across layers.

    ``layer_slack_u[name]`` is the sensitivity of the final bound to one unit
    of u spent at that layer (obtained by re-running the analysis with a
    probe, see analyze.sensitivity). We budget margin_i = target_margin ·
    share_i (default equal shares) and pick per-layer k_i accordingly —
    the "removing the global u" extension the paper names as future work.
    """
    names = list(layer_slack_u)
    share = share or {n: 1.0 / len(names) for n in names}
    plan = []
    for n in names:
        budget = target_margin * share[n]
        sens = layer_slack_u[n]
        if sens <= 0:
            k = 1
        else:
            k = formats.required_k_from_bound(sens, budget)
        fmt = next(
            (f.name for f in sorted(formats.REGISTRY.values(), key=lambda f: f.k)
             if f.k >= k),
            f"custom_k{k}",
        )
        plan.append(LayerPrecision(n, k, fmt))
    return plan


def classification_safe(probs_lo, probs_hi, predicted: int) -> bool:
    """Rigorous argmax check: class `predicted` is guaranteed top-1 iff its
    lower probability bound beats every other class's upper bound."""
    import numpy as np

    lo = np.asarray(probs_lo)
    hi = np.asarray(probs_hi)
    others = np.delete(hi, predicted)
    return bool(lo[predicted] > others.max())
