"""Semi-automatic precision/accuracy analysis driver (paper Section V).

The workflow the paper describes — load a trained model, annotate the input
with interval ranges, run it once per class under the enhanced arithmetic,
read off absolute/relative output bounds in units of u, then tailor the
precision — is implemented here against our backends:

    report = analyze(forward, params, input_range, p_star=0.6)
    report.decision.required_k        # Table-I style answer
    report.layers                     # per-layer trace
    plan = mixed_precision(forward, params, input_range, p_star=0.6)

``forward(backend, params, x)`` must be written against
:class:`repro.core.backend.Backend` and return the output (for classifiers:
the softmax probabilities).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import caa, interval as iv, precision, theory
from .backend import (Backend, CaaOps, StackedCaaOps, StackedRangeCaaOps,
                      TraceRecord)
from .caa import CaaConfig, CaaTensor
from .scopes import (STACK_SCOPE, expand_stacked, resolve_scope_value,
                     scope_active, scope_prefixes)


@dataclasses.dataclass
class ErrorReport:
    """The analyser's output — everything Table I reports, plus the trace."""

    final_abs_u: float
    final_rel_u: float
    output_range: tuple  # (lo, hi) arrays
    layers: List[TraceRecord]
    analysis_seconds: float
    cfg: CaaConfig
    decision: Optional[precision.PrecisionDecision] = None
    router_records: List[TraceRecord] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        lines = [
            f"max absolute error: {self.final_abs_u:.4g} u",
            f"max relative error: {self.final_rel_u:.4g} u",
            f"analysis time: {self.analysis_seconds:.3f} s",
        ]
        if self.decision is not None:
            lines.append(self.decision.explain())
        return "\n".join(lines)

    def dominant_layer(self) -> Optional[TraceRecord]:
        finite = [r for r in self.layers if jnp.isfinite(r.max_dbar)]
        return max(finite, key=lambda r: r.max_dbar, default=None)


def analyze(
    forward: Callable[[Backend, dict, CaaTensor], CaaTensor],
    params: dict,
    x: CaaTensor,
    p_star: Optional[float] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
) -> ErrorReport:
    """One analysis pass (the paper's 'one representative per class' run —
    the interval input covers the whole class, so one run per control flow
    suffices; with fixed routing that is one run)."""
    ops = CaaOps(cfg, weights_exact=weights_exact)
    t0 = time.perf_counter()
    out = forward(ops, params, x)
    abs_u, rel_u = caa.worst(out)
    dt = time.perf_counter() - t0
    decision = None
    if p_star is not None:
        try:
            decision = precision.decide(abs_u, rel_u, p_star)
        except ValueError:
            decision = None  # bounds saturated at this u_max — re-run smaller
    return ErrorReport(
        final_abs_u=abs_u,
        final_rel_u=rel_u,
        output_range=(out.exact.lo, out.exact.hi),
        layers=[r for r in ops.trace if r.kind != "router"],
        analysis_seconds=dt,
        cfg=cfg,
        decision=decision,
        router_records=[r for r in ops.trace if r.kind == "router"],
    )


@dataclasses.dataclass
class BatchedErrorReport:
    """Per-class bounds from ONE joint CAA pass over stacked class inputs.

    The paper runs the analysis "once per class"; since every CAA rule is
    tensorised and row-independent along a leading batch axis, stacking the
    per-class interval inputs collapses those C runs into one compiled
    evaluation with bit-identical per-class bounds (tests/test_analyze.py
    asserts the agreement).
    """

    abs_u: np.ndarray            # [C] max δ̄ per class, units of u
    rel_u: np.ndarray            # [C] max ε̄ per class, units of u
    output_range: tuple          # (lo, hi) arrays, leading axis = class
    layers: List[TraceRecord]    # trace of the joint pass (maxima span classes)
    analysis_seconds: float
    cfg: CaaConfig               # the caller's per-class-equivalent config
    decisions: Optional[List[Optional[precision.PrecisionDecision]]] = None
    scopes: List[str] = dataclasses.field(default_factory=list)
    # ^ every scope path the pass entered (first-seen order) — lets callers
    #   (e.g. the mixed-precision pipeline) pick a layer granularity without
    #   paying a second analysis just to enumerate names

    @property
    def n_classes(self) -> int:
        return int(self.abs_u.shape[0])

    def per_class(self, c: int) -> tuple:
        return float(self.abs_u[c]), float(self.rel_u[c])


def batch_config(cfg: CaaConfig, n_classes: int) -> CaaConfig:
    """Per-class-equivalent config for a stacked run.

    The trajectory-mode gate in :func:`caa.matmul` counts *output elements
    across the whole stack*, so a batched run over C classes would fall back
    to the looser γ_n rule C× earlier than the sequential runs it replaces.
    Scaling the budget by C makes the batched pass take exactly the same
    trajectory-vs-γ branch per class as C sequential passes — the invariant
    behind the batched == sequential bound agreement.
    """
    return dataclasses.replace(
        cfg, traj_max_elems=cfg.traj_max_elems * max(int(n_classes), 1)
    )


def analyze_batched(
    forward: Callable[[Backend, dict, CaaTensor], CaaTensor],
    params: dict,
    x: CaaTensor,
    p_star: Optional[float] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
    class_axis: int = 0,
) -> BatchedErrorReport:
    """All classes at once: the paper's C per-class runs in one evaluation.

    ``x`` stacks the per-class interval inputs along ``class_axis`` (use
    :func:`caa.from_range` on stacked lo/hi envelopes). Bounds per class
    match :func:`analyze` on the corresponding slice exactly.
    """
    n = int(jnp.shape(x.val)[class_axis])
    ops = CaaOps(batch_config(cfg, n), weights_exact=weights_exact)
    t0 = time.perf_counter()
    out = forward(ops, params, x)
    axis = class_axis % out.ndim
    red = tuple(i for i in range(out.ndim) if i != axis)
    dbar = jnp.broadcast_to(out.dbar, out.shape)
    ebar = jnp.broadcast_to(out.ebar, out.shape)
    abs_u = np.asarray(jnp.max(dbar, axis=red), np.float64)
    rel_u = np.asarray(jnp.max(ebar, axis=red), np.float64)
    dt = time.perf_counter() - t0
    decisions = None
    if p_star is not None:
        decisions = []
        for c in range(n):
            try:
                decisions.append(precision.decide(
                    float(abs_u[c]), float(rel_u[c]), p_star))
            except ValueError:
                decisions.append(None)  # saturated at this u_max
    return BatchedErrorReport(
        abs_u=abs_u,
        rel_u=rel_u,
        output_range=(out.exact.lo, out.exact.hi),
        layers=[r for r in ops.trace if r.kind != "router"],
        analysis_seconds=dt,
        cfg=cfg,
        decisions=decisions,
        scopes=list(ops.seen_scopes),
    )


def verify_classification(
    forward, params, x: CaaTensor, fmt, predicted: int,
    cfg: Optional[CaaConfig] = None,
) -> bool:
    """Rigorous per-input argmax check at a concrete format: inflate the
    output enclosure by the error bounds at u = fmt.u and test top-1."""
    from . import formats as _f

    fmt = _f.get(fmt)
    cfg = cfg or CaaConfig(u_max=fmt.u)
    if fmt.u > cfg.u_max:
        raise ValueError("format's u exceeds the analysed u_max — re-analyse")
    ops = CaaOps(cfg)
    out = forward(ops, params, x)
    rng = out.fp_range(fmt.u)
    return precision.classification_safe(rng.lo, rng.hi, predicted)


def sensitivity(
    forward, params, x: CaaTensor,
    layer_names: Sequence[str],
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
) -> Dict[str, float]:
    """Per-layer contribution to the final absolute bound.

    Re-runs the analysis once per layer with fresh roundings enabled *only*
    in that layer's scope (round_scale gating) — the attribution needed by
    :func:`repro.core.precision.mixed_precision_plan`. Cost: L analyses —
    affordable because the tensorised analysis is fast (see
    benchmarks/analysis_speed.py).
    """
    out: Dict[str, float] = {}
    for name in layer_names:
        ops = _GatedCaaOps(cfg, active_scope=name)
        y = forward(ops, params, x)
        abs_u, _ = caa.worst(y)
        out[name] = abs_u
    return out


# Scope-path matching/resolution (string keys, plus the stacked "layer*"
# wildcard whose [L]-array values are indexed by layer number) lives in
# :mod:`repro.core.scopes`; re-exported here for the established call sites.
_scope_active = scope_active


class _GatedCaaOps(CaaOps):
    """CaaOps whose fresh roundings are active only inside one scope."""

    def __init__(self, cfg: CaaConfig, active_scope: str):
        super().__init__(cfg)
        self._active = active_scope
        self._base_cfg = cfg
        self._off_cfg = dataclasses.replace(cfg, round_scale=0.0)
        self.cfg = self._off_cfg

    def _scope_changed(self):
        super()._scope_changed()
        self.cfg = (self._base_cfg
                    if _scope_active(self._active, self._scope)
                    else self._off_cfg)


def discover_scopes(
    forward, params, x: CaaTensor,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    depth: int = 1,
) -> List[str]:
    """The scope names one analysis pass enters, truncated to ``depth`` path
    segments, unique, in first-seen order.

    This is the granularity mixed-precision certificates assign k at: depth 1
    yields the model's top-level blocks ("dense1", "layer0", ...); deeper
    depths split blocks into sublayers. Only *scopes* qualify (record() names
    don't open one), so the result is exactly what `_GatedCaaOps` /
    `repro.certify.mixed` scope gating can address. Costs one eager pass —
    when a :class:`BatchedErrorReport` is already in hand, use its ``scopes``
    with :func:`scope_prefixes` instead.
    """
    ops = CaaOps(cfg)
    forward(ops, params, x)
    return scope_prefixes(ops.seen_scopes, depth)


def aggregate_ranges(path_stats: Dict[str, Any],
                     keys: Sequence[str]) -> Dict[str, Any]:
    """Fold per-path RangeStats onto a chosen scope granularity.

    Each recorded scope path is assigned to the most specific matching key
    (same longest-contiguous-segment rule as :func:`resolve_scope_value`,
    so the aggregation mirrors exactly how serving resolves a per-scope
    format map); paths outside every key fold into the ``""`` default
    entry. Every key is present in the result (empty RangeStat if its scope
    produced no values)."""
    from .backend import RangeStat

    out: Dict[str, Any] = {k: RangeStat() for k in list(keys) + [""]}
    ident = {k: k for k in keys}
    for path, stat in path_stats.items():
        segs = [s for s in path.split("/") if s]
        key = resolve_scope_value(segs, ident, "")
        out[key] = out[key].merge(stat)
    return out


def analyze_ranges(
    forward, params, x: CaaTensor,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
    keys: Optional[Sequence[str]] = None,
    depth: int = 1,
) -> Dict[str, Any]:
    """Per-scope IA magnitude enclosures [min_nonzero, max_abs] from one
    eager pass (the range analysis behind (k, emin, emax) format
    certification — see :mod:`repro.certify.formats`).

    Returns {scope_key: RangeStat} at the same granularity mixed-precision
    maps use (``keys``, or the depth-``depth`` prefixes of the discovered
    scopes), plus the ``""`` entry covering ops outside every key.
    """
    from .backend import RangeCaaOps

    ops = RangeCaaOps(cfg, weights_exact=weights_exact)
    forward(ops, params, x)
    if keys is None:
        keys = scope_prefixes(ops.seen_scopes, depth)
    return aggregate_ranges(ops.scope_ranges, keys)


def mixed_precision(
    forward, params, x: CaaTensor, p_star: float,
    layer_names: Sequence[str],
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
):
    """End-to-end mixed-precision plan (the paper's future-work item):
    attribute the bound per layer, then split the margin budget."""
    slack = sensitivity(forward, params, x, layer_names, cfg)
    mu = theory.abs_margin(p_star)
    return precision.mixed_precision_plan(slack, mu)


# ---------------------------------------------------------------------------
# scan-native (layer-stacked) variants — O(1) HLO in depth, the analysis
# path LM architectures certify through (repro.certify.lm)
# ---------------------------------------------------------------------------

def discover_scopes_stacked(
    forward, params, x: CaaTensor, n_layers: int,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    depth: int = 1,
) -> List[str]:
    """The scope keys one *scan-native* pass enters, with the ``layer*``
    stack wildcard expanded to concrete ``layer{i}`` names.

    Equivalent to :func:`discover_scopes` on an eager unrolled pass, but
    the walk traces each ``layer_loop`` body once (lax.scan) — for an
    L-layer model this costs O(1) analysis work in depth instead of O(L).
    """
    ops = StackedCaaOps(cfg)
    forward(ops, params, x)
    return expand_stacked(scope_prefixes(ops.seen_scopes, depth), n_layers)


def onehot_scale_vector(scope_keys: Sequence[str],
                        scope_key: str) -> np.ndarray:
    """Scale vector enabling fresh roundings ONLY in one scope (the
    trailing default slot stays 0) — the sensitivity probe's input. Single
    home of the convention: every probe interface (here, MixedProbeLadder,
    the format ladder's mixed view) builds its one-hot through this."""
    scales = np.zeros(len(scope_keys) + 1, np.float64)
    scales[list(scope_keys).index(scope_key)] = 1.0
    return scales


def sensitivity_stacked(
    forward, params, x: CaaTensor,
    scope_keys: Sequence[str],
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
) -> Dict[str, float]:
    """Per-scope contribution to the final absolute bound, scan-native.

    The jitted equivalent of :func:`sensitivity`: fresh roundings are
    enabled one scope at a time via one-hot entries of a *traced* scale
    vector — ``layer{i}`` keys gather through the scan carry's layer index
    — so the whole ranking costs exactly ONE compilation + L cheap probes
    instead of L full retraces.
    """
    keys = tuple(scope_keys)
    if not keys:
        return {}

    def bounds(params_, x_, scales):
        sm = {key: scales[i] for i, key in enumerate(keys)}
        ops = StackedCaaOps(cfg, sm, default_scale=scales[len(keys)],
                            weights_exact=weights_exact)
        out = forward(ops, params_, x_)
        return jnp.max(out.dbar)

    fn = jax.jit(bounds)
    return {key: float(fn(params, x,
                          jnp.asarray(onehot_scale_vector(keys, key))))
            for key in keys}


def analyze_ranges_stacked(
    forward, params, x: CaaTensor,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
    keys: Optional[Sequence[str]] = None,
    sublanes: Sequence[str] = (),
) -> Dict[str, Any]:
    """Scan-native sibling of :func:`analyze_ranges`: per-layer IA magnitude
    enclosures accumulate as [L, 4] lanes through `.at[i]` updates on the
    scan carry (:class:`repro.core.backend.StackedRangeCaaOps`), one pass
    whose HLO is flat in depth. Returns {scope_key: RangeStat} with the
    ``""`` entry covering every op outside the layer stack. ``sublanes``
    names sub-layer scopes (e.g. ``("attn", "mlp")``) that get their own
    accumulator lane, so the evidence lands at ``layer{i}/attn``
    granularity instead of folding into the per-layer lane."""
    ops = StackedRangeCaaOps(cfg, weights_exact=weights_exact,
                             sublanes=sublanes)
    forward(ops, params, x)
    stats = ops.collect_ranges()
    if keys is None:
        keys = [k for k in stats if k]
    return aggregate_ranges(stats, keys)


def analyze_ranges_affine(
    forward, params, x: CaaTensor,
    scope_fmts: Dict[str, Any],
    default_fmt,
    keys: Optional[Sequence[str]] = None,
    stacked: bool = True,
    sublanes: Sequence[str] = (),
    budget: int = iv.AFF_DEFAULT_BUDGET,
    weights_exact: bool = True,
    condense_rank: str = iv.AFF_DEFAULT_RANK,
) -> Dict[str, Any]:
    """Affine/zonotope range pass: per-scope magnitude enclosures of the
    ROUNDED values under a per-scope format map, via the two-channel
    forward propagation of :class:`repro.core.backend.AffineRangeCaaOps`.

    Unlike the IA passes above — which bound |v̂| through the CAA error
    terms and saturate once the parametric γ accumulation bounds blow up
    at coarse k — this pass's enclosures are finite at every precision
    (its rounding model is the operational (1+u/2)^n growth). It proves
    nothing about (δ̄, ε̄); its RangeStats exist to be min-combined with
    the IA evidence via :func:`tighten_range_maps`, which is what lets
    the mixed-mantissa format attempt survive on attention archs.

    ``budget`` caps the live noise symbols per tensor (condensation folds
    the overflow into the interval remainder — smaller is cheaper, larger
    cancels more correlation); ``condense_rank`` picks which symbols the
    condensation retains (:data:`repro.core.interval.AFF_DEFAULT_RANK`:
    sensitivity-ranked — largest downstream contribution to the output
    enclosure — rather than largest current magnitude)."""
    from .backend import AffineRangeCaaOps, StackedAffineRangeCaaOps

    if stacked:
        ops = StackedAffineRangeCaaOps(scope_fmts, default_fmt,
                                       budget=budget,
                                       weights_exact=weights_exact,
                                       sublanes=sublanes,
                                       condense_rank=condense_rank)
        forward(ops, params, x)
        stats = ops.collect_ranges()
    else:
        ops = AffineRangeCaaOps(scope_fmts, default_fmt, budget=budget,
                                weights_exact=weights_exact,
                                condense_rank=condense_rank)
        forward(ops, params, x)
        stats = dict(ops.scope_ranges)
    if keys is None:
        keys = [k for k in stats if k]
    return aggregate_ranges(stats, keys)


def tighten_range_maps(base: Dict[str, Any],
                       tight: Dict[str, Any]) -> Dict[str, Any]:
    """Min-combine two sound range maps over the same values and format
    map (e.g. the IA evidence with the affine pass's): both ``max_abs``
    are upper bounds on the same |v̂|, so their min is a sound, tighter
    bound. Underflow evidence stays conservative — ``min_nonzero`` keeps
    the weaker (smaller) claim and ``crosses_zero`` ORs, because those are
    per-scope aggregates whose per-value intersection is not recoverable
    here. Keys missing from ``tight`` pass through unchanged.

    Soundness requires both maps to describe the SAME input profile and
    format map — tighten per profile first, then widen across profiles
    with :func:`merge_range_maps`, never the other way around."""
    from .backend import RangeStat

    out: Dict[str, Any] = {}
    for key, b in base.items():
        t = tight.get(key)
        if t is None or t.n_ops == 0 or b.n_ops == 0:
            out[key] = b
            continue
        out[key] = RangeStat(
            max_abs=min(b.max_abs, t.max_abs),
            min_nonzero=min(b.min_nonzero, t.min_nonzero),
            crosses_zero=b.crosses_zero or t.crosses_zero,
            n_ops=max(b.n_ops, t.n_ops),
        )
    return out


def merge_range_maps(maps: Sequence[Dict[str, Any]],
                     keys: Sequence[str]) -> Dict[str, Any]:
    """Fold several {scope: RangeStat} maps (e.g. one per input profile)
    onto one key set, through :func:`aggregate_ranges` so the per-path →
    key assignment stays identical to single-profile aggregation. The
    profile prefix keeps colliding paths distinct; it matches no key, so
    each path still lands where its own segments say."""
    combined: Dict[str, Any] = {}
    for p, m in enumerate(maps):
        for path, stat in m.items():
            combined[f"profile{p}/{path}" if path else f"profile{p}"] = stat
    return aggregate_ranges(combined, keys)
