"""Semi-automatic precision/accuracy analysis driver (paper Section V).

The workflow the paper describes — load a trained model, annotate the input
with interval ranges, run it once per class under the enhanced arithmetic,
read off absolute/relative output bounds in units of u, then tailor the
precision — is implemented here against our backends:

    report = analyze(forward, params, input_range, p_star=0.6)
    report.decision.required_k        # Table-I style answer
    report.layers                     # per-layer trace
    plan = mixed_precision(forward, params, input_range, p_star=0.6)

``forward(backend, params, x)`` must be written against
:class:`repro.core.backend.Backend` and return the output (for classifiers:
the softmax probabilities).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from . import caa, interval as iv, precision, theory
from .backend import Backend, CaaOps, TraceRecord
from .caa import CaaConfig, CaaTensor


@dataclasses.dataclass
class ErrorReport:
    """The analyser's output — everything Table I reports, plus the trace."""

    final_abs_u: float
    final_rel_u: float
    output_range: tuple  # (lo, hi) arrays
    layers: List[TraceRecord]
    analysis_seconds: float
    cfg: CaaConfig
    decision: Optional[precision.PrecisionDecision] = None
    router_records: List[TraceRecord] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        lines = [
            f"max absolute error: {self.final_abs_u:.4g} u",
            f"max relative error: {self.final_rel_u:.4g} u",
            f"analysis time: {self.analysis_seconds:.3f} s",
        ]
        if self.decision is not None:
            lines.append(self.decision.explain())
        return "\n".join(lines)

    def dominant_layer(self) -> Optional[TraceRecord]:
        finite = [r for r in self.layers if jnp.isfinite(r.max_dbar)]
        return max(finite, key=lambda r: r.max_dbar, default=None)


def analyze(
    forward: Callable[[Backend, dict, CaaTensor], CaaTensor],
    params: dict,
    x: CaaTensor,
    p_star: Optional[float] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
) -> ErrorReport:
    """One analysis pass (the paper's 'one representative per class' run —
    the interval input covers the whole class, so one run per control flow
    suffices; with fixed routing that is one run)."""
    ops = CaaOps(cfg, weights_exact=weights_exact)
    t0 = time.perf_counter()
    out = forward(ops, params, x)
    abs_u, rel_u = caa.worst(out)
    dt = time.perf_counter() - t0
    decision = None
    if p_star is not None:
        try:
            decision = precision.decide(abs_u, rel_u, p_star)
        except ValueError:
            decision = None  # bounds saturated at this u_max — re-run smaller
    return ErrorReport(
        final_abs_u=abs_u,
        final_rel_u=rel_u,
        output_range=(out.exact.lo, out.exact.hi),
        layers=[r for r in ops.trace if r.kind != "router"],
        analysis_seconds=dt,
        cfg=cfg,
        decision=decision,
        router_records=[r for r in ops.trace if r.kind == "router"],
    )


@dataclasses.dataclass
class BatchedErrorReport:
    """Per-class bounds from ONE joint CAA pass over stacked class inputs.

    The paper runs the analysis "once per class"; since every CAA rule is
    tensorised and row-independent along a leading batch axis, stacking the
    per-class interval inputs collapses those C runs into one compiled
    evaluation with bit-identical per-class bounds (tests/test_analyze.py
    asserts the agreement).
    """

    abs_u: np.ndarray            # [C] max δ̄ per class, units of u
    rel_u: np.ndarray            # [C] max ε̄ per class, units of u
    output_range: tuple          # (lo, hi) arrays, leading axis = class
    layers: List[TraceRecord]    # trace of the joint pass (maxima span classes)
    analysis_seconds: float
    cfg: CaaConfig               # the caller's per-class-equivalent config
    decisions: Optional[List[Optional[precision.PrecisionDecision]]] = None
    scopes: List[str] = dataclasses.field(default_factory=list)
    # ^ every scope path the pass entered (first-seen order) — lets callers
    #   (e.g. the mixed-precision pipeline) pick a layer granularity without
    #   paying a second analysis just to enumerate names

    @property
    def n_classes(self) -> int:
        return int(self.abs_u.shape[0])

    def per_class(self, c: int) -> tuple:
        return float(self.abs_u[c]), float(self.rel_u[c])


def batch_config(cfg: CaaConfig, n_classes: int) -> CaaConfig:
    """Per-class-equivalent config for a stacked run.

    The trajectory-mode gate in :func:`caa.matmul` counts *output elements
    across the whole stack*, so a batched run over C classes would fall back
    to the looser γ_n rule C× earlier than the sequential runs it replaces.
    Scaling the budget by C makes the batched pass take exactly the same
    trajectory-vs-γ branch per class as C sequential passes — the invariant
    behind the batched == sequential bound agreement.
    """
    return dataclasses.replace(
        cfg, traj_max_elems=cfg.traj_max_elems * max(int(n_classes), 1)
    )


def analyze_batched(
    forward: Callable[[Backend, dict, CaaTensor], CaaTensor],
    params: dict,
    x: CaaTensor,
    p_star: Optional[float] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
    class_axis: int = 0,
) -> BatchedErrorReport:
    """All classes at once: the paper's C per-class runs in one evaluation.

    ``x`` stacks the per-class interval inputs along ``class_axis`` (use
    :func:`caa.from_range` on stacked lo/hi envelopes). Bounds per class
    match :func:`analyze` on the corresponding slice exactly.
    """
    n = int(jnp.shape(x.val)[class_axis])
    ops = CaaOps(batch_config(cfg, n), weights_exact=weights_exact)
    t0 = time.perf_counter()
    out = forward(ops, params, x)
    axis = class_axis % out.ndim
    red = tuple(i for i in range(out.ndim) if i != axis)
    dbar = jnp.broadcast_to(out.dbar, out.shape)
    ebar = jnp.broadcast_to(out.ebar, out.shape)
    abs_u = np.asarray(jnp.max(dbar, axis=red), np.float64)
    rel_u = np.asarray(jnp.max(ebar, axis=red), np.float64)
    dt = time.perf_counter() - t0
    decisions = None
    if p_star is not None:
        decisions = []
        for c in range(n):
            try:
                decisions.append(precision.decide(
                    float(abs_u[c]), float(rel_u[c]), p_star))
            except ValueError:
                decisions.append(None)  # saturated at this u_max
    return BatchedErrorReport(
        abs_u=abs_u,
        rel_u=rel_u,
        output_range=(out.exact.lo, out.exact.hi),
        layers=[r for r in ops.trace if r.kind != "router"],
        analysis_seconds=dt,
        cfg=cfg,
        decisions=decisions,
        scopes=list(ops.seen_scopes),
    )


def verify_classification(
    forward, params, x: CaaTensor, fmt, predicted: int,
    cfg: Optional[CaaConfig] = None,
) -> bool:
    """Rigorous per-input argmax check at a concrete format: inflate the
    output enclosure by the error bounds at u = fmt.u and test top-1."""
    from . import formats as _f

    fmt = _f.get(fmt)
    cfg = cfg or CaaConfig(u_max=fmt.u)
    if fmt.u > cfg.u_max:
        raise ValueError("format's u exceeds the analysed u_max — re-analyse")
    ops = CaaOps(cfg)
    out = forward(ops, params, x)
    rng = out.fp_range(fmt.u)
    return precision.classification_safe(rng.lo, rng.hi, predicted)


def sensitivity(
    forward, params, x: CaaTensor,
    layer_names: Sequence[str],
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
) -> Dict[str, float]:
    """Per-layer contribution to the final absolute bound.

    Re-runs the analysis once per layer with fresh roundings enabled *only*
    in that layer's scope (round_scale gating) — the attribution needed by
    :func:`repro.core.precision.mixed_precision_plan`. Cost: L analyses —
    affordable because the tensorised analysis is fast (see
    benchmarks/analysis_speed.py).
    """
    out: Dict[str, float] = {}
    for name in layer_names:
        ops = _GatedCaaOps(cfg, active_scope=name)
        y = forward(ops, params, x)
        abs_u, _ = caa.worst(y)
        out[name] = abs_u
    return out


def resolve_scope_value(path: Sequence[str], mapping: Dict[str, Any],
                        default):
    """Value of the most specific (longest) map key matching ``path``.

    Matching is by contiguous path *segments* (same rule as
    :func:`_scope_active` — 'block1' never matches inside 'block10');
    ``default`` covers ops outside every mapped scope. Shared by the
    mixed-precision analysis (scope → round_scale) and the mixed serving
    backend (scope → quantisation k).
    """
    best, best_len = default, 0
    for key, v in mapping.items():
        want_len = len(key.split("/"))
        if want_len >= best_len and path and _scope_active(key, path):
            best, best_len = v, want_len
    return best


def _scope_active(active: str, scope: Sequence[str]) -> bool:
    """True iff ``active``'s '/'-separated segments appear as a contiguous
    run of the current scope path's segments. Substring matching is wrong
    here: layer 'block1' must not activate inside 'block10'."""
    parts = [seg for s in scope for seg in s.split("/")]
    want = active.split("/")
    return any(
        parts[i:i + len(want)] == want
        for i in range(len(parts) - len(want) + 1)
    )


class _GatedCaaOps(CaaOps):
    """CaaOps whose fresh roundings are active only inside one scope."""

    def __init__(self, cfg: CaaConfig, active_scope: str):
        super().__init__(cfg)
        self._active = active_scope
        self._base_cfg = cfg
        self._off_cfg = dataclasses.replace(cfg, round_scale=0.0)
        self.cfg = self._off_cfg

    def _scope_changed(self):
        super()._scope_changed()
        self.cfg = (self._base_cfg
                    if _scope_active(self._active, self._scope)
                    else self._off_cfg)


def scope_prefixes(paths: Sequence[str], depth: int = 1) -> List[str]:
    """Unique ``depth``-segment prefixes of scope paths, first-seen order."""
    out: List[str] = []
    for path in paths:
        prefix = "/".join(path.split("/")[:depth])
        if prefix not in out:
            out.append(prefix)
    return out


def discover_scopes(
    forward, params, x: CaaTensor,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    depth: int = 1,
) -> List[str]:
    """The scope names one analysis pass enters, truncated to ``depth`` path
    segments, unique, in first-seen order.

    This is the granularity mixed-precision certificates assign k at: depth 1
    yields the model's top-level blocks ("dense1", "layer0", ...); deeper
    depths split blocks into sublayers. Only *scopes* qualify (record() names
    don't open one), so the result is exactly what `_GatedCaaOps` /
    `repro.certify.mixed` scope gating can address. Costs one eager pass —
    when a :class:`BatchedErrorReport` is already in hand, use its ``scopes``
    with :func:`scope_prefixes` instead.
    """
    ops = CaaOps(cfg)
    forward(ops, params, x)
    return scope_prefixes(ops.seen_scopes, depth)


def aggregate_ranges(path_stats: Dict[str, Any],
                     keys: Sequence[str]) -> Dict[str, Any]:
    """Fold per-path RangeStats onto a chosen scope granularity.

    Each recorded scope path is assigned to the most specific matching key
    (same longest-contiguous-segment rule as :func:`resolve_scope_value`,
    so the aggregation mirrors exactly how serving resolves a per-scope
    format map); paths outside every key fold into the ``""`` default
    entry. Every key is present in the result (empty RangeStat if its scope
    produced no values)."""
    from .backend import RangeStat

    out: Dict[str, Any] = {k: RangeStat() for k in list(keys) + [""]}
    ident = {k: k for k in keys}
    for path, stat in path_stats.items():
        segs = [s for s in path.split("/") if s]
        key = resolve_scope_value(segs, ident, "")
        out[key] = out[key].merge(stat)
    return out


def analyze_ranges(
    forward, params, x: CaaTensor,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
    keys: Optional[Sequence[str]] = None,
    depth: int = 1,
) -> Dict[str, Any]:
    """Per-scope IA magnitude enclosures [min_nonzero, max_abs] from one
    eager pass (the range analysis behind (k, emin, emax) format
    certification — see :mod:`repro.certify.formats`).

    Returns {scope_key: RangeStat} at the same granularity mixed-precision
    maps use (``keys``, or the depth-``depth`` prefixes of the discovered
    scopes), plus the ``""`` entry covering ops outside every key.
    """
    from .backend import RangeCaaOps

    ops = RangeCaaOps(cfg, weights_exact=weights_exact)
    forward(ops, params, x)
    if keys is None:
        keys = scope_prefixes(ops.seen_scopes, depth)
    return aggregate_ranges(ops.scope_ranges, keys)


def mixed_precision(
    forward, params, x: CaaTensor, p_star: float,
    layer_names: Sequence[str],
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
):
    """End-to-end mixed-precision plan (the paper's future-work item):
    attribute the bound per layer, then split the margin budget."""
    slack = sensitivity(forward, params, x, layer_names, cfg)
    mu = theory.abs_margin(p_star)
    return precision.mixed_precision_plan(slack, mu)
