"""CAA — Combined (absolute + relative) Affine Arithmetic, tensorised.

The paper's Section III attaches to every FP scalar: a unique id, its FP
value, an enclosure of the ideal value, an enclosure of the rounded value,
an absolute error bound δ̄ and a relative error bound ε̄ — both in units of
``u = 2^{1-k}`` and both allowed to be +∞ — and re-derives, per operation,
how the operand bounds combine with the fresh rounding (eq. (5)) into bounds
on the result, using Interval Arithmetic to bound amplification factors
(the α_r, α_s of eq. (8)).

We keep *exactly* that semantics, but in tensor form:

  CaaTensor(val, exact, dbar, ebar)

  val    reference evaluation in f64 (plays the role of the paper's FP value
         computed "without the enhanced arithmetic"; f64 ≫ any target format)
  exact  Interval enclosure of the ideal, error-free quantity
  dbar   absolute error bound, units of u:  |q̂ − q| ≤ dbar·u
  ebar   relative error bound, units of u:  q̂ = q(1+εu), |ε| ≤ ebar·u
         (+inf in either bound = "no bound of this kind", paper convention)

The enclosure of the *rounded* value is derived on demand (``fp_range``) as
the tighter of the two inflations of ``exact`` — keeping it as a stored
field (as the paper's C++ objects do) would be redundant here because the
tensor rules below never let it drift from that derivation.

Key difference to the paper's scalar C++ objects: rules for *reductions*
(dot products, convolutions, sums — the body of every computational layer)
are applied in closed form (Higham-style γ_n factors, parameterised by the
accumulation order) rather than by folding the scalar rule n times. The
closed form is what the fold converges to; it is sound for every order we
model:

  sequential  γ_n          (frugally-deep's scalar loop — paper-faithful)
  pairwise    γ_{⌈log2 n⌉+1}   (XLA/TPU reduction trees)
  kahan       γ_{3} + n²u² term (compensated summation — the paper's
                                 'future work' codegen hook)

Unique-id decorrelation and FP-dependent control flow (paper §III, last
part) are handled structurally: the analyser walks the same layer graph the
runtime executes, so x−x never occurs syntactically, and ordering facts
(softmax's x − max(x) ≤ 0) are applied as dedicated composite rules.

Everything below is straight-line jnp on f64; bounds are kept sound under
f64 evaluation by an upward-slop multiplier on every bound expression
(``_ru``), and ranges by the outward rounding inside :mod:`interval`.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from . import interval as iv
from .interval import Interval

_F64 = jnp.float64
_INF = jnp.inf
# Upward slop for bound expressions of <= ~2^10 f64 flops.
_SLOP = 1.0 + 2.0 ** -40


def _ru(x):
    """Round a non-negative bound expression upward (sound in f64)."""
    return jnp.asarray(x, _F64) * _SLOP


def _san(x):
    """inf−inf / 0·inf artefacts mean 'no information' → +inf (paper conv.)."""
    return jnp.where(jnp.isnan(x), _INF, x)


def _emul(val, cfg):
    """Round a freshly computed reference value into the emulated format."""
    if cfg.emulate_k is None:
        return val
    from .quantize import _quantize_normal

    return _quantize_normal(jnp.asarray(val, _F64), cfg.emulate_k)


@dataclasses.dataclass(frozen=True)
class CaaConfig:
    """Analysis-wide parameters.

    u_max: user-configurable upper bound on u (paper §V: "in units of u, an
      upper bound on which is user-configurable"). Second-order terms are
      bounded with it. Instantiating bounds for a format with u ≤ u_max is
      sound.
    acc_order: reduction/accumulation order being analysed.
    libm_rel: relative rounding bound (units of u) for one transcendental
      evaluation in the target arithmetic; 0.5 = correctly rounded, 1.0 =
      faithful.
    """

    u_max: float = 2.0 ** -7
    acc_order: str = "sequential"
    libm_rel: float = 0.5
    # Scales every *fresh* rounding introduced by an op (0 = exact arithmetic,
    # propagation only). Used by analyze.sensitivity to attribute the final
    # bound to individual layers for mixed-precision planning.
    round_scale: float = 1.0
    # Absolute error charged per fresh rounding, in units of u (0 = the
    # unbounded-exponent-range model of eq. (5)). This is the underflow /
    # subnormal-absorption term of a format with finite emin: the full
    # standard model is fl(x) = x(1+ε) + η with |η| ≤ the subnormal grid
    # spacing 2^{emin-(k-1)} (flush-to-zero: 2^{emin}); round_abs = η/u.
    # Charged into δ̄ (and into ε̄ via η/mig — no purely-relative claim
    # survives a flush through zero) by :func:`_finish`. Like u_max and
    # round_scale it may be a jax tracer: the format probe ladder
    # (repro.certify.formats) sweeps it as a traced argument. NOTE: because
    # η is a fixed absolute quantity while δ̄ is in units of u, bounds with
    # round_abs > 0 are exact statements at u = u_max only — which is how
    # the format pipeline instantiates them (one probe per candidate k).
    round_abs: float = 0.0
    # Trajectory mode: bound dot-product roundings by the magnitudes of the
    # actual partial sums (the exact tensorised equivalent of folding the
    # paper's scalar rule — benefits from cancellation, vastly tighter for
    # trained weights) instead of the γ_n·Σ|x||w| worst case. Applied when
    # the materialised per-term product tensor fits under traj_max_elems.
    use_trajectory: bool = True
    traj_max_elems: int = 2 ** 24
    # Emulate the target format in the ``val`` field: every op's reference
    # value is rounded to k-bit mantissa after computation. The paper's CAA
    # objects carry exactly this ('the FP value ... if the DNNs were
    # implemented without this enhanced arithmetic') plus 'an interval
    # holding the actual error of the latter FP value' — recoverable here as
    # actual_error_in_u(). None → val stays f64 (pure-bound analysis).
    emulate_k: int | None = None
    # When emulating, run matmul accumulations step-by-step in the target
    # format (sequential/pairwise per acc_order) instead of rounding the f64
    # result once — the faithful frugally-deep semantics.
    emulate_accum: bool = True

    @property
    def half(self) -> float:
        """One elementary rounding, in units of u (×round_scale)."""
        return 0.5 * self.round_scale

    @property
    def libm(self) -> float:
        return self.libm_rel * self.round_scale

    def gamma(self, n_terms: int):
        """γ factor in units of u for reducing ``n_terms`` values (+ products).

        Standard model with unit roundoff u/2: γ_m = (m·u/2)/(1 − m·u/2),
        expressed in units of u → (m/2)/(1 − m·u/2).

        ``u_max``/``round_scale`` are usually Python floats, but may also be
        jax tracers (the jitted probe ladder traces one analysis over a whole
        precision grid with u_max as an argument) — the saturation branch is
        then a ``where``, not Python control flow, and a 0-d array is
        returned; every consumer only does arithmetic with the result.
        """
        n = max(int(n_terms), 1)
        if self.acc_order == "sequential":
            m = n
        elif self.acc_order == "pairwise":
            m = max(1, math.ceil(math.log2(n))) + 1
        elif self.acc_order == "kahan":
            # Compensated summation: 2u + O(n u^2) per Higham; +1 for the
            # product rounding; n²u second-order guard keeps it rigorous.
            m = 3 + n * n * self.u_max
        else:
            raise ValueError(f"unknown acc_order {self.acc_order!r}")
        denom = 1.0 - 0.5 * m * self.u_max
        if isinstance(denom, (int, float)) and isinstance(self.round_scale, (int, float)):
            if denom <= 0:
                return float(_INF)
            return (0.5 * m) / denom * _SLOP * self.round_scale
        safe = jnp.where(denom > 0, denom, 1.0)
        g = (0.5 * m) / safe * _SLOP * self.round_scale
        return jnp.where(denom > 0, g, _INF)


DEFAULT_CONFIG = CaaConfig()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CaaTensor:
    val: jax.Array
    exact: Interval
    dbar: jax.Array
    ebar: jax.Array

    # -- pytree plumbing --
    def tree_flatten(self):
        return (self.val, self.exact.lo, self.exact.hi, self.dbar, self.ebar), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        v, lo, hi, d, e = leaves
        return cls(v, Interval(lo, hi), d, e)

    @property
    def shape(self):
        return jnp.shape(self.val)

    @property
    def ndim(self):
        return jnp.ndim(self.val)

    def fp_range(self, u: float) -> Interval:
        """Enclosure of the value as computed in FP with unit u ≤ u_max."""
        d = jnp.where(jnp.isfinite(self.dbar), self.dbar, _INF)
        by_abs = iv.widen_abs(self.exact, _ru(d * u))
        f = jnp.where(jnp.isfinite(self.ebar), self.ebar * u, _INF)
        by_rel = Interval(
            jnp.minimum(self.exact.lo * (1 + f), self.exact.lo * (1 - f)),
            jnp.maximum(self.exact.hi * (1 + f), self.exact.hi * (1 - f)),
        )
        lo = jnp.maximum(_san(by_abs.lo * -1) * -1, _san(-by_rel.lo) * -1)
        hi = jnp.minimum(_san(by_abs.hi), _san(by_rel.hi))
        return Interval(lo, hi)


# ---------------------------------------------------------------------------
# construction & normalisation
# ---------------------------------------------------------------------------

def _normalize(c: CaaTensor) -> CaaTensor:
    """Cross-improve the two bounds (paper: 'CAA improves the one bound using
    the other whenever possible')."""
    m = iv.mag(c.exact)
    g = iv.mig(c.exact)
    d_from_e = _san(jnp.where(jnp.isfinite(c.ebar), _ru(c.ebar * m), _INF))
    e_from_d = _san(
        jnp.where(g > 0, _ru(c.dbar / jnp.where(g > 0, g, 1.0)), _INF)
    )
    dbar = jnp.minimum(_san(c.dbar), d_from_e)
    ebar = jnp.minimum(_san(c.ebar), e_from_d)
    return CaaTensor(c.val, c.exact, dbar, ebar)


def _finish(cfg: CaaConfig, c: CaaTensor, rounds=1) -> CaaTensor:
    """Normalise an op result, then charge its finite-range underflow term.

    Each of the op's ``rounds`` fresh roundings may — beyond the relative
    (1+εu) part the rule already charged — displace the result by the
    absolute η of the target format (``cfg.round_abs``, units of u). δ̄
    takes the charge directly; ε̄ is inflated by η/mig(exact) (+∞ when the
    enclosure touches zero: a flush through zero is 100% relative error), so
    the cross-improvement in :func:`_normalize` stays sound downstream.
    With the default round_abs = 0.0 this is exactly :func:`_normalize`
    (bit-for-bit — the mantissa-only pipelines are untouched).
    """
    c = _normalize(c)
    ra = cfg.round_abs
    if isinstance(ra, (int, float)) and ra == 0.0:
        return c
    add = _ru(jnp.asarray(rounds, _F64) * ra)
    g = iv.mig(c.exact)
    rel = _san(jnp.where(g > 0, add / jnp.where(g > 0, g, 1.0), _INF))
    return CaaTensor(c.val, c.exact, _san(c.dbar + add), _san(c.ebar + rel))


def make(val, exact: Optional[Interval] = None, dbar=0.0, ebar=0.0) -> CaaTensor:
    val = jnp.asarray(val, _F64)
    if exact is None:
        exact = iv.point(val)
    dbar = jnp.broadcast_to(jnp.asarray(dbar, _F64), val.shape)
    ebar = jnp.broadcast_to(jnp.asarray(ebar, _F64), val.shape)
    return _normalize(CaaTensor(val, exact, dbar, ebar))


def const_exact(val) -> CaaTensor:
    """A constant exactly representable in the target format (δ̄=ε̄=0)."""
    return make(val)


def const_rounded(val, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """A real constant stored rounded-to-nearest in the target format:
    one rounding → ε̄ = 1/2 (this covers weights re-quantised from f32)."""
    return make(val, dbar=_INF, ebar=cfg.half)


def weight(w, cfg: CaaConfig = DEFAULT_CONFIG, exact: bool = True) -> CaaTensor:
    """A parameter tensor under the analysis/emulation config.

    exact=True (paper default): the stored, format-representable weight *is*
    the reference — val is quantised into the emulated format (if any) and
    the ideal equals it (δ̄=ε̄=0).
    exact=False: the ideal is the full-precision weight; storage costs one
    rounding (ε̄ = ½, val quantised).
    """
    w = jnp.asarray(w, _F64)
    wq = _emul(w, cfg)
    if exact:
        return make(wq)
    return _normalize(CaaTensor(wq, iv.point(w),
                                jnp.full(w.shape, _INF), jnp.full(w.shape, cfg.half)))


def from_range(lo, hi, dbar=0.0, ebar=0.0) -> CaaTensor:
    """Input data known only by an interval (paper §V: images in [0;255])."""
    lo = jnp.asarray(lo, _F64)
    hi = jnp.asarray(hi, _F64)
    mid = 0.5 * (lo + hi)
    return make(mid, Interval(*jnp.broadcast_arrays(lo, hi)), dbar, ebar)


# ---------------------------------------------------------------------------
# rel-bound combinators
# ---------------------------------------------------------------------------

def _combine_rel(cfg: CaaConfig, *es):
    """Bound (Π(1+θ_i u) − 1)/u for |θ_i| ≤ e_i u — the product-of-factors
    pattern from the paper's eq. (8) second-order handling, bounded at
    u_max."""
    total = jnp.asarray(0.0, _F64)
    for e in es:
        e = jnp.asarray(e, _F64)
        total = total + e + total * e * cfg.u_max
    return _san(_ru(total))


def _eff_dbar(c: CaaTensor) -> jax.Array:
    """The sharpest absolute bound derivable from both fields."""
    m = iv.mag(c.exact)
    alt = _san(jnp.where(jnp.isfinite(c.ebar), c.ebar * m, _INF))
    return jnp.minimum(_san(c.dbar), _ru(alt))


def _eff_ebar(c: CaaTensor) -> jax.Array:
    g = iv.mig(c.exact)
    alt = _san(jnp.where(g > 0, c.dbar / jnp.where(g > 0, g, 1.0), _INF))
    return jnp.minimum(_san(c.ebar), _ru(alt))


def _mig_fp(c: CaaTensor, cfg: CaaConfig) -> jax.Array:
    """inf |x̂| over the FP-perturbed range — the safe distance from 0 that
    Lipschitz-style absolute rules need (0 if the perturbation may cross 0)."""
    d = _eff_dbar(c)
    pad = _san(d * cfg.u_max)
    return iv.mig(Interval(c.exact.lo - pad, c.exact.hi + pad))


# ---------------------------------------------------------------------------
# basic arithmetic
# ---------------------------------------------------------------------------

def add(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.add(a.exact, b.exact)
    da, db = _eff_dbar(a), _eff_dbar(b)
    # |fl(â+b̂) − (a+b)| ≤ (δa+δb)u + ½u·|â+b̂|
    mag_fp = iv.mag(exact) + (da + db) * cfg.u_max
    dbar = _ru(da + db + cfg.half * mag_fp)
    # relative path with IA-bounded amplification (paper eq. (8))
    g = iv.mig(exact)
    alpha_a = _san(jnp.where(g > 0, iv.mag(a.exact) / jnp.where(g > 0, g, 1.0), _INF))
    alpha_b = _san(jnp.where(g > 0, iv.mag(b.exact) / jnp.where(g > 0, g, 1.0), _INF))
    e_prop = _san(_eff_ebar(a) * alpha_a) + _san(_eff_ebar(b) * alpha_b)
    ebar = _combine_rel(cfg, e_prop, cfg.half)
    return _finish(cfg, CaaTensor(_emul(a.val + b.val, cfg), exact, _san(dbar), ebar))


def sub(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    return add(a, neg(b), cfg)


def neg(a: CaaTensor) -> CaaTensor:
    return CaaTensor(-a.val, iv.neg(a.exact), a.dbar, a.ebar)


def mul(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.mul(a.exact, b.exact)
    ebar = _combine_rel(cfg, _eff_ebar(a), _eff_ebar(b), cfg.half)
    # direct absolute path: |âb̂ − ab| ≤ |a|δb u + |b|δa u + δaδb u² + ½u|âb̂|
    da, db = _eff_dbar(a), _eff_dbar(b)
    ma, mb = iv.mag(a.exact), iv.mag(b.exact)
    direct = (
        ma * db
        + mb * da
        + da * db * cfg.u_max
        + cfg.half * (ma + da * cfg.u_max) * (mb + db * cfg.u_max)
    )
    dbar = _san(_ru(direct))
    return _finish(cfg, CaaTensor(_emul(a.val * b.val, cfg), exact, dbar, ebar))


def div(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.div(a.exact, b.exact)
    eb = _eff_ebar(b)
    inv_e = _san(jnp.where(eb * cfg.u_max < 1, eb / (1 - eb * cfg.u_max), _INF))
    ebar = _combine_rel(cfg, _eff_ebar(a), inv_e, cfg.half)
    # absolute path: |â/b̂ − a/b| ≤ δ_a u/|b̂| + |a| δ_b u/(|b||b̂|), plus the
    # division's own rounding — all on the FP-inflated denominator range
    mig_b = iv.mig(b.exact)
    mfp_b = _mig_fp(b, cfg)
    ok = (mfp_b > 0) & (mig_b > 0)
    inv_fp = jnp.where(ok, 1.0 / jnp.where(ok, mfp_b, 1.0), _INF)
    inv_bb = jnp.where(ok, 1.0 / jnp.where(ok, mig_b * mfp_b, 1.0), _INF)
    dbar = _san(_ru(
        _eff_dbar(a) * inv_fp
        + iv.mag(a.exact) * _eff_dbar(b) * inv_bb
        + cfg.half * _san(iv.mag(exact) + (_eff_dbar(a) * inv_fp) * cfg.u_max)
    ))
    val = _emul(a.val / b.val, cfg)
    return _finish(cfg, CaaTensor(val, exact, dbar, ebar))


def sqrt(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.sqrt(a.exact)
    ea = _eff_ebar(a)
    x = ea * cfg.u_max
    # relative path: |sqrt(1+x)−1| ≤ |x| / (1 + sqrt(max(0,1−|x|)))
    amp = _san(jnp.where(x < 1, ea / (1 + jnp.sqrt(jnp.maximum(0.0, 1 - x))), _INF))
    ebar = _combine_rel(cfg, amp, cfg.half)
    # absolute path: sqrt is 1/(2√t)-Lipschitz on t ≥ mig_fp > 0 — survives
    # ε̄·u ≥ 1 as long as the absolute perturbation keeps the input positive
    mfp = _mig_fp(a, cfg)
    L = _san(jnp.where(mfp > 0, 0.5 / jnp.sqrt(jnp.where(mfp > 0, mfp, 1.0)), _INF))
    dbar = _san(_ru(_eff_dbar(a) * L + cfg.half * iv.mag(exact)))
    val = _emul(jnp.sqrt(a.val), cfg)
    return _finish(cfg, CaaTensor(val, exact, dbar, ebar))


def rsqrt(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    one = make(jnp.ones((), _F64))
    return div(one, sqrt(a, cfg), cfg)


def square(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    # x·x is perfectly correlated — the paper's id-equality decorrelation
    # case. Exact range via iv.square (tight), rel error 2ε + rounding.
    exact = iv.square(a.exact)
    ebar = _combine_rel(cfg, _eff_ebar(a), _eff_ebar(a), cfg.half)
    da = _eff_dbar(a)
    ma = iv.mag(a.exact)
    direct = 2 * ma * da + da * da * cfg.u_max + cfg.half * (ma + da * cfg.u_max) ** 2
    return _finish(cfg, CaaTensor(_emul(a.val * a.val, cfg), exact, _san(_ru(direct)), ebar))


def scale_const(a: CaaTensor, c, exact_const: bool = False,
                cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """Multiply by a scalar/array constant. exact_const=True → the constant is
    exactly representable in the target format (e.g. a power of two)."""
    exact = iv.scale(a.exact, c)
    extra = () if exact_const else (1.2 * cfg.half,)
    ebar = _combine_rel(cfg, _eff_ebar(a), cfg.half, *extra)
    c_abs = jnp.abs(jnp.asarray(c, _F64))
    da = _eff_dbar(a)
    dir_d = c_abs * da * (1 + cfg.u_max) + (cfg.half + (0 if exact_const else 1.2 * cfg.half)) * iv.mag(exact)
    return _finish(cfg, CaaTensor(_emul(a.val * jnp.asarray(c, _F64), cfg), exact,
                                  _san(_ru(dir_d)), ebar),
                   rounds=1 if exact_const else 2)


def shift_const(a: CaaTensor, c, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    return add(a, const_exact(c), cfg)


# ---------------------------------------------------------------------------
# elementwise nonlinearities
# ---------------------------------------------------------------------------

def exp(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """Paper rule: exp converts an *absolute* input bound into a *relative*
    output bound: e^{q+δu} = e^q·(1 + (e^{δu}−1))."""
    exact = iv.exp(a.exact)
    d = _eff_dbar(a)
    x = d * cfg.u_max
    conv = _san(jnp.where(jnp.isfinite(x), jnp.expm1(x) / cfg.u_max, _INF))
    ebar = _combine_rel(cfg, conv, cfg.libm)
    val = _emul(jnp.exp(a.val), cfg)
    return _finish(cfg, CaaTensor(val, exact, jnp.full_like(val, _INF), ebar))


def log(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """Paper rule: log converts relative into absolute. An abs-in path
    (1/mig_fp Lipschitz) covers ε̄·u ≥ 1 when the value stays off 0."""
    exact = iv.log(a.exact)
    e = _eff_ebar(a)
    x = e * cfg.u_max
    conv = _san(jnp.where(x < 1, e / (1 - x), _INF))
    mfp = _mig_fp(a, cfg)
    lips = _san(jnp.where(mfp > 0,
                          _eff_dbar(a) / jnp.where(mfp > 0, mfp, 1.0), _INF))
    dbar = _ru(jnp.minimum(_san(conv), lips) + cfg.libm * iv.mag(exact))
    val = _emul(jnp.log(a.val), cfg)
    return _finish(cfg, CaaTensor(val, exact, _san(dbar), jnp.full_like(val, _INF)))


TANH_REL_FACTOR = 2.63  # paper §III, valid while ε̄·u ≤ 1/4
TANH_REL_GATE = 0.25


def tanh(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.tanh(a.exact)
    # abs → abs with the local Lipschitz bound L = sup sech² = 1 − mig(tanh)²
    t_mig = iv.mig(exact)
    L = jnp.minimum(1.0, _ru(1.0 - t_mig * t_mig) + 2.0 ** -50)
    d = _eff_dbar(a)
    own_abs = cfg.libm * iv.mag(exact)
    dbar = _san(_ru(d * L + own_abs))
    # rel → rel with the paper's constant, gated exactly as in the paper
    e = _eff_ebar(a)
    prop = jnp.where(e * cfg.u_max <= TANH_REL_GATE, TANH_REL_FACTOR * e, _INF)
    ebar = _combine_rel(cfg, _san(prop), cfg.libm)
    val = _emul(jnp.tanh(a.val), cfg)
    return _finish(cfg, CaaTensor(val, exact, dbar, ebar))


def sigmoid(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.sigmoid(a.exact)
    # L = sup σ(1−σ) over the output range
    slo, shi = exact.lo, exact.hi
    f = lambda s: s * (1 - s)
    L = jnp.where((slo <= 0.5) & (shi >= 0.5), 0.25,
                  jnp.maximum(f(slo), f(shi)))
    d = _eff_dbar(a)
    dbar = _san(_ru(d * L + cfg.libm * iv.mag(exact)))
    # κ = sup |x·(1−σ(x))| over the input range
    xlo, xhi = a.exact.lo, a.exact.hi
    kpos = jnp.where(xhi > 0, 0.2785, 0.0)
    kneg = jnp.where(xlo < 0, _ru(jnp.abs(xlo) * (1 - jax.nn.sigmoid(xlo)) + 2e-16), 0.0)
    kappa = jnp.maximum(kpos, kneg)
    e = _eff_ebar(a)
    ebar = _combine_rel(cfg, _san(e * kappa), cfg.libm)
    val = _emul(jax.nn.sigmoid(a.val), cfg)
    return _finish(cfg, CaaTensor(val, exact, dbar, ebar))


def relu(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """Comparison+selection is exact in FP: no fresh rounding (paper §II:
    ReLU 'maintains an upper bound while clipping negative values')."""
    exact = iv.clamp_min(a.exact, 0.0)
    e = _eff_ebar(a)
    ebar = jnp.where(e * cfg.u_max < 1.0, e, _INF)
    return _normalize(CaaTensor(jnp.maximum(a.val, 0.0), exact, _eff_dbar(a), _san(ebar)))


def silu(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    return mul(a, sigmoid(a, cfg), cfg)


def gelu(a: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """tanh-approximated GELU, composed from CAA primitives."""
    c = math.sqrt(2.0 / math.pi)
    x3 = mul(square(a, cfg), a, cfg)
    inner = add(a, scale_const(x3, 0.044715, cfg=cfg), cfg)
    t = tanh(scale_const(inner, c, cfg=cfg), cfg)
    one_plus = shift_const(t, 1.0, cfg)
    return scale_const(mul(a, one_plus, cfg), 0.5, exact_const=True, cfg=cfg)


def maximum(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """max is 1-Lipschitz in each arg and selection is exact → bounds max."""
    exact = iv.maximum(a.exact, b.exact)
    dbar = jnp.maximum(_eff_dbar(a), _eff_dbar(b))
    ebar = jnp.maximum(_eff_ebar(a), _eff_ebar(b))
    return _normalize(CaaTensor(jnp.maximum(a.val, b.val), exact, dbar, ebar))


def minimum(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    return neg(maximum(neg(a), neg(b), cfg))


def where(mask, a: CaaTensor, b: CaaTensor) -> CaaTensor:
    """Selection by an *exact* (non-FP-derived) predicate — error-free."""
    mask = jnp.asarray(mask, bool)
    pick = lambda x, y: jnp.where(mask, x, y)
    return CaaTensor(
        pick(a.val, b.val),
        Interval(pick(a.exact.lo, b.exact.lo), pick(a.exact.hi, b.exact.hi)),
        pick(a.dbar, b.dbar),
        pick(a.ebar, b.ebar),
    )


# ---------------------------------------------------------------------------
# reductions & contractions — the computational-layer workhorse
# ---------------------------------------------------------------------------

def reduce_sum(a: CaaTensor, axis, keepdims: bool = False,
               cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    n = int(jnp.shape(a.val)[axis])
    exact = iv.sum_(a.exact, axis=axis, keepdims=keepdims)
    da = _eff_dbar(a)
    mag_fp = iv.mag(a.exact) + da * cfg.u_max
    g = cfg.gamma(max(n - 1, 1))
    dbar = _ru(
        jnp.sum(da, axis=axis, keepdims=keepdims)
        + g * jnp.sum(mag_fp, axis=axis, keepdims=keepdims)
    )
    val = _emul(jnp.sum(a.val, axis=axis, keepdims=keepdims), cfg)
    return _finish(cfg, CaaTensor(val, exact, _san(dbar), jnp.full_like(val, _INF)),
                   rounds=max(n - 1, 1))


def reduce_mean(a: CaaTensor, axis, keepdims: bool = False,
                cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    n = int(jnp.shape(a.val)[axis])
    s = reduce_sum(a, axis, keepdims, cfg)
    return scale_const(s, 1.0 / n, exact_const=(n & (n - 1) == 0), cfg=cfg)


def reduce_max(a: CaaTensor, axis, keepdims: bool = False,
               cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    exact = iv.max_(a.exact, axis=axis, keepdims=keepdims)
    dbar = jnp.max(_eff_dbar(a), axis=axis, keepdims=keepdims)
    ebar = jnp.max(_eff_ebar(a), axis=axis, keepdims=keepdims)
    val = jnp.max(a.val, axis=axis, keepdims=keepdims)
    # pure selection — no fresh rounding, no underflow charge
    return _normalize(CaaTensor(val, exact, dbar, ebar))


def contract(bilinear: Callable, n_contract: int, a: CaaTensor, b: CaaTensor,
             cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """General rigorous bilinear contraction (matmul/einsum/conv).

    ``bilinear(x, y)`` must be a bilinear map with non-negative structure
    (e.g. ``lambda x, y: x @ y`` or a conv): called on non-negative arrays it
    must produce the elementwise-|·| majorant of itself. ``n_contract`` is
    the reduction length feeding one output element.

    Error model (units of u), the closed form of folding the paper's scalar
    ⊗/⊕ rules across the reduction:

      δ_out ≤ B(|a|, δ_b) + B(δ_a, |b|) + u·B(δ_a, δ_b)      [operand errors]
              + γ(n)·B(|â|, |b̂|)                              [roundings]
    """
    val = _emul(bilinear(a.val, b.val), cfg)
    exact = _einsum_exact(bilinear, a.exact, b.exact)
    da, db = _eff_dbar(a), _eff_dbar(b)
    ma, mb = iv.mag(a.exact), iv.mag(b.exact)
    ma_fp = ma + da * cfg.u_max
    mb_fp = mb + db * cfg.u_max
    g = cfg.gamma(n_contract)
    dbar = _ru(
        bilinear(ma, db)
        + bilinear(da, mb)
        + cfg.u_max * bilinear(da, db)
        + g * bilinear(ma_fp, mb_fp)
    )
    # n products + n−1 partial sums ≤ 2n fresh roundings per output element
    return _finish(cfg, CaaTensor(val, exact, _san(dbar), jnp.full_like(val, _INF)),
                   rounds=2 * n_contract)


def _einsum_exact(bilinear: Callable, a: Interval, b: Interval) -> Interval:
    """Ball-arithmetic enclosure of a bilinear map on two intervals."""
    ma, ra = iv.ball(a)
    mb, rb = iv.ball(b)
    mid = bilinear(ma, mb)
    rad = (
        bilinear(jnp.abs(ma), rb)
        + bilinear(ra, jnp.abs(mb))
        + bilinear(ra, rb)
    )
    rad = _ru(rad) + 1e-14 * _ru(bilinear(jnp.abs(ma) + ra, jnp.abs(mb) + rb))
    rad = jnp.where(jnp.isnan(rad), _INF, rad)
    mid = jnp.where(jnp.isnan(mid), 0.0, mid)
    return iv.from_ball(mid, _ru(rad))


def _traj_rounding_bound(a: CaaTensor, b: CaaTensor, cfg: CaaConfig) -> jax.Array:
    """Fresh-rounding bound for fl(x·W) from actual partial-sum magnitudes.

    This is the closed γ form's tight sibling: folding the paper's scalar
    rule over the reduction charges ½u·|p̂_i| per product and ½u·|ŝ_t| per
    partial sum; we materialise those magnitudes (midpoint ± radius, with the
    radius inflated by the operands' own FP error) and sum them. Sound for
    both sequential and pairwise orders; benefits from sign cancellation in
    trained weights, unlike γ_n·Σ|x||w|.

    a: [..., n], b: [n, m]. Returns [..., m] in units of u.
    """
    ma, ra = iv.ball(a.exact)
    mb, rb = iv.ball(b.exact)
    ra = ra + _eff_dbar(a) * cfg.u_max          # FP-inflated radii
    rb = rb + _eff_dbar(b) * cfg.u_max
    # per-term product midpoint/radius: [..., n, m]
    p_mid = ma[..., :, None] * mb
    p_rad = (
        jnp.abs(ma)[..., :, None] * rb
        + ra[..., :, None] * jnp.abs(mb)
        + ra[..., :, None] * rb
    )
    prod_mag = jnp.abs(p_mid) + p_rad
    half = cfg.half
    t_prod = half * jnp.sum(prod_mag, axis=-2)
    if cfg.acc_order == "pairwise":
        t_sum = jnp.zeros_like(t_prod)
        mid, rad = p_mid, p_rad
        while mid.shape[-2] > 1:
            n_now = mid.shape[-2]
            if n_now % 2:  # odd: carry the last term
                carry_m, carry_r = mid[..., -1:, :], rad[..., -1:, :]
                mid, rad = mid[..., :-1, :], rad[..., :-1, :]
            else:
                carry_m = carry_r = None
            mid = mid[..., 0::2, :] + mid[..., 1::2, :]
            rad = rad[..., 0::2, :] + rad[..., 1::2, :]
            t_sum = t_sum + half * jnp.sum(jnp.abs(mid) + rad, axis=-2)
            if carry_m is not None:
                mid = jnp.concatenate([mid, carry_m], axis=-2)
                rad = jnp.concatenate([rad, carry_r], axis=-2)
    else:  # sequential (also a sound over-estimate for kahan)
        s_mid = jnp.cumsum(p_mid, axis=-2)
        s_rad = jnp.cumsum(p_rad, axis=-2)
        # partial sums s_2..s_n round (s_1 is just the first product)
        t_sum = half * jnp.sum(
            (jnp.abs(s_mid) + s_rad)[..., 1:, :], axis=-2
        )
    return _ru(t_prod + t_sum)


def _matmul_val(av, bv, cfg: CaaConfig):
    """Reference value of x@W under the configured emulation."""
    if cfg.emulate_k is None:
        return av @ bv
    if cfg.emulate_accum and jnp.ndim(bv) == 2:
        from . import quantize as qz
        from .formats import custom

        fmt = custom(cfg.emulate_k)
        if cfg.acc_order == "pairwise":
            return qz.pairwise_dot(av, bv, fmt)
        return qz.seq_dot(av, bv, fmt)
    return _emul(av @ bv, cfg)


def matmul(a: CaaTensor, b: CaaTensor, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    n = int(jnp.shape(a.val)[-1])
    bilinear = lambda x, y: x @ y
    out_elems = math.prod(jnp.shape(a.val)[:-1]) * jnp.shape(b.val)[-1]
    if (
        cfg.use_trajectory
        and jnp.ndim(b.val) == 2
        and out_elems * n <= cfg.traj_max_elems
        and cfg.acc_order in ("sequential", "pairwise")
    ):
        val = _matmul_val(a.val, b.val, cfg)
        exact = _einsum_exact(bilinear, a.exact, b.exact)
        da, db = _eff_dbar(a), _eff_dbar(b)
        ma, mb = iv.mag(a.exact), iv.mag(b.exact)
        fresh = _traj_rounding_bound(a, b, cfg)
        dbar = _ru(
            bilinear(ma, db) + bilinear(da, mb) + cfg.u_max * bilinear(da, db) + fresh
        )
        return _finish(cfg, CaaTensor(val, exact, _san(dbar),
                                      jnp.full_like(val, _INF)),
                       rounds=2 * n)
    return contract(bilinear, n, a, b, cfg)


def einsum(subscripts: str, a: CaaTensor, b: CaaTensor,
           cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    n = _contraction_length(subscripts, jnp.shape(a.val), jnp.shape(b.val))
    return contract(partial(jnp.einsum, subscripts), n, a, b, cfg)


def _contraction_length(subscripts: str, sa, sb) -> int:
    ins, out = subscripts.replace(" ", "").split("->")
    la, lb = ins.split(",")
    dims = {}
    for labels, shape in ((la, sa), (lb, sb)):
        core = labels.replace("...", "")
        trail = shape[len(shape) - len(core):]
        for ch, d in zip(core, trail):
            dims[ch] = d
    n = 1
    for ch, d in dims.items():
        if ch not in out:
            n *= int(d)
    return max(n, 1)


def dense(x: CaaTensor, w: CaaTensor, b: Optional[CaaTensor] = None,
          cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """y = x @ W (+ b): the paper's Dense layer rule. The bias add is one more
    term in the same accumulation (costs one γ step, folded in here)."""
    y = matmul(x, w, cfg)
    if b is not None:
        y = add(y, b, cfg)
    return y


# ---------------------------------------------------------------------------
# softmax — the paper's Section IV analysis, as a composite rule
# ---------------------------------------------------------------------------

def softmax(a: CaaTensor, axis: int = -1, cfg: CaaConfig = DEFAULT_CONFIG) -> CaaTensor:
    """Absolute-in → relative-out (paper eq. (10)–(11)).

    Rigorous form of the paper's bound: with input absolute errors ≤ δ̄u
    (after the max-shift subtraction rounding is folded in),
      |η_i| ≤ max_k |e^{(δ_k−δ_i)u} − 1| ≤ e^{2δ̄u} − 1 =: η̄
      |ε_i| ≤ η̄/(1−η̄) in relative terms, to which the layer's own roundings
      (exp, positive-sum, div) are appended. The paper's looser constant
      11/2·δ̄ (eq. (11)) is exposed in :mod:`repro.core.theory` and
      property-tested against this.

    The max-shift x − max(x) uses the ordering side-information exactly as
    the paper prescribes for FP-dependent control flow: the shifted exact
    range is ⊆ [lo − hi_max, 0].
    """
    n = int(jnp.shape(a.val)[axis])
    d_in = _eff_dbar(a)
    d_in_max = jnp.max(d_in, axis=axis, keepdims=True)

    # shifted range: the subtraction x - max(x) is bounded above by 0
    hi_max = jnp.max(a.exact.hi, axis=axis, keepdims=True)
    shifted = Interval(
        jnp.minimum(a.exact.lo - hi_max, 0.0), jnp.zeros_like(a.exact.hi)
    )
    # the shift itself: max is exact (selection), the subtract rounds once:
    # each shifted input picks up ≤ ½u·|x−m| absolute error; both operands'
    # prior absolute errors add (the shared m's error cancels in softmax
    # mathematically but we keep the sound per-element view: δ + δ_max).
    shift_round = cfg.half * iv.mag(shifted)
    d_tot = _ru(d_in + d_in_max + shift_round)        # δ̄_k, per element

    # Weighted η bound — the paper's eq. (10) with the softmax weights kept
    # (crucial under masking: −1e9 mask constants carry huge |x−m| hence
    # huge shift-rounding terms, but exactly vanishing weight):
    #   |η_i| ≤ Σ_k w_k (e^{(δ̄_k+δ̄_i)u}−1) = e^{δ̄_i u}·Σ_k w_k e^{δ̄_k u} − Σ_k w_k
    # with w_k = sup softmax_k over the exact ranges.
    exact = iv.softmax_range(a.exact, axis=axis)
    w_hi = exact.hi
    edu = jnp.exp(d_tot * cfg.u_max)                  # may overflow → inf
    term = _san(jnp.where(w_hi > 0, w_hi * edu, 0.0))  # 0·inf guard: w=0 ⇒ 0
    S1 = _ru(jnp.sum(term, axis=axis, keepdims=True))
    W = jnp.sum(w_hi, axis=axis, keepdims=True)
    eta = _san(jnp.maximum(edu * S1 - W, 0.0))        # per output element i
    prop = _san(jnp.where(eta < 1.0, (eta / (1.0 - eta)) / cfg.u_max, _INF))

    # layer's own roundings: exp (libm), positive sum (γ_{n-1}), div (½)
    own = _combine_rel(cfg, cfg.libm, cfg.gamma(max(n - 1, 1)), cfg.half)
    ebar = _combine_rel(cfg, prop, own)
    ebar = jnp.broadcast_to(ebar, jnp.shape(a.val))

    # absolute bound: |ŷ_i − y_i| ≤ w_hi_i · ε̄_i u in value terms, i.e.
    # w_hi·ε̄ in units of u; exactly-0 weights (masked positions underflow
    # to 0 in every format) have zero error.
    dbar = _san(jnp.where(w_hi > 0, w_hi * ebar, 0.0))
    val = _emul(jax.nn.softmax(a.val, axis=axis), cfg)
    # shift-sub + exp + (n−1)-sum + div: ≤ n+3 roundings feed one output
    return _finish(cfg, CaaTensor(val, exact, _ru(dbar), ebar), rounds=n + 3)


# ---------------------------------------------------------------------------
# recurrences (SSM layers) — beyond-paper extension, documented in DESIGN.md
# ---------------------------------------------------------------------------

def scan_affine_fixpoint(decay: CaaTensor, drive: CaaTensor, n_steps: int,
                         cfg: CaaConfig = DEFAULT_CONFIG,
                         decay_le_one: bool = True) -> CaaTensor:
    """Sound bound for h_T from h_{t+1} = decay ⊙ h_t + drive, h_0 = 0.

    With m = sup|decay| (FP-inflated) and per-step absolute error δ_step
    (one mul + one add at the current magnitude), the accumulated error is
    ≤ δ_step·Σ m^t = δ_step·min(T, (1−m^T)/(1−m)) — geometric for
    contraction (m<1), linear otherwise. Ranges get the same treatment.
    This is the closed form of the CAA fold over the scan; the paper has no
    recurrent layers so this rule is ours.
    """
    m = _ru(iv.mag(decay.exact) + _eff_dbar(decay) * cfg.u_max)
    if decay_le_one:
        # Decays of the form exp(−exp(·)) / exp(−dt·A) are ≤ 1 both ideally
        # and as FP values (RNE of exp(negative) never exceeds 1), so the
        # error-recurrence multiplier is soundly clamped — this keeps
        # 500k-step bounds finite (linear worst case instead of blow-up).
        m = jnp.minimum(m, 1.0)
    mag_b = _ru(iv.mag(drive.exact) + _eff_dbar(drive) * cfg.u_max)
    # Σ_{t<T} m^t, soundly (upper)
    T = float(n_steps)
    geo = jnp.where(
        m < 1.0,
        jnp.minimum(T, 1.0 / jnp.maximum(1.0 - m, 1e-300)),
        _san(jnp.where(m == 1.0, T, jnp.exp(jnp.log(jnp.maximum(m, 1.0)) * T) / jnp.maximum(m - 1.0, 1e-300))),
    )
    geo = _ru(geo)
    mag_h = _ru(mag_b * geo)
    # one-step error recurrence δ_{t+1} ≤ m·δ_t + c with
    # c = δ_drive + mag_h·δ_decay + (½+½)·mag_h   (mul + add roundings)
    # whose solution is δ_T ≤ c·Σ m^t = c·geo.
    c = _ru(_eff_dbar(drive) + mag_h * _eff_dbar(decay) + 2 * cfg.half * mag_h
            + 2 * jnp.asarray(cfg.round_abs, _F64))
    dbar = _san(_ru(c * geo))
    exact = Interval(-mag_h, mag_h)
    # reference value: the steady-state fixpoint of the val fields
    val = drive.val / jnp.maximum(1.0 - jnp.abs(decay.val), 1e-6)
    return _normalize(CaaTensor(val, exact, dbar, jnp.full_like(val, _INF)))


# ---------------------------------------------------------------------------
# shape ops — error-free data movement
# ---------------------------------------------------------------------------

def _shape_op(fn: Callable, a: CaaTensor) -> CaaTensor:
    return CaaTensor(
        fn(a.val),
        Interval(fn(a.exact.lo), fn(a.exact.hi)),
        fn(jnp.broadcast_to(a.dbar, a.shape)),
        fn(jnp.broadcast_to(a.ebar, a.shape)),
    )


def reshape(a: CaaTensor, shape) -> CaaTensor:
    return _shape_op(lambda x: jnp.reshape(x, shape), a)


def transpose(a: CaaTensor, axes) -> CaaTensor:
    return _shape_op(lambda x: jnp.transpose(x, axes), a)


def broadcast_to(a: CaaTensor, shape) -> CaaTensor:
    return _shape_op(lambda x: jnp.broadcast_to(x, shape), a)


def concatenate(parts: Sequence[CaaTensor], axis: int) -> CaaTensor:
    cat = lambda get: jnp.concatenate([get(p) for p in parts], axis=axis)
    return CaaTensor(
        cat(lambda p: p.val),
        Interval(cat(lambda p: p.exact.lo), cat(lambda p: p.exact.hi)),
        cat(lambda p: jnp.broadcast_to(p.dbar, p.shape)),
        cat(lambda p: jnp.broadcast_to(p.ebar, p.shape)),
    )


def take(a: CaaTensor, idx, axis: int) -> CaaTensor:
    return _shape_op(lambda x: jnp.take(x, idx, axis=axis), a)


def slice_(a: CaaTensor, slices) -> CaaTensor:
    return _shape_op(lambda x: x[slices], a)


def worst(a: CaaTensor) -> tuple[float, float]:
    """(max δ̄, max ε̄) over the tensor — the Table-I-style summary."""
    return float(jnp.max(a.dbar)), float(jnp.max(a.ebar))


def clamp_exact(c: CaaTensor, lo, hi) -> CaaTensor:
    """Intersect the ideal-value enclosure with an externally-proven bound.

    This is the paper's 'provide the arithmetic with just enough global
    insight on the program's logic': algebraic facts IA cannot see locally —
    |rmsnorm(x)| ≤ √n·|γ| whatever x, attention outputs are convex
    combinations of values, softmax sums to 1 — are injected as sound range
    intersections. Error bounds are untouched (they remain sound); the
    normalisation step then tightens them from the sharper range."""
    lo = jnp.asarray(lo, _F64)
    hi = jnp.asarray(hi, _F64)
    new_lo = jnp.maximum(c.exact.lo, lo)
    new_hi = jnp.minimum(c.exact.hi, hi)
    # guard: never produce an empty interval (possible only if the caller's
    # bound was wrong — keep the original then)
    bad = new_lo > new_hi
    new_lo = jnp.where(bad, c.exact.lo, new_lo)
    new_hi = jnp.where(bad, c.exact.hi, new_hi)
    return _normalize(CaaTensor(c.val, Interval(new_lo, new_hi), c.dbar, c.ebar))


def actual_error_in_u(c: CaaTensor, u: float) -> tuple[jax.Array, jax.Array]:
    """Rigorous enclosure of the *actual* error of the emulated run.

    With ``cfg.emulate_k`` set, ``c.val`` is the value the target format
    would compute; ``c.exact`` rigorously encloses the ideal value; hence
    sup_{q ∈ exact} |val − q| = max(|val−lo|, |val−hi|) rigorously bounds
    the concrete run's error. This is the paper's 'interval holding the
    actual error of the latter FP value' — the quantity Table I tabulates
    (tight, per-run), as opposed to the parametric δ̄/ε̄ (format-generic).
    Returns (absolute, relative), both in units of u.
    """
    dist = jnp.maximum(jnp.abs(c.val - c.exact.lo), jnp.abs(c.val - c.exact.hi))
    abs_u = _ru(dist) / u
    g = iv.mig(c.exact)
    rel_u = _san(jnp.where(g > 0, abs_u / jnp.where(g > 0, g, 1.0), _INF))
    return abs_u, rel_u
