"""Scope-path matching & per-scope value resolution (string and stacked).

Every backend tracks the model's scope path ("layer3/attn", ...); certified
per-scope maps — ``{scope: k}``, ``{scope: FpFormat}``, ``{scope:
round_scale}`` — are resolved against that path both by the analysis
backends (scope-gated CAA knobs) and by the serving backends (per-scope
quantisation). This module is the single home of that resolution so the
analysis and serving sides can never drift apart.

Two kinds of keys resolve:

  * **string keys** — ``"block1"``/``"block1/inner"``: matched as a
    contiguous run of '/'-separated path segments (``"block1"`` never
    matches inside ``"block10"``), most specific (longest) key wins;
  * **stacked keys** — the wildcard segment :data:`STACK_SCOPE`
    (``"layer*"``), which matches any concrete ``layer<i>`` path segment.
    When its mapped value is an ``[L]``-shaped array/sequence, resolution
    *indexes it by the matched layer number*: ``{"layer*": ks}`` resolves
    ``layer3/attn`` to ``ks[3]``. This is the map form the scan-native
    analysis (:class:`repro.core.backend.StackedCaaOps`) and the scanned
    serving backends exchange: one ``[L]`` lane vector instead of L string
    entries.

A concrete key (``"layer3"``) always beats the wildcard at equal depth.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Sequence

# The wildcard segment a scan-stacked layer_loop pushes: one traced body
# analyses all L layers, so the scope path cannot name a concrete layer.
STACK_SCOPE = "layer*"

_LAYER_RE = re.compile(r"^layer(\d+)$")


def _segment_matches(want: str, have: str) -> bool:
    """Does key segment ``want`` match path segment ``have``?"""
    if want == have:
        return True
    return want == STACK_SCOPE and _LAYER_RE.match(have) is not None


def scope_active(active: str, scope: Sequence[str]) -> bool:
    """True iff ``active``'s '/'-separated segments appear as a contiguous
    run of the current scope path's segments. Substring matching is wrong
    here: layer 'block1' must not activate inside 'block10'. The
    :data:`STACK_SCOPE` wildcard segment matches any ``layer<i>``."""
    parts = [seg for s in scope for seg in s.split("/")]
    want = active.split("/")
    return any(
        all(_segment_matches(w, parts[i + j]) for j, w in enumerate(want))
        for i in range(len(parts) - len(want) + 1)
    )


def _layer_index_of(active: str, scope: Sequence[str]):
    """Layer number bound by ``active``'s wildcard segment against ``scope``
    (None when the key has no wildcard or binds no concrete layer)."""
    parts = [seg for s in scope for seg in s.split("/")]
    want = active.split("/")
    for i in range(len(parts) - len(want) + 1):
        if all(_segment_matches(w, parts[i + j]) for j, w in enumerate(want)):
            for j, w in enumerate(want):
                if w == STACK_SCOPE:
                    m = _LAYER_RE.match(parts[i + j])
                    if m:
                        return int(m.group(1))
            return None
    return None


def _maybe_index(value, idx):
    """Index an [L]-shaped mapped value by the bound layer number; scalars
    and values bound by a non-wildcard key pass through unchanged."""
    if idx is None:
        return value
    if isinstance(value, (list, tuple)):
        return value[idx]
    if hasattr(value, "ndim") and getattr(value, "ndim", 0) >= 1:
        return value[idx]
    return value


def resolve_scope_value(path: Sequence[str], mapping: Dict[str, Any],
                        default):
    """Value of the most specific map key matching ``path``.

    Specificity is (segment count, number of exact segments): a concrete
    ``"layer3"`` beats the ``"layer*"`` wildcard at equal depth; ties keep
    the later key (dict order), matching the historical behaviour.
    ``default`` covers ops outside every mapped scope. A wildcard key whose
    value is an ``[L]`` array/sequence is indexed by the matched layer
    number (``layer3/attn`` through ``{"layer*": ks}`` → ``ks[3]``).
    Shared by the mixed/format analyses (scope → round_scale/round_abs) and
    the serving backends (scope → quantisation k / format triple).
    """
    best, best_spec = default, (0, -1)
    for key, v in mapping.items():
        segs = key.split("/")
        spec = (len(segs), sum(s != STACK_SCOPE for s in segs))
        if spec >= best_spec and path and scope_active(key, path):
            best = _maybe_index(v, _layer_index_of(key, path))
            best_spec = spec
    return best


def scope_prefixes(paths: Sequence[str], depth: int = 1) -> List[str]:
    """Unique ``depth``-segment prefixes of scope paths, first-seen order."""
    out: List[str] = []
    seen = set()
    for path in paths:
        prefix = "/".join(path.split("/")[:depth])
        if prefix not in seen:
            seen.add(prefix)
            out.append(prefix)
    return out


def expand_stacked(scopes: Sequence[str], n_layers: int) -> List[str]:
    """Replace the :data:`STACK_SCOPE` wildcard with concrete per-layer
    names: ``["embed", "layer*", "head"]`` → ``["embed", "layer0", ...,
    "layer{L-1}", "head"]`` — the key set a stacked analysis certifies at
    (certificates store concrete names; the wildcard is an analysis-side
    encoding)."""
    out: List[str] = []
    for s in scopes:
        if s == STACK_SCOPE or s.startswith(STACK_SCOPE + "/"):
            suffix = s[len(STACK_SCOPE):]
            for i in range(n_layers):
                name = f"layer{i}{suffix}"
                if name not in out:
                    out.append(name)
        elif s not in out:
            out.append(s)
    return out
