"""Emulation of low-precision FP formats on f32/f64 carriers.

This is the *empirical oracle* for the rigorous CAA analysis: we can actually
run a network with every intermediate rounded to a k-bit mantissa (RNE) and
check the measured error against the CAA bound (tests/test_soundness.py), and
run low-precision inference end-to-end to confirm the paper's headline claim
that the predicted precision preserves the top-1 class.

Rounding is performed by bit-twiddling the carrier format (round-to-nearest,
ties-to-even on the retained mantissa), followed by exponent-range handling
(overflow → ±inf or saturate; gradual underflow by re-quantising in a scaled
frame). The same routine, jitted, is what the quantised inference path uses —
and the Pallas ``quant_matmul`` kernel fuses it into the GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .formats import FpFormat, get as get_format


def _round_mantissa_bits(bits, total_mant: int, k: int, uint_t, one):
    """RNE-truncate `bits` (carrier uint) to k mantissa bits (incl. implicit)."""
    s = total_mant - (k - 1)  # bits to drop from the *stored* mantissa
    if s <= 0:
        return bits
    half = one << (s - 1)
    lsb = (bits >> s) & one
    rounded = (bits + (half - one) + lsb) & ~((one << s) - one)
    return rounded.astype(uint_t)


def _quantize_normal(x: jax.Array, k: int) -> jax.Array:
    """Round mantissa of x to k bits (RNE), full carrier exponent range.

    Works for f32 (k<=24) and f64 (k<=53) carriers. NaN/Inf pass through.
    Carry into the exponent on mantissa overflow is handled naturally by the
    integer addition (e.g. 1.111..1 rounds up to 10.0 → exponent += 1).
    """
    dt = x.dtype
    if dt == jnp.float32:
        uint_t, total_mant = jnp.uint32, 23
    elif dt == jnp.float64:
        uint_t, total_mant = jnp.uint64, 52
    else:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    if k - 1 >= total_mant + 1:
        return x
    one = jnp.asarray(1, uint_t)
    bits = jax.lax.bitcast_convert_type(x, uint_t)
    rounded = _round_mantissa_bits(bits, total_mant, k, uint_t, one)
    out = jax.lax.bitcast_convert_type(rounded, dt)
    # NaN payloads can carry into Inf under the integer trick; restore NaN.
    out = jnp.where(jnp.isnan(x), x, out)
    out = jnp.where(jnp.isinf(x), x, out)
    return out


@functools.partial(jax.jit, static_argnames=("fmt_name",))
def _quantize_impl(x: jax.Array, fmt_name: str) -> jax.Array:
    fmt = get_format(fmt_name)
    k = fmt.k
    y = _quantize_normal(x, k)

    # Exponent-range handling in the carrier.
    max_fin = jnp.asarray(fmt.max_finite, y.dtype)
    min_norm = jnp.asarray(fmt.min_normal, y.dtype)

    # Overflow. Gate on the ORIGINAL value's finiteness: mantissa rounding
    # can overflow the carrier itself (y = ±inf for finite x near carrier
    # max) and a saturating format must still clamp that; for non-saturating
    # formats sign(±inf)·inf reproduces the ±inf unchanged.
    over = jnp.abs(y) > max_fin
    inf_like = jnp.where(
        jnp.asarray(fmt.saturating),
        jnp.sign(y) * max_fin,
        jnp.sign(y) * jnp.asarray(jnp.inf, y.dtype),
    )
    y = jnp.where(over & jnp.isfinite(x), inf_like, y)

    # Underflow: values with magnitude below the smallest normal.
    tiny = (jnp.abs(y) < min_norm) & (y != 0)
    if fmt.has_subnormals:
        # Quantise on the fixed-point grid of spacing 2^{emin-(k-1)} —
        # from the *original* value (single rounding, no double-round)
        step = jnp.asarray(fmt.min_subnormal, y.dtype)
        snapped = jnp.round(x / step) * step  # RNE via jnp.round (banker's)
        y = jnp.where(tiny, snapped, y)
    else:
        # Flush-to-zero below the subnormal midpoint threshold.
        y = jnp.where(tiny & (jnp.abs(y) < min_norm / 2), jnp.zeros_like(y), y)
        y = jnp.where(tiny & (jnp.abs(y) >= min_norm / 2), jnp.sign(y) * min_norm, y)
    return y


def quantize_to_k(x: jax.Array, k) -> jax.Array:
    """Mantissa-only RNE rounding to k bits where ``k`` may be a *traced*
    scalar (jnp int), not just a Python int.

    Bitwise-identical to :func:`_quantize_normal` at the same static k — the
    property tests assert it — but with the dropped-bit count computed in
    integer arithmetic instead of Python control flow, so ONE jit compilation
    serves every k. This is the scalar-k-as-argument path the mixed-precision
    serving backend and the jitted certificate probe ladder rely on: per-layer
    k can come out of a scanned array without recompiling per precision.
    """
    x = jnp.asarray(x)
    dt = x.dtype
    if dt == jnp.float32:
        uint_t, total_mant = jnp.uint32, 23
    elif dt == jnp.float64:
        uint_t, total_mant = jnp.uint64, 52
    else:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    k = jnp.asarray(k, jnp.int32)
    s = total_mant - (k - 1)               # bits to drop; <= 0 → identity
    eff = jnp.clip(s, 1, total_mant).astype(uint_t)
    one = jnp.asarray(1, uint_t)
    bits = jax.lax.bitcast_convert_type(x, uint_t)
    half = (one << (eff - one)) - one      # 2^{s-1} - 1
    lsb = (bits >> eff) & one
    rounded = (bits + half + lsb) & ~((one << eff) - one)
    out = jax.lax.bitcast_convert_type(rounded.astype(uint_t), dt)
    out = jnp.where(s <= 0, x, out)
    out = jnp.where(jnp.isnan(x) | jnp.isinf(x), x, out)
    return out


def pow2(e, dt) -> jax.Array:
    """Exact 2^e for integer (possibly traced) ``e``, carrier subnormals
    included — by exponent-bit construction, NOT exp2 (XLA lowers exp2
    through exp(x·ln2), which is off by many ulps: unusable where bitwise
    agreement with the static :func:`quantize` path is the contract)."""
    e = jnp.asarray(e, jnp.int32)
    if dt == jnp.float32:
        uint_t, bias, mant, min_e = jnp.uint32, 127, 23, -149
    elif dt == jnp.float64:
        uint_t, bias, mant, min_e = jnp.uint64, 1023, 52, -1074
    else:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    normal = e >= 1 - bias
    bits_n = jnp.clip(e + bias, 0, 2 * bias).astype(uint_t) << mant
    bits_s = (jnp.asarray(1, uint_t)
              << jnp.clip(e - min_e, 0, mant).astype(uint_t))
    return jax.lax.bitcast_convert_type(jnp.where(normal, bits_n, bits_s), dt)


def quantize_to_format(x: jax.Array, k, emax, emin,
                       has_subnormals: bool = True,
                       saturating: bool = True,
                       max_finite=None) -> jax.Array:
    """Full custom-format rounding where ``k``/``emax``/``emin`` may be
    *traced* scalars — ONE jit compilation serves every certified format.

    Semantics are bitwise-identical to :func:`quantize` at the same static
    format (the property tests assert it): RNE mantissa rounding
    (:func:`quantize_to_k`), overflow beyond ``max_finite`` saturates to
    ±max_finite (or ±inf with ``saturating=False``), magnitudes below
    ``2^emin`` are re-quantised on the subnormal grid of spacing
    ``2^{emin-(k-1)}`` from the *original* value (single rounding), or
    flushed to 0 / ±min_normal without subnormals. NaN/Inf pass through.

    This is the serving-side contract of a schema-v3 format certificate:
    the scalar-prefetch Pallas kernel (:mod:`repro.kernels.quant_matmul`)
    computes exactly this function on its tiles. ``max_finite`` overrides
    the (2−2^{1-k})·2^emax formula for encoding-clipped formats (e4m3).

    Caveat: the identity is stated for carrier-NORMAL inputs (plus 0/±inf/
    NaN). When the emulated format's subnormal grid dips below the
    carrier's own normal range (only possible for emin ≈ the carrier's,
    e.g. bfloat16 emulated on f32), carrier-subnormal inputs hit XLA's
    flush-to-zero inconsistencies in both paths and they may disagree —
    synthesized formats (narrow emin by construction) never get there.
    """
    x = jnp.asarray(x)
    dt = x.dtype
    if dt not in (jnp.float32, jnp.float64):
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    y = quantize_to_k(x, k)
    k = jnp.asarray(k, jnp.int32)
    emax = jnp.asarray(emax, jnp.int32)
    emin = jnp.asarray(emin, jnp.int32)
    if max_finite is None:
        max_fin = (2.0 - pow2(1 - k, dt)) * pow2(emax, dt)
    else:
        max_fin = jnp.asarray(max_finite, dt)
    min_norm = pow2(emin, dt)

    # gate on x, not y: mantissa rounding may overflow the CARRIER (finite x
    # near carrier max → y = ±inf), and saturation must still clamp that
    over = (jnp.abs(y) > max_fin) & jnp.isfinite(x)
    if saturating:
        inf_like = jnp.sign(y) * max_fin
    else:
        inf_like = jnp.sign(y) * jnp.asarray(jnp.inf, dt)
    y = jnp.where(over, inf_like, y)

    tiny = (jnp.abs(y) < min_norm) & (y != 0)
    if has_subnormals:
        step = pow2(emin - (k - 1), dt)
        snapped = jnp.round(x / step) * step   # RNE via jnp.round (banker's)
        y = jnp.where(tiny, snapped, y)
    else:
        y = jnp.where(tiny & (jnp.abs(y) < min_norm / 2), jnp.zeros_like(y), y)
        y = jnp.where(tiny & (jnp.abs(y) >= min_norm / 2),
                      jnp.sign(y) * min_norm, y)
    return jnp.where(jnp.isnan(x) | jnp.isinf(x), x, y)


def numeric_health(x: jax.Array, k, emax, emin) -> dict:
    """Cheap per-tensor numeric-health stats against a (k, emax, emin) format
    whose fields may be *traced* scalars — jit-safe, O(n) elementwise.

    Returns a dict of 0-d arrays:
      max_abs:     largest finite magnitude observed
      min_nonzero: smallest nonzero magnitude observed (+inf if all zero)
      n_over:      elements beyond the format's max_finite (overflow /
                   saturation events under a saturating format)
      n_under:     nonzero elements below the format's min_normal = 2^emin
                   (landing on the subnormal grid / flush region)
      n_nonfinite: NaN/Inf elements (upstream pathology, format-independent)

    This is the runtime observation half of a certificate-violation monitor:
    the certified IA enclosure says where magnitudes *must* lie; these stats
    say where they *did*. The caller compares (on the host, via
    ``jax.debug.callback``) so the jitted serving values stay untouched.
    """
    x = jnp.asarray(x)
    dt = x.dtype
    if dt not in (jnp.float32, jnp.float64):
        x = x.astype(jnp.float32)
        dt = jnp.float32
    k = jnp.asarray(k, jnp.int32)
    max_fin = (2.0 - pow2(1 - k, dt)) * pow2(jnp.asarray(emax, jnp.int32), dt)
    min_norm = pow2(jnp.asarray(emin, jnp.int32), dt)
    a = jnp.abs(x)
    finite = jnp.isfinite(x)
    nonzero = finite & (a > 0)
    inf_dt = jnp.asarray(jnp.inf, dt)
    return {
        "max_abs": jnp.max(jnp.where(finite, a, 0.0)),
        "min_nonzero": jnp.min(jnp.where(nonzero, a, inf_dt)),
        "n_over": jnp.sum((a > max_fin) & finite),
        "n_under": jnp.sum(nonzero & (a < min_norm)),
        "n_nonfinite": jnp.sum(~finite),
    }


def quantize(x: jax.Array, fmt: FpFormat | str | int) -> jax.Array:
    """Round every element of ``x`` to the given format (value kept in carrier).

    ``quantize(x, 'bfloat16')`` on an f32 array returns the f32 array whose
    values are exactly representable in bfloat16 — i.e. an emulated bf16
    storage. ``quantize(x, 8)`` emulates a custom k=8 format.
    """
    fmt = get_format(fmt)
    x = jnp.asarray(x)
    if x.dtype not in (jnp.float32, jnp.float64):
        x = x.astype(jnp.float32)
    return _quantize_impl(x, fmt.name)


def quantized_op(op, fmt: FpFormat | str | int):
    """Wrap a binary/unary op so its *result* is rounded into ``fmt``.

    This is the emulation of 'every FP operation rounds once' from the first
    standard model (paper eq. (5)) at precision k: operands are assumed
    already representable; the op computes in the (much wider) carrier and
    rounds once.
    """
    fmt = get_format(fmt)

    def wrapped(*args):
        return quantize(op(*args), fmt)

    return wrapped


def seq_dot(x: jax.Array, w: jax.Array, fmt: FpFormat | str | int) -> jax.Array:
    """Sequential-order matmul ``x[..., n] @ w[n, m]`` with one rounding per
    FLOP, in ``fmt``.

    The reference semantics of frugally-deep's scalar loop, which the paper
    analyses: acc = fl(acc + fl(x_i * w_i)). Used by the soundness tests as
    the ground-truth low-precision execution for the ``sequential``
    accumulation order.
    """
    fmt = get_format(fmt)
    xq = quantize(x, fmt)
    wq = quantize(w, fmt)

    def body(acc, xw):
        xi, wi = xw  # xi: [...], wi: [m]
        prod = quantize(xi[..., None] * wi, fmt)
        return quantize(acc + prod, fmt), None

    acc0 = jnp.zeros(x.shape[:-1] + (w.shape[-1],), x.dtype)
    acc, _ = jax.lax.scan(body, acc0, (jnp.moveaxis(xq, -1, 0), wq))
    return acc


def pairwise_dot(x: jax.Array, w: jax.Array, fmt: FpFormat | str | int) -> jax.Array:
    """Pairwise(tree)-order matmul ``x[..., n] @ w[n, m]`` with one rounding
    per op, in ``fmt``.

    Models the XLA/TPU reduction tree; error constant γ_{⌈log2 n⌉+1} instead
    of γ_n.
    """
    fmt = get_format(fmt)
    prods = quantize(
        quantize(x, fmt)[..., :, None] * quantize(w, fmt), fmt
    )  # [..., n, m]
    vals = jnp.moveaxis(prods, -2, 0)
    n = vals.shape[0]
    while vals.shape[0] > 1:
        m = vals.shape[0]
        if m % 2:
            carry, vals = vals[-1:], vals[:-1]
        else:
            carry = None
        vals = quantize(vals[0::2] + vals[1::2], fmt)
        if carry is not None:
            vals = jnp.concatenate([vals, carry], axis=0)
    return vals[0]


def kahan_dot(x: jax.Array, w: jax.Array, fmt: FpFormat | str | int) -> jax.Array:
    """Kahan-compensated matmul ``x[..., n] @ w[n, m]`` with one rounding per
    op, in ``fmt`` — the oracle for the 'kahan' accumulation order (the
    paper's future-work codegen hook)."""
    fmt = get_format(fmt)
    xq = quantize(x, fmt)
    wq = quantize(w, fmt)

    def body(carry, xw):
        acc, comp = carry
        xi, wi = xw
        prod = quantize(xi[..., None] * wi, fmt)
        y = quantize(prod - comp, fmt)
        t = quantize(acc + y, fmt)
        comp = quantize(quantize(t - acc, fmt) - y, fmt)
        return (t, comp), None

    z = jnp.zeros(x.shape[:-1] + (w.shape[-1],), x.dtype)
    (acc, _), _ = jax.lax.scan(body, (z, z),
                               (jnp.moveaxis(xq, -1, 0), wq))
    return acc


def measured_error_in_u(exact: jax.Array, approx: jax.Array, fmt) -> tuple[jax.Array, jax.Array]:
    """(absolute, relative) error of ``approx`` vs ``exact``, in units of u."""
    fmt = get_format(fmt)
    u = fmt.u
    abs_err = jnp.abs(approx.astype(jnp.float64) - exact.astype(jnp.float64)) / u
    denom = jnp.abs(exact.astype(jnp.float64))
    rel_err = jnp.where(denom > 0, abs_err / denom, jnp.where(abs_err > 0, jnp.inf, 0.0))
    return abs_err, rel_err
