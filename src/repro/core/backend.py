"""Arithmetic back-ends: one model definition, two executions.

The paper binds its CAA arithmetic into frugally-deep by C++ operator
overloading, so the *same network code* runs either in plain IEEE754 or in
the enhanced analysis arithmetic. We reproduce that design JAX-natively:
every model in :mod:`repro.models` is written against the ``Backend``
interface below, and

  * :class:`JOps` executes it as ordinary jnp (jit/pjit-able, any dtype
    policy — this is the training/serving path), while
  * :class:`CaaOps` executes it on :class:`repro.core.caa.CaaTensor`s,
    producing rigorous absolute/relative error bounds in units of u
    (this is the analysis path), recording a per-layer trace.

``CaaOps`` additionally implements the paper's control-flow handling for
data-dependent routing (MoE top-k): the route is fixed by the reference
values (the paper's "run for one representative per class"), and the margin
between chosen and rejected logits is recorded so routing-flip safety can be
checked against the final error bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from . import caa
from . import interval as iv
from .caa import CaaConfig, CaaTensor, DEFAULT_CONFIG
from .scopes import STACK_SCOPE, resolve_scope_value


@dataclasses.dataclass
class TraceRecord:
    name: str
    kind: str
    shape: tuple
    out_mag: float      # sup |exact range|
    max_dbar: float     # units of u
    max_ebar: float     # units of u
    extra: dict = dataclasses.field(default_factory=dict)


class Backend:
    """Interface models are written against. Methods mirror caa.py rules."""

    is_analysis: bool = False

    # -- scoping ------------------------------------------------------------
    # Every backend tracks the model's scope path (layer_loop pushes
    # "layer{i}", models push named blocks). CaaOps uses it for trace names
    # and sensitivity gating; serving backends use it to apply per-scope
    # precision formats (mixed-precision certificates). The default
    # additionally records every distinct path entered (``seen_scopes`` —
    # the raw material scope discovery turns into a layer→k granularity);
    # subclasses react to pushes/pops via the `_scope_changed` hook.

    @property
    def scope_path(self) -> List[str]:
        sp = getattr(self, "_scope", None)
        if sp is None:
            sp = self._scope = []
        return sp

    @property
    def seen_scopes(self) -> List[str]:
        """Every distinct scope path entered, in first-seen order."""
        ss = getattr(self, "_seen_scopes", None)
        if ss is None:
            ss = self._seen_scopes = []
            self._seen_set = set()
        return ss

    def scope(self, name: str):
        ops = self

        class _Scope:
            def __enter__(self):
                ops.scope_path.append(name)
                ops._scope_changed()

            def __exit__(self, *exc):
                ops.scope_path.pop()
                ops._scope_changed()

        return _Scope()

    def _scope_changed(self):
        """Hook fired after every scope push/pop (see scope_path).

        The base implementation maintains ``seen_scopes``. Membership is
        tested against a companion set — `path not in list` is O(n) per
        push, O(n²) across a deep model's scopes, which is exactly the
        scaling a 56-layer × per-sublayer scope walk would hit."""
        if self.scope_path:
            path = "/".join(self._scope)
            seen = self.seen_scopes          # materialises the set too
            if path not in self._seen_set:
                self._seen_set.add(path)
                seen.append(path)

    # construction
    def param(self, w, exact: bool = False): raise NotImplementedError
    def input(self, x): raise NotImplementedError
    def const(self, c): raise NotImplementedError

    # arithmetic
    def add(self, a, b): raise NotImplementedError
    def sub(self, a, b): raise NotImplementedError
    def mul(self, a, b): raise NotImplementedError
    def div(self, a, b): raise NotImplementedError
    def neg(self, a): raise NotImplementedError
    def scale(self, a, c, exact_const: bool = False): raise NotImplementedError
    def shift(self, a, c): raise NotImplementedError
    def matmul(self, a, b): raise NotImplementedError
    def einsum(self, subscripts, a, b): raise NotImplementedError

    # nonlinearities
    def tanh(self, a): raise NotImplementedError
    def sigmoid(self, a): raise NotImplementedError
    def exp(self, a): raise NotImplementedError
    def log(self, a): raise NotImplementedError
    def sqrt(self, a): raise NotImplementedError
    def rsqrt(self, a): raise NotImplementedError
    def square(self, a): raise NotImplementedError
    def relu(self, a): raise NotImplementedError
    def silu(self, a): raise NotImplementedError
    def gelu(self, a): raise NotImplementedError
    def softmax(self, a, axis: int = -1): raise NotImplementedError
    def softcap(self, a, cap: float):
        """tanh soft-capping (gemma2): cap * tanh(x / cap)."""
        return self.scale(self.tanh(self.scale(a, 1.0 / cap)), cap)

    # reductions
    def sum(self, a, axis, keepdims: bool = False): raise NotImplementedError
    def mean(self, a, axis, keepdims: bool = False): raise NotImplementedError
    def max(self, a, axis, keepdims: bool = False): raise NotImplementedError

    # selection / comparison
    def maximum(self, a, b): raise NotImplementedError
    def where(self, mask, a, b): raise NotImplementedError
    def top_k_mask(self, scores, k: int, name: str = "router"):
        raise NotImplementedError

    # data movement
    def reshape(self, a, shape): raise NotImplementedError
    def transpose(self, a, axes): raise NotImplementedError
    def broadcast_to(self, a, shape): raise NotImplementedError
    def concat(self, parts, axis): raise NotImplementedError
    def take(self, a, idx, axis): raise NotImplementedError
    def slice(self, a, slices): raise NotImplementedError
    def shape_of(self, a) -> tuple: raise NotImplementedError
    def value_of(self, a) -> jax.Array: raise NotImplementedError

    # structure
    def layer_loop(self, fn: Callable, stacked_params, x, n_layers: int,
                   aux=None):
        """Apply ``fn(layer_params, x, layer_index, aux_i) -> (x, aux_out_i)``
        across layers. Returns (x, stacked_aux_out).

        JOps uses lax.scan over stacked parameters (O(1) HLO in depth —
        essential for 512-device compiles of 56-layer models); CaaOps
        unrolls in Python so per-layer trace records survive. ``aux`` is an
        optional per-layer pytree (e.g. the layer's KV cache slice)."""
        raise NotImplementedError

    def ssm_scan(self, decay, drive, n_steps: int, time_axis: int = 1):
        """h_{t+1} = decay_t ⊙ h_t + drive_t over ``time_axis``."""
        raise NotImplementedError

    def record(self, name: str, a, kind: str = "layer"):
        """Trace hook; identity for JOps."""
        return a

    def clamp_range(self, a, lo, hi):
        """Inject an externally-proven range bound (identity under JOps;
        sound enclosure intersection under CaaOps) — the paper's global-
        insight mechanism for fighting decorrelation."""
        return a

    def shard_hint(self, a, kind: str):
        """Optional sharding annotation (identity by default). Training
        backends use it for sequence-parallel attention (kind='q_seq');
        serving threads kind='act_batch' through the scanned layer body."""
        return a

    def decode_attention(self, q, k, v, lengths):
        """Fused single-token decode attention hook: q [B,K,G,D] against
        the full cache k/v [B,Smax,K,D] with per-lane valid ``lengths``
        [B]. Return the [B,K,G,D] context, or None to use the composed
        einsum/softmax path (the default). Certified serving backends
        override this with the certificate-aware flash decode kernel."""
        return None


# ---------------------------------------------------------------------------
# plain-jnp execution
# ---------------------------------------------------------------------------

class JOps(Backend):
    """Straight jnp with a dtype policy — the performance path.

    ``compute_dtype`` is what activations/GEMMs run in (bf16 on TPU);
    ``param_dtype`` what parameters are stored in; accumulation is left to
    XLA (f32 on MXU via preferred_element_type).
    """

    is_analysis = False

    def __init__(self, compute_dtype=jnp.float32, accum_dtype=jnp.float32,
                 mesh=None):
        self.compute_dtype = compute_dtype
        self.accum_dtype = accum_dtype
        self.mesh = mesh  # enables shard_map paths (expert parallelism)

    def param(self, w, exact: bool = False):
        return jnp.asarray(w).astype(self.compute_dtype)

    def input(self, x):
        return jnp.asarray(x).astype(self.compute_dtype)

    def const(self, c):
        return jnp.asarray(c, self.compute_dtype)

    def add(self, a, b): return a + b
    def sub(self, a, b): return a - b
    def mul(self, a, b): return a * b
    def div(self, a, b): return a / b
    def neg(self, a): return -a

    def scale(self, a, c, exact_const: bool = False):
        return a * jnp.asarray(c, a.dtype)

    def shift(self, a, c): return a + jnp.asarray(c, a.dtype)

    def matmul(self, a, b):
        return jnp.matmul(a, b, preferred_element_type=self.accum_dtype).astype(
            self.compute_dtype
        )

    def einsum(self, subscripts, a, b):
        return jnp.einsum(
            subscripts, a, b, preferred_element_type=self.accum_dtype
        ).astype(self.compute_dtype)

    def tanh(self, a): return jnp.tanh(a)
    def sigmoid(self, a): return jax.nn.sigmoid(a)
    def exp(self, a): return jnp.exp(a)
    def log(self, a): return jnp.log(a)
    def sqrt(self, a): return jnp.sqrt(a)
    def rsqrt(self, a): return jax.lax.rsqrt(a)
    def square(self, a): return a * a
    def relu(self, a): return jax.nn.relu(a)
    def silu(self, a): return jax.nn.silu(a)
    def gelu(self, a): return jax.nn.gelu(a, approximate=True)

    def softmax(self, a, axis: int = -1):
        return jax.nn.softmax(a.astype(self.accum_dtype), axis=axis).astype(
            self.compute_dtype
        )

    def sum(self, a, axis, keepdims=False): return jnp.sum(a, axis=axis, keepdims=keepdims)
    def mean(self, a, axis, keepdims=False): return jnp.mean(a, axis=axis, keepdims=keepdims)
    def max(self, a, axis, keepdims=False): return jnp.max(a, axis=axis, keepdims=keepdims)

    def maximum(self, a, b): return jnp.maximum(a, b)
    def where(self, mask, a, b): return jnp.where(mask, a, b)

    def top_k_mask(self, scores, k: int, name: str = "router"):
        _, idx = jax.lax.top_k(scores, k)
        return jax.nn.one_hot(idx, scores.shape[-1], dtype=scores.dtype).sum(-2)

    def reshape(self, a, shape): return jnp.reshape(a, shape)
    def transpose(self, a, axes): return jnp.transpose(a, axes)
    def broadcast_to(self, a, shape): return jnp.broadcast_to(a, shape)
    def concat(self, parts, axis): return jnp.concatenate(list(parts), axis=axis)
    def take(self, a, idx, axis): return jnp.take(a, idx, axis=axis)
    def slice(self, a, slices): return a[slices]
    def shape_of(self, a): return tuple(a.shape)
    def value_of(self, a): return a

    def shard_hint(self, a, kind: str):
        """Activation sharding constraints on the mesh (identity without
        one). kind='act_batch' pins the residual stream to batch-over-
        "data", REPLICATED over "model" — threaded through the scanned
        serving body so XLA all-gathers column-parallel matmul outputs
        (exact values) instead of propagating a contraction split (which
        would reassociate the accumulation and break the serving path's
        bit-for-bit contract)."""
        mesh = self.mesh
        if mesh is None or kind != "act_batch":
            return a
        from jax.sharding import NamedSharding, PartitionSpec as P
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if all(s <= 1 for s in sizes.values()):
            return a
        dp = tuple(ax for ax in ("pod", "data")
                   if sizes.get(ax, 1) > 1)
        rem = a.shape[0]
        for ax in dp:
            if rem % sizes[ax]:
                return a
            rem //= sizes[ax]
        spec = P(dp if dp else None, *([None] * (a.ndim - 1)))
        return jax.lax.with_sharding_constraint(a, NamedSharding(mesh, spec))

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        def body(carry, xs):
            p, i, a = xs
            new_x, aux_out = fn(p, carry, i, a)
            new_x = self.shard_hint(new_x, "act_batch")
            return new_x, aux_out

        idx = jnp.arange(n_layers)
        out, aux_outs = jax.lax.scan(body, x, (stacked_params, idx, aux))
        return out, aux_outs

    def ssm_scan(self, decay, drive, n_steps: int, time_axis: int = 1):
        dec = jnp.moveaxis(decay, time_axis, 0)
        drv = jnp.moveaxis(drive, time_axis, 0)

        def body(h, xs):
            d, b = xs
            h = d * h + b
            return h, h

        h0 = jnp.zeros_like(drv[0])
        _, hs = jax.lax.scan(body, h0, (dec, drv))
        return jnp.moveaxis(hs, 0, time_axis)


# ---------------------------------------------------------------------------
# CAA analysis execution
# ---------------------------------------------------------------------------

class UnrolledLayerLoop:
    """Mixin: the eager per-layer ``layer_loop`` — a Python unroll pushing
    a static ``layer{i}`` scope per layer, so every per-scope knob
    resolves eagerly by name. This single implementation is both the
    analysis-side unroll (CaaOps and its string-scope subclasses) and the
    serving-side differential baseline (compose in front of a scanned
    backend: ``class Ref(UnrolledLayerLoop, MixedQuantJOps)``) — the two
    must never diverge, since certificates are confirmed on the former and
    bit-for-bit checked against the latter."""

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        aux_outs = []
        for i in range(n_layers):
            layer_params = jax.tree_util.tree_map(lambda p: p[i], stacked_params)
            aux_i = (
                None if aux is None
                else jax.tree_util.tree_map(lambda a: a[i], aux)
            )
            with self.scope(f"layer{i}"):
                x, aux_out = fn(layer_params, x, i, aux_i)
            aux_outs.append(aux_out)
        if all(a is None for a in aux_outs):
            stacked = None
        else:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *aux_outs
            )
        return x, stacked


class CaaOps(UnrolledLayerLoop, Backend):
    """Executes the model on CaaTensors, recording a per-layer trace.

    weights_exact: treat parameters as exactly representable in the target
      format (paper's default: the stored weights *are* the reference) —
      set False to additionally charge the f32→target re-quantisation
      (ε̄ = 1/2 per weight).
    """

    is_analysis = True

    def __init__(self, cfg: CaaConfig = DEFAULT_CONFIG, weights_exact: bool = True):
        self.cfg = cfg
        self.weights_exact = weights_exact
        self.trace: List[TraceRecord] = []
        self._scope: List[str] = []
        # seen_scopes bookkeeping (first-seen order + dedup set) lives on
        # Backend._scope_changed, shared with the serving backends.

    # -- scoping / tracing --
    def _name(self, leaf: str) -> str:
        return "/".join(self._scope + [leaf]) if self._scope else leaf

    @staticmethod
    def _f(x) -> float:
        """Concretise for the trace; NaN placeholder under tracing (scan)."""
        try:
            return float(x)
        except (jax.errors.TracerArrayConversionError, jax.errors.ConcretizationTypeError):
            return float("nan")

    def record(self, name: str, a: CaaTensor, kind: str = "layer", **extra):
        self.trace.append(
            TraceRecord(
                name=self._name(name),
                kind=kind,
                shape=tuple(a.shape),
                out_mag=self._f(jnp.max(iv.mag(a.exact))),
                max_dbar=self._f(jnp.max(a.dbar)),
                max_ebar=self._f(jnp.max(a.ebar)),
                extra=extra,
            )
        )
        return a

    # -- construction --
    def param(self, w, exact: Optional[bool] = None):
        exact = self.weights_exact if exact is None else exact
        return caa.weight(w, self.cfg, exact=exact)

    def input(self, x):
        if isinstance(x, CaaTensor):
            return x
        return caa.make(x)

    def const(self, c):
        return caa.const_exact(c)

    # -- arithmetic --
    def add(self, a, b): return caa.add(a, b, self.cfg)
    def sub(self, a, b): return caa.sub(a, b, self.cfg)
    def mul(self, a, b): return caa.mul(a, b, self.cfg)
    def div(self, a, b): return caa.div(a, b, self.cfg)
    def neg(self, a): return caa.neg(a)

    def scale(self, a, c, exact_const: bool = False):
        return caa.scale_const(a, c, exact_const=exact_const, cfg=self.cfg)

    def shift(self, a, c): return caa.shift_const(a, c, self.cfg)
    def matmul(self, a, b): return caa.matmul(a, b, self.cfg)
    def einsum(self, subscripts, a, b): return caa.einsum(subscripts, a, b, self.cfg)

    def tanh(self, a): return caa.tanh(a, self.cfg)
    def sigmoid(self, a): return caa.sigmoid(a, self.cfg)
    def exp(self, a): return caa.exp(a, self.cfg)
    def log(self, a): return caa.log(a, self.cfg)
    def sqrt(self, a): return caa.sqrt(a, self.cfg)
    def rsqrt(self, a): return caa.rsqrt(a, self.cfg)
    def square(self, a): return caa.square(a, self.cfg)
    def relu(self, a): return caa.relu(a, self.cfg)
    def silu(self, a): return caa.silu(a, self.cfg)
    def gelu(self, a): return caa.gelu(a, self.cfg)
    def softmax(self, a, axis: int = -1): return caa.softmax(a, axis, self.cfg)

    def sum(self, a, axis, keepdims=False): return caa.reduce_sum(a, axis, keepdims, self.cfg)
    def mean(self, a, axis, keepdims=False): return caa.reduce_mean(a, axis, keepdims, self.cfg)
    def max(self, a, axis, keepdims=False): return caa.reduce_max(a, axis, keepdims, self.cfg)

    def maximum(self, a, b): return caa.maximum(a, b, self.cfg)
    def where(self, mask, a, b): return caa.where(mask, a, b)

    def top_k_mask(self, scores: CaaTensor, k: int, name: str = "router"):
        """Fix the route from reference values; record the decision margin.

        The route is safe against rounding iff the gap between the k-th
        chosen and the best rejected logit exceeds twice the logit error
        (in value terms) — recorded for the report (the paper's argmax
        analysis, applied to routing)."""
        vals, idx = jax.lax.top_k(scores.val, k)
        mask = jax.nn.one_hot(idx, scores.shape[-1], dtype=scores.val.dtype).sum(-2)
        rejected = jnp.where(mask > 0, -jnp.inf, scores.val)
        margin = jnp.min(vals, -1) - jnp.max(rejected, -1)
        # per-run certified error (finite even when the parametric bound
        # saturates): sup distance from the emulated value to the ideal range
        dist = jnp.maximum(jnp.abs(scores.val - scores.exact.lo),
                           jnp.abs(scores.val - scores.exact.hi))
        err_val = jnp.minimum(
            jnp.max(caa._eff_dbar(scores)) * self.cfg.u_max, jnp.max(dist))
        # _f: concretise for the trace, NaN placeholder under tracing — MoE
        # routing inside a scan-native layer stack traces this path
        self.trace.append(
            TraceRecord(
                name=self._name(name),
                kind="router",
                shape=tuple(scores.shape),
                out_mag=self._f(jnp.max(iv.mag(scores.exact))),
                max_dbar=self._f(jnp.max(scores.dbar)),
                max_ebar=self._f(jnp.max(scores.ebar)),
                extra={
                    "min_margin": self._f(jnp.min(margin)),
                    "flip_safe_if_u_le": self._f(
                        jnp.min(margin) / (2 * err_val + 1e-300)),
                },
            )
        )
        return mask

    def reshape(self, a, shape): return caa.reshape(a, shape)
    def transpose(self, a, axes): return caa.transpose(a, axes)
    def broadcast_to(self, a, shape): return caa.broadcast_to(a, shape)
    def concat(self, parts, axis): return caa.concatenate(list(parts), axis)
    def take(self, a, idx, axis): return caa.take(a, idx, axis)
    def slice(self, a, slices): return caa.slice_(a, slices)
    def shape_of(self, a): return tuple(a.shape)
    def value_of(self, a): return a.val

    def clamp_range(self, a, lo, hi):
        return caa.clamp_exact(a, lo, hi)

    # layer_loop: the eager per-layer unroll from UnrolledLayerLoop —
    # per-layer trace records and string-scope knob gating survive.

    def ssm_scan(self, decay: CaaTensor, drive: CaaTensor, n_steps: int,
                 time_axis: int = 1):
        """Closed-form fixpoint bound (caa.scan_affine_fixpoint) broadcast
        back over time — sound for every step since bounds are monotone in t."""
        dec_w = caa.reduce_max(caa.CaaTensor(
            jnp.abs(decay.val), iv.abs_(decay.exact), decay.dbar, decay.ebar
        ), axis=time_axis, keepdims=True)
        drv_w = caa.CaaTensor(
            drive.val,
            iv.Interval(
                jnp.min(drive.exact.lo, axis=time_axis, keepdims=True),
                jnp.max(drive.exact.hi, axis=time_axis, keepdims=True),
            ),
            jnp.max(jnp.broadcast_to(drive.dbar, drive.shape), axis=time_axis, keepdims=True),
            jnp.max(jnp.broadcast_to(drive.ebar, drive.shape), axis=time_axis, keepdims=True),
        )
        fix = caa.scan_affine_fixpoint(
            caa.CaaTensor(dec_w.val, dec_w.exact, dec_w.dbar, dec_w.ebar),
            caa.CaaTensor(jnp.mean(drive.val, axis=time_axis, keepdims=True),
                          drv_w.exact, drv_w.dbar, drv_w.ebar),
            n_steps, self.cfg,
        )
        # reference values still come from the true scan for val fidelity
        jb = JOps(jnp.float64, jnp.float64)
        vals = jb.ssm_scan(decay.val, drive.val, n_steps, time_axis)
        return caa.CaaTensor(
            vals,
            iv.Interval(jnp.broadcast_to(fix.exact.lo, vals.shape),
                        jnp.broadcast_to(fix.exact.hi, vals.shape)),
            jnp.broadcast_to(fix.dbar, vals.shape),
            jnp.broadcast_to(fix.ebar, vals.shape),
        )


# ---------------------------------------------------------------------------
# per-scope IA magnitude enclosures — the range analysis behind custom
# (k, emin, emax) format certification (repro.certify.formats)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RangeStat:
    """Magnitude enclosure of every FP value a scope produces.

    ``max_abs`` is a rigorous upper bound on |v̂| over every intermediate
    (IA range inflated by the value's own FP error at u_max) — the quantity
    the smallest overflow-free ``emax`` is certified from. ``min_nonzero``
    is the smallest positive element-wise mignitude seen (+inf if none):
    when it clears the format's ``min_normal``, no *provably-nonzero* value
    can go subnormal. ``crosses_zero`` records whether some enclosure
    touches 0 — those values may underflow, which is exactly what the
    λ·2^{emin-(k-1)} absolute term (CaaConfig.round_abs) charges for.
    """

    max_abs: float = 0.0
    min_nonzero: float = math.inf
    crosses_zero: bool = False
    n_ops: int = 0

    def merge(self, other: "RangeStat") -> "RangeStat":
        return RangeStat(
            max_abs=max(self.max_abs, other.max_abs),
            min_nonzero=min(self.min_nonzero, other.min_nonzero),
            crosses_zero=self.crosses_zero or other.crosses_zero,
            n_ops=self.n_ops + other.n_ops,
        )

    def to_dict(self) -> dict:
        return {"max_abs": self.max_abs, "min_nonzero": self.min_nonzero,
                "crosses_zero": self.crosses_zero, "n_ops": self.n_ops}


class RangeCaaOps(CaaOps):
    """CaaOps that additionally accumulates per-scope magnitude enclosures.

    Every op result (and every param/input/const — weights must be
    representable in a scope's format too) updates ``scope_ranges`` at the
    current scope path. The accumulated bounds are concretised floats, so
    this backend is eager-only (under jit the observations would be
    tracers); the format pipeline runs it exactly where PR 1/2 already run
    eager confirmation passes. Observation is side-effect-only — the
    returned tensors are bit-identical to the parent class's, and method
    dispatch goes through ``super()`` so the mixin composes with subclasses
    that redefine scope behaviour (e.g. FormatCaaOps).
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.scope_ranges: Dict[str, RangeStat] = {}

    def _observe(self, out, is_op: bool = True):
        if not isinstance(out, CaaTensor):
            return out
        rng = out.fp_range(self.cfg.u_max)
        lo = jnp.broadcast_to(rng.lo, out.shape)
        hi = jnp.broadcast_to(rng.hi, out.shape)
        import numpy as np
        lo = np.asarray(lo, np.float64).ravel()
        hi = np.asarray(hi, np.float64).ravel()
        mag = np.maximum(np.abs(lo), np.abs(hi))
        mig = np.maximum(np.maximum(lo, -hi), 0.0)
        pos = mig[mig > 0]
        stat = RangeStat(
            max_abs=float(mag.max(initial=0.0)),
            min_nonzero=float(pos.min()) if pos.size else math.inf,
            crosses_zero=bool((mig <= 0).any()),
            n_ops=1 if is_op else 0,
        )
        key = "/".join(self._scope) if self._scope else ""
        prev = self.scope_ranges.get(key)
        self.scope_ranges[key] = stat if prev is None else prev.merge(stat)
        return out


_RANGE_TRACKED_OPS = (
    "param", "input", "const", "add", "sub", "mul", "div", "neg", "scale",
    "shift", "matmul", "einsum", "tanh", "sigmoid", "exp", "log", "sqrt",
    "rsqrt", "square", "relu", "silu", "gelu", "softmax", "sum", "mean",
    "max", "maximum", "where", "concat", "clamp_range", "ssm_scan",
)


def _make_range_wrapper(cls, name: str):
    def method(self, *args, **kwargs):
        out = getattr(super(cls, self), name)(*args, **kwargs)
        # operands cross scope boundaries: a matmul in scope s quantises
        # values produced elsewhere INTO s's format, so every consumed
        # tensor belongs to s's enclosure too (n_ops counts outputs only)
        for a in args:
            if isinstance(a, CaaTensor):
                self._observe(a, is_op=False)
        self._observe(out)
        return out
    method.__name__ = name
    method.__qualname__ = f"{cls.__name__}.{name}"
    return method


def _install_range_wrappers(cls):
    """Wrap every value-producing op of ``cls`` with the `_observe` hook
    (dispatch goes through super(cls), so observation composes with any
    scope/knob behaviour of the base class)."""
    for name in _RANGE_TRACKED_OPS:
        setattr(cls, name, _make_range_wrapper(cls, name))
    return cls


_install_range_wrappers(RangeCaaOps)


# ---------------------------------------------------------------------------
# scan-native (layer-stacked) analysis — one traced body for all L layers
# ---------------------------------------------------------------------------

def _canon_caa(c: CaaTensor) -> CaaTensor:
    """Broadcast every field to val's shape: a lax.scan carry must keep one
    fixed aval across iterations, but CAA rules freely return scalar-
    broadcast dbar/ebar."""
    shape = jnp.shape(c.val)
    b = lambda t: jnp.broadcast_to(jnp.asarray(t, jnp.float64), shape)
    return CaaTensor(c.val, iv.Interval(b(c.exact.lo), b(c.exact.hi)),
                     b(c.dbar), b(c.ebar))


class StackedCaaOps(CaaOps):
    """Scan-native CAA: ``layer_loop`` runs as ONE ``lax.scan`` over the
    stacked parameters — O(1) HLO in depth, the analysis twin of the JOps
    serving path — instead of CaaOps' per-layer Python unroll.

    Scope-dependent knobs become **traced per-layer lanes**: at loop entry
    each layer's ``round_scale``/``round_abs`` is resolved by name against
    ``scope_scales``/``scope_abs`` (static strings, possibly traced values
    — e.g. a probe ladder's scale vector), stacked into ``[L]`` vectors,
    and gathered by the scan carry's layer index inside the one traced
    body. Outside the stack the knobs resolve statically from the scope
    path, exactly like :class:`repro.certify.formats.FormatCaaOps`. With
    empty maps and unit defaults this is the uniform analysis (bounds agree
    with the eager unroll to fp tolerance; the eager path remains the
    reference the pipelines re-confirm against).

    Costs of the scan form: per-layer TraceRecords collapse into one
    ``layer*/...`` record with NaN concretisations, and ``seen_scopes``
    reports the :data:`repro.core.scopes.STACK_SCOPE` wildcard instead of
    concrete layer names (expand with :func:`repro.core.scopes.
    expand_stacked`). Per-layer (δ̄, ε̄) of the carry after every layer is
    emitted as the ``layer_stats`` ``[L]`` arrays instead.
    """

    def __init__(self, cfg: CaaConfig = DEFAULT_CONFIG,
                 scope_scales: Optional[Dict[str, Any]] = None,
                 scope_abs: Optional[Dict[str, Any]] = None,
                 default_scale=1.0, default_abs=None,
                 weights_exact: bool = True):
        self._scales = dict(scope_scales or {})
        self._abs = dict(scope_abs or {})
        self._default_scale = default_scale
        self._default_abs = cfg.round_abs if default_abs is None else default_abs
        self._base_cfg = cfg
        self._in_stack = False
        self._layer_index = None
        self._stack_ctx = None      # (outer_path, n_layers) while scanning
        self._lane_cache: Dict[tuple, tuple] = {}
        self.layer_stats: Optional[Dict[str, jax.Array]] = None
        super().__init__(cfg, weights_exact=weights_exact)
        self._apply_static()

    # -- knob resolution ----------------------------------------------------
    def _apply_static(self):
        s = resolve_scope_value(self._scope, self._scales,
                                self._default_scale)
        ra = resolve_scope_value(self._scope, self._abs, self._default_abs)
        self.cfg = dataclasses.replace(
            self._base_cfg,
            round_scale=self._base_cfg.round_scale * s,
            round_abs=ra)

    def _scope_changed(self):
        super()._scope_changed()
        if not self._in_stack:
            self._apply_static()
        elif self._stack_ctx is not None:
            # inside the one traced body the knobs follow the sub-layer
            # suffix (layer*/attn, layer*/mlp, ...): each distinct suffix
            # gets its own [L] lane, resolved by name exactly like the
            # per-layer lane and gathered at the traced layer index. With
            # no sub-layer keys in the maps every suffix lane equals the
            # per-layer lane, so behaviour is unchanged.
            self._apply_stack_lane()

    def _stack_suffix(self) -> tuple:
        """Scope segments below the stack wildcard (static strings)."""
        outer, _ = self._stack_ctx
        return tuple(self._scope[len(outer) + 1:])

    def _stack_lanes(self, suffix: tuple):
        """[L] knob lanes for one sub-layer suffix, cached per suffix (the
        cache lives on the ops instance, which jit retracing recreates)."""
        cached = self._lane_cache.get(suffix)
        if cached is None:
            outer, n_layers = self._stack_ctx

            def vec(mapping, default):
                vals = [resolve_scope_value(
                    outer + [f"layer{i}", *suffix], mapping, default)
                    for i in range(n_layers)]
                if any(isinstance(v, jax.core.Tracer) for v in vals):
                    return jnp.stack(
                        [jnp.asarray(v, jnp.float64) for v in vals])
                import numpy as np
                return jnp.asarray(np.asarray(vals, np.float64))

            cached = (vec(self._scales, self._default_scale),
                      vec(self._abs, self._default_abs))
            self._lane_cache[suffix] = cached
        return cached

    def _apply_stack_lane(self):
        scale_vec, abs_vec = self._stack_lanes(self._stack_suffix())
        i = self._layer_index
        base = self._base_cfg
        self.cfg = dataclasses.replace(
            base,
            round_scale=base.round_scale * scale_vec[i],
            round_abs=abs_vec[i])

    # -- scan-state hooks (range subclass threads accumulators) -------------
    def _stack_state_init(self, n_layers: int):
        return None

    def _set_stack_state(self, state):
        pass

    def _get_stack_state(self):
        return None

    def _finish_stack_state(self, state):
        pass

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        if self._in_stack:
            # nested stacks are out of scope for the scan form — fall back
            # to the eager unroll for the inner loop
            return super().layer_loop(fn, stacked_params, x, n_layers, aux)
        outer = list(self._scope)
        self._stack_ctx = (outer, n_layers)
        self._lane_cache = {}

        def body(carry, xs):
            p, i, a = xs
            cx, state = carry
            self._in_stack = True
            self._layer_index = i
            self._set_stack_state(state)
            # per-layer knob lane (suffix ()), resolved by name exactly like
            # the scanned serving backends build their i32 k/format arrays;
            # sub-layer scope pushes inside fn re-pin to their suffix lane
            # via _scope_changed → _apply_stack_lane
            self._apply_stack_lane()
            new_x, aux_out = fn(p, cx, i, a)
            new_x = _canon_caa(new_x)
            stats = (jnp.max(new_x.dbar), jnp.max(new_x.ebar))
            return (new_x, self._get_stack_state()), (aux_out, stats)

        idx = jnp.arange(n_layers)
        with self.scope(STACK_SCOPE):
            (out, state), (aux_outs, stats) = jax.lax.scan(
                body, (_canon_caa(x), self._stack_state_init(n_layers)),
                (stacked_params, idx, aux))
            self._in_stack = False
            self._layer_index = None
            self._stack_ctx = None
            self._finish_stack_state(state)
        self.layer_stats = {"abs_u": stats[0], "rel_u": stats[1]}
        return out, aux_outs


class StackedRangeCaaOps(StackedCaaOps):
    """Scan-native range analysis: per-scope IA magnitude enclosures as
    ``[L, 4]`` lanes — (max_abs, min_nonzero, crosses_zero, n_ops) —
    accumulated via ``.at[i]`` updates on the scan carry, one lane per
    layer plus one scalar lane for every op outside the stack. Unlike
    :class:`RangeCaaOps` the observations are traced jnp (they live inside
    the one compiled scan body); :meth:`collect_ranges` concretises them to
    the same ``{scope_key: RangeStat}`` shape the eager path produces."""

    _ACC_INIT = (0.0, math.inf, 0.0, 0.0)

    def __init__(self, *args, sublanes: Sequence[str] = (), **kwargs):
        # sublanes: sub-layer scope names (e.g. ("attn", "mlp")) that get
        # their own accumulator lane inside the stack; everything else in a
        # layer lands on lane 0 (the layer-direct lane). With the default
        # () the lanes collapse to the original per-layer shape.
        self._sublanes = tuple(sublanes)
        self._sub_map = {s: j + 1 for j, s in enumerate(self._sublanes)}
        self._outer_accs = None
        self._lane_acc = None
        self._done_lanes: List = []
        super().__init__(*args, **kwargs)
        # outside the stack the scope path is a concrete Python string, so
        # per-path accumulators keep the eager path's key fidelity there
        self._outer_accs: Dict[str, jax.Array] = {}

    def _sub_idx(self) -> int:
        """Static accumulator-lane index of the current sub-layer scope."""
        if self._stack_ctx is None or not self._sub_map:
            return 0
        suffix = self._stack_suffix()
        if suffix:
            return self._sub_map.get(suffix[0], 0)
        return 0

    @staticmethod
    def _merge_acc(acc, stat):
        return jnp.stack([
            jnp.maximum(acc[..., 0], stat[0]),
            jnp.minimum(acc[..., 1], stat[1]),
            jnp.maximum(acc[..., 2], stat[2]),
            acc[..., 3] + stat[3],
        ], axis=-1)

    def _observe(self, out, is_op: bool = True):
        if not isinstance(out, CaaTensor) or self._outer_accs is None:
            return out
        rng = out.fp_range(self.cfg.u_max)
        lo = jnp.broadcast_to(rng.lo, out.shape).ravel()
        hi = jnp.broadcast_to(rng.hi, out.shape).ravel()
        mag = jnp.max(jnp.maximum(jnp.abs(lo), jnp.abs(hi)))
        mig = jnp.maximum(jnp.maximum(lo, -hi), 0.0)
        min_nz = jnp.min(jnp.where(mig > 0, mig, jnp.inf))
        crossed = jnp.any(mig <= 0).astype(jnp.float64)
        stat = (mag, min_nz, crossed,
                jnp.asarray(1.0 if is_op else 0.0, jnp.float64))
        if self._in_stack and self._lane_acc is not None:
            i = self._layer_index
            j = self._sub_idx()
            self._lane_acc = self._lane_acc.at[i, j].set(
                self._merge_acc(self._lane_acc[i, j], stat))
        else:
            key = "/".join(self._scope) if self._scope else ""
            prev = self._outer_accs.get(
                key, jnp.asarray(self._ACC_INIT, jnp.float64))
            self._outer_accs[key] = self._merge_acc(prev, stat)
        return out

    # scan-state plumbing: the [L, S, 4] lanes ride the carry (S = 1 layer-
    # direct lane + one lane per tracked sub-layer scope)
    def _stack_state_init(self, n_layers: int):
        return jnp.broadcast_to(
            jnp.asarray(self._ACC_INIT, jnp.float64),
            (n_layers, 1 + len(self._sublanes), 4))

    def _set_stack_state(self, state):
        self._lane_acc = state

    def _get_stack_state(self):
        return self._lane_acc

    def _finish_stack_state(self, state):
        self._done_lanes.append(state)
        self._lane_acc = None

    def collect_ranges(self) -> Dict[str, RangeStat]:
        """Concretise the lanes: {"layer{i}": RangeStat} per stack lane,
        outside-the-stack paths keyed by their concrete scope string (plus
        ``""`` for unscoped ops) — the same key shape the eager
        :class:`RangeCaaOps` + aggregate_ranges path produces. Stacks from
        repeated layer_loops (e.g. encoder + decoder) merge by layer
        name, matching the eager string-scope aggregation."""
        import numpy as np

        def stat(row) -> RangeStat:
            return RangeStat(
                max_abs=float(row[0]), min_nonzero=float(row[1]),
                crosses_zero=bool(row[2] > 0), n_ops=int(row[3]))

        out: Dict[str, RangeStat] = {}
        for lanes in self._done_lanes:
            arr = np.asarray(lanes, np.float64)
            for i in range(arr.shape[0]):
                for j in range(arr.shape[1]):
                    key = (f"layer{i}" if j == 0
                           else f"layer{i}/{self._sublanes[j - 1]}")
                    s = stat(arr[i, j])
                    if (j > 0 and s.n_ops == 0 and s.max_abs == 0.0
                            and s.min_nonzero == math.inf):
                        continue  # sub-lane never entered

                    out[key] = s if key not in out else out[key].merge(s)
        for key, acc in self._outer_accs.items():
            # the stack wildcard path holds ops observed between scope entry
            # and the scan (none today) — fold it into the default
            key = "" if key.startswith(STACK_SCOPE) else key
            s = stat(np.asarray(acc, np.float64))
            out[key] = s if key not in out else out[key].merge(s)
        out.setdefault("", RangeStat())
        return out


_install_range_wrappers(StackedRangeCaaOps)


# ---------------------------------------------------------------------------
# affine-arithmetic range analysis — finite enclosures where IA saturates
# ---------------------------------------------------------------------------
#
# The IA range pass bounds |v̂| through the CAA error terms: at coarse
# emulated precision the parametric accumulation bounds (CaaConfig.gamma)
# saturate to ∞ and every enclosure downstream is ∞ — which is exactly why
# certify_lm's mixed-mantissa format attempt dies on attention archs. The
# affine pass sidesteps the error terms entirely: it FORWARD-PROPAGATES an
# enclosure of the rounded values themselves, through TWO channels per
# tensor (:class:`AffTensor`):
#
#   * an affine form (interval.AffineForm) — center + noise-symbol terms —
#     that survives elementwise linear ops exactly, so correlated paths
#     (residual adds, gating products) cancel instead of compounding;
#   * a plain interval, advanced by direct outward-rounded interval rules
#     with an operational rounding inflation (1+u/2)^n — this channel keeps
#     the sign/structure facts a symmetric form cannot represent (x² ≥ 0,
#     softmax ∈ [0,1], clamp bounds), so norm denominators never swallow 0.
#
# The enclosure of a tensor is the channels' intersection; both are sound
# for the same rounded-value set. Every rounding charge is the operational
# growth model (1+u/2)^n − 1 plus n·η — finite at EVERY precision, never a
# γ-style closed form whose denominator crosses zero at coarse u (that
# saturation is the bug this pass exists to fix). The pass proves nothing
# about (δ̄, ε̄); it exists solely to tighten RangeStat range evidence, and
# is sound to min-combine with the IA pass.

class AffTensor:
    """Two-channel rounded-value enclosure for the affine range pass.

    Exposes the CaaTensor surface the models (and caa's shape ops) touch
    under ``is_analysis``: ``val`` is the f64 reference value (the form's
    center), ``exact`` the channel intersection — an enclosure of the
    ROUNDED values; unlike CaaTensor, whose ``exact`` holds ideal values
    and whose FP deviation lives in (dbar, ebar), here the deviation is
    inside the enclosure and the error channels read zero."""

    __slots__ = ("form", "ivl")

    def __init__(self, form: iv.AffineForm, ivl: Optional[iv.Interval] = None):
        self.form = form
        self.ivl = iv.aff_interval(form) if ivl is None else ivl

    @property
    def val(self) -> jax.Array:
        return self.form.center

    @property
    def exact(self) -> iv.Interval:
        a = iv.aff_interval(self.form)
        shape = self.form.shape
        lo = jnp.maximum(jnp.broadcast_to(a.lo, shape),
                         jnp.broadcast_to(self.ivl.lo, shape))
        hi = jnp.minimum(jnp.broadcast_to(a.hi, shape),
                         jnp.broadcast_to(self.ivl.hi, shape))
        return iv.Interval(lo, hi)

    @property
    def dbar(self) -> jax.Array:
        return jnp.zeros(self.form.shape, jnp.float64)

    ebar = dbar

    @property
    def shape(self) -> tuple:
        return tuple(self.form.shape)

    @property
    def ndim(self) -> int:
        return len(self.form.shape)


def _aff_struct(f: iv.AffineForm, fn) -> iv.AffineForm:
    """Apply a shape-only op: fn(arr, is_terms) on center/rad and the
    axis-shifted terms."""
    return iv.AffineForm(fn(f.center, False), fn(f.terms, True), f.ids,
                         fn(f.rad, False))


class AffineRangeCaaOps(UnrolledLayerLoop, Backend):
    """Eager affine range pass over per-scope FP formats.

    ``scope_fmts[s]`` is the :class:`repro.core.formats.FpFormat` scope
    ``s`` runs in (resolved with the scopes matcher — ``layer3``,
    ``layer*``, ``layer*/attn`` keys all work); each op charges roundings
    of half-width ``(u_s/2)·|v| + η_s`` at the scope it executes in.
    Observations land in ``scope_ranges`` exactly like
    :class:`RangeCaaOps` (operands observed into the consuming scope,
    enclosures inflated by one re-quantisation into that scope's format),
    so :func:`repro.core.analyze.aggregate_ranges` and the synthesizer
    consume either pass interchangeably."""

    is_analysis = True

    def __init__(self, scope_fmts: Dict[str, Any], default_fmt,
                 budget: int = iv.AFF_DEFAULT_BUDGET,
                 weights_exact: bool = True,
                 condense_rank: str = iv.AFF_DEFAULT_RANK):
        self._fmts = dict(scope_fmts or {})
        self._default_fmt = default_fmt
        self.budget = int(budget)
        self.condense_rank = str(condense_rank)
        self.weights_exact = weights_exact
        self._scope: List[str] = []
        self._knobs: Dict[tuple, tuple] = {}
        self._sym_counter = 1  # 0 marks the empty slot
        self.scope_ranges: Dict[str, RangeStat] = {}

    # -- knobs / symbols -----------------------------------------------------
    def _hu_eta(self):
        """(u_s/2, η_s) of the current scope's format."""
        key = tuple(self._scope)
        got = self._knobs.get(key)
        if got is None:
            fmt = resolve_scope_value(self._scope, self._fmts,
                                      self._default_fmt)
            got = (0.5 * fmt.u, fmt.underflow_unit)
            self._knobs[key] = got
        return got

    def _next_id(self):
        i = self._sym_counter
        self._sym_counter = i + 1
        return i

    # -- lift / rounding charges / observe -----------------------------------
    def _lift(self, x, observe: bool = True) -> AffTensor:
        if isinstance(x, AffTensor):
            t = x
        elif isinstance(x, CaaTensor):
            # a CaaTensor reaching this backend carries exact reference
            # values (inputs built by caa.make) — enclose its fp range at
            # the coarsest unit it may run under (u = 2·hu of this scope)
            hu, _ = self._hu_eta()
            rng = x.fp_range(2.0 * hu)
            form = iv.aff_from_interval(
                rng, self.budget, center=jnp.asarray(x.val, jnp.float64))
            t = AffTensor(form, rng)
        else:
            t = AffTensor(iv.aff_make(x, self.budget))
        if observe:
            self._observe(t, is_op=False)
        return t

    def _round_iv(self, I: iv.Interval, rounds) -> iv.Interval:
        """Widen an ideal-result enclosure by ``rounds`` elementary
        roundings at this scope's format: relative growth (1+u/2)^n − 1
        (plus our own f64 slop) and n·η absolute — the operational model,
        finite at every precision."""
        hu, eta = self._hu_eta()
        grow = (jnp.power(1.0 + hu, float(rounds))
                * (1.0 + 8.0 * iv._gamma_f64(8)) - 1.0)
        add = float(rounds) * eta * (1.0 + grow)
        lo = iv._down(I.lo - (grow * jnp.abs(I.lo) + add))
        hi = iv._up(I.hi + (grow * jnp.abs(I.hi) + add))
        # rounding is monotone with rd(0) = 0: a provably-nonnegative
        # quantity stays nonnegative under FP evaluation (likewise ≤ 0), so
        # the η slop must not push an enclosure across zero — that spurious
        # crossing is what lets mean(x²)+eps reach rsqrt with lo < 0
        lo = jnp.where(I.lo >= 0.0, jnp.maximum(lo, 0.0), lo)
        hi = jnp.where(I.hi <= 0.0, jnp.minimum(hi, 0.0), hi)
        bad = jnp.isnan(lo) | jnp.isnan(hi)
        return iv.Interval(jnp.where(bad, -_AFF_INF, lo),
                           jnp.where(bad, _AFF_INF, hi))

    def _sym(self, f: iv.AffineForm, rounds) -> iv.AffineForm:
        """Charge ``rounds`` output roundings on the form channel as one
        fresh per-element noise symbol."""
        hu, eta = self._hu_eta()
        coeff = float(rounds) * (hu * (jnp.abs(f.center) + iv.aff_tot(f))
                                 + eta)
        return iv.aff_append_symbol(f, coeff, self._next_id(), self.budget,
                                    self.condense_rank)

    def _refit(self, I: iv.Interval, center) -> iv.AffineForm:
        """Terms-free form recentred on the reference value (nonlinear ops
        and contractions drop their symbols; the interval channel carries
        the asymmetric part the form cannot)."""
        c = jnp.asarray(center, jnp.float64)
        return iv.aff_from_interval(I, self.budget,
                                    center=jnp.where(jnp.isfinite(c), c, 0.0))

    def _out(self, f: iv.AffineForm, I: iv.Interval,
             is_op: bool = True) -> AffTensor:
        t = AffTensor(f, I)
        self._observe(t, is_op=is_op)
        return t

    def _requant_interval(self, t: AffTensor) -> iv.Interval:
        """Channel intersection inflated by one re-quantisation into this
        scope's format — the envelope a value must fit when scope s
        consumes or produces it ((1 ± u/2)·v ± η)."""
        return self._round_iv(t.exact, 1)

    def _observe(self, t: AffTensor, is_op: bool):
        import numpy as np
        ivl = self._requant_interval(t)
        lo = np.asarray(jnp.broadcast_to(ivl.lo, t.shape),
                        np.float64).ravel()
        hi = np.asarray(jnp.broadcast_to(ivl.hi, t.shape),
                        np.float64).ravel()
        mag = np.maximum(np.abs(lo), np.abs(hi))
        mig = np.maximum(np.maximum(lo, -hi), 0.0)
        pos = mig[mig > 0]
        stat = RangeStat(
            max_abs=float(mag.max(initial=0.0)),
            min_nonzero=float(pos.min()) if pos.size else math.inf,
            crosses_zero=bool((mig <= 0).any()),
            n_ops=1 if is_op else 0,
        )
        key = "/".join(self._scope) if self._scope else ""
        prev = self.scope_ranges.get(key)
        self.scope_ranges[key] = stat if prev is None else prev.merge(stat)

    # -- construction --------------------------------------------------------
    def param(self, w, exact: Optional[bool] = None):
        exact = self.weights_exact if exact is None else exact
        f = iv.aff_make(w, self.budget)
        if not exact:
            f = self._sym(f, 1)
        return self._out(f, iv.aff_interval(f))

    def input(self, x):
        if isinstance(x, AffTensor):
            self._observe(x, is_op=False)
            return x
        t = self._lift(x, observe=False)
        self._observe(t, is_op=True)
        return t

    def const(self, c):
        f = iv.aff_make(c, self.budget)
        return self._out(f, iv.aff_interval(f))

    # -- elementwise arithmetic (form terms survive — correlations cancel) --
    def add(self, a, b):
        A, B = self._lift(a), self._lift(b)
        f = self._sym(iv.aff_add(A.form, B.form, self.budget,
                                 self.condense_rank), 1)
        I = self._round_iv(iv.add(A.exact, B.exact), 1)
        return self._out(f, I)

    def sub(self, a, b):
        A, B = self._lift(a), self._lift(b)
        f = self._sym(iv.aff_sub(A.form, B.form, self.budget,
                                 self.condense_rank), 1)
        I = self._round_iv(iv.sub(A.exact, B.exact), 1)
        return self._out(f, I)

    def mul(self, a, b):
        A, B = self._lift(a), self._lift(b)
        f = self._sym(iv.aff_mul(A.form, B.form, self.budget,
                                 self.condense_rank), 1)
        I = self._round_iv(iv.mul(A.exact, B.exact), 1)
        return self._out(f, I)

    def neg(self, a):
        A = self._lift(a)
        return self._out(iv.aff_neg(A.form), iv.neg(A.exact))

    def scale(self, a, c, exact_const: bool = False):
        A = self._lift(a)
        f = iv.aff_scale(A.form, c)
        I = iv.scale(A.exact, jnp.asarray(c, jnp.float64))
        if not exact_const:
            f = self._sym(f, 1)
            I = self._round_iv(I, 1)
        return self._out(f, I)

    def shift(self, a, c):
        A = self._lift(a)
        f = self._sym(iv.aff_shift(A.form, c), 1)
        I = self._round_iv(iv.shift(A.exact, jnp.asarray(c, jnp.float64)), 1)
        return self._out(f, I)

    def square(self, a):
        A = self._lift(a)
        f = self._sym(iv.aff_mul(A.form, A.form, self.budget,
                                 self.condense_rank), 1)
        Iq = iv.square(A.exact)
        # squares are exactly nonnegative; iv.square's outward nextafter
        # turns a 0 endpoint into -5e-324, which would defeat _round_iv's
        # sign preservation and ultimately the norm rsqrt guards
        I = self._round_iv(iv.Interval(jnp.maximum(Iq.lo, 0.0), Iq.hi), 1)
        return self._out(f, I)

    def div(self, a, b):
        A, B = self._lift(a), self._lift(b)
        I = self._round_iv(iv.div(A.exact, B.exact), 1)
        return self._out(self._refit(I, A.val / B.val), I)

    # -- nonlinear unaries (interval rule; form refits on the reference) ----
    def _fb_unary(self, a, ivl_fn, val_fn, rounds=1):
        A = self._lift(a)
        I = self._round_iv(ivl_fn(A.exact), rounds)
        return self._out(self._refit(I, val_fn(A.val)), I)

    def tanh(self, a): return self._fb_unary(a, iv.tanh, jnp.tanh)
    def sigmoid(self, a): return self._fb_unary(a, iv.sigmoid,
                                                jax.nn.sigmoid)
    def exp(self, a): return self._fb_unary(a, iv.exp, jnp.exp)
    def log(self, a): return self._fb_unary(a, iv.log, jnp.log)
    def sqrt(self, a): return self._fb_unary(a, iv.sqrt, jnp.sqrt)

    def rsqrt(self, a):
        return self._fb_unary(a, lambda t: iv.recip(iv.sqrt(t)),
                              jax.lax.rsqrt, rounds=2)

    def relu(self, a):
        # exact in FP: selection, no rounding
        A = self._lift(a)
        I = iv.clamp_min(A.exact, 0.0)
        return self._out(self._refit(I, jax.nn.relu(A.val)), I)

    def silu(self, a): return self._fb_unary(a, iv.silu, jax.nn.silu,
                                             rounds=3)

    def gelu(self, a):
        return self._fb_unary(a, iv.gelu_tanh,
                              lambda x: jax.nn.gelu(x, approximate=True),
                              rounds=4)

    def softmax(self, a, axis: int = -1):
        A = self._lift(a)
        # max-shift + exp + sum + div per output: 4 elementary roundings
        I = self._round_iv(iv.softmax_range(A.exact, axis=axis), 4)
        c = jax.nn.softmax(jnp.asarray(A.val, jnp.float64), axis=axis)
        return self._out(self._refit(I, c), I)

    # -- contractions (symbols of distinct elements mix → interval rule) ----
    def matmul(self, a, b):
        A, B = self._lift(a), self._lift(b)
        Ia = self._round_iv(A.exact, 1)   # operand requant into this scope
        Ib = self._round_iv(B.exact, 1)
        n = int(jnp.shape(A.val)[-1])
        I = self._round_iv(iv.matmul(Ia, Ib), n + 2)
        return self._out(self._refit(I, jnp.matmul(A.val, B.val)), I)

    def einsum(self, subscripts, a, b):
        A, B = self._lift(a), self._lift(b)
        Ia = self._round_iv(A.exact, 1)
        Ib = self._round_iv(B.exact, 1)
        n = _einsum_contract_length(subscripts, A.shape, B.shape)
        I = self._round_iv(iv.einsum_ball(subscripts, Ia, Ib), n + 2)
        return self._out(
            self._refit(I, jnp.einsum(subscripts, A.val, B.val)), I)

    def sum(self, a, axis, keepdims: bool = False):
        A = self._lift(a)
        Ia = self._round_iv(A.exact, 1)
        n = _reduced_count(A.shape, axis)
        I = self._round_iv(iv.sum_(Ia, axis=axis, keepdims=keepdims), n + 1)
        return self._out(
            self._refit(I, jnp.sum(A.val, axis=axis, keepdims=keepdims)), I)

    def mean(self, a, axis, keepdims: bool = False):
        # sum-then-scale: the accumulation's n·η absolute slop must be
        # charged on the SUM and divided down with it — charging it on the
        # mean directly is n× too wide, enough to push mean(x²)+eps through
        # zero and blow up every norm's rsqrt
        A = self._lift(a)
        Ia = self._round_iv(A.exact, 1)
        n = _reduced_count(A.shape, axis)
        Is = self._round_iv(iv.sum_(Ia, axis=axis, keepdims=keepdims), n - 1)
        I = self._round_iv(iv.scale(Is, 1.0 / n), 1)
        return self._out(
            self._refit(I, jnp.mean(A.val, axis=axis, keepdims=keepdims)), I)

    def max(self, a, axis, keepdims: bool = False):
        A = self._lift(a)
        I = iv.max_(A.exact, axis=axis, keepdims=keepdims)
        c = jnp.max(jnp.asarray(A.val, jnp.float64), axis=axis,
                    keepdims=keepdims)
        return self._out(self._refit(I, c), I)

    def maximum(self, a, b):
        A, B = self._lift(a), self._lift(b)
        I = iv.maximum(A.exact, B.exact)
        return self._out(self._refit(I, jnp.maximum(A.val, B.val)), I)

    def where(self, mask, a, b):
        m = mask.val if isinstance(mask, (AffTensor, CaaTensor)) else mask
        A, B = self._lift(a), self._lift(b)
        f = iv.aff_where(m, A.form, B.form, self.budget,
                         self.condense_rank)
        Ea, Eb = A.exact, B.exact
        I = iv.Interval(jnp.where(m, Ea.lo, Eb.lo),
                        jnp.where(m, Ea.hi, Eb.hi))
        return self._out(f, I)

    def top_k_mask(self, scores, k: int, name: str = "router"):
        s = self._lift(scores, observe=False)
        _, idx = jax.lax.top_k(s.val, k)
        return jax.nn.one_hot(idx, int(s.shape[-1]),
                              dtype=jnp.float64).sum(-2)

    # -- structure (exact movement: both channels shuffled in place) --------
    def _struct_out(self, a, fn) -> AffTensor:
        A = self._lift(a, observe=False)
        f = iv._aff_broadcast(A.form, A.shape)
        lo = jnp.broadcast_to(A.ivl.lo, A.shape)
        hi = jnp.broadcast_to(A.ivl.hi, A.shape)
        return self._out(_aff_struct(f, fn),
                         iv.Interval(fn(lo, False), fn(hi, False)))

    def reshape(self, a, shape):
        shape = tuple(shape)
        return self._struct_out(a, lambda t, terms: jnp.reshape(
            t, (t.shape[0],) + shape if terms else shape))

    def transpose(self, a, axes):
        axes = tuple(axes)
        taxes = (0,) + tuple(ax + 1 for ax in axes)
        return self._struct_out(a, lambda t, terms: jnp.transpose(
            t, taxes if terms else axes))

    def broadcast_to(self, a, shape):
        A = self._lift(a, observe=False)
        return self._out(
            iv._aff_broadcast(A.form, shape),
            iv.Interval(jnp.broadcast_to(A.ivl.lo, shape),
                        jnp.broadcast_to(A.ivl.hi, shape)))

    def take(self, a, idx, axis):
        tax = axis + 1 if axis >= 0 else axis  # terms lead with the slot dim
        return self._struct_out(a, lambda t, terms: jnp.take(
            t, idx, axis=tax if terms else axis))

    def slice(self, a, slices):
        sl = (tuple(slices) if isinstance(slices, (tuple, list))
              else (slices,))
        return self._struct_out(
            a, lambda t, terms: t[(slice(None),) + sl if terms else sl])

    def concat(self, parts, axis):
        ts = [self._lift(p) for p in parts]
        forms = [iv._aff_broadcast(t.form, t.shape) for t in ts]
        out = forms[0]
        tax = axis + 1 if axis >= 0 else axis
        for f in forms[1:]:
            ids, ta, tb = iv._aff_common(out, f)
            out = iv.aff_condense(iv.AffineForm(
                jnp.concatenate([out.center, f.center], axis=axis),
                jnp.concatenate([ta, tb], axis=tax),
                ids,
                jnp.concatenate([out.rad, f.rad], axis=axis)), self.budget,
                self.condense_rank)
        I = iv.Interval(
            jnp.concatenate([jnp.broadcast_to(t.ivl.lo, t.shape)
                             for t in ts], axis=axis),
            jnp.concatenate([jnp.broadcast_to(t.ivl.hi, t.shape)
                             for t in ts], axis=axis))
        return self._out(out, I)

    def shape_of(self, a):
        return tuple(self._lift(a, observe=False).shape)

    def value_of(self, a):
        return self._lift(a, observe=False).val

    def clamp_range(self, a, lo, hi):
        A = self._lift(a, observe=False)
        lo = jnp.asarray(lo, jnp.float64)
        hi = jnp.asarray(hi, jnp.float64)
        f = iv.aff_intersect(A.form, iv.Interval(lo, hi))
        nlo = jnp.maximum(jnp.broadcast_to(A.ivl.lo, A.shape), lo)
        nhi = jnp.minimum(jnp.broadcast_to(A.ivl.hi, A.shape), hi)
        bad = nlo > nhi   # wrong external bound: keep the original channel
        I = iv.Interval(jnp.where(bad, A.ivl.lo, nlo),
                        jnp.where(bad, A.ivl.hi, nhi))
        return self._out(f, I)

    def record(self, name: str, a, kind: str = "layer"):
        return a

    def ssm_scan(self, decay, drive, n_steps: int, time_axis: int = 1):
        """Interval fixpoint of h' = d⊙h + b under rounded arithmetic:
        with w = max_t |d|, B = max_t |b| and per-step inflation
        (1+u/2)² + 2η, |h| ≤ B'/(1−w') when the rounded decay w' < 1
        (∞ otherwise — still free of saturating γ forms). Reference
        values come from the true f64 scan."""
        D, V = self._lift(decay), self._lift(drive)
        hu, eta = self._hu_eta()
        w = jnp.max(iv.mag(D.exact), axis=time_axis, keepdims=True)
        Bm = jnp.max(iv.mag(V.exact), axis=time_axis, keepdims=True)
        infl = (1.0 + hu) ** 2 * (1.0 + 8.0 * iv._gamma_f64(8))
        wr = iv._up(w * infl)
        Br = iv._up(Bm * infl + 2.0 * eta)
        H = jnp.where(wr < 1.0, Br / jnp.maximum(1.0 - wr, 1e-300),
                      jnp.inf)
        H = iv._up(H * (1.0 + 8.0 * iv._gamma_f64(8)))
        vals = JOps(jnp.float64, jnp.float64).ssm_scan(
            D.val, V.val, n_steps, time_axis)
        I = iv.Interval(jnp.broadcast_to(-H, vals.shape),
                        jnp.broadcast_to(H, vals.shape))
        return self._out(self._refit(I, vals), I)


_AFF_INF = jnp.inf


def _einsum_contract_length(subscripts: str, sa, sb) -> int:
    """Number of products summed per output element of a two-operand
    einsum — the n of the accumulation-rounding charge."""
    ins, out = subscripts.replace(" ", "").split("->")
    A, B = ins.split(",")
    dims = {}
    for ch, d in zip(A, sa):
        dims[ch] = int(d)
    for ch, d in zip(B, sb):
        dims[ch] = int(d)
    n = 1
    for ch, d in dims.items():
        if ch not in out:
            n *= d
    return max(n, 1)


def _reduced_count(shape, axis) -> int:
    if axis is None:
        n = 1
        for d in shape:
            n *= int(d)
        return max(n, 1)
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    n = 1
    for ax in axes:
        n *= int(shape[ax])
    return max(n, 1)


def _canon_aff(t: AffTensor) -> AffTensor:
    """Broadcast every field to center's shape — a scan carry needs one
    fixed aval (the affine twin of :func:`_canon_caa`)."""
    f = t.form
    shape = jnp.shape(f.center)
    form = iv.AffineForm(
        jnp.asarray(f.center, jnp.float64),
        jnp.broadcast_to(jnp.asarray(f.terms, jnp.float64),
                         (f.budget,) + shape),
        jnp.asarray(f.ids, jnp.int32),
        jnp.broadcast_to(jnp.asarray(f.rad, jnp.float64), shape))
    I = iv.Interval(
        jnp.broadcast_to(jnp.asarray(t.ivl.lo, jnp.float64), shape),
        jnp.broadcast_to(jnp.asarray(t.ivl.hi, jnp.float64), shape))
    return AffTensor(form, I)


class StackedAffineRangeCaaOps(AffineRangeCaaOps):
    """Scan-native affine range pass: ``layer_loop`` is ONE ``lax.scan``
    whose carry threads (two-channel enclosure, ``[L, S, 4]`` range lanes,
    noise-symbol counter). The traced i32 counter keeps symbol ids
    distinct across scan iterations — static ids would alias layer i's
    rounding errors with layer i+1's and unsoundly cancel them. Sub-layer
    scopes (``sublanes``, e.g. ``("attn", "mlp")``) get their own
    accumulator lane and their own per-suffix format lane, mirroring
    :class:`StackedRangeCaaOps` / :class:`StackedCaaOps`; ops outside the
    stack run eagerly into ``scope_ranges`` as in the parent class."""

    def __init__(self, scope_fmts: Dict[str, Any], default_fmt,
                 budget: int = iv.AFF_DEFAULT_BUDGET,
                 weights_exact: bool = True,
                 sublanes: Sequence[str] = (),
                 condense_rank: str = iv.AFF_DEFAULT_RANK):
        super().__init__(scope_fmts, default_fmt, budget=budget,
                         weights_exact=weights_exact,
                         condense_rank=condense_rank)
        self._sublanes = tuple(sublanes)
        self._sub_map = {s: j + 1 for j, s in enumerate(self._sublanes)}
        self._in_stack = False
        self._layer_index = None
        self._stack_ctx = None
        self._lane_cache: Dict[tuple, tuple] = {}
        self._lane_acc = None
        self._sym_ctr_traced = None
        self._done_lanes: List = []

    # -- stack plumbing ------------------------------------------------------
    def _stack_suffix(self) -> tuple:
        outer, _ = self._stack_ctx
        return tuple(self._scope[len(outer) + 1:])

    def _sub_idx(self) -> int:
        if self._stack_ctx is None or not self._sub_map:
            return 0
        suffix = self._stack_suffix()
        return self._sub_map.get(suffix[0], 0) if suffix else 0

    def _fmt_lanes(self, suffix: tuple):
        """Per-layer (u/2, η) lanes for one sub-layer suffix (formats are
        static objects, so the lanes are concrete [L] constants)."""
        cached = self._lane_cache.get(suffix)
        if cached is None:
            import numpy as np
            outer, n_layers = self._stack_ctx
            hu, eta = [], []
            for i in range(n_layers):
                fmt = resolve_scope_value(
                    outer + [f"layer{i}", *suffix], self._fmts,
                    self._default_fmt)
                hu.append(0.5 * fmt.u)
                eta.append(fmt.underflow_unit)
            cached = (jnp.asarray(np.asarray(hu, np.float64)),
                      jnp.asarray(np.asarray(eta, np.float64)))
            self._lane_cache[suffix] = cached
        return cached

    def _hu_eta(self):
        if self._in_stack and self._stack_ctx is not None:
            hu_vec, eta_vec = self._fmt_lanes(self._stack_suffix())
            i = self._layer_index
            return hu_vec[i], eta_vec[i]
        return super()._hu_eta()

    def _next_id(self):
        if self._in_stack:
            i = self._sym_ctr_traced
            self._sym_ctr_traced = i + 1
            return i
        return super()._next_id()

    def _observe(self, t: AffTensor, is_op: bool):
        if not self._in_stack:
            return super()._observe(t, is_op)
        ivl = self._requant_interval(t)
        lo = jnp.broadcast_to(ivl.lo, t.shape).ravel()
        hi = jnp.broadcast_to(ivl.hi, t.shape).ravel()
        mag = jnp.max(jnp.maximum(jnp.abs(lo), jnp.abs(hi)))
        mig = jnp.maximum(jnp.maximum(lo, -hi), 0.0)
        min_nz = jnp.min(jnp.where(mig > 0, mig, jnp.inf))
        crossed = jnp.any(mig <= 0).astype(jnp.float64)
        stat = (mag, min_nz, crossed,
                jnp.asarray(1.0 if is_op else 0.0, jnp.float64))
        i, j = self._layer_index, self._sub_idx()
        self._lane_acc = self._lane_acc.at[i, j].set(
            StackedRangeCaaOps._merge_acc(self._lane_acc[i, j], stat))

    # -- the one scan --------------------------------------------------------
    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        if self._in_stack:
            return super().layer_loop(fn, stacked_params, x, n_layers, aux)
        outer = list(self._scope)
        self._stack_ctx = (outer, n_layers)
        self._lane_cache = {}
        x0 = _canon_aff(self._lift(x, observe=False))
        acc0 = jnp.broadcast_to(
            jnp.asarray(StackedRangeCaaOps._ACC_INIT, jnp.float64),
            (n_layers, 1 + len(self._sublanes), 4))
        ctr0 = jnp.asarray(self._sym_counter, jnp.int32)

        def body(carry, xs):
            p, i, a = xs
            cf, clo, chi, acc, ctr = carry
            self._in_stack = True
            self._layer_index = i
            self._lane_acc = acc
            self._sym_ctr_traced = ctr
            cx = AffTensor(cf, iv.Interval(clo, chi))
            new_x, aux_out = fn(p, cx, i, a)
            nt = _canon_aff(self._lift(new_x, observe=False))
            return ((nt.form, nt.ivl.lo, nt.ivl.hi,
                     self._lane_acc, self._sym_ctr_traced), aux_out)

        idx = jnp.arange(n_layers)
        with self.scope(STACK_SCOPE):
            carry0 = (x0.form, x0.ivl.lo, x0.ivl.hi, acc0, ctr0)
            (out_f, out_lo, out_hi, acc, ctr), aux_outs = jax.lax.scan(
                body, carry0, (stacked_params, idx, aux))
            self._in_stack = False
            self._layer_index = None
            self._stack_ctx = None
            self._lane_acc = None
        self._done_lanes.append(acc)
        # eager ids must stay ahead of every id the scan consumed
        self._sym_counter = int(ctr)
        return AffTensor(out_f, iv.Interval(out_lo, out_hi)), aux_outs

    def collect_ranges(self) -> Dict[str, RangeStat]:
        """Concretised lanes (``layer{i}`` / ``layer{i}/{sub}`` keys)
        merged with the eager outside-the-stack ``scope_ranges``."""
        import numpy as np
        out: Dict[str, RangeStat] = {}
        for lanes in self._done_lanes:
            arr = np.asarray(lanes, np.float64)
            for i in range(arr.shape[0]):
                for j in range(arr.shape[1]):
                    row = arr[i, j]
                    s = RangeStat(
                        max_abs=float(row[0]), min_nonzero=float(row[1]),
                        crosses_zero=bool(row[2] > 0), n_ops=int(row[3]))
                    if (j > 0 and s.n_ops == 0 and s.max_abs == 0.0
                            and s.min_nonzero == math.inf):
                        continue
                    key = (f"layer{i}" if j == 0
                           else f"layer{i}/{self._sublanes[j - 1]}")
                    out[key] = (s if key not in out
                                else out[key].merge(s))
        for key, s in self.scope_ranges.items():
            key = "" if key.startswith(STACK_SCOPE) else key
            out[key] = s if key not in out else out[key].merge(s)
        out.setdefault("", RangeStat())
        return out
