"""Vectorised rigorous interval arithmetic (IA) in JAX.

Replaces the paper's MPFI back-end. MPFI computes each bound with directed
rounding in arbitrary precision; we compute bounds in float64 round-to-nearest
and then *widen outward* with ``nextafter`` — the enclosure property is
preserved, one-or-two ulps looser, and the whole thing vectorises over tensors
(the paper's measured bottleneck was precisely per-scalar MPFI allocations:
4.2 h for one MobileNet class; this back-end does the equivalent work in
milliseconds, see benchmarks/analysis_speed.py).

Transcendentals (exp, tanh, log, ...) in f64 libm are not correctly rounded;
we assume a ≤ 2 ulp libm and widen monotone-function bounds outward by
``LIBM_SLOP_ULPS`` ulps (default 4) — rigorous for every libm in practical
use, and checkable: tests/test_interval.py samples densely and asserts
enclosure.

Intervals are represented as a NamedTuple of (lo, hi) float64 arrays; an
empty/invalid interval is never produced (ops that could, e.g. division by an
interval containing 0, return [-inf, inf] — the paper's "bound becomes
infinite" convention).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

LIBM_SLOP_ULPS = 4
_F64 = jnp.float64
_INF = jnp.inf


class Interval(NamedTuple):
    lo: jax.Array
    hi: jax.Array

    @property
    def shape(self):
        return jnp.shape(self.lo)

    def astuple(self):
        return (self.lo, self.hi)


def _f(x) -> jax.Array:
    return jnp.asarray(x, _F64)


def _is_subnormal(x):
    """Bit-level detection — float comparisons themselves run under DAZ."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint64)
    expo = (bits >> jnp.uint64(52)) & jnp.uint64(0x7FF)
    mant = bits & jnp.uint64((1 << 52) - 1)
    return (expo == 0) & (mant != 0)


def _sign_bit(x):
    bits = jax.lax.bitcast_convert_type(x, jnp.uint64)
    return (bits >> jnp.uint64(63)) != 0


def _desub_lo(lo):
    """Snap subnormal lower endpoints outward (XLA DAZ would zero them as
    operands, silently *shrinking* the interval)."""
    tiny = _is_subnormal(lo)
    return jnp.where(
        tiny, jnp.where(_sign_bit(lo), -2.2250738585072014e-308, 0.0), lo)


def _desub_hi(hi):
    tiny = _is_subnormal(hi)
    return jnp.where(
        tiny, jnp.where(_sign_bit(hi), 0.0, 2.2250738585072014e-308), hi)


def make(lo, hi=None) -> Interval:
    lo = _f(lo)
    hi = lo if hi is None else _f(hi)
    lo, hi = jnp.broadcast_arrays(lo, hi)
    return Interval(_desub_lo(lo), _desub_hi(hi))


def point(x) -> Interval:
    x = _f(x)
    return Interval(_desub_lo(x), _desub_hi(x))


#: XLA CPU executes f64 with FTZ/DAZ — subnormal values flush to zero. Any
#: computed endpoint inside the subnormal range could therefore stand for a
#: true value anywhere in (−DBL_MIN, DBL_MIN); directed rounding floors
#: there. The extra ±2.2e-308 of width is irrelevant at DNN scales and
#: restores the enclosure property (tests/test_interval.py hits this).
_MINN = 2.2250738585072014e-308


def _down(x):
    """Next float64 toward -inf (no-op on -inf; preserves NaN; FTZ-safe)."""
    y = jnp.where(jnp.isfinite(x), jnp.nextafter(x, _f(-_INF)), x)
    return jnp.where(jnp.abs(x) < _MINN, -_MINN, y)


def _up(x):
    y = jnp.where(jnp.isfinite(x), jnp.nextafter(x, _f(_INF)), x)
    return jnp.where(jnp.abs(x) < _MINN, _MINN, y)


def _down_n(x, n):
    for _ in range(n):
        x = _down(x)
    return x


def _up_n(x, n):
    for _ in range(n):
        x = _up(x)
    return x


def widen(iv: Interval, ulps: int = 1) -> Interval:
    return Interval(_down_n(iv.lo, ulps), _up_n(iv.hi, ulps))


def widen_abs(iv: Interval, slack) -> Interval:
    """Widen both ends outward by an absolute amount (itself rounded up)."""
    s = _up(_f(slack))
    return Interval(_down(iv.lo - s), _up(iv.hi + s))


# --- structural helpers ----------------------------------------------------

def mag(iv: Interval) -> jax.Array:
    """sup |x| over the interval."""
    return jnp.maximum(jnp.abs(iv.lo), jnp.abs(iv.hi))


def mig(iv: Interval) -> jax.Array:
    """inf |x| over the interval (0 if the interval contains 0)."""
    contains0 = (iv.lo <= 0) & (iv.hi >= 0)
    return jnp.where(contains0, 0.0, jnp.minimum(jnp.abs(iv.lo), jnp.abs(iv.hi)))


def width(iv: Interval) -> jax.Array:
    return _up(iv.hi - iv.lo)


def midpoint(iv: Interval) -> jax.Array:
    return 0.5 * (iv.lo + iv.hi)


def radius(iv: Interval) -> jax.Array:
    m = midpoint(iv)
    return _up(jnp.maximum(iv.hi - m, m - iv.lo))


def contains(iv: Interval, x) -> jax.Array:
    x = _f(x)
    return (iv.lo <= x) & (x <= iv.hi)


def subset(a: Interval, b: Interval) -> jax.Array:
    return (b.lo <= a.lo) & (a.hi <= b.hi)


def hull(a: Interval, b: Interval) -> Interval:
    return Interval(jnp.minimum(a.lo, b.lo), jnp.maximum(a.hi, b.hi))


def intersect_nonempty(a: Interval, b: Interval) -> jax.Array:
    return (a.lo <= b.hi) & (b.lo <= a.hi)


# --- arithmetic -------------------------------------------------------------

def neg(a: Interval) -> Interval:
    return Interval(-a.hi, -a.lo)


def add(a: Interval, b: Interval) -> Interval:
    return Interval(_down(a.lo + b.lo), _up(a.hi + b.hi))


def sub(a: Interval, b: Interval) -> Interval:
    return Interval(_down(a.lo - b.hi), _up(a.hi - b.lo))


def scale(a: Interval, c) -> Interval:
    """Multiply by an exact scalar/array constant."""
    c = _f(c)
    p1, p2 = a.lo * c, a.hi * c
    return Interval(_down(jnp.minimum(p1, p2)), _up(jnp.maximum(p1, p2)))


def shift(a: Interval, c) -> Interval:
    c = _f(c)
    return Interval(_down(a.lo + c), _up(a.hi + c))


def mul(a: Interval, b: Interval) -> Interval:
    p = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
    lo = jnp.minimum(jnp.minimum(p[0], p[1]), jnp.minimum(p[2], p[3]))
    hi = jnp.maximum(jnp.maximum(p[0], p[1]), jnp.maximum(p[2], p[3]))
    # 0 * inf protection: an interval with a 0 endpoint times an infinite one
    nan = jnp.isnan(lo) | jnp.isnan(hi)
    lo = jnp.where(nan, -_INF, lo)
    hi = jnp.where(nan, _INF, hi)
    return Interval(_down(lo), _up(hi))


def recip(a: Interval) -> Interval:
    contains0 = (a.lo <= 0) & (a.hi >= 0)
    lo = jnp.where(contains0, -_INF, _down(1.0 / a.hi))
    hi = jnp.where(contains0, _INF, _up(1.0 / a.lo))
    return Interval(lo, hi)


def div(a: Interval, b: Interval) -> Interval:
    return mul(a, recip(b))


def abs_(a: Interval) -> Interval:
    return Interval(mig(a), _up(mag(a)))


def square(a: Interval) -> Interval:
    m, M = mig(a), mag(a)
    return Interval(_down(m * m), _up(M * M))


def sqrt(a: Interval) -> Interval:
    lo = jnp.sqrt(jnp.maximum(a.lo, 0.0))
    hi = jnp.sqrt(jnp.maximum(a.hi, 0.0))
    return widen(Interval(lo, hi), 1)


def maximum(a: Interval, b: Interval) -> Interval:
    return Interval(jnp.maximum(a.lo, b.lo), jnp.maximum(a.hi, b.hi))


def minimum(a: Interval, b: Interval) -> Interval:
    return Interval(jnp.minimum(a.lo, b.lo), jnp.minimum(a.hi, b.hi))


def clamp_min(a: Interval, c) -> Interval:  # e.g. ReLU with c=0
    c = _f(c)
    return Interval(jnp.maximum(a.lo, c), jnp.maximum(a.hi, c))


# --- monotone transcendentals ----------------------------------------------

def _monotone(f, a: Interval, slop: int = LIBM_SLOP_ULPS) -> Interval:
    return widen(Interval(f(a.lo), f(a.hi)), slop)


def exp(a: Interval) -> Interval:
    iv = _monotone(jnp.exp, a)
    return Interval(jnp.maximum(iv.lo, 0.0), iv.hi)


def expm1(a: Interval) -> Interval:
    iv = _monotone(jnp.expm1, a)
    return Interval(jnp.maximum(iv.lo, -1.0), iv.hi)


def log(a: Interval) -> Interval:
    lo = jnp.where(a.lo <= 0, -_INF, jnp.log(a.lo))
    hi = jnp.where(a.hi <= 0, -_INF, jnp.log(a.hi))
    return widen(Interval(lo, hi), LIBM_SLOP_ULPS)


def tanh(a: Interval) -> Interval:
    iv = _monotone(jnp.tanh, a)
    # XLA CPU's tanh drifts by more than our ulp slop near saturation
    # (found by hypothesis: jnp.tanh(19)+4ulps < true tanh(19)); add an
    # absolute guard there — negligible (1e-12) and sound.
    sat_lo = jnp.where(a.lo < -12.0, 1e-12, 0.0)
    sat_hi = jnp.where(a.hi > 12.0, 1e-12, 0.0)
    lo = jnp.maximum(iv.lo - sat_lo, -1.0)
    hi = jnp.minimum(iv.hi + sat_hi, 1.0)
    return Interval(lo, hi)


def sigmoid(a: Interval) -> Interval:
    iv = _monotone(jax.nn.sigmoid, a)
    sat_lo = jnp.where(a.lo < -25.0, 1e-12, 0.0)
    sat_hi = jnp.where(a.hi > 25.0, 1e-12, 0.0)
    return Interval(jnp.clip(iv.lo - sat_lo, 0.0, 1.0),
                    jnp.clip(iv.hi + sat_hi, 0.0, 1.0))


def erf(a: Interval) -> Interval:
    iv = _monotone(jax.scipy.special.erf, a)  # type: ignore[attr-defined]
    sat_lo = jnp.where(a.lo < -4.0, 1e-12, 0.0)
    sat_hi = jnp.where(a.hi > 4.0, 1e-12, 0.0)
    return Interval(jnp.maximum(iv.lo - sat_lo, -1.0),
                    jnp.minimum(iv.hi + sat_hi, 1.0))


def silu(a: Interval) -> Interval:
    """x*sigmoid(x). Not monotone on (-∞,≈-1.278]; global min ≈ -0.27846.

    We use: silu is increasing on [x*, ∞) and decreasing on (-∞, x*] with
    x* ≈ -1.27846; handle by case split on the enclosure.
    """
    xstar = -1.2784645427610738
    fmin = -0.2784645427610738  # silu(x*) rounded down a touch below
    f = lambda x: x * jax.nn.sigmoid(x)
    cand_lo = jnp.minimum(f(a.lo), f(a.hi))
    cand_hi = jnp.maximum(f(a.lo), f(a.hi))
    crosses = (a.lo <= xstar) & (a.hi >= xstar)
    lo = jnp.where(crosses, fmin, cand_lo)
    # deep-underflow zone: x·sigmoid(x) loses all relative accuracy; add an
    # absolute slack far below any representable activation scale
    return widen_abs(widen(Interval(lo, cand_hi), LIBM_SLOP_ULPS), 1e-290)


def gelu_tanh(a: Interval) -> Interval:
    """tanh-approximated GELU; same treatment as silu (min ≈ -0.17).

    Monotone decreasing left of x* ≈ -0.7517916, increasing right of it.
    """
    xstar = -0.7517916243494656
    fmin = -0.1700425
    f = lambda x: jax.nn.gelu(x, approximate=True)
    cand_lo = jnp.minimum(f(a.lo), f(a.hi))
    cand_hi = jnp.maximum(f(a.lo), f(a.hi))
    crosses = (a.lo <= xstar) & (a.hi >= xstar)
    lo = jnp.where(crosses, fmin, cand_lo)
    return widen_abs(widen(Interval(lo, cand_hi), LIBM_SLOP_ULPS), 1e-290)


# --- reductions / linear algebra --------------------------------------------

def _gamma_f64(n: int) -> float:
    """Higham's γ_n for float64 — the slop our own f64 bound computation incurs."""
    un = n * 2.0 ** -53
    return un / (1.0 - un)


def sum_(a: Interval, axis=None, keepdims: bool = False) -> Interval:
    n = (
        int(jnp.size(a.lo))
        if axis is None
        else int(jnp.shape(a.lo)[axis] if isinstance(axis, int) else 1)
    )
    lo = jnp.sum(a.lo, axis=axis, keepdims=keepdims)
    hi = jnp.sum(a.hi, axis=axis, keepdims=keepdims)
    slop = _gamma_f64(max(n, 1))
    # each endpoint's own f64 summation error is bounded by γ·Σ|terms of
    # that endpoint| — using the other endpoint's magnitudes would e.g.
    # push a sum of non-negative lows below zero.
    m_lo = jnp.sum(jnp.abs(a.lo), axis=axis, keepdims=keepdims)
    m_hi = jnp.sum(jnp.abs(a.hi), axis=axis, keepdims=keepdims)
    # all-zero endpoints sum exactly — keep ±0 exact (rsqrt guards rely on it)
    lo_w = jnp.where(m_lo == 0, lo, _down(lo - slop * m_lo))
    hi_w = jnp.where(m_hi == 0, hi, _up(hi + slop * m_hi))
    return Interval(lo_w, hi_w)


def max_(a: Interval, axis=None, keepdims: bool = False) -> Interval:
    return Interval(
        jnp.max(a.lo, axis=axis, keepdims=keepdims),
        jnp.max(a.hi, axis=axis, keepdims=keepdims),
    )


def min_(a: Interval, axis=None, keepdims: bool = False) -> Interval:
    return Interval(
        jnp.min(a.lo, axis=axis, keepdims=keepdims),
        jnp.min(a.hi, axis=axis, keepdims=keepdims),
    )


def mean(a: Interval, axis=None, keepdims: bool = False) -> Interval:
    n = int(jnp.size(a.lo)) if axis is None else int(jnp.shape(a.lo)[axis])
    s = sum_(a, axis=axis, keepdims=keepdims)
    return scale(s, 1.0 / n)


def matmul_const(a: Interval, w) -> Interval:
    """Interval @ exact-constant matrix, by sign-splitting W.

    lo = lo@W⁺ + hi@W⁻ ; hi = hi@W⁺ + lo@W⁻, then widened by the f64 GEMM's
    own γ_n slop (computed against |a|@|W|). Sound and one fused GEMM per
    bound — this replaces n² scalar MPFI updates per output.
    """
    w = _f(w)
    wp = jnp.maximum(w, 0.0)
    wm = jnp.minimum(w, 0.0)
    lo = a.lo @ wp + a.hi @ wm
    hi = a.hi @ wp + a.lo @ wm
    n = w.shape[-2]
    slop = _gamma_f64(2 * n + 2)
    m = jnp.maximum(jnp.abs(a.lo), jnp.abs(a.hi)) @ jnp.abs(w)
    return Interval(_down(lo - slop * m), _up(hi + slop * m))


def ball(iv: Interval) -> tuple[jax.Array, jax.Array]:
    """Midpoint-radius ('ball') form; radius rounded up.

    Unbounded intervals get (0, inf) — a sound ball — instead of the NaN
    that (−inf+inf)/2 would produce."""
    m = midpoint(iv)
    r = radius(iv)
    bad = ~jnp.isfinite(m)
    return jnp.where(bad, 0.0, m), jnp.where(bad, _INF, r)


def from_ball(m: jax.Array, r: jax.Array) -> Interval:
    lo = _down(m - r)
    hi = _up(m + r)
    # NaN arises only from inf·0 / inf−inf on *unbounded* operand intervals;
    # [-inf, inf] is the sound enclosure then (paper's "bound becomes
    # infinite" convention).
    lo = jnp.where(jnp.isnan(lo), -_INF, lo)
    hi = jnp.where(jnp.isnan(hi), _INF, hi)
    return Interval(lo, hi)


def einsum_ball(subscripts: str, a: Interval, b: Interval) -> Interval:
    """Interval einsum via ball arithmetic: (ma±ra)·(mb±rb).

    |result - ma·mb| ≤ |ma|·rb + ra·|mb| + ra·rb, accumulated through the
    same einsum. Slightly looser than exact interval products but one einsum
    per term — the only practical option at tensor scale, and sound.
    """
    ma, ra = ball(a)
    mb, rb = ball(b)
    mid = jnp.einsum(subscripts, ma, mb)
    rad = (
        jnp.einsum(subscripts, jnp.abs(ma), rb)
        + jnp.einsum(subscripts, ra, jnp.abs(mb))
        + jnp.einsum(subscripts, ra, rb)
    )
    # f64 slop for the einsum itself
    n = max(1, int(jnp.size(ma) // max(1, int(jnp.size(mid)))))
    slop = _gamma_f64(4 * n + 4)
    mag_term = jnp.einsum(subscripts, jnp.abs(ma) + ra, jnp.abs(mb) + rb)
    rad = _up(_up(rad) + slop * mag_term)
    rad = jnp.where(jnp.isnan(rad), _INF, rad)
    mid = jnp.where(jnp.isnan(mid), 0.0, mid)
    return from_ball(mid, rad)


def matmul(a: Interval, b: Interval) -> Interval:
    return einsum_ball("...ij,jk->...ik", a, b)


# --- stable softmax range ----------------------------------------------------

def softmax_range(x: Interval, axis: int = -1) -> Interval:
    """Rigorous enclosure of softmax(x) along ``axis``.

    y_i ∈ [ e^{lo_i} / (e^{lo_i} + Σ_{j≠i} e^{hi_j}),
            e^{hi_i} / (e^{hi_i} + Σ_{j≠i} e^{lo_j}) ]
    computed in a max-shifted frame for stability.
    """
    m = jnp.max(x.hi, axis=axis, keepdims=True)
    elo = exp(shift(Interval(x.lo, x.lo), -m))  # enclosure of e^{lo_i - m}
    ehi = exp(shift(Interval(x.hi, x.hi), -m))  # enclosure of e^{hi_i - m}
    n = x.lo.shape[axis]
    slop = 1.0 + _gamma_f64(n + 4)
    # upper bound of Σ_j e^{hi_j}; lower bound of Σ_j e^{lo_j}
    s_hi_up = jnp.sum(ehi.hi, axis=axis, keepdims=True) * slop
    s_lo_dn = jnp.sum(elo.lo, axis=axis, keepdims=True) / slop
    # y_i lower: num = lower(e^{lo_i}); den = upper(e^{lo_i} + Σ_{j≠i} e^{hi_j})
    #   upper(Σ_{j≠i} e^{hi_j}) = s_hi_up - lower(e^{hi_i})
    denom_lo_i = _up(elo.hi + jnp.maximum(s_hi_up - ehi.lo, 0.0))
    # y_i upper: num = upper(e^{hi_i}); den = lower(e^{hi_i} + Σ_{j≠i} e^{lo_j})
    #   lower(Σ_{j≠i} e^{lo_j}) = s_lo_dn - upper(e^{lo_i})
    denom_hi_i = _down(ehi.lo + jnp.maximum(s_lo_dn - elo.hi, 0.0))
    lo = elo.lo / jnp.maximum(denom_lo_i, jnp.finfo(_F64).tiny)
    hi = ehi.hi / jnp.maximum(denom_hi_i, jnp.finfo(_F64).tiny)
    lo = jnp.clip(_down(lo), 0.0, 1.0)
    hi = jnp.clip(_up(hi), 0.0, 1.0)
    return Interval(lo, hi)


# ---------------------------------------------------------------------------
# affine forms (zonotopes) — the paper's antidote to IA decorrelation
# ---------------------------------------------------------------------------
#
# An AffineForm encloses a tensor of real values as
#
#     v ∈ center + Σ_b terms[b]·ε_b + rad·ε̂,     ε_b, ε̂ ∈ [-1, 1]
#
# where every (slot b, element) pair carries an INDEPENDENT noise symbol
# identified by ids[b] (0 marks an empty slot — its coefficients are zero by
# invariant). Linear ops propagate the terms exactly, so correlated paths
# (residual adds, x - mean(x)) cancel instead of compounding the way plain
# IA does; everything nonlinear and every f64 slop of our own bound
# computation folds into the interval remainder ``rad``. The slot budget is
# fixed (a lax.scan carry must keep one aval), so :func:`aff_condense`
# soundly folds the smallest slots into ``rad`` when ops overflow it.
#
# Symbols are per-element: ids identify *tensors'* rounding/creation events,
# and two forms sharing id b mean their elements' symbols agree elementwise.
# Contractions (matmul/einsum/sum) mix symbols of different elements, which
# no single coefficient can represent — callers collapse terms through
# :func:`aff_tot` there (see repro.core.backend.AffineRangeCaaOps).

#: default noise-symbol slot budget per tensor (the README's noise-budget
#: knob; certify_lm exposes it as format_opts["affine_budget"])
AFF_DEFAULT_BUDGET = 8

#: condensation rankings (format_opts["affine_rank"]): which slots survive
#: when a form overflows its budget. "sensitivity" keeps the symbols with
#: the largest downstream contribution to the output enclosure — the slots
#: holding the largest SHARE of some element's total deviation, whose
#: future cancellations (residual subtractions, normalisations) the form
#: channel still needs; "magnitude" is the legacy total-coefficient-mass
#: order, which over-keeps symbols that are individually large but a tiny
#: fraction of every element they touch. Both are sound: the ranking only
#: picks WHICH dropped slots fold into ``rad``.
AFF_RANK_SENSITIVITY = "sensitivity"
AFF_RANK_MAGNITUDE = "magnitude"
AFF_DEFAULT_RANK = AFF_RANK_SENSITIVITY

_I32 = jnp.int32


class AffineForm(NamedTuple):
    center: jax.Array    # [*S] f64
    terms: jax.Array     # [B, *S] f64 — coefficient of noise symbol ids[b]
    ids: jax.Array       # [B] int32; 0 = empty slot (zero coefficients)
    rad: jax.Array       # [*S] f64 ≥ 0 — interval remainder

    @property
    def shape(self):
        return jnp.shape(self.center)

    @property
    def budget(self) -> int:
        return int(self.terms.shape[0])


def aff_make(center, budget: int = AFF_DEFAULT_BUDGET) -> AffineForm:
    """Point form (exactly-known values; e.g. weights under weights_exact)."""
    c = _f(center)
    return AffineForm(c, jnp.zeros((budget,) + c.shape, _F64),
                      jnp.zeros((budget,), _I32), jnp.zeros(c.shape, _F64))


def aff_from_interval(ivl: Interval, budget: int = AFF_DEFAULT_BUDGET,
                      center=None) -> AffineForm:
    """Terms-free form from an enclosure; ``center`` defaults to the
    midpoint, and may lie anywhere (rad covers both endpoints)."""
    c = midpoint(ivl) if center is None else _f(center)
    r = _up(jnp.maximum(jnp.abs(c - ivl.lo), jnp.abs(ivl.hi - c)))
    r = jnp.where(jnp.isnan(r) | ~jnp.isfinite(ivl.lo) | ~jnp.isfinite(ivl.hi),
                  _INF, r)
    c, r = jnp.broadcast_arrays(c, r)
    return AffineForm(jnp.where(jnp.isfinite(c), c, 0.0),
                      jnp.zeros((budget,) + jnp.shape(c), _F64),
                      jnp.zeros((budget,), _I32), r)


def aff_tot(a: AffineForm) -> jax.Array:
    """Per-element upper bound on the total deviation Σ_b|terms| + rad."""
    B = a.budget
    s = jnp.sum(jnp.abs(a.terms), axis=0) + a.rad
    t = _up(s * (1.0 + _gamma_f64(B + 2)))
    return jnp.where(jnp.isnan(t), _INF, t)


def aff_interval(a: AffineForm) -> Interval:
    """Sound enclosure center ± tot (nan-guarded to [-inf, inf])."""
    t = aff_tot(a)
    lo = _down(a.center - t)
    hi = _up(a.center + t)
    bad = jnp.isnan(lo) | jnp.isnan(hi) | jnp.isnan(a.center)
    return Interval(jnp.where(bad, -_INF, lo), jnp.where(bad, _INF, hi))


def _aff_slop(a: AffineForm, n_ops: int = 4) -> AffineForm:
    """Charge the f64 round-to-nearest error of our OWN bound computation:
    every produced quantity (center, coefficients, rad) comes from a chain
    of ≤ B + n_ops f64 ops on magnitudes bounded by |center| + tot, so
    γ_{B+n}·(|center| + tot) rounded outward covers it (the same blanket
    the IA back-end applies per primitive via _down/_up/γ)."""
    g = _gamma_f64(a.budget + n_ops)
    tot = jnp.sum(jnp.abs(a.terms), axis=0) + a.rad
    rad = _up(a.rad + g * (jnp.abs(a.center) + tot))
    rad = jnp.where(jnp.isnan(rad) | jnp.isnan(a.center), _INF, rad)
    return AffineForm(a.center, a.terms, a.ids, rad)


def aff_condense(a: AffineForm, budget: int,
                 rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    """Fold slots into ``rad`` until ≤ ``budget`` remain.

    ``rank`` picks the survivors (empty slots always rank last):

    * :data:`AFF_RANK_SENSITIVITY` — keep the slots carrying the largest
      share of some element's total deviation. A symbol dominating an
      element's enclosure is the one whose downstream cancellation the
      form channel still needs (folding it moves that whole element's
      deviation into the uncancellable rad); one that is a small fraction
      everywhere loses almost nothing by folding, however large its raw
      mass. A mass tiebreak keeps the order total among non-dominant slots.
    * :data:`AFF_RANK_MAGNITUDE` — legacy total coefficient mass.

    Either way the dropped mass enters rad via the triangle inequality —
    a pure widening, hence sound under every ranking."""
    if rank not in (AFF_RANK_SENSITIVITY, AFF_RANK_MAGNITUDE):
        raise ValueError(f"unknown affine condensation rank {rank!r}")
    B = a.budget
    if B <= budget:
        return a
    # B and budget are static Python ints (the slot axis is a static shape),
    # so counting drops is trace-safe; lazy import keeps core free of an
    # obs dependency at module load, and both calls are no-ops untraced
    from repro import obs
    obs.counter("affine.condense_calls")
    obs.counter("affine.condense_drops", B - budget)
    tr = obs.get_tracer()
    if tr is not None:
        obs.gauge("affine.condense_drops",
                  tr.counters.get("affine.condense_drops", 0))
    red = tuple(range(1, a.terms.ndim))
    mass = jnp.abs(a.terms)
    sums = jnp.sum(mass, axis=red)
    if rank == AFF_RANK_MAGNITUDE:
        norms = sums
    else:
        # share of each element's total deviation held by each slot; a
        # saturated element (tot = inf) contributes share 0 for finite
        # coefficients while an infinite coefficient keeps share 1 (it IS
        # that element's enclosure)
        tot = jnp.sum(mass, axis=0) + a.rad
        denom = jnp.where((tot > 0.0) & jnp.isfinite(tot), tot, _INF)
        share = jnp.where(jnp.isfinite(mass), mass / denom, 1.0)
        peak = jnp.max(jnp.reshape(share, (B, -1)), axis=1)
        msum = jnp.max(jnp.where(jnp.isfinite(sums), sums, 0.0))
        msum = jnp.where(msum > 0.0, msum, 1.0)
        tie = jnp.where(jnp.isfinite(sums), sums, msum) / msum
        norms = peak + 1e-3 * tie
    norms = jnp.where(a.ids == 0, -1.0, norms)
    order = jnp.argsort(-norms)
    keep, drop = order[:budget], order[budget:]
    kept_t = jnp.take(a.terms, keep, axis=0)
    kept_i = jnp.take(a.ids, keep)
    dropped = jnp.abs(jnp.take(a.terms, drop, axis=0))
    extra = jnp.sum(dropped, axis=0) * (1.0 + _gamma_f64(B - budget + 2))
    rad = _up(a.rad + extra)
    rad = jnp.where(jnp.isnan(rad), _INF, rad)
    return AffineForm(a.center, kept_t, kept_i, rad)


def aff_append_symbol(a: AffineForm, coeff, sym_id, budget: int,
                      rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    """Add a FRESH independent per-element unknown of half-width ``coeff``
    (≥ 0) — the shape a rounding error charge takes. ``sym_id`` may be a
    traced i32 scalar (the scan-carried symbol counter)."""
    c = jnp.broadcast_to(_up(_f(coeff)), a.shape)
    t = jnp.concatenate([a.terms, c[None]], axis=0)
    i = jnp.concatenate([a.ids, jnp.reshape(jnp.asarray(sym_id, _I32), (1,))])
    return aff_condense(AffineForm(a.center, t, i, a.rad), budget, rank)


def _aff_broadcast(a: AffineForm, shape) -> AffineForm:
    B = a.budget
    shape = tuple(shape)
    t = a.terms
    el = t.shape[1:]
    if len(el) < len(shape):
        # grow the element rank behind the slot dim before broadcasting
        t = jnp.reshape(t, (B,) + (1,) * (len(shape) - len(el)) + tuple(el))
    return AffineForm(
        jnp.broadcast_to(a.center, shape),
        jnp.broadcast_to(t, (B,) + shape),
        a.ids, jnp.broadcast_to(a.rad, shape))


def _aff_common(a: AffineForm, b: AffineForm):
    """Rewrite both forms over one shared id layout [Ba+Bb].

    ids are unique per form (creation is a strictly increasing counter and
    merges preserve uniqueness), so the match matrix has at most one hit
    per row/column and matched coefficients move with ONE addition."""
    eq = (a.ids[:, None] == b.ids[None, :]) & (a.ids[:, None] != 0)
    matched = eq.any(axis=0)                              # [Bb]
    b_on_a = jnp.tensordot(eq.astype(_F64), b.terms, axes=(1, 0))
    mshape = (b.ids.shape[0],) + (1,) * (b.terms.ndim - 1)
    b_un = jnp.where(matched.reshape(mshape), 0.0, b.terms)
    ids = jnp.concatenate([a.ids, jnp.where(matched, 0, b.ids)])
    ta = jnp.concatenate([a.terms, jnp.zeros_like(b_un)], axis=0)
    tb = jnp.concatenate([b_on_a, b_un], axis=0)
    return ids, ta, tb


def _aff_linear(a: AffineForm, b: AffineForm, ca, cb, budget: int,
                rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    """ca·a + cb·b for exact per-element multipliers ca/cb (the one affine
    combinator: add, sub and where-blends route through it)."""
    shape = jnp.broadcast_shapes(jnp.shape(a.center), jnp.shape(b.center),
                                 jnp.shape(_f(ca)), jnp.shape(_f(cb)))
    a, b = _aff_broadcast(a, shape), _aff_broadcast(b, shape)
    ca, cb = _f(ca), _f(cb)
    ids, ta, tb = _aff_common(a, b)
    center = ca * a.center + cb * b.center
    terms = ca * ta + cb * tb
    rad = jnp.abs(ca) * a.rad + jnp.abs(cb) * b.rad
    out = _aff_slop(AffineForm(center, terms, ids, rad), n_ops=6)
    return aff_condense(out, budget, rank)


def aff_add(a: AffineForm, b: AffineForm, budget: int,
            rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    return _aff_linear(a, b, 1.0, 1.0, budget, rank)


def aff_sub(a: AffineForm, b: AffineForm, budget: int,
            rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    return _aff_linear(a, b, 1.0, -1.0, budget, rank)


def aff_neg(a: AffineForm) -> AffineForm:
    return AffineForm(-a.center, -a.terms, a.ids, a.rad)


def aff_scale(a: AffineForm, c) -> AffineForm:
    """Multiply by an exact constant (scalar or array)."""
    c = _f(c)
    shape = jnp.broadcast_shapes(jnp.shape(a.center), jnp.shape(c))
    a = _aff_broadcast(a, shape)
    out = AffineForm(a.center * c, a.terms * c, a.ids, a.rad * jnp.abs(c))
    return _aff_slop(out, n_ops=4)


def aff_shift(a: AffineForm, c) -> AffineForm:
    c = _f(c)
    shape = jnp.broadcast_shapes(jnp.shape(a.center), jnp.shape(c))
    a = _aff_broadcast(a, shape)
    return _aff_slop(AffineForm(a.center + c, a.terms, a.ids, a.rad),
                     n_ops=4)


def aff_mul(a: AffineForm, b: AffineForm, budget: int,
            rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    """Bilinear product: linear parts keep their symbols, the quadratic
    cross term (deviation × deviation) and each center × remainder term
    fold into rad."""
    shape = jnp.broadcast_shapes(jnp.shape(a.center), jnp.shape(b.center))
    a, b = _aff_broadcast(a, shape), _aff_broadcast(b, shape)
    ta_tot, tb_tot = aff_tot(a), aff_tot(b)
    ids, ta, tb = _aff_common(a, b)
    center = a.center * b.center
    terms = b.center * ta + a.center * tb
    rad = (jnp.abs(a.center) * b.rad + jnp.abs(b.center) * a.rad
           + ta_tot * tb_tot)
    out = _aff_slop(AffineForm(center, terms, ids, rad), n_ops=8)
    return aff_condense(out, budget, rank)


def aff_where(mask, a: AffineForm, b: AffineForm, budget: int,
              rank: str = AFF_DEFAULT_RANK) -> AffineForm:
    """Element-wise select — exact (comparisons don't round). The common
    id layout keeps each element's coefficients attached to its own
    symbols."""
    m = jnp.asarray(mask)
    shape = jnp.broadcast_shapes(jnp.shape(a.center), jnp.shape(b.center),
                                 jnp.shape(m))
    a, b = _aff_broadcast(a, shape), _aff_broadcast(b, shape)
    ids, ta, tb = _aff_common(a, b)
    out = AffineForm(jnp.where(m, a.center, b.center),
                     jnp.where(m[None], ta, tb),
                     ids, jnp.where(m, a.rad, b.rad))
    return aff_condense(out, budget, rank)


def aff_intersect(a: AffineForm, ivl: Interval) -> AffineForm:
    """Intersect with an externally-proven bound (clamp_range): keep the
    center (it is the reference value) and terms only when the affine
    enclosure was already at least as tight; otherwise recenter on the
    intersection. Never empty (a wrong external bound keeps the original —
    mirroring caa.clamp_exact's guard)."""
    own = aff_interval(a)
    lo = jnp.maximum(own.lo, ivl.lo)
    hi = jnp.minimum(own.hi, ivl.hi)
    bad = lo > hi
    lo = jnp.where(bad, own.lo, lo)
    hi = jnp.where(bad, own.hi, hi)
    tighter = (lo <= own.lo) & (own.hi <= hi)
    rec = aff_from_interval(Interval(lo, hi), a.budget, center=a.center)
    keep = jnp.broadcast_to(tighter, a.shape)
    return AffineForm(a.center,
                      jnp.where(keep[None], a.terms, rec.terms),
                      a.ids, jnp.where(keep, a.rad, rec.rad))
