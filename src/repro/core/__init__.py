"""Core: the paper's contribution — CAA+IA rigorous FP error analysis.

Public surface:
  interval   — vectorised rigorous interval arithmetic (MPFI replacement)
  caa        — CaaTensor + per-op combined abs/rel error propagation rules
  backend    — Backend protocol; JOps (runtime) / CaaOps (analysis)
  analyze    — analysis driver: ErrorReport, sensitivity, mixed precision
  formats    — FP format zoo parameterised by precision k (u = 2^{1-k})
  quantize   — k-bit-mantissa RNE emulation (empirical oracle + low-precision
               inference path)
  precision  — p* margins → required precision k (Section IV end-game)
  theory     — the paper's closed-form constants, kept verbatim for tests
"""
from . import analyze, backend, caa, formats, interval, precision, quantize, theory
from .analyze import ErrorReport, analyze as run_analysis
from .backend import Backend, CaaOps, JOps
from .caa import CaaConfig, CaaTensor
from .formats import FpFormat, get as get_format
from .interval import Interval

__all__ = [
    "analyze", "backend", "caa", "formats", "interval", "precision",
    "quantize", "theory", "ErrorReport", "run_analysis", "Backend",
    "CaaOps", "JOps", "CaaConfig", "CaaTensor", "FpFormat", "get_format",
    "Interval",
]
