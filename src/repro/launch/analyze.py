"""CLI: rigorous precision analysis of any registered architecture.

The paper's semi-automatic workflow as a command:

  PYTHONPATH=src python -m repro.launch.analyze --arch qwen2_7b --k 12
  PYTHONPATH=src python -m repro.launch.analyze --arch mixtral_8x22b \\
      --k 10 --seq 16 --routers

Runs the reduced (smoke) configuration of the arch under CaaOps with the
target-format emulation, and reports: per-layer trace, the rigorous actual
error of the emulated run, router decision margins (MoE), and — for the
paper's classifier models — the required-k decision at a given p*.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import caa
from repro.core.backend import CaaOps
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--k", type=int, default=12,
                    help="emulated mantissa bits (u = 2^{1-k})")
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--routers", action="store_true",
                    help="print MoE router flip-safety records")
    ap.add_argument("--trace", type=int, default=8,
                    help="how many trace records to print")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch).SMOKE
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ccfg = caa.CaaConfig(u_max=2.0 ** (1 - args.k), emulate_k=args.k)
    bk = CaaOps(ccfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.seq), 0, cfg.vocab)
    kwargs = {}
    rng = np.random.RandomState(0)
    if cfg.frontend == "audio":
        kwargs["enc_embeds"] = rng.randn(
            args.batch, cfg.frontend_seq, cfg.frontend_dim).astype(np.float32)
    elif cfg.frontend == "vision":
        kwargs["frontend_embeds"] = rng.randn(
            args.batch, cfg.frontend_seq, cfg.frontend_dim).astype(np.float32)

    logits, _ = T.forward(bk, params, cfg, tokens, **kwargs)
    a_abs, a_rel = caa.actual_error_in_u(logits, ccfg.u_max)
    d, e = caa.worst(logits)

    print(f"=== {args.arch} (reduced config) — emulated k={args.k}, "
          f"u = 2^{1 - args.k} ===")
    print(f"logits: certified actual |error| ≤ {float(jnp.max(a_abs)):.4g} u")
    fin = jnp.where(jnp.isfinite(a_rel), a_rel, 0.0)
    print(f"        top-anything relative     ≤ {float(jnp.max(fin)):.4g} u "
          f"(where finite)")
    print(f"parametric bounds (units of u): δ̄ = {d:.4g}, ε̄ = {e:.4g} "
          f"{'(saturated — use the per-run mode above)' if not np.isfinite(d) else ''}")
    print(f"\nper-layer trace ({len(bk.trace)} records, first {args.trace}):")
    for r in bk.trace[: args.trace]:
        print(f"  {r.name:30s} {r.kind:8s} |range|≤{r.out_mag:9.3g}")
    if args.routers:
        routers = [r for r in bk.trace if r.kind == "router"]
        print(f"\nrouter records ({len(routers)}):")
        for r in routers:
            print(f"  {r.name}: min margin {r.extra['min_margin']:.4f}, "
                  f"flip-safe for u ≤ {r.extra['flip_safe_if_u_le']:.3g}")


if __name__ == "__main__":
    main()
