import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: prove the distribution config is coherent.

For every assigned (architecture × input-shape) cell, on the single-pod
(16×16 = data×model) and multi-pod (2×16×16 = pod×data×model) production
meshes:

    jax.jit(step, in_shardings=…, out_shardings=…).lower(**input_specs)
        .compile()

must succeed — sharding mismatches, OOM-at-compile or unsupported
collectives are bugs. We record per cell: memory analysis (bytes/device),
cost analysis (FLOPs/bytes), and the collective-op byte census parsed from
the optimized HLO — the three §Roofline terms derive from these
(benchmarks/roofline.py).

NOTE the XLA_FLAGS line above MUST run before any jax import: jax locks the
device count at first initialisation. Do not move it; do not set that flag
globally (tests/benches must see the real single device).
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import SHAPES, ShapeSpec, skip_reason
from repro.launch import mesh as meshlib
from repro.launch.serve import ServeConfig, build_serve_steps
from repro.launch.train import TrainConfig, build_train_step
from repro.models import transformer as T
from repro.optim import optimizer as opt
from repro.parallel import sharding as sh

BF16 = jnp.bfloat16
F32 = jnp.float32
I32 = jnp.int32

# archs large enough to need 8-bit Adam moments to fit HBM
_BIG = {"mixtral_8x22b", "llama4_maverick", "gemma2_27b", "command_r_35b"}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _with_dtype(tree, dtype):
    return jax.tree_util.tree_map(
        lambda s: _sds(s.shape, dtype) if jnp.issubdtype(s.dtype, jnp.floating)
        else s, tree)


def effective_shape(cfg, shape: ShapeSpec) -> ShapeSpec:
    """Architectural caps: whisper's decoder context is 448 (its prefill/
    decode cells run at the cap — documented reinterpretation)."""
    if cfg.enc_dec and shape.kind in ("prefill", "decode"):
        seq = min(shape.seq, cfg.max_decode_seq)
        return ShapeSpec(shape.name, shape.kind, seq, shape.batch)
    if cfg.enc_dec and shape.kind == "train":
        return ShapeSpec(shape.name, shape.kind, min(shape.seq, cfg.max_decode_seq),
                         shape.batch)
    return shape


def input_specs(arch: str, shape_name: str, *, cache_dtype=BF16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell —
    weak-type-correct, shardable, no device allocation."""
    cfg = configs.get(arch).FULL
    shape = effective_shape(cfg, SHAPES[shape_name])
    B, S = shape.batch, shape.seq
    out: Dict[str, Any] = {"kind": shape.kind}
    # vision prefixes occupy cache slots: size the KV buffers accordingly
    cache_len = S + (cfg.frontend_seq if cfg.frontend == "vision" else 0)
    if shape.kind == "train":
        batch = {"tokens": _sds((B, S), I32), "targets": _sds((B, S), I32)}
        if cfg.frontend == "audio":
            batch["frontend"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim), BF16)
        elif cfg.frontend == "vision":
            batch["frontend"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim), BF16)
        out["batch"] = batch
    else:
        cache = jax.eval_shape(lambda: T.init_cache(cfg, B, cache_len, cache_dtype))
        out["cache"] = cache
        if shape.kind == "prefill":
            batch = {"tokens": _sds((B, S), I32)}
            if cfg.frontend == "audio":
                batch["frontend"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim), BF16)
        else:
            batch = {"tokens": _sds((B, 1), I32), "pos": _sds((), I32)}
            if cfg.frontend == "audio":
                # decode reuses the prefill-computed encoder states
                batch["enc_out"] = _sds((B, cfg.frontend_seq, cfg.d_model), BF16)
        if cfg.frontend == "vision" and shape.kind == "prefill":
            batch["frontend"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim), BF16)
        out["batch"] = batch
    return out


# ---------------------------------------------------------------------------
# collective census from optimized HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}
_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_census(hlo_text: str) -> Dict[str, Any]:
    per_kind: Dict[str, int] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_part, dt, dims, kind = m.group(1), m.group(2), m.group(3), m.group(4)
        if tuple_part is not None:
            bytes_ = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            bytes_ = _shape_bytes(dt, dims)
        per_kind[kind] = per_kind.get(kind, 0) + bytes_
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                keep_hlo: bool = False,
                serve_policy: Optional[Dict[str, Any]] = None,
                train_policy: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    cfg = configs.get(arch).FULL
    shape = effective_shape(cfg, SHAPES[shape_name])
    reason = skip_reason(cfg, SHAPES[shape_name])
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    specs = input_specs(arch, shape_name)

    with mesh:
        if specs["kind"] == "train":
            tpol = train_policy or {}
            tc = TrainConfig(arch=arch, seq=shape.seq,
                             global_batch=shape.batch,
                             compute_dtype="bfloat16", remat=True,
                             quantized_moments=arch in _BIG,
                             param_sharding=tpol.get("param_sharding", "fsdp"),  # baseline sweep stays paper-faithful
                             grad_compression=tpol.get("grad_compression", False))
            step, _, shardings = build_train_step(cfg, tc, mesh)
            state_shapes = jax.eval_shape(
                lambda: _train_state_shapes(cfg, tc))
            state_shapes = {
                "params": _with_dtype(state_shapes["params"], BF16),
                "opt": state_shapes["opt"],
                "ef": state_shapes["ef"],
            }
            lowered = step.lower(state_shapes, specs["batch"])
        else:
            pol = serve_policy or {}
            sc = ServeConfig(arch=arch, batch=shape.batch, max_seq=shape.seq,
                             prefill_len=shape.seq,
                             compute_dtype="bfloat16",
                             cache_dtype=pol.get("cache_dtype", "bfloat16"),
                             param_dtype=pol.get("param_dtype", "same"),
                             params_resident=pol.get("params_resident", False))
            prefill, decode, shardings = build_serve_steps(cfg, sc, mesh)
            pshapes = jax.eval_shape(
                lambda: T.init_params(jax.random.PRNGKey(0), cfg))
            pdt = jnp.float8_e4m3fn if pol.get("param_dtype") == "fp8" else BF16
            pshapes = _with_dtype(pshapes, pdt)
            cache_specs = specs["cache"]
            if pol.get("cache_dtype") == "fp8":
                cache_specs = jax.tree_util.tree_map(
                    lambda s: _sds(s.shape, jnp.float8_e4m3fn)
                    if jnp.issubdtype(s.dtype, jnp.floating) else s,
                    cache_specs)
            fn = prefill if specs["kind"] == "prefill" else decode
            lowered = fn.lower(pshapes, cache_specs, specs["batch"])

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    census = collective_census(hlo)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "effective_seq": shape.seq,
        "effective_batch": shape.batch,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost": {
            "flops": cost.get("flops"),
            "transcendentals": cost.get("transcendentals"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "collectives": census,
    }
    if keep_hlo:
        rec["hlo_len"] = len(hlo)
    return rec


def _train_state_shapes(cfg, tc: TrainConfig):
    adam_cfg = opt.AdamWConfig(quantized_moments=tc.quantized_moments,
                               total_steps=tc.steps)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params, adam_cfg)
    from repro.optim import grad_compress as gc
    ef = gc.init_ef(params) if tc.grad_compression else None
    return {"params": params, "opt": state, "ef": ef}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true", help="ignore cache")
    ap.add_argument("--serve-policy", default=None,
                    help='JSON, e.g. \'{"params_resident": true, "param_dtype": "fp8"}\'')
    ap.add_argument("--train-policy", default=None,
                    help='JSON, e.g. \'{"param_sharding": "tp"}\'')
    args = ap.parse_args(argv)
    serve_policy = json.loads(args.serve_policy) if args.serve_policy else None
    train_policy = json.loads(args.train_policy) if args.train_policy else None

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        rec = json.load(f)
                    print(f"[cached] {tag}: {rec['status']}")
                    results.append(rec)
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = dryrun_cell(arch, shape, multi_pod=mp,
                                      serve_policy=serve_policy,
                                      train_policy=train_policy)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                ok = rec["status"]
                extra = ""
                if ok == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"flops={rec['cost']['flops']:.3g} "
                             f"coll={rec['collectives']['total_bytes']:.3g}B "
                             f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB")
                elif ok == "error":
                    extra = " " + rec["error"][:200]
                print(f"[done]   {tag}: {ok}{extra}", flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors ===")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
