"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS *before* any jax initialisation, while tests/benches
must see the real single device.

  single-pod: (16, 16)      axes ("data", "model")   — 256 chips (v5e pod)
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") — 512 chips;
              the "pod" axis is pure data parallelism whose gradient
              all-reduce crosses the DCN (slow links) — kept outermost so
              XLA's hierarchical collectives do ICI reduce-scatter first.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1×1 mesh on the real local device — smoke tests of the pjit path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(data: int = None, model: int = None, *,
                      devices=None):
    """A (data, model) mesh over the available devices — the serving mesh.

    On CI this is the forced-host path: run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and jax exposes
    N CPU "devices", so the full NamedSharding/SPMD machinery (param
    layouts, activation constraints, collective insertion) compiles and
    executes exactly as it would on a real slice. With both factors None
    the whole device set goes to "data" (pure lane parallelism — the
    bitwise-safe default for continuous batching: every collective is a
    gather/slice, never a split reduction). ``devices`` restricts to a
    subset (the benchmark's mesh-size sweep takes prefixes of
    ``jax.devices()``).
    """
    import numpy as np
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs)
    if data is None and model is None:
        data, model = n, 1
    elif data is None:
        assert n % model == 0, (n, model)
        data = n // model
    elif model is None:
        assert n % data == 0, (n, data)
        model = n // data
    assert data * model <= n, (data, model, n)
    grid = np.array(devs[: data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(grid, ("data", "model"))


def device_count() -> int:
    return len(jax.devices())


def data_axes(mesh) -> tuple:
    """All axes that carry pure data parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
