"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS *before* any jax initialisation, while tests/benches
must see the real single device.

  single-pod: (16, 16)      axes ("data", "model")   — 256 chips (v5e pod)
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") — 512 chips;
              the "pod" axis is pure data parallelism whose gradient
              all-reduce crosses the DCN (slow links) — kept outermost so
              XLA's hierarchical collectives do ICI reduce-scatter first.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1×1 mesh on the real local device — smoke tests of the pjit path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """All axes that carry pure data parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
