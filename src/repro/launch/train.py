"""Distributed training step builder + driver.

``build_train_step(cfg, mesh, ...)`` returns a jitted SPMD step:
  params/opt-state fully sharded (parallel.sharding greedy FSDP×TP×EP),
  batch over the DP axes, per-layer remat under the layer scan,
  optional int8-EF gradient compression and 8-bit Adam moments.

The driver (main) wires data pipeline → step → checkpointing → fault
tolerance and runs a real (small) training job on the local device — the
same code lowers to the 512-chip production mesh in launch.dryrun.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.backend import JOps
from repro.data import pipeline
from repro.models import transformer as T
from repro.optim import optimizer as opt
from repro.optim import grad_compress as gc
from repro.parallel import sharding as sh
from repro.launch import mesh as meshlib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    arch: str = "qwen2_7b"
    smoke: bool = True
    seq: int = 128
    global_batch: int = 8
    steps: int = 50
    compute_dtype: str = "float32"     # bf16 on TPU
    remat: bool = True
    quantized_moments: bool = False
    grad_compression: bool = False
    # "fsdp": greedy ZeRO-3 sharding of params over model+data (needed for
    # 400B-class and MoE); "tp": params model-axis-resident (≤35B dense —
    # avoids data-axis parameter gathers and SPMD resharding churn);
    # "auto": per-arch policy matrix from §Perf
    param_sharding: str = "auto"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 20
    seed: int = 0


class RematJOps(JOps):
    """JOps whose layer loop checkpoints each layer (full remat).

    The rematerialised residual carry is constrained to be model-axis
    sharded on its feature dim (Megatron sequence-parallel style): the
    per-layer saved activation shrinks 16× — without this, 40-plus-layer
    train cells blow HBM on saved residuals alone (§Perf)."""

    def _residual_constraint(self, x):
        mesh = self.mesh
        if mesh is None or x.ndim != 3:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        d = x.shape[-1]
        m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        if m > 1 and d % m == 0:
            spec = P(dp or None, None, "model")
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return x

    def shard_hint(self, a, kind: str):
        """Sequence-parallel attention: shard the query sequence over the
        'model' axis so the [B,H,S,S] score tensor shards 16× even when the
        KV-head count doesn't divide the axis (kv=8 archs replicate it
        otherwise — the dominant train-cell temp, §Perf)."""
        mesh = self.mesh
        if mesh is None or kind != "q_seq" or a.ndim < 3:
            return a
        from jax.sharding import NamedSharding, PartitionSpec as P
        m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        S = a.shape[1]
        if m > 1 and S % m == 0:
            dp = tuple(ax for ax in ("pod", "data") if ax in mesh.axis_names)
            spec = P(dp or None, "model", *([None] * (a.ndim - 2)))
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, spec))
        return a

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        def fn_constrained(p, carry, i, a):
            new_x, aux_out = fn(p, carry, i, a)
            return self._residual_constraint(new_x), aux_out

        fn_r = jax.checkpoint(fn_constrained, static_argnums=())

        def body(carry, xs):
            p, i, a = xs
            new_x, aux_out = fn_r(p, carry, i, a)
            return new_x, aux_out
        idx = jnp.arange(n_layers)
        x = self._residual_constraint(x)
        out, aux_outs = jax.lax.scan(body, x, (stacked_params, idx, aux))
        return out, aux_outs


def _backend(tc: TrainConfig, remat: Optional[bool] = None, mesh=None):
    dt = jnp.bfloat16 if tc.compute_dtype == "bfloat16" else jnp.float32
    cls = RematJOps if (tc.remat if remat is None else remat) else JOps
    return cls(dt, jnp.float32, mesh=mesh)


def make_loss_fn(arch_cfg, tc: TrainConfig, frontend_shapes=None, mesh=None):
    bk = _backend(tc, mesh=mesh)

    def loss_fn(params, batch):
        kwargs = {}
        if arch_cfg.frontend == "audio":
            kwargs["enc_embeds"] = batch["frontend"]
        elif arch_cfg.frontend == "vision":
            kwargs["frontend_embeds"] = batch["frontend"]
        return T.next_token_loss(bk, params, arch_cfg, batch["tokens"],
                                 batch["targets"], **kwargs)

    return loss_fn


def build_train_step(arch_cfg, tc: TrainConfig, mesh, adam_cfg=None):
    """Returns (step_fn, init_fn, shardings dict). step_fn is jitted with
    explicit in/out shardings — the same object the dry-run lowers."""
    adam_cfg = adam_cfg or opt.AdamWConfig(
        quantized_moments=tc.quantized_moments, total_steps=tc.steps)
    loss_fn = make_loss_fn(arch_cfg, tc, mesh=mesh)

    def init_fn(key):
        params = T.init_params(key, arch_cfg)
        state = opt.init(params, adam_cfg)
        ef = gc.init_ef(params) if tc.grad_compression else None
        return {"params": params, "opt": state, "ef": ef}

    def step_fn(train_state, batch):
        params = train_state["params"]
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if tc.grad_compression:
            grads, new_ef = gc.compress_tree(grads, train_state["ef"])
        else:
            new_ef = None
        new_params, new_opt = opt.update(params, grads, train_state["opt"],
                                         adam_cfg)
        return {"params": new_params, "opt": new_opt, "ef": new_ef}, loss

    # shardings
    key = jax.random.PRNGKey(tc.seed)
    pshapes = jax.eval_shape(lambda: T.init_params(key, arch_cfg))
    mode = tc.param_sharding
    if mode == "auto":  # §Perf policy matrix
        dense_small = (arch_cfg.family != "moe"
                       and T.analytic_params(arch_cfg) <= 40e9)
        mode = "tp" if dense_small else "fsdp"
    p_sh = sh.shard_params(pshapes, mesh, model_only=(mode == "tp"))

    def state_shardings():
        opt_shapes = jax.eval_shape(
            lambda: opt.init(jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), pshapes), adam_cfg))
        o_sh = _opt_shardings(opt_shapes, p_sh, mesh)
        ef_sh = p_sh if tc.grad_compression else None
        return {"params": p_sh, "opt": o_sh, "ef": ef_sh}

    st_sh = state_shardings()
    b_sh = {
        "tokens": sh.shard_batch(mesh, tc.global_batch, tc.seq),
        "targets": sh.shard_batch(mesh, tc.global_batch, tc.seq),
    }
    if arch_cfg.frontend:
        b_sh["frontend"] = NamedSharding(
            mesh, sh.batch_spec(mesh, tc.global_batch, arch_cfg.frontend_seq))

    jitted = jax.jit(step_fn,
                     in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
    return jitted, init_fn, {"state": st_sh, "batch": b_sh}


def _opt_shardings(opt_shapes, p_sh, mesh):
    """Moments inherit the param shardings when shapes match (ZeRO);
    quantised payloads/scales ([blocks, block]-shaped) get the same greedy
    fully-sharded rule as parameters; scalars replicate."""
    rep = NamedSharding(mesh, P())

    def for_tree(ms, like_params: bool):
        def one(path, m_leaf):
            if like_params:
                ref = p_sh
                for p in path:
                    key = getattr(p, "key", getattr(p, "idx", None))
                    ref = ref[key] if isinstance(ref, (dict, list)) else ref
                if isinstance(ref, NamedSharding) and len(ref.spec) == len(m_leaf.shape):
                    return ref
            spec = sh._greedy_param_spec(m_leaf.shape, mesh, stacked=False)
            return NamedSharding(mesh, spec)
        return jax.tree_util.tree_map_with_path(one, ms)

    quant = opt_shapes.m_scale is not None
    return opt.OptState(
        step=rep,
        m=for_tree(opt_shapes.m, like_params=not quant),
        v=for_tree(opt_shapes.v, like_params=not quant),
        m_scale=None if not quant else for_tree(opt_shapes.m_scale, False),
        v_scale=None if not quant else for_tree(opt_shapes.v_scale, False),
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--quantized-moments", action="store_true")
    args = ap.parse_args(argv)

    arch_cfg = configs.get(args.arch).SMOKE
    tc = TrainConfig(arch=args.arch, seq=args.seq,
                     global_batch=args.global_batch, steps=args.steps,
                     grad_compression=args.grad_compression,
                     quantized_moments=args.quantized_moments,
                     checkpoint_dir=args.checkpoint_dir)
    mesh = meshlib.make_host_mesh()
    dc = pipeline.DataConfig(vocab=arch_cfg.vocab, seq=tc.seq,
                             global_batch=tc.global_batch)

    with mesh:
        step_fn, init_fn, _ = build_train_step(arch_cfg, tc, mesh)
        state = init_fn(jax.random.PRNGKey(tc.seed))
        ck = None
        if tc.checkpoint_dir:
            from repro.checkpoint.checkpointing import Checkpointer
            ck = Checkpointer(tc.checkpoint_dir)
        t0 = time.perf_counter()
        for step in range(tc.steps):
            batch = pipeline.batch_at(dc, step)
            if arch_cfg.frontend:
                import numpy as np
                rng = np.random.RandomState(step)
                batch["frontend"] = rng.randn(
                    tc.global_batch, arch_cfg.frontend_seq,
                    arch_cfg.frontend_dim).astype("float32")
            state, loss = step_fn(state, batch)
            if step % 10 == 0 or step == tc.steps - 1:
                print(f"step {step:5d}  loss {float(loss):.4f}  "
                      f"({time.perf_counter()-t0:.1f}s)")
            if ck and step and step % tc.checkpoint_every == 0:
                ck.save(step, state, blocking=False)
        if ck:
            ck.wait()


if __name__ == "__main__":
    main()
