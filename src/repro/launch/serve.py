"""Serving: prefill + decode step builders (the inference shape families).

``build_serve_steps`` returns jitted SPMD (prefill_fn, decode_fn) over the
production mesh with cache shardings from parallel.sharding (KV-heads or
KV-sequence over "model" — the latter makes XLA build the distributed-
softmax flash pattern).

Includes the certified low-precision mode: with ``precision_k`` set, all
matmul-heavy blocks run through the emulated k-bit path (matching what the
CAA analysis certified) — on real low-precision silicon this is where the
speedup cashes in; here it demonstrates the bit-exact pipeline.

With ``--certificates STORE_DIR`` the flag becomes certificate-driven:
``precision_k`` is read from the persisted certificate set for (arch,
exact params) in the :mod:`repro.certify` store — certifying on first use,
loading thereafter — and every response carries the certificate's
(δ̄, ε̄, k) error bars.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, obs
from repro.core.backend import JOps, UnrolledLayerLoop  # noqa: F401 — the
# unrolled mixin is re-exported here as the serving-side differential
# baseline (compose it in front of a scanned backend; see tests/examples)
from repro.models import transformer as T
from repro.parallel import sharding as sh
from repro.launch import mesh as meshlib

log = obs.get_logger("serve")


def _emit_health(bk, out, k, emax=127, emin=-126):
    """Stream per-scope numeric-health stats to the backend's attached
    :class:`repro.obs.ViolationMonitor` (if any) via ``jax.debug.callback``.

    The stats ride alongside the jitted computation as a side effect — the
    returned serving values are untouched bitwise, and with no monitor
    attached (the default) nothing is staged at all, so the certified
    serving differentials are exactly what they were without observability.
    ``k``/``emax``/``emin`` may be traced scalars (the scanned per-layer
    paths)."""
    mon = getattr(bk, "monitor", None)
    if mon is None:
        return
    from repro.core.quantize import numeric_health
    stats = numeric_health(out, k, emax, emin)
    path = list(bk.scope_path)

    def _cb(max_abs, min_nonzero, n_over, n_under, n_nonfinite):
        mon.observe_scope(path, {
            "max_abs": float(max_abs), "min_nonzero": float(min_nonzero),
            "n_over": int(n_over), "n_under": int(n_under),
            "n_nonfinite": int(n_nonfinite)})

    jax.debug.callback(_cb, stats["max_abs"], stats["min_nonzero"],
                       stats["n_over"], stats["n_under"],
                       stats["n_nonfinite"])


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    arch: str = "qwen2_7b"
    batch: int = 8
    max_seq: int = 256
    prefill_len: int = 128
    compute_dtype: str = "float32"
    cache_dtype: str = "float32"     # bf16 on TPU; 'fp8' = certified 8-bit
    param_dtype: str = "same"        # 'fp8' = certified 8-bit storage
    precision_k: Optional[int] = None
    # Per-layer mixed-precision map {layer_scope: k} from a v2 certificate:
    # matmuls inside a mapped scope run at that scope's k, everything else at
    # precision_k. Requires precision_k as the default/fallback.
    precision_layer_k: Optional[Dict[str, int]] = None
    # Per-scope FULL-format map {layer_scope: FpFormat descriptor} from a
    # schema-v3 certificate: matmuls inside a mapped scope run in that
    # scope's custom (k, emax, emin) format (saturating clamp + subnormal
    # emulation); the "" entry is the default for unmapped scopes. Takes
    # precedence over precision_layer_k / precision_k.
    precision_layer_format: Optional[Dict[str, Dict]] = None
    # Certificate-driven precision: path of a repro.certify store; when set,
    # precision_k is taken from the stored CertificateSet for (arch, params)
    # (and precision_layer_k from its mixed map, when certified) and
    # responses carry (δ̄, ε̄, k) error bars.
    certificates: Optional[str] = None
    # §Perf policy matrix: keep params resident on the model axis (no
    # data-axis gathers) — the right call for decode with ≤~70B params.
    # None → auto by param count; False reproduces the greedy-FSDP baseline.
    params_resident: Optional[bool] = None


class QuantJOps(JOps):
    """JOps whose matmuls run in the certified k-bit emulation.

    ``monitor`` (a :class:`repro.obs.ViolationMonitor`, default None)
    receives per-scope numeric-health stats of every matmul product —
    attached by :func:`_backend` when the CLI asked for violation
    monitoring; None stages nothing."""

    monitor = None

    def __init__(self, k: int, *a, **kw):
        super().__init__(*a, **kw)
        self._k = k

    def matmul(self, a, b):
        from repro.core.quantize import _quantize_normal
        aq = _quantize_normal(a.astype(jnp.float32), self._k)
        bq = _quantize_normal(b.astype(jnp.float32), self._k)
        out = jnp.matmul(aq, bq, preferred_element_type=jnp.float32)
        _emit_health(self, out, self._k)
        return _quantize_normal(out, self._k).astype(self.compute_dtype)

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        # one traced body serves every layer, so monitor observations from
        # inside the scan carry the stacked wildcard scope (matching the
        # certificate's layer* / layer<i> envelope keys), not an empty path.
        # The span measures TRACE time of the scanned quantize/matmul body
        # (once per compile) — the per-scope attribution of compile cost
        from repro.core.scopes import STACK_SCOPE
        with self.scope(STACK_SCOPE), obs.span(
                "serve.layer_scan", backend=type(self).__name__,
                layers=n_layers):
            return super().layer_loop(fn, stacked_params, x, n_layers, aux)


class _SuffixLanes:
    """Scan-side sub-layer scope resolution for the quantised serving
    backends.

    Inside the ONE scanned layer body, the current scope suffix (e.g.
    ``("attn",)`` under ``bk.scope("attn")``) picks an ``[L]`` lane built
    by resolving ``outer + [layer{i}, *suffix]`` against the certificate's
    scope map — so ``layer*/attn``-style sub-layer certificate keys apply
    at the right ops instead of being dropped to per-layer granularity.
    With no sub-layer keys in the map, every suffix lane resolves to the
    layer lane (``layer{i}`` matches the longer path), preserving the
    per-layer behavior exactly. Lanes are cached per suffix; ``_dyn``
    holds the gathered per-layer value while tracing the scan body."""

    def _lane_static(self, path):
        raise NotImplementedError

    def _init_lanes(self):
        self._stack_ctx = None
        self._lane_cache: Dict[tuple, Any] = {}
        self._layer_idx = None
        self._dyn = None

    def _suffix_lane(self):
        outer, n_layers = self._stack_ctx
        suffix = tuple(self.scope_path[len(outer) + 1:])
        lane = self._lane_cache.get(suffix)
        if lane is None:
            lane = jnp.asarray(
                [self._lane_static(outer + [f"layer{i}", *suffix])
                 for i in range(n_layers)], jnp.int32)
            self._lane_cache[suffix] = lane
        return lane

    def _refresh_dyn(self):
        self._dyn = self._suffix_lane()[self._layer_idx]

    def _scope_changed(self):
        super()._scope_changed()
        if (getattr(self, "_stack_ctx", None) is not None
                and getattr(self, "_layer_idx", None) is not None):
            self._refresh_dyn()

    def _lane_loop(self, fn, stacked_params, x, n_layers, aux, super_loop):
        from repro.core.scopes import STACK_SCOPE
        outer = list(self.scope_path)
        self._stack_ctx = (outer, n_layers)
        self._lane_cache = {}

        def scoped_fn(p, carry, i, a):
            self._layer_idx = i
            self._refresh_dyn()
            try:
                return fn(p, carry, i, a)
            finally:
                self._layer_idx = None
                self._dyn = None

        try:
            with self.scope(STACK_SCOPE), obs.span(
                    "serve.layer_scan", backend=type(self).__name__,
                    layers=n_layers):
                return super_loop(scoped_fn, stacked_params, x,
                                  n_layers, aux)
        finally:
            self._stack_ctx = None
            self._lane_cache = {}


class MixedQuantJOps(_SuffixLanes, JOps):
    """JOps whose matmuls run at a per-layer certified precision.

    ``layer_k`` maps scope names (the same bk.scope(...) names the analysis
    gated on) to mantissa precisions; matmuls outside every mapped scope run
    at ``default_k`` — exactly the semantics the mixed certificate proved.
    Outside ``layer_loop`` the current scope path resolves a static Python k;
    inside the scanned layer stack (one traced body for all layers) the
    per-layer k is fetched from a scanned i32 lane by the carry's layer
    index — sub-layer keys resolve through :class:`_SuffixLanes` — and
    flows through :func:`repro.core.quantize.quantize_to_k`, whose traced-k
    rounding is bitwise-identical to the static path — so a single
    compilation serves every layer's precision.
    """

    def __init__(self, layer_k: Dict[str, int], default_k: int, *a, **kw):
        super().__init__(*a, **kw)
        self.layer_k = {str(s): int(v) for s, v in (layer_k or {}).items()}
        self.default_k = int(default_k)
        self._init_lanes()

    def _lane_static(self, path):
        from repro.core.analyze import resolve_scope_value
        return resolve_scope_value(path, self.layer_k, self.default_k)

    def _current_k(self):
        if self._dyn is not None:
            return self._dyn
        return self._lane_static(self.scope_path)

    monitor = None

    def matmul(self, a, b):
        from repro.kernels.quant_matmul import quant_matmul_dynamic_k
        k = self._current_k()
        out = quant_matmul_dynamic_k(a, b, k)
        _emit_health(self, out, k)
        return out.astype(self.compute_dtype)

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        return self._lane_loop(fn, stacked_params, x, n_layers, aux,
                               super().layer_loop)


class _FmtTriple:
    """Opaque (k, emax, emin) holder for scope maps — NOT a sequence, so
    :func:`repro.core.scopes.resolve_scope_value` never mistakes it for an
    ``[L]`` per-layer array when a ``layer*`` wildcard key matches."""

    __slots__ = ("triple",)

    def __init__(self, triple):
        self.triple = triple


class FormatQuantJOps(_SuffixLanes, JOps):
    """JOps whose matmuls run in per-scope certified CUSTOM FORMATS.

    ``layer_format`` maps scope names (the bk.scope(...) names the format
    synthesizer certified) to FpFormat descriptor dicts; the ``""`` entry
    (or ``default_format``) covers matmuls outside every mapped scope —
    exactly the semantics a schema-v3 certificate proves: operands and
    result of each matmul rounded into the scope's (k, emax, emin)
    saturating format. Outside ``layer_loop`` the scope resolves a static
    (k, emax, emin) triple; inside the scanned layer stack the per-layer
    triple is fetched from a scanned i32[L, 3] lane (sub-layer keys like
    ``layer*/attn`` resolve through :class:`_SuffixLanes`) — both flow
    through
    :func:`repro.kernels.quant_matmul.quant_matmul_format_ref`, whose
    traced-format rounding is bitwise the static path, so a single
    compilation serves every layer's format.
    """

    def __init__(self, layer_format: Dict[str, Dict],
                 default_format: Optional[Dict] = None, *a, **kw):
        super().__init__(*a, **kw)
        self.layer_format = {str(s): dict(f)
                             for s, f in (layer_format or {}).items()}
        default = default_format or self.layer_format.get("")
        if default is None:
            raise ValueError("layer_format needs a '' default entry (or an "
                             "explicit default_format) for unmapped scopes")
        fmts = list(self.layer_format.values()) + [dict(default)]
        # the (k, emax, emin) triple is per-scope data; the flags must be
        # map-uniform (serving_layer_format guarantees it) because they are
        # compiled statically into the quantisation path — serving a flag
        # the certificate didn't prove would silently change the arithmetic
        flags = {(f.get("has_subnormals", True), f.get("saturating", True))
                 for f in fmts}
        if len(flags) != 1:
            raise ValueError(f"layer_format mixes subnormal/saturation "
                             f"flags {sorted(flags)} — not representable by "
                             "one serving map")
        self.has_subnormals, self.saturating = next(iter(flags))
        if any(f.get("max_finite_override") is not None for f in fmts):
            raise NotImplementedError(
                "encoding-clipped formats (max_finite_override) are not "
                "servable through the (k, emax, emin) triple path")
        self.default_triple = self._triple(default)
        # triples are held in an opaque wrapper: resolve_scope_value
        # layer-indexes tuple values matched through a "layer*" wildcard
        # (the [L]-per-layer map convenience), which would tear a bare
        # (k, emax, emin) apart — wrapped, the triple passes through whole
        self._triples = {s: _FmtTriple(self._triple(f))
                         for s, f in self.layer_format.items() if s}
        self._init_lanes()

    @staticmethod
    def _triple(f: Dict) -> tuple:
        return (int(f["k"]), int(f["emax"]), int(f["emin"]))

    def _lane_static(self, path):
        from repro.core.analyze import resolve_scope_value
        got = resolve_scope_value(path, self._triples,
                                  _FmtTriple(self.default_triple))
        return got.triple

    def _current_fmt(self):
        if self._dyn is not None:
            return self._dyn
        return jnp.asarray(self._lane_static(self.scope_path), jnp.int32)

    monitor = None
    # Certificate-aware flash decode: gqa_attention offers the S==1 decode
    # step to decode_attention below, which quantizes q/k/v tiles into the
    # scope's certified format (resolved through the SAME _SuffixLanes
    # machinery as matmul, so layer*/attn sub-lanes apply). Class-level so
    # tests can force the composed einsum/softmax path off.
    use_flash_decode = True

    def matmul(self, a, b):
        from repro.kernels.quant_matmul import quant_matmul_format_dispatch
        fmt = self._current_fmt()
        out = quant_matmul_format_dispatch(a, b, fmt,
                                           has_subnormals=self.has_subnormals,
                                           saturating=self.saturating)
        _emit_health(self, out, fmt[0], fmt[1], fmt[2])
        return out.astype(self.compute_dtype)

    def decode_attention(self, q, k, v, lengths):
        if not self.use_flash_decode:
            return None
        from repro.kernels.flash_decode import certified_decode_attention
        fmt = self._current_fmt()
        out = certified_decode_attention(q, k, v, lengths, fmt,
                                         has_subnormals=self.has_subnormals,
                                         saturating=self.saturating)
        return out.astype(self.compute_dtype)

    def layer_loop(self, fn, stacked_params, x, n_layers: int, aux=None):
        return self._lane_loop(fn, stacked_params, x, n_layers, aux,
                               super().layer_loop)


def _backend(sc: ServeConfig, mesh=None, monitor=None):
    # every backend gets the mesh: JOps.shard_hint('act_batch') threads the
    # lane-batch sharding constraint through the scanned layer body (a
    # no-op on 1-device meshes), and MoE expert parallelism reads bk.mesh
    dt = jnp.bfloat16 if sc.compute_dtype == "bfloat16" else jnp.float32
    bk = None
    if sc.precision_layer_format:
        bk = FormatQuantJOps(sc.precision_layer_format, None,
                             dt, jnp.float32, mesh=mesh)
    elif sc.precision_layer_k:
        if sc.precision_k is None:
            raise ValueError("precision_layer_k needs precision_k as the "
                             "default for unmapped scopes")
        bk = MixedQuantJOps(sc.precision_layer_k, sc.precision_k,
                            dt, jnp.float32, mesh=mesh)
    elif sc.precision_k is not None:
        bk = QuantJOps(sc.precision_k, dt, jnp.float32, mesh=mesh)
    if bk is not None:
        bk.monitor = monitor
        return bk
    if monitor is not None:
        raise ValueError("violation monitoring needs a certified quantised "
                         "backend (precision_k / layer map / format map)")
    return JOps(dt, jnp.float32, mesh=mesh)


DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "fp8": jnp.float8_e4m3fn}


def build_serve_steps(arch_cfg, sc: ServeConfig, mesh, monitor=None):
    bk = _backend(sc, mesh=mesh, monitor=monitor)
    resident = sc.params_resident
    if resident is None:  # §Perf auto-policy: resident decode ≤ ~70B params
        resident = T.analytic_params(arch_cfg) <= 70e9
    sc = dataclasses.replace(sc, params_resident=bool(resident))
    cache_dtype = DTYPES.get(sc.cache_dtype, jnp.float32)

    def _fwd_kwargs(batch):
        kwargs = {}
        if arch_cfg.frontend == "audio":
            if "enc_out" in batch:          # decode: reuse prefill's encoding
                kwargs["enc_out"] = batch["enc_out"]
            else:
                kwargs["enc_embeds"] = batch["frontend"]
        elif arch_cfg.frontend == "vision" and "frontend" in batch:
            # prefill only: the patch KV lives in the cache afterwards —
            # re-prepending 256 patches per decoded token was a 700x
            # HLO-flop bug caught by the roofline calibration test (§Perf)
            kwargs["frontend_embeds"] = batch["frontend"]
        return kwargs

    def prefill_fn(params, cache, batch):
        kwargs = _fwd_kwargs(batch)
        enc_out = None
        if arch_cfg.enc_dec:
            enc_out = T.encode(bk, params, arch_cfg, batch["frontend"])
            kwargs = {"enc_out": enc_out}
        logits, cache = T.forward(bk, params, arch_cfg, batch["tokens"],
                                  cache=cache, q_offset=0, **kwargs)
        if arch_cfg.enc_dec:
            return logits[:, -1:, :], cache, bk.value_of(enc_out)
        return logits[:, -1:, :], cache

    def decode_fn(params, cache, batch):
        """One token for every sequence at absolute position batch['pos']."""
        logits, cache = T.forward(bk, params, arch_cfg, batch["tokens"],
                                  cache=cache, q_offset=batch["pos"],
                                  **_fwd_kwargs(batch))
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, cache

    # shardings
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: T.init_params(key, arch_cfg))
    p_sh = sh.shard_params(pshapes, mesh, model_only=bool(sc.params_resident))
    cache_shapes = jax.eval_shape(
        lambda: T.init_cache(arch_cfg, sc.batch, sc.max_seq, cache_dtype))
    c_sh = sh.shard_cache(cache_shapes, mesh, arch_cfg)
    rep = NamedSharding(mesh, P())
    b_sh_prefill = {"tokens": sh.shard_batch(mesh, sc.batch, sc.prefill_len)}
    b_sh_decode = {"tokens": sh.shard_batch(mesh, sc.batch, 1), "pos": rep}
    if arch_cfg.frontend:
        fsh = NamedSharding(mesh, sh.batch_spec(mesh, sc.batch,
                                                arch_cfg.frontend_seq))
        b_sh_prefill["frontend"] = fsh
        if arch_cfg.enc_dec:
            b_sh_decode["enc_out"] = fsh  # reused encoder states

    prefill_out_sh = (rep, c_sh, rep) if arch_cfg.enc_dec else (rep, c_sh)
    prefill = jax.jit(prefill_fn,
                      in_shardings=(p_sh, c_sh, b_sh_prefill),
                      out_shardings=prefill_out_sh,
                      donate_argnums=(1,))
    decode = jax.jit(decode_fn,
                     in_shardings=(p_sh, c_sh, b_sh_decode),
                     out_shardings=(rep, c_sh),
                     donate_argnums=(1,))
    return prefill, decode, {"params": p_sh, "cache": c_sh}


def apply_certificates(sc: ServeConfig, arch_cfg, params, **certify_kw) -> tuple:
    """Resolve ``sc.certificates`` into a concrete precision_k.

    Loads (or creates, on first use) the certificate set for this exact
    (arch, params) pair from the store and pins ``precision_k`` to its
    ``serving_k``. Returns (updated ServeConfig, CertificateSet) — the set's
    ``error_bars()`` is what gets attached to responses. ``certify_kw``
    (e.g. ``k_max=32``) reaches :func:`repro.certify.certify_lm` — a wider
    range is a *different* store request, so an uncertifiable result at the
    default range never shadows it.
    """
    from repro.certify import serving_certificate

    cs = serving_certificate(sc.arch, arch_cfg, params, sc.certificates,
                             **certify_kw)
    k = cs.serving_k
    if k is None:
        # No usable uniform k across the set (e.g. a v3 format-only
        # certificate whose required_k is None). A complete layer_format
        # map still carries its own "" default, so format serving does not
        # need a uniform fallback k — degrade to format-only serving
        # rather than refusing to serve a certified model.
        lf = cs.serving_layer_format
        if lf is not None and lf.get(""):
            obs.event("serve.format_only_degrade", arch=sc.arch,
                      scopes=len(lf))
            return dataclasses.replace(
                sc, precision_k=None,
                precision_layer_k=None,
                precision_layer_format=lf), cs
        raise RuntimeError(
            f"certificate store holds no certifiable precision for {sc.arch} "
            "— serve at full precision, or widen the search "
            "(--certify-k-max on the CLI)")
    # a v2 certificate with a jointly-certified per-layer map upgrades the
    # uniform k to mixed-precision execution (unmapped scopes stay at k); a
    # v3 certificate further upgrades to full per-scope custom formats
    # (mantissa AND exponent range certified)
    return dataclasses.replace(
        sc, precision_k=k,
        precision_layer_k=cs.serving_layer_k,
        precision_layer_format=cs.serving_layer_format), cs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--precision-k", type=int, default=None)
    ap.add_argument("--certificates", default=None, metavar="STORE_DIR",
                    help="pick precision_k from the certificate store and "
                         "attach (δ̄, ε̄, k) error bars to responses")
    ap.add_argument("--certify-k-max", type=int, default=None,
                    help="ceiling of the certification search (default 24; "
                         "53 with --certify-mixed/--certify-formats)")
    ap.add_argument("--certify-mixed", action="store_true",
                    help="certify (or load) a per-layer {scope: k} map via "
                         "the scan-native stacked analysis and serve it "
                         "through the scanned per-layer quantisation path")
    ap.add_argument("--certify-formats", action="store_true",
                    help="additionally certify per-scope custom (k, emin, "
                         "emax) formats; an attached map serves through the "
                         "traced-format quantisation path")
    ap.add_argument("--metrics", default=None, metavar="OUT.JSONL",
                    help="append a serving-metrics snapshot (latency "
                         "histograms, tokens/s, occupancy, violation "
                         "counters) as one JSONL object")
    ap.add_argument("--prom", default=None, metavar="OUT.PROM",
                    help="also write the metrics as a Prometheus text "
                         "exposition file (no server; point a scraper/"
                         "node-exporter textfile collector at it)")
    ap.add_argument("--monitor", action="store_true",
                    help="attach certificate-violation monitors: per-scope "
                         "numeric-health checked against the certified "
                         "enclosures, plus one sampled empirical-error "
                         "check against δ̄ (requires --certificates)")
    ap.add_argument("--trace", default=None, metavar="OUT.JSONL",
                    help="record a JSONL trace of the serving run: "
                         "prefill/decode spans, the scanned layer-body "
                         "trace span, per-jit compile-time and jaxpr-size "
                         "gauges; render with `python -m repro.obs report`")
    args = ap.parse_args(argv)
    if args.trace:
        obs.configure(path=args.trace, program="repro.launch.serve",
                      argv=argv)
    if ((args.certify_mixed or args.certify_formats or
         args.certify_k_max is not None) and args.certificates is None):
        ap.error("--certify-mixed/--certify-formats/--certify-k-max require "
                 "--certificates STORE_DIR")
    if args.monitor and args.certificates is None:
        ap.error("--monitor needs --certificates (violations are relative "
                 "to a certificate's bounds)")

    arch_cfg = configs.get(args.arch).SMOKE
    extra = arch_cfg.frontend_seq if arch_cfg.frontend == "vision" else 0
    sc = ServeConfig(arch=args.arch, batch=args.batch,
                     max_seq=args.prefill_len + args.decode_steps + 1 + extra,
                     prefill_len=args.prefill_len,
                     precision_k=args.precision_k,
                     certificates=args.certificates)
    params = T.init_params(jax.random.PRNGKey(0), arch_cfg)
    certset = None
    if sc.certificates is not None:
        kw = {}
        if args.certify_mixed or args.certify_formats:
            # flags map 1:1 onto the certify CLI's --mixed/--formats so the
            # two tools address the same store entry for the same intent
            kw.update(mixed=args.certify_mixed,
                      formats=args.certify_formats,
                      k_max=args.certify_k_max or 53)
        elif args.certify_k_max is not None:
            kw["k_max"] = args.certify_k_max
        sc, certset = apply_certificates(sc, arch_cfg, params, **kw)
        log.info("certificate resolved",
                 k=sc.precision_k,
                 source=("store" if certset.meta.get("from_store")
                         else "fresh analysis (now persisted)"),
                 mixed_scopes=(None if sc.precision_layer_k is None
                               else len(sc.precision_layer_k)),
                 format_scopes=(None if sc.precision_layer_format is None
                                else len(sc.precision_layer_format)),
                 error_bars=certset.error_bars())
    monitor = None
    if args.monitor:
        monitor = obs.ViolationMonitor.from_certificate_set(certset)
        log.info("violation monitor attached",
                 envelopes=len(monitor.envelopes),
                 dbar_u=monitor.dbar_u)
    registry = obs.MetricsRegistry()
    registry.meta.update(arch=args.arch, batch=sc.batch,
                         precision_k=sc.precision_k)
    mesh = meshlib.make_host_mesh()
    with mesh:
        with obs.span("serve.build_steps", arch=args.arch):
            prefill, decode, _ = build_serve_steps(arch_cfg, sc, mesh,
                                                   monitor=monitor)
        cache = T.init_cache(arch_cfg, sc.batch, sc.max_seq, jnp.float32)
        import numpy as np
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, arch_cfg.vocab, (sc.batch, sc.prefill_len)))}
        if arch_cfg.frontend:
            batch["frontend"] = rng.randn(
                sc.batch, arch_cfg.frontend_seq,
                arch_cfg.frontend_dim).astype("float32")
        if obs.enabled():
            # AOT-compile with the lower/compile phases separately timed so
            # compile cost lands in the trace as gauges (not smeared into
            # the first prefill latency); jaxpr size gauges ride along
            from repro.obs.profile import jaxpr_stats, time_compile
            with obs.span("serve.compile", stage="prefill"):
                pc = time_compile(prefill, params, cache, batch)
            obs.gauge("serve.prefill_compile_s", pc["compile_s"])
            obs.gauge("serve.prefill_lower_s", pc["lower_s"])
            obs.gauge("serve.prefill_jaxpr_eqns",
                      jaxpr_stats(prefill, params, cache, batch)["eqns"])
            registry.gauge("serve.prefill_compile_s", pc["compile_s"])
            # run through the AOT executable — lower().compile() doesn't
            # seed the jit cache, and the compile is already gauged above
            prefill = pc["compiled"]
        t0 = time.perf_counter()
        with obs.span("serve.prefill", arch=args.arch, batch=sc.batch,
                      prefill_len=sc.prefill_len):
            logits, cache = prefill(params, cache, batch)
            jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        registry.observe("serve.prefill_latency_s", t_prefill)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)
        out_toks = [tok]
        prefix = (arch_cfg.frontend_seq
                  if arch_cfg.frontend == "vision" else 0)
        if obs.enabled():
            db0 = {"tokens": tok[:, None],
                   "pos": jnp.asarray(prefix + sc.prefill_len, jnp.int32)}
            if arch_cfg.frontend == "audio":
                db0["frontend"] = batch["frontend"]
            from repro.obs.profile import jaxpr_stats
            obs.gauge("serve.decode_jaxpr_eqns", jaxpr_stats(
                decode, params, jax.eval_shape(lambda: cache), db0)["eqns"])
            with obs.span("serve.compile", stage="decode"):
                tdl = time.perf_counter()
                lowered = decode.lower(params, jax.eval_shape(lambda: cache),
                                       db0)
                tdc = time.perf_counter()
                # lower().compile() doesn't seed the jit's own cache — keep
                # the executable and decode through it, so the percentile
                # digest measures steady-state steps, not a hidden recompile
                decode = lowered.compile()
                obs.gauge("serve.decode_lower_s", tdc - tdl)
                obs.gauge("serve.decode_compile_s",
                          time.perf_counter() - tdc)
                registry.gauge("serve.decode_compile_s",
                               time.perf_counter() - tdc)
        t_decode = 0.0
        for i in range(args.decode_steps):
            db = {"tokens": tok[:, None],
                  "pos": jnp.asarray(prefix + sc.prefill_len + i, jnp.int32)}
            if arch_cfg.frontend == "audio":
                db["frontend"] = batch["frontend"]
            td = time.perf_counter()
            with obs.span("serve.decode", step=i):
                tok, cache = decode(params, cache, db)
                jax.block_until_ready(tok)
            td = time.perf_counter() - td
            t_decode += td
            registry.observe("serve.decode_latency_s", td)
            out_toks.append(tok)
        dt = time.perf_counter() - t0
        toks = jnp.stack(out_toks, axis=1)
        registry.counter("serve.requests", sc.batch)
        registry.counter("serve.tokens", int(toks.size))
        registry.gauge("serve.batch_occupancy", 1.0)  # demo: all slots live
        if t_decode > 0:
            registry.gauge("serve.decode_tokens_per_s",
                           sc.batch * args.decode_steps / t_decode)
        registry.gauge("serve.prefill_tokens_per_s",
                       sc.batch * sc.prefill_len / t_prefill)
        if (monitor is not None and not arch_cfg.frontend
                and not arch_cfg.enc_dec):
            # one sampled empirical-error check: a full-precision reference
            # pass over the same prefill, |Δlogits| in units of the
            # certified u vs δ̄ (gross under-certification detector)
            ref_cache = T.init_cache(arch_cfg, sc.batch, sc.max_seq,
                                     jnp.float32)
            ref_logits, _ = T.forward(JOps(jnp.float32, jnp.float32), params,
                                      arch_cfg, batch["tokens"],
                                      cache=ref_cache, q_offset=0)
            u = certset.error_bars().get("u")
            if u:
                err_u = float(jnp.max(jnp.abs(
                    ref_logits[:, -1:, :].astype(jnp.float64)
                    - logits.astype(jnp.float64)))) / u
                monitor.observe_error(err_u)
        responses = make_responses(toks, certset)
        log.info("served", seqs=sc.batch, decode_steps=args.decode_steps,
                 total_s=round(dt, 2), prefill_s=round(t_prefill, 3),
                 decode_s_per_tok=round(t_decode / max(args.decode_steps, 1),
                                        4),
                 sample=toks[0][:10].tolist())
        dh = registry.histograms.get("serve.decode_latency_s")
        if dh is not None and dh.count:
            pct = dh.percentiles()
            log.info("decode latency percentiles",
                     p50_ms=round(pct["p50"] * 1e3, 3),
                     p95_ms=round(pct["p95"] * 1e3, 3),
                     p99_ms=round(pct["p99"] * 1e3, 3),
                     steps=dh.count)
            for q, v in pct.items():
                registry.gauge(f"serve.decode_latency_{q}_s", v)
        if certset is not None:
            log.info("response metadata",
                     certificate=responses[0]["certificate"])
        if monitor is not None:
            monitor.export(registry)
            ms = monitor.summary()
            log.info("monitor", violations=ms["violations"],
                     observations=ms["counters"]["obs.scope_observations"],
                     worst_err_u=ms["worst_err_u"], dbar_u=ms["dbar_u"],
                     scope_margin_log2={
                         k: round(v, 2)
                         for k, v in ms["scope_margin_log2"].items()})
        if args.metrics:
            registry.write_jsonl(args.metrics)
            log.info("metrics written", path=args.metrics)
        if args.prom:
            registry.write_prometheus(args.prom)
            log.info("prometheus exposition written", path=args.prom)
        if args.trace:
            obs.shutdown()
            log.info("trace written", path=args.trace,
                     hint="render with: python -m repro.obs report "
                          + args.trace)
        return registry, monitor


def make_responses(toks, certset=None):
    """Per-sequence response dicts; with a certificate set attached, every
    response carries the certified (δ̄, ε̄, k) error bars it was served
    under — the contract the certificate pipeline exists to provide."""
    bars = None if certset is None else certset.error_bars()
    responses = []
    for i in range(toks.shape[0]):
        r = {"tokens": toks[i].tolist()}
        if bars is not None:
            r["certificate"] = dict(bars, params_digest=certset.params_digest)
        responses.append(r)
    return responses


if __name__ == "__main__":
    main()
