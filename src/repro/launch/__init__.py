"""launch subsystem."""
