"""Continuous batching: a decode scheduler over a lane-structured KV cache.

The classic serving loop (:mod:`repro.launch.serve`) runs lock-step: one
prefill, then B sequences decode together and finish together. This module
adds the production shape — a persistent decode batch of ``n_lanes`` lanes
that requests join and leave independently:

- **Admission control**: a bounded FIFO queue in front of the lanes; a
  request is admitted when a lane is free AND its worst-case KV footprint
  (``ceil((prompt + max_new) / page_size)`` fixed-size pages) fits the page
  pool. Reserving worst-case at admission means an admitted request can
  never OOM mid-flight — the rejection happens at the door, with a metric,
  not at token 37. Over-capacity submissions are rejected outright.
- **Batched prefill-insert**: a new request prefills at batch 1 (padded to
  a whole number of pages) and its cache slice + per-lane index are
  inserted into the running [L, B, Smax, ...] cache at the free lane —
  the decode batch never drains to let someone in.
- **Lane recycling**: on EOS / max-new-tokens the lane's pages return to
  the pool and the lane is immediately reusable; stale cache contents need
  no scrubbing because every mask in the ragged decode path is
  length-limited (positions ≥ the lane's length are unreachable).

Bit-for-bit contract: a request's tokens are identical to running that
request ALONE through the single-device eager reference
(:func:`reference_generate`: ``UnrolledLayerLoop``-composed backend, batch
1, unpadded prefill, no mesh). This holds because every per-lane row of
the transformer is bitwise independent of batch composition — f32 matmul
rows don't see other rows, masked-softmax columns beyond a lane's length
contribute exact zeros, cache writes are vmapped per lane — which the
engine tests assert against staggered-arrival schedules.

Mesh execution: with a (data, model) mesh from
:func:`repro.launch.mesh.make_serving_mesh`, params shard column-parallel
(:func:`repro.parallel.sharding.shard_params_serving` — output dims only,
never a contraction, so the math stays bitwise), lanes shard over "data",
and the scanned layer body re-constrains activations each layer
(``shard_hint('act_batch')``).
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.core.backend import JOps, UnrolledLayerLoop
from repro.launch import mesh as meshlib
from repro.launch import serve
from repro.models import transformer as T
from repro.parallel import sharding as sh

log = obs.get_logger("batching")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    arrival_step: int = 0


@dataclasses.dataclass
class _Lane:
    req: Request
    length: int                 # tokens currently in this lane's cache
    pages: int                  # pages reserved from the pool
    out: List[int] = dataclasses.field(default_factory=list)
    t_admit: float = 0.0


def make_backend(sc: serve.ServeConfig, *, mesh=None, unrolled: bool = False):
    """The serving backend for a ServeConfig — optionally composed with
    :class:`UnrolledLayerLoop` (the eager per-layer differential baseline;
    scope resolution degrades to the static ``layer{i}`` path, which the
    lane machinery is bitwise against)."""
    dt = jnp.bfloat16 if sc.compute_dtype == "bfloat16" else jnp.float32

    def cls(base):
        if not unrolled:
            return base
        return type("Unrolled" + base.__name__, (UnrolledLayerLoop, base), {})

    if sc.precision_layer_format:
        return cls(serve.FormatQuantJOps)(sc.precision_layer_format, None,
                                          dt, jnp.float32, mesh=mesh)
    if sc.precision_layer_k:
        if sc.precision_k is None:
            raise ValueError("precision_layer_k needs precision_k")
        return cls(serve.MixedQuantJOps)(sc.precision_layer_k, sc.precision_k,
                                         dt, jnp.float32, mesh=mesh)
    if sc.precision_k is not None:
        return cls(serve.QuantJOps)(sc.precision_k, dt, jnp.float32,
                                    mesh=mesh)
    return cls(JOps)(dt, jnp.float32, mesh=mesh)


class ContinuousBatchingEngine:
    """Decode scheduler: admission queue → lanes → recycled lanes.

    ``params`` may live on host; with a mesh they are placed under the
    bitwise-safe column-parallel serving sharding. ``registry`` (a
    :class:`repro.obs.MetricsRegistry`) receives occupancy / queue-depth
    gauges and per-lane ``serve.decode_latency_s{lane=N}`` histograms.
    """

    def __init__(self, arch_cfg, sc: serve.ServeConfig, params, *,
                 mesh=None, n_lanes: int = 4, max_seq: int = 64,
                 page_size: int = 16, queue_depth: int = 8,
                 total_pages: Optional[int] = None, eos_id: int = -1,
                 registry=None, certset=None):
        if max_seq % page_size:
            raise ValueError(f"max_seq {max_seq} must be a whole number of "
                             f"pages (page_size {page_size})")
        self.arch_cfg, self.sc = arch_cfg, sc
        self.n_lanes, self.max_seq = n_lanes, max_seq
        self.page_size = page_size
        self.queue_depth = queue_depth
        self.total_pages = (n_lanes * (max_seq // page_size)
                            if total_pages is None else total_pages)
        self.free_pages = self.total_pages
        self.eos_id = eos_id
        self.registry = registry
        self.certset = certset
        self.mesh = mesh
        self.bk = make_backend(sc, mesh=mesh)

        self.queue: Deque[Request] = collections.deque()
        self.lanes: List[Optional[_Lane]] = [None] * n_lanes
        self.responses: List[Dict[str, Any]] = []
        self.steps = 0
        self.decode_tokens = 0
        self.decode_s = 0.0

        cache = T.init_cache(arch_cfg, n_lanes, max_seq, jnp.float32,
                             per_lane_idx=True)
        if not (isinstance(cache, dict) and "idx" in cache):
            raise NotImplementedError(
                f"continuous batching needs an indexed KV cache "
                f"(family {arch_cfg.family!r} has none)")
        if mesh is not None:
            p_sh = sh.shard_params_serving(params, mesh)
            self._c_sh = sh.shard_cache_serving(cache, mesh)
            params = jax.device_put(params, p_sh)
            cache = jax.device_put(cache, self._c_sh)
        self.params, self.cache = params, cache
        self._build_steps()

    # -- jitted steps -------------------------------------------------------

    def _build_steps(self):
        cfg, bk, S = self.arch_cfg, self.bk, self.max_seq

        def prefill_fn(params, tokens, length):
            # batch-1 prefill into a fresh cache; bitwise == the same rows
            # of any batched prefill (row independence), == the unpadded
            # prefill (pad columns are causally masked). The returned
            # slice's index is pinned to the TRUE length so pad-region
            # junk is overwritten by the first decode steps.
            cache = T.init_cache(cfg, 1, S, jnp.float32, per_lane_idx=True)
            logits, cache = T.forward(bk, params, cfg, tokens, cache=cache,
                                      q_offset=jnp.zeros((1,), jnp.int32))
            tok = jnp.argmax(logits[0, length - 1, :], axis=-1)
            cache = {**cache, "idx": jnp.full_like(cache["idx"], length)}
            return tok.astype(jnp.int32), cache

        def insert_fn(cache, sl, lane):
            def one(b, s):
                z = jnp.zeros((), jnp.int32)
                starts = (z, lane) + (z,) * (b.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    b, s.astype(b.dtype), starts)
            return jax.tree_util.tree_map(one, cache, sl)

        def decode_fn(params, cache, tokens, offsets):
            # pin every lane's write index to the scheduler's view of its
            # length — idle lanes neither drift nor clamp at the buffer edge
            idx = jnp.broadcast_to(offsets[None, :], cache["idx"].shape)
            cache = {**cache, "idx": idx.astype(cache["idx"].dtype)}
            logits, cache = T.forward(bk, params, cfg, tokens[:, None],
                                      cache=cache, q_offset=offsets)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)
            return nxt.astype(jnp.int32), cache

        if self.mesh is not None:
            rep = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec())
            self._prefill = jax.jit(prefill_fn)
            self._insert = jax.jit(insert_fn, donate_argnums=(0,),
                                   out_shardings=self._c_sh)
            self._decode = jax.jit(decode_fn, donate_argnums=(1,),
                                   out_shardings=(rep, self._c_sh))
        else:
            self._prefill = jax.jit(prefill_fn)
            self._insert = jax.jit(insert_fn, donate_argnums=(0,))
            self._decode = jax.jit(decode_fn, donate_argnums=(1,))

    # -- scheduling ---------------------------------------------------------

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def submit(self, req: Request) -> bool:
        """Enqueue; False = rejected (queue full / can never fit)."""
        worst = len(req.prompt) + req.max_new_tokens
        if worst > self.max_seq or self._pages_for(worst) > self.total_pages:
            self._count("serve.requests_rejected{reason=too_long}")
            return False
        if len(self.queue) >= self.queue_depth:
            self._count("serve.requests_rejected{reason=queue_full}")
            return False
        self.queue.append(req)
        return True

    def _count(self, name, inc=1):
        if self.registry is not None:
            self.registry.counter(name, inc)

    def _gauges(self):
        if self.registry is None:
            return
        occ = sum(l is not None for l in self.lanes) / self.n_lanes
        self.registry.gauge("serve.batch_occupancy", occ)
        self.registry.gauge("serve.admission_queue_depth", len(self.queue))
        self.registry.gauge("serve.kv_pages_free", self.free_pages)

    def _admit(self):
        while self.queue:
            free = [i for i, l in enumerate(self.lanes) if l is None]
            if not free:
                break
            req = self.queue[0]
            P = len(req.prompt)
            pages = self._pages_for(P + req.max_new_tokens)
            if pages > self.free_pages:
                break                      # honest FIFO: no head-of-line skip
            self.queue.popleft()
            lane = free[0]
            # pad the prompt to whole pages: one prefill compilation per
            # page-count bucket, and the cache slice lands page-aligned
            Ppad = min(self.max_seq, self.page_size * self._pages_for(P))
            toks = np.zeros((1, Ppad), np.int32)
            toks[0, :P] = np.asarray(req.prompt, np.int32)
            tok, sl = self._prefill(self.params, jnp.asarray(toks),
                                    jnp.asarray(P, jnp.int32))
            self.cache = self._insert(self.cache, sl,
                                      jnp.asarray(lane, jnp.int32))
            first = int(tok)
            self.free_pages -= pages
            self.lanes[lane] = _Lane(req=req, length=P, pages=pages,
                                     out=[first], t_admit=time.perf_counter())
            self._count("serve.requests_admitted")
            self._finish_if_done(lane, first)

    def _finish_if_done(self, i: int, last_tok: int):
        lane = self.lanes[i]
        if lane is None:
            return
        done = (last_tok == self.eos_id
                or len(lane.out) >= lane.req.max_new_tokens
                or lane.length + 1 >= self.max_seq)
        if not done:
            return
        r: Dict[str, Any] = {"id": lane.req.rid, "tokens": list(lane.out),
                             "n_prompt": len(lane.req.prompt)}
        if self.certset is not None:
            r["certificate"] = dict(self.certset.error_bars(),
                                    params_digest=self.certset.params_digest)
        self.responses.append(r)
        self.free_pages += lane.pages
        self.lanes[i] = None
        self._count("serve.requests_completed")

    def step(self) -> bool:
        """Admit + one decode step for every active lane. False = idle."""
        self._admit()
        self._gauges()
        active = [i for i, l in enumerate(self.lanes) if l is not None]
        if not active:
            return bool(self.queue)
        tokens = np.zeros((self.n_lanes,), np.int32)
        offsets = np.zeros((self.n_lanes,), np.int32)
        for i, lane in enumerate(self.lanes):
            if lane is not None:
                tokens[i] = lane.out[-1]
                offsets[i] = lane.length
        t0 = time.perf_counter()
        nxt, self.cache = self._decode(self.params, self.cache,
                                       jnp.asarray(tokens),
                                       jnp.asarray(offsets))
        nxt = jax.block_until_ready(nxt)
        dt = time.perf_counter() - t0
        self.steps += 1
        self.decode_tokens += len(active)
        self.decode_s += dt
        if self.registry is not None:
            self.registry.observe("serve.decode_latency_s", dt)
            for i in active:
                self.registry.observe(f"serve.decode_latency_s{{lane={i}}}",
                                      dt)
            self._count("serve.tokens", len(active))
        nxt = np.asarray(nxt)
        for i in active:
            lane = self.lanes[i]
            lane.length += 1
            lane.out.append(int(nxt[i]))
            self._finish_if_done(i, int(nxt[i]))
        return True

    def run(self, requests: Sequence[Request] = (),
            max_steps: int = 100_000) -> List[Dict[str, Any]]:
        """Drive the schedule to completion: requests enter the queue at
        their ``arrival_step``; returns the responses in completion order."""
        pending = sorted(requests, key=lambda r: r.arrival_step)
        pi = 0
        for _ in range(max_steps):
            while pi < len(pending) and pending[pi].arrival_step <= self.steps:
                self.submit(pending[pi])
                pi += 1
            busy = self.step()
            if (not busy and pi >= len(pending)
                    and all(l is None for l in self.lanes)
                    and not self.queue):
                break
        self._gauges()
        if self.registry is not None and self.decode_s > 0:
            self.registry.gauge("serve.decode_tokens_per_s",
                                self.decode_tokens / self.decode_s)
        return self.responses


def reference_generate(arch_cfg, sc: serve.ServeConfig, params,
                       prompt: Sequence[int], max_new_tokens: int, *,
                       max_seq: int, eos_id: int = -1) -> List[int]:
    """Single-device eager reference: batch 1, unpadded prefill, unrolled
    per-layer backend, no mesh — the bitwise oracle the engine must match.
    ``max_seq`` must equal the engine's (the cache width is part of the
    masked-softmax shape)."""
    bk = make_backend(sc, mesh=None, unrolled=True)
    cache = T.init_cache(arch_cfg, 1, max_seq, jnp.float32,
                         per_lane_idx=True)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
    logits, cache = T.forward(bk, params, arch_cfg, toks, cache=cache,
                              q_offset=jnp.zeros((1,), jnp.int32))
    P = len(prompt)
    tok = int(jnp.argmax(logits[0, -1, :]))
    out = [tok]
    while (tok != eos_id and len(out) < max_new_tokens
           and P + len(out) < max_seq):
        offs = jnp.asarray([P + len(out) - 1], jnp.int32)
        logits, cache = T.forward(bk, params, arch_cfg,
                                  jnp.asarray([[tok]], jnp.int32),
                                  cache=cache, q_offset=offs)
        tok = int(jnp.argmax(logits[0, -1, :]))
        out.append(tok)
    return out


def _arch(name: str):
    try:
        return name, configs.get(name).SMOKE
    except KeyError:
        if name == "transformer":       # certify-CLI alias, same default
            return "qwen2_7b", configs.get("qwen2_7b").SMOKE
        raise


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="continuous-batching serving demo / smoke")
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--queue-depth", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--arrival-stride", type=int, default=2,
                    help="steps between request arrivals (staggered joins)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", type=int, default=None,
                    help="mesh data-axis size (default: all devices)")
    ap.add_argument("--model", type=int, default=None,
                    help="mesh model-axis size (default: 1)")
    ap.add_argument("--precision-k", type=int, default=None)
    ap.add_argument("--certificates", default=None, metavar="STORE_DIR")
    ap.add_argument("--certify-mixed", action="store_true")
    ap.add_argument("--certify-formats", action="store_true")
    ap.add_argument("--certify-k-max", type=int, default=None)
    ap.add_argument("--check-ref", action="store_true",
                    help="re-serve every request through the single-device "
                         "eager reference and assert token-for-token "
                         "equality (exits 1 on any mismatch)")
    ap.add_argument("--metrics", default=None, metavar="OUT.JSONL")
    ap.add_argument("--prom", default=None, metavar="OUT.PROM")
    args = ap.parse_args(argv)
    if ((args.certify_mixed or args.certify_formats
         or args.certify_k_max is not None) and args.certificates is None):
        ap.error("--certify-* require --certificates STORE_DIR")

    arch, arch_cfg = _arch(args.arch)
    sc = serve.ServeConfig(arch=arch, batch=args.lanes,
                           max_seq=args.max_seq,
                           precision_k=args.precision_k,
                           certificates=args.certificates)
    params = T.init_params(jax.random.PRNGKey(0), arch_cfg)
    certset = None
    if args.certificates is not None:
        kw = {}
        if args.certify_mixed or args.certify_formats:
            kw.update(mixed=args.certify_mixed, formats=args.certify_formats,
                      k_max=args.certify_k_max or 53)
        elif args.certify_k_max is not None:
            kw["k_max"] = args.certify_k_max
        sc, certset = serve.apply_certificates(sc, arch_cfg, params, **kw)
        log.info("certificate resolved", k=sc.precision_k,
                 mixed_scopes=(None if sc.precision_layer_k is None
                               else len(sc.precision_layer_k)),
                 format_scopes=(None if sc.precision_layer_format is None
                                else len(sc.precision_layer_format)),
                 error_bars=certset.error_bars())

    mesh = meshlib.make_serving_mesh(data=args.data, model=args.model)
    registry = obs.MetricsRegistry()
    registry.meta.update(arch=arch, lanes=args.lanes,
                         devices=meshlib.device_count(),
                         mesh=dict(zip(mesh.axis_names, mesh.devices.shape)),
                         precision_k=sc.precision_k)
    engine = ContinuousBatchingEngine(
        arch_cfg, sc, params, mesh=mesh, n_lanes=args.lanes,
        max_seq=args.max_seq, page_size=args.page_size,
        queue_depth=args.queue_depth, registry=registry, certset=certset)

    rng = np.random.RandomState(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.randint(max(1, args.prompt_len // 2),
                               args.prompt_len + 1))
        reqs.append(Request(
            rid=i, prompt=rng.randint(0, arch_cfg.vocab, plen).tolist(),
            max_new_tokens=args.max_new,
            arrival_step=i * args.arrival_stride))
    t0 = time.perf_counter()
    responses = engine.run(reqs)
    wall = time.perf_counter() - t0
    log.info("served", requests=len(responses), steps=engine.steps,
             wall_s=round(wall, 2),
             decode_tokens_per_s=round(
                 engine.decode_tokens / engine.decode_s, 1)
             if engine.decode_s else None,
             sample=responses[0]["tokens"][:8] if responses else None)
    if certset is not None:
        for r in responses:
            assert "certificate" in r, r
        log.info("responses certified",
                 bars=responses[0]["certificate"] if responses else None)
    if args.check_ref:
        bad = []
        for req in reqs:
            got = next(r["tokens"] for r in responses if r["id"] == req.rid)
            want = reference_generate(arch_cfg, sc, params, req.prompt,
                                      req.max_new_tokens,
                                      max_seq=args.max_seq)
            if got != want:
                bad.append((req.rid, got, want))
        if bad:
            log.error("reference mismatch", n=len(bad), first=bad[0])
            raise SystemExit(1)
        log.info("reference check passed", requests=len(reqs),
                 contract="batched+sharded == single-device eager, "
                          "token-for-token")
    if args.metrics:
        registry.write_jsonl(args.metrics)
    if args.prom:
        registry.write_prometheus(args.prom)
    return engine, responses


if __name__ == "__main__":
    main()
