"""Batched certificate analysis: trace once, analyse all classes at once.

The paper's workflow is "one analysis run per class" — each class is an
interval annotation of the input, and each run walks the whole network under
the enhanced arithmetic. Every CAA rule in :mod:`repro.core.caa` is
tensorised and row-independent along a leading batch axis, so the C runs
collapse into ONE evaluation over class-stacked inputs
(:func:`repro.core.analyze.analyze_batched`); this module adds the pieces
that turn that into a certificate pipeline:

  * :func:`stack_class_ranges` — per-class (lo, hi) envelopes → one CaaTensor;
  * :func:`required_k_batched` — per-class smallest safe precision k via a
    vectorised binary search whose every probe is one batched analysis
    shared by all still-unresolved classes (feasibility is monotone in k);
  * :func:`make_reverifier` — a jit-compiled fast path that re-checks
    argmax safety of concrete inputs at a FIXED certified format, the hot
    call the serving path makes per request batch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import analyze, caa, formats, theory
from repro.core.backend import CaaOps
from repro.core.caa import CaaConfig, CaaTensor


def stack_class_ranges(los: Sequence, his: Sequence,
                       dbar=0.0, ebar=0.0) -> CaaTensor:
    """Per-class input envelopes → one class-stacked interval CaaTensor.

    ``los[c]``/``his[c]`` is the paper's §V input annotation for class c
    (e.g. pixel envelopes in [0,1]); the result has leading axis C.
    """
    lo = np.stack([np.asarray(l, np.float64) for l in los])
    hi = np.stack([np.asarray(h, np.float64) for h in his])
    if np.any(lo > hi):
        raise ValueError("class range with lo > hi")
    return caa.from_range(lo, hi, dbar=dbar, ebar=ebar)


def batched_bounds(
    forward, params, x: CaaTensor, cfg: CaaConfig,
    weights_exact: bool = True,
) -> analyze.BatchedErrorReport:
    """One joint pass → per-class (δ̄, ε̄). Thin alias of the core entry."""
    return analyze.analyze_batched(
        forward, params, x, cfg=cfg, weights_exact=weights_exact)


# ---------------------------------------------------------------------------
# jitted probe ladder: ONE compilation serves the whole precision grid
# ---------------------------------------------------------------------------

class ProbeLadder:
    """Per-class (δ̄, ε̄) at any probed precision, jit-compiled exactly once.

    The binary search re-analyses per candidate k because the bounds carry
    u_max-dependent second-order terms; eagerly that is a full re-dispatch of
    every CAA rule per probe. Here the whole batched analysis is traced once
    with ``u_max`` as a *traced scalar argument* (CaaConfig.gamma is tracer-
    safe for exactly this), so every subsequent probe of the k grid is a call
    into the same compiled executable — at most one compilation for the whole
    ladder (``compiles`` exposes the jit cache size so benchmarks/tests can
    assert it). Per-layer trace records degrade to NaN under jit, which is
    why the pipeline re-runs ONE eager analysis at each class's final k for
    the bounds/trace it persists.
    """

    def __init__(self, forward, params, x: CaaTensor,
                 cfg: CaaConfig = caa.DEFAULT_CONFIG,
                 weights_exact: bool = True):
        n = int(jnp.shape(x.val)[0])
        base = analyze.batch_config(cfg, n)

        def bounds(params_, x_, u_max):
            kcfg = dataclasses.replace(base, u_max=u_max)
            ops = CaaOps(kcfg, weights_exact=weights_exact)
            out = forward(ops, params_, x_)
            red = tuple(range(1, out.ndim))
            dbar = jnp.broadcast_to(out.dbar, out.shape)
            ebar = jnp.broadcast_to(out.ebar, out.shape)
            return jnp.max(dbar, axis=red), jnp.max(ebar, axis=red)

        self._fn = jax.jit(bounds)
        self._params = params
        self._x = x
        self.ks_probed: list = []

    def __call__(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        import time as _time

        self.ks_probed.append(int(k))
        u = jnp.asarray(2.0 ** (1 - int(k)), jnp.float64)
        before = self.compiles
        # a probe that triggers the (single) XLA compilation is the ladder's
        # dominant cost — give it its own span name so the report separates
        # compile time from steady-state probe time
        with obs.span("ladder_probe", ladder="uniform", k=int(k)) as _sp:
            t0 = _time.perf_counter()
            abs_u, rel_u = self._fn(self._params, self._x, u)
            if self.compiles > before:
                _sp.rename("ladder_compile")
                obs.counter("ladder.compiles")
                obs.gauge("ladder.uniform_compile_s",
                          _time.perf_counter() - t0)
                if obs.enabled():
                    from repro.obs.profile import jaxpr_stats
                    obs.gauge("ladder.uniform_jaxpr_eqns", jaxpr_stats(
                        self._fn, self._params, self._x, u)["eqns"])
        return (np.asarray(abs_u, np.float64), np.asarray(rel_u, np.float64))

    @property
    def compiles(self) -> int:
        """Number of distinct compilations behind the ladder so far."""
        return int(self._fn._cache_size())


# ---------------------------------------------------------------------------
# per-class required-k: vectorised binary search over shared batched probes
# ---------------------------------------------------------------------------

FeasibleFn = Callable[[np.ndarray, np.ndarray, int], np.ndarray]


def margin_feasibility(p_star: float) -> FeasibleFn:
    """Classifier feasibility: class c is safe at precision k iff either
    output bound fits its top-1 margin — δ̄·u ≤ μ(p*) or ε̄·u ≤ ν(p*)
    (paper Section IV; whichever bound is finite/tighter suffices)."""
    mu = theory.abs_margin(p_star)
    nu = theory.rel_margin(p_star)

    def feasible(abs_u: np.ndarray, rel_u: np.ndarray, k: int) -> np.ndarray:
        u = 2.0 ** (1 - k)
        with np.errstate(invalid="ignore"):
            return (abs_u * u <= mu) | (rel_u * u <= nu)

    return feasible


def tolerance_feasibility(abs_tol: float) -> FeasibleFn:
    """Regression feasibility: absolute output error δ̄·u ≤ abs_tol (the
    pendulum/Lyapunov certificate a formal verifier consumes)."""

    def feasible(abs_u: np.ndarray, rel_u: np.ndarray, k: int) -> np.ndarray:
        del rel_u
        with np.errstate(invalid="ignore"):
            return abs_u * 2.0 ** (1 - k) <= abs_tol

    return feasible


def required_k_batched(
    forward, params, x: CaaTensor,
    feasible: FeasibleFn,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    k_min: int = 2,
    k_max: int = 53,
    weights_exact: bool = True,
    ladder: Optional[ProbeLadder] = None,
) -> Tuple[np.ndarray, Dict[int, analyze.BatchedErrorReport]]:
    """Smallest per-class k with ``feasible``, probing all classes jointly.

    CAA bounds are parameterised by u but carry u_max-dependent second-order
    terms (and the softmax abs→rel conversion saturates at large δ̄·u_max),
    so each candidate k needs a re-analysis at u_max = 2^{1-k} — feasibility
    is monotone in k (the premise :func:`repro.core.precision.decide_iterative`
    already relies on). One probe is ONE batched analysis; its result
    advances the (lo, hi) bracket of *every* unresolved class at once, so the
    total probe count is O(log k_max + #distinct answers), not C·log k_max.

    With a :class:`ProbeLadder`, search probes run through one jit-compiled
    executable (no per-k retrace); the eager reports are then produced only
    at each class's *final* k — those are what the certificate persists, so
    stored bounds and traces stay bit-identical to a sequential analysis.

    Returns (per-class k array, float NaN for uncertifiable classes;
    the eagerly-probed reports keyed by k — the caller reuses the one at
    each class's final k for the certificate bounds).
    """
    n = int(jnp.shape(x.val)[0])
    reports: Dict[int, analyze.BatchedErrorReport] = {}

    def eager_report(k: int) -> analyze.BatchedErrorReport:
        if k not in reports:
            kcfg = dataclasses.replace(cfg, u_max=2.0 ** (1 - k))
            reports[k] = batched_bounds(
                forward, params, x, kcfg, weights_exact=weights_exact)
        return reports[k]

    probe_cache: Dict[int, np.ndarray] = {}

    def probe(k: int) -> np.ndarray:
        if k not in probe_cache:
            if ladder is not None:
                abs_u, rel_u = ladder(k)
            else:
                r = eager_report(k)
                abs_u, rel_u = r.abs_u, r.rel_u
            probe_cache[k] = np.asarray(feasible(abs_u, rel_u, k), bool)
        return probe_cache[k]

    ok_max = probe(k_max)
    lo = np.full(n, k_min, np.int64)
    hi = np.full(n, k_max, np.int64)          # invariant: hi feasible (where ok)
    certifiable = ok_max.copy()
    while True:
        open_ = certifiable & (lo < hi)
        if not open_.any():
            break
        # one shared probe per round: the midpoint of the first open class
        # (guaranteed strict progress for it); every other class's bracket
        # also advances whenever monotonicity lets it, and repeated probes
        # of the same k are free (cached)
        c = int(np.argmax(open_))
        k = int((lo[c] + hi[c]) // 2)
        ok = probe(k)
        hi = np.where(certifiable & ok & (k < hi) & (k >= lo), k, hi)
        lo = np.where(certifiable & ~ok & (k >= lo) & (k < hi), k + 1, lo)
    ks = hi.astype(np.float64)
    ks[~certifiable] = np.nan
    if ladder is not None:
        # The persisted bounds come from eager reports at the final ks; the
        # ladder's jitted bounds can differ from eager in the last ulp, so
        # any class whose eager bounds land infeasible-by-a-hair steps up
        # until report and decision agree (in practice: zero iterations).
        # The loop runs to fixpoint (every class's k only moves up, bounded
        # by k_max), so on exit each surviving class has an eager report at
        # its final k that CONFIRMS feasibility — a class still infeasible
        # at k_max flips to uncertifiable rather than ship unsound bounds.
        while True:
            changed = False
            for k in sorted({int(v) for v in ks[certifiable]}):
                r = eager_report(k)
                ok_eager = np.asarray(feasible(r.abs_u, r.rel_u, k), bool)
                need_bump = certifiable & (ks == k) & ~ok_eager
                if not need_bump.any():
                    continue
                if k < k_max:
                    ks[need_bump] += 1
                else:
                    certifiable &= ~need_bump
                    ks[need_bump] = np.nan
                changed = True
            if not changed:
                break
        if (~certifiable).any():
            eager_report(k_max)   # the diagnostic report uncertifiable classes use
    return ks, reports


# ---------------------------------------------------------------------------
# serving fast path: jit re-verification at a fixed certified format
# ---------------------------------------------------------------------------

def _argmax_safe(lo: jax.Array, hi: jax.Array, pred: jax.Array) -> jax.Array:
    """jnp version of precision.classification_safe, batched over rows."""
    onehot = jax.nn.one_hot(pred, lo.shape[-1], dtype=bool)
    others_hi = jnp.max(jnp.where(onehot, -jnp.inf, hi), axis=-1)
    own_lo = jnp.take_along_axis(lo, pred[..., None], axis=-1)[..., 0]
    return own_lo > others_hi


def make_reverifier(
    forward, params, fmt, cfg: Optional[CaaConfig] = None,
    weights_exact: bool = True,
):
    """jit-compiled per-request re-verification at the certified format.

    The offline certificate fixes the format; at serving time each concrete
    request batch still wants its own rigorous argmax check (the paper's
    per-input Table-I mode). This builds ``verify(x) -> (pred, safe)``:
    one compiled CAA pass whose output enclosure is inflated to the
    format's u, then the top-1 test — amortised to microseconds after the
    first call. Trace recording degrades to NaN placeholders under jit,
    which is exactly what CaaOps does under tracing.
    """
    fmt = formats.get(fmt)
    cfg = cfg or CaaConfig(u_max=fmt.u)
    if fmt.u > cfg.u_max:
        raise ValueError("format's u exceeds the analysed u_max — re-analyse")

    @jax.jit
    def verify(x):
        ops = CaaOps(cfg, weights_exact=weights_exact)
        out = forward(ops, params, caa.make(x))
        rng = out.fp_range(fmt.u)
        pred = jnp.argmax(out.val, axis=-1)
        return pred, _argmax_safe(rng.lo, rng.hi, pred)

    return verify
