"""Content-addressed certificate store: analyse once, serve forever.

The serving path must never pay the analysis cost twice for the same
(model, params, annotation, analysis-config) request, and must never serve a
certificate proven for different weights. Both follow from one design: the
store key is the sha256 of the canonical request — model id, params digest,
class/range key, CaaConfig, decision target — so a retrain (new params
digest) or a changed analysis semantics (new CaaConfig) *is* a different
address, and stale entries can simply never be hit. On top sits a small
in-memory LRU so the serving hot path (one lookup per request batch)
touches disk only on first use.

Layout: ``<root>/<key>.json``, one CertificateSet per file, the key readable
back from the content (``request`` is stored alongside for `ls` debugging).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.core.caa import CaaConfig
from .spec import SCHEMA_VERSION, CertificateSet, _cfg_to_dict

DEFAULT_ROOT = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "certificates")


def params_digest(params) -> str:
    """sha256 over the exact parameter pytree: dtypes, shapes, bytes, and
    tree structure. Any finetune/retrain/re-quantisation changes it, which
    is precisely the invalidation the certificates need."""
    h = hashlib.sha256()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h.update(str(treedef).encode())
    for leaf in leaves:
        if isinstance(leaf, (int, float, str, bool)) or leaf is None:
            h.update(repr(leaf).encode())
            continue
        arr = np.asarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def request_key(
    model_id: str,
    params_digest_: str,
    range_key: str,
    cfg: CaaConfig,
    target: Any = None,
) -> str:
    """The content address of one certification request.

    The writer's schema version is part of the address: a v2 pipeline (which
    proves strictly more — the per-layer map) never collides with a v1
    entry, while v1 files stay readable at their old keys (the migration
    test pins this).
    """
    canon = json.dumps(
        {
            "schema": SCHEMA_VERSION,
            "model_id": model_id,
            "params_digest": params_digest_,
            "range_key": range_key,
            "cfg": _cfg_to_dict(cfg),
            "target": target,
        },
        sort_keys=True,
    )
    return hashlib.sha256(canon.encode()).hexdigest()


@dataclasses.dataclass
class StoreStats:
    hits_mem: int = 0
    hits_disk: int = 0
    misses: int = 0
    puts: int = 0
    rejected_stale: int = 0
    corrupt: int = 0
    read_v1: int = 0   # legacy uniform-k entries served (migration visibility)
    evicted: int = 0   # entries removed by gc (age/count policy)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def add(self, other: Dict[str, int]) -> "StoreStats":
        known = {f.name for f in dataclasses.fields(StoreStats)}
        merged = {k: getattr(self, k) + int(other.get(k, 0)) for k in known}
        return StoreStats(**merged)


class CertificateStore:
    """On-disk certificate sets behind an in-memory LRU.

    get/put are by request key; ``get`` additionally re-checks the stored
    params digest against the caller's expectation (defence in depth — the
    key already encodes it, but a hand-copied file must still never serve
    bounds for the wrong weights).
    """

    def __init__(self, root: str = DEFAULT_ROOT, lru_size: int = 64):
        self.root = root
        self.lru_size = int(lru_size)
        self._lru: "collections.OrderedDict[str, CertificateSet]" = (
            collections.OrderedDict())
        self.stats = StoreStats()
        os.makedirs(self.root, exist_ok=True)

    # -- paths --
    def path_for(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def _bump(self, name: str, inc: int = 1):
        """One stats increment, mirrored to the tracer's counters so a
        ``--trace`` run records hit/miss/eviction/migration activity."""
        setattr(self.stats, name, getattr(self.stats, name) + inc)
        obs.counter(f"store.{name}", inc)

    # -- hot path --
    def get(self, key: str,
            expect_params_digest: Optional[str] = None
            ) -> Optional[CertificateSet]:
        cs = self._lru.get(key)
        if cs is not None:
            self._lru.move_to_end(key)
            self._bump("hits_mem")
            # memory hits count as use too — otherwise a long-running
            # server's hottest entry looks idle to gc's age policy
            self._touch(self.path_for(key))
        else:
            path = self.path_for(key)
            if not os.path.exists(path):
                self._bump("misses")
                return None
            try:
                with open(path) as f:
                    payload = json.load(f)
                raw = payload["certificate_set"]
                cs = CertificateSet.from_dict(raw)
                if raw.get("schema_version", 1) == 1:
                    # legacy uniform-k entry: fully served (layer_k is just
                    # absent), counted so operators can see migration debt
                    self._bump("read_v1")
            except (json.JSONDecodeError, KeyError, TypeError, ValueError,
                    OSError):
                # a corrupted/truncated/unreadably-new entry is a miss, not a
                # crash — the pipeline re-analyses and overwrites it atomically
                self._bump("corrupt")
                return None
            self._bump("hits_disk")
            self._touch(path)
            self._remember(key, cs)
        if (expect_params_digest is not None
                and cs.params_digest != expect_params_digest):
            self._bump("rejected_stale")
            return None
        return cs

    def put(self, key: str, cs: CertificateSet,
            request: Optional[Dict[str, Any]] = None) -> str:
        """Crash- and concurrency-safe write.

        Each writer serialises into its OWN mkstemp file (unique name — two
        interleaved writers never share a buffer), fsyncs it so the bytes
        are durable before they become visible, then publishes with one
        atomic ``os.replace``. A reader therefore only ever observes either
        the previous complete entry or the new complete entry — never a
        truncated mix — and concurrent writers simply race to be last, each
        leaving a fully-formed file (the interleaved-writer test hammers
        exactly this).
        """
        path = self.path_for(key)
        payload = {
            "key": key,
            "request": request or {},
            "certificate_set": cs.to_dict(),
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        finally:
            try:
                os.unlink(tmp)          # no-op after a successful replace
            except FileNotFoundError:
                pass
        self._remember(key, cs)
        self._bump("puts")
        return path

    def _remember(self, key: str, cs: CertificateSet):
        self._lru[key] = cs
        self._lru.move_to_end(key)
        while len(self._lru) > self.lru_size:
            self._lru.popitem(last=False)

    # -- maintenance --
    def keys(self):
        for name in sorted(os.listdir(self.root)):
            # "_"-prefixed files are store metadata (the persistent stats
            # sidecar), not certificate entries
            if name.endswith(".json") and not name.startswith("_"):
                yield name[:-len(".json")]

    # -- stats persistence (gc --stats reads these) --
    _STATS_NAME = "_stats.json"

    def _stats_path(self) -> str:
        return os.path.join(self.root, self._STATS_NAME)

    def read_persistent_stats(self) -> Dict[str, int]:
        """Cumulative lifetime counters persisted by past processes."""
        try:
            with open(self._stats_path()) as f:
                data = json.load(f)
            return {k: int(v) for k, v in data.items()
                    if isinstance(v, (int, float))}
        except (OSError, json.JSONDecodeError, ValueError):
            return {}

    def persist_stats(self) -> Dict[str, int]:
        """Fold this process's counters into the on-disk cumulative sidecar
        (atomic read-modify-replace; the folded counters are zeroed locally
        so a second call never double-counts). Returns the new cumulative
        totals. CLI entry points call this at exit; stats stop being
        write-only internals without the hot path paying any disk I/O."""
        cumulative = self.read_persistent_stats()
        session = self.stats.to_dict()
        merged = dict(cumulative)
        for k, v in session.items():
            merged[k] = merged.get(k, 0) + v
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(merged, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._stats_path())
        finally:
            try:
                os.unlink(tmp)
            except FileNotFoundError:
                pass
        self.stats = StoreStats()
        return merged

    def entry_summary(self) -> Dict[str, Any]:
        """Scan of what is on disk right now: entry count, bytes, and the
        per-schema-version breakdown (v1/v2 counts = migration debt)."""
        n = 0
        total_bytes = 0
        by_schema: Dict[str, int] = {}
        for key in self.keys():
            path = self.path_for(key)
            try:
                total_bytes += os.stat(path).st_size
                with open(path) as f:
                    payload = json.load(f)
                v = payload["certificate_set"].get("schema_version", 1)
            except (OSError, json.JSONDecodeError, KeyError, TypeError):
                v = "unreadable"
            n += 1
            by_schema[f"v{v}"] = by_schema.get(f"v{v}", 0) + 1
        return {"entries": n, "bytes": total_bytes, "by_schema": by_schema}

    @staticmethod
    def _touch(path: str):
        """Refresh the entry's recency marker (mtime) — ``gc`` evicts
        oldest-UNUSED, so serving an entry must count as use."""
        try:
            os.utime(path)
        except OSError:
            pass                     # raced with an invalidator/gc: harmless

    def gc(self, max_age_days: Optional[float] = None,
           max_entries: Optional[int] = None) -> int:
        """Evict certificate sets by age and/or count; returns #removed.

        Entries whose recency marker (mtime — refreshed by every disk read
        and every put's atomic replace) is older than ``max_age_days`` go
        first; then, if the store still holds more than ``max_entries``,
        the oldest-unused survivors go until it fits. Deletion is per-file
        ``os.unlink`` — each entry was published by fsync+atomic-replace as
        one complete file, so eviction can never expose a torn entry, and
        losing a race with a concurrent writer/invalidator is harmless
        (FileNotFoundError is swallowed; a re-put simply re-creates the
        address). Evicted entries are dropped from the LRU and counted in
        ``stats.evicted``.
        """
        import time as _time

        entries = []
        for key in self.keys():
            path = self.path_for(key)
            try:
                entries.append((os.stat(path).st_mtime, key))
            except OSError:
                continue             # concurrently removed
        entries.sort()               # oldest-unused first
        doomed = []
        if max_age_days is not None:
            cutoff = _time.time() - float(max_age_days) * 86400.0
            doomed += [kv for kv in entries if kv[0] < cutoff]
        if max_entries is not None:
            doomed_set = set(doomed)
            survivors = [kv for kv in entries if kv not in doomed_set]
            excess = len(survivors) - int(max_entries)
            if excess > 0:
                doomed += survivors[:excess]
        n = 0
        for _, key in doomed:
            try:
                os.unlink(self.path_for(key))
                n += 1
            except FileNotFoundError:
                pass                 # a concurrent evictor won the race
            self._lru.pop(key, None)
        self._bump("evicted", n)
        return n

    def invalidate_params(self, params_digest_: str) -> int:
        """Drop every stored set proven for the given weights (e.g. after a
        rollback forces re-certification). Returns the number removed."""
        n = 0
        for key in list(self.keys()):
            path = self.path_for(key)
            try:
                with open(path) as f:
                    payload = json.load(f)
                stored = payload["certificate_set"]["params_digest"]
            except (json.JSONDecodeError, KeyError, OSError):
                continue
            if stored == params_digest_:
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass                 # a concurrent invalidator won the race
                self._lru.pop(key, None)
                n += 1
        return n

    def __len__(self) -> int:
        return sum(1 for _ in self.keys())
