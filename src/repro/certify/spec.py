"""Certificate schema: the precision facts the analyser proves, made durable.

A :class:`Certificate` is one (model, params, input-range/class) precision
fact — everything Table I of the paper reports for one class run, plus the
identifiers that make it safe to reuse: the params digest pins the exact
weights the bounds were proven for, the class key pins the input annotation,
and the :class:`repro.core.caa.CaaConfig` pins the analysis semantics
(accumulation order, trajectory mode, u_max). A :class:`CertificateSet`
bundles all classes of one model into the unit the store persists and the
serving path loads.

JSON round-trip notes: bounds are routinely ``+inf`` ("no bound of this
kind", the paper's convention) — Python's json emits/parses the literal
``Infinity`` for these, which we rely on; everything else is plain JSON.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.core import formats
from repro.core.caa import CaaConfig

# v1 (PR 1): uniform per-class required_k only.
# v2 (PR 2): adds the per-layer mixed-precision map ``layer_k`` (+ mixed meta).
# v3: adds ``layer_format`` — full per-scope FpFormat descriptors
#     (k, emax, emin, subnormal/saturation flags) certified by the format
#     synthesizer (repro.certify.formats): mantissa AND exponent range.
# Readers accept all three; writers emit v3 (and the store's content key
# carries the writer schema, so newer entries never shadow older addresses).
SCHEMA_VERSION = 3
_READABLE_SCHEMAS = (1, 2, 3)


def _cfg_to_dict(cfg: CaaConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def _cfg_from_dict(d: Dict[str, Any]) -> CaaConfig:
    known = {f.name for f in dataclasses.fields(CaaConfig)}
    return CaaConfig(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class Certificate:
    """One rigorous precision fact: bounds + the decision they license.

    Attributes:
      model_id: stable name of the analysed network (e.g. "digits/h64x32").
      params_digest: sha256 over the exact parameter tensors (see
        :func:`repro.certify.store.params_digest`) — any retrain/finetune
        changes it and invalidates the certificate.
      class_key: identifies the input annotation this was proven for
        (classifier class envelope, LM input profile, ...).
      cfg: the per-class-equivalent CaaConfig of the analysis.
      bounds_u_max: the u at which ``final_abs_u``/``final_rel_u`` were
        computed (bounds are sound for any format with u ≤ bounds_u_max).
      final_abs_u / final_rel_u: output δ̄ / ε̄ in units of u (+inf = no
        bound of that kind at this u_max).
      required_k: smallest mantissa precision k (implicit bit included)
        at which the certified property holds; None if uncertifiable.
      layer_k: per-layer mixed-precision map {layer_scope: k} (v2) — a
        rigorous refinement of required_k: serving each mapped scope's
        matmuls at its own k (everything else at required_k) still satisfies
        the certified property. None = uniform-only certificate (v1).
      layer_format: per-scope FULL format map {layer_scope: FpFormat
        descriptor dict} (v3): each scope's matmuls served in its own
        (k, emax, emin) custom format — overflow-freedom proven by IA range
        analysis at the chosen emax, underflow absorption folded into the
        bounds as the λ·2^{emin-(k-1)} absolute term. The ``""`` key is the
        default format for scopes outside the map. None = range-unbounded
        certificate (v1/v2).
      satisfied_by: standard formats with k ≥ required_k.
      trace_summary: the dominant per-layer records of the analysis pass
        (name, kind, out_mag, max_dbar, max_ebar) — the debugging view.
      meta: free-form extras (margins used, analysis seconds, ...).
    """

    model_id: str
    params_digest: str
    class_key: str
    cfg: CaaConfig
    bounds_u_max: float
    final_abs_u: float
    final_rel_u: float
    required_k: Optional[int]
    satisfied_by: List[str]
    trace_summary: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    p_star: Optional[float] = None
    layer_k: Optional[Dict[str, int]] = None
    layer_format: Optional[Dict[str, Dict[str, Any]]] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def u(self) -> Optional[float]:
        """The unit of the certified format, u = 2^{1-k}."""
        return None if self.required_k is None else 2.0 ** (1 - self.required_k)

    def format(self) -> Optional[formats.FpFormat]:
        return None if self.required_k is None else formats.custom(self.required_k)

    def error_bars(self) -> Dict[str, float]:
        """The (δ̄, ε̄, k) triple served alongside responses."""
        bars = {
            "dbar_u": self.final_abs_u,
            "ebar_u": self.final_rel_u,
            "k": self.required_k,
            "u": self.u,
        }
        if self.layer_k is not None:
            bars["layer_k"] = dict(self.layer_k)
        if self.layer_format is not None:
            bars["layer_format"] = {s: dict(f)
                                    for s, f in self.layer_format.items()}
        return bars

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["cfg"] = _cfg_to_dict(self.cfg)
        d["schema_version"] = SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Certificate":
        d = dict(d)
        version = d.pop("schema_version", 1)
        if version not in _READABLE_SCHEMAS:
            raise ValueError(
                f"certificate schema v{version} is newer than this reader "
                f"(understands {_READABLE_SCHEMAS})")
        d["cfg"] = _cfg_from_dict(d["cfg"])
        if d.get("layer_k") is not None:
            d["layer_k"] = {str(s): int(k) for s, k in d["layer_k"].items()}
        if d.get("layer_format") is not None:
            # round-trip through FpFormat so descriptors are validated and
            # normalised (unknown keys dropped, defaults filled)
            d["layer_format"] = {
                str(s): formats.from_dict(f).to_dict()
                for s, f in d["layer_format"].items()}
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=None, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Certificate":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass
class CertificateSet:
    """All certificates of one (model, params, analysis request).

    ``serving_k`` is what the serving path consumes: the smallest precision
    that simultaneously satisfies every class certificate (max over the
    per-class required_k).
    """

    model_id: str
    params_digest: str
    certificates: List[Certificate]
    p_star: Optional[float] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def serving_k(self) -> Optional[int]:
        ks = [c.required_k for c in self.certificates]
        if not ks or any(k is None for k in ks):
            return None
        return max(ks)

    @property
    def serving_layer_k(self) -> Optional[Dict[str, int]]:
        """The per-layer map the serving path may apply: for every scope any
        class certified, the pointwise max over classes of that class's
        demand there — its mapped k, or its uniform required_k for a scope
        absent from its own map (that class never certified lowering that
        scope, so only its uniform k is proven for it). The coarsest-demand
        merge is therefore sound for all classes simultaneously. None unless
        EVERY certificate is certifiable and carries a map (a class without
        one needs uniform serving_k everywhere, so no mixed map is jointly
        certified)."""
        if not self.certificates:
            return None
        for c in self.certificates:
            if c.layer_k is None or c.required_k is None:
                return None
        scopes = {s for c in self.certificates for s in c.layer_k}
        return {
            s: max(int(c.layer_k.get(s, c.required_k))
                   for c in self.certificates)
            for s in sorted(scopes)
        }

    @property
    def serving_layer_format(self) -> Optional[Dict[str, Dict[str, Any]]]:
        """The per-scope FULL-format map the serving path may apply: for
        each scope, the coarsest-demand merge over classes — k and emax
        pointwise max, emin pointwise min (every direction only shrinks
        rounding/underflow error and widens the overflow-free range, so the
        merged format is sound for every class simultaneously; a scope
        absent from a class's own map falls back to that class's ``""``
        default entry). None unless EVERY certificate carries a format map
        with consistent subnormal/saturation flags."""
        if not self.certificates:
            return None
        for c in self.certificates:
            if c.layer_format is None or "" not in c.layer_format:
                return None
        flags = {(f["has_subnormals"], f["saturating"])
                 for c in self.certificates
                 for f in c.layer_format.values()}
        if len(flags) != 1:
            return None
        subn, sat = next(iter(flags))
        scopes = {s for c in self.certificates for s in c.layer_format}
        out = {}
        for s in sorted(scopes):
            fs = [formats.from_dict(c.layer_format.get(s,
                                                       c.layer_format[""]))
                  for c in self.certificates]
            k = max(f.k for f in fs)
            emax = max(f.emax for f in fs)
            emin = min(f.emin for f in fs)
            merged = formats.FpFormat(
                f"custom_k{k}_e{emax}_{emin}", k=k, emax=emax, emin=emin,
                has_subnormals=bool(subn), saturating=bool(sat))
            # encoding-clipped entries (e4m3-style max_finite_override) cap
            # the provable range below the formula: the coarsest demand is
            # the LARGEST per-class max_finite (serving wider range is
            # sound), carried as an override when the formula overshoots it
            widest = max(f.max_finite for f in fs)
            if widest != merged.max_finite:
                merged = dataclasses.replace(merged,
                                             max_finite_override=widest)
            out[s] = merged.to_dict()
        return out

    def map_provenance(self) -> Dict[str, Dict[str, str]]:
        """Per-class provenance of the served maps: for each certificate
        that records one, ``{class_key: {"layer_k"|"layer_format":
        "synthesized"|"primary-confirmed"|"resynthesized"|"raised"|...}}``.
        "resynthesized" means the class rejected the primary profile's map
        and got its own greedy descent from its own margins; "raised" means
        the legacy raise-until-feasible fallback. Free-form meta, so v3
        certificates round-trip it with no schema change."""
        out: Dict[str, Dict[str, str]] = {}
        for c in self.certificates:
            prov = c.meta.get("map_provenance")
            if prov:
                out[c.class_key] = {str(k): str(v) for k, v in prov.items()}
        return out

    @property
    def worst_abs_u(self) -> float:
        return max((c.final_abs_u for c in self.certificates), default=float("inf"))

    @property
    def worst_rel_u(self) -> float:
        return max((c.final_rel_u for c in self.certificates), default=float("inf"))

    def lookup(self, class_key: str) -> Optional[Certificate]:
        for c in self.certificates:
            if c.class_key == class_key:
                return c
        return None

    def error_bars(self) -> Dict[str, Any]:
        """Set-level (δ̄, ε̄, k): worst bounds, the k that serves all classes
        (plus the merged per-layer map when every class certified one)."""
        k = self.serving_k
        bars = {
            "dbar_u": self.worst_abs_u,
            "ebar_u": self.worst_rel_u,
            "k": k,
            "u": None if k is None else 2.0 ** (1 - k),
        }
        lk = self.serving_layer_k
        if lk is not None:
            bars["layer_k"] = lk
        lf = self.serving_layer_format
        if lf is not None:
            bars["layer_format"] = lf
        return bars

    def summary(self) -> str:
        lines = [
            f"certificate set: {self.model_id} "
            f"(params {self.params_digest[:12]}…, {len(self.certificates)} classes)"
        ]
        for c in self.certificates:
            k = "—" if c.required_k is None else str(c.required_k)
            sat = ", ".join(c.satisfied_by[:3]) or "none"
            lines.append(
                f"  {c.class_key:24s} δ̄={c.final_abs_u:12.5g}u "
                f"ε̄={c.final_rel_u:12.5g}u  k={k:>3s}  [{sat}]"
            )
        k = self.serving_k
        lines.append(
            f"  serving precision: k={k} (u=2^{1 - k})" if k is not None
            else "  serving precision: uncertified"
        )
        lk = self.serving_layer_k
        if lk is not None:
            per = ", ".join(f"{s}:k={v}" for s, v in lk.items())
            lines.append(f"  mixed-precision map: {per}")
        lf = self.serving_layer_format
        if lf is not None:
            per = ", ".join(
                f"{s or '<default>'}:(k={f['k']},e[{f['emin']},{f['emax']}],"
                f"{1 + formats.exponent_bits(f['emax'], f['emin']) + f['k'] - 1}b)"
                for s, f in lf.items())
            lines.append(f"  certified formats: {per}")
        prov = self.map_provenance()
        if prov:
            per = "; ".join(
                f"{ck}: " + ",".join(f"{k}={v}" for k, v in sorted(p.items()))
                for ck, p in sorted(prov.items()))
            lines.append(f"  map provenance: {per}")
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": SCHEMA_VERSION,
            "model_id": self.model_id,
            "params_digest": self.params_digest,
            "p_star": self.p_star,
            "meta": self.meta,
            "certificates": [c.to_dict() for c in self.certificates],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CertificateSet":
        version = d.get("schema_version", 1)
        if version not in _READABLE_SCHEMAS:
            raise ValueError(
                f"certificate-set schema v{version} is newer than this "
                f"reader (understands {_READABLE_SCHEMAS})")
        return cls(
            model_id=d["model_id"],
            params_digest=d["params_digest"],
            p_star=d.get("p_star"),
            meta=dict(d.get("meta", {})),
            certificates=[Certificate.from_dict(c) for c in d["certificates"]],
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "CertificateSet":
        return cls.from_dict(json.loads(s))


def trace_summary(records, top_n: int = 8) -> List[Dict[str, Any]]:
    """The dominant layers of a trace, JSON-ready (inf kept, nan dropped)."""
    import math

    def _key(r):
        v = r.max_dbar
        return -1.0 if math.isnan(v) else (math.inf if math.isinf(v) else v)

    ranked = sorted(records, key=_key, reverse=True)[:top_n]
    out = []
    for r in ranked:
        out.append({
            "name": r.name,
            "kind": r.kind,
            "shape": list(r.shape),
            "out_mag": None if math.isnan(r.out_mag) else r.out_mag,
            "max_dbar": None if math.isnan(r.max_dbar) else r.max_dbar,
            "max_ebar": None if math.isnan(r.max_ebar) else r.max_ebar,
        })
    return out
