"""Per-layer mixed-precision certificates: sensitivity-driven layer→k maps.

The paper's key observation is that well-conditioned activation layers
*recover* the relative accuracy the matmul-heavy layers lose — precision
demand is per-layer, not global. PR 1's certificates assign one uniform k
per class; this module extends them with a rigorous per-layer map
``{layer_scope: k}``.

Soundness model (how one analysis covers heterogeneous precisions):

  * all bounds stay in units of ONE reference ``u_ref = 2^{1-k_ref}`` where
    ``k_ref = min over layers of k`` (the coarsest format in the map);
  * a layer running at precision ``k_l`` has unit ``u_l = 2^{1-k_l} ≤ u_ref``,
    so its fresh roundings cost ``½·u_l = ½·(u_l/u_ref)`` units of u_ref —
    exactly what :class:`MixedCaaOps` charges by scaling ``round_scale`` to
    ``u_l/u_ref`` inside that layer's scope;
  * every second-order / γ-denominator term is bounded at ``u_max = u_ref``,
    an upper bound for every layer's actual unit — conservative, rigorous.

With all scales equal to 1 this degenerates bit-for-bit to the uniform
batched analysis, which is the invariant the greedy descent starts from.

The probe ladder is jit-compiled ONCE over (u_ref, scale-vector): the scope
structure is static, the scales are traced scalars, so the whole greedy
descent (and the sensitivity ranking, which is just one-hot scale vectors)
runs through a single compiled executable — no per-precision recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import analyze, caa
from repro.core.analyze import resolve_scope_value
from repro.core.backend import CaaOps, StackedCaaOps
from repro.core.caa import CaaConfig, CaaTensor
from .batch import FeasibleFn

_F64 = jnp.float64


class MixedCaaOps(CaaOps):
    """CaaOps whose fresh-rounding scale follows the current scope.

    ``scope_scales[scope] = u_scope / u_ref`` (a float or a jax tracer);
    ``default_scale`` applies outside every mapped scope. Propagation terms
    are untouched — only the *fresh* roundings an op introduces are charged
    at the scope's own unit, which is precisely the semantics of running
    that layer's arithmetic in its own format.
    """

    def __init__(self, cfg: CaaConfig, scope_scales: Dict[str, object],
                 default_scale=1.0, weights_exact: bool = True):
        super().__init__(cfg, weights_exact=weights_exact)
        self._scales = dict(scope_scales)
        self._default = default_scale
        self._base_cfg = cfg
        self._apply_scale(default_scale)

    def _apply_scale(self, s):
        self.cfg = dataclasses.replace(
            self._base_cfg, round_scale=self._base_cfg.round_scale * s)

    def _scope_changed(self):
        super()._scope_changed()
        self._apply_scale(
            resolve_scope_value(self._scope, self._scales, self._default))


def mixed_scale_vectors(scope_keys: Sequence[str],
                        layer_k: Dict[str, int],
                        default_k: int) -> Tuple[float, np.ndarray, int]:
    """(u_ref, scales, k_ref) encoding a concrete {scope: k} map.

    Entry i of ``scales`` is scope_keys[i]'s ``u/u_ref``, the last entry
    the default's; ``u_ref = 2^{1-k_ref}`` with ``k_ref`` the coarsest k in
    play. The mantissa sibling of :func:`repro.certify.formats.ladder.
    scope_vectors` — every probe interface (MixedProbeLadder and the
    format ladder's mixed view) encodes through here so the reference-unit
    convention can never drift between them.
    """
    ks = [int(layer_k[s]) for s in scope_keys] + [int(default_k)]
    k_ref = min(ks)
    u_ref = 2.0 ** (1 - k_ref)
    scales = np.asarray([2.0 ** (1 - k) / u_ref for k in ks], np.float64)
    return u_ref, scales, k_ref


# the one-hot sensitivity-probe convention lives next to the stacked
# analysis it feeds; re-exported here for the ladder interfaces
onehot_scale_vector = analyze.onehot_scale_vector


class MixedProbeLadder:
    """Per-class (δ̄, ε̄) under a per-layer k map — one jit compilation total.

    The jitted function takes ``u_ref`` and a scale vector (one entry per
    scope key + one default) as traced arguments; every probe of the greedy
    descent, and every one-hot sensitivity probe, reuses the same
    executable. ``compiles`` exposes the jit cache size for the
    at-most-one-compilation assertion.

    ``stacked=True`` runs the traced analysis through
    :class:`repro.core.backend.StackedCaaOps`: each ``layer_loop`` is ONE
    ``lax.scan`` whose body gathers its layer's scale from the traced
    vector by the carry's layer index — the compiled HLO is O(1) in model
    depth, which is what makes per-layer maps affordable for scan-shaped
    LM architectures (``scope_keys`` then name concrete ``layer{i}``
    lanes plus any scopes outside the stack).
    """

    def __init__(self, forward, params, x: CaaTensor,
                 scope_keys: Sequence[str],
                 cfg: CaaConfig = caa.DEFAULT_CONFIG,
                 weights_exact: bool = True,
                 stacked: bool = False):
        self.scope_keys: Tuple[str, ...] = tuple(scope_keys)
        if not self.scope_keys:
            raise ValueError("no scope keys — the model must enter named "
                             "bk.scope(...) blocks to get per-layer k")
        n = int(jnp.shape(x.val)[0])
        base = analyze.batch_config(cfg, n)
        keys = self.scope_keys

        def bounds(params_, x_, u_max, scales):
            sm = {key: scales[i] for i, key in enumerate(keys)}
            kcfg = dataclasses.replace(base, u_max=u_max)
            if stacked:
                ops = StackedCaaOps(kcfg, sm,
                                    default_scale=scales[len(keys)],
                                    weights_exact=weights_exact)
            else:
                ops = MixedCaaOps(kcfg, sm, default_scale=scales[len(keys)],
                                  weights_exact=weights_exact)
            out = forward(ops, params_, x_)
            red = tuple(range(1, out.ndim))
            dbar = jnp.broadcast_to(out.dbar, out.shape)
            ebar = jnp.broadcast_to(out.ebar, out.shape)
            return jnp.max(dbar, axis=red), jnp.max(ebar, axis=red)

        self._fn = jax.jit(bounds)
        self._params = params
        self._x = x
        self.probes = 0

    def _run(self, u_ref: float, scales: np.ndarray):
        self.probes += 1
        before = self.compiles
        with obs.span("ladder_probe", ladder="mixed") as _sp:
            a, e = self._fn(self._params, self._x,
                            jnp.asarray(u_ref, _F64),
                            jnp.asarray(scales, _F64))
            if self.compiles > before:
                _sp.rename("ladder_compile")
                obs.counter("ladder.compiles")
        return np.asarray(a, np.float64), np.asarray(e, np.float64)

    def __call__(self, layer_k: Dict[str, int], default_k: int):
        """Bounds for a concrete map. Returns (abs_u, rel_u, k_ref): per-class
        bounds in units of u_ref = 2^{1-k_ref}, k_ref = coarsest k in play."""
        u_ref, scales, k_ref = mixed_scale_vectors(
            self.scope_keys, layer_k, default_k)
        abs_u, rel_u = self._run(u_ref, scales)
        return abs_u, rel_u, k_ref

    def sensitivity(self, scope_key: str, at_k: int) -> float:
        """Layer's isolated contribution to the final absolute bound: fresh
        roundings enabled ONLY in this scope (one-hot scale vector), at
        precision ``at_k`` — the jitted equivalent of
        :func:`repro.core.analyze.sensitivity`, zero extra compilations."""
        scales = onehot_scale_vector(self.scope_keys, scope_key)
        abs_u, _ = self._run(2.0 ** (1 - int(at_k)), scales)
        return float(np.max(abs_u))

    @property
    def compiles(self) -> int:
        return int(self._fn._cache_size())


@dataclasses.dataclass
class MixedPlan:
    """Result of the greedy per-layer descent.

    ``layer_k`` is the certified map; ``abs_u``/``rel_u`` are the per-class
    bounds of the final map in units of ``u_ref = 2^{1-k_ref}``. The map is
    valid exactly for serving that quantises each mapped scope's matmuls to
    its k and everything else to ``default_k``.
    """

    layer_k: Dict[str, int]
    uniform_k: int
    default_k: int
    k_ref: int
    abs_u: np.ndarray
    rel_u: np.ndarray
    sensitivity: Dict[str, float]
    probes: int
    compiles: int
    feasible: bool

    def mean_k(self, layer_flops: Optional[Dict[str, float]] = None) -> float:
        return flop_weighted_mean_k(self.layer_k, layer_flops)

    def savings(self, layer_flops: Optional[Dict[str, float]] = None) -> float:
        """FLOP-weighted mean-k reduction vs the uniform certificate."""
        return self.uniform_k - self.mean_k(layer_flops)


def flop_weighted_mean_k(layer_k: Dict[str, int],
                         layer_flops: Optional[Dict[str, float]] = None
                         ) -> float:
    """Σ flops_l·k_l / Σ flops_l — the serving-cost view of a mixed map
    (unweighted mean when no FLOP counts are given)."""
    if not layer_k:
        raise ValueError("empty layer_k map")
    w = {s: float((layer_flops or {}).get(s, 1.0)) for s in layer_k}
    tot = sum(w.values())
    if tot <= 0:
        raise ValueError("layer_flops sum to zero")
    return sum(w[s] * layer_k[s] for s in layer_k) / tot


def greedy_mixed_assignment(
    forward, params, x: CaaTensor,
    feasible: FeasibleFn,
    uniform_k: int,
    scope_keys: Optional[Sequence[str]] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    k_min: int = 2,
    weights_exact: bool = True,
    ladder: Optional[MixedProbeLadder] = None,
    stacked: bool = False,
) -> MixedPlan:
    """Greedy sensitivity-driven per-layer descent from a uniform k.

    Start every layer at the uniform certified ``uniform_k`` (the base case,
    which equals the uniform analysis bit-for-bit). Rank layers by their
    isolated error contribution (least sensitive first), then for each layer
    drop its k one step at a time until the joint feasibility check — every
    class's (δ̄, ε̄) at u_ref against its decision margins — fails, and
    backtrack one step. Feasibility is monotone in each layer's k (raising a
    k only shrinks fresh-rounding charges), so the greedy endpoint is a
    certified map with ``layer_k[s] ≤ uniform_k`` pointwise.
    """
    if scope_keys is None:
        scope_keys = analyze.discover_scopes(forward, params, x, cfg)
    if ladder is None:
        ladder = MixedProbeLadder(forward, params, x, scope_keys, cfg=cfg,
                                  weights_exact=weights_exact,
                                  stacked=stacked)
    uniform_k = int(uniform_k)

    with obs.span("sensitivity_rank", scopes=len(ladder.scope_keys)):
        sens = {s: ladder.sensitivity(s, uniform_k)
                for s in ladder.scope_keys}
    order = sorted(ladder.scope_keys, key=lambda s: (sens[s], s))

    layer_k = {s: uniform_k for s in ladder.scope_keys}

    def ok(lk: Dict[str, int]) -> bool:
        abs_u, rel_u, k_ref = ladder(lk, uniform_k)
        return bool(np.all(feasible(abs_u, rel_u, k_ref)))

    base_ok = ok(layer_k)
    if base_ok:
        for s in order:
            with obs.span("greedy_descent_step", scope=s,
                          start_k=layer_k[s]) as _sp:
                while layer_k[s] > k_min:
                    layer_k[s] -= 1
                    if not ok(layer_k):
                        layer_k[s] += 1   # backtrack one step
                        break
                _sp.set(final_k=layer_k[s])
    abs_u, rel_u, k_ref = ladder(layer_k, uniform_k)
    return MixedPlan(
        layer_k=dict(layer_k),
        uniform_k=uniform_k,
        default_k=uniform_k,
        k_ref=k_ref,
        abs_u=abs_u,
        rel_u=rel_u,
        sensitivity=sens,
        probes=ladder.probes,
        compiles=ladder.compiles,
        feasible=base_ok,
    )
