"""CLI: produce (or fetch) a persisted certificate set.

  # the paper's Digits classifier, all 10 classes batched, top-1 safe at p*:
  PYTHONPATH=src python -m repro.certify --arch digits --p-star 0.6

  # the pendulum Lyapunov net, absolute-tolerance certificate:
  PYTHONPATH=src python -m repro.certify --arch pendulum --abs-tol 1e-3

  # full custom-format synthesis (per-scope k AND exponent range, v3):
  PYTHONPATH=src python -m repro.certify --arch digits --formats --mixed

  # a registered LM architecture (reduced config), decode-argmax certificate:
  PYTHONPATH=src python -m repro.certify --arch qwen2_7b

  # scan-native LM mixed-precision / custom-format certificates (per-layer
  # {layer{i}|head: k} maps probed through ONE compiled lax.scan analysis;
  # "transformer" is an alias for the default dense arch):
  PYTHONPATH=src python -m repro.certify --arch transformer --mixed --max-layers 2
  PYTHONPATH=src python -m repro.certify --arch qwen2_7b --mixed --formats \\
      --profiles 4,16

  # store maintenance: evict entries unused for 30 days, keep at most 256:
  PYTHONPATH=src python -m repro.certify gc --max-age-days 30 --max-entries 256

A second identical invocation is served from the content-addressed store —
no re-analysis (watch the 'from store' line and the timing collapse).
Params are derived deterministically (seeded init + seeded training), so
re-runs address the same certificate.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import JOps
from .pipeline import certify, certify_lm
from .store import DEFAULT_ROOT, CertificateStore


def _train_digits(params, imgs, labels, steps: int, lr: float = 0.2):
    from repro.models import paper_models as PM

    bk = JOps()

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(PM.digits_logits(bk, p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)

    n = imgs.shape[0]
    for i in range(steps):
        idx = np.random.RandomState(i).choice(n, 64)
        params = step(params, jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]))
    return params


def _digits(args, store):
    from repro.data import synthetic_digits
    from repro.models import paper_models as PM

    imgs, labels = synthetic_digits.make_dataset(args.samples, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0), h1=args.h1, h2=args.h2)
    params = _train_digits(params, imgs, labels, args.train_steps)
    acc = float((jnp.argmax(
        PM.digits_logits(JOps(), params, jnp.asarray(imgs)), -1)
        == jnp.asarray(labels)).mean())
    print(f"digits model h1={args.h1} h2={args.h2}: train acc {acc:.3f}")

    los, his = [], []
    for c in range(10):
        m = imgs[labels == c].mean(0)
        los.append(np.clip(m - args.pad, 0.0, 1.0))
        his.append(np.clip(m + args.pad, 0.0, 1.0))
    d_in = imgs.shape[-1]
    # matmul FLOPs per scoped block — the weights of the mean-k savings
    flops = {"dense1": 2.0 * d_in * args.h1,
             "dense2": 2.0 * args.h1 * args.h2,
             "dense3": 2.0 * args.h2 * 10,
             "softmax": 4.0 * 10}
    return certify(
        PM.digits_forward, params, los, his, p_star=args.p_star,
        model_id=f"digits/h{args.h1}x{args.h2}",
        class_keys=[f"digit{c}(±{args.pad})" for c in range(10)],
        store=store, k_max=args.k_max,
        mixed=args.mixed, layer_flops=flops, formats=args.formats,
    )


def _pendulum(args, store):
    from repro.models import paper_models as PM

    params = PM.init_pendulum(jax.random.PRNGKey(2), h=args.h1)
    lo, hi = np.full(2, -6.0), np.full(2, 6.0)
    flops = {"dense1": 2.0 * 2 * args.h1,
             "dense2": 2.0 * args.h1 * args.h1,
             "dense3": 2.0 * args.h1 * 1}
    return certify(
        PM.pendulum_forward, params, [lo], [hi], abs_tol=args.abs_tol,
        model_id=f"pendulum/h{args.h1}",
        class_keys=["state[-6,6]^2"],
        store=store, k_max=args.k_max,
        mixed=args.mixed, layer_flops=flops, formats=args.formats,
    )


def _gc(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.certify gc",
        description="evict old/excess certificate-store entries")
    ap.add_argument("--store", default=DEFAULT_ROOT)
    ap.add_argument("--max-age-days", type=float, default=None,
                    help="evict entries unused for more than N days")
    ap.add_argument("--max-entries", type=int, default=None,
                    help="keep at most M entries (oldest-unused evicted)")
    args = ap.parse_args(argv)
    if args.max_age_days is None and args.max_entries is None:
        ap.error("pass --max-age-days and/or --max-entries")
    store = CertificateStore(args.store)
    n = store.gc(max_age_days=args.max_age_days,
                 max_entries=args.max_entries)
    print(f"evicted {n} entr{'y' if n == 1 else 'ies'} from {store.root} "
          f"({len(store)} remain)  |  store stats: {store.stats}")
    return n


def main(argv=None):
    import sys
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "gc":
        return _gc(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.certify",
        description="batched certificate pipeline: analyse, persist, serve")
    ap.add_argument("--arch", default="digits",
                    help="digits | pendulum | any registered LM arch")
    ap.add_argument("--p-star", type=float, default=0.6)
    ap.add_argument("--abs-tol", type=float, default=1e-3,
                    help="absolute tolerance (pendulum mode)")
    ap.add_argument("--store", default=DEFAULT_ROOT)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--pad", type=float, default=0.02,
                    help="class envelope half-width around the class mean")
    ap.add_argument("--h1", type=int, default=64)
    ap.add_argument("--h2", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--k-max", type=int, default=None,
                    help="search ceiling (default: 53; LM archs: 24, "
                         "or 53 with --mixed/--formats)")
    ap.add_argument("--seq", type=int, default=8, help="LM profile length")
    ap.add_argument("--batch", type=int, default=1,
                    help="LM profile batch (sequences certified jointly)")
    ap.add_argument("--max-layers", type=int, default=None,
                    help="cap the LM arch's layer count (reduced smoke runs "
                         "of the scan-native analysis)")
    ap.add_argument("--profiles", default=None, metavar="S1,S2,...",
                    help="extra sequence lengths whose range passes widen "
                         "the --formats overflow (emax) evidence, "
                         "aggregated via analyze.aggregate_ranges")
    ap.add_argument("--mixed", action="store_true",
                    help="additionally certify a per-layer {scope: k} map "
                         "(sensitivity-driven greedy descent) and report the "
                         "FLOP-weighted mean-k savings vs the uniform k; LM "
                         "archs certify through the scan-native stacked "
                         "analysis (one compiled probe ladder)")
    ap.add_argument("--formats", action="store_true",
                    help="additionally certify FULL per-scope custom formats "
                         "(k, emin, emax): IA range analysis proves the "
                         "smallest overflow-free emax, underflow absorption "
                         "is folded into the bounds, and schema-v3 "
                         "certificates carry {scope: FpFormat} maps; reports "
                         "total-bits savings vs uniform-k + binary32 range")
    args = ap.parse_args(argv)
    if args.arch == "transformer":   # CI-smoke-friendly alias
        args.arch = "qwen2_7b"
    if args.arch == "digits" and not 0.5 < args.p_star <= 1.0:
        ap.error("--p-star must be in (0.5, 1] (guaranteed top-1 probability)")
    if args.arch == "pendulum" and args.abs_tol <= 0:
        ap.error("--abs-tol must be positive")

    store = CertificateStore(args.store)
    t0 = time.perf_counter()
    if args.arch == "digits":
        args.k_max = args.k_max or 53
        cs = _digits(args, store)
    elif args.arch == "pendulum":
        args.k_max = args.k_max or 53
        cs = _pendulum(args, store)
    else:
        arch_cfg = None
        if args.max_layers is not None:
            import dataclasses

            from repro import configs

            smoke = configs.get(args.arch).SMOKE
            arch_cfg = dataclasses.replace(
                smoke, n_layers=min(args.max_layers, smoke.n_layers))
        profiles = tuple(int(s) for s in args.profiles.split(",")) \
            if args.profiles else ()
        cs = certify_lm(
            args.arch, arch_cfg, seq=args.seq, batch=args.batch, store=store,
            k_max=args.k_max or (53 if (args.mixed or args.formats) else 24),
            mixed=args.mixed, formats=args.formats, profiles=profiles)
    dt = time.perf_counter() - t0

    print()
    print(cs.summary())
    print()
    if cs.meta.get("from_store"):
        print(f"served FROM STORE in {cs.meta['lookup_seconds']*1e3:.1f} ms "
              f"(no re-analysis; store: {store.root})")
    else:
        probes = cs.meta.get("probes", [])
        n_probes = probes if isinstance(probes, int) else len(probes)
        print(f"analysed in {cs.meta['analysis_seconds']:.2f} s "
              f"({n_probes} precision probes, "
              f"all classes per probe batched, "
              f"{cs.meta.get('ladder_compiles', '?')} ladder compilation(s))")
        print(f"persisted to {store.root} — re-run to load from the store")
    if cs.meta.get("scan_native") and not cs.meta.get("from_store"):
        print(f"scan-native analysis: {len(cs.meta.get('scope_keys', []))} "
              f"stacked scopes, {cs.meta.get('probes', '?')} probes through "
              f"{cs.meta.get('ladder_compiles', '?')} compiled ladder(s)")
    mx = cs.meta.get("mixed")
    if mx:
        if mx.get("applied"):
            print(f"mixed precision: uniform k={mx['uniform_k']} → "
                  f"FLOP-weighted mean k={mx['mean_k_flop_weighted']:.2f} "
                  f"(saves {mx['savings_k_flop_weighted']:.2f} bits/FLOP; "
                  f"{mx['probes']} ladder probes, "
                  f"{mx['ladder_compiles']} compilation)")
            if "savings_bits_vs_binary32" in mx:
                s = mx["savings_bits_vs_binary32"]
                verdict = (f"beats uniform binary32 by {s:.2f}" if s > 0
                           else f"still {-s:.2f} above uniform binary32")
                print(f"    serving cost {mx['mean_bits_flop_weighted']:.2f} "
                      f"bits/value — {verdict} bits/value")
        else:
            print(f"mixed precision: not applied — {mx.get('reason')}")
    fm = cs.meta.get("formats")
    if fm:
        if fm.get("applied"):
            print(f"custom formats: baseline {fm['baseline_bits']} bits "
                  f"(uniform k={fm['uniform_k']} + binary32 range) → "
                  f"FLOP-weighted mean {fm['mean_bits_flop_weighted']:.2f} "
                  f"bits (saves {fm['savings_bits_flop_weighted']:.2f} "
                  f"bits/value; {fm['probes']} lattice probes, "
                  f"{fm['ladder_compiles']} compilation)")
            from repro.core import formats as F
            for s, f in sorted(fm["layer_format"].items()):
                r = fm["scope_ranges"].get(s, {})
                ma = r.get("max_abs")
                bits = 1 + F.exponent_bits(f["emax"], f["emin"]) + f["k"] - 1
                print(f"    {s or '<default>':12s} k={f['k']:>2d} "
                      f"e[{f['emin']},{f['emax']}] = {bits:>2d} bits  "
                      f"(range sup {ma if ma is None else round(ma, 4)})")
            if "savings_bits_vs_binary32" in fm:
                s = fm["savings_bits_vs_binary32"]
                print(f"    cheapest certified serving "
                      + (f"beats uniform binary32 by {s:.2f} bits/value"
                         if s > 0 else
                         f"is {-s:.2f} bits/value above uniform binary32"))
            if fm.get("attached") is False:
                print(f"    ({fm.get('attach_reason')})")
        else:
            print(f"custom formats: not applied — {fm.get('reason')}")
    print(f"total {dt:.2f} s  |  store stats: {store.stats}")
    return cs


if __name__ == "__main__":
    main()
