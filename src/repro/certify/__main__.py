"""CLI: produce (or fetch) a persisted certificate set.

  # the paper's Digits classifier, all 10 classes batched, top-1 safe at p*:
  PYTHONPATH=src python -m repro.certify --arch digits --p-star 0.6

  # the pendulum Lyapunov net, absolute-tolerance certificate:
  PYTHONPATH=src python -m repro.certify --arch pendulum --abs-tol 1e-3

  # full custom-format synthesis (per-scope k AND exponent range, v3):
  PYTHONPATH=src python -m repro.certify --arch digits --formats --mixed

  # a registered LM architecture (reduced config), decode-argmax certificate:
  PYTHONPATH=src python -m repro.certify --arch qwen2_7b

  # scan-native LM mixed-precision / custom-format certificates (per-layer
  # {layer{i}|head: k} maps probed through ONE compiled lax.scan analysis;
  # "transformer" is an alias for the default dense arch):
  PYTHONPATH=src python -m repro.certify --arch transformer --mixed --max-layers 2
  PYTHONPATH=src python -m repro.certify --arch qwen2_7b --mixed --formats \\
      --profiles 4,16

  # store maintenance: evict entries unused for 30 days, keep at most 256:
  PYTHONPATH=src python -m repro.certify gc --max-age-days 30 --max-entries 256

A second identical invocation is served from the content-addressed store —
no re-analysis (watch the 'from store' line and the timing collapse).
Params are derived deterministically (seeded init + seeded training), so
re-runs address the same certificate.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.backend import JOps
from .pipeline import certify, certify_lm
from .store import DEFAULT_ROOT, CertificateStore

log = obs.get_logger("certify")


def _train_digits(params, imgs, labels, steps: int, lr: float = 0.2):
    from repro.models import paper_models as PM

    bk = JOps()

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(PM.digits_logits(bk, p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)

    n = imgs.shape[0]
    for i in range(steps):
        idx = np.random.RandomState(i).choice(n, 64)
        params = step(params, jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]))
    return params


def _eager_format_opts(args):
    """format_opts for the EAGER (digits/pendulum) pipeline: only user-set
    affine knobs enter (the opts are part of the store request key, so the
    default must keep addressing the same stored certificates as before the
    flags existed). Setting either knob turns the eager affine tightening
    pass on via synthesize_formats' own affine plumbing."""
    opts = {}
    if args.affine_budget is not None:
        opts["affine_budget"] = args.affine_budget
    if args.affine_rank is not None:
        opts["affine_rank"] = args.affine_rank
    return opts or None


def _digits(args, store):
    from repro.data import synthetic_digits
    from repro.models import paper_models as PM

    imgs, labels = synthetic_digits.make_dataset(args.samples, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0), h1=args.h1, h2=args.h2)
    params = _train_digits(params, imgs, labels, args.train_steps)
    acc = float((jnp.argmax(
        PM.digits_logits(JOps(), params, jnp.asarray(imgs)), -1)
        == jnp.asarray(labels)).mean())
    log.info("trained digits model", h1=args.h1, h2=args.h2,
             train_acc=round(acc, 3))

    los, his = [], []
    for c in range(10):
        m = imgs[labels == c].mean(0)
        los.append(np.clip(m - args.pad, 0.0, 1.0))
        his.append(np.clip(m + args.pad, 0.0, 1.0))
    d_in = imgs.shape[-1]
    # matmul FLOPs per scoped block — the weights of the mean-k savings
    flops = {"dense1": 2.0 * d_in * args.h1,
             "dense2": 2.0 * args.h1 * args.h2,
             "dense3": 2.0 * args.h2 * 10,
             "softmax": 4.0 * 10}
    cs = certify(
        PM.digits_forward, params, los, his, p_star=args.p_star,
        model_id=f"digits/h{args.h1}x{args.h2}",
        class_keys=[f"digit{c}(±{args.pad})" for c in range(10)],
        store=store, k_max=args.k_max,
        mixed=args.mixed, layer_flops=flops, formats=args.formats,
        format_opts=_eager_format_opts(args),
    )
    return cs, flops


def _pendulum(args, store):
    from repro.models import paper_models as PM

    params = PM.init_pendulum(jax.random.PRNGKey(2), h=args.h1)
    lo, hi = np.full(2, -6.0), np.full(2, 6.0)
    flops = {"dense1": 2.0 * 2 * args.h1,
             "dense2": 2.0 * args.h1 * args.h1,
             "dense3": 2.0 * args.h1 * 1}
    cs = certify(
        PM.pendulum_forward, params, [lo], [hi], abs_tol=args.abs_tol,
        model_id=f"pendulum/h{args.h1}",
        class_keys=["state[-6,6]^2"],
        store=store, k_max=args.k_max,
        mixed=args.mixed, layer_flops=flops, formats=args.formats,
        format_opts=_eager_format_opts(args),
    )
    return cs, flops


def _cost_report(out_path: str, cs, layer_flops, tokens: int = 1):
    """The ``--cost-report`` what-if pass: fit a measured cost model from a
    quick kernel profile, re-score the certificate's serving map by
    predicted latency vs the FLOP-weighted-bits objective, persist both as
    JSON, and print the per-scope comparison (the objective-swap evidence;
    the greedy descent itself still optimises bits — a follow-up)."""
    import json
    import os

    from repro.obs import costmodel as CM
    from repro.obs import profile as P

    with obs.span("cost_report_profile"):
        # minimal measured sweep: one point per kernel class is enough to
        # fit achieved (α, β) rates; the full sweep lives in kernel_bench
        rows = P.profile_kernels(
            gemm_shapes=((128, 128, 128),), ks=(8,),
            formats=((8, 15, -14),),
            flash_shapes=((2, 256, 2, 2, 64),),
            blocks=((128, 128, 128),), reps=3, warmup=1)
    model = CM.fit_cost_model(rows)
    with obs.span("cost_report_score"):
        rep = CM.certificate_cost_report(cs, layer_flops, model,
                                         tokens=tokens)
    payload = {"schema": 1, "cost_model": model.to_dict(), "report": rep}
    d = os.path.dirname(os.path.abspath(out_path))
    os.makedirs(d, exist_ok=True)
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    os.replace(tmp, out_path)
    print()
    print(CM.render_cost_report(rep))
    log.info("cost report written", path=out_path,
             scopes=len(rep["scopes"]),
             rank_agreement=round(rep["rank_agreement"], 3),
             disagreements=len(rep["disagreements"]))
    return rep


def _gc(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.certify gc",
        description="evict old/excess certificate-store entries, or "
                    "inspect the store's cumulative stats")
    ap.add_argument("--store", default=DEFAULT_ROOT)
    ap.add_argument("--max-age-days", type=float, default=None,
                    help="evict entries unused for more than N days")
    ap.add_argument("--max-entries", type=int, default=None,
                    help="keep at most M entries (oldest-unused evicted)")
    ap.add_argument("--stats", action="store_true",
                    help="print cumulative store stats (lifetime hits/"
                         "misses/evictions/v1-reads) and the on-disk entry "
                         "breakdown; no eviction unless a policy flag is "
                         "also given")
    args = ap.parse_args(argv)
    if (args.max_age_days is None and args.max_entries is None
            and not args.stats):
        ap.error("pass --max-age-days and/or --max-entries (or --stats)")
    store = CertificateStore(args.store)
    n = 0
    if args.max_age_days is not None or args.max_entries is not None:
        n = store.gc(max_age_days=args.max_age_days,
                     max_entries=args.max_entries)
        log.info("gc done", evicted=n, remaining=len(store),
                 root=store.root)
    if args.stats:
        lifetime = store.persist_stats()
        scan = store.entry_summary()
        print(f"store: {store.root}")
        print(f"  entries: {scan['entries']}  "
              f"({scan['bytes']} bytes on disk)")
        for v, cnt in sorted(scan["by_schema"].items()):
            print(f"    schema {v}: {cnt}")
        print("  lifetime stats (all processes):")
        for k in sorted(lifetime):
            print(f"    {k:<16} {lifetime[k]}")
    else:
        store.persist_stats()
    return n


def main(argv=None):
    import sys
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "gc":
        return _gc(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.certify",
        description="batched certificate pipeline: analyse, persist, serve")
    ap.add_argument("--arch", default="digits",
                    help="digits | pendulum | any registered LM arch")
    ap.add_argument("--p-star", type=float, default=0.6)
    ap.add_argument("--abs-tol", type=float, default=1e-3,
                    help="absolute tolerance (pendulum mode)")
    ap.add_argument("--store", default=DEFAULT_ROOT)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--pad", type=float, default=0.02,
                    help="class envelope half-width around the class mean")
    ap.add_argument("--h1", type=int, default=64)
    ap.add_argument("--h2", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--k-max", type=int, default=None,
                    help="search ceiling (default: 53; LM archs: 24, "
                         "or 53 with --mixed/--formats)")
    ap.add_argument("--seq", type=int, default=8, help="LM profile length")
    ap.add_argument("--batch", type=int, default=1,
                    help="LM profile batch (sequences certified jointly)")
    ap.add_argument("--max-layers", type=int, default=None,
                    help="cap the LM arch's layer count (reduced smoke runs "
                         "of the scan-native analysis)")
    ap.add_argument("--profiles", default=None, metavar="S1,S2,...",
                    help="extra sequence lengths whose range passes widen "
                         "the --formats overflow (emax) evidence, "
                         "aggregated via analyze.aggregate_ranges")
    ap.add_argument("--mixed", action="store_true",
                    help="additionally certify a per-layer {scope: k} map "
                         "(sensitivity-driven greedy descent) and report the "
                         "FLOP-weighted mean-k savings vs the uniform k; LM "
                         "archs certify through the scan-native stacked "
                         "analysis (one compiled probe ladder)")
    ap.add_argument("--trace", default=None, metavar="OUT.JSONL",
                    help="record a per-stage JSONL trace (spans, ladder "
                         "compile counts, store hit/miss counters) to this "
                         "path; render it with `python -m repro.obs report`")
    ap.add_argument("--formats", action="store_true",
                    help="additionally certify FULL per-scope custom formats "
                         "(k, emin, emax): IA range analysis proves the "
                         "smallest overflow-free emax, underflow absorption "
                         "is folded into the bounds, and schema-v3 "
                         "certificates carry {scope: FpFormat} maps; reports "
                         "total-bits savings vs uniform-k + binary32 range")
    ap.add_argument("--affine-budget", type=int, default=None,
                    metavar="N",
                    help="noise-symbol budget of the affine range pass (LM "
                         "--formats only; default: core.interval."
                         "AFF_DEFAULT_BUDGET). Larger budgets keep more "
                         "correlated rounding symbols alive (tighter "
                         "enclosures, more memory); condensation drops are "
                         "recorded as gauges in the --trace. NOTE: a "
                         "non-default budget addresses a different store "
                         "entry")
    ap.add_argument("--affine-rank", default=None,
                    choices=["sensitivity", "magnitude"],
                    help="noise-symbol retention policy of the affine "
                         "condensation: 'sensitivity' (default) keeps the "
                         "symbols with the largest downstream contribution "
                         "to the output enclosure, 'magnitude' the legacy "
                         "largest-coefficient-mass ranking. NOTE: a "
                         "non-default rank addresses a different store entry")
    ap.add_argument("--cost-report", default=None, metavar="OUT.JSON",
                    help="what-if pass: fit a measured cost model (quick "
                         "kernel profile), re-score the certificate's "
                         "serving map by PREDICTED LATENCY vs the "
                         "FLOP-weighted-bits objective, write the fitted "
                         "model + per-scope comparison as JSON, and print "
                         "where the two objectives disagree")
    args = ap.parse_args(argv)
    if args.arch == "transformer":   # CI-smoke-friendly alias
        args.arch = "qwen2_7b"
    if args.arch == "digits" and not 0.5 < args.p_star <= 1.0:
        ap.error("--p-star must be in (0.5, 1] (guaranteed top-1 probability)")
    if args.arch == "pendulum" and args.abs_tol <= 0:
        ap.error("--abs-tol must be positive")

    if args.trace:
        obs.configure(path=args.trace, program="repro.certify", argv=argv)

    store = CertificateStore(args.store)
    t0 = time.perf_counter()
    with obs.span("certify_run", arch=args.arch, mixed=args.mixed,
                  formats=args.formats):
        if args.arch == "digits":
            args.k_max = args.k_max or 53
            cs, layer_flops = _digits(args, store)
        elif args.arch == "pendulum":
            args.k_max = args.k_max or 53
            cs, layer_flops = _pendulum(args, store)
        else:
            import dataclasses

            from repro import configs
            from .lm import lm_layer_flops

            arch_cfg = None
            effective_cfg = configs.get(args.arch).SMOKE
            if args.max_layers is not None:
                arch_cfg = dataclasses.replace(
                    effective_cfg,
                    n_layers=min(args.max_layers, effective_cfg.n_layers))
                effective_cfg = arch_cfg
            layer_flops = lm_layer_flops(effective_cfg)
            profiles = tuple(int(s) for s in args.profiles.split(",")) \
                if args.profiles else ()
            # only user-set knobs enter format_opts: the opts are part
            # of the store request key, so the defaults must keep
            # addressing the same stored certificates as before the flags
            format_opts = {}
            if args.affine_budget is not None:
                format_opts["affine_budget"] = args.affine_budget
            if args.affine_rank is not None:
                format_opts["affine_rank"] = args.affine_rank
            format_opts = format_opts or None
            cs = certify_lm(
                args.arch, arch_cfg, seq=args.seq, batch=args.batch,
                store=store,
                k_max=args.k_max or (53 if (args.mixed or args.formats)
                                     else 24),
                mixed=args.mixed, formats=args.formats, profiles=profiles,
                format_opts=format_opts)
    dt = time.perf_counter() - t0

    print()
    print(cs.summary())
    print()
    if cs.meta.get("from_store"):
        log.info("served from store",
                 lookup_ms=round(cs.meta["lookup_seconds"] * 1e3, 1),
                 store=store.root)
    else:
        probes = cs.meta.get("probes", [])
        n_probes = probes if isinstance(probes, int) else len(probes)
        log.info("analysed (all classes batched per probe)",
                 seconds=round(cs.meta["analysis_seconds"], 2),
                 probes=n_probes,
                 ladder_compiles=cs.meta.get("ladder_compiles", "?"))
        log.info("persisted — re-run to load from the store",
                 store=store.root)
        fm = cs.meta.get("formats") or {}
        mx = cs.meta.get("mixed") or {}
        obs.append_bench("runs", {
            "kind": "certify", "arch": args.arch,
            "mixed": bool(args.mixed), "formats": bool(args.formats),
            "analysis_seconds": cs.meta["analysis_seconds"],
            "probes": n_probes,
            "ladder_compiles": cs.meta.get("ladder_compiles"),
            # serving-cost headlines (None when the stage didn't run/apply):
            # the acceptance gate for attention archs is mean_bits strictly
            # below the uniform-k fallback's baseline_bits
            "mantissa_mode": fm.get("mantissa_mode"),
            "mean_bits_flop_weighted": fm.get(
                "mean_bits_flop_weighted",
                mx.get("mean_bits_flop_weighted")),
            "baseline_bits": fm.get("baseline_bits"),
            # multi-profile serving headlines: the merged serving map's
            # cost must never exceed the legacy raise-until-feasible merge
            "profiles": cs.meta.get("profiles") or None,
            "serving_mean_bits": (cs.meta.get("serving") or {}).get(
                "mean_bits_flop_weighted"),
            "raised_baseline_bits": (cs.meta.get("serving") or {}).get(
                "raised_baseline_mean_bits"),
            "profile_maps_differ": (cs.meta.get("serving") or {}).get(
                "profile_maps_differ"),
        })
    if cs.meta.get("scan_native") and not cs.meta.get("from_store"):
        log.info("scan-native analysis",
                 stacked_scopes=len(cs.meta.get("scope_keys", [])),
                 probes=cs.meta.get("probes", "?"),
                 ladder_compiles=cs.meta.get("ladder_compiles", "?"))
    mx = cs.meta.get("mixed")
    if mx:
        if mx.get("applied"):
            log.info("mixed precision applied",
                     uniform_k=mx["uniform_k"],
                     mean_k_flop_weighted=round(
                         mx["mean_k_flop_weighted"], 2),
                     savings_k_flop_weighted=round(
                         mx["savings_k_flop_weighted"], 2),
                     probes=mx["probes"],
                     ladder_compiles=mx["ladder_compiles"])
            if "savings_bits_vs_binary32" in mx:
                sv = mx["savings_bits_vs_binary32"]
                log.info("mixed serving cost vs uniform binary32",
                         mean_bits_flop_weighted=round(
                             mx["mean_bits_flop_weighted"], 2),
                         savings_bits_per_value=round(sv, 2),
                         beats_binary32=sv > 0)
        else:
            log.info("mixed precision not applied", reason=mx.get("reason"))
    fm = cs.meta.get("formats")
    if fm:
        if fm.get("applied"):
            log.info("custom formats applied",
                     baseline_bits=fm["baseline_bits"],
                     uniform_k=fm["uniform_k"],
                     mean_bits_flop_weighted=round(
                         fm["mean_bits_flop_weighted"], 2),
                     savings_bits_flop_weighted=round(
                         fm["savings_bits_flop_weighted"], 2),
                     probes=fm["probes"],
                     ladder_compiles=fm["ladder_compiles"])
            from repro.core import formats as F
            for sc, f in sorted(fm["layer_format"].items()):
                r = fm["scope_ranges"].get(sc, {})
                ma = r.get("max_abs")
                bits = 1 + F.exponent_bits(f["emax"], f["emin"]) + f["k"] - 1
                log.info("format", scope=sc or "<default>", k=f["k"],
                         emin=f["emin"], emax=f["emax"], bits=bits,
                         range_sup=ma if ma is None else round(ma, 4))
            if "savings_bits_vs_binary32" in fm:
                sv = fm["savings_bits_vs_binary32"]
                log.info("cheapest certified serving vs uniform binary32",
                         savings_bits_per_value=round(sv, 2),
                         beats_binary32=sv > 0)
            if fm.get("attached") is False:
                log.info("format map not attached",
                         reason=fm.get("attach_reason"))
        else:
            log.info("custom formats not applied", reason=fm.get("reason"))
    if args.cost_report:
        _cost_report(args.cost_report, cs, layer_flops)
    log.info("done", total_seconds=round(dt, 2),
             **store.stats.to_dict())
    store.persist_stats()
    if args.trace:
        obs.shutdown()
        log.info("trace written", path=args.trace,
                 hint="render with: python -m repro.obs report " + args.trace)
    return cs


if __name__ == "__main__":
    main()
