"""repro.certify — the batched certificate pipeline.

Sits between the analyser (:mod:`repro.core.analyze`) and the server
(:mod:`repro.launch.serve`): traces a model once, analyses all classes in a
single batched CAA pass, binary-searches the smallest certified precision,
persists the result content-addressed, and serves it back with error bars.

  from repro import certify
  cs = certify.certify(forward, params, class_los, class_his, p_star=0.6,
                       model_id="digits/h64x32",
                       store=certify.CertificateStore("certs/"))
  cs.serving_k, cs.error_bars()

CLI:  python -m repro.certify --arch digits --p-star 0.6
"""
from .batch import (  # noqa: F401
    ProbeLadder,
    make_reverifier,
    margin_feasibility,
    required_k_batched,
    stack_class_ranges,
    tolerance_feasibility,
)
from .mixed import (  # noqa: F401
    MixedCaaOps,
    MixedPlan,
    MixedProbeLadder,
    flop_weighted_mean_k,
    greedy_mixed_assignment,
)
from .formats import (  # noqa: F401
    FormatCaaOps,
    FormatPlan,
    FormatProbeLadder,
    synthesize_formats,
)
from .lm import (  # noqa: F401
    certify_lm_stacked,
    lm_layer_flops,
)
from .pipeline import (  # noqa: F401
    certify,
    certify_lm,
    range_digest,
    serving_certificate,
)
from .spec import Certificate, CertificateSet, trace_summary  # noqa: F401
from .store import CertificateStore, params_digest, request_key  # noqa: F401
