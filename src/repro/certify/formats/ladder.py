"""Format-aware CAA execution + the jit-once (k, emin, emax) probe ladder.

:class:`FormatCaaOps` generalises :class:`repro.certify.mixed.MixedCaaOps`
from per-scope mantissa scales to per-scope FULL formats: inside scope ``s``
every fresh rounding is charged at the scope's own unit (``round_scale =
u_s/u_ref``, exactly as the mixed analysis does) AND may additionally absorb
the scope's underflow term (``round_abs = η_s/u_ref`` with η_s the format's
subnormal grid spacing — see :attr:`repro.core.formats.FpFormat.
underflow_unit` and :func:`repro.core.caa._finish`).

:class:`FormatProbeLadder` jit-compiles ONE batched analysis over
``(u_ref, scale-vector, underflow-vector)`` as traced arguments — the scope
structure is static, the per-scope numbers are data — so the whole exponent
descent of the synthesizer (and any re-probe of a candidate lattice point)
runs through a single compiled executable, the same trick PR 2's
MixedProbeLadder uses for the mantissa descent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import analyze, caa
from repro.core import formats as F
from repro.core.analyze import resolve_scope_value
from repro.core.backend import CaaOps, RangeCaaOps, StackedCaaOps
from repro.core.caa import CaaConfig, CaaTensor

_F64 = jnp.float64


class FormatCaaOps(CaaOps):
    """CaaOps whose fresh roundings follow per-scope custom FP formats.

    ``scope_scales[s] = u_s / u_ref`` and ``scope_abs[s] = η_s / u_ref``
    (floats or jax tracers); the defaults apply outside every mapped scope.
    Propagation terms are untouched — only the fresh roundings an op
    introduces are charged at the scope's own unit and underflow grid,
    which is precisely the semantics of running that scope's arithmetic in
    its own (k, emin, emax) format. With all scales 1 and all abs 0 this
    degenerates bit-for-bit to the uniform batched analysis.
    """

    def __init__(self, cfg: CaaConfig, scope_scales: Dict[str, object],
                 scope_abs: Dict[str, object],
                 default_scale=1.0, default_abs=0.0,
                 weights_exact: bool = True):
        self._scales = dict(scope_scales)
        self._abs = dict(scope_abs)
        self._default_scale = default_scale
        self._default_abs = default_abs
        self._base_cfg = cfg
        super().__init__(cfg, weights_exact=weights_exact)
        self._apply()

    def _apply(self):
        s = resolve_scope_value(self._scope, self._scales,
                                self._default_scale)
        ra = resolve_scope_value(self._scope, self._abs, self._default_abs)
        self.cfg = dataclasses.replace(
            self._base_cfg,
            round_scale=self._base_cfg.round_scale * s,
            round_abs=ra)

    def _scope_changed(self):
        super()._scope_changed()
        self._apply()


class RangeFormatCaaOps(RangeCaaOps, FormatCaaOps):
    """Format-aware analysis that also accumulates per-scope IA magnitude
    enclosures — the eager confirmation backend of the synthesizer (one
    pass yields bounds AND the ranges the emax certificates re-check)."""


def scope_vectors(layer_fmt: Dict[str, F.FpFormat],
                  default_fmt: F.FpFormat,
                  scope_keys: Sequence[str]) -> Tuple[float, np.ndarray,
                                                      np.ndarray]:
    """(u_ref, scales, ras) encoding a concrete per-scope format map.

    ``u_ref = 2^{1-k_ref}`` with ``k_ref`` the coarsest mantissa precision
    in play (bounds are stated in units of u_ref); entry i of the vectors
    is scope_keys[i]'s format, the last entry the default's.
    """
    fmts = [layer_fmt[s] for s in scope_keys] + [default_fmt]
    k_ref = min(f.k for f in fmts)
    u_ref = 2.0 ** (1 - k_ref)
    scales = np.asarray([f.u / u_ref for f in fmts], np.float64)
    ras = np.asarray([f.underflow_unit / u_ref for f in fmts], np.float64)
    return u_ref, scales, ras


class FormatProbeLadder:
    """Per-class (δ̄, ε̄) under a per-scope format map — one jit compile.

    The jitted function takes ``u_ref``, a scale vector and an underflow
    vector (one entry per scope key + one default) as traced arguments;
    every probe of the exponent descent reuses the same executable.
    ``compiles`` exposes the jit cache size for the at-most-one-compilation
    assertion.

    ``stacked=True`` swaps the traced backend for
    :class:`repro.core.backend.StackedCaaOps`: every ``layer_loop`` is ONE
    ``lax.scan`` whose body gathers its layer's (scale, underflow) pair
    from the traced vectors by layer index — O(1) compiled HLO in model
    depth, the form LM architectures certify through.

    :meth:`mixed_view` exposes a mantissa-only adapter over the SAME jitted
    executable (underflow vector pinned to 0), so a pipeline that runs both
    the mixed-k descent and the exponent descent pays exactly one
    compilation overall.
    """

    def __init__(self, forward, params, x: CaaTensor,
                 scope_keys: Sequence[str],
                 cfg: CaaConfig = caa.DEFAULT_CONFIG,
                 weights_exact: bool = True,
                 stacked: bool = False,
                 tag: str = "format"):
        self.tag = str(tag)
        self.scope_keys: Tuple[str, ...] = tuple(scope_keys)
        if not self.scope_keys:
            raise ValueError("no scope keys — the model must enter named "
                             "bk.scope(...) blocks to get per-scope formats")
        n = int(jnp.shape(x.val)[0])
        base = analyze.batch_config(cfg, n)
        keys = self.scope_keys

        def bounds(params_, x_, u_max, scales, ras):
            sm = {key: scales[i] for i, key in enumerate(keys)}
            am = {key: ras[i] for i, key in enumerate(keys)}
            kcfg = dataclasses.replace(base, u_max=u_max)
            ops_cls = StackedCaaOps if stacked else FormatCaaOps
            ops = ops_cls(kcfg, sm, am,
                          default_scale=scales[len(keys)],
                          default_abs=ras[len(keys)],
                          weights_exact=weights_exact)
            out = forward(ops, params_, x_)
            red = tuple(range(1, out.ndim))
            dbar = jnp.broadcast_to(out.dbar, out.shape)
            ebar = jnp.broadcast_to(out.ebar, out.shape)
            return jnp.max(dbar, axis=red), jnp.max(ebar, axis=red)

        self._fn = jax.jit(bounds)
        self._params = params
        self._x = x
        self.probes = 0

    def __call__(self, layer_fmt: Dict[str, F.FpFormat],
                 default_fmt: F.FpFormat):
        """Bounds for a concrete map. Returns (abs_u, rel_u, k_ref):
        per-class bounds in units of u_ref = 2^{1-k_ref}."""
        u_ref, scales, ras = scope_vectors(layer_fmt, default_fmt,
                                           self.scope_keys)
        self.probes += 1
        before = self.compiles
        u_arr = jnp.asarray(u_ref, _F64)
        s_arr = jnp.asarray(scales, _F64)
        r_arr = jnp.asarray(ras, _F64)
        with obs.span("ladder_probe", ladder=self.tag) as _sp:
            t0 = time.perf_counter()
            a, e = self._fn(self._params, self._x, u_arr, s_arr, r_arr)
            if self.compiles > before:
                _sp.rename("ladder_compile")
                obs.counter("ladder.compiles")
                obs.gauge("ladder.format_compile_s",
                          time.perf_counter() - t0)
                if obs.enabled():
                    from repro.obs.profile import jaxpr_stats
                    obs.gauge("ladder.format_jaxpr_eqns", jaxpr_stats(
                        self._fn, self._params, self._x,
                        u_arr, s_arr, r_arr)["eqns"])
        k_ref = 1 - int(np.round(np.log2(u_ref)))
        return (np.asarray(a, np.float64), np.asarray(e, np.float64), k_ref)

    @property
    def compiles(self) -> int:
        return int(self._fn._cache_size())

    def mixed_view(self) -> "MixedLadderView":
        """A mantissa-only probe interface over this ladder's executable."""
        return MixedLadderView(self)


class MixedLadderView:
    """:class:`repro.certify.mixed.MixedProbeLadder`-shaped adapter that
    probes through a :class:`FormatProbeLadder`'s jitted executable with
    the underflow vector pinned to 0 — per-layer {scope: k} maps and
    one-hot sensitivity probes cost zero extra compilations on top of the
    format ladder (``compiles``/``probes`` are the shared ladder's).
    """

    def __init__(self, ladder: FormatProbeLadder):
        self._ladder = ladder
        self.scope_keys = ladder.scope_keys

    def _run(self, u_ref: float, scales: np.ndarray):
        lad = self._ladder
        lad.probes += 1
        zeros = jnp.zeros(len(scales), _F64)
        before = lad.compiles
        with obs.span("ladder_probe",
                      ladder=f"{lad.tag}.mixed_view") as _sp:
            a, e = lad._fn(lad._params, lad._x, jnp.asarray(u_ref, _F64),
                           jnp.asarray(scales, _F64), zeros)
            if lad.compiles > before:
                _sp.rename("ladder_compile")
                obs.counter("ladder.compiles")
        return np.asarray(a, np.float64), np.asarray(e, np.float64)

    def __call__(self, layer_k: Dict[str, int], default_k: int):
        from ..mixed import mixed_scale_vectors

        u_ref, scales, k_ref = mixed_scale_vectors(
            self.scope_keys, layer_k, default_k)
        abs_u, rel_u = self._run(u_ref, scales)
        return abs_u, rel_u, k_ref

    def sensitivity(self, scope_key: str, at_k: int) -> float:
        from ..mixed import onehot_scale_vector

        scales = onehot_scale_vector(self.scope_keys, scope_key)
        abs_u, _ = self._run(2.0 ** (1 - int(at_k)), scales)
        return float(np.max(abs_u))

    @property
    def probes(self) -> int:
        return self._ladder.probes

    @property
    def compiles(self) -> int:
        return self._ladder.compiles


def eager_format_report(forward, params, x: CaaTensor,
                        layer_fmt: Dict[str, F.FpFormat],
                        default_fmt: F.FpFormat,
                        scope_keys: Sequence[str],
                        cfg: CaaConfig = caa.DEFAULT_CONFIG,
                        weights_exact: bool = True):
    """One EAGER format-aware pass: per-class bounds at u_ref + per-scope
    range enclosures under the map's own underflow terms — the confirmation
    the persisted certificate is built from (jitted ladder bounds can
    differ from eager in the last ulp, exactly as in PR 2's pipeline).

    Returns (abs_u[C], rel_u[C], k_ref, ranges: {key: RangeStat}).
    """
    n = int(jnp.shape(x.val)[0])
    u_ref, scales, ras = scope_vectors(layer_fmt, default_fmt, scope_keys)
    sm = {key: float(scales[i]) for i, key in enumerate(scope_keys)}
    am = {key: float(ras[i]) for i, key in enumerate(scope_keys)}
    base = analyze.batch_config(
        dataclasses.replace(cfg, u_max=u_ref), n)
    ops = RangeFormatCaaOps(base, sm, am,
                            default_scale=float(scales[-1]),
                            default_abs=float(ras[-1]),
                            weights_exact=weights_exact)
    with obs.span("range_pass", scopes=len(scope_keys)):
        out = forward(ops, params, x)
    red = tuple(range(1, out.ndim))
    dbar = jnp.broadcast_to(out.dbar, out.shape)
    ebar = jnp.broadcast_to(out.ebar, out.shape)
    abs_u = np.asarray(jnp.max(dbar, axis=red), np.float64)
    rel_u = np.asarray(jnp.max(ebar, axis=red), np.float64)
    k_ref = 1 - int(np.round(np.log2(u_ref)))
    ranges = analyze.aggregate_ranges(ops.scope_ranges, scope_keys)
    return abs_u, rel_u, k_ref, ranges
