"""Full custom-FP format synthesis: from rigorous range analysis to a
certified per-scope {scope: (k, emin, emax)} map and its Pallas serving.

See :mod:`repro.certify.formats.synth` (search + confirmation) and
:mod:`repro.certify.formats.ladder` (format-aware CAA execution + the
jit-once probe ladder). The pipeline entry is
``repro.certify.certify(..., formats=True)`` / ``python -m repro.certify
--formats``.
"""
from .ladder import (FormatCaaOps, FormatProbeLadder, MixedLadderView,
                     RangeFormatCaaOps, eager_format_report, scope_vectors)
from .synth import (DEFAULT_KEY, FormatPlan, min_exponent_bits_for_range,
                    synthesize_formats)

__all__ = [
    "DEFAULT_KEY",
    "FormatCaaOps",
    "FormatPlan",
    "FormatProbeLadder",
    "MixedLadderView",
    "RangeFormatCaaOps",
    "eager_format_report",
    "min_exponent_bits_for_range",
    "scope_vectors",
    "synthesize_formats",
]
