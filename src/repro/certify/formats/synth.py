"""Full-format synthesis: certify per-scope (k, emin, emax) custom formats.

The mantissa pipeline (PR 1/2) answers "how many mantissa bits"; this module
answers the rest of the paper's claim — DNNs also tolerate narrow *exponent
ranges* — rigorously, per scope:

  1. **Range analysis** — one eager format-aware pass accumulates per-scope
     IA magnitude enclosures (:class:`repro.core.backend.RangeCaaOps`); a
     scope's smallest overflow-free ``emax`` is the one whose
     ``max_finite(k, emax)`` clears the scope's proven ``max_abs``.
  2. **Underflow soundness** — a finite ``emin`` makes roundings absorb an
     absolute η = 2^{emin-(k-1)} each (flush-to-zero: 2^{emin}); the
     analysis charges λ·η into δ̄/ε̄ via ``CaaConfig.round_abs``
     (:func:`repro.core.caa._finish`), so the certified bounds stay sound
     for the *actual* finite-range format, not just unbounded-range
     rounding.
  3. **Search** — a greedy per-scope descent over the exponent-bit lattice
     (the (k, emax) lattice: k fixed per scope by the mixed-precision map,
     emax stepping down IEEE exponent widths), every probe running through
     the jit-once :class:`.ladder.FormatProbeLadder`; the final map is
     EAGERLY re-confirmed (bounds within the class margins AND no overflow
     at the chosen emax under the map's own underflow terms), stepping back
     up until confirmation holds — certificates never ship unconfirmed
     lattice points.

The result prices out as total storage bits (sign + exponent field + stored
mantissa), reported FLOP-weighted against the uniform-k + binary32-range
baseline the mantissa-only pipeline would serve.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import analyze, caa
from repro.core import formats as F
from repro.core import interval as iv
from repro.core.backend import RangeStat
from repro.core.caa import CaaConfig, CaaTensor
from ..batch import FeasibleFn
from repro import obs
from .ladder import FormatProbeLadder, eager_format_report

DEFAULT_KEY = ""        # map key for ops outside every named scope


@dataclasses.dataclass
class FormatPlan:
    """Result of the format synthesis.

    ``layer_format`` maps every scope key — plus the ``""`` default — to
    its certified :class:`repro.core.formats.FpFormat`; ``abs_u``/``rel_u``
    are the per-class bounds of the final map in units of
    ``u_ref = 2^{1-k_ref}``, confirmed by an eager re-analysis WITH the
    map's underflow terms; ``scope_ranges`` are that pass's magnitude
    enclosures (the no-overflow evidence); ``history`` records every probed
    lattice point (the Pareto sweep trail).
    """

    layer_format: Dict[str, F.FpFormat]
    layer_k: Dict[str, int]
    uniform_k: int
    baseline_bits: int
    abs_u: np.ndarray
    rel_u: np.ndarray
    k_ref: int
    scope_ranges: Dict[str, RangeStat]
    emax_floor: Dict[str, int]
    history: List[dict]
    probes: int
    compiles: int
    feasible: bool

    def formats_dict(self) -> Dict[str, dict]:
        """JSON-ready {scope: descriptor} — what schema-v3 certificates
        carry in ``layer_format``."""
        return {s: f.to_dict() for s, f in self.layer_format.items()}

    def mean_bits(self, layer_flops: Optional[Dict[str, float]] = None
                  ) -> float:
        """FLOP-weighted mean total storage bits of the mapped scopes."""
        from ..mixed import flop_weighted_mean_k

        bits = {s: float(f.total_bits)
                for s, f in self.layer_format.items() if s != DEFAULT_KEY}
        return flop_weighted_mean_k(bits, layer_flops)

    def savings_bits(self, layer_flops: Optional[Dict[str, float]] = None
                     ) -> float:
        """Bits/value saved vs the uniform-k + binary32-range baseline."""
        return self.baseline_bits - self.mean_bits(layer_flops)


def min_exponent_bits_for_range(k: int, max_abs: float,
                                e_min: int, e_max: int) -> int:
    """Smallest IEEE exponent width e whose emax = 2^{e-1}−1 makes every
    value of magnitude ≤ max_abs representable at precision k (i.e.
    max_finite(k, emax) ≥ max_abs — the overflow-freedom floor). Saturates
    at ``e_max`` when even that cannot hold (inf ranges)."""
    if not math.isfinite(max_abs):
        return e_max
    for e in range(e_min, e_max):
        if F.from_bits(k, e).max_finite >= max_abs:
            return e
    return e_max


def _emax_floors(scope_keys: Sequence[str], layer_k: Dict[str, int],
                 ranges: Dict[str, RangeStat],
                 e_min_bits: int, e_max_bits: int) -> Dict[str, int]:
    out = {}
    for s in scope_keys:
        r = ranges.get(s)
        if r is None or r.n_ops == 0:
            # no value was ever observed under this scope: there is no
            # range evidence to narrow on — keep the widest exponent
            out[s] = e_max_bits
        else:
            out[s] = min_exponent_bits_for_range(
                layer_k[s], r.max_abs, e_min_bits, e_max_bits)
    return out


def synthesize_formats(
    forward, params, x: CaaTensor,
    feasible: FeasibleFn,
    uniform_k: int,
    layer_k: Optional[Dict[str, int]] = None,
    scope_keys: Optional[Sequence[str]] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    weights_exact: bool = True,
    e_min_bits: int = 2,
    e_max_bits: int = 8,
    has_subnormals: bool = True,
    saturating: bool = True,
    ladder: Optional[FormatProbeLadder] = None,
    stacked: bool = False,
    extra_ranges_fn=None,
    tighten_ranges_fn=None,
    affine: bool = False,
    affine_budget: Optional[int] = None,
    affine_rank: Optional[str] = None,
) -> FormatPlan:
    """Greedy certified descent over the per-scope (k, emax) lattice.

    ``uniform_k`` is the certified uniform mantissa precision (the class-max
    of the batched search); ``layer_k`` an optional per-scope refinement
    (PR 2's mixed map) — k per scope is FIXED by these, the exponent width
    descends. Start every scope at ``e_max_bits`` (binary32-range baseline,
    where η ≈ 0 and the map provably reproduces the mantissa-only
    certificate), then per scope step the exponent width down while (a) the
    scope's range-analysis floor keeps the format overflow-free and (b) the
    joint feasibility check — every class's (δ̄, ε̄) at u_ref against its
    decision margins, WITH every scope's underflow term charged — stays
    green; backtrack one step on failure. Feasibility is monotone in each
    scope's exponent width (shrinking emin only grows η), so the endpoint
    is a certified lattice point; a final eager pass re-confirms it (and
    re-checks overflow under the final η-inflated ranges), undoing descent
    steps until confirmation holds.

    ``stacked`` routes the ladder probes through the scan-native analysis
    (O(1) HLO in depth — LM architectures); the eager confirmations stay on
    the unrolled per-layer reference either way. ``extra_ranges_fn(lf, df)
    -> {key: RangeStat}`` injects additional range evidence — e.g. range
    passes over several sequence-length input profiles — which is merged
    into every floors/overflow decision, so the certified ``emax`` covers
    those profiles too.

    ``tighten_ranges_fn(lf, df) -> {key: RangeStat}`` injects a second
    sound range map over the SAME profile (e.g. the affine pass of
    :func:`repro.core.analyze.analyze_ranges_affine`) that is min-combined
    with the eager IA evidence BEFORE profile widening — this is what
    keeps the emax floors finite when the IA pass saturates at coarse
    mixed-map k. ``extra_ranges_fn`` maps must already be tightened per
    profile by the caller; tightening after the cross-profile max would be
    unsound.

    ``affine`` / ``affine_budget`` / ``affine_rank`` build that tighten
    pass here (an EAGER :class:`repro.core.backend.AffineRangeCaaOps`
    pass) when the caller did not supply ``tighten_ranges_fn`` — this is
    how the eager (non-LM) pipelines honor ``--affine-budget`` through
    ``format_opts`` instead of silently diverging from the LM path.
    Passing any of them alongside an explicit ``tighten_ranges_fn`` is an
    error (the caller's pass already fixed its own budget/ranking).
    """
    if scope_keys is None:
        scope_keys = analyze.discover_scopes(forward, params, x, cfg)
    scope_keys = list(scope_keys)
    if affine or affine_budget is not None or affine_rank is not None:
        if tighten_ranges_fn is not None:
            raise ValueError(
                "pass either tighten_ranges_fn or the affine/affine_budget/"
                "affine_rank knobs, not both")
        aff_budget = int(affine_budget if affine_budget is not None
                         else iv.AFF_DEFAULT_BUDGET)
        aff_rank = str(affine_rank or iv.AFF_DEFAULT_RANK)
        obs.gauge("affine.budget", aff_budget)
        aff_cache: Dict[tuple, Dict[str, RangeStat]] = {}

        def tighten_ranges_fn(lf, df):
            ck = (tuple(sorted((s, f.name) for s, f in lf.items())),
                  df.name)
            if ck not in aff_cache:
                with obs.span("affine_ranges", scopes=len(lf),
                              budget=aff_budget, rank=aff_rank):
                    aff_cache[ck] = analyze.analyze_ranges_affine(
                        forward, params, x, lf, df, keys=scope_keys,
                        stacked=stacked, budget=aff_budget,
                        weights_exact=weights_exact,
                        condense_rank=aff_rank)
            return aff_cache[ck]
    uniform_k = int(uniform_k)
    ks = {s: int((layer_k or {}).get(s, uniform_k)) for s in scope_keys}
    ks[DEFAULT_KEY] = uniform_k
    all_keys = scope_keys + [DEFAULT_KEY]
    flags = {"has_subnormals": has_subnormals, "saturating": saturating}

    def fmt_map(e: Dict[str, int]) -> Dict[str, F.FpFormat]:
        return {s: F.from_bits(ks[s], e[s], **flags) for s in all_keys}

    def split(m: Dict[str, F.FpFormat]):
        return {s: m[s] for s in scope_keys}, m[DEFAULT_KEY]

    def widen(ranges: Dict[str, RangeStat],
              m: Dict[str, F.FpFormat]) -> Dict[str, RangeStat]:
        lf, df = split(m)
        if tighten_ranges_fn is not None:
            ranges = analyze.tighten_range_maps(
                ranges, tighten_ranges_fn(lf, df))
        if extra_ranges_fn is None:
            return ranges
        return analyze.merge_range_maps(
            [ranges, extra_ranges_fn(lf, df)], scope_keys)

    if ladder is None:
        ladder = FormatProbeLadder(forward, params, x, scope_keys, cfg=cfg,
                                   weights_exact=weights_exact,
                                   stacked=stacked)

    history: List[dict] = []

    def ok_ladder(e: Dict[str, int], tag: str) -> bool:
        lf, df = split(fmt_map(e))
        abs_u, rel_u, k_ref = ladder(lf, df)
        good = bool(np.all(feasible(abs_u, rel_u, k_ref)))
        history.append({"e": dict(e), "feasible": good, "probe": tag})
        return good

    # -- baseline: widest exponent everywhere, eagerly confirmed ------------
    e = {s: int(e_max_bits) for s in all_keys}
    lf, df = split(fmt_map(e))
    with obs.span("format_baseline"):
        abs_u, rel_u, k_ref, ranges = eager_format_report(
            forward, params, x, lf, df, scope_keys, cfg=cfg,
            weights_exact=weights_exact)
    ranges = widen(ranges, fmt_map(e))
    floors = _emax_floors(all_keys, ks, ranges, e_min_bits, e_max_bits)
    base_ok = bool(np.all(feasible(abs_u, rel_u, k_ref)))
    base_overflow = any(
        ranges[s].max_abs > fmt_map(e)[s].max_finite for s in all_keys)
    if not base_ok or base_overflow:
        return FormatPlan(
            layer_format=fmt_map(e), layer_k=ks, uniform_k=uniform_k,
            baseline_bits=F.from_bits(uniform_k, e_max_bits).total_bits,
            abs_u=abs_u, rel_u=rel_u, k_ref=k_ref, scope_ranges=ranges,
            emax_floor=floors, history=history, probes=ladder.probes,
            compiles=ladder.compiles, feasible=False)

    # -- greedy exponent descent through the jit-once ladder ----------------
    descended: List[str] = []       # successful steps, for confirmed undo
    with obs.span("exponent_descent", scopes=len(all_keys)) as _sp:
        for s in all_keys:
            while e[s] > max(floors[s], e_min_bits):
                e[s] -= 1
                if ok_ladder(e, f"descend:{s}"):
                    descended.append(s)
                else:
                    e[s] += 1           # backtrack one step
                    break
        _sp.set(steps=len(descended))

    # -- eager confirmation fixpoint ---------------------------------------
    # The persisted bounds must come from an eager pass (ladder bounds can
    # differ in the last ulp), and the overflow floors must hold under the
    # FINAL map's own η-inflated ranges. Undo descent steps until both
    # confirm; terminates at the (eagerly confirmed) baseline at worst.
    while True:
        lf, df = split(fmt_map(e))
        with obs.span("eager_confirm"):
            abs_u, rel_u, k_ref, ranges = eager_format_report(
                forward, params, x, lf, df, scope_keys, cfg=cfg,
                weights_exact=weights_exact)
        ranges = widen(ranges, fmt_map(e))
        over = [s for s in all_keys
                if ranges[s].max_abs > fmt_map(e)[s].max_finite]
        bounds_ok = bool(np.all(feasible(abs_u, rel_u, k_ref)))
        if bounds_ok and not over:
            break
        if over:
            bumped = False
            for s in over:
                if e[s] < e_max_bits:
                    e[s] += 1
                    bumped = True
            if bumped:
                history.append({"e": dict(e), "feasible": None,
                                "probe": "overflow-bump"})
                continue
        if descended:
            s = descended.pop()
            e[s] = min(e[s] + 1, e_max_bits)
            history.append({"e": dict(e), "feasible": None,
                            "probe": f"confirm-undo:{s}"})
            continue
        # nothing left to undo and still failing: report infeasible
        return FormatPlan(
            layer_format=fmt_map(e), layer_k=ks, uniform_k=uniform_k,
            baseline_bits=F.from_bits(uniform_k, e_max_bits).total_bits,
            abs_u=abs_u, rel_u=rel_u, k_ref=k_ref, scope_ranges=ranges,
            emax_floor=floors, history=history, probes=ladder.probes,
            compiles=ladder.compiles, feasible=False)

    floors = _emax_floors(all_keys, ks, ranges, e_min_bits, e_max_bits)
    return FormatPlan(
        layer_format=fmt_map(e), layer_k=ks, uniform_k=uniform_k,
        baseline_bits=F.from_bits(uniform_k, e_max_bits).total_bits,
        abs_u=abs_u, rel_u=rel_u, k_ref=k_ref, scope_ranges=ranges,
        emax_floor=floors, history=history, probes=ladder.probes,
        compiles=ladder.compiles, feasible=True)
