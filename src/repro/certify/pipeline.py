"""certify(): analyse → decide → persist, behind the content-addressed store.

Two certification shapes cover the repo's workloads:

  * :func:`certify` — the paper's classifier workflow, batched: per-class
    interval input envelopes, one joint CAA pass per probed precision, a
    vectorised binary search for each class's smallest safe k against the
    p* margins, and one stored CertificateSet.
  * :func:`certify_lm` — the LM serving certificate: run the architecture's
    reduced config under k-bit emulated CAA and binary-search the smallest
    k whose rigorous enclosure still pins the model's top-1 next-token
    decision (the paper's argmax analysis applied to decode logits).

Both consult the store first; a hit costs a file read instead of a
re-analysis, and a params change can never hit (the digest is part of the
address).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import caa, formats, precision
from repro.core.backend import CaaOps
from repro.core.caa import CaaConfig
from . import batch as B
from . import formats as FS
from . import mixed as MX
from .spec import Certificate, CertificateSet, trace_summary
from .store import CertificateStore, params_digest, request_key


def range_digest(los: Sequence, his: Sequence) -> str:
    """Content key of the per-class input annotation."""
    h = hashlib.sha256()
    for lo, hi in zip(los, his):
        a = np.ascontiguousarray(np.asarray(lo, np.float64))
        b = np.ascontiguousarray(np.asarray(hi, np.float64))
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
        h.update(b.tobytes())
    return h.hexdigest()[:32]


def _as_store_hit(hit: CertificateSet, t0: float) -> CertificateSet:
    """A store hit, marked as such WITHOUT mutating the LRU-cached object
    (whose meta a previous caller may still be holding)."""
    return dataclasses.replace(hit, meta=dict(
        hit.meta, from_store=True,
        lookup_seconds=time.perf_counter() - t0))


def _satisfied_by(k: Optional[int]) -> List[str]:
    if k is None:
        return []
    return sorted(f.name for f in formats.REGISTRY.values() if f.k >= k)


def certify(
    forward,
    params,
    class_los: Sequence,
    class_his: Sequence,
    p_star: Optional[float] = None,
    *,
    abs_tol: Optional[float] = None,
    model_id: str,
    class_keys: Optional[Sequence[str]] = None,
    cfg: CaaConfig = caa.DEFAULT_CONFIG,
    store: Optional[CertificateStore] = None,
    k_min: int = 2,
    k_max: int = 53,
    weights_exact: bool = True,
    use_ladder: bool = True,
    mixed: bool = False,
    mixed_scopes: Optional[Sequence[str]] = None,
    layer_flops: Optional[Dict[str, float]] = None,
    formats: bool = False,
    format_opts: Optional[Dict] = None,
) -> CertificateSet:
    """The batched certificate pipeline.

    ``class_los[c]/class_his[c]`` annotate the input for class c (paper §V).
    The decision target is either ``p_star`` (classifier: ``forward`` must
    return softmax probabilities, bounds must fit the top-1 margins) or
    ``abs_tol`` (regression: absolute output error ≤ abs_tol — the
    pendulum-style verifier certificate). The result's meta records whether
    it was served from the store (``meta["from_store"]``) and the
    end-to-end seconds.

    ``use_ladder`` routes the required-k binary search through the
    jit-once :class:`repro.certify.batch.ProbeLadder` (one compilation for
    the whole precision grid; persisted bounds still come from eager
    analyses at the final ks). ``mixed`` additionally runs the
    sensitivity-driven greedy per-layer descent
    (:mod:`repro.certify.mixed`) from the uniform serving k and attaches
    the certified ``{layer_scope: k}`` map to every class certificate;
    ``mixed_scopes`` overrides the auto-discovered layer granularity and
    ``layer_flops`` weights the reported mean-k savings.

    ``formats`` runs the FULL-format synthesizer on top
    (:mod:`repro.certify.formats`): per-scope IA range analysis certifies
    the smallest overflow-free ``emax``, underflow absorption is folded
    into the bounds as the λ·2^{emin-(k-1)} absolute term, a greedy
    descent over exponent widths (jit-once ladder) finds the narrowest
    jointly-feasible map, and schema-v3 certificates carry the resulting
    ``{layer_scope: FpFormat}`` descriptors (k per scope from the mixed
    map when ``mixed`` is also set, else the uniform k).
    ``format_opts`` reaches :func:`repro.certify.formats.
    synthesize_formats` (e.g. ``e_min_bits``).
    """
    if (p_star is None) == (abs_tol is None):
        raise ValueError("pass exactly one of p_star / abs_tol")
    t0 = time.perf_counter()
    digest = params_digest(params)
    rkey = range_digest(class_los, class_his)
    n = len(class_los)
    class_keys = list(class_keys or (f"class{c}" for c in range(n)))
    if len(class_keys) != n or len(class_his) != n:
        raise ValueError(
            f"{n} class ranges but {len(class_his)} highs / "
            f"{len(class_keys)} class_keys")
    # everything that changes the proven facts OR their labelling is part
    # of the address: analysis semantics (cfg, weights_exact), decision
    # target, and the class labels the certificates are issued under
    target = {"p_star": p_star, "abs_tol": abs_tol,
              "k_min": k_min, "k_max": k_max,
              "weights_exact": weights_exact,
              "class_keys": class_keys}
    if mixed:
        # the mixed map changes what the stored certificates PROVE, so it is
        # part of the address (plain uniform requests keep their target
        # layout — and the key schema bump already separates v1 from v2)
        target["mixed"] = {"scopes": (list(mixed_scopes)
                                      if mixed_scopes is not None else None)}
    if formats:
        # likewise for the full-format map: its scope granularity AND its
        # search hyper-params change what the stored certificates prove
        target["formats"] = {"opts": dict(format_opts or {}),
                             "scopes": (list(mixed_scopes)
                                        if mixed_scopes is not None
                                        else None)}
    key = request_key(model_id, digest, rkey, cfg, target=target)
    if store is not None:
        with obs.span("store_lookup"):
            hit = store.get(key, expect_params_digest=digest)
        if hit is not None:
            obs.event("certify.store_hit", key=key[:12])
            return _as_store_hit(hit, t0)

    x = B.stack_class_ranges(class_los, class_his)
    feasible = (B.margin_feasibility(p_star) if p_star is not None
                else B.tolerance_feasibility(abs_tol))
    ladder = (B.ProbeLadder(forward, params, x, cfg=cfg,
                            weights_exact=weights_exact)
              if use_ladder else None)
    with obs.span("required_k_search", classes=n) as _sp:
        ks, reports = B.required_k_batched(
            forward, params, x, feasible,
            cfg=cfg, k_min=k_min, k_max=k_max, weights_exact=weights_exact,
            ladder=ladder,
        )
        _sp.set(ks=[None if np.isnan(v) else int(v) for v in ks],
                compiles=None if ladder is None else ladder.compiles)

    plan = None
    fplan = None
    certifiable_all = not np.isnan(ks).any()
    if (mixed or formats) and certifiable_all and mixed_scopes is None:
        # the eager reports already walked the model — their seen-scope
        # paths give the layer granularity for free (no extra pass)
        from repro.core.analyze import scope_prefixes
        mixed_scopes = scope_prefixes(next(iter(reports.values())).scopes)
    if mixed and certifiable_all:
        with obs.span("mixed_descent") as _sp:
            plan = MX.greedy_mixed_assignment(
                forward, params, x, feasible, int(np.max(ks)),
                scope_keys=mixed_scopes, cfg=cfg, k_min=k_min,
                weights_exact=weights_exact,
            )
            _sp.set(feasible=plan.feasible, probes=plan.probes,
                    compiles=plan.compiles)
    if formats and certifiable_all:
        with obs.span("format_synthesis") as _sp:
            fplan = FS.synthesize_formats(
                forward, params, x, feasible, int(np.max(ks)),
                layer_k=(dict(plan.layer_k)
                         if plan is not None and plan.feasible else None),
                scope_keys=mixed_scopes, cfg=cfg, weights_exact=weights_exact,
                **(format_opts or {}),
            )
            _sp.set(feasible=fplan.feasible, probes=fplan.probes,
                    compiles=fplan.compiles)
    layer_format = (fplan.formats_dict()
                    if fplan is not None and fplan.feasible else None)
    certs = []
    for c in range(n):
        k = None if np.isnan(ks[c]) else int(ks[c])
        # bounds come from the probe at this class's certified precision
        # (for uncertifiable classes: the deepest probe, as a diagnostic);
        # the stored cfg is the probe's, so cfg.u_max == bounds_u_max and a
        # consumer can re-derive/re-verify from the certificate alone
        probe_k = k if k is not None else k_max
        rep = reports[probe_k]
        abs_c, rel_c = rep.per_class(c)
        certs.append(Certificate(
            model_id=model_id,
            params_digest=digest,
            class_key=class_keys[c],
            cfg=dataclasses.replace(cfg, u_max=2.0 ** (1 - probe_k)),
            bounds_u_max=2.0 ** (1 - probe_k),
            final_abs_u=abs_c,
            final_rel_u=rel_c,
            required_k=k,
            satisfied_by=_satisfied_by(k),
            trace_summary=trace_summary(rep.layers),
            p_star=p_star,
            layer_k=None if plan is None else dict(plan.layer_k),
            layer_format=layer_format,
            meta={"range_digest": rkey, "abs_tol": abs_tol},
        ))
    dt = time.perf_counter() - t0
    meta = {
        "from_store": False,
        "analysis_seconds": dt,
        "probes": (sorted(set(ladder.ks_probed) | set(reports))
                   if ladder is not None else sorted(reports)),
        "n_classes": n,
        "batched": True,
        "abs_tol": abs_tol,
    }
    if ladder is not None:
        meta["ladder_compiles"] = ladder.compiles
    if mixed:
        if plan is None:
            meta["mixed"] = {"applied": False,
                             "reason": "some class is uncertifiable"}
        else:
            meta["mixed"] = {
                "applied": True,
                "layer_k": dict(plan.layer_k),
                "uniform_k": plan.uniform_k,
                "mean_k_flop_weighted": plan.mean_k(layer_flops),
                "savings_k_flop_weighted": plan.savings(layer_flops),
                "sensitivity_abs_u": {s: float(v)
                                      for s, v in plan.sensitivity.items()},
                "probes": plan.probes,
                "ladder_compiles": plan.compiles,
            }
    if formats:
        if fplan is None:
            meta["formats"] = {"applied": False,
                               "reason": "some class is uncertifiable"}
        elif not fplan.feasible:
            meta["formats"] = {
                "applied": False,
                "reason": "no jointly-feasible format map confirmed",
                "history": fplan.history,
            }
        else:
            meta["formats"] = {
                "applied": True,
                "layer_format": layer_format,
                "uniform_k": fplan.uniform_k,
                # per-class bounds of the CONFIRMING eager pass, in units of
                # u_ref = 2^{1-k_ref} — what the acceptance re-verification
                # reproduces from the stored descriptors alone
                "abs_u_ref": [float(v) for v in fplan.abs_u],
                "rel_u_ref": [float(v) for v in fplan.rel_u],
                "k_ref": int(fplan.k_ref),
                "baseline_bits": fplan.baseline_bits,
                "mean_bits_flop_weighted": fplan.mean_bits(layer_flops),
                "savings_bits_flop_weighted":
                    fplan.savings_bits(layer_flops),
                "scope_ranges": {s: r.to_dict()
                                 for s, r in fplan.scope_ranges.items()},
                "emax_floor_bits": dict(fplan.emax_floor),
                "history": fplan.history,
                "probes": fplan.probes,
                "ladder_compiles": fplan.compiles,
            }
    cs = CertificateSet(
        model_id=model_id,
        params_digest=digest,
        certificates=certs,
        p_star=p_star,
        meta=meta,
    )
    if store is not None:
        with obs.span("store_put"):
            store.put(key, cs, request={
                "model_id": model_id, "range_digest": rkey,
                "p_star": p_star})
    return cs


# ---------------------------------------------------------------------------
# LM serving certificates
# ---------------------------------------------------------------------------

def _lm_probe(arch_cfg, params, tokens, k: int):
    """One emulated-k CAA pass over the reduced arch; returns per-sequence
    argmax safety of the final-position logits plus the certified actual
    error of the emulated run (both rigorous)."""
    from repro.models import transformer as T

    ccfg = CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k)
    bk = CaaOps(ccfg)
    logits, _ = T.forward(bk, params, arch_cfg, tokens)
    last = caa.slice_(logits, (slice(None), slice(-1, None)))
    lo = np.asarray(last.exact.lo)[:, 0]
    hi = np.asarray(last.exact.hi)[:, 0]
    preds = np.asarray(jnp.argmax(last.val, axis=-1))[:, 0]
    safe = np.array([
        precision.classification_safe(lo[i], hi[i], int(preds[i]))
        for i in range(lo.shape[0])
    ])
    a_abs, a_rel = caa.actual_error_in_u(last, ccfg.u_max)
    return {
        "safe": bool(safe.all()),
        "abs_u": float(jnp.max(a_abs)),
        # +inf propagates (paper convention: 'no bound of this kind') —
        # masking it as 0 would serve 'perfect relative accuracy'
        "rel_u": float(jnp.max(a_rel)),
        "trace": bk.trace,
        "preds": preds,
    }


def certify_lm(
    arch_name: str,
    arch_cfg=None,
    params=None,
    *,
    seq: int = 8,
    batch: int = 1,
    seed: int = 1,
    k_min: int = 4,
    k_max: int = 24,
    store: Optional[CertificateStore] = None,
    mixed: bool = False,
    formats: bool = False,
    profiles: Sequence[int] = (),
    layer_flops: Optional[Dict[str, float]] = None,
    format_opts: Optional[Dict] = None,
) -> CertificateSet:
    """Certified serving precision for a registered architecture.

    Binary-searches the smallest k (u = 2^{1-k}) at which the k-bit emulated
    model's next-token argmax is rigorously pinned by the CAA enclosure for
    the certification input profile. The resulting certificate is what
    ``launch/serve.py --certificates`` consumes for ``precision_k`` and the
    (δ̄, ε̄, k) response metadata.

    ``mixed``/``formats`` switch to the scan-native layer-stacked pipeline
    (:func:`repro.certify.lm.certify_lm_stacked`): per-layer {scope: k}
    maps and per-scope full FpFormats certified against the decode-argmax
    margins through ONE compiled probe ladder, schema-v3 output, serving
    applied through the scanned per-layer quantisation backends.
    ``profiles`` (extra sequence lengths) widen the format pipeline's range
    evidence; it implies nothing for the plain uniform path.
    """
    if mixed or formats:
        from .lm import certify_lm_stacked

        return certify_lm_stacked(
            arch_name, arch_cfg, params, seq=seq, batch=batch, seed=seed,
            k_min=k_min, k_max=k_max, mixed=mixed, formats=formats,
            profiles=profiles, store=store, layer_flops=layer_flops,
            format_opts=format_opts)

    from repro import configs
    from repro.models import transformer as T

    t0 = time.perf_counter()
    if arch_cfg is None:
        arch_cfg = configs.get(arch_name).SMOKE
    if params is None:
        params = T.init_params(jax.random.PRNGKey(0), arch_cfg)
    digest = params_digest(params)
    tokens = jax.random.randint(
        jax.random.PRNGKey(seed), (batch, seq), 0, arch_cfg.vocab)
    class_key = f"lm/{arch_cfg.name}/tokens[{batch}x{seq}]seed{seed}"
    base_cfg = CaaConfig(u_max=2.0 ** (1 - k_max), emulate_k=k_max)
    key = request_key(
        f"lm/{arch_name}", digest, class_key, base_cfg,
        target={"argmax_safe": True, "k_min": k_min, "k_max": k_max},
    )
    if store is not None:
        with obs.span("store_lookup"):
            hit = store.get(key, expect_params_digest=digest)
        if hit is not None:
            obs.event("certify.store_hit", key=key[:12])
            return _as_store_hit(hit, t0)

    probes: Dict[int, dict] = {}

    def probe(k: int) -> dict:
        if k not in probes:
            with obs.span("lm_probe", k=k):
                probes[k] = _lm_probe(arch_cfg, params, tokens, k)
        return probes[k]

    with obs.span("uniform_search", k_min=k_min, k_max=k_max) as _sp:
        if not probe(k_max)["safe"]:
            required = None
        else:
            lo, hi = k_min, k_max      # invariant: hi safe
            while lo < hi:
                mid = (lo + hi) // 2
                if probe(mid)["safe"]:
                    hi = mid
                else:
                    lo = mid + 1
            required = hi
        _sp.set(required_k=required, probes=len(probes))
    rep = probes[required if required is not None else k_max]
    kcfg = CaaConfig(
        u_max=2.0 ** (1 - (required if required is not None else k_max)),
        emulate_k=required if required is not None else k_max,
    )
    cert = Certificate(
        model_id=f"lm/{arch_name}",
        params_digest=digest,
        class_key=class_key,
        cfg=kcfg,
        bounds_u_max=kcfg.u_max,
        final_abs_u=rep["abs_u"],
        final_rel_u=rep["rel_u"],
        required_k=required,
        satisfied_by=_satisfied_by(required),
        trace_summary=trace_summary(rep["trace"]),
        p_star=None,
        meta={"criterion": "decode argmax rigorously pinned",
              "sample_next_tokens": [int(t) for t in rep["preds"][:4]]},
    )
    dt = time.perf_counter() - t0
    cs = CertificateSet(
        model_id=f"lm/{arch_name}",
        params_digest=digest,
        certificates=[cert],
        p_star=None,
        meta={"from_store": False, "analysis_seconds": dt,
              "probes": sorted(probes), "arch": arch_name},
    )
    if store is not None:
        with obs.span("store_put"):
            store.put(key, cs, request={"model_id": f"lm/{arch_name}",
                                        "class_key": class_key})
    return cs


def serving_certificate(
    arch_name: str, arch_cfg, params,
    store_dir: str, **kw,
) -> CertificateSet:
    """What the serving path calls: store-first LM certification."""
    return certify_lm(arch_name, arch_cfg, params,
                      store=CertificateStore(store_dir), **kw)
