"""Scan-native mixed-precision / custom-format certificates for LM archs.

The classifier pipelines (PR 2/3) certify per-scope {scope: k} and
{scope: FpFormat} maps by gating CAA knobs on Python-side scope strings —
which only exists where ``layer_loop`` unrolls eagerly. LM architectures
run their layer stack as ONE ``lax.scan`` body; this module is the
layer-stacked version of the same pipeline:

  * **probes** go through a single jit-compiled
    :class:`repro.certify.formats.FormatProbeLadder` in ``stacked`` mode —
    the scan body gathers each layer's (round_scale, round_abs) from traced
    ``[L]`` lanes by the carry's layer index, so the whole uniform search,
    the sensitivity ranking, the greedy mixed-k descent AND the exponent
    descent cost exactly ONE compilation with HLO flat in depth (the
    mantissa searches ride the same executable via
    :meth:`~repro.certify.formats.ladder.FormatProbeLadder.mixed_view`);
  * **decisions** use the decode-argmax margin: the exact logits enclosure
    of the certification profile pins the next-token argmax as long as
    2·δ̄·u stays below the top-1 gap (the paper's argmax analysis applied
    to decode logits, parametric in u);
  * **confirmations** stay on the eager per-layer reference (unrolled
    ``layer{i}`` string scopes, the PR 2/3 machinery): persisted bounds
    always come from an eager pass that re-proves feasibility — and, for
    formats, overflow-freedom — at the final map; ladder bounds only steer
    the search.

Scope keys are the concrete ``layer{i}`` lanes plus the ``head`` block
(:mod:`repro.models.transformer` names both); ``embed`` and other unmapped
scopes serve at the uniform certified k. The certificates are ordinary
schema-v3 :class:`repro.certify.spec.Certificate`s, so
``launch/serve.py --certificates`` applies the maps through its scanned
per-layer quantisation backends with no new plumbing.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import analyze, caa
from repro.core import interval as iv
from repro.core.backend import CaaOps
from repro.core.scopes import scope_prefixes
from . import formats as FS
from . import mixed as MX
from .spec import Certificate, CertificateSet, trace_summary
from .store import CertificateStore, params_digest, request_key

_LAYER_KEY = re.compile(r"^layer\d+$")


def _frontend_kwargs(arch_cfg, batch: int, seed: int) -> Dict:
    rng = np.random.RandomState(seed)
    if arch_cfg.frontend == "audio":
        return {"enc_embeds": rng.randn(
            batch, arch_cfg.frontend_seq,
            arch_cfg.frontend_dim).astype(np.float32)}
    if arch_cfg.frontend == "vision":
        return {"frontend_embeds": rng.randn(
            batch, arch_cfg.frontend_seq,
            arch_cfg.frontend_dim).astype(np.float32)}
    return {}


def _lm_forward_adapter(arch_cfg, tokens, fw_kwargs):
    """Close the arch/profile over a classifier-shaped ``forward(bk, params,
    x)``: returns the final-position logits as a CaaTensor [B, 1, V] (the
    dummy ``x`` only fixes the per-sequence "class" axis for the ladders).
    Works for every CAA backend — eager unrolled or scan-native."""
    from repro.models import transformer as T

    def forward(bk, params, x):
        del x
        logits, _ = T.forward(bk, params, arch_cfg, tokens, **fw_kwargs)
        return caa.slice_(logits, (slice(None), slice(-1, None)))

    return forward


def lm_layer_flops(arch_cfg) -> Dict[str, float]:
    """Per-scope matmul FLOPs per token — the weights of the mean-k /
    mean-bits savings reports (relative weights only; the token count
    cancels). Derived from the same closed forms as
    :func:`repro.models.transformer.analytic_params`: 2 FLOPs per stored
    matmul parameter per token."""
    from repro.models import transformer as T

    total = T.analytic_params(arch_cfg, active=True)
    emb = arch_cfg.vocab * arch_cfg.d_model
    head = 2.0 * arch_cfg.d_model * arch_cfg.vocab
    n_emb = emb * (1 if arch_cfg.tie_embeddings else 2)
    per_layer = 2.0 * max(total - n_emb, 1) / max(arch_cfg.n_layers, 1)
    out = {f"layer{i}": per_layer for i in range(arch_cfg.n_layers)}
    out["head"] = head
    return out


def _gap_feasibility(gaps: np.ndarray):
    """Per-sequence argmax feasibility: the exact logits enclosure (which no
    probe changes — only δ̄ depends on the knobs) pins the top-1 decision
    iff inflating every logit by δ̄·u keeps the predicted logit's lower end
    above every rival's upper end: 2·δ̄·u < gap."""

    def feasible(abs_u, rel_u, k: int) -> np.ndarray:
        del rel_u                      # logits cross 0: ε̄ is typically +inf
        u = 2.0 ** (1 - int(k))
        with np.errstate(invalid="ignore"):
            return np.asarray(abs_u, np.float64) * u * 2.0 < gaps

    return feasible


@dataclasses.dataclass
class _EagerRef:
    """One eager per-layer reference pass (the confirmation oracle)."""

    abs_u: np.ndarray          # [B] max δ̄ of final-position logits
    rel_u: np.ndarray
    gaps: np.ndarray           # [B] exact-enclosure top-1 margins
    preds: np.ndarray          # [B] predicted next tokens
    trace: list
    scopes: List[str]


def _eager_pass(forward, params, x, ops) -> _EagerRef:
    out = forward(ops, params, x)
    red = tuple(range(1, out.ndim))
    dbar = jnp.broadcast_to(out.dbar, out.shape)
    ebar = jnp.broadcast_to(out.ebar, out.shape)
    lo = np.asarray(out.exact.lo).reshape(out.shape[0], -1)
    hi = np.asarray(out.exact.hi).reshape(out.shape[0], -1)
    val = np.asarray(out.val).reshape(out.shape[0], -1)
    preds = val.argmax(-1)
    gaps = np.array([
        lo[b, preds[b]] - np.max(np.delete(hi[b], preds[b]))
        for b in range(lo.shape[0])
    ])
    return _EagerRef(
        abs_u=np.asarray(jnp.max(dbar, axis=red), np.float64),
        rel_u=np.asarray(jnp.max(ebar, axis=red), np.float64),
        gaps=gaps, preds=preds, trace=list(ops.trace),
        scopes=list(ops.seen_scopes))


def certify_lm_stacked(
    arch_name: str,
    arch_cfg=None,
    params=None,
    *,
    seq: int = 8,
    batch: int = 1,
    seed: int = 1,
    k_min: int = 4,
    k_max: int = 53,
    mixed: bool = True,
    formats: bool = False,
    profiles: Sequence[int] = (),
    store: Optional[CertificateStore] = None,
    layer_flops: Optional[Dict[str, float]] = None,
    format_opts: Optional[Dict] = None,
) -> CertificateSet:
    """Mixed-precision / custom-format serving certificate for an LM arch.

    Certifies, for the (batch × seq) certification profile, the smallest
    uniform mantissa k whose rigorous parametric bounds pin the next-token
    argmax — then refines it into a per-layer ``{layer{i}|head: k}`` map
    (``mixed``) and per-scope full ``FpFormat``s (``formats``), all probed
    through ONE compiled scan-native analysis and eagerly re-confirmed on
    the per-layer reference before anything persists. ``profiles`` lists
    extra sequence lengths whose range passes widen the overflow (emax)
    evidence via :func:`repro.core.analyze.merge_range_maps`.
    """
    from repro import configs
    from repro.models import transformer as T

    t0 = time.perf_counter()
    if arch_cfg is None:
        arch_cfg = configs.get(arch_name).SMOKE
    if params is None:
        params = T.init_params(jax.random.PRNGKey(0), arch_cfg)
    digest = params_digest(params)
    tokens = jax.random.randint(
        jax.random.PRNGKey(seed), (batch, seq), 0, arch_cfg.vocab)
    fw_kwargs = _frontend_kwargs(arch_cfg, batch, seed)
    class_key = f"lm/{arch_cfg.name}/tokens[{batch}x{seq}]seed{seed}"
    base_cfg = caa.DEFAULT_CONFIG
    target = {
        "criterion": "decode argmax pinned (parametric margins)",
        "k_min": k_min, "k_max": k_max,
        "mixed": bool(mixed), "formats": bool(formats),
        "profiles": sorted({int(p) for p in profiles}),
    }
    if formats:
        target["format_opts"] = dict(format_opts or {})
    key = request_key(f"lm/{arch_name}", digest, class_key,
                      dataclasses.replace(base_cfg, u_max=2.0 ** (1 - k_max)),
                      target=target)
    if store is not None:
        with obs.span("store_lookup"):
            hit = store.get(key, expect_params_digest=digest)
        if hit is not None:
            obs.event("certify.store_hit", key=key[:12])
            return dataclasses.replace(hit, meta=dict(
                hit.meta, from_store=True,
                lookup_seconds=time.perf_counter() - t0))

    forward = _lm_forward_adapter(arch_cfg, tokens, fw_kwargs)
    x = caa.make(np.zeros((batch, 1)))

    # -- eager reference: margins + scope discovery (one unrolled pass) -----
    eager_cache: Dict[Tuple, _EagerRef] = {}

    def eager_uniform(k: int) -> _EagerRef:
        if ("u", k) not in eager_cache:
            ops = CaaOps(analyze.batch_config(
                dataclasses.replace(base_cfg, u_max=2.0 ** (1 - k)), batch))
            with obs.span("eager_reference", k=int(k)):
                eager_cache[("u", k)] = _eager_pass(forward, params, x, ops)
        return eager_cache[("u", k)]

    ref = eager_uniform(k_max)
    gaps = ref.gaps
    feasible = _gap_feasibility(gaps)
    scope_keys = [s for s in scope_prefixes(ref.scopes, 1)
                  if _LAYER_KEY.match(s) or s == "head"]

    def finish(cs: CertificateSet) -> CertificateSet:
        cs.meta["analysis_seconds"] = time.perf_counter() - t0
        if store is not None:
            with obs.span("store_put"):
                store.put(key, cs, request={"model_id": f"lm/{arch_name}",
                                            "class_key": class_key})
        return cs

    def certificate(required, rep: _EagerRef, layer_k=None,
                    layer_format=None, extra_meta=None,
                    class_key_=None) -> Certificate:
        probe_k = required if required is not None else k_max
        return Certificate(
            model_id=f"lm/{arch_name}",
            params_digest=digest,
            class_key=class_key if class_key_ is None else class_key_,
            cfg=dataclasses.replace(base_cfg, u_max=2.0 ** (1 - probe_k)),
            bounds_u_max=2.0 ** (1 - probe_k),
            final_abs_u=float(np.max(rep.abs_u)),
            final_rel_u=float(np.max(rep.rel_u)),
            required_k=None if required is None else int(required),
            satisfied_by=_satisfied_by(required),
            trace_summary=trace_summary(
                [r for r in rep.trace if r.kind != "router"]),
            p_star=None,
            layer_k=(None if layer_k is None
                     else {str(s): int(v) for s, v in layer_k.items()}),
            layer_format=layer_format,
            meta=dict({
                "criterion": target["criterion"],
                "min_gap": float(np.min(rep.gaps)),
                "sample_next_tokens": [int(t) for t in rep.preds[:4]],
            }, **(extra_meta or {})),
        )

    meta = {"from_store": False, "arch": arch_name, "batched": True,
            "scan_native": True, "scope_keys": list(scope_keys),
            "profiles": target["profiles"]}

    if (gaps <= 0).any() or not scope_keys:
        meta["reason"] = ("no positive argmax margin on the certification "
                          "profile" if (gaps <= 0).any()
                          else "model exposes no certifiable scopes")
        return finish(CertificateSet(
            model_id=f"lm/{arch_name}", params_digest=digest,
            certificates=[certificate(None, ref)], p_star=None, meta=meta))

    # -- ONE stacked ladder serves every search below -----------------------
    ladder = FS.FormatProbeLadder(forward, params, x, scope_keys,
                                  cfg=base_cfg, stacked=True)
    mview = ladder.mixed_view()

    def ladder_ok(k: int) -> bool:
        abs_u, rel_u, k_ref = mview({s: k for s in scope_keys}, k)
        return bool(np.all(feasible(abs_u, rel_u, k_ref)))

    # uniform binary search (ladder), then eager-confirm the endpoint —
    # mirroring batch.required_k_batched's confirm-or-bump fixpoint
    if not ladder_ok(k_max):
        meta["reason"] = f"not certifiable at k_max={k_max}"
        meta["probes"] = ladder.probes
        meta["ladder_compiles"] = ladder.compiles
        return finish(CertificateSet(
            model_id=f"lm/{arch_name}", params_digest=digest,
            certificates=[certificate(None, ref)], p_star=None, meta=meta))
    with obs.span("uniform_search", k_min=k_min, k_max=k_max) as _sp:
        lo, hi = k_min, k_max
        while lo < hi:
            mid = (lo + hi) // 2
            if ladder_ok(mid):
                hi = mid
            else:
                lo = mid + 1
        uniform_k = hi
        _sp.set(uniform_k=int(uniform_k))
    while not bool(np.all(feasible(eager_uniform(uniform_k).abs_u, None,
                                   uniform_k))):
        if uniform_k >= k_max:
            meta["reason"] = "eager confirmation failed at k_max"
            meta["probes"] = ladder.probes
            meta["ladder_compiles"] = ladder.compiles
            return finish(CertificateSet(
                model_id=f"lm/{arch_name}", params_digest=digest,
                certificates=[certificate(None, ref)], p_star=None,
                meta=meta))
        uniform_k += 1
    urep = eager_uniform(uniform_k)
    flops = layer_flops if layer_flops is not None else lm_layer_flops(arch_cfg)
    flops = {s: flops.get(s, 1.0) for s in scope_keys}

    # extra input profiles: forward adapters shared by the format range
    # evidence AND the per-profile argmax certificates below
    extra_profiles = sorted({int(p) for p in target["profiles"]
                             if int(p) != seq})
    prof_fwds = {
        p_seq: _lm_forward_adapter(
            arch_cfg,
            jax.random.randint(jax.random.PRNGKey(seed), (batch, p_seq), 0,
                               arch_cfg.vocab),
            fw_kwargs)
        for p_seq in extra_profiles
    }

    # -- greedy per-layer mixed descent (stacked probes, eager confirm) -----
    # formats imply the mixed descent: the synthesis's mixed-mantissa
    # attempt needs a layer_k map to fix per-scope ks
    run_mixed = mixed or formats
    layer_k = None
    if run_mixed:
        with obs.span("mixed_descent") as _sp:
            plan = MX.greedy_mixed_assignment(
                forward, params, x, feasible, uniform_k,
                scope_keys=scope_keys, cfg=base_cfg, k_min=k_min,
                ladder=mview)
            _sp.set(feasible=plan.feasible)
        layer_k = dict(plan.layer_k)
        confirms = 0
        while True:
            k_ref = min(list(layer_k.values()) + [uniform_k])
            u_ref = 2.0 ** (1 - k_ref)
            ops = MX.MixedCaaOps(
                analyze.batch_config(
                    dataclasses.replace(base_cfg, u_max=u_ref), batch),
                {s: 2.0 ** (1 - k) / u_ref for s, k in layer_k.items()},
                default_scale=2.0 ** (1 - uniform_k) / u_ref)
            with obs.span("mixed_confirm", k_ref=int(k_ref)):
                rep = _eager_pass(forward, params, x, ops)
            confirms += 1
            if bool(np.all(feasible(rep.abs_u, None, k_ref))):
                break
            raised = False
            for s in sorted(layer_k):
                if layer_k[s] < uniform_k:
                    layer_k[s] += 1
                    raised = True
            if not raised:
                break
        mixed_rep, mixed_k_ref = rep, k_ref
        mean_k = MX.flop_weighted_mean_k(layer_k, flops)
        meta["mixed"] = {
            "applied": True,
            "layer_k": {s: int(v) for s, v in layer_k.items()},
            "uniform_k": int(uniform_k),
            "mean_k_flop_weighted": mean_k,
            "savings_k_flop_weighted": uniform_k - mean_k,
            # serving cost of the mixed map: k-bit mantissa in a binary32
            # carrier → 1 sign + 8 exponent + (k−1) stored mantissa bits
            "mean_bits_flop_weighted": mean_k + 8.0,
            "savings_bits_vs_binary32": 32.0 - (mean_k + 8.0),
            "sensitivity_abs_u": {s: float(v)
                                  for s, v in plan.sensitivity.items()},
            "probes": ladder.probes,
            "eager_confirms": confirms,
            "ladder_compiles": ladder.compiles,
        }

    # -- full-format synthesis (shared ladder; profile-widened ranges) ------
    layer_format = None
    fplan = None
    if formats:
        opts = dict(format_opts or {})
        # affine/zonotope range evidence: min-combined with the IA ranges
        # per profile, it keeps the emax floors finite where the IA pass
        # saturates at the mixed map's coarse u_ref — without it the
        # mixed-mantissa attempt below dies on base_overflow for every
        # attention arch (the silent uniform-k fallback this knob fixes)
        affine = bool(opts.pop("affine", True))
        affine_budget = int(opts.pop("affine_budget",
                                     iv.AFF_DEFAULT_BUDGET))
        affine_rank = str(opts.pop("affine_rank", iv.AFF_DEFAULT_RANK))
        obs.gauge("affine.budget", affine_budget)
        affine_stacked = bool(opts.pop("affine_stacked", False))
        affine_sublanes = tuple(opts.pop("affine_sublanes",
                                         ("attn", "mlp")))

        tighten_ranges_fn = None
        aff_cache: Dict[Tuple, Dict] = {}

        def affine_map(fwd, lf, df):
            return analyze.analyze_ranges_affine(
                fwd, params, x, lf, df, keys=scope_keys,
                stacked=affine_stacked, sublanes=affine_sublanes,
                budget=affine_budget, condense_rank=affine_rank)

        if affine:
            def tighten_ranges_fn(lf, df):
                ck = (tuple(sorted((s, f.name) for s, f in lf.items())),
                      df.name)
                if ck not in aff_cache:
                    with obs.span("affine_ranges", scopes=len(lf),
                                  budget=affine_budget, rank=affine_rank):
                        aff_cache[ck] = affine_map(forward, lf, df)
                return aff_cache[ck]

        extra_ranges_fn = None
        if extra_profiles:
            def extra_ranges_fn(lf, df):
                maps = []
                for p_seq in extra_profiles:
                    pf = prof_fwds[p_seq]
                    _, _, _, ranges = FS.eager_format_report(
                        pf, params, x, lf, df, scope_keys, cfg=base_cfg)
                    if affine:
                        # tighten per profile BEFORE the cross-profile
                        # max — the other order is unsound
                        ranges = analyze.tighten_range_maps(
                            ranges, affine_map(pf, lf, df))
                    maps.append(ranges)
                return analyze.merge_range_maps(maps, scope_keys)

        # Exponent-lattice mantissas: "auto" tries the mixed map's per-scope
        # ks first (the affine evidence keeps its overflow floors finite);
        # only if the joint feasibility still fails does it fall back to
        # the uniform mantissa so the exponent descent can proceed alone.
        layer_k_mode = opts.pop("layer_k_mode", "auto")
        attempts = []
        if layer_k_mode in ("auto", "mixed") and layer_k:
            attempts.append(("mixed", dict(layer_k)))
        if layer_k_mode in ("auto", "uniform") or not attempts:
            attempts.append(("uniform", None))
        for mode, lk in attempts:
            with obs.span("format_synthesis", mantissa_mode=mode) as _sp:
                fplan = FS.synthesize_formats(
                    forward, params, x, feasible, uniform_k, layer_k=lk,
                    scope_keys=scope_keys, cfg=base_cfg, ladder=ladder,
                    extra_ranges_fn=extra_ranges_fn,
                    tighten_ranges_fn=tighten_ranges_fn, **opts)
                _sp.set(feasible=fplan.feasible)
            if fplan.feasible:
                break
            saturated = [s for s, r in fplan.scope_ranges.items()
                         if not np.isfinite(r.max_abs)]
            obs.event(
                "formats.mantissa_fallback", mode=mode,
                affine=bool(affine), saturated_scopes=len(saturated),
                reason=("range enclosures saturated — overflow floors "
                        "unprovable at this mantissa map" if saturated
                        else "joint feasibility failed at this mantissa "
                             "map"))
        if fplan.feasible:
            mean_bits = fplan.mean_bits(flops)
            from repro.core import formats as F
            mixed_bits = (meta["mixed"]["mean_bits_flop_weighted"]
                          if layer_k is not None else
                          float(F.from_bits(uniform_k, 8).total_bits))
            # attach the format map only when it is the cheaper serving
            # option — serving prefers layer_format over layer_k, so
            # attaching a costlier map would regress real-silicon bits
            attach = mean_bits <= mixed_bits
            if attach:
                layer_format = fplan.formats_dict()
            meta["formats"] = {
                "applied": True,
                "attached": bool(attach),
                "mantissa_mode": mode,
                "layer_format": fplan.formats_dict(),
                "uniform_k": int(uniform_k),
                "baseline_bits": fplan.baseline_bits,
                "mean_bits_flop_weighted": mean_bits,
                "savings_bits_flop_weighted": fplan.savings_bits(flops),
                # the serving-cost headline: the cheapest certified map vs
                # shipping uniform binary32 values
                "savings_bits_vs_binary32":
                    32.0 - min(mean_bits, mixed_bits),
                "scope_ranges": {s: r.to_dict()
                                 for s, r in fplan.scope_ranges.items()},
                "emax_floor_bits": dict(fplan.emax_floor),
                "probes": fplan.probes,
                "ladder_compiles": ladder.compiles,
            }
            if not attach:
                meta["formats"]["attach_reason"] = (
                    "mixed {scope: k} map serves cheaper "
                    f"({mixed_bits:.2f}b < {mean_bits:.2f}b/value) — format "
                    "map certified but not attached")
        else:
            meta["formats"] = {
                "applied": False,
                "reason": "no jointly-feasible format map confirmed",
                "history": fplan.history,
            }

    meta["probes"] = ladder.probes
    meta["ladder_compiles"] = ladder.compiles
    # The persisted (final_abs_u, bounds_u_max) pair comes from the UNIFORM
    # eager confirmation — bounds_u_max is documented as "the u at which
    # final_abs_u was computed", and error_bars() serves dbar_u·u, so the
    # units must match required_k (exactly as the classifier pipeline
    # persists the uniform probe's bounds next to its layer_k map). The
    # mixed confirmation's own bounds ride in meta, in THEIR unit.
    extra_meta = {}
    if layer_k is not None:
        extra_meta["mixed_confirm"] = {
            "abs_u_ref": float(np.max(mixed_rep.abs_u)),
            "rel_u_ref": float(np.max(mixed_rep.rel_u)),
            "k_ref": int(mixed_k_ref),
        }
    primary_prov = {}
    if layer_k is not None:
        primary_prov["layer_k"] = "synthesized"
    if layer_format is not None:
        primary_prov["layer_format"] = "synthesized"
    if primary_prov:
        extra_meta["map_provenance"] = primary_prov
    cert = certificate(
        uniform_k, urep, layer_k=layer_k, layer_format=layer_format,
        extra_meta=extra_meta)

    # -- full multi-profile argmax certificates -----------------------------
    # Each extra profile gets its own eagerly-confirmed certificate at the
    # certified uniform k (its own class_key, its own margins) — only
    # profiles whose argmax actually pins are appended; failures are
    # recorded in meta and never poison the primary certificate. A profile
    # first re-confirms the attached layer_k / layer_format maps under ITS
    # OWN margins; a profile that REJECTS a map no longer just raises it
    # until feasible — it re-runs the greedy mixed descent / the exponent
    # synthesis from its own margins and its own tightened range evidence
    # through its own jit-once stacked ladder (built lazily, so accepting
    # profiles compile nothing), then eagerly re-confirms the result.
    # serving_layer_k / serving_layer_format merge per-scope COARSEST
    # demand across the set, so per-profile maps stay jointly sound; the
    # legacy raise-until-feasible map is still computed as the baseline and
    # the fallback whenever re-synthesis fails to beat it scope-wise, which
    # keeps the merged serving cost ≤ the legacy merge by construction.
    profile_certs: List[Certificate] = []
    ok_profiles: List[int] = []
    p_old_maps: Dict[int, Optional[Dict[str, int]]] = {}
    p_format_whole: Dict[int, bool] = {}
    prof_ladders: Dict[int, FS.FormatProbeLadder] = {}
    if extra_profiles:
        from repro.certify.formats.ladder import eager_format_report
        from repro.core import formats as F

        meta["profile_certificates"] = {}
        for p_seq in extra_profiles:
            pf = prof_fwds[p_seq]
            ops = CaaOps(analyze.batch_config(
                dataclasses.replace(base_cfg, u_max=2.0 ** (1 - uniform_k)),
                batch))
            with obs.span("profile_confirm", seq=int(p_seq),
                          k=int(uniform_k)):
                prep = _eager_pass(pf, params, x, ops)
            p_feasible = _gap_feasibility(prep.gaps)
            p_ok = bool((prep.gaps > 0).all()) and bool(np.all(
                p_feasible(prep.abs_u, None, uniform_k)))
            p_meta = {
                "certified": bool(p_ok),
                "min_gap": float(np.min(prep.gaps)),
                "abs_u": float(np.max(prep.abs_u)),
            }
            prov: Dict[str, str] = {}

            def p_ladder(pf=pf, p_seq=p_seq):
                if p_seq not in prof_ladders:
                    prof_ladders[p_seq] = FS.FormatProbeLadder(
                        pf, params, x, scope_keys, cfg=base_cfg,
                        stacked=True, tag=f"format[seq{p_seq}]")
                return prof_ladders[p_seq]

            def eager_mixed(trial, pf=pf, p_seq=p_seq):
                k_ref = min(list(trial.values()) + [uniform_k])
                u_ref = 2.0 ** (1 - k_ref)
                ops_m = MX.MixedCaaOps(
                    analyze.batch_config(
                        dataclasses.replace(base_cfg, u_max=u_ref), batch),
                    {s: 2.0 ** (1 - k) / u_ref for s, k in trial.items()},
                    default_scale=2.0 ** (1 - uniform_k) / u_ref)
                with obs.span("profile_confirm_mixed", seq=int(p_seq),
                              k_ref=int(k_ref)):
                    prep_m = _eager_pass(pf, params, x, ops_m)
                return bool(np.all(_gap_feasibility(prep_m.gaps)(
                    prep_m.abs_u, None, k_ref)))

            def confirm_raise(start, eager_mixed=eager_mixed):
                # the legacy fixpoint: lift every below-uniform scope one
                # step until this profile's eager confirm passes (the
                # all-uniform endpoint reduces to the uniform pass that
                # already certified above)
                trial = dict(start)
                while True:
                    if eager_mixed(trial):
                        return trial
                    raised = False
                    for s in sorted(trial):
                        if trial[s] < uniform_k:
                            trial[s] += 1
                            raised = True
                    if not raised:
                        return None

            p_layer_k = None
            if p_ok and layer_k is not None:
                raised_map = confirm_raise(layer_k)
                p_old_maps[p_seq] = raised_map
                if raised_map == layer_k:
                    p_layer_k = dict(layer_k)
                    prov["layer_k"] = "primary-confirmed"
                else:
                    # rejected: greedy descent from THIS profile's margins
                    with obs.span("profile_mixed_descent",
                                  seq=int(p_seq)) as _sp:
                        pplan = MX.greedy_mixed_assignment(
                            pf, params, x, p_feasible, uniform_k,
                            scope_keys=scope_keys, cfg=base_cfg,
                            k_min=k_min, ladder=p_ladder().mixed_view())
                        _sp.set(feasible=pplan.feasible)
                    cand = confirm_raise(pplan.layer_k)
                    if (cand is not None and raised_map is not None
                            and any(cand[s] > raised_map[s]
                                    for s in raised_map)):
                        # scope-wise cap so the coarsest-demand merge can
                        # never exceed the legacy merge; capping lowers ks
                        # (grows error), so the cap must re-confirm
                        cand = confirm_raise(
                            {s: min(cand[s], raised_map[s]) for s in cand})
                    if cand is not None and (
                            raised_map is None
                            or all(cand[s] <= raised_map[s]
                                   for s in raised_map)):
                        p_layer_k = cand
                        prov["layer_k"] = "resynthesized"
                    elif raised_map is not None:
                        p_layer_k = raised_map
                        prov["layer_k"] = "raised"
                    if raised_map is not None:
                        p_meta["mixed_raised_mean_k"] = \
                            MX.flop_weighted_mean_k(raised_map, flops)
                        p_meta["mixed_resynth_differs"] = bool(
                            p_layer_k is not None
                            and p_layer_k != raised_map)
                p_meta["mixed_certified"] = p_layer_k is not None
                if p_layer_k is not None:
                    p_meta["mixed_mean_k"] = MX.flop_weighted_mean_k(
                        p_layer_k, flops)
                    p_meta["mixed_raised_scopes"] = sum(
                        1 for s in layer_k if p_layer_k[s] > layer_k[s])
            p_layer_format = None
            if p_ok and layer_format is not None:
                lf = {s: F.from_dict(d) for s, d in layer_format.items()
                      if s}
                df = F.from_dict(layer_format[""])
                with obs.span("profile_confirm_format", seq=int(p_seq)):
                    f_abs, _f_rel, fk_ref, _r = eager_format_report(
                        pf, params, x, lf, df, scope_keys, cfg=base_cfg)
                whole = bool(np.all(p_feasible(f_abs, None, fk_ref)))
                p_format_whole[p_seq] = whole
                if whole:
                    p_layer_format = dict(layer_format)
                    prov["layer_format"] = "primary-confirmed"
                else:
                    # rejected: exponent synthesis from THIS profile's own
                    # tightened range evidence. Per-profile soundness is
                    # enough — serving merges coarsest demand, and the
                    # primary certificate already carries the
                    # profile-widened overflow evidence.
                    p_tighten = None
                    if affine:
                        p_aff_cache: Dict[Tuple, Dict] = {}

                        def p_tighten(lf_, df_, pf=pf,
                                      p_aff_cache=p_aff_cache):
                            ck = (tuple(sorted((s, f.name)
                                               for s, f in lf_.items())),
                                  df_.name)
                            if ck not in p_aff_cache:
                                with obs.span("affine_ranges",
                                              scopes=len(lf_),
                                              budget=affine_budget,
                                              rank=affine_rank):
                                    p_aff_cache[ck] = affine_map(
                                        pf, lf_, df_)
                            return p_aff_cache[ck]

                    p_attempts = []
                    if p_layer_k:
                        p_attempts.append(("mixed", dict(p_layer_k)))
                    p_attempts.append(("uniform", None))
                    pfp = None
                    for p_mode, p_lk in p_attempts:
                        with obs.span("profile_format_synthesis",
                                      seq=int(p_seq),
                                      mantissa_mode=p_mode) as _sp:
                            pfp = FS.synthesize_formats(
                                pf, params, x, p_feasible, uniform_k,
                                layer_k=p_lk, scope_keys=scope_keys,
                                cfg=base_cfg, ladder=p_ladder(),
                                tighten_ranges_fn=p_tighten, **opts)
                            _sp.set(feasible=pfp.feasible)
                        if pfp.feasible:
                            break
                    if pfp.feasible:
                        p_layer_format = pfp.formats_dict()
                        prov["layer_format"] = "resynthesized"
                        p_meta["format_mean_bits"] = pfp.mean_bits(flops)
                    else:
                        prov["layer_format"] = "uncertified"
                p_meta["format_certified"] = p_layer_format is not None
            p_meta["map_provenance"] = dict(prov)
            meta["profile_certificates"][str(p_seq)] = p_meta
            if p_ok:
                ok_profiles.append(p_seq)
                profile_certs.append(certificate(
                    uniform_k, prep, layer_k=p_layer_k,
                    layer_format=p_layer_format,
                    extra_meta={"map_provenance": dict(prov),
                                "profile_seq": int(p_seq)},
                    class_key_=(f"lm/{arch_cfg.name}/tokens"
                                f"[{batch}x{p_seq}]seed{seed}")))
            else:
                obs.event("certify.profile_uncertified", seq=int(p_seq),
                          k=int(uniform_k))
        meta["profile_ladders"] = {
            str(p): {"probes": lad.probes, "compiles": lad.compiles}
            for p, lad in prof_ladders.items()}

    cs = CertificateSet(
        model_id=f"lm/{arch_name}", params_digest=digest,
        certificates=[cert] + profile_certs, p_star=None, meta=meta)

    # -- serving summary: merged cost vs the legacy raise-until-feasible ----
    from repro.core import formats as F

    def _k_bits(m):
        # k-bit mantissa in a binary32 carrier (sign + 8 exponent bits)
        return MX.flop_weighted_mean_k(m, flops) + 8.0

    def _f_bits(fm):
        tot = sum(flops.values()) or 1.0
        return sum(
            flops[s] * F.from_dict(fm.get(s, fm[""])).total_bits
            for s in scope_keys) / tot

    def _serving_bits(cs_):
        sf_ = cs_.serving_layer_format
        if sf_ is not None:
            return _f_bits(sf_), "formats"
        sk_ = cs_.serving_layer_k
        if sk_ is not None:
            return _k_bits(sk_), "mixed"
        return float(uniform_k + 8.0), "uniform"

    baseline_bits, baseline_src = float(uniform_k + 8.0), "uniform"
    if layer_format is not None and all(
            p_format_whole.get(p, False) for p in ok_profiles):
        # every class wholesale-confirmed the primary format map — the
        # legacy merge equals today's
        sf = cs.serving_layer_format
        if sf is not None:
            baseline_bits, baseline_src = _f_bits(sf), "formats"
    elif layer_k is not None:
        old_maps = [layer_k] + [p_old_maps.get(p) for p in ok_profiles]
        if all(m is not None for m in old_maps):
            merged_old = {s: max(m[s] for m in old_maps)
                          for s in scope_keys}
            baseline_bits, baseline_src = _k_bits(merged_old), "mixed"

    if cs.serving_layer_format is not None:
        serving_bits, _src = _serving_bits(cs)
        if serving_bits > baseline_bits:
            # a resynthesized format map made the merged format map pricier
            # than the legacy serving — drop the PROFILE format maps so the
            # set demotes to the mixed merge, which the scope-wise cap
            # above keeps ≤ the legacy merge
            obs.event("certify.profile_format_maps_dropped",
                      merged_bits=float(serving_bits),
                      baseline_bits=float(baseline_bits))
            profile_certs = [
                dataclasses.replace(
                    c, layer_format=None,
                    meta=dict(c.meta, map_provenance=dict(
                        c.meta.get("map_provenance", {}),
                        layer_format="dropped-pricier-than-mixed")))
                for c in profile_certs]
            cs = CertificateSet(
                model_id=f"lm/{arch_name}", params_digest=digest,
                certificates=[cert] + profile_certs, p_star=None,
                meta=meta)

    serving_bits, serving_src = _serving_bits(cs)
    differ = any(
        v == "resynthesized"
        for p in cs.map_provenance().values() for v in p.values())
    meta["serving"] = {
        "mean_bits_flop_weighted": float(serving_bits),
        "map_source": serving_src,
        "raised_baseline_mean_bits": float(baseline_bits),
        "raised_baseline_source": baseline_src,
        "profile_maps_differ": bool(differ),
        "provenance": cs.map_provenance(),
    }
    return finish(cs)


def _satisfied_by(k: Optional[int]) -> List[str]:
    from repro.core import formats as F

    if k is None:
        return []
    return sorted(f.name for f in F.REGISTRY.values() if f.k >= k)
