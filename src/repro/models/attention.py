"""Attention variants: GQA/MQA (+ sliding window, softcap, QKV-bias), MLA.

Backend-generic (CAA-analysable); the decode paths take a KV cache of raw
arrays and an absolute position, covering the ``decode_*``/``long_*`` shape
families. Softmax here is *the* paper object: its abs→rel error conversion
(×≤5.5) is what keeps low-precision attention accurate.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import layers as L


class KVCache(NamedTuple):
    k: jax.Array       # [B, Smax, K, Dh]  (MLA: compressed c_kv [B, Smax, R])
    v: jax.Array       # [B, Smax, K, Dh]  (MLA: rope key     [B, Smax, Dr])
    index: jax.Array   # int32 tokens already present: scalar, or [B] when
    #                    lanes advance independently (continuous batching)


def _cache_write(buf, upd, index):
    """Append ``upd`` into ``buf`` at sequence offset ``index`` (dim 1 of
    [B, Smax, ...]). A scalar index writes the whole batch at one offset
    (the classic lock-step decode); a [B] vector writes each lane at its
    own offset (continuous batching) via a vmapped per-lane update."""
    if getattr(index, "ndim", 0) == 0:
        z = jnp.zeros((), index.dtype)
        starts = (z, index) + (z,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, upd, starts)

    def one(b, u, i):
        starts = (i,) + (jnp.zeros((), i.dtype),) * (b.ndim - 1)
        return jax.lax.dynamic_update_slice(b, u, starts)

    return jax.vmap(one)(buf, upd, index)


def _mask5(mask):
    """Broadcast a [q,kv] (shared) or [B,q,kv] (per-lane) mask to the
    [B,K,G,q,s] score layout."""
    if mask.ndim == 3:
        return mask[:, None, None, :, :]
    return mask[None, None, None, :, :]


def _split_heads(bk, x, n_heads: int, d_head: int):
    b, s, _ = bk.shape_of(x)
    return bk.reshape(x, (b, s, n_heads, d_head))


def gqa_attention(
    bk, x, p, *,
    n_heads: int, n_kv_heads: int, d_head: int,
    cos, sin, mask,
    softcap: Optional[float] = None,
    qkv_bias: bool = False,
    cache: Optional[KVCache] = None,
    q_offset=0,
    fused_decode: bool = False,
):
    """Grouped-query attention. x: [B,S,d]. Returns (out, new_cache).

    With ``cache`` set this is a decode/prefill step at absolute position
    ``q_offset``; keys/values are appended into the cache buffers.
    ``fused_decode`` (set by the caller only when the mask is plain causal)
    offers the S==1 step to ``bk.decode_attention`` — the certificate-aware
    flash decode hook; a backend returning None falls back to the composed
    einsum/softmax path.
    """
    B, S, d = bk.shape_of(x)
    G = n_heads // n_kv_heads

    q = bk.matmul(x, bk.param(p["wq"]))
    k = bk.matmul(x, bk.param(p["wk"]))
    v = bk.matmul(x, bk.param(p["wv"]))
    if qkv_bias:
        q = bk.add(q, bk.param(p["bq"]))
        k = bk.add(k, bk.param(p["bk"]))
        v = bk.add(v, bk.param(p["bv"]))

    q = _split_heads(bk, q, n_heads, d_head)
    k = _split_heads(bk, k, n_kv_heads, d_head)
    v = _split_heads(bk, v, n_kv_heads, d_head)

    q = L.apply_rope(bk, q, cos, sin)
    k = L.apply_rope(bk, k, cos, sin)

    new_cache = None
    if cache is not None:
        kr = bk.value_of(k).astype(cache.k.dtype)
        vr = bk.value_of(v).astype(cache.v.dtype)
        ck = _cache_write(cache.k, kr, cache.index)
        cv = _cache_write(cache.v, vr, cache.index)
        new_cache = KVCache(ck, cv, cache.index + S)
        if fused_decode and S == 1 and not softcap:
            lengths = new_cache.index
            if getattr(lengths, "ndim", 0) == 0:
                lengths = jnp.full((B,), lengths, jnp.int32)
            q4 = bk.reshape(q, (B, n_kv_heads, G, d_head))
            fused = bk.decode_attention(q4, ck, cv,
                                        lengths.astype(jnp.int32))
            if fused is not None:
                out = bk.reshape(fused, (B, S, n_heads * d_head))
                return bk.matmul(out, bk.param(p["wo"])), new_cache
        k = bk.input(ck)
        v = bk.input(cv)

    # group the query heads: [B,S,K,G,Dh]; in training, hint sequence
    # parallelism on q (shards the S×S score tensor over "model")
    if cache is None:
        q = bk.shard_hint(q, "q_seq")
    q = bk.reshape(q, (B, S, n_kv_heads, G, d_head))
    scale = d_head ** -0.5
    scores = bk.einsum("bqkgd,bskd->bkgqs", q, k)
    scores = bk.scale(scores, scale)
    if softcap:
        scores = bk.softcap(scores, softcap)
    neg = bk.const(L.NEG_BIG)
    scores = bk.where(_mask5(mask), scores, neg)
    probs = bk.softmax(scores, axis=-1)
    probs = bk.record("attn_probs", probs, kind="softmax")
    out = bk.einsum("bkgqs,bskd->bqkgd", probs, v)
    if bk.is_analysis:
        # convex-combination fact: Σ_s probs = 1, probs ≥ 0 ⇒ out lies in
        # the value hull (IA cannot see the simplex constraint)
        vlo = jnp.min(v.exact.lo, axis=1)[:, None, :, None, :]
        vhi = jnp.max(v.exact.hi, axis=1)[:, None, :, None, :]
        out = bk.clamp_range(out, vlo, vhi)
    out = bk.reshape(out, (B, S, n_heads * d_head))
    out = bk.matmul(out, bk.param(p["wo"]))
    return out, new_cache


def init_gqa(key, d: int, n_heads: int, n_kv_heads: int, d_head: int,
             qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, n_heads * d_head),
        "wk": L.dense_init(ks[1], d, n_kv_heads * d_head),
        "wv": L.dense_init(ks[2], d, n_kv_heads * d_head),
        "wo": L.dense_init(ks[3], n_heads * d_head, d),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), jnp.float32)
    return p


# --------------------------------------------------------------------------
# Multi-head Latent Attention (MiniCPM3 / DeepSeek style)
# --------------------------------------------------------------------------

def mla_attention(
    bk, x, p, *,
    n_heads: int, q_rank: int, kv_rank: int,
    d_nope: int, d_rope: int, d_v: int,
    cos, sin, mask,
    cache: Optional[KVCache] = None,
    q_offset=0,
):
    """MLA: queries via low-rank down/up; KV via a shared compressed latent
    (cached) + a shared rope key. Decode uses the absorbed form (scores in
    latent space) so the cache stays [B,S,kv_rank(+d_rope)].

    Chained low-rank GEMMs are exactly two γ_n contractions in the CAA view
    (see DESIGN.md arch table)."""
    B, S, d = bk.shape_of(x)
    H = n_heads

    # --- queries ---
    qc = bk.matmul(x, bk.param(p["wq_a"]))              # [B,S,q_rank]
    qc = L.rmsnorm(bk, qc, p["q_norm"])
    q = bk.matmul(qc, bk.param(p["wq_b"]))              # [B,S,H*(dn+dr)]
    q = bk.reshape(q, (B, S, H, d_nope + d_rope))
    q_nope = bk.slice(q, (Ellipsis, slice(0, d_nope)))
    q_rope = bk.slice(q, (Ellipsis, slice(d_nope, d_nope + d_rope)))
    q_rope = L.apply_rope(bk, q_rope, cos, sin)

    # --- compressed KV latent ---
    ckv = bk.matmul(x, bk.param(p["wkv_a"]))            # [B,S,kv_rank+dr]
    c = bk.slice(ckv, (Ellipsis, slice(0, kv_rank)))
    k_rope = bk.slice(ckv, (Ellipsis, slice(kv_rank, kv_rank + d_rope)))
    c = L.rmsnorm(bk, c, p["kv_norm"])
    k_rope = L.apply_rope(
        bk, bk.reshape(k_rope, (B, S, 1, d_rope)), cos, sin
    )
    k_rope = bk.reshape(k_rope, (B, S, d_rope))

    new_cache = None
    if cache is not None:
        cr = bk.value_of(c).astype(cache.k.dtype)
        rr = bk.value_of(k_rope).astype(cache.v.dtype)
        cc = _cache_write(cache.k, cr, cache.index)
        crp = _cache_write(cache.v, rr, cache.index)
        new_cache = KVCache(cc, crp, cache.index + S)
        c = bk.input(cc)
        k_rope = bk.input(crp)

    # absorbed scores: q_nope projected into latent space through W_uk
    # wkv_b packs [kv_rank, H*(dn+dv)] → W_uk = [...,:dn], W_uv = [...,dn:]
    wkv_b = bk.param(p["wkv_b"])
    wkv_b = bk.reshape(wkv_b, (kv_rank, H, d_nope + d_v))
    w_uk = bk.slice(wkv_b, (Ellipsis, slice(0, d_nope)))
    w_uv = bk.slice(wkv_b, (Ellipsis, slice(d_nope, d_nope + d_v)))
    q_lat = bk.einsum("bqhd,rhd->bqhr", q_nope, w_uk)   # [B,S,H,kv_rank]
    s_nope = bk.einsum("bqhr,bsr->bhqs", q_lat, c)
    s_rope = bk.einsum("bqhd,bsd->bhqs", q_rope, k_rope)
    scale = (d_nope + d_rope) ** -0.5
    scores = bk.scale(bk.add(s_nope, s_rope), scale)
    neg = bk.const(L.NEG_BIG)
    mb = mask[:, None, :, :] if mask.ndim == 3 else mask[None, None, :, :]
    scores = bk.where(mb, scores, neg)
    probs = bk.softmax(scores, axis=-1)
    probs = bk.record("attn_probs", probs, kind="softmax")
    out_lat = bk.einsum("bhqs,bsr->bqhr", probs, c)     # [B,S,H,kv_rank]
    if bk.is_analysis:
        clo = jnp.min(c.exact.lo, axis=1)[:, None, None, :]
        chi = jnp.max(c.exact.hi, axis=1)[:, None, None, :]
        out_lat = bk.clamp_range(out_lat, clo, chi)
    out = bk.einsum("bqhr,rhd->bqhd", out_lat, w_uv)    # [B,S,H,dv]
    out = bk.reshape(out, (B, S, H * d_v))
    out = bk.matmul(out, bk.param(p["wo"]))
    return out, new_cache


def init_mla(key, d: int, n_heads: int, q_rank: int, kv_rank: int,
             d_nope: int, d_rope: int, d_v: int):
    ks = jax.random.split(key, 5)
    return {
        "wq_a": L.dense_init(ks[0], d, q_rank),
        "wq_b": L.dense_init(ks[1], q_rank, n_heads * (d_nope + d_rope)),
        "wkv_a": L.dense_init(ks[2], d, kv_rank + d_rope),
        "wkv_b": L.dense_init(ks[3], kv_rank, n_heads * (d_nope + d_v)),
        "wo": L.dense_init(ks[4], n_heads * d_v, d),
        "q_norm": jnp.ones((q_rank,), jnp.float32),
        "kv_norm": jnp.ones((kv_rank,), jnp.float32),
    }
