"""State-space / linear-attention layers: RWKV6 ("Finch") and Mamba-lite.

TPU adaptation (DESIGN.md): the CUDA reference evaluates the recurrence
token-by-token; on TPU we use a *chunked* formulation — scan over chunks of
L tokens, with intra-chunk interactions as dense MXU-friendly einsums whose
decay exponents are all ≤ 0 (numerically stable by construction), and an
[B,H,C,Cv] state carried between chunks. Decode is the O(1) single-step
recurrence.

Under CAA analysis (bk.is_analysis) the recurrence is bounded through
``bk.ssm_scan`` — the geometric fixpoint rule (caa.scan_affine_fixpoint):
data-dependent decay w = exp(-exp(·)) ∈ (0,1) gives contraction, so error
grows like 1/(1−w̄), not linearly in T — the key to finite 500k-token
bounds.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import layers as L


# --------------------------------------------------------------------------
# RWKV6 time mix
# --------------------------------------------------------------------------

def init_rwkv_tmix(key, d: int, n_heads: int, lora_rank: int = 64):
    ks = jax.random.split(key, 9)
    C = d // n_heads
    return {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "wr": L.dense_init(ks[0], d, d),
        "wk": L.dense_init(ks[1], d, d),
        "wv": L.dense_init(ks[2], d, d),
        "wg": L.dense_init(ks[3], d, d),
        "wo": L.dense_init(ks[4], d, d),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x@A)@B))
        "w0": jnp.full((d,), -0.6, jnp.float32),
        "wA": L.dense_init(ks[5], d, lora_rank),
        "wB": L.dense_init(ks[6], lora_rank, d, scale=0.01),
        "u": jax.random.normal(ks[7], (n_heads, C), jnp.float32) * 0.3,
        "ln_out": jnp.ones((d,), jnp.float32),
    }


class RwkvState(NamedTuple):
    S: jax.Array        # [B, H, C, C] wkv state
    x_prev: jax.Array   # [B, d] last token (for token shift)


def _token_shift(bk, x, mu, x_prev=None):
    """lerp(x, shift(x, 1), mu) — RWKV's 1-token lookback (exact gather)."""
    B, S, d = bk.shape_of(x)
    xv = bk.value_of(x)
    if x_prev is None:
        prev = jnp.concatenate([jnp.zeros_like(xv[:, :1]), xv[:, :-1]], axis=1)
    else:
        # shift states may live in a narrower cache format (fp8)
        prev = jnp.concatenate([x_prev.astype(xv.dtype)[:, None, :],
                                xv[:, :-1]], axis=1)
    prev = bk.input(prev)
    m = bk.param(mu)
    return bk.add(bk.mul(x, m), bk.mul(prev, bk.shift(bk.neg(m), 1.0)))


def rwkv_tmix(bk, x, p, *, n_heads: int, chunk: int = 32,
              state: Optional[RwkvState] = None):
    """x: [B,S,d] → ([B,S,d], new_state). S=1 with state = decode step."""
    B, S, d = bk.shape_of(x)
    C = d // n_heads
    xp = state.x_prev if state is not None else None

    xr = _token_shift(bk, x, p["mu_r"], xp)
    xk = _token_shift(bk, x, p["mu_k"], xp)
    xv = _token_shift(bk, x, p["mu_v"], xp)
    xw = _token_shift(bk, x, p["mu_w"], xp)
    xg = _token_shift(bk, x, p["mu_g"], xp)

    r = bk.matmul(xr, bk.param(p["wr"]))
    k = bk.matmul(xk, bk.param(p["wk"]))
    v = bk.matmul(xv, bk.param(p["wv"]))
    g = bk.silu(bk.matmul(xg, bk.param(p["wg"])))

    # data-dependent decay (the Finch feature): w ∈ (0,1) per channel
    dw = bk.matmul(bk.tanh(bk.matmul(xw, bk.param(p["wA"]))), bk.param(p["wB"]))
    w_log = bk.neg(bk.exp(bk.add(bk.param(p["w0"]), dw)))   # = log w  (≤ 0)

    hsplit = lambda t: bk.reshape(t, (B, S, n_heads, C))
    r, k, v = hsplit(r), hsplit(k), hsplit(v)
    w_log = hsplit(w_log)
    u = bk.param(p["u"])

    if bk.is_analysis:
        out, new_S = _wkv_analysis(bk, r, k, v, w_log, u, S)
    else:
        out, new_S = _wkv_chunked(bk, r, k, v, w_log, u,
                                  chunk=chunk,
                                  S0=None if state is None else state.S)
    out = bk.reshape(out, (B, S, d))
    out = L.rmsnorm(bk, out, p["ln_out"])
    out = bk.mul(out, g)
    out = bk.matmul(out, bk.param(p["wo"]))
    xv_last = bk.value_of(x)[:, -1, :]
    return out, RwkvState(new_S, xv_last)


def _wkv_chunked(bk, r, k, v, w_log, u, *, chunk: int, S0=None):
    """Chunked WKV (jnp path). All decay exponents ≤ 0 → stable."""
    r, k, v, w_log = map(bk.value_of, (r, k, v, w_log))
    u = bk.value_of(u) if not isinstance(u, jax.Array) else u
    B, T, H, C = r.shape
    Lc = min(chunk, T)
    n_chunks = (T + Lc - 1) // Lc
    pad = n_chunks * Lc - T
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=0.0)
    rs = r.reshape(B, n_chunks, Lc, H, C).swapaxes(0, 1)
    ks = k.reshape(B, n_chunks, Lc, H, C).swapaxes(0, 1)
    vs = v.reshape(B, n_chunks, Lc, H, C).swapaxes(0, 1)
    ws = w_log.reshape(B, n_chunks, Lc, H, C).swapaxes(0, 1)

    if S0 is None:
        S0 = jnp.zeros((B, H, C, C), r.dtype)
    else:
        S0 = S0.astype(r.dtype)  # cache may store a narrower format (fp8)

    causal = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)  # strict lower: i > j

    def one_chunk(S, xs):
        rc, kc, vc, wc = xs                         # [B,Lc,H,C]
        la = jnp.cumsum(wc, axis=1)                  # inclusive cumulative log-decay
        la_shift = la - wc                           # la_{i-1} (0 for i=0)
        # inter-chunk: r_i decayed from chunk start × carried state
        rdec = rc * jnp.exp(la_shift)
        out = jnp.einsum("blhc,bhcv->blhv", rdec, S)
        # intra-chunk: pairwise decay factors exp(la_{i-1} - la_j), i > j
        Dexp = jnp.exp(
            jnp.clip(la_shift[:, :, None] - la[:, None, :], -60.0, 0.0)
        )                                            # [B,Lc(i),Lc(j),H,C]
        kD = kc[:, None, :, :, :] * Dexp
        scores = jnp.einsum("bihc,bijhc->bijh", rc, kD)
        scores = scores * causal[None, :, :, None]
        out = out + jnp.einsum("bijh,bjhv->bihv", scores, vc)
        # current-token bonus u
        diag = jnp.einsum("bihc,bihc->bih", rc, u[None, None] * kc)
        out = out + diag[..., None] * vc
        # state update: S' = exp(la_L)⊙S + Σ_j exp(la_L - la_j) k_j ⊗ v_j
        dec_all = jnp.exp(la[:, -1])                 # [B,H,C]
        kdec = kc * jnp.exp(
            jnp.clip(la[:, -1][:, None] - la, -60.0, 0.0)
        )
        S_new = dec_all[..., None] * S + jnp.einsum("bjhc,bjhv->bhcv", kdec, vc)
        return S_new, out

    S_fin, outs = jax.lax.scan(one_chunk, S0, (rs, ks, vs, ws))
    out = outs.swapaxes(0, 1).reshape(B, n_chunks * Lc, H, C)
    if pad:
        out = out[:, :T]
    return bk.input(out) if bk.is_analysis else out, S_fin


def _wkv_analysis(bk, r, k, v, w_log, u, T):
    """CAA path: bound the recurrence by the geometric fixpoint rule."""
    B, S, H, C = bk.shape_of(r)
    w = bk.exp(w_log)                                # decay ∈ (0,1)
    drive = bk.mul(
        bk.reshape(k, (B, S, H, C, 1)), bk.reshape(v, (B, S, H, 1, C))
    )
    states = bk.ssm_scan(bk.reshape(w, (B, S, H, C, 1)), drive, S, time_axis=1)
    out = bk.einsum("bshc,bshcv->bshv", r, states)
    bonus = bk.mul(bk.mul(r, bk.broadcast_to(u, (B, S, H, C))), k)
    out = bk.add(out, bk.mul(bk.sum(bonus, axis=-1, keepdims=True), v))
    S_fin = jnp.zeros((B, H, C, C))
    return out, S_fin


# --------------------------------------------------------------------------
# RWKV channel mix
# --------------------------------------------------------------------------

def init_rwkv_cmix(key, d: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": L.dense_init(ks[0], d, d_ff),
        "wv": L.dense_init(ks[1], d_ff, d),
        "wr": L.dense_init(ks[2], d, d),
    }


def rwkv_cmix(bk, x, p, x_prev=None):
    xk = _token_shift(bk, x, p["mu_k"], x_prev)
    xr = _token_shift(bk, x, p["mu_r"], x_prev)
    k = bk.relu(bk.matmul(xk, bk.param(p["wk"])))
    k = bk.square(k)
    kv = bk.matmul(k, bk.param(p["wv"]))
    return bk.mul(bk.sigmoid(bk.matmul(xr, bk.param(p["wr"]))), kv)


# --------------------------------------------------------------------------
# Mamba-lite (hymba's SSM heads)
# --------------------------------------------------------------------------

def init_mamba(key, d: int, d_inner: int, d_state: int = 16):
    ks = jax.random.split(key, 6)
    return {
        "w_in": L.dense_init(ks[0], d, d_inner),
        "w_gate": L.dense_init(ks[1], d, d_inner),
        "w_B": L.dense_init(ks[2], d_inner, d_state),
        "w_C": L.dense_init(ks[3], d_inner, d_state),
        "w_dt": L.dense_init(ks[4], d_inner, 1, scale=0.1),
        "A_log": jnp.log(jnp.linspace(1.0, d_state, d_state, dtype=jnp.float32)),
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": L.dense_init(ks[5], d_inner, d),
    }


def mamba_lite(bk, x, p, *, d_state: int = 16, h0: Optional[jax.Array] = None,
               return_state: bool = False):
    """Selective-SSM head (simplified): per-channel state of size d_state.

    x: [B,S,d] → y [B,S,d] (or (y, h_last [B,din,N]) if return_state)."""
    B, S, d = bk.shape_of(x)
    xin = bk.matmul(x, bk.param(p["w_in"]))              # [B,S,din]
    gate = bk.silu(bk.matmul(x, bk.param(p["w_gate"])))
    din = bk.shape_of(xin)[-1]

    # data-dependent dt > 0, per token/channel (softplus via exp/log1p)
    dt_raw = bk.matmul(xin, bk.param(p["w_dt"]))         # [B,S,1]
    dt = bk.log(bk.shift(bk.exp(dt_raw), 1.0))           # softplus
    Bm = bk.matmul(xin, bk.param(p["w_B"]))              # [B,S,N]
    Cm = bk.matmul(xin, bk.param(p["w_C"]))              # [B,S,N]

    # decay = exp(-dt·exp(A_log)) ∈ (0,1):   [B,S,1,N]
    A = bk.exp(bk.param(p["A_log"]))
    neg_dtA = bk.neg(bk.mul(bk.reshape(dt, (B, S, 1, 1)),
                            bk.reshape(A, (1, 1, 1, d_state))))
    decay = bk.exp(neg_dtA)
    # drive = dt · x ⊗ B:                    [B,S,din,N]
    drive = bk.mul(
        bk.reshape(bk.mul(xin, dt), (B, S, din, 1)),
        bk.reshape(Bm, (B, S, 1, d_state)),
    )

    if bk.is_analysis:
        hs = bk.ssm_scan(decay, drive, S, time_axis=1)   # [B,S,din,N]
        y = bk.einsum("bsdn,bsn->bsd", hs, Cm)
        h_fin = jnp.zeros((B, din, d_state))
    else:
        y, h_fin = _mamba_scan_project(
            bk.value_of(decay), bk.value_of(drive), bk.value_of(Cm),
            None if h0 is None else h0,
        )
        y = bk.input(y)
    y = bk.add(y, bk.mul(xin, bk.param(p["D"])))
    y = bk.mul(y, gate)
    out = bk.matmul(y, bk.param(p["w_out"]))
    return (out, h_fin) if return_state else out


def _mamba_scan_project(decay, drive, C, h0=None):
    """Scan that projects the state down inside the loop (never materialises
    [B,S,din,N])."""
    B, S, din, N = drive.shape
    dec = jnp.moveaxis(decay, 1, 0)
    drv = jnp.moveaxis(drive, 1, 0)
    Cs = jnp.moveaxis(C, 1, 0)

    def body(h, xs):
        d, b, c = xs
        h = d * h + b                                    # [B,din,N]
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    h0 = (jnp.zeros((B, din, N), drive.dtype) if h0 is None
          else h0.astype(drive.dtype))  # fp8-stored state upcasts at use
    h_fin, ys = jax.lax.scan(body, h0, (dec, drv, Cs))
    return jnp.moveaxis(ys, 0, 1), h_fin
